"""Ternary-matmul kernel microbenchmarks + serving-path measurements.

Sections (all emit ``name,us_per_call,derived`` rows):
  * ``ternary_matmul_shapes`` — impl axis (xla vs pallas) over decode-shaped
    rows (M ∈ {1, 8, 32}: the continuous-batching regime) and prefill-shaped
    rows. On CPU the Pallas rows run in interpret mode (correctness-path
    timing, not TPU latency) and are annotated as such.
  * ``decode_blocking`` — shape-aware skinny-M blocks (select_blocks) vs the
    historical pad-M-to-256 baseline at decode shapes.
  * ``fused_epilogue`` — epilogue-fused kernel (scales applied in VMEM, no
    (M, N) int32 intermediate in HBM) vs raw kernel + separate XLA rescale.
  * ``fused_prologue`` — two-phase act-quant-prologue kernel (raw bf16/f32
    in, int8 quantization inside the kernel's phase-0 K sweep) vs the
    separate act_quant + known-scale fused kernel, decode rows M ∈ {1,8,32}.
  * ``expert_eloop`` — ONE E-loop launch over all experts (fused gate‖up)
    vs E vmapped per-expert XLA launches, decode-ish capacities C ∈ {1,8,32}.
  * ``fused_projection`` — one fused wq‖wk‖wv launch vs three separate
    projections (falcon3-7b-ish dims), including act-quant.
  * ``flash_decode`` — streaming flash-decode attention over the tiered KV
    cache, capacity × length sweep. Three timings per row: the
    length-predicated kernel at the target length, the SAME kernel at full
    occupancy (lengths = capacity — the unpredicated ceiling, the
    pallas-vs-pallas proxy structure of ``decode_blocking``), and the
    masked full-capacity XLA path. The quantity the kernel optimizes —
    KV tokens streamed per step — is recorded per row
    (``kv_tokens_streamed`` vs the capacity the XLA path always touches):
    that ratio is what the per-slot BlockSpec parking converts into
    elided HBM copies on real TPU. CPU interpret wall time can NOT show
    it: the interpreter pays a fixed per-grid-step cost and executes
    parked copies anyway, so ``predication_win`` hovers near 1x on CPU
    and the xla column wins wall-clock outright — see the honest-proxy
    note in docs/kernels.md.
  * ``flash_prefill`` — streaming flash-prefill attention: fresh-prompt
    causal sweep (the upper-triangle kv blocks a q block never needs are
    parked — ``kv_blocks_streamed`` out of the full q×kv grid is the
    causal-skip ledger) and a chunked continuation over a populated
    tiered cache (streams the slots' prefixes, not the capacity). Same
    honest-proxy caveat as flash_decode: the ledgers, not CPU interpret
    wall time, are the signal.
  * ``packing_density`` / ``serving_token_rate`` — unchanged ledgers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_us
from repro.core import bitlinear, packing
from repro.kernels import ops

# decode-shaped (continuous-batching) + prefill-shaped rows
BENCH_SHAPES = (
    (1, 2048, 2048),
    (8, 2048, 2048),
    (32, 2048, 2048),
    (16, 2048, 8192),
    (128, 4096, 4096),
)


def _interpreted() -> bool:
    return jax.default_backend() == "cpu"


def _iters(impl: str) -> int:
    # Pallas-interpret on CPU is the correctness path, not a speed path;
    # keep the bench wall-time bounded.
    return 2 if (impl == "pallas" and _interpreted()) else 5


def _note(impl: str) -> str:
    return "pallas-interpret" if (impl == "pallas" and _interpreted()) else impl


def _random_packed(k: int, n: int, codec: str, seed: int = 1):
    wq = jax.random.randint(jax.random.PRNGKey(seed), (k, n), -1, 2, dtype=jnp.int8)
    pack = packing.pack2 if codec == "pack2" else packing.pack243
    return pack(wq)


def ternary_matmul_shapes() -> list:
    rows = []
    for m, k, n in BENCH_SHAPES:
        xq = jax.random.randint(jax.random.PRNGKey(0), (m, k), -128, 128, dtype=jnp.int8)
        for codec in ("pack2", "pack243"):
            packed = _random_packed(k, n, codec)
            for impl in ("xla", "pallas"):
                if impl == "pallas" and _interpreted() and m > 32:
                    continue  # interpret-mode prefill rows add minutes, no signal
                fn = jax.jit(
                    lambda x, p, codec=codec, impl=impl, k=k: ops.ternary_matmul(
                        x, p, k=k, codec=codec, impl=impl
                    )
                )
                us = time_us(lambda: jax.block_until_ready(fn(xq, packed)),
                             iters=_iters(impl))
                flops = 2.0 * m * k * n
                rows.append(row(
                    f"kernel/ternary_{impl}_{codec}_{m}x{k}x{n}", us,
                    f"gflops={flops/us/1e3:.2f} impl={_note(impl)} "
                    f"bytes_per_w={8/(4 if codec=='pack2' else 5):.1f}bit"))
    return rows


def decode_blocking() -> list:
    """Skinny-M auto blocks vs the pad-to-256 baseline at decode shapes."""
    rows = []
    k, n, codec = 2048, 2048, "pack2"
    packed = _random_packed(k, n, codec)
    for m in (1, 8, 32):
        xq = jax.random.randint(jax.random.PRNGKey(0), (m, k), -128, 128, dtype=jnp.int8)
        variants = {
            "auto": dict(),  # select_blocks: bm=32, bn=512, bk=1024
            "pad256": dict(block_m=256, block_n=256, block_k=512),
        }
        t = {}
        for name, kw in variants.items():
            fn = jax.jit(lambda x, p, kw=kw: ops.ternary_matmul(
                x, p, k=k, codec=codec, impl="pallas", **kw))
            t[name] = time_us(lambda: jax.block_until_ready(fn(xq, packed)),
                              iters=_iters("pallas"))
        bm, bn, bk = ops.select_blocks(m, n, k, codec)
        rows.append(row(
            f"kernel/decode_blocking_m{m}", t["auto"],
            f"pad256_us={t['pad256']:.1f} speedup={t['pad256']/t['auto']:.2f}x "
            f"blocks={bm}x{bn}x{bk} impl={_note('pallas')}"))
    return rows


def fused_epilogue() -> list:
    """Epilogue fusion: scaled-float out of the kernel vs raw int32 kernel +
    separate XLA rescale pass over an (M, N) int32 HBM intermediate."""
    rows = []
    k, n, codec = 2048, 2048, "pack2"
    packed = _random_packed(k, n, codec)
    for m in (8, 32):
        xq = jax.random.randint(jax.random.PRNGKey(0), (m, k), -128, 128, dtype=jnp.int8)
        xs = jax.random.uniform(jax.random.PRNGKey(1), (m, 1)) + 0.5
        cs = jax.random.uniform(jax.random.PRNGKey(2), (n,)) + 0.5

        fused = jax.jit(lambda x, p, s, c: ops.ternary_matmul_fused(
            x, p, s, c, k=k, codec=codec, impl="pallas"))
        unfused = jax.jit(lambda x, p, s, c: (
            ops.ternary_matmul(x, p, k=k, codec=codec, impl="pallas")
            .astype(jnp.float32) * (c / s)))
        t_f = time_us(lambda: jax.block_until_ready(fused(xq, packed, xs, cs)),
                      iters=_iters("pallas"))
        t_u = time_us(lambda: jax.block_until_ready(unfused(xq, packed, xs, cs)),
                      iters=_iters("pallas"))
        rows.append(row(
            f"kernel/fused_epilogue_m{m}", t_f,
            f"unfused_us={t_u:.1f} int32_hbm_intermediate_bytes=0 "
            f"(unfused={4*m*n}) impl={_note('pallas')}"))
    return rows


def fused_prologue() -> list:
    """Act-quant prologue fusion: raw floats into the two-phase kernel vs
    the separate act_quant pass + known-scale fused kernel. The eliminated
    HBM traffic per call: one (M, K) int8 write + read."""
    from repro.core.ternary import act_quant

    rows = []
    k, n, codec = 2048, 2048, "pack2"
    packed = _random_packed(k, n, codec)
    cs = jax.random.uniform(jax.random.PRNGKey(2), (n,)) + 0.5
    for m in (1, 8, 32):
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
        fused = jax.jit(lambda xx: ops.ternary_matmul_actq(
            xx, packed, cs, k=k, codec=codec, impl="pallas"))
        two_pass = jax.jit(lambda xx: (lambda q: ops.ternary_matmul_fused(
            q.xq, packed, q.scale, cs, k=k, codec=codec, impl="pallas"
        ))(act_quant(xx)))
        t_f = time_us(lambda: jax.block_until_ready(fused(x)),
                      iters=_iters("pallas"))
        t_u = time_us(lambda: jax.block_until_ready(two_pass(x)),
                      iters=_iters("pallas"))
        rows.append(row(
            f"kernel/fused_prologue_m{m}", t_f,
            f"two_pass_us={t_u:.1f} int8_hbm_intermediate_bytes=0 "
            f"(two_pass={m*k}) impl={_note('pallas')}"))
    return rows


def expert_eloop() -> list:
    """E-loop expert kernel: ONE launch over all experts (pack-time-fused
    gate‖up, act-quant prologue) vs the vmapped per-expert XLA path —
    decode-ish capacities on mixtral-ish expert dims (scaled down to keep
    interpret-mode wall time bounded)."""
    from repro.models.pack import _pack_weight, fuse_packed

    rows = []
    e, d, ff, codec = 4, 1024, 1024, "pack2"
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    w_g = jax.random.normal(keys[0], (e, d, ff)) * d**-0.5
    w_u = jax.random.normal(keys[1], (e, d, ff)) * d**-0.5
    fused_leaf = fuse_packed([_pack_weight(w_g, codec), _pack_weight(w_u, codec)])
    for c in (1, 8, 32):
        x = jax.random.normal(keys[2], (e, c, d))
        f_one = jax.jit(lambda xx: bitlinear.expert_packed_matmul(
            fused_leaf, xx, impl="pallas"))
        f_vmap = jax.jit(lambda xx: bitlinear.expert_packed_matmul(
            fused_leaf, xx, impl="xla"))
        t_f = time_us(lambda: jax.block_until_ready(f_one(x)),
                      iters=_iters("pallas"))
        t_v = time_us(lambda: jax.block_until_ready(f_vmap(x)),
                      iters=_iters("pallas"))
        rows.append(row(
            f"kernel/expert_eloop_c{c}", t_f,
            f"vmapped_xla_us={t_v:.1f} launches=1_vs_{e} experts={e} "
            f"impl={_note('pallas')}"))
    return rows


def fused_projection() -> list:
    """One fused wq‖wk‖wv launch vs three separate projections (act-quant
    included) — the serving-path QKV shape (d=2048, h*hd=2048, g*hd=512)."""
    from repro.models.pack import fuse_packed

    rows = []
    d, widths = 2048, (2048, 512, 512)
    keys = jax.random.split(jax.random.PRNGKey(7), len(widths) + 1)
    pws = [
        bitlinear.quantize_pack(
            {"w": jax.random.normal(kk, (d, w)) * d**-0.5}, codec="pack2")
        for kk, w in zip(keys, widths)
    ]
    fused_leaf = fuse_packed(pws)
    impl = "pallas"
    for m in (1, 32):
        x = jax.random.normal(keys[-1], (m, d))
        f_one = jax.jit(lambda xx: bitlinear.packed_matmul(fused_leaf, xx, impl=impl))
        f_sep = jax.jit(lambda xx: tuple(
            bitlinear.packed_matmul(pw, xx, impl=impl) for pw in pws))
        t_f = time_us(lambda: jax.block_until_ready(f_one(x)), iters=_iters(impl))
        t_s = time_us(lambda: jax.block_until_ready(f_sep(x)), iters=_iters(impl))
        rows.append(row(
            f"kernel/fused_qkv_m{m}", t_f,
            f"separate_us={t_s:.1f} speedup={t_s/t_f:.2f}x launches=1_vs_3 "
            f"impl={_note(impl)}"))
    return rows


def flash_decode() -> list:
    """Flash-decode attention over (capacity, length) decode shapes.

    All slots sit at ``length`` so each row isolates the predication
    effect: the kernel touches ``ceil(hot_valid/bs) + ceil(cold_valid/bs)``
    live S-blocks per slot and parks the rest, the full-occupancy run of
    the SAME kernel is the unpredicated ceiling, and the XLA path pays
    the padded capacity regardless of length."""
    from repro.core import kv_cache as kvc
    from repro.kernels import flash_decode as fd
    from repro.kernels.ops import select_blocks

    def filled(cap, length):
        cache = kvc.init_cache(b, hot, cap - hot, (g, d), jnp.bfloat16)
        ks = jax.random.normal(jax.random.PRNGKey(0), (b, length, g, d))
        vs = jax.random.normal(jax.random.PRNGKey(1), (b, length, g, d))
        return kvc.append(cache, ks, vs)

    rows = []
    b, g, rep, d, hot = 4, 4, 4, 128, 32
    for cap, length in ((128, 16), (128, 96), (512, 32), (2048, 48)):
        cache = filled(cap, length)
        full = filled(cap, cap)  # every S-block live: unpredicated ceiling
        q = jax.random.normal(jax.random.PRNGKey(2), (b, g * rep, d),
                              jnp.bfloat16)
        f_p = jax.jit(lambda qq, cc: fd.flash_decode_attention(
            qq, cc, impl="pallas"))
        f_x = jax.jit(lambda qq, cc: fd.flash_decode_attention(
            qq, cc, impl="xla"))
        t_p = time_us(lambda: jax.block_until_ready(f_p(q, cache)),
                      iters=_iters("pallas"))
        t_f = time_us(lambda: jax.block_until_ready(f_p(q, full)),
                      iters=_iters("pallas"))
        t_x = time_us(lambda: jax.block_until_ready(f_x(q, cache)),
                      iters=_iters("pallas"))
        bs = select_blocks(rep, d, cap, "pack2", kind="decode_attn")[2]
        bs_hot, bs_cold = min(bs, hot), min(bs, cap - hot)
        total = -(-hot // bs_hot) + -(-(cap - hot) // bs_cold)
        live_h = -(-min(length, hot) // bs_hot)
        live_c = -(-max(length - hot, 0) // bs_cold)
        streamed = live_h * bs_hot + live_c * bs_cold
        rows.append(row(
            f"kernel/flash_decode_cap{cap}_len{length}", t_p,
            f"full_occupancy_us={t_f:.1f} predication_win={t_f/t_p:.2f}x "
            f"xla_us={t_x:.1f} s_blocks_streamed={live_h + live_c}/{total} "
            f"kv_tokens_streamed={streamed}_vs_capacity={cap} "
            f"block_s={bs} impl={_note('pallas')}"))
    return rows


def flash_prefill() -> list:
    """Flash-prefill attention: fresh-prompt causal sweep + a chunked
    continuation row over a populated tiered cache.

    The quantity the kernel optimizes is the causal-skip / predication
    ledger — ``kv_blocks_streamed`` out of the full q×kv grid for fresh
    prompts (upper-triangle blocks park), and cache S-blocks touched vs
    capacity for the continuation (a chunk at offset 448 streams ~448
    cached tokens, not the 1024-token capacity). CPU interpret wall time
    can NOT show either win (fixed per-grid-step interpreter cost,
    parked copies still execute — the same honest-proxy caveat as
    flash_decode in docs/kernels.md); the xla column is the production
    CPU path (blockwise / tiered_chunk_attention composition).
    """
    from repro.core import kv_cache as kvc
    from repro.kernels import flash_prefill as fpk

    rows = []
    b, h, g, d, theta = 2, 8, 4, 64, 1e6
    # -- fresh prompts: causal skip across the q-block x kv-block grid --
    for s, bq, bs in ((256, 64, 64), (512, 128, 128)):
        ks = jax.random.split(jax.random.PRNGKey(s), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, s, g, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, s, g, d), jnp.bfloat16)
        f_p = jax.jit(lambda q, k, v, bq=bq, bs=bs: fpk.flash_prefill_attention(
            q, k, v, None, rope_theta=theta, impl="pallas",
            block_q=bq, block_s=bs))
        f_x = jax.jit(lambda q, k, v, bq=bq, bs=bs: fpk.flash_prefill_attention(
            q, k, v, None, rope_theta=theta, impl="xla",
            block_q=bq, block_s=bs))
        t_p = time_us(lambda: jax.block_until_ready(f_p(q, k, v)[0]),
                      iters=_iters("pallas"))
        t_x = time_us(lambda: jax.block_until_ready(f_x(q, k, v)[0]),
                      iters=_iters("pallas"))
        nq, n_new = -(-s // bq), -(-s // bs)
        live = sum(
            min((qi * bq + bq - 1) // bs, n_new - 1) + 1 for qi in range(nq)
        )
        rows.append(row(
            f"kernel/flash_prefill_s{s}", t_p,
            f"xla_us={t_x:.1f} kv_blocks_streamed={live}/{nq * n_new} "
            f"causal_skip={1 - live / (nq * n_new):.2f} "
            f"block_q={bq} block_s={bs} impl={_note('pallas')}"))
    # -- chunked continuation: a 64-token chunk at offset 448 of a
    # 1024-capacity cache streams only the slots' own prefixes ---------
    cap, hot, off, c = 1024, 32, 448, 64
    cache = kvc.init_cache(b, hot, cap - hot, (g, d), jnp.bfloat16)
    hist_k = jax.random.normal(jax.random.PRNGKey(7), (b, off, g, d))
    hist_v = jax.random.normal(jax.random.PRNGKey(8), (b, off, g, d))
    cache = kvc.append(cache, hist_k, hist_v)
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (b, c, h, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, c, g, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, c, g, d), jnp.bfloat16)
    f_p = jax.jit(lambda q, k, v, cc: fpk.flash_prefill_attention(
        q, k, v, cc, rope_theta=theta, impl="pallas"))
    f_x = jax.jit(lambda q, k, v, cc: fpk.flash_prefill_attention(
        q, k, v, cc, rope_theta=theta, impl="xla"))
    t_p = time_us(lambda: jax.block_until_ready(f_p(q, k, v, cache)[0]),
                  iters=_iters("pallas"))
    t_x = time_us(lambda: jax.block_until_ready(f_x(q, k, v, cache)[0]),
                  iters=_iters("pallas"))
    bs = ops.select_blocks(h // g, d, c, "pack2", kind="prefill_attn")[2]
    bs_hot, bs_cold = min(bs, hot), min(bs, cap - hot)
    streamed = (
        -(-min(off, hot) // bs_hot) * bs_hot
        + -(-max(off - hot, 0) // bs_cold) * bs_cold + c
    )
    rows.append(row(
        f"kernel/flash_prefill_chunk{c}_off{off}", t_p,
        f"xla_us={t_x:.1f} kv_tokens_streamed={streamed}_vs_capacity={cap + c} "
        f"block_s={bs} impl={_note('pallas')}"))
    return rows


def packing_density() -> list:
    n = 1_000_000
    rows = []
    for codec in ("none", "pack2", "pack243"):
        b = packing.packed_bytes(n, codec)
        rows.append(row(f"kernel/density_{codec}", 0.0,
                        f"bytes_per_million_weights={b} bits_per_w={8*b/n:.2f}"))
    return rows


def serving_token_rate(steps: int = 8) -> list:
    """Packed-weight decode throughput on the falcon3 smoke config (CPU)."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine

    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, hot_cap=8, max_len=96)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
    res = eng.generate(prompts, max_new_tokens=steps)
    toks = res.steps * prompts.shape[0]
    return [
        row("serving/decode_smoke", res.wall_s / max(res.steps, 1) * 1e6,
            f"tokens={toks} ext_reduction={100*res.external_reduction:.1f}% "
            f"weight_reloads={eng.weight_loads}"),
    ]
