"""Ternary-matmul kernel microbenchmarks + serving-path measurements."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_us
from repro.core import packing
from repro.kernels import ops


def ternary_matmul_shapes() -> list:
    rows = []
    for m, k, n in [(1, 2048, 2048), (16, 2048, 8192), (128, 4096, 4096)]:
        xq = jax.random.randint(jax.random.PRNGKey(0), (m, k), -128, 128, dtype=jnp.int8)
        wq = jax.random.randint(jax.random.PRNGKey(1), (k, n), -1, 2, dtype=jnp.int8)
        for codec in ("pack2", "pack243"):
            pack = packing.pack2 if codec == "pack2" else packing.pack243
            packed = pack(wq)
            fn = jax.jit(
                lambda x, p: ops.ternary_matmul(x, p, k=k, codec=codec, impl="xla")
            )
            us = time_us(lambda: jax.block_until_ready(fn(xq, packed)), iters=5)
            flops = 2.0 * m * k * n
            rows.append(row(f"kernel/ternary_{codec}_{m}x{k}x{n}", us,
                            f"gflops={flops/us/1e3:.2f} bytes_per_w={8/ (4 if codec=='pack2' else 5):.1f}bit"))
    return rows


def packing_density() -> list:
    n = 1_000_000
    rows = []
    for codec in ("none", "pack2", "pack243"):
        b = packing.packed_bytes(n, codec)
        rows.append(row(f"kernel/density_{codec}", 0.0,
                        f"bytes_per_million_weights={b} bits_per_w={8*b/n:.2f}"))
    return rows


def serving_token_rate(steps: int = 8) -> list:
    """Packed-weight decode throughput on the falcon3 smoke config (CPU)."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine

    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, hot_cap=8, max_len=96)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
    res = eng.generate(prompts, max_new_tokens=steps)
    toks = res.steps * prompts.shape[0]
    return [
        row("serving/decode_smoke", res.wall_s / max(res.steps, 1) * 1e6,
            f"tokens={toks} ext_reduction={100*res.external_reduction:.1f}% "
            f"weight_reloads={eng.weight_loads}"),
    ]
