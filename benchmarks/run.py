"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:
  table1/*  LoRA parameter % across the Falcon3 family   (Table I)
  table2/*  adapter-placement ablation                   (Table II)
  table3/*  hardware comparison column                   (Table III)
  fig1a/*   CiROM full-model area estimates              (Fig. 1a)
  fig5b/*   DR eDRAM access-reduction sweep              (Fig. 5b)
  fig6a/*   LoRA quantization-bit ablation (measured)    (Fig. 6a)
  kernel/*  ternary matmul + packing microbenchmarks
  serving/* packed decode + DR traffic (measured), plus the
            continuous-batching vs lock-step throughput comparison

Run:  PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the trained ablation")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_tables, serving_bench

    rows: list = []
    sections = [
        ("table1", paper_tables.table1),
        ("table2", paper_tables.table2),
        ("table3", paper_tables.table3),
        ("fig1a", paper_tables.fig1a),
        ("fig5b", paper_tables.fig5b),
        ("kernel/density", kernel_bench.packing_density),
        ("kernel/matmul", kernel_bench.ternary_matmul_shapes),
        ("serving", kernel_bench.serving_token_rate),
        ("serving/continuous", serving_bench.serving_throughput),
    ]
    if not args.fast:
        sections.append(("fig6a", paper_tables.fig6a))

    failures = 0
    for name, fn in sections:
        try:
            rows.extend(fn())
        except AssertionError as e:
            failures += 1
            rows.append(f"{name}/REPRODUCTION-MISMATCH,0.0,{e}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            rows.append(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")

    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    if failures:
        print(f"\n{failures} section(s) failed", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
