"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:
  table1/*  LoRA parameter % across the Falcon3 family   (Table I)
  table2/*  adapter-placement ablation                   (Table II)
  table3/*  hardware comparison column                   (Table III)
  fig1a/*   CiROM full-model area estimates              (Fig. 1a)
  fig5b/*   DR eDRAM access-reduction sweep              (Fig. 5b)
  fig6a/*   LoRA quantization-bit ablation (measured)    (Fig. 6a)
  kernel/*  ternary matmul + packing microbenchmarks: impl axis
            (xla vs pallas), decode-shaped rows, shape-aware blocking vs
            pad-to-256, fused epilogue, fused QKV projections, and the
            flash-decode attention capacity × length sweep
  serving/* packed decode + DR traffic (measured), the
            continuous-batching vs lock-step throughput comparison,
            chunked vs grouped admission, prefix sharing, the overload
            degradation sweep, the speculative-decoding K x
            draft-quality sweep (tokens per verify round + ledger), and
            the router-failover replicas x kill-rate sweep (goodput +
            migration ledger, bit-exactness asserted under kills)

Run:  PYTHONPATH=src python -m benchmarks.run [--fast] [--only PREFIX]
                                              [--json [PATH]]

``--only kernel`` runs just the kernel sections; ``--json`` additionally
records the rows as structured JSON, split by section family: kernel and
paper-table rows land in PATH (default BENCH_kernels.json), ``serving/``
rows in BENCH_serving.json next to it.
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the trained ablation")
    ap.add_argument("--only", default=None,
                    help="run only sections whose name starts with this prefix")
    ap.add_argument("--json", nargs="?", const="BENCH_kernels.json", default=None,
                    help="also write rows as JSON (default: BENCH_kernels.json)")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_tables, serving_bench

    rows: list = []
    sections = [
        ("table1", paper_tables.table1),
        ("table2", paper_tables.table2),
        ("table3", paper_tables.table3),
        ("fig1a", paper_tables.fig1a),
        ("fig5b", paper_tables.fig5b),
        ("kernel/density", kernel_bench.packing_density),
        ("kernel/matmul", kernel_bench.ternary_matmul_shapes),
        ("kernel/decode_blocking", kernel_bench.decode_blocking),
        ("kernel/fused_epilogue", kernel_bench.fused_epilogue),
        ("kernel/fused_prologue", kernel_bench.fused_prologue),
        ("kernel/expert_eloop", kernel_bench.expert_eloop),
        ("kernel/fused_qkv", kernel_bench.fused_projection),
        ("kernel/flash_decode", kernel_bench.flash_decode),
        ("kernel/flash_prefill", kernel_bench.flash_prefill),
        ("serving", kernel_bench.serving_token_rate),
        ("serving/continuous", serving_bench.serving_throughput),
        ("serving/admission", serving_bench.chunked_admission),
        ("serving/prefix", serving_bench.shared_prefix),
        ("serving/overload", serving_bench.overload),
        ("serving/speculative", serving_bench.speculative_sweep),
        ("serving/router", serving_bench.router_failover),
        ("serving/sdc", serving_bench.sdc_resilience),
    ]
    if not args.fast:
        sections.append(("fig6a", paper_tables.fig6a))
    if args.only:
        sections = [(n, f) for n, f in sections if n.startswith(args.only)]

    failures = 0
    for name, fn in sections:
        try:
            rows.extend(fn())
        except AssertionError as e:
            failures += 1
            rows.append(f"{name}/REPRODUCTION-MISMATCH,0.0,{e}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            rows.append(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")

    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    if args.json:
        import os

        import jax

        backend = jax.default_backend()
        # serving rows go to their own artifact: the CI conformance job
        # diffs BENCH_serving.json (scheduling + speculation ledgers)
        # independently of the kernel-latency file
        serving_path = os.path.join(
            os.path.dirname(args.json) or ".", "BENCH_serving.json")
        buckets = {args.json: [], serving_path: []}
        for r in rows:
            name, us, derived = r.split(",", 2)
            path = serving_path if name.startswith("serving") else args.json
            buckets[path].append({"name": name, "us_per_call": float(us),
                                  "derived": derived})
        for path, structured in buckets.items():
            if not structured:
                continue
            with open(path, "w") as f:
                json.dump({"backend": backend, "rows": structured},
                          f, indent=1)
            print(f"\nwrote {len(structured)} rows to {path}",
                  file=sys.stderr)
    if failures:
        print(f"\n{failures} section(s) failed", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
