"""Reproductions of the paper's tables/figures (analytic + measured).

  table1  — Falcon3 1/3/7/10B LoRA parameter %   (paper: 0.30/0.25/0.22/0.23)
  table2  — SQuAD adapter-placement ablation %   (paper: 0.37/0.16/0.19/0.22/0.59)
  table3  — BitROM hardware comparison column    (20.8/5.2 TOPS/W, 4967 kb/mm², -43.6%)
  fig1a   — CiROM full-model area estimates
  fig5b   — DR eDRAM external-access reduction sweep
  fig6a   — LoRA quantization-bit ablation (synthetic-task loss recovery)

Quality metrics (EM/F1/ROUGE) need the trained Falcon3 checkpoints +
datasets (offline-gated, see DESIGN.md §7); every architectural column is
reproduced exactly.
"""

from __future__ import annotations

from benchmarks.common import falcon3_config, lora_dims_for, row
from repro.core import dr_edram
from repro.core.lora import adapter_param_fraction
from repro.hwmodel import model as hw

PAPER_TABLE1 = {"falcon3-1b": 0.30, "falcon3-3b": 0.25, "falcon3-7b": 0.22,
                "falcon3-10b": 0.23}

TABLE2_COMBOS = [
    (("q", "k", "g", "u"), 0.37),
    (("down",), 0.16),
    (("o", "down"), 0.19),
    (("v", "o", "down"), 0.22),  # the paper's configuration
    (("q", "k", "v", "o", "g", "u", "down"), 0.59),
]


def table1() -> list:
    rows = []
    for member, paper_pct in PAPER_TABLE1.items():
        cfg = falcon3_config(member)
        pct = 100 * adapter_param_fraction(
            lora_dims_for(cfg, ("v", "o", "down")), cfg.param_count()
        )
        ok = abs(pct - paper_pct) <= 0.02
        rows.append(row(f"table1/{member}", 0.0,
                        f"lora_pct={pct:.3f} paper={paper_pct} match={ok}"))
        assert ok, (member, pct, paper_pct)
    return rows


def table2() -> list:
    cfg = falcon3_config("falcon3-7b")
    rows = []
    for targets, paper_pct in TABLE2_COMBOS:
        pct = 100 * adapter_param_fraction(
            lora_dims_for(cfg, targets), cfg.param_count()
        )
        ok = abs(pct - paper_pct) <= 0.02
        rows.append(row(f"table2/{'+'.join(targets)}", 0.0,
                        f"lora_pct={pct:.3f} paper={paper_pct} match={ok}"))
        assert ok, (targets, pct, paper_pct)
    return rows


def table3() -> list:
    from repro.configs import get_config

    dep = hw.falcon3_deployment(get_config("falcon3-1b"))
    rows = [
        row("table3/tops_per_w_a4", 0.0, f"{hw.TOPS_PER_W_A4}"),
        row("table3/tops_per_w_a8", 0.0, f"{hw.TOPS_PER_W_A8}"),
        row("table3/bit_density_kb_mm2", 0.0, f"{hw.BIT_DENSITY_KB_MM2}"),
        row("table3/density_x_dcirom", 0.0, f"{hw.density_ratio_vs_dcirom():.2f}"),
        row("table3/kv_optimization_pct", 0.0, f"{-100*dep['kv_reduction']:.1f}"),
        row("table3/update_free", 0.0, "true_weights_resident"),
        row("table3/edram_mib", 0.0, f"{dep['edram_mib']:.2f}"),
    ]
    return rows


def fig1a() -> list:
    d = hw.DCIROM_TASK_DENSITY_KB_MM2
    cases = [
        ("resnet56_8b", 0.85e6, 8.0, d),
        ("llama7b_8b", 7e9, 8.0, d),
        ("bitnet1b_1.58b", 1e9, 1.58, d),
        ("bitnet1b_bitrom", 1e9, 1.58, hw.BIT_DENSITY_KB_MM2),
    ]
    rows = []
    for name, n, bits, dens in cases:
        rows.append(row(f"fig1a/{name}", 0.0,
                        f"area_cm2={hw.model_area_estimate_cm2(n, bits, dens):.2f}"))
    return rows


def fig5b() -> list:
    rows = []
    for s, cols in dr_edram.fig5b_sweep().items():
        vals = " ".join(f"B{b}={100*r:.1f}%" for b, r in cols.items())
        rows.append(row(f"fig5b/seq{s}", 0.0, vals))
    # headline
    rows.append(row("fig5b/headline_s128_b32", 0.0,
                    f"{100*dr_edram.closed_form_reduction(128,32):.1f}% (paper 43.6%)"))
    return rows


def fig6a(steps: int = 40) -> list:
    """LoRA weight-bit ablation (paper Fig 6a protocol: quantize a *trained*
    adapter, measure the impact).

    Claim reproduced: 6-bit adapter weights are ~lossless vs 8-bit, with
    monotone degradation at lower widths. We train one rank-4 adapter on a
    frozen ternary base, then evaluate the SAME adapter under 2/4/6/8-bit
    weight quantization — isolating quantization error from training noise:
      * delta error = ||Δy(bits) − Δy(fp)|| / ||Δy(fp)||  (deterministic)
      * eval CE at each width (informational)
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.core import lora as lora_lib
    from repro.data.pipeline import DataConfig, batch_at_step
    from repro.training import loop as train_loop
    from repro.training import train_lib

    base = get_smoke_config("falcon3-1b")
    cfg = dataclasses.replace(
        base, bitnet=dataclasses.replace(base.bitnet, lora_rank=4, lora_bits=8)
    )
    r = train_loop.train(cfg, steps=steps, global_batch=8, seq_len=32,
                         lora_only=True, verbose=False, seed=1)
    params = r["params"]

    # deterministic adapter-delta quantization error on one trained adapter
    # (layer 0 of the stacked attention lora_v)
    blk = params["blocks"]["attn"]["lora_v"]
    one = {"a": blk["a"][0], "b": blk["b"][0]}
    x = jax.random.normal(jax.random.PRNGKey(0), (16, one["a"].shape[0]))
    ref_delta = lora_lib.apply(one, x, weight_bits=16)
    rows = []
    errs = {}
    for bits in (2, 4, 6, 8):
        d = lora_lib.apply(one, x, weight_bits=bits)
        err = float(jnp.linalg.norm(d - ref_delta) / (jnp.linalg.norm(ref_delta) + 1e-9))
        errs[bits] = err
        rows.append(row(f"fig6a/delta_err_{bits}bit", 0.0, f"{err:.4f}"))

    # eval CE under each quantization width
    batch = batch_at_step(cfg, DataConfig(seed=1), steps + 1, 8, 32)
    for bits in (2, 6, 8):
        cb = dataclasses.replace(
            cfg, bitnet=dataclasses.replace(cfg.bitnet, lora_rank=4, lora_bits=bits)
        )
        loss, _ = train_lib.loss_fn(params, cb, batch)
        rows.append(row(f"fig6a/eval_ce_{bits}bit", 0.0, f"{float(loss):.4f}"))
        jax.clear_caches()

    ok = errs[6] < 0.05 and errs[2] > errs[4] > errs[6] > errs[8]
    rows.append(row("fig6a/6bit_lossless_and_monotone", 0.0, f"{ok}"))
    assert ok, errs
    return rows
