"""Serving-throughput benchmark: continuous batching vs lock-step batches.

Workload: a queue of mixed-length requests (prompt lengths and generation
budgets drawn from small sets, like real traffic). Two ways to serve it
with the same engine and the same weights:

  * lock-step (the seed engine's model): requests grouped by prompt
    length, each group decoded as an aligned batch for the *longest*
    budget in the group — short requests burn dispatches as padding until
    the longest finishes, and the next group waits for the whole batch to
    drain.
  * continuous (this PR): a fixed pool of slots, per-slot lengths, done
    slots retire mid-flight and queued prompts prefill into the freed
    rows while the other slots keep decoding.

Both paths issue one jitted dispatch per decode step with no per-step
host sync; the difference measured here is purely scheduling: useful
tokens per decode-dispatch-row and wall-clock tokens/s.

Run:  PYTHONPATH=src python -m benchmarks.serving_bench
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro.serving.scheduler import Request


def _workload(vocab: int, n_requests: int = 24, seed: int = 0):
    rng = np.random.RandomState(seed)
    p_lens = [4, 8, 16]
    budgets = [2, 8, 32]  # heavy-tailed decode lengths, like real traffic
    reqs = []
    for i in range(n_requests):
        p = int(p_lens[rng.randint(len(p_lens))])
        m = int(budgets[rng.randint(len(budgets))])
        toks = rng.randint(0, vocab, size=(p,)).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks, max_new_tokens=m))
    return reqs


def _serve_lockstep(eng, reqs, slots: int):
    """Seed-style serving at the same device batch width: aligned groups
    of up to ``slots`` same-length prompts, each batch drains completely
    (everyone decodes to the batch max budget) before the next starts."""
    done_tokens = 0
    dispatch_rows = 0
    groups: dict = {}
    for r in reqs:
        groups.setdefault(r.prompt_len, []).append(r)
    for p_len, group in sorted(groups.items()):
        for i in range(0, len(group), slots):
            batch_reqs = group[i : i + slots]
            prompts = np.stack([r.tokens for r in batch_reqs])
            budget = max(r.max_new_tokens for r in batch_reqs)
            res = eng.generate(jax.numpy.asarray(prompts), max_new_tokens=budget)
            res.tokens.block_until_ready()
            done_tokens += sum(r.max_new_tokens for r in batch_reqs)  # useful
            dispatch_rows += budget * len(batch_reqs)  # rows dispatched
    return done_tokens, dispatch_rows


def _serve_continuous(eng, reqs, slots: int):
    fin = eng.serve(reqs, slots=slots, sync_every=8)
    useful = sum(len(f.tokens) for f in fin)
    return useful, fin


def serving_throughput(slots: int = 4) -> list:
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine

    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, hot_cap=8, max_len=64, slots=slots)
    reqs = _workload(cfg.vocab_size)

    # warm both paths over the full workload once so every (group, prompt)
    # shape is compiled, then time a second pass
    _serve_continuous(eng, reqs, slots)
    _serve_lockstep(eng, reqs, slots)

    t0 = time.perf_counter()
    useful_c, fin = _serve_continuous(eng, reqs, slots)
    t_cont = time.perf_counter() - t0

    t0 = time.perf_counter()
    useful_l, rows_l = _serve_lockstep(eng, reqs, slots)
    t_lock = time.perf_counter() - t0

    assert useful_c == useful_l, (useful_c, useful_l)
    tps_c = useful_c / t_cont
    tps_l = useful_l / t_lock
    return [
        row("serving/continuous", t_cont / max(useful_c, 1) * 1e6,
            f"tok_s={tps_c:.1f} slots={slots} requests={len(reqs)}"),
        row("serving/lockstep", t_lock / max(useful_l, 1) * 1e6,
            f"tok_s={tps_l:.1f} padded_rows={rows_l} useful={useful_l}"),
        row("serving/speedup", 0.0,
            f"continuous_vs_lockstep={tps_c / tps_l:.2f}x"),
    ]


def chunked_admission(slots: int = 4) -> list:
    """Chunked vs grouped admission on a length-diverse workload.

    Same engine weights, same requests, greedy tokens asserted equal.
    The separating axis is prefill *compilations*: grouped admission
    compiles one XLA prefill per (group_size, prompt_len) shape it
    encounters — a cold-start cost that grows with traffic diversity —
    while chunked admission compiles its fixed (slots, chunk) dispatch
    exactly once and admits any length mix immediately (no waiting for a
    same-length partner, no head-of-line blocking on odd lengths).
    """
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine

    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(1)
    p_lens = [3, 4, 5, 7, 9, 12, 16, 17]  # deliberately diverse
    reqs = []
    for i in range(24):
        p = int(p_lens[rng.randint(len(p_lens))])
        toks = rng.randint(0, cfg.vocab_size, size=(p,)).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks, max_new_tokens=int(
            [2, 8, 16][rng.randint(3)])))

    eng_g = Engine(cfg, params, hot_cap=8, max_len=64, slots=slots)
    eng_c = Engine(cfg, params, hot_cap=8, max_len=64, slots=slots,
                   prefill_chunk=8)
    # warm both so compile cost is not in the timed pass (it IS the
    # recorded compile-count signal)
    fin_g = eng_g.serve(list(reqs), slots=slots)
    fin_c = eng_c.serve(list(reqs), slots=slots)
    tok_g = {f.rid: f.tokens.tolist() for f in fin_g}
    tok_c = {f.rid: f.tokens.tolist() for f in fin_c}
    assert tok_g == tok_c, "admission modes must agree on greedy tokens"

    t0 = time.perf_counter()
    fin_g = eng_g.serve(list(reqs), slots=slots)
    t_g = time.perf_counter() - t0
    t0 = time.perf_counter()
    fin_c = eng_c.serve(list(reqs), slots=slots)
    t_c = time.perf_counter() - t0
    useful = sum(len(f.tokens) for f in fin_c)
    compiles_g = eng_g._prefill._cache_size()
    compiles_c = eng_c._chunk_step_fn._cache_size()
    return [
        row("serving/admission_grouped", t_g / max(useful, 1) * 1e6,
            f"tok_s={useful / t_g:.1f} prefill_compiles={compiles_g} "
            f"(per (group,prompt_len) shape)"),
        row("serving/admission_chunked", t_c / max(useful, 1) * 1e6,
            f"tok_s={useful / t_c:.1f} prefill_compiles={compiles_c} "
            f"chunk=8 (one fixed (slots,chunk) shape)"),
    ]


def shared_prefix(slots: int = 4, n_users: int = 8) -> list:
    """Shared-system-prompt workload under paged serving with prefix
    sharing: ``n_users`` requests carry one common prefix (a system
    prompt) plus a short private suffix.

    Three runs over the same requests — contiguous chunked (baseline),
    paged without sharing, paged with the refcounted prefix tree — with
    greedy tokens asserted bit-exact across all three. The sweep reports:

      * ``prefix_tokens_reused`` per the finished-request ledger (every
        request after the first skips prefilling the shared pages),
      * the physical page footprint: with sharing the prefix occupies ONE
        set of pool pages adopted by every slot (asserted through the
        pool's refcount ledger: a shared page has > 1 reader),
      * the DR external-read reduction: the closed-form prompt ledger
        delta vs the unshared run reconciles token-for-token with the
        reuse count (the same identity tests/test_paged.py asserts).
    """
    from repro.configs import get_smoke_config
    from repro.core import kv_cache as kvc
    from repro.models import transformer as T
    from repro.serving.engine import Engine

    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(3)
    hot_cap, max_len, ps, chunk = 8, 96, 8, 8
    system = rng.randint(0, cfg.vocab_size, size=(41,)).astype(np.int32)
    reqs = [
        Request(
            rid=i,
            tokens=np.concatenate(
                [system,
                 rng.randint(0, cfg.vocab_size, size=(4,)).astype(np.int32)]
            ),
            max_new_tokens=8,
        )
        for i in range(n_users)
    ]

    def build(**kw):
        return Engine(cfg, params, hot_cap=hot_cap, max_len=max_len,
                      slots=slots, prefill_chunk=chunk, **kw)

    eng_c = build()
    eng_u = build(paged=True, page_size=ps, prefix_sharing=False)
    eng_s = build(paged=True, page_size=ps)

    runs = {}
    for name, eng in (("contig", eng_c), ("paged", eng_u), ("shared", eng_s)):
        eng.serve(list(reqs), slots=slots)  # warm
        t0 = time.perf_counter()
        fin = eng.serve(list(reqs), slots=slots)
        runs[name] = (time.perf_counter() - t0, {f.rid: f for f in fin})

    base = runs["contig"][1]
    for name in ("paged", "shared"):
        for r in reqs:
            assert (runs[name][1][r.rid].tokens.tolist()
                    == base[r.rid].tokens.tolist()), (name, r.rid)

    fin_s, fin_u = runs["shared"][1], runs["paged"][1]
    reused = sum(f.prefix_tokens_reused for f in fin_s.values())
    assert reused > 0, "shared-prefix workload reused nothing"
    # physical sharing: the tree's prefix pages were concurrently mapped
    # by live slots — the pool holds ONE copy, not one per user
    pool, tree = eng_s._last_pool, eng_s._last_ptree
    tree_pages = set(tree.tree_pages())
    assert tree_pages and all(pool.refs[p] == 1 for p in tree_pages)
    # ... and after every slot retired, that one copy is ALL that's left
    assert pool.used() == len(tree_pages)
    # the external-read delta vs the unshared run reconciles with the
    # reuse ledger through the closed-form resumed prompt traffic
    tb = eng_s._kv_token_bytes()
    saved_bytes = 0
    for r in reqs:
        m = fin_s[r.rid].prefix_tokens_reused
        full = kvc.prompt_traffic_tokens(r.prompt_len, hot_cap)
        res = kvc.prompt_traffic_tokens_resumed(r.prompt_len, m, hot_cap)
        delta = fin_u[r.rid].traffic["ext_read"] - fin_s[r.rid].traffic["ext_read"]
        assert delta == (full["ext_read"] - res["ext_read"]) * tb, r.rid
        saved_bytes += delta
    useful = sum(len(f.tokens) for f in fin_s.values())
    prefix_pages = len(tree_pages)
    return [
        row("serving/prefix_contig", runs["contig"][0] / max(useful, 1) * 1e6,
            f"tok_s={useful / runs['contig'][0]:.1f} users={n_users} "
            f"prefix_len={len(system)}"),
        row("serving/prefix_paged", runs["paged"][0] / max(useful, 1) * 1e6,
            f"tok_s={useful / runs['paged'][0]:.1f} reused=0 (sharing off)"),
        row("serving/prefix_shared", runs["shared"][0] / max(useful, 1) * 1e6,
            f"tok_s={useful / runs['shared'][0]:.1f} reused={reused}tok "
            f"prefix_pages={prefix_pages} (one physical copy) "
            f"ext_read_saved={saved_bytes}B"),
    ]


def overload(slots: int = 4) -> list:
    """Graceful-degradation sweep: the same burst served against a
    shrinking page pool (1x / 0.5x / 0.25x of the default sizing).

    Each row records goodput (useful tokens/s) and the degradation
    counters from ``Engine.last_stats``: preemptions, pages grown
    on demand, and the recompute-token overhead preemption paid vs the
    prefix-sharing savings that re-admission recovered
    (``prefix_tokens_reused``). Greedy tokens are asserted BIT-EXACT
    across every pool size — pressure changes scheduling, never output.
    A final row bounds the queue (``max_queue``) to show explicit
    backpressure shedding instead of unbounded buffering.
    """
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.chaos import check_serving_invariants
    from repro.serving.engine import Engine

    Rq = Request
    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(7)
    hot_cap, max_len, ps, chunk = 8, 64, 8, 8
    system = rng.randint(0, cfg.vocab_size, size=(17,)).astype(np.int32)
    reqs = []
    for i in range(16):  # half the burst shares a system prompt
        suffix = rng.randint(0, cfg.vocab_size,
                             size=(int(rng.randint(2, 6)),)).astype(np.int32)
        toks = (np.concatenate([system, suffix]) if i % 2 == 0
                else rng.randint(0, cfg.vocab_size,
                                 size=(int(rng.randint(6, 18)),))
                .astype(np.int32))
        reqs.append(Rq(rid=i, tokens=toks,
                       max_new_tokens=int([4, 8, 16][rng.randint(3)])))

    def build(n_pages=None, max_queue=None):
        return Engine(cfg, params, hot_cap=hot_cap, max_len=max_len,
                      slots=slots, prefill_chunk=chunk, paged=True,
                      page_size=ps, n_pages=n_pages, max_queue=max_queue)

    full_pool = build()._pool_pages(slots)
    out, base_tokens = [], None
    for frac in (1.0, 0.5, 0.25):
        n_pages = max(8, int(full_pool * frac))  # >= any request's peak
        eng = build(n_pages=n_pages)
        mk = [Rq(r.rid, r.tokens, r.max_new_tokens) for r in reqs]
        eng.serve(mk, slots=slots)  # warm (compiles)
        mk = [Rq(r.rid, r.tokens, r.max_new_tokens) for r in reqs]
        t0 = time.perf_counter()
        fin = {f.rid: f for f in eng.serve(
            mk, slots=slots, on_iteration=check_serving_invariants)}
        dt = time.perf_counter() - t0
        st = eng.last_stats
        assert all(f.outcome == "finished" for f in fin.values())
        toks = {rid: f.tokens.tolist() for rid, f in fin.items()}
        if base_tokens is None:
            base_tokens = toks
        else:  # pressure degrades throughput, never correctness
            assert toks == base_tokens, f"tokens diverged at pool x{frac}"
        useful = sum(len(t) for t in toks.values())
        reused = sum(f.prefix_tokens_reused for f in fin.values())
        out.append(row(
            f"serving/overload_pool_x{frac:g}",
            dt / max(useful, 1) * 1e6,
            f"tok_s={useful / dt:.1f} pages={n_pages} "
            f"preemptions={st.preemptions} grown={st.grown_pages} "
            f"recompute={st.recompute_tokens}tok reused={reused}tok",
        ))
    # explicit backpressure: a bounded queue sheds instead of buffering
    eng = build(n_pages=full_pool, max_queue=6)
    mk = [Rq(r.rid, r.tokens, r.max_new_tokens) for r in reqs]
    fin = eng.serve(mk, slots=slots)
    shed = sum(f.outcome == "rejected" for f in fin)
    served = sum(f.outcome == "finished" for f in fin)
    assert shed == eng.last_stats.rejected and shed + served == len(reqs)
    out.append(row(
        "serving/overload_backpressure", 0.0,
        f"max_queue=6 burst={len(reqs)} served={served} shed={shed}",
    ))
    return out


def speculative_sweep(slots: int = 2) -> list:
    """Draft-verify speculation sweep: K × draft quality → tokens per
    decode round.

    Three draft models span the acceptance axis without training
    anything: the target itself (every proposal accepted — the
    acceptance=1.0 ceiling), an untrained tiny draft (near-random
    agreement — the realistic floor before distillation), and
    ``spec_force="reject"`` (every proposal rejected — the adversarial
    worst case, pure overhead). For each (K, draft) cell the row
    records tokens emitted per verify round, the realized acceptance
    rate, and the drafted/accepted ledger.

    Everything is asserted, not just reported: greedy tokens bit-exact
    against the non-speculative engine for every cell, the per-request
    identity ``emitted == accepted + rounds`` (each round emits the
    accepted prefix plus the target's own next token), the aggregate
    stats reconciling with the per-request ledgers, and the ceiling
    cells actually clearing 1 token/round.
    """
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import speculative as spec_lib
    from repro.serving.engine import Engine

    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    dcfg = spec_lib.make_draft_config(cfg)
    dparams = T.init_params(jax.random.PRNGKey(7), dcfg)
    rng = np.random.RandomState(11)
    new = 16
    prompts = [rng.randint(0, cfg.vocab_size, size=(p,)).astype(np.int32)
               for p in (6, 9, 13, 8)]

    def mk():
        return [Request(rid=i, tokens=t, max_new_tokens=new)
                for i, t in enumerate(prompts)]

    base = Engine(cfg, params, hot_cap=8, max_len=64, slots=slots,
                  prefill_chunk=8)
    ref = {f.rid: f.tokens.tolist() for f in base.serve(mk(), slots=slots)}

    drafts = [
        ("self", cfg, params, None),  # acceptance ceiling: draft == target
        ("tiny", dcfg, dparams, None),  # untrained draft: realistic floor
        ("reject", dcfg, dparams, "reject"),  # adversarial: all rolled back
    ]
    out = []
    for k in (2, 4, 8):
        for tag, dc, dp, force in drafts:
            eng = Engine(cfg, params, hot_cap=8, max_len=64, slots=slots,
                         prefill_chunk=8, draft_cfg=dc, draft_params=dp,
                         spec_k=k, spec_force=force)
            assert eng.spec
            eng.serve(mk(), slots=slots)  # warm (compiles)
            t0 = time.perf_counter()
            fin = {f.rid: f for f in eng.serve(mk(), slots=slots)}
            dt = time.perf_counter() - t0
            emitted = rounds = 0
            for rid, f in fin.items():
                assert f.tokens.tolist() == ref[rid], (tag, k, rid)
                assert 0 <= f.accepted_tokens <= f.drafted_tokens
                emitted += len(f.tokens)
                # every round emits the accepted prefix + one target token
                rounds += len(f.tokens) - f.accepted_tokens
            st = eng.last_stats
            drafted = sum(f.drafted_tokens for f in fin.values())
            accepted = sum(f.accepted_tokens for f in fin.values())
            assert (st.drafted_tokens, st.accepted_tokens) == (
                drafted, accepted), "stats ledger != per-request ledger"
            tok_round = emitted / rounds
            acc = accepted / max(drafted, 1)
            if tag == "self":
                assert acc == 1.0 and (tok_round > 1.0 if k > 1 else True)
            if tag == "reject":
                assert accepted == 0 and tok_round == 1.0
            out.append(row(
                f"serving/spec_k{k}_{tag}", dt / max(emitted, 1) * 1e6,
                f"tok_round={tok_round:.2f} acc={acc:.2f} "
                f"drafted={drafted} accepted={accepted} rounds={rounds}"))
    return out


def router_failover(slots: int = 2) -> list:
    """Fault-tolerant fleet sweep: replicas × kill-rate → goodput and
    the failover ledger.

    The same burst is served by a faultless single engine (the oracle),
    then by 2- and 3-replica fleets behind the router, each fleet once
    quiet and once under seeded replica-kill chaos (the fleet-invariant
    checker runs after every tick). Every cell asserts the hard failover
    guarantees — every request reaches the ``finished`` terminal and its
    greedy tokens are BIT-IDENTICAL to the oracle — and reports what
    fault tolerance *cost*: cold/warm migrations, the recompute tokens
    re-admission actually paid (summed from every session's
    ``ServeStats``) vs the prefix-cache tokens it got back for free, and
    router retries/restarts. Kills change throughput, never output.
    """
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import (FleetChaosConfig, FleetChaosInjector,
                               LocalTransport, Replica, Router)
    from repro.serving.engine import Engine

    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, size=(int(p),)).astype(np.int32)
               for p in (6, 9, 13, 8, 11, 7, 15, 10)]

    def mk():
        return [Request(rid=i, tokens=t.copy(), max_new_tokens=12)
                for i, t in enumerate(prompts)]

    def build():
        # sync_every=2 keeps router ticks fine-grained, so the seeded
        # kill schedule has real injection points mid-decode
        return Engine(cfg, params, hot_cap=8, max_len=64, slots=slots,
                      prefill_chunk=8, paged=True, page_size=8,
                      sync_every=2)

    ref_eng = build()
    ref = {f.rid: f.tokens.tolist() for f in ref_eng.serve(mk(), slots=slots)}

    out = []
    for n_rep in (2, 3):
        engines = [build() for _ in range(n_rep)]
        # warm pass: compiles every engine's dispatch shapes untimed
        Router([Replica(f"r{i}", e) for i, e in enumerate(engines)],
               seed=0).serve(mk())
        for kill_rate in (0.0, 0.08):
            replicas = [Replica(f"r{i}", e) for i, e in enumerate(engines)]
            # retry_limit is generous on purpose: wall-clock noise (jit
            # pauses) can trigger straggler drains, and each drain
            # re-dispatch spends an attempt — the budget must outlast
            # benign migrations so only real pathology ever "fail"s
            router = Router(replicas, seed=0, retry_limit=8,
                            transport=LocalTransport())
            chaos = FleetChaosInjector(FleetChaosConfig(
                seed=3, kill_rate=kill_rate, max_kills=n_rep - 1))
            t0 = time.perf_counter()
            fin = {f.rid: f for f in router.serve(mk(), on_tick=chaos.on_tick)}
            dt = time.perf_counter() - t0
            for rid, want in ref.items():
                assert fin[rid].outcome == "finished", (n_rep, kill_rate, rid)
                assert fin[rid].tokens.tolist() == want, \
                    f"tokens diverged: replicas={n_rep} kill={kill_rate} rid={rid}"
            useful = sum(len(f.tokens) for f in fin.values())
            reused = sum(f.prefix_tokens_reused for f in fin.values())
            recompute = 0
            for rep in replicas:
                stats = rep.past_stats + ([rep.ctx.stats] if rep.ctx else [])
                recompute += sum(s.recompute_tokens for s in stats)
            st = router.stats
            out.append(row(
                f"serving/router_r{n_rep}_kill{kill_rate:g}",
                dt / max(useful, 1) * 1e6,
                f"tok_s={useful / dt:.1f} kills={len(chaos.kills)} "
                f"cold={st.cold_migrations} warm={st.warm_migrations} "
                f"imported={st.handoffs_imported} recompute={recompute}tok "
                f"reused={reused}tok retries={st.retries} "
                f"restarts={st.restarts} (bit-exact vs single engine)"))
    return out


def sdc_resilience(slots: int = 3) -> list:
    """SDC-ladder sweep: fault rates × scrub cadence → what resilience
    costs, plus the raw ABFT check overhead.

    The same burst is served by a faultless engine (the oracle), then by
    integrity engines under seeded ROM / retention / NaN injection at
    two scrub cadences. Every cell asserts the ladder's hard guarantee —
    every ``finished`` request's greedy tokens are BIT-IDENTICAL to the
    oracle — and reports the price: faults detected, weight reloads,
    pages scrubbed/quarantined, slots contained, rollback recompute
    tokens and goodput. The final row times the ABFT row-sum check
    (one guard GEMV riding the matmul, docs/kernels.md) against the
    unchecked packed matmul on a real packed leaf.
    """
    from repro.configs import get_smoke_config
    from repro.core import bitlinear
    from repro.models import pack as pack_lib
    from repro.models import transformer as T
    from repro.serving import sdc as sdc_lib
    from repro.serving.chaos import ChaosConfig, ChaosInjector
    from repro.serving.engine import Engine

    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, cfg.vocab_size, size=(int(p),)).astype(np.int32)
               for p in (6, 10, 8, 12, 7, 9)]

    def mk():
        return [Request(rid=i, tokens=t.copy(), max_new_tokens=12)
                for i, t in enumerate(prompts)]

    kw = dict(hot_cap=8, max_len=64, slots=slots, prefill_chunk=8,
              paged=True, page_size=8, sync_every=2)
    ref_eng = Engine(cfg, params, **kw)
    ref = {f.rid: f.tokens.tolist() for f in ref_eng.serve(mk())}

    out = []
    cells = [(0.0, 0.0, 0.0, 4), (0.15, 0.05, 0.0, 4),
             (0.15, 0.05, 0.0, 1)]
    for wf, pd, nan, scrub_every in cells:
        eng = Engine(cfg, params,
                     integrity=sdc_lib.IntegrityConfig(
                         scrub_every=scrub_every, max_weight_strikes=10 ** 6),
                     **kw)
        chaos = ChaosInjector(eng, ChaosConfig(
            seed=11, weight_flip_rate=wf, page_decay_rate=pd, nan_rate=nan))
        ctx = eng.start_session(mk(), on_iteration=chaos.on_iteration)
        t0 = time.perf_counter()
        while eng.run_iteration(ctx):
            pass
        dt = time.perf_counter() - t0
        chaos.release_all(ctx)
        fin = {f.rid: f for f in ctx.finished}
        for rid, want in ref.items():
            assert fin[rid].outcome == "finished", (wf, pd, scrub_every, rid)
            assert fin[rid].tokens.tolist() == want, \
                f"tokens diverged: wf={wf} pd={pd} scrub={scrub_every} rid={rid}"
        st = ctx.stats
        useful = sum(len(f.tokens) for f in fin.values())
        eng.finish_session(ctx)
        out.append(row(
            f"serving/sdc_wf{wf:g}_pd{pd:g}_scrub{scrub_every}",
            dt / max(useful, 1) * 1e6,
            f"tok_s={useful / dt:.1f} injected={chaos.sdc_budget()} "
            f"detected={st.sdc_detected} reloads={st.weight_reloads} "
            f"scrubbed={st.pages_scrubbed} "
            f"quarantined_pages={len(ctx.pool.quarantined)} "
            f"contained={st.slots_quarantined} "
            f"recompute={st.recompute_tokens}tok "
            f"preempts={st.preemptions} (bit-exact vs faultless)"))

    # raw ABFT overhead: checked vs unchecked matmul on one packed leaf
    packed = pack_lib.add_integrity(pack_lib.pack_params(params, cfg))
    path, pw = next(iter(pack_lib.iter_packed_leaves(packed)))
    sub = next(iter(sdc_lib._leaf_slices(pw)))  # first 2-D (K, N) slice
    x = jax.random.normal(jax.random.PRNGKey(1), (16, sub.k), "float32")
    plain = jax.jit(lambda a: bitlinear.packed_matmul(sub, a))
    checked = jax.jit(lambda a: bitlinear.abft_check(sub, a)[0])
    for fn in (plain, checked):
        fn(x).block_until_ready()  # compile
    def med(fn, iters=30):
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn(x).block_until_ready()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))
    tp, tc = med(plain), med(checked)
    out.append(row(
        "serving/abft_overhead", tc * 1e6,
        f"leaf={path} k={sub.k} plain={tp * 1e6:.1f}us "
        f"checked={tc * 1e6:.1f}us overhead={(tc / tp - 1) * 100:.1f}%"))
    return out


def main() -> None:
    print("name,us_per_call,derived")
    for r in serving_throughput():
        print(r)
    for r in chunked_admission():
        print(r)
    for r in shared_prefix():
        print(r)
    for r in overload():
        print(r)
    for r in speculative_sweep():
        print(r)
    for r in router_failover():
        print(r)
    for r in sdc_resilience():
        print(r)


if __name__ == "__main__":
    main()
