"""Shared benchmark utilities: timing + CSV rows (name,us_per_call,derived)."""

from __future__ import annotations

import time
from typing import Callable


def time_us(fn: Callable, iters: int = 10, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"


def falcon3_config(member: str):
    """ModelConfig for a Falcon3 family member (paper Tables I/II)."""
    from repro.configs.base import BitNetConfig, ModelConfig
    from repro.configs.falcon3_1b import FALCON3_FAMILY

    dims = FALCON3_FAMILY[member]
    return ModelConfig(
        name=member, family="dense",
        bitnet=BitNetConfig(lora_rank=16, lora_bits=6),
        **dims,
    )


def lora_dims_for(cfg, targets) -> list:
    """(d_in, d_out) pairs of the adapted projections, all layers."""
    d, f = cfg.d_model, cfg.d_ff
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    per_layer = {
        "q": (d, h * hd),
        "k": (d, g * hd),
        "v": (d, g * hd),
        "o": (h * hd, d),
        "g": (d, f),
        "u": (d, f),
        "down": (f, d),
    }
    return [per_layer[t] for t in targets] * cfg.n_layers
