"""The paper's 6-partition pipeline (§V-B) as a GPipe schedule on a device mesh.

BitROM maps Falcon3-1B as 6 macro partitions x 3 layers and streams 6
batches through them. Here: a reduced falcon3 config with its layer stack
split into 6 stages over 6 placeholder devices, microbatches handed along
with collective-permute. Verifies the pipelined forward matches the plain
forward exactly and reports the bubble fraction.

NOTE: sets XLA_FLAGS for 8 host devices — run standalone, not under pytest.
Run:  PYTHONPATH=src python examples/pipeline_falcon3.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.distributed import pipeline as pp  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.transformer import _attn_block_fwd  # noqa: E402

N_STAGES = 6
N_MICRO = 6  # the paper's 6 pipelined batches


def main() -> None:
    cfg = get_smoke_config("falcon3-1b")
    cfg = dataclasses.replace(cfg, n_layers=N_STAGES * 3)  # 6 partitions x 3 layers
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    mesh = jax.make_mesh((N_STAGES,), ("stage",))
    staged = pp.reshape_to_stages(params["blocks"], N_STAGES)
    # mode="none": scheduling exactness check without fake-quant rounding
    fwd = pp.make_pipeline_forward(cfg, mesh, N_STAGES, N_MICRO, axis="stage", mode="none")

    mb, s, d = 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (N_MICRO, mb, s, d)) * 0.3

    with mesh:
        out = fwd(staged, x)  # (n_micro, mb, s, d)

    # reference: run each microbatch through the plain (unpipelined) stack
    positions = jnp.arange(s, dtype=jnp.int32)

    def plain(h):
        def body(carry, bp):
            out, _, _ = _attn_block_fwd(bp, carry, cfg, "none", positions)
            return out, None

        h, _ = jax.lax.scan(body, h, params["blocks"])
        return h

    ref = jax.vmap(plain)(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
    print(f"pipelined forward == plain forward across {N_MICRO} microbatches")
    print(f"stages={N_STAGES} microbatches={N_MICRO} "
          f"bubble={100*pp.bubble_fraction(N_STAGES, N_MICRO):.1f}% "
          f"(paper's 6x6 edge configuration)")


if __name__ == "__main__":
    main()
