"""Continuous-batching serving with the DR-tiered KV cache (paper §IV + §V-B).

Loads (or initializes) a reduced BitNet model, fabricates the ROM (packed
ternary weights), then:

  1. serves aligned batches at several sequence lengths to sweep
     Fig. 5(b): the measured external-DRAM reduction from buffering
     ``hot_cap`` early tokens on-die must track the closed form;
  2. serves a mixed-length request queue through a small slot pool with
     mid-decode admission — each sequence's per-slot traffic ledger still
     reconciles with the closed form at *its own* length.

Run:  PYTHONPATH=src python examples/serve_tiered_kv.py
"""

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import dr_edram
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.scheduler import Request


def main() -> None:
    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    print(f"{'seq':>5s} {'hot':>4s} {'measured':>9s} {'closed-form':>11s}")
    for seq_len, hot in [(32, 4), (64, 16), (128, 32)]:
        eng = Engine(cfg, params, hot_cap=hot, max_len=seq_len + 8)
        p_len = seq_len // 4
        prompts = jax.random.randint(
            jax.random.PRNGKey(seq_len), (4, p_len), 0, cfg.vocab_size
        )
        res = eng.generate(prompts, max_new_tokens=seq_len - p_len)
        expect = dr_edram.closed_form_reduction(p_len + res.steps, hot)
        print(f"{seq_len:5d} {hot:4d} {100*res.external_reduction:8.1f}% "
              f"{100*expect:10.1f}%")

    # the paper's headline cell
    print(f"\npaper headline (S=128, B=32): "
          f"{100*dr_edram.closed_form_reduction(128, 32):.1f}% reduction "
          f"(paper: 43.6%)")

    # -- continuous batching: mixed-length queue through 3 slots ----------
    hot = 8
    eng = Engine(cfg, params, hot_cap=hot, max_len=96, slots=3)
    rng = np.random.RandomState(0)
    reqs = [
        Request(rid=i,
                tokens=rng.randint(0, cfg.vocab_size, size=(p,)).astype(np.int32),
                max_new_tokens=m)
        for i, (p, m) in enumerate([(4, 24), (16, 8), (9, 30), (2, 5), (16, 12)])
    ]
    fin = eng.serve(reqs, sync_every=6)
    print(f"\ncontinuous batching: {len(reqs)} mixed-length requests "
          f"through {eng.slots} slots (mid-decode admission)")
    print(f"{'rid':>4s} {'prompt':>6s} {'new':>4s} {'seq':>4s} "
          f"{'measured':>9s} {'closed-form':>11s}")
    for f in sorted(fin, key=lambda f: f.rid):
        expect = dr_edram.closed_form_reduction(f.seq_len, hot)
        print(f"{f.rid:4d} {f.prompt_len:6d} {len(f.tokens):4d} {f.seq_len:4d} "
              f"{100*f.external_reduction:8.1f}% {100*expect:10.1f}%")

    print("\nweights were loaded to device once and never reloaded "
          "(the CiROM property).")


if __name__ == "__main__":
    main()
