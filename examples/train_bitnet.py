"""End-to-end training driver: QAT-train a BitNet LM with checkpoint/resume.

Presets:
  tiny — ~3M params, 300 steps, runs in minutes on CPU (default)
  100m — ~100M-param mamba2-family config, a few hundred steps (the spec's
         "train ~100M model" driver; give it a real machine or be patient)

Features exercised: ternary QAT (STE), AdamW (+optional 8-bit states),
grad accumulation, atomic checkpointing + auto-resume, straggler monitor.

Run:  PYTHONPATH=src python examples/train_bitnet.py [--preset tiny]
      [--steps N] [--resume-dir DIR] [--opt-8bit]
"""

import argparse
import dataclasses

from repro.configs import get_config, get_smoke_config, shrink
from repro.training import loop as train_loop
from repro.training.optimizer import AdamWConfig


def build_preset(name: str):
    if name == "tiny":
        cfg = get_smoke_config("falcon3-1b")
        return cfg, dict(global_batch=8, seq_len=64, n_micro=2)
    if name == "100m":
        # mamba2-130m is the assigned ~100M-class architecture
        cfg = get_config("mamba2-130m")
        return cfg, dict(global_batch=8, seq_len=256, n_micro=2)
    raise SystemExit(f"unknown preset {name}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--resume-dir", default="/tmp/bitnet_ckpt")
    ap.add_argument("--opt-8bit", action="store_true")
    args = ap.parse_args()

    cfg, kw = build_preset(args.preset)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                      quantized_state=args.opt_8bit)
    print(f"== training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, ckpt -> {args.resume_dir} ==")
    r = train_loop.train(
        cfg,
        steps=args.steps,
        opt_cfg=opt,
        ckpt_dir=args.resume_dir,
        ckpt_every=50,
        log_every=20,
        **kw,
    )
    first, last = r["losses"][0], sum(r["losses"][-10:]) / 10
    print(f"loss: {first:.3f} -> {last:.3f} over {r['step']} steps "
          f"({len(r['stragglers'])} straggler events)")
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
