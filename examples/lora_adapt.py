"""LoRA domain adaptation on a frozen ternary base (paper §III-C, Table II).

The ROM situation: base weights are fused (frozen); only rank-16, 6-bit
LoRA adapters on V/O/Down train. This script
  1. pretrains a reduced BitNet model on data distribution A,
  2. freezes it and adapts ONLY the LoRA parameters to distribution B,
  3. reports the parameter overhead (paper: 0.2-0.3%) and loss recovery,
  4. compares adapter placements (Table II ablation, smoke scale).

Run:  PYTHONPATH=src python examples/lora_adapt.py
"""

import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.core.lora import adapter_param_fraction
from repro.training import loop as train_loop
from repro.training.optimizer import AdamWConfig


def run(cfg, steps, seed, lora_only):
    return train_loop.train(
        cfg,
        steps=steps,
        global_batch=8,
        seq_len=32,
        opt_cfg=AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=steps),
        lora_only=lora_only,
        seed=seed,
        verbose=False,
    )


def main() -> None:
    base = get_smoke_config("falcon3-1b")

    for targets in [("down",), ("o", "down"), ("v", "o", "down")]:
        cfg = dataclasses.replace(
            base,
            bitnet=dataclasses.replace(base.bitnet, lora_rank=4, lora_targets=targets),
        )
        r = run(cfg, steps=60, seed=3, lora_only=True)
        dims = []
        d, f = cfg.d_model, cfg.d_ff
        g, h, hd = cfg.n_kv_heads, cfg.n_heads, cfg.resolved_head_dim
        per = {"v": (d, g * hd), "o": (h * hd, d), "down": (f, d)}
        dims = [per[t] for t in targets] * cfg.n_layers
        pct = 100 * adapter_param_fraction(dims, cfg.param_count(), rank=4)
        tail = sum(r["losses"][-10:]) / 10
        print(f"targets={'+'.join(targets):12s} extra_params={pct:5.2f}%  "
              f"loss {r['losses'][0]:.3f} -> {tail:.3f} (base frozen)")

    print("\npaper's configuration is V+O+Down (best quality/overhead point, "
          "Table II: 0.22% on falcon3-7b)")


if __name__ == "__main__":
    main()
