"""Quickstart: the whole BitROM story in one script (CPU, ~1 min).

1. Build a (reduced) Falcon3-style BitNet model.
2. QAT-train a few steps (ternary weights + A8 activations, STE).
3. "Fabricate the ROM": pack trained weights to 2-bit trits (BiROMA).
4. Serve with the DR-tiered KV cache — zero weight reload — and check the
   measured external-DRAM reduction against the paper's closed form.
5. Run the Pallas ternary-matmul kernel against its oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import dr_edram, packing
from repro.kernels import ops, ref
from repro.models import pack as pack_lib
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.training import loop as train_loop


def main() -> None:
    cfg = get_smoke_config("falcon3-1b")
    print(f"== arch: {cfg.name} ({cfg.n_layers}L d={cfg.d_model}, "
          f"GQA kv={cfg.n_kv_heads}, BitNet A{cfg.bitnet.act_bits}) ==")

    # -- 2. short QAT run ---------------------------------------------------
    r = train_loop.train(cfg, steps=12, global_batch=8, seq_len=32, log_every=4)
    params = r["params"]

    # -- 3. fabricate the ROM ------------------------------------------------
    packed = pack_lib.pack_params(params, cfg)
    ledger = pack_lib.packed_param_bytes(packed)
    n = cfg.param_count()
    print(f"packed {n/1e6:.1f}M params -> {ledger['packed_bytes']/1e6:.2f} MB trits "
          f"({8*ledger['packed_bytes']/n:.2f} bits/weight; fp residue "
          f"{ledger['other_bytes']/1e6:.1f} MB)")

    # -- 4. weight-reload-free serving with DR-tiered KV ---------------------
    eng = Engine(cfg, params, hot_cap=8, max_len=128)
    prompts = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, cfg.vocab_size)
    res = eng.generate(prompts, max_new_tokens=24)
    seq_len = 16 + res.steps
    expect = dr_edram.closed_form_reduction(seq_len, 8)
    print(f"generated {res.steps} tokens/seq; external-DRAM reduction "
          f"{100*res.external_reduction:.1f}% (closed form {100*expect:.1f}%)")
    print(f"weight reloads after ROM fabrication: {eng.weight_loads}")

    # -- 5. kernel vs oracle --------------------------------------------------
    xq = jax.random.randint(jax.random.PRNGKey(1), (8, 256), -128, 128, dtype=jnp.int8)
    wq = jax.random.randint(jax.random.PRNGKey(2), (256, 64), -1, 2, dtype=jnp.int8)
    pk = packing.pack2(wq)
    out_k = ops.ternary_matmul(xq, pk, k=256, codec="pack2", impl="pallas",
                               block_m=8, block_n=64, block_k=64)
    out_r = ref.ternary_matmul_ref(xq, pk, k=256, codec="pack2")
    assert (out_k == out_r).all()
    print("pallas ternary kernel == oracle (exact int32 match)")


if __name__ == "__main__":
    main()
