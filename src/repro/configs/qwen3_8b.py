"""qwen3-8b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936, head_dim=128.
"""

from repro.configs.base import ModelConfig, register, shrink

CFG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B; hf",
)

register(
    CFG,
    shrink(CFG, qk_norm=True),
    dryrun_overrides={
        "train_4k": {"microbatches": 4},
        "prefill_32k": {},
        "decode_32k": {},
    },
)
