"""Architecture configs: the 10 assigned archs + the paper's falcon3-1b.

Use ``get_config(name)`` / ``get_smoke_config(name)`` / ``list_configs()``.
"""

from repro.configs.base import (  # noqa: F401
    SHAPES,
    BitNetConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    applicable_shapes,
    get_config,
    get_overrides,
    get_smoke_config,
    list_configs,
    shrink,
)
