"""llava-next-34b [vlm] — anyres tiling [hf:llava-hf/llava-v1.6; unverified].

Backbone only per assignment: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 (Yi-34B-like). The vision tower is a STUB — input_specs()
provides precomputed patch embeddings (B, 576, 1024) = one 336px CLIP tile;
anyres multi-tile reduces to more patches, same code path. Image patches
are the sequence *prefix* => they are the paper's "early tokens": DR
tiering is maximally effective here (read at every decode step).
"""

from repro.configs.base import ModelConfig, register, shrink

CFG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision",
    frontend_dim=1024,
    n_patches=576,
    rope_theta=5_000_000.0,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)

register(
    CFG,
    shrink(CFG),
    dryrun_overrides={
        "train_4k": {"microbatches": 8},
        "prefill_32k": {},
        "decode_32k": {},
    },
)
