"""falcon3-1b — the paper's own deployment target (§V-B) [hf:tiiuae/Falcon3-1B].

18 Transformer layers, GQA with 4 KV heads, head_dim 256 (8 Q heads),
d_model 2048, FFN 8192. The paper maps it as 6 macro partitions × 3 layers
with a 6-stage batch pipeline and 13.5 MB DR eDRAM (S=128, 32 hot tokens,
6 batches). LoRA rank 16 on V/O/Down, 6-bit weights — the Falcon3 BitNet
convention the paper adopts.

Not part of the assigned 10-arch pool; used by the paper-reproduction
benchmarks, the pipeline example and hwmodel calibration.
"""

from repro.configs.base import BitNetConfig, ModelConfig, register, shrink

CFG = ModelConfig(
    name="falcon3-1b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=8192,
    vocab_size=131072,
    rope_theta=1_000_042.0,
    bitnet=BitNetConfig(lora_rank=16, lora_targets=("v", "o", "down"), lora_bits=6),
    source="hf:tiiuae/Falcon3-1B-Instruct; hf",
)

register(CFG, shrink(CFG))

# Draft model for speculative serving (serving/engine.py, spec_k > 0):
# a 4-layer ternary model sharing falcon3-1b's tokenizer/vocab — the only
# hard coupling between draft and target is the token-id space. Ternary
# weights make it nearly free next to the target (ROADMAP: speculation);
# depth/width follow the Falcon3 head ratio at ~1/10 the parameters.
DRAFT = ModelConfig(
    name="falcon3-draft",
    family="dense",
    n_layers=4,
    d_model=1024,
    n_heads=8,
    n_kv_heads=4,
    head_dim=128,
    d_ff=4096,
    vocab_size=131072,
    rope_theta=1_000_042.0,
    tie_embeddings=True,
    bitnet=BitNetConfig(),
    source="derived; speculative draft for falcon3-1b",
)

register(DRAFT, shrink(DRAFT))

# The paper's sibling models (Table I) — parameter-count reproduction only.
FALCON3_FAMILY = {
    "falcon3-1b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=4,
                       head_dim=256, d_ff=8192, vocab_size=131072),
    "falcon3-3b": dict(n_layers=22, d_model=3072, n_heads=12, n_kv_heads=4,
                       head_dim=256, d_ff=9216, vocab_size=131072),
    "falcon3-7b": dict(n_layers=28, d_model=3072, n_heads=12, n_kv_heads=4,
                       head_dim=256, d_ff=23040, vocab_size=131072),
    "falcon3-10b": dict(n_layers=40, d_model=3072, n_heads=12, n_kv_heads=4,
                        head_dim=256, d_ff=23040, vocab_size=131072),
}
