"""Config system: model/shape dataclasses + registry.

Every assigned architecture registers a ``ModelConfig`` (exact public
numbers) plus a reduced ``smoke`` variant of the same family for CPU
tests. Shapes are the four assigned (seq_len, global_batch) cells; each
config declares which cells apply (encoder-only archs have no decode,
full-attention archs skip long_500k — see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def kv_cache_dim(self) -> int:  # latent + rope key per token
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    d_ff_dense: int = 0  # FFN width of the leading dense layers (0 -> d_ff)
    n_dense_layers: int = 0  # leading dense layers (DeepSeek-V3: 3)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 64

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class BitNetConfig:
    """The paper's quantization recipe (BitNet b1.58 / a4.8 + LoRA §III-C)."""

    enabled: bool = True
    act_bits: int = 8  # 8 = b1.58, 4 = a4.8 (TriMLA-native)
    codec: str = "pack2"  # "pack2" (BiROMA 2b/trit) | "pack243" (1.6b, beyond-paper)
    # packed-matmul execution path: "auto" resolves to the Pallas fused-
    # epilogue kernel on TPU (single-device) and the XLA unpack+dot path on
    # CPU / under GSPMD sharding hints; "pallas" / "xla" force a path.
    impl: str = "auto"
    # fuse wq|wk|wv, gate|up, w_dq|w_dkv and per-expert w_gate|w_up into one
    # packed projection at pack time (one act-quant + one kernel launch per
    # group; see models/pack.py)
    fuse_proj: bool = True
    # fuse the int8 act-quant (per-row absmax + scale) into the Pallas
    # kernel prologue (two-phase K sweep; kernels/ternary_matmul.py) —
    # False falls back to the separate act-quant + known-scale epilogue
    # kernel. Ignored on the XLA impl (always separate, same numerics).
    fuse_act_quant: bool = True
    lora_rank: int = 0  # 0 disables adapters
    lora_targets: Tuple[str, ...] = ("v", "o", "down")
    lora_bits: int = 6
    embed_int8: bool = False  # beyond-paper: int8 embedding/lm_head at inference
    kv_fp8: bool = False  # beyond-paper: fp8(e4m3) KV-cache tiers at inference


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    activation: str = "swiglu"  # swiglu | geglu | gelu (non-gated)
    qk_norm: bool = False
    attn_type: str = "full"  # full | swa | mla | none
    swa_window: int = 4096
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embed: bool = False  # Gemma: embeddings scaled by sqrt(d_model)
    is_encoder: bool = False  # bidirectional attention, no decode
    hybrid_attn_every: int = 0  # Zamba2: shared attn block every k layers
    frontend: str = "none"  # none | audio | vision
    frontend_dim: int = 0
    n_patches: int = 0  # VLM: image patches per sample
    # decode headroom: when transformer.prefill is called without an
    # explicit max_len, the cache is sized prompt_len + decode_headroom —
    # this is the hard cap on how many tokens can then be decoded (the
    # historical hard-wired "+128"; see docs/serving.md "Knobs").
    decode_headroom: int = 128
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    bitnet: BitNetConfig = field(default_factory=BitNetConfig)
    source: str = ""  # provenance note [arXiv/hf; tier]

    # ---- derived -----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return not self.is_encoder

    def param_count(self) -> int:
        """Exact parameter count of the backbone (embeddings included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, g, hd = self.n_heads, self.n_kv_heads, self.resolved_head_dim
        total = v * d  # embedding
        if not self.tie_embeddings and not self.is_encoder:
            total += d * v  # lm head
        if self.is_encoder:
            total += d * v  # output projection
        if self.frontend == "audio":
            total += self.frontend_dim * d
        if self.frontend == "vision":
            total += self.frontend_dim * d + d * d  # 2-layer projector

        def attn_params() -> int:
            if self.attn_type == "mla":
                m = self.mla
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                return (
                    d * m.q_lora_rank
                    + m.q_lora_rank * h * qk_head
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
                    + h * m.v_head_dim * d
                )
            return d * h * hd + 2 * d * g * hd + h * hd * d

        def mlp_params(ff: int) -> int:
            n_in = 1 if self.activation == "gelu" else 2
            return d * ff * n_in + ff * d

        def moe_layer_params() -> int:
            mo = self.moe
            ff = mo.d_ff_expert or f
            p = d * mo.n_experts  # router
            p += mo.n_experts * mlp_params(ff)
            p += mo.n_shared * mlp_params(f)
            return p

        def ssm_layer_params() -> int:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            conv_ch = di + 2 * s.n_groups * s.d_state
            p = d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj [z,x,B,C,dt]
            p += conv_ch * s.d_conv  # depthwise conv
            p += nh * 2  # A_log, D
            p += nh  # dt bias
            p += di  # gated norm
            p += di * d  # out_proj
            return p

        norms = 2 * d  # per layer (attn ln + mlp ln), approx for all families
        for layer in range(self.n_layers):
            if self.family == "ssm":
                total += ssm_layer_params() + d
            elif self.family == "hybrid":
                total += ssm_layer_params() + d
            elif self.family == "moe" and layer >= self.moe.n_dense_layers:
                total += attn_params() + moe_layer_params() + norms
            elif self.family == "moe":
                total += attn_params() + mlp_params(self.moe.d_ff_dense or f) + norms
            else:
                total += attn_params() + mlp_params(f) + norms
        if self.family == "hybrid" and self.hybrid_attn_every:
            # one shared attention+MLP block (parameters counted once)
            total += attn_params() + mlp_params(f) + norms
        total += d  # final norm
        return total


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which of the four cells run for this arch (DESIGN.md §4 rules)."""
    out = ["train_4k", "prefill_32k"]
    if cfg.has_decode:
        out.append("decode_32k")
        if cfg.sub_quadratic:
            out.append("long_500k")
    return tuple(out)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}
_SMOKE: Dict[str, ModelConfig] = {}
_OVERRIDES: Dict[str, Dict[str, dict]] = {}  # arch -> shape -> dryrun overrides


def register(cfg: ModelConfig, smoke: ModelConfig, dryrun_overrides: dict | None = None):
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    _OVERRIDES[cfg.name] = dryrun_overrides or {}
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE[name]


def get_overrides(name: str, shape: str) -> dict:
    _ensure_loaded()
    return _OVERRIDES.get(name, {}).get(shape, {})


def list_configs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def replace(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, **kw)


def shrink(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests.

    Preserves every structural flag (family, attention variant, activation,
    qk-norm, tying, frontend kind, MoE/MLA/SSM presence) while shrinking
    width/depth/tables to run a forward+train step in seconds on CPU.
    """
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=4 if cfg.n_kv_heads == cfg.n_heads else 2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        swa_window=8 if cfg.attn_type == "swa" else cfg.swa_window,
    )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            d_ff_dense=128 if cfg.moe.d_ff_dense else 0,
            n_dense_layers=1 if cfg.moe.n_dense_layers else 0,
            capacity_factor=4.0,  # no token drops in smoke (determinism tests)
        )
        kw["n_layers"] = 2
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=8, chunk=8
        )
    if cfg.family == "hybrid":
        kw["hybrid_attn_every"] = 2
        kw["n_layers"] = 5  # 2 groups of 2 + tail of 1
    if cfg.frontend == "audio":
        kw["frontend_dim"] = 32
    if cfg.frontend == "vision":
        kw["frontend_dim"] = 32
        kw["n_patches"] = 8
    if cfg.bitnet.lora_rank:
        kw["bitnet"] = dataclasses.replace(cfg.bitnet, lora_rank=4)
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import all config modules for registration side effects
    from repro.configs import (  # noqa: F401
        deepseek_coder_33b,
        deepseek_v3_671b,
        falcon3_1b,
        gemma_7b,
        hubert_xlarge,
        llava_next_34b,
        mamba2_130m,
        mixtral_8x22b,
        qwen3_8b,
        qwen3_32b,
        zamba2_7b,
    )
