"""hubert-xlarge [audio] — encoder-only, w2v2 arch [arXiv:2106.07447; unverified].

48L d_model=1280 16H (kv=16, i.e. MHA) d_ff=5120 vocab=504 (cluster targets).
Modality frontend is a STUB per assignment: input_specs() provides
precomputed frame embeddings (B, T, 512) — the conv feature extractor is
replaced by a projection. Encoder-only => no decode shapes (DESIGN.md §4).
Deviations: RoPE instead of conv positional embeddings; RMSNorm for
LayerNorm (uniform substrate) — value-level only, shapes exact.
"""

from repro.configs.base import ModelConfig, register, shrink

CFG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    activation="gelu",  # non-gated transformer-encoder MLP
    attn_type="full",
    is_encoder=True,
    frontend="audio",
    frontend_dim=512,
    rope_theta=10_000.0,
    source="arXiv:2106.07447; unverified",
)

register(
    CFG,
    shrink(CFG),
    dryrun_overrides={
        "train_4k": {"microbatches": 8},
        "prefill_32k": {},
    },
)
