"""zamba2-7b [hybrid] — Mamba2 + shared attention blocks [arXiv:2411.15242; unverified].

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Structure: groups of 6 Mamba2 blocks, each followed by ONE shared
attention+MLP block (single parameter set) with per-invocation LoRA —
Zamba2's own trick, realized with the paper's §III-C LoRA machinery.
Hybrid => sub-quadratic decode => owns the long_500k cell (attention KV
exists only at the 13 shared-block invocations).
"""

from repro.configs.base import BitNetConfig, ModelConfig, SSMConfig, register, shrink

CFG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    hybrid_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=2, chunk=64),
    bitnet=BitNetConfig(lora_rank=16),  # shared-block per-invocation adapters
    rope_theta=10_000.0,
    source="arXiv:2411.15242; unverified",
)

register(
    CFG,
    shrink(CFG),
    dryrun_overrides={
        "train_4k": {"microbatches": 4},
        "prefill_32k": {},
        "decode_32k": {},
        "long_500k": {},
    },
)
