"""gemma-7b [dense] — GeGLU, head_dim=256 [arXiv:2403.08295; hf].

28L d_model=3072 16H (kv=16: MHA on 7b; MQA on 2b) d_ff=24576 vocab=256000.
Tied embeddings, embeddings scaled by sqrt(d_model).
"""

from repro.configs.base import ModelConfig, register, shrink

CFG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    activation="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    scale_embed=True,
    source="arXiv:2403.08295; hf",
)

register(
    CFG,
    shrink(CFG),
    dryrun_overrides={
        "train_4k": {"microbatches": 4},
        "prefill_32k": {},
        "decode_32k": {},
    },
)
