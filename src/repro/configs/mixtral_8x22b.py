"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768. Sliding-window
attention (window 4096) caps the decode KV cache at the window (ring
buffer) — DR tiering is N/A under SWA eviction (DESIGN.md §4).
"""

from repro.configs.base import ModelConfig, MoEConfig, register, shrink

CFG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    attn_type="swa",
    swa_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088; hf",
)

register(
    CFG,
    shrink(CFG),
    dryrun_overrides={
        "train_4k": {"microbatches": 8, "opt_8bit": True},
        "prefill_32k": {},
        "decode_32k": {},
    },
)
