"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified].

24L d_model=768, attention-free, vocab=50280, ssm_state=128, head_dim=64,
expand=2 (d_inner 1536, 24 SSM heads). O(1) decode state => owns the
long_500k cell; the paper's DR KV tiering is N/A (no growing cache) —
recorded in DESIGN.md §Arch-applicability; ternary quantization still
applies to all projections.
"""

from repro.configs.base import ModelConfig, SSMConfig, register, shrink

CFG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,  # attention-free; SSM heads derive from ssm config
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    attn_type="none",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=64),
    source="arXiv:2405.21060; unverified",
)

register(
    CFG,
    shrink(CFG),
    dryrun_overrides={
        "train_4k": {},
        "prefill_32k": {},
        "decode_32k": {},
        "long_500k": {},
    },
)
