"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 [arXiv:2412.19437; hf].

61L d_model=7168 128H d_ff=2048 (per expert) vocab=129280; 3 leading dense
layers (FFN 18432); MLA latent cache = 512+64 per token. The MTP head is
omitted (orthogonal to the paper's technique — DESIGN.md §4).

This is the flagship cell for the paper's headline property: ternary-packed
(pack2) the 671B fits in ~168 GB — one TPU pod's HBM, zero weight reload.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register, shrink

CFG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=2048,
    vocab_size=129280,
    attn_type="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        n_shared=1,
        d_ff_expert=2048,
        d_ff_dense=18432,
        n_dense_layers=3,
    ),
    rope_theta=10_000.0,
    source="arXiv:2412.19437; hf",
)

register(
    CFG,
    shrink(CFG),
    dryrun_overrides={
        "train_4k": {"microbatches": 16, "opt_8bit": True},
        "prefill_32k": {},
        "decode_32k": {},
    },
)
