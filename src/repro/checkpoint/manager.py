"""Sharded, atomic, mesh-agnostic checkpointing (numpy-backed).

Layout:  <root>/step_<N>/
            manifest.json    — flattened tree paths, dtypes, shapes, hashes
            <leaf-id>.npy    — one file per array leaf

Fault-tolerance properties (tested in tests/test_fault_tolerance.py):
  * atomic commit: written to ``step_<N>.tmp`` then os.rename'd — a crash
    mid-save never corrupts the latest checkpoint;
  * integrity: every leaf carries a content hash, verified on load;
  * elastic resume: arrays are saved UNSHARDED (logical values) and
    resharded on load via device_put with the *target* shardings — a
    restart may use a different mesh shape than the writer;
  * data-pipeline state and the step counter ride in the manifest, so a
    resumed run continues the exact token stream.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.training.optimizer import QTensor


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QTensor)
    )[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        if isinstance(leaf, QTensor):
            flat[key + "@q"] = leaf.q
            flat[key + "@scale"] = leaf.scale
        else:
            flat[key] = leaf
    return flat


def _leaf_hash(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


def save(root: str | Path, step: int, trees: dict, extra: Optional[dict] = None) -> Path:
    """trees: {"params": pytree, "opt": pytree, ...}; extra: JSON metadata."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    manifest: dict = {"step": step, "extra": extra or {}, "leaves": {}}
    for tree_name, tree in trees.items():
        for key, leaf in _flatten(tree).items():
            arr = np.asarray(jax.device_get(leaf))
            leaf_id = f"{tree_name}__{hashlib.md5(key.encode()).hexdigest()[:12]}"
            np.save(tmp / f"{leaf_id}.npy", arr)
            manifest["leaves"][f"{tree_name}/{key}"] = {
                "file": f"{leaf_id}.npy",
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "hash": _leaf_hash(arr),
            }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(root: str | Path) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = []
    for p in root.iterdir():
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
            if (p / "manifest.json").exists():
                steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(
    root: str | Path,
    step: int,
    templates: dict,
    shardings: Optional[dict] = None,
    verify: bool = True,
) -> tuple:
    """Restore trees matching ``templates`` structure. Returns (trees, extra).

    ``shardings``: optional matching pytrees of NamedSharding — arrays are
    device_put directly to their (possibly different-mesh) placement.
    """
    ckpt = Path(root) / f"step_{step:08d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())

    out = {}
    for tree_name, template in templates.items():
        flat_t = _flatten(template)
        sh_flat = _flatten(shardings[tree_name]) if shardings and shardings.get(tree_name) else {}
        loaded = {}
        for key in flat_t:
            meta = manifest["leaves"][f"{tree_name}/{key}"]
            arr = np.load(ckpt / meta["file"])
            if verify and _leaf_hash(arr) != meta["hash"]:
                raise IOError(f"checkpoint corruption in {tree_name}/{key}")
            if key in sh_flat and sh_flat[key] is not None:
                loaded[key] = jax.device_put(arr, sh_flat[key])
            else:
                loaded[key] = arr
        out[tree_name] = _unflatten_like(template, loaded)
    return out, manifest["extra"]


def _unflatten_like(template, flat: dict):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: isinstance(x, QTensor)
    )
    new_leaves = []
    for path, leaf in leaves_p:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        if isinstance(leaf, QTensor):
            new_leaves.append(QTensor(q=flat[key + "@q"], scale=flat[key + "@scale"]))
        else:
            new_leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def keep_last_k(root: str | Path, k: int = 3) -> None:
    root = Path(root)
    steps = sorted(
        int(p.name.split("_")[1])
        for p in root.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
    )
    for s in steps[:-k]:
        shutil.rmtree(root / f"step_{s:08d}")
