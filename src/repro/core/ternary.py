"""BitNet b1.58 ternary quantization (paper §II-A, §III-B).

Weight quantization follows BitNet b1.58 [Ma et al., 2402.17764]:
    scale = mean(|W|)                      (absmean, per tensor)
    W_q   = round_clip(W / scale, -1, +1)  in {-1, 0, +1}
so the dequantized weight is ``W_q * scale``.

Activation quantization follows the paper's two modes:
  * A8 — BitNet b1.58: per-token absmax int8 in [-128, 127]
  * A4 — BitNet a4.8:  per-token absmax int4 in [-8, 7]
(BitROM's TriMLA takes 4-bit activations natively and runs 8-bit
bit-serially in two cycles; on TPU both execute as one int8 MXU pass —
see DESIGN.md §2.1.)

All functions are pure and jit-safe. Straight-through-estimator (STE)
variants are provided for quantization-aware training.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

EPS = 1e-5


class QuantizedWeight(NamedTuple):
    """Ternary weight in unpacked form: values in {-1, 0, +1} (int8)."""

    wq: jax.Array  # int8, same shape as the source weight
    scale: jax.Array  # f32 scalar (absmean of the source weight)


class QuantizedActivation(NamedTuple):
    """Integer activation with a per-token (row) dequantization scale."""

    xq: jax.Array  # int8 (A8 uses full range, A4 stays in [-8, 7])
    scale: jax.Array  # f32, shape x.shape[:-1] + (1,); dequant: xq / scale


def weight_quant_absmean(w: jax.Array) -> QuantizedWeight:
    """BitNet b1.58 absmean ternary quantization. Returns int8 trits + scale."""
    scale = jnp.mean(jnp.abs(w.astype(jnp.float32)))
    scale = jnp.maximum(scale, EPS)
    wq = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -1.0, 1.0)
    return QuantizedWeight(wq.astype(jnp.int8), scale)


def weight_dequant(q: QuantizedWeight, dtype=jnp.float32) -> jax.Array:
    return (q.wq.astype(jnp.float32) * q.scale).astype(dtype)


def act_quant(x: jax.Array, bits: int = 8) -> QuantizedActivation:
    """Per-token absmax symmetric quantization to ``bits`` (8 or 4)."""
    if bits == 8:
        qmax, qmin = 127.0, -128.0
    elif bits == 4:
        qmax, qmin = 7.0, -8.0
    else:  # pragma: no cover - guarded by config validation
        raise ValueError(f"unsupported activation bits: {bits}")
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = qmax / jnp.maximum(absmax, EPS)
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * scale), qmin, qmax)
    return QuantizedActivation(xq.astype(jnp.int8), scale)


def act_dequant(q: QuantizedActivation, dtype=jnp.float32) -> jax.Array:
    return (q.xq.astype(jnp.float32) / q.scale).astype(dtype)


# ---------------------------------------------------------------------------
# Straight-through estimators for QAT (train_step forward).
# ---------------------------------------------------------------------------


def weight_quant_ste(w: jax.Array) -> jax.Array:
    """Fake-quantized weight with identity gradient (BitNet training rule)."""
    q = weight_quant_absmean(w)
    wdq = weight_dequant(q, dtype=jnp.float32)
    w32 = w.astype(jnp.float32)
    return (w32 + jax.lax.stop_gradient(wdq - w32)).astype(w.dtype)


def act_quant_ste(x: jax.Array, bits: int = 8) -> jax.Array:
    """Fake-quantized activation with identity gradient."""
    q = act_quant(x, bits=bits)
    xdq = act_dequant(q, dtype=jnp.float32)
    x32 = x.astype(jnp.float32)
    return (x32 + jax.lax.stop_gradient(xdq - x32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Reference integer matmul semantics (TriMLA truth table).
#
#   weight  mode      contribution
#   ------  --------  ------------
#     0     skip      0            (EN=0: accumulator disabled)
#    +1     add       +activation
#    -1     subtract  -activation
#
# A ternary MAC is therefore a signed add, never a multiply.
# ---------------------------------------------------------------------------


def ternary_mac_reference(xq: jax.Array, wq: jax.Array) -> jax.Array:
    """int32 accumulation of int8 activations against {-1,0,+1} trits.

    xq: (..., K) int8; wq: (K, N) int8 trits. Returns (..., N) int32.
    Implemented as select(add/sub/skip) to mirror TriMLA exactly.
    """
    x32 = xq.astype(jnp.int32)
    contrib_pos = jnp.einsum("...k,kn->...n", x32, (wq == 1).astype(jnp.int32))
    contrib_neg = jnp.einsum("...k,kn->...n", x32, (wq == -1).astype(jnp.int32))
    return contrib_pos - contrib_neg


def ternary_sparsity(wq: jax.Array) -> jax.Array:
    """Fraction of zero weights (the TriMLA skip rate)."""
    return jnp.mean((wq == 0).astype(jnp.float32))


@partial(jax.jit, static_argnames=("bits",))
def fake_quant_linear(x: jax.Array, w: jax.Array, bits: int = 8) -> jax.Array:
    """QAT forward: y = act_q(x) @ weight_q(w), computed in float with STE."""
    return act_quant_ste(x, bits=bits) @ weight_quant_ste(w)
