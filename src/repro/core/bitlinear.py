"""BitLinear: the ternary projection layer (paper §III-B) in two modes.

QAT mode (training / train_4k cells)
    Master weights are float; the forward fake-quantizes weights (absmean
    ternary) and activations (A8/A4) with straight-through gradients —
    BitNet's training rule. This is what ``train_step`` lowers.

Packed mode (inference / prefill, decode cells)
    Weights are stored as packed trits (uint8, 2.0 or 1.6 bits/weight — the
    BiROMA density analogue) plus one f32 absmean scale. The forward
    quantizes activations to int8, runs the ternary matmul (Pallas kernel
    on TPU, XLA unpack+dot path for sharded lowering), and rescales:

        y = (xq @ trits) * w_scale / x_scale

    Packed weights never exist in bf16 in HBM — dequantization happens in
    VMEM/registers (the "weights never move" property).

Optionally carries a quantized LoRA adapter (paper §III-C) whose delta is
added to the projection output.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import lora as lora_lib
from repro.core import packing
from repro.core.ternary import (
    act_quant,
    fake_quant_linear,
    weight_quant_absmean,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedLinear:
    """Inference-form ternary weight: packed trits + scale (+ true K)."""

    packed: jax.Array  # uint8 (ceil(K/g), N)
    scale: jax.Array  # () f32 absmean
    k: int = dataclasses.field(metadata=dict(static=True))
    codec: str = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Int8Linear:
    """int8 weight + per-axis absmax scale — the beyond-paper codec for the
    high-precision residue (embedding / lm_head), which dominates the
    unpacked HBM bytes of memory-bound decode once the ternary projections
    are packed (e.g. gemma-7b: 1.57 GB of 256k-vocab embeddings)."""

    q: jax.Array  # int8, same shape as the source weight
    scale: jax.Array  # f32, keepdims absmax/127 along the quantized axis


def quantize_int8(w: jax.Array, axis: int) -> Int8Linear:
    w32 = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(w32), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return Int8Linear(q=q, scale=scale)


def dequant_int8(t: Int8Linear, dtype=jnp.bfloat16) -> jax.Array:
    return (t.q.astype(jnp.float32) * t.scale).astype(dtype)


def init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32) -> dict:
    w = jax.random.normal(key, (d_in, d_out), dtype) * (d_in**-0.5)
    return {"w": w}


def apply_qat(params: dict, x: jax.Array, act_bits: int = 8,
              lora_params: Optional[dict] = None) -> jax.Array:
    """Training forward: STE fake-quantized ternary linear."""
    y = fake_quant_linear(x, params["w"], bits=act_bits)
    if lora_params is not None:
        y = y + lora_lib.apply(lora_params, x)
    return y.astype(x.dtype)


def quantize_pack(params: dict, codec: str = "pack2") -> PackedLinear:
    """Freeze a trained master weight into ROM form (packed trits)."""
    q = weight_quant_absmean(params["w"])
    pack = packing.pack2 if codec == "pack2" else packing.pack243
    return PackedLinear(packed=pack(q.wq), scale=q.scale, k=params["w"].shape[0], codec=codec)


def apply_packed(
    pw: PackedLinear,
    x: jax.Array,
    act_bits: int = 8,
    impl: str = "xla",
    lora_params: Optional[dict] = None,
) -> jax.Array:
    """Inference forward on packed ternary weights."""
    from repro.kernels import ops  # lazy: kernels depend on core.packing

    xq = act_quant(x, bits=act_bits)
    acc = ops.ternary_matmul(
        xq.xq, pw.packed, k=pw.k, codec=pw.codec, impl=impl
    )  # (..., N) int32
    y = acc.astype(jnp.float32) * (pw.scale / xq.scale)
    if lora_params is not None:
        y = y + lora_lib.apply(lora_params, x)
    return y.astype(x.dtype)


def apply(
    params_or_packed,
    x: jax.Array,
    act_bits: int = 8,
    impl: str = "xla",
    lora_params: Optional[dict] = None,
) -> jax.Array:
    """Mode-dispatching forward (dict => QAT, PackedLinear => packed)."""
    if isinstance(params_or_packed, PackedLinear):
        return apply_packed(params_or_packed, x, act_bits, impl, lora_params)
    return apply_qat(params_or_packed, x, act_bits, lora_params)
