"""BitLinear: the ternary projection layer (paper §III-B) in two modes.

QAT mode (training / train_4k cells)
    Master weights are float; the forward fake-quantizes weights (absmean
    ternary) and activations (A8/A4) with straight-through gradients —
    BitNet's training rule. This is what ``train_step`` lowers.

Packed mode (inference / prefill, decode cells)
    Weights are stored as packed trits (uint8, 2.0 or 1.6 bits/weight — the
    BiROMA density analogue) plus one f32 absmean scale. The forward
    quantizes activations to int8, runs the ternary matmul (Pallas kernel
    on TPU, XLA unpack+dot path for sharded lowering), and rescales:

        y = (xq @ trits) * w_scale / x_scale

    Packed weights never exist in bf16 in HBM — dequantization happens in
    VMEM/registers (the "weights never move" property).

Optionally carries a quantized LoRA adapter (paper §III-C) whose delta is
added to the projection output.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import lora as lora_lib
from repro.core import packing
from repro.core.ternary import (
    QuantizedActivation,
    act_quant,
    fake_quant_linear,
    weight_quant_absmean,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedLinear:
    """Inference-form ternary weight: packed trits + scale (+ true K)."""

    packed: jax.Array  # uint8 (ceil(K/g), N)
    scale: jax.Array  # () f32 absmean
    k: int = dataclasses.field(metadata=dict(static=True))
    codec: str = dataclasses.field(metadata=dict(static=True))
    # SDC integrity metadata (optional; stamped by models/pack.py when
    # cfg.bitnet.integrity): wsum is the (K,) scale-weighted ABFT column
    # checksum (kernels/ternary_matmul.abft_wsum), crc the pack-time
    # crc32 of the packed words (core/packing.packed_crc32). None on
    # trees packed without integrity — every consumer must tolerate it.
    wsum: Optional[jax.Array] = None  # f32 (K,) (+ leading stack dims)
    crc: Optional[int] = dataclasses.field(
        default=None, metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FusedPackedLinear:
    """Several same-input ternary projections packed side by side along N.

    The fused-projection form (wq‖wk‖wv, gate‖up): one act-quant + one
    kernel launch serve every segment, amortizing the in-VMEM trit decode
    across the combined output width. ``scale`` is *per column* (each
    segment keeps its own absmean scale, repeated over its width) so the
    epilogue rescale stays exact; ``splits`` records the segment widths
    for the output split.
    """

    packed: jax.Array  # uint8 (ceil(K/g), sum(splits))
    scale: jax.Array  # (sum(splits),) f32 per-column absmean
    k: int = dataclasses.field(metadata=dict(static=True))
    codec: str = dataclasses.field(metadata=dict(static=True))
    splits: tuple = dataclasses.field(metadata=dict(static=True))
    # SDC integrity metadata — see PackedLinear; the fused wsum is the
    # SUM of the segments' wsum vectors (each already scale-weighted, so
    # the per-segment row-sums add), the crc covers the fused words.
    wsum: Optional[jax.Array] = None  # f32 (K,) (+ leading stack dims)
    crc: Optional[int] = dataclasses.field(
        default=None, metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Int8Linear:
    """int8 weight + per-axis absmax scale — the beyond-paper codec for the
    high-precision residue (embedding / lm_head), which dominates the
    unpacked HBM bytes of memory-bound decode once the ternary projections
    are packed (e.g. gemma-7b: 1.57 GB of 256k-vocab embeddings)."""

    q: jax.Array  # int8, same shape as the source weight
    scale: jax.Array  # f32, keepdims absmax/127 along the quantized axis


def quantize_int8(w: jax.Array, axis: int) -> Int8Linear:
    w32 = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(w32), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return Int8Linear(q=q, scale=scale)


def dequant_int8(t: Int8Linear, dtype=jnp.bfloat16) -> jax.Array:
    return (t.q.astype(jnp.float32) * t.scale).astype(dtype)


def init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32) -> dict:
    w = jax.random.normal(key, (d_in, d_out), dtype) * (d_in**-0.5)
    return {"w": w}


def apply_qat(params: dict, x: jax.Array, act_bits: int = 8,
              lora_params: Optional[dict] = None) -> jax.Array:
    """Training forward: STE fake-quantized ternary linear."""
    y = fake_quant_linear(x, params["w"], bits=act_bits)
    if lora_params is not None:
        y = y + lora_lib.apply(lora_params, x)
    return y.astype(x.dtype)


def quantize_pack(params: dict, codec: str = "pack2") -> PackedLinear:
    """Freeze a trained master weight into ROM form (packed trits)."""
    q = weight_quant_absmean(params["w"])
    pack = packing.pack2 if codec == "pack2" else packing.pack243
    return PackedLinear(packed=pack(q.wq), scale=q.scale, k=params["w"].shape[0], codec=codec)


def packed_matmul(
    pw,
    x,
    act_bits: int = 8,
    impl: str = "xla",
    fuse_actq: bool = True,
) -> jax.Array:
    """The ONE packed ternary fast path: act-quant -> matmul -> rescale.

    Shared by every consumer (qops.linear, apply_packed, and through them
    the models, the serving engine and the LoRA add-on). ``pw`` is a
    ``PackedLinear`` (scalar absmean scale) or ``FusedPackedLinear``
    (per-column scale); ``x`` is (..., K) raw float — or an already-
    quantized ``QuantizedActivation`` when the producing op knows the
    scale, which skips the absmax pass entirely (the carried-scale
    fallback). Returns the *float32* projection output (callers cast to
    the activation dtype).

    Path selection on ``impl="pallas"``:
      * raw ``x`` + ``fuse_actq`` (the default, ``BitNetConfig.
        fuse_act_quant``) -> act-quant-PROLOGUE-fused kernel: the int8
        quantization happens inside the kernel's phase-0 K sweep, so
        neither the (M, K) int8 activations nor the (M, N) int32
        accumulator ever exist in HBM — one launch goes raw bf16/f32 ->
        scaled float out;
      * ``QuantizedActivation`` x, or ``fuse_actq=False`` -> the known-
        scale epilogue-fused kernel (act-quant as a separate XLA op).
    The XLA impl always runs the separate quantize-then-matmul pipeline
    (numerically identical ops; bit-exact against the fused prologue).
    """
    from repro.kernels import ops  # lazy: kernels depend on core.packing

    scale = jnp.asarray(pw.scale, jnp.float32)
    if impl == "pallas" and fuse_actq and not isinstance(x, QuantizedActivation):
        col = jnp.broadcast_to(scale.reshape(-1), (pw.packed.shape[-1],))
        return ops.ternary_matmul_actq(
            x, pw.packed, col, k=pw.k, codec=pw.codec, act_bits=act_bits,
        )
    xq = x if isinstance(x, QuantizedActivation) else act_quant(x, bits=act_bits)
    if impl == "pallas":
        # the kernel wants an explicit (N,) per-column vector; the XLA path
        # keeps the scale's natural shape — a scalar scale must divide by
        # the per-row activation scale BEFORE broadcasting over N, or the
        # (b, N)-shaped division costs a ulp that breaks the bit-exactness
        # of mixed-batch vs solo decode across batch-size compilations.
        scale = jnp.broadcast_to(scale.reshape(-1), (pw.packed.shape[-1],))
    return ops.ternary_matmul_fused(
        xq.xq, pw.packed, xq.scale, scale,
        k=pw.k, codec=pw.codec, impl=impl,
    )


# ---------------------------------------------------------------------------
# ABFT-checked matmul (SDC detection — docs/kernels.md "ABFT checksums")
# ---------------------------------------------------------------------------

# Tolerance model for the f32 row-sum comparison: both sides reassociate
# sums of ~K (prediction GEMV) and ~N (output row-sum) f32 terms, so the
# rounding error is bounded by a small multiple of eps times the
# POSITIVE-TERM magnitude of those sums; a single flipped trit shifts the
# row-sum by ±|xq[r, k]| * s (±2x for a -1<->+1 flip), far outside that
# envelope whenever the row's activation quant is nonzero.
ABFT_ATOL = 1e-4
ABFT_EPS_FACTOR = 64.0


class AbftError(ValueError):
    """An ABFT row-sum check failed: the packed weights disagree with
    their pack-time checksum — a weight (or checksum) bit flipped since
    pack time. Carries the worst offending row index."""

    def __init__(self, msg: str, row: Optional[int] = None):
        super().__init__(msg)
        self.row = row


def abft_check(pw, x, act_bits: int = 8, impl: str = "xla"):
    """Run the packed matmul WITH the ABFT row-sum check (jittable).

    Quantizes ``x`` once, computes ``y = packed_matmul(pw, xq)``, then
    predicts every output row-sum from the pack-time checksum vector:

        pred[r] = (xq[r, :] @ pw.wsum) / x_scale[r]

    (one GEMV — a factor-N cheaper than the matmul it guards). Returns
    ``(y, residual, tol)`` where ``residual[r] = |sum_n y[r, n] -
    pred[r]|`` and ``tol`` is the dtype-derived bound above; a sound
    check is ``residual <= tol``. Callers that want an exception use
    :func:`packed_matmul_checked`. Leaf must be 2-D (slice stacked
    leaves per layer first) and carry ``wsum`` (pack with integrity).
    """
    from repro.kernels import ops  # lazy: kernels depend on core.packing

    if pw.wsum is None:
        raise AbftError(
            "packed leaf carries no ABFT checksum — repack with "
            "models.pack.pack_params(..., integrity=True) or stamp via "
            "models.pack.add_integrity")
    xq = x if isinstance(x, QuantizedActivation) else act_quant(
        x, bits=act_bits)
    scale = jnp.asarray(pw.scale, jnp.float32)
    if impl == "pallas":  # same broadcast discipline as packed_matmul
        scale = jnp.broadcast_to(scale.reshape(-1), (pw.packed.shape[-1],))
    return ops.ternary_matmul_abft(
        xq.xq, pw.packed, xq.scale, scale, jnp.asarray(pw.wsum, jnp.float32),
        k=pw.k, codec=pw.codec, impl=impl,
        atol=ABFT_ATOL, eps_factor=ABFT_EPS_FACTOR,
    )


def packed_matmul_checked(pw, x, act_bits: int = 8, impl: str = "xla"):
    """Host-level ABFT-checked matmul: returns ``y`` or raises
    :class:`AbftError` naming the worst offending row. The residual
    comparison syncs to host — use at scrub points and in tests, not
    inside the jitted decode graph."""
    y, residual, tol = abft_check(pw, x, act_bits=act_bits, impl=impl)
    bad = jnp.asarray(residual > tol)
    if bool(bad.any()):
        r = int(jnp.argmax(residual - tol))
        raise AbftError(
            f"ABFT row-sum mismatch on {int(bad.sum())} row(s): worst "
            f"row {r} residual {float(residual[r]):.3e} > tol "
            f"{float(tol[r]):.3e} — packed words disagree with their "
            "pack-time checksum (weight SDC)", row=r)
    return y


def expert_packed_matmul(
    pw,
    x: jax.Array,
    act_bits: int = 8,
    impl: str = "xla",
    fuse_actq: bool = True,
) -> jax.Array:
    """Expert-batched packed fast path: x (E, C, K) @ packed (E, K/g, N).

    On the Pallas path this is ONE E-loop kernel launch over all experts
    (leading expert grid dimension) — the ``pallas_call`` batching rule
    the vmapped per-expert path never had. With raw ``x`` and
    ``fuse_actq`` (the default) the act-quant prologue fuses into the
    launch; with a pre-quantized ``QuantizedActivation`` x or
    ``fuse_actq=False`` the *carried-scale* E-loop kernel runs instead
    (act-quant as a separate XLA op, known-scale epilogue-fused launch) —
    experts no longer fall back to the vmapped XLA path in that mode.
    The XLA impl runs the vmapped per-expert ``packed_matmul``,
    bit-identical numerics. ``pw`` is an expert-stacked ``PackedLinear``
    (scale (E,)) or ``FusedPackedLinear`` (per-column scale (E, N), e.g.
    pack-time-fused w_gate‖w_up). Returns (E, C, N) float32.
    """
    from repro.kernels import ops  # lazy: kernels depend on core.packing

    if impl == "pallas":
        scale = jnp.asarray(pw.scale, jnp.float32)
        n = pw.packed.shape[-1]
        if scale.ndim == 1:  # (E,) scalar absmean per expert -> per-column
            scale = jnp.broadcast_to(scale[:, None], (scale.shape[0], n))
        if fuse_actq and not isinstance(x, QuantizedActivation):
            return ops.ternary_matmul_expert(
                x, pw.packed, scale, k=pw.k, codec=pw.codec,
                act_bits=act_bits,
            )
        q = x if isinstance(x, QuantizedActivation) else act_quant(
            x, bits=act_bits
        )
        return ops.ternary_matmul_expert_fused(
            q.xq, pw.packed, q.scale, scale, k=pw.k, codec=pw.codec,
        )

    def one(packed_e, scale_e, x_e):
        if isinstance(pw, FusedPackedLinear):
            leaf = FusedPackedLinear(packed=packed_e, scale=scale_e, k=pw.k,
                                     codec=pw.codec, splits=pw.splits)
        else:
            leaf = PackedLinear(packed=packed_e, scale=scale_e, k=pw.k,
                                codec=pw.codec)
        # impl pinned to "xla": a vmapped pallas_call has no batching rule
        # on this jax version — the E-loop branch above is the Pallas path.
        return packed_matmul(leaf, x_e, act_bits=act_bits, impl="xla")

    return jax.vmap(one)(pw.packed, jnp.asarray(pw.scale, jnp.float32), x)


def apply_packed(
    pw: PackedLinear,
    x: jax.Array,
    act_bits: int = 8,
    impl: str = "xla",
    lora_params: Optional[dict] = None,
) -> jax.Array:
    """Inference forward on packed ternary weights.

    ``lora_params`` is a standalone convenience using ``lora_lib.apply``
    defaults; the model projection paths apply adapters with the
    config-driven recipe in ``qops._apply_lora`` instead.
    """
    y = packed_matmul(pw, x, act_bits=act_bits, impl=impl)
    if lora_params is not None:
        y = y + lora_lib.apply(lora_params, x)
    return y.astype(x.dtype)


def apply(
    params_or_packed,
    x: jax.Array,
    act_bits: int = 8,
    impl: str = "xla",
    lora_params: Optional[dict] = None,
) -> jax.Array:
    """Mode-dispatching forward (dict => QAT, Packed/Fused => packed)."""
    if isinstance(params_or_packed, (PackedLinear, FusedPackedLinear)):
        return apply_packed(params_or_packed, x, act_bits, impl, lora_params)
    return apply_qat(params_or_packed, x, act_bits, lora_params)
