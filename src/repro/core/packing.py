"""Ternary weight packing codecs — the BiROMA density analogue (paper §III-B).

BiROMA stores two ternary weights per transistor, doubling bit density to
4,967 kb/mm². On TPU the scarce resource is HBM capacity/bandwidth, so the
analogue is packing trits densely in HBM:

  * ``pack2`` — 2 bits/trit, 4 trits per uint8 (fast shift/mask decode).
      encoding: 0b00 = 0, 0b01 = +1, 0b10 = -1 (matches the TriMLA
      comparator truth table: MSB = "is negative", LSB = "is positive";
      MSB|LSB == 0 means skip).
  * ``pack243`` — base-3^5, 5 trits per uint8 = 1.6 bits/trit, within
      1.3% of the 1.58-bit entropy limit. This is the "two weights per
      cell" trick pushed to its arithmetic conclusion (beyond-paper).

Both codecs pack along the *contraction* (K) axis of a (K, N) weight so a
matmul kernel can decode K-tiles locally in VMEM. K must be padded to a
multiple of the group size (4 or 5); ``pad_k`` handles that with zeros
(zero trits are skip-ops, so padding is computation-neutral).
"""

from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp
import numpy as np

PACK2_GROUP = 4
PACK243_GROUP = 5

# 2-bit trit codes (TriMLA comparator truth table).
_CODE_ZERO = 0b00
_CODE_POS = 0b01
_CODE_NEG = 0b10


def padded_k(k: int, group: int) -> int:
    return (k + group - 1) // group * group


def pad_k(wq: jax.Array, group: int) -> jax.Array:
    """Zero-pad the K (first) axis of an int8 trit array to a group multiple."""
    k = wq.shape[0]
    pk = padded_k(k, group)
    if pk == k:
        return wq
    pad = [(0, pk - k)] + [(0, 0)] * (wq.ndim - 1)
    return jnp.pad(wq, pad)


# ---------------------------------------------------------------------------
# pack2: 4 trits / byte, 2 bits each
# ---------------------------------------------------------------------------


def _trit_to_code2(t: jax.Array) -> jax.Array:
    """{-1,0,+1} int8 -> 2-bit code (uint8)."""
    return jnp.where(t == 1, _CODE_POS, jnp.where(t == -1, _CODE_NEG, _CODE_ZERO)).astype(
        jnp.uint8
    )


def _code2_to_trit(c: jax.Array) -> jax.Array:
    """2-bit code -> {-1,0,+1} int8. trit = LSB - MSB."""
    lsb = (c & 1).astype(jnp.int8)
    msb = ((c >> 1) & 1).astype(jnp.int8)
    return lsb - msb


def pack2(wq: jax.Array) -> jax.Array:
    """(K, ...) int8 trits -> (K/4, ...) uint8. K padded with zeros."""
    wq = pad_k(wq, PACK2_GROUP)
    k = wq.shape[0]
    codes = _trit_to_code2(wq).reshape((k // PACK2_GROUP, PACK2_GROUP) + wq.shape[1:])
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8).reshape(
        (1, PACK2_GROUP) + (1,) * (wq.ndim - 1)
    )
    return jnp.sum(
        codes.astype(jnp.uint8) << shifts, axis=1, dtype=jnp.uint8
    )


def unpack2(packed: jax.Array, k: int | None = None) -> jax.Array:
    """(K/4, ...) uint8 -> (K, ...) int8 trits; trims padding to ``k``."""
    parts = []
    for i in range(PACK2_GROUP):
        parts.append(_code2_to_trit((packed >> (2 * i)) & 0b11))
    out = jnp.stack(parts, axis=1).reshape((-1,) + packed.shape[1:])
    if k is not None:
        out = out[:k]
    return out


# ---------------------------------------------------------------------------
# pack243: 5 trits / byte, base-3 (beyond-paper density: 1.6 b/trit)
# ---------------------------------------------------------------------------


def pack243(wq: jax.Array) -> jax.Array:
    """(K, ...) int8 trits -> (K/5, ...) uint8 with value sum (t_i+1)*3^i."""
    wq = pad_k(wq, PACK243_GROUP)
    k = wq.shape[0]
    digits = (wq.astype(jnp.int32) + 1).reshape(
        (k // PACK243_GROUP, PACK243_GROUP) + wq.shape[1:]
    )
    weights = jnp.array([1, 3, 9, 27, 81], dtype=jnp.int32).reshape(
        (1, PACK243_GROUP) + (1,) * (wq.ndim - 1)
    )
    return jnp.sum(digits * weights, axis=1).astype(jnp.uint8)


def unpack243(packed: jax.Array, k: int | None = None) -> jax.Array:
    """(K/5, ...) uint8 -> (K, ...) int8 trits via repeated divmod 3."""
    v = packed.astype(jnp.int32)
    parts = []
    for _ in range(PACK243_GROUP):
        parts.append((v % 3 - 1).astype(jnp.int8))
        v = v // 3
    out = jnp.stack(parts, axis=1).reshape((-1,) + packed.shape[1:])
    if k is not None:
        out = out[:k]
    return out


# numpy lookup table (243, 5) used by the Pallas kernel for decode-by-gather.
def decode_table_243() -> np.ndarray:
    tbl = np.zeros((243, PACK243_GROUP), dtype=np.int8)
    for v in range(243):
        x = v
        for i in range(PACK243_GROUP):
            tbl[v, i] = x % 3 - 1
            x //= 3
    return tbl


# ---------------------------------------------------------------------------
# Density accounting (DESIGN.md §2 / hwmodel)
# ---------------------------------------------------------------------------

BITS_PER_TRIT = {"none": 8.0, "pack2": 2.0, "pack243": 8.0 / 5.0}
TRIT_ENTROPY_BITS = 1.5849625007211563  # log2(3)


def packed_bytes(n_weights: int, codec: str) -> int:
    """HBM bytes needed to store ``n_weights`` ternary weights under a codec."""
    if codec == "none":
        return n_weights  # int8 unpacked
    if codec == "pack2":
        return (n_weights + PACK2_GROUP - 1) // PACK2_GROUP
    if codec == "pack243":
        return (n_weights + PACK243_GROUP - 1) // PACK243_GROUP
    raise ValueError(f"unknown codec {codec!r}")


# ---------------------------------------------------------------------------
# Integrity (serving/sdc.py scrub path)
# ---------------------------------------------------------------------------


def packed_crc32(packed) -> int:
    """crc32 over a packed trit array's bytes — the ROM integrity stamp.

    Computed once at pack time (the "fab" checksum of the ROM contents)
    and re-verified by the serving scrub: any bit flip in the packed
    words — including flips ABFT cannot see because the matching
    activations were zero — changes the crc. Device arrays are pulled to
    host; uint8 packed words have no endianness ambiguity."""
    return zlib.crc32(np.asarray(packed).tobytes()) & 0xFFFFFFFF
