"""Quantized LoRA domain adapters (paper §III-C, Tables I/II, Fig. 6).

BitROM's ROM weights are fused at fabrication; task flexibility comes from
small SRAM-backed LoRA adapters. The paper's configuration (which we adopt
as defaults and reproduce in benchmarks):

  * rank 16
  * adapters ONLY on Value + Output projections (attention) and the Down
    projection (MLP) — Table II shows this matches full adaptation at
    0.22% extra parameters
  * LoRA weights quantized to 6 bits, activations 8 bits (Falcon3 BitNet
    convention; Fig. 6(a) shows 6b is lossless for task metrics)
  * extra ops ~0.7% of the host projection layer

The base (ROM) weights are frozen during adaptation — training updates only
LoRA parameters, mirroring the hardware exactly.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.ternary import act_quant_ste

DEFAULT_RANK = 16
DEFAULT_LORA_BITS = 6
DEFAULT_ACT_BITS = 8
# Paper's Table II row 4 ("our configuration"): V, O, Down.
DEFAULT_TARGETS: tuple = ("v", "o", "down")


def init(key: jax.Array, d_in: int, d_out: int, rank: int = DEFAULT_RANK, dtype=jnp.float32):
    """LoRA factors: A ~ N(0, 1/r) (d_in, r); B = 0 (r, d_out)."""
    a = jax.random.normal(key, (d_in, rank), dtype) * (1.0 / rank) ** 0.5
    b = jnp.zeros((rank, d_out), dtype)
    return {"a": a, "b": b}


def _quant_sym_ste(w: jax.Array, bits: int) -> jax.Array:
    """Per-output-column symmetric fake quantization with STE."""
    qmax = 2.0 ** (bits - 1) - 1.0
    w32 = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(w32), axis=0, keepdims=True)
    scale = qmax / jnp.maximum(absmax, 1e-8)
    wq = jnp.clip(jnp.round(w32 * scale), -qmax - 1.0, qmax) / scale
    return (w32 + jax.lax.stop_gradient(wq - w32)).astype(w.dtype)


def apply(
    params: dict,
    x: jax.Array,
    alpha: float = 2.0 * DEFAULT_RANK,
    weight_bits: int = DEFAULT_LORA_BITS,
    act_bits: int = DEFAULT_ACT_BITS,
) -> jax.Array:
    """Quantized LoRA delta: (x_q @ A_q) @ B_q * (alpha / r)."""
    rank = params["a"].shape[-1]
    aq = _quant_sym_ste(params["a"], weight_bits)
    bq = _quant_sym_ste(params["b"], weight_bits)
    xq = act_quant_ste(x, bits=act_bits)
    return ((xq @ aq) @ bq) * (alpha / rank)


# ---------------------------------------------------------------------------
# Accounting (reproduces Table I/II parameter-% columns and the 0.7%-ops claim)
# ---------------------------------------------------------------------------


def lora_params_count(d_in: int, d_out: int, rank: int = DEFAULT_RANK) -> int:
    return rank * (d_in + d_out)


def lora_ops_fraction(d_in: int, d_out: int, rank: int = DEFAULT_RANK) -> float:
    """Extra MACs relative to the host projection (paper: ~0.7%)."""
    return rank * (d_in + d_out) / (d_in * d_out)


def adapter_param_fraction(
    layer_dims: Sequence[tuple], total_base_params: int, rank: int = DEFAULT_RANK
) -> float:
    """Σ LoRA params over adapted layers / base model params (Table I col 2)."""
    extra = sum(lora_params_count(di, do, rank) for di, do in layer_dims)
    return extra / total_base_params
