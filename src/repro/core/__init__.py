"""Core: the paper's contribution as composable JAX modules.

  ternary    — BitNet b1.58 quantization (absmean ternary weights, A8/A4 acts)
  packing    — BiROMA-analogue trit packing codecs (pack2 / pack243)
  bitlinear  — the ternary projection layer (QAT + packed-inference modes)
  lora       — 6-bit quantized LoRA adapters (V/O/Down, rank 16)
  kv_cache   — two-tier DR KV cache (hot early-token buffer + cold tail)
  dr_edram   — decode-refresh eDRAM access model (43.6% reduction, Fig. 5)
"""

from repro.core import bitlinear, dr_edram, kv_cache, lora, packing, ternary  # noqa: F401
