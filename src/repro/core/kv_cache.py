"""Two-tier (DR eDRAM-style) KV cache (paper §IV).

BitROM buffers the first ``hot_cap`` tokens of a sequence on-die (DR eDRAM)
and leaves the tail in external DRAM. The TPU adaptation keeps the same
*structure* — a small pinned "hot" buffer for early tokens plus a large
"cold" buffer — because the structure is what produces the access-traffic
win (early tokens are read at every decode step; see ``dr_edram.py``).

The cache is a pytree of fixed-shape arrays (jit/scan friendly):

  hot_k/hot_v   : (batch, hot_cap, ...)      early tokens
  cold_k/cold_v : (batch, cold_cap, ...)     the rest
  length        : ()  int32                  tokens written so far

``...`` is whatever a layer caches per token: (n_kv_heads, head_dim) for
GQA/MQA, (d_latent,) for MLA latents. Appends route on position; attention
runs per-tier and combines with a numerically-stable streaming softmax, so
no concat of the two tiers is ever materialized.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp


class TieredKVCache(NamedTuple):
    hot_k: jax.Array
    hot_v: jax.Array
    cold_k: jax.Array
    cold_v: jax.Array
    length: jax.Array  # scalar int32: number of tokens currently cached

    @property
    def hot_cap(self) -> int:
        return self.hot_k.shape[1]

    @property
    def cold_cap(self) -> int:
        return self.cold_k.shape[1]

    @property
    def capacity(self) -> int:
        return self.hot_cap + self.cold_cap


def init_cache(
    batch: int,
    hot_cap: int,
    cold_cap: int,
    kv_shape: Sequence[int],
    dtype=jnp.bfloat16,
) -> TieredKVCache:
    shape_hot = (batch, hot_cap) + tuple(kv_shape)
    shape_cold = (batch, cold_cap) + tuple(kv_shape)
    return TieredKVCache(
        hot_k=jnp.zeros(shape_hot, dtype),
        hot_v=jnp.zeros(shape_hot, dtype),
        cold_k=jnp.zeros(shape_cold, dtype),
        cold_v=jnp.zeros(shape_cold, dtype),
        length=jnp.zeros((), jnp.int32),
    )


def append(cache: TieredKVCache, k_new: jax.Array, v_new: jax.Array) -> TieredKVCache:
    """Append ``t_new`` tokens (batch, t_new, ...). Early positions land hot.

    Routing is data-independent given ``cache.length`` (a traced scalar), so
    we write both tiers with masked dynamic_update_slice semantics: each new
    token goes to the hot tier if its absolute position < hot_cap, else cold.
    """
    b, t_new = k_new.shape[0], k_new.shape[1]
    start = cache.length
    pos = start + jnp.arange(t_new, dtype=jnp.int32)  # absolute positions

    def scatter(tier_k, tier_v, tier_pos, in_tier):
        # tier_pos: position within the tier (clipped); in_tier: bool mask
        cap = tier_k.shape[1]
        idx = jnp.clip(tier_pos, 0, cap - 1)
        onehot = (
            jax.nn.one_hot(idx, cap, dtype=tier_k.dtype)
            * in_tier.astype(tier_k.dtype)[:, None]
        )  # (t_new, cap)
        # (b, t, ...) -> (b, cap, ...): accumulate-overwrite via where
        upd_k = jnp.einsum("tc,bt...->bc...", onehot, k_new.astype(tier_k.dtype))
        upd_v = jnp.einsum("tc,bt...->bc...", onehot, v_new.astype(tier_v.dtype))
        written = jnp.einsum("tc->c", onehot) > 0
        mask = written.reshape((1, cap) + (1,) * (tier_k.ndim - 2))
        return jnp.where(mask, upd_k, tier_k), jnp.where(mask, upd_v, tier_v)

    in_hot = pos < cache.hot_cap
    hot_k, hot_v = scatter(cache.hot_k, cache.hot_v, pos, in_hot)
    cold_k, cold_v = scatter(cache.cold_k, cache.cold_v, pos - cache.hot_cap, ~in_hot)
    return TieredKVCache(hot_k, hot_v, cold_k, cold_v, start + t_new)


def append_decode(cache: TieredKVCache, k_new: jax.Array, v_new: jax.Array) -> TieredKVCache:
    """Fast path for decode: append exactly one token (batch, ...)."""
    pos = cache.length
    in_hot = pos < cache.hot_cap

    def upd(tier, new, tier_pos, write):
        cap = tier.shape[1]
        if cap == 0:  # zero-size tier (e.g. SWA: hot_cap=0) — nothing to write
            return tier
        idx = jnp.clip(tier_pos, 0, cap - 1)
        new = new.astype(tier.dtype)[:, None]  # (b, 1, ...)
        updated = jax.lax.dynamic_update_slice_in_dim(tier, new, idx, axis=1)
        return jnp.where(write, updated, tier)

    hot_k = upd(cache.hot_k, k_new, pos, in_hot)
    hot_v = upd(cache.hot_v, v_new, pos, in_hot)
    cold_k = upd(cache.cold_k, k_new, pos - cache.hot_cap, ~in_hot)
    cold_v = upd(cache.cold_v, v_new, pos - cache.hot_cap, ~in_hot)
    return TieredKVCache(hot_k, hot_v, cold_k, cold_v, pos + 1)


def append_decode_ring(cache: TieredKVCache, k_new: jax.Array, v_new: jax.Array) -> TieredKVCache:
    """Decode append with a *ring-buffer* cold tier (sliding-window archs).

    Position p ≥ hot_cap lands at cold slot (p - hot_cap) % cold_cap, so the
    cold tier holds exactly the last ``cold_cap`` tokens (SWA window) and
    early tokens are evicted — DR tiering uses hot_cap=0 here (DESIGN.md §4).
    """
    pos = cache.length
    in_hot = pos < cache.hot_cap

    def upd(tier, new, tier_pos, write):
        cap = tier.shape[1]
        if cap == 0:  # zero-size tier — nothing to write
            return tier
        idx = jnp.clip(tier_pos % cap, 0, cap - 1)
        new = new.astype(tier.dtype)[:, None]
        updated = jax.lax.dynamic_update_slice_in_dim(tier, new, idx, axis=1)
        return jnp.where(write, updated, tier)

    hot_k = upd(cache.hot_k, k_new, pos, in_hot)
    hot_v = upd(cache.hot_v, v_new, pos, in_hot)
    cold_k = upd(cache.cold_k, k_new, pos - cache.hot_cap, ~in_hot)
    cold_v = upd(cache.cold_v, v_new, pos - cache.hot_cap, ~in_hot)
    return TieredKVCache(hot_k, hot_v, cold_k, cold_v, pos + 1)


# ---------------------------------------------------------------------------
# Tiered attention read: per-tier partial attention + streaming-softmax merge
# (never concatenates the tiers — the "hot" tier stays a separate buffer).
# ---------------------------------------------------------------------------


def _upcast(x):
    """fp8 tiers compute in bf16 (avoids materializing a 4x f32 copy of the
    whole cache — observed as multi-GiB temp on the decode dry-run);
    everything else upcasts to f32 for exactness."""
    if x.dtype == jnp.float8_e4m3fn:
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _tier_partial(q, k, v, valid, scale):
    """Partial attention over one tier.

    q: (b, h, d); k/v: (b, s, g, d) with g = kv heads (h = g * rep);
    valid: (b, s) bool. Returns (numerator (b,h,d), denom (b,h), max (b,h)).
    """
    b, s, g, d = k.shape
    h = q.shape[1]
    if s == 0:  # zero-capacity tier: neutral element of the streaming merge
        dv = v.shape[-1]
        return (
            jnp.zeros((b, h, dv), jnp.float32),
            jnp.zeros((b, h), jnp.float32),
            jnp.full((b, h), jnp.finfo(jnp.float32).min),
        )
    rep = h // g
    qg = q.reshape(b, g, rep, d).astype(jnp.float32)
    kf = _upcast(k)
    vf = _upcast(v)
    logits = jnp.einsum(
        "bgrd,bsgd->bgrs", qg.astype(kf.dtype), kf, preferred_element_type=jnp.float32
    ) * scale
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(valid[:, None, None, :], logits, neg)
    m = jnp.max(logits, axis=-1)  # (b,g,r)
    # guard fully-invalid tiers: exp(neg - neg) would be 1; zero them via mask
    p = jnp.exp(logits - m[..., None]) * valid[:, None, None, :]
    denom = jnp.sum(p, axis=-1)  # (b,g,r)
    num = jnp.einsum("bgrs,bsgd->bgrd", p.astype(vf.dtype), vf,
                     preferred_element_type=jnp.float32)  # (b,g,r,d)
    return num.reshape(b, h, d), denom.reshape(b, h), m.reshape(b, h)


def tiered_decode_attention(
    q: jax.Array,
    cache: TieredKVCache,
    scale: float | None = None,
    ring: bool = False,
) -> jax.Array:
    """One-token attention over both tiers. q: (b, h, d) -> (b, h, d).

    ``ring`` marks a ring-buffer cold tier (SWA): validity clamps at
    cold_cap (every slot valid once the window has wrapped). The clamped
    formula is also correct for the non-ring case, so it is always used;
    the flag is kept for call-site clarity.
    """
    del ring  # validity formula below covers both layouts
    d = q.shape[-1]
    scale = scale if scale is not None else d**-0.5
    length = cache.length
    hot_valid = jnp.arange(cache.hot_cap) < length
    n_cold = jnp.clip(length - cache.hot_cap, 0, cache.cold_cap)
    cold_valid = jnp.arange(cache.cold_cap) < n_cold
    b = q.shape[0]
    hot_valid = jnp.broadcast_to(hot_valid[None], (b, cache.hot_cap))
    cold_valid = jnp.broadcast_to(cold_valid[None], (b, cache.cold_cap))

    n1, d1, m1 = _tier_partial(q, cache.hot_k, cache.hot_v, hot_valid, scale)
    n2, d2, m2 = _tier_partial(q, cache.cold_k, cache.cold_v, cold_valid, scale)

    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * (d1 > 0)
    a2 = jnp.exp(m2 - m) * (d2 > 0)
    num = n1 * a1[..., None] + n2 * a2[..., None]
    den = d1 * a1 + d2 * a2
    return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)


def tiered_decode_attention_latent(
    q: jax.Array,  # (b, h, D) — D = latent + rope dims
    cache: TieredKVCache,
    value_dim: int,
    scale: float,
) -> jax.Array:
    """MLA absorbed-form attention over a tiered *latent* cache.

    The cache k-slot holds (c_kv ‖ k_rope) per token, shape (b, s, D); the
    v-slot is empty (0-dim) — values are the first ``value_dim`` dims of the
    k-slot (the latent), so the latent is stored exactly once. Returns the
    per-head latent context (b, h, value_dim).
    """
    length = cache.length
    b = q.shape[0]
    hot_valid = jnp.broadcast_to(
        (jnp.arange(cache.hot_cap) < length)[None], (b, cache.hot_cap)
    )
    n_cold = jnp.clip(length - cache.hot_cap, 0, cache.cold_cap)
    cold_valid = jnp.broadcast_to(
        (jnp.arange(cache.cold_cap) < n_cold)[None], (b, cache.cold_cap)
    )

    def partial(kbuf, valid):
        if kbuf.shape[1] == 0:  # zero-capacity tier: neutral merge element
            h = q.shape[1]
            return (
                jnp.zeros((b, h, value_dim), jnp.float32),
                jnp.zeros((b, h), jnp.float32),
                jnp.full((b, h), jnp.finfo(jnp.float32).min),
            )
        kf = kbuf.astype(jnp.float32)  # (b, s, D)
        logits = jnp.einsum("bhd,bsd->bhs", q.astype(jnp.float32), kf) * scale
        neg = jnp.finfo(jnp.float32).min
        logits = jnp.where(valid[:, None, :], logits, neg)
        m = jnp.max(logits, axis=-1)  # (b, h)
        p = jnp.exp(logits - m[..., None]) * valid[:, None, :]
        denom = jnp.sum(p, axis=-1)
        num = jnp.einsum("bhs,bsv->bhv", p, kf[..., :value_dim])
        return num, denom, m

    n1, d1, m1 = partial(cache.hot_k, hot_valid)
    n2, d2, m2 = partial(cache.cold_k, cold_valid)
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * (d1 > 0)
    a2 = jnp.exp(m2 - m) * (d2 > 0)
    num = n1 * a1[..., None] + n2 * a2[..., None]
    den = d1 * a1 + d2 * a2
    return num / jnp.maximum(den, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# Traffic accounting hooks (ties the functional cache to hwmodel/dr_edram)
# ---------------------------------------------------------------------------


def step_traffic_bytes(
    length: int, hot_cap: int, token_bytes: int
) -> dict:
    """External vs on-die bytes moved by one decode step at cache length L."""
    hot_tokens = min(length, hot_cap)
    cold_tokens = max(length - hot_cap, 0)
    write_ext = 0 if length < hot_cap else token_bytes
    return {
        "ondie_read": hot_tokens * token_bytes,
        "ext_read": cold_tokens * token_bytes,
        "ondie_write": token_bytes - write_ext,
        "ext_write": write_ext,
    }
