"""Two-tier (DR eDRAM-style) KV cache with per-slot lengths (paper §IV).

BitROM buffers the first ``hot_cap`` tokens of a sequence on-die (DR eDRAM)
and leaves the tail in external DRAM. The TPU adaptation keeps the same
*structure* — a small pinned "hot" buffer for early tokens plus a large
"cold" buffer — because the structure is what produces the access-traffic
win (early tokens are read at every decode step; see ``dr_edram.py``).

The cache is a pytree of fixed-shape arrays (jit/scan friendly):

  hot_k/hot_v   : (batch, hot_cap, ...)      early tokens
  cold_k/cold_v : (batch, cold_cap, ...)     the rest
  lengths       : (batch,) int32             tokens written, per slot

``...`` is whatever a layer caches per token: (n_kv_heads, head_dim) for
GQA/MQA, (d_latent,) for MLA latents. Appends route on position; attention
runs per-tier and combines with a numerically-stable streaming softmax, so
no concat of the two tiers is ever materialized.

Continuous batching (serving/scheduler.py) treats each batch row as a
*slot*: sequences of different lengths decode side by side, so every
operation is vectorized over ``lengths``, and the decode-path appends
(``append_decode`` / ``append_decode_ring``) take an optional
``active: (batch,) bool`` mask — inactive slots (retired / not yet
admitted) neither write their tier buffers nor advance their length.
Bulk ``append`` has no mask: prefill always targets a fresh cache whose
rows are scattered into live slots afterwards (see Engine._admit).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp


class TieredKVCache(NamedTuple):
    hot_k: jax.Array
    hot_v: jax.Array
    cold_k: jax.Array
    cold_v: jax.Array
    lengths: jax.Array  # (batch,) int32: tokens currently cached per slot

    @property
    def hot_cap(self) -> int:
        return self.hot_k.shape[1]

    @property
    def cold_cap(self) -> int:
        return self.cold_k.shape[1]

    @property
    def capacity(self) -> int:
        return self.hot_cap + self.cold_cap


def init_cache(
    batch: int,
    hot_cap: int,
    cold_cap: int,
    kv_shape: Sequence[int],
    dtype=jnp.bfloat16,
) -> TieredKVCache:
    shape_hot = (batch, hot_cap) + tuple(kv_shape)
    shape_cold = (batch, cold_cap) + tuple(kv_shape)
    return TieredKVCache(
        hot_k=jnp.zeros(shape_hot, dtype),
        hot_v=jnp.zeros(shape_hot, dtype),
        cold_k=jnp.zeros(shape_cold, dtype),
        cold_v=jnp.zeros(shape_cold, dtype),
        lengths=jnp.zeros((batch,), jnp.int32),
    )


def _active_mask(cache: TieredKVCache, active: Optional[jax.Array]) -> jax.Array:
    if active is None:
        return jnp.ones(cache.lengths.shape, bool)
    return active.astype(bool)


def append(cache: TieredKVCache, k_new: jax.Array, v_new: jax.Array) -> TieredKVCache:
    """Append ``t_new`` tokens (batch, t_new, ...). Early positions land hot.

    Each slot appends starting at its own ``lengths[b]``, so the same call
    serves aligned prefill (all lengths equal) and per-slot refill. Routing
    is data-independent given the traced lengths: every new token goes to
    the hot tier if its absolute position < hot_cap, else cold.
    """
    t_new = k_new.shape[1]
    start = cache.lengths  # (b,)
    pos = start[:, None] + jnp.arange(t_new, dtype=jnp.int32)[None]  # (b, t)

    def scatter(tier_k, tier_v, tier_pos, in_tier):
        # tier_pos: (b, t) position within the tier (clipped); in_tier: bool
        cap = tier_k.shape[1]
        if cap == 0:
            return tier_k, tier_v
        idx = jnp.clip(tier_pos, 0, cap - 1)
        onehot = (
            jax.nn.one_hot(idx, cap, dtype=tier_k.dtype)
            * in_tier.astype(tier_k.dtype)[..., None]
        )  # (b, t, cap)
        upd_k = jnp.einsum("btc,bt...->bc...", onehot, k_new.astype(tier_k.dtype))
        upd_v = jnp.einsum("btc,bt...->bc...", onehot, v_new.astype(tier_v.dtype))
        written = jnp.einsum("btc->bc", onehot) > 0
        mask = written.reshape(written.shape + (1,) * (tier_k.ndim - 2))
        return jnp.where(mask, upd_k, tier_k), jnp.where(mask, upd_v, tier_v)

    in_hot = pos < cache.hot_cap
    hot_k, hot_v = scatter(cache.hot_k, cache.hot_v, pos, in_hot)
    cold_k, cold_v = scatter(cache.cold_k, cache.cold_v, pos - cache.hot_cap, ~in_hot)
    return TieredKVCache(hot_k, hot_v, cold_k, cold_v, start + t_new)


def _append_one(
    cache: TieredKVCache,
    k_new: jax.Array,
    v_new: jax.Array,
    active: Optional[jax.Array],
    ring: bool,
) -> TieredKVCache:
    pos = cache.lengths  # (b,)
    act = _active_mask(cache, active)
    in_hot = pos < cache.hot_cap

    def upd(tier, new, tier_pos, write):
        cap = tier.shape[1]
        if cap == 0:  # zero-size tier (e.g. SWA: hot_cap=0) — nothing to write
            return tier
        idx = tier_pos % cap if ring else jnp.clip(tier_pos, 0, cap - 1)
        onehot = idx[:, None] == jnp.arange(cap, dtype=jnp.int32)[None]  # (b, cap)
        mask = onehot & write[:, None] & act[:, None]
        mask = mask.reshape(mask.shape + (1,) * (tier.ndim - 2))
        return jnp.where(mask, new.astype(tier.dtype)[:, None], tier)

    hot_k = upd(cache.hot_k, k_new, pos, in_hot)
    hot_v = upd(cache.hot_v, v_new, pos, in_hot)
    cold_k = upd(cache.cold_k, k_new, pos - cache.hot_cap, ~in_hot)
    cold_v = upd(cache.cold_v, v_new, pos - cache.hot_cap, ~in_hot)
    return TieredKVCache(hot_k, hot_v, cold_k, cold_v, pos + act.astype(jnp.int32))


def append_decode(
    cache: TieredKVCache,
    k_new: jax.Array,
    v_new: jax.Array,
    active: Optional[jax.Array] = None,
) -> TieredKVCache:
    """Fast path for decode: append exactly one token (batch, ...) per slot.

    ``active`` (batch,) bool gates the write per slot: inactive slots keep
    their buffers and length untouched (continuous-batching retirement).
    """
    return _append_one(cache, k_new, v_new, active, ring=False)


def append_decode_ring(
    cache: TieredKVCache,
    k_new: jax.Array,
    v_new: jax.Array,
    active: Optional[jax.Array] = None,
) -> TieredKVCache:
    """Decode append with a *ring-buffer* cold tier (sliding-window archs).

    Position p ≥ hot_cap lands at cold slot (p - hot_cap) % cold_cap, so the
    cold tier holds exactly the last ``cold_cap`` tokens (SWA window) and
    early tokens are evicted — DR tiering uses hot_cap=0 here (DESIGN.md §4).
    """
    return _append_one(cache, k_new, v_new, active, ring=True)


# ---------------------------------------------------------------------------
# Tiered attention read: per-tier partial attention + streaming-softmax merge
# (never concatenates the tiers — the "hot" tier stays a separate buffer).
# ---------------------------------------------------------------------------


def _upcast(x):
    """fp8 tiers compute in bf16 (avoids materializing a 4x f32 copy of the
    whole cache — observed as multi-GiB temp on the decode dry-run);
    everything else upcasts to f32 for exactness."""
    if x.dtype == jnp.float8_e4m3fn:
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _valid_masks(cache: TieredKVCache):
    """Per-slot validity of each tier position: (b, hot_cap), (b, cold_cap).

    The cold formula clamps at cold_cap, which is correct for both the
    linear layout (lengths never exceed capacity) and the ring layout
    (every slot is valid once the window has wrapped).
    """
    lengths = cache.lengths  # (b,)
    hot_valid = jnp.arange(cache.hot_cap)[None] < lengths[:, None]
    n_cold = jnp.clip(lengths - cache.hot_cap, 0, cache.cold_cap)
    cold_valid = jnp.arange(cache.cold_cap)[None] < n_cold[:, None]
    return hot_valid, cold_valid


def _tier_partial(q, k, v, valid, scale):
    """Partial attention over one tier.

    q: (b, h, d); k/v: (b, s, g, d) with g = kv heads (h = g * rep);
    valid: (b, s) bool. Returns (numerator (b,h,d), denom (b,h), max (b,h)).
    """
    b, s, g, d = k.shape
    h = q.shape[1]
    if s == 0:  # zero-capacity tier: neutral element of the streaming merge
        dv = v.shape[-1]
        return (
            jnp.zeros((b, h, dv), jnp.float32),
            jnp.zeros((b, h), jnp.float32),
            jnp.full((b, h), jnp.finfo(jnp.float32).min),
        )
    rep = h // g
    qg = q.reshape(b, g, rep, d).astype(jnp.float32)
    kf = _upcast(k)
    vf = _upcast(v)
    logits = jnp.einsum(
        "bgrd,bsgd->bgrs", qg.astype(kf.dtype), kf, preferred_element_type=jnp.float32
    ) * scale
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(valid[:, None, None, :], logits, neg)
    m = jnp.max(logits, axis=-1)  # (b,g,r)
    # guard fully-invalid tiers: exp(neg - neg) would be 1; zero them via mask
    p = jnp.exp(logits - m[..., None]) * valid[:, None, None, :]
    denom = jnp.sum(p, axis=-1)  # (b,g,r)
    num = jnp.einsum("bgrs,bsgd->bgrd", p.astype(vf.dtype), vf,
                     preferred_element_type=jnp.float32)  # (b,g,r,d)
    return num.reshape(b, h, d), denom.reshape(b, h), m.reshape(b, h)


def tiered_decode_attention(
    q: jax.Array,
    cache: TieredKVCache,
    scale: float | None = None,
) -> jax.Array:
    """One-token attention over both tiers. q: (b, h, d) -> (b, h, d).

    Validity is per slot (``cache.lengths``), so mixed-length batches each
    attend to exactly their own prefix. A slot with length 0 (unadmitted)
    returns zeros. Ring-buffer cold tiers (SWA) need no flag: the clamped
    validity formula in ``_valid_masks`` covers the wrapped layout, and
    attention is permutation-invariant over KV positions — call sites
    that want to state their layout use the flash-decode entry points
    (``kernels/flash_decode.py``), for which this function is the XLA
    reference path.
    """
    d = q.shape[-1]
    scale = scale if scale is not None else d**-0.5
    hot_valid, cold_valid = _valid_masks(cache)

    n1, d1, m1 = _tier_partial(q, cache.hot_k, cache.hot_v, hot_valid, scale)
    n2, d2, m2 = _tier_partial(q, cache.cold_k, cache.cold_v, cold_valid, scale)

    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * (d1 > 0)
    a2 = jnp.exp(m2 - m) * (d2 > 0)
    num = n1 * a1[..., None] + n2 * a2[..., None]
    den = d1 * a1 + d2 * a2
    return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)


def tiered_decode_attention_latent(
    q: jax.Array,  # (b, h, D) — D = latent + rope dims
    cache: TieredKVCache,
    value_dim: int,
    scale: float,
) -> jax.Array:
    """MLA absorbed-form attention over a tiered *latent* cache.

    The cache k-slot holds (c_kv ‖ k_rope) per token, shape (b, s, D); the
    v-slot is empty (0-dim) — values are the first ``value_dim`` dims of the
    k-slot (the latent), so the latent is stored exactly once. Returns the
    per-head latent context (b, h, value_dim). Validity is per slot.
    """
    b = q.shape[0]
    hot_valid, cold_valid = _valid_masks(cache)

    def partial(kbuf, valid):
        if kbuf.shape[1] == 0:  # zero-capacity tier: neutral merge element
            h = q.shape[1]
            return (
                jnp.zeros((b, h, value_dim), jnp.float32),
                jnp.zeros((b, h), jnp.float32),
                jnp.full((b, h), jnp.finfo(jnp.float32).min),
            )
        kf = kbuf.astype(jnp.float32)  # (b, s, D)
        logits = jnp.einsum("bhd,bsd->bhs", q.astype(jnp.float32), kf) * scale
        neg = jnp.finfo(jnp.float32).min
        logits = jnp.where(valid[:, None, :], logits, neg)
        m = jnp.max(logits, axis=-1)  # (b, h)
        p = jnp.exp(logits - m[..., None]) * valid[:, None, :]
        denom = jnp.sum(p, axis=-1)
        num = jnp.einsum("bhs,bsv->bhv", p, kf[..., :value_dim])
        return num, denom, m

    n1, d1, m1 = partial(cache.hot_k, hot_valid)
    n2, d2, m2 = partial(cache.cold_k, cold_valid)
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * (d1 > 0)
    a2 = jnp.exp(m2 - m) * (d2 > 0)
    num = n1 * a1[..., None] + n2 * a2[..., None]
    den = d1 * a1 + d2 * a2
    return num / jnp.maximum(den, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# Traffic accounting hooks (ties the functional cache to hwmodel/dr_edram)
# ---------------------------------------------------------------------------


def step_traffic_bytes(
    length: int, hot_cap: int, token_bytes: int
) -> dict:
    """External vs on-die bytes moved by one decode step at cache length L.

    Host-side scalar form (single sequence). The vectorized per-slot form
    used by the jitted serving loop is ``step_traffic_tokens``.
    """
    hot_tokens = min(length, hot_cap)
    cold_tokens = max(length - hot_cap, 0)
    write_ext = 0 if length < hot_cap else token_bytes
    return {
        "ondie_read": hot_tokens * token_bytes,
        "ext_read": cold_tokens * token_bytes,
        "ondie_write": token_bytes - write_ext,
        "ext_write": write_ext,
    }


TRAFFIC_KEYS = ("ondie_read", "ext_read", "ondie_write", "ext_write")


def external_reduction(traffic: dict) -> float:
    """Fraction of accesses kept on-die, from a 4-key traffic ledger.

    Shared by every result type that carries a ledger (engine
    GenerationResult, scheduler FinishedRequest) so the accounting rule
    lives in exactly one place."""
    ext = traffic["ext_read"] + traffic["ext_write"]
    total = ext + traffic["ondie_read"] + traffic["ondie_write"]
    return 1.0 - ext / total if total else 0.0


def step_traffic_tokens(lengths: jax.Array, hot_cap: int) -> dict:
    """Vectorized per-slot ledger for one decode step, in *token* units.

    ``lengths`` (b,) is each slot's cache length *before* the step's append.
    Returns a dict of (b,) int32 token counts; multiply by the per-token KV
    byte size to get bytes (kept as counts on device so int32 never meets
    byte-scaled magnitudes inside the jitted loop). Summing this over steps
    i = 0..S-1 for one slot reproduces ``dr_edram.simulate`` exactly, so the
    accumulated ledger reconciles with ``dr_edram.closed_form_reduction``
    per sequence even in mixed-length batches.
    """
    lengths = lengths.astype(jnp.int32)
    hot = jnp.minimum(lengths, hot_cap)
    cold = jnp.maximum(lengths - hot_cap, 0)
    ext_w = (lengths >= hot_cap).astype(jnp.int32)
    return {
        "ondie_read": hot,
        "ext_read": cold,
        "ondie_write": 1 - ext_w,
        "ext_write": ext_w,
    }


def prompt_traffic_tokens(prompt_len: int, hot_cap: int) -> dict:
    """Closed-form prompt-phase ledger (token units) for one sequence.

    Paper's accounting (§IV Fig. 5a): the edge pipeline processes prompt
    tokens sequentially, so token i writes once and reads tokens 0..i-1 —
    the same ledger as a decode step at length i. This host-side closed
    form equals sum(step_traffic_tokens(i) for i in range(prompt_len)).
    """
    p, b = prompt_len, hot_cap
    if p <= b:
        ondie_read = p * (p - 1) // 2
        ext_read = 0
    else:
        ondie_read = b * (b - 1) // 2 + (p - b) * b
        ext_read = (p - b - 1) * (p - b) // 2
    return {
        "ondie_read": ondie_read,
        "ext_read": ext_read,
        "ondie_write": min(p, b),
        "ext_write": max(p - b, 0),
    }
