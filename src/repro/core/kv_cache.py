"""Two-tier (DR eDRAM-style) KV cache with per-slot lengths (paper §IV).

BitROM buffers the first ``hot_cap`` tokens of a sequence on-die (DR eDRAM)
and leaves the tail in external DRAM. The TPU adaptation keeps the same
*structure* — a small pinned "hot" buffer for early tokens plus a large
"cold" buffer — because the structure is what produces the access-traffic
win (early tokens are read at every decode step; see ``dr_edram.py``).

The cache is a pytree of fixed-shape arrays (jit/scan friendly):

  hot_k/hot_v   : (batch, hot_cap, ...)      early tokens
  cold_k/cold_v : (batch, cold_cap, ...)     the rest
  lengths       : (batch,) int32             tokens written, per slot

``...`` is whatever a layer caches per token: (n_kv_heads, head_dim) for
GQA/MQA, (d_latent,) for MLA latents. Appends route on position; attention
runs per-tier and combines with a numerically-stable streaming softmax, so
no concat of the two tiers is ever materialized.

Continuous batching (serving/scheduler.py) treats each batch row as a
*slot*: sequences of different lengths decode side by side, so every
operation is vectorized over ``lengths``, and the decode-path appends
(``append_decode`` / ``append_decode_ring``) take an optional
``active: (batch,) bool`` mask — inactive slots (retired / not yet
admitted) neither write their tier buffers nor advance their length.
Bulk ``append`` takes ``valid`` (per-slot count of real rows — chunked
prefill masks its final partial chunk with it) and ``ring`` (sliding
-window cold layout); both default to the legacy whole-chunk append.

``PagedKVCache`` is the paged variant of the cold tier: instead of one
contiguous (batch, cold_cap, ...) row per slot, cold tokens live in a
shared physical *page pool* (n_pages, page_size, ...) and each slot owns
an int32 ``page_table`` row mapping its logical cold pages to pool pages.
Slot j's cold position c lives at pool page ``page_table[j, c // ps]``,
row ``c % ps``. The hot tier stays contiguous/pinned per slot (the
DR-eDRAM buffer of the paper). Pages let the serving layer share one
physical copy of a common prompt prefix across slots (refcounted radix
tree, serving/paging.py) — ``as_tiered`` gathers the paged cold tier
back into the contiguous layout, which is how every XLA reference path
here supports paging with bit-exact parity to the contiguous cache.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class TieredKVCache(NamedTuple):
    hot_k: jax.Array
    hot_v: jax.Array
    cold_k: jax.Array
    cold_v: jax.Array
    lengths: jax.Array  # (batch,) int32: tokens currently cached per slot

    @property
    def hot_cap(self) -> int:
        return self.hot_k.shape[1]

    @property
    def cold_cap(self) -> int:
        return self.cold_k.shape[1]

    @property
    def capacity(self) -> int:
        return self.hot_cap + self.cold_cap


def init_cache(
    batch: int,
    hot_cap: int,
    cold_cap: int,
    kv_shape: Sequence[int],
    dtype=jnp.bfloat16,
) -> TieredKVCache:
    shape_hot = (batch, hot_cap) + tuple(kv_shape)
    shape_cold = (batch, cold_cap) + tuple(kv_shape)
    return TieredKVCache(
        hot_k=jnp.zeros(shape_hot, dtype),
        hot_v=jnp.zeros(shape_hot, dtype),
        cold_k=jnp.zeros(shape_cold, dtype),
        cold_v=jnp.zeros(shape_cold, dtype),
        lengths=jnp.zeros((batch,), jnp.int32),
    )


class PagedKVCache(NamedTuple):
    """Tiered cache with a paged cold tier (see module docstring).

    ``hot_k/hot_v`` are identical to ``TieredKVCache`` (contiguous,
    per-slot). The cold tier is a shared pool: ``pool_k/pool_v`` hold
    ``n_pages`` pages of ``page_size`` tokens each, and ``page_table``
    (batch, pages_per_slot) int32 maps each slot's logical cold pages to
    pool pages. Unused table entries must hold a *valid* pool index
    (convention: 0) — reads are masked by ``lengths``, never by the
    table, so sentinel values out of range would break the gather.
    Ring/SWA layouts are not supported in paged form.
    """

    hot_k: jax.Array
    hot_v: jax.Array
    pool_k: jax.Array  # (n_pages, page_size, ...)
    pool_v: jax.Array
    page_table: jax.Array  # (batch, pages_per_slot) int32
    lengths: jax.Array  # (batch,) int32

    @property
    def hot_cap(self) -> int:
        return self.hot_k.shape[1]

    @property
    def page_size(self) -> int:
        return self.pool_k.shape[1]

    @property
    def n_pages(self) -> int:
        return self.pool_k.shape[0]

    @property
    def pages_per_slot(self) -> int:
        return self.page_table.shape[1]

    @property
    def cold_cap(self) -> int:
        return self.pages_per_slot * self.page_size

    @property
    def capacity(self) -> int:
        return self.hot_cap + self.cold_cap


def init_paged_cache(
    batch: int,
    hot_cap: int,
    cold_cap: int,
    kv_shape: Sequence[int],
    dtype=jnp.bfloat16,
    page_size: int = 256,
    n_pages: Optional[int] = None,
) -> PagedKVCache:
    """Fresh paged cache. ``cold_cap`` rounds up to whole pages; the pool
    defaults to exactly one private page set per slot and the page table
    to the identity mapping (slot b's page j = pool page b * pps + j), so
    an unshared paged cache is the contiguous cache re-addressed."""
    assert cold_cap > 0, "paged cache needs a non-empty cold tier"
    pps = -(-cold_cap // page_size)
    if n_pages is None:
        n_pages = batch * pps
    assert n_pages >= 1
    table = (jnp.arange(batch, dtype=jnp.int32)[:, None] * pps
             + jnp.arange(pps, dtype=jnp.int32)[None])
    table = jnp.minimum(table, n_pages - 1)
    shape_hot = (batch, hot_cap) + tuple(kv_shape)
    shape_pool = (n_pages, page_size) + tuple(kv_shape)
    return PagedKVCache(
        hot_k=jnp.zeros(shape_hot, dtype),
        hot_v=jnp.zeros(shape_hot, dtype),
        pool_k=jnp.zeros(shape_pool, dtype),
        pool_v=jnp.zeros(shape_pool, dtype),
        page_table=table,
        lengths=jnp.zeros((batch,), jnp.int32),
    )


def cold_view(cache: PagedKVCache) -> tuple:
    """Gather the paged cold tier into contiguous (batch, cold_cap, ...)
    k/v arrays — the indirection the flash kernels do per S-block, done
    at once for the XLA reference paths."""
    b = cache.page_table.shape[0]
    ck = cache.pool_k[cache.page_table]  # (b, pps, ps, ...)
    cv = cache.pool_v[cache.page_table]
    tail = cache.pool_k.shape[2:]
    return (ck.reshape((b, cache.cold_cap) + tail),
            cv.reshape((b, cache.cold_cap) + tail))


def as_tiered(cache: PagedKVCache) -> TieredKVCache:
    """Contiguous view of a paged cache (gathers the cold tier)."""
    ck, cv = cold_view(cache)
    return TieredKVCache(cache.hot_k, cache.hot_v, ck, cv, cache.lengths)


def _active_mask(cache: TieredKVCache, active: Optional[jax.Array]) -> jax.Array:
    if active is None:
        return jnp.ones(cache.lengths.shape, bool)
    return active.astype(bool)


def append(
    cache: TieredKVCache,
    k_new: jax.Array,
    v_new: jax.Array,
    valid: Optional[jax.Array] = None,
    ring: bool = False,
) -> TieredKVCache:
    """Append up to ``t_new`` tokens (batch, t_new, ...). Early positions
    land hot.

    Each slot appends starting at its own ``lengths[b]``, so the same call
    serves aligned prefill (all lengths equal), per-slot refill and the
    chunked-prefill placement (serving/engine.py): ``valid`` (b,) int32
    caps how many of the ``t_new`` rows are real per slot — rows past a
    slot's valid count (chunk padding) are neither written nor counted,
    and lengths advance by ``valid``. Routing is data-independent given
    the traced lengths: every new token goes to the hot tier if its
    absolute position < hot_cap, else cold — or, with ``ring=True``
    (sliding-window archs), to cold slot (pos - hot_cap) % cold_cap. In
    ring mode only each slot's last ``cold_cap`` valid tokens write (the
    earlier ones would be evicted within this very call; keeping a single
    writer per ring slot keeps the one-hot scatter exact).
    """
    if isinstance(cache, PagedKVCache):
        assert not ring, "ring layout is not supported for paged caches"
        return _paged_append(cache, k_new, v_new, valid)
    t_new = k_new.shape[1]
    start = cache.lengths  # (b,)
    t_idx = jnp.arange(t_new, dtype=jnp.int32)[None]  # (1, t)
    pos = start[:, None] + t_idx  # (b, t)
    if valid is None:
        vmask = jnp.ones(pos.shape, bool)
        n_new = jnp.full_like(start, t_new)
    else:
        n_new = valid.astype(jnp.int32)
        vmask = t_idx < n_new[:, None]

    def scatter(tier_k, tier_v, tier_pos, in_tier):
        # tier_pos: (b, t) position within the tier (clipped); in_tier: bool
        cap = tier_k.shape[1]
        if cap == 0:
            return tier_k, tier_v
        idx = jnp.clip(tier_pos, 0, cap - 1)
        onehot = (
            jax.nn.one_hot(idx, cap, dtype=tier_k.dtype)
            * in_tier.astype(tier_k.dtype)[..., None]
        )  # (b, t, cap)
        upd_k = jnp.einsum("btc,bt...->bc...", onehot, k_new.astype(tier_k.dtype))
        upd_v = jnp.einsum("btc,bt...->bc...", onehot, v_new.astype(tier_v.dtype))
        written = jnp.einsum("btc->bc", onehot) > 0
        mask = written.reshape(written.shape + (1,) * (tier_k.ndim - 2))
        return jnp.where(mask, upd_k, tier_k), jnp.where(mask, upd_v, tier_v)

    in_hot = (pos < cache.hot_cap) & vmask
    hot_k, hot_v = scatter(cache.hot_k, cache.hot_v, pos, in_hot)
    in_cold = (pos >= cache.hot_cap) & vmask
    cold_pos = pos - cache.hot_cap
    if ring and cache.cold_cap:
        cold_pos = cold_pos % cache.cold_cap
        # single writer per ring slot: only the last cold_cap valid rows
        in_cold &= (n_new[:, None] - 1 - t_idx) < cache.cold_cap
    cold_k, cold_v = scatter(cache.cold_k, cache.cold_v, cold_pos, in_cold)
    return TieredKVCache(hot_k, hot_v, cold_k, cold_v, start + n_new)


def _paged_cold_rows(cache: PagedKVCache, cold_pos, write):
    """Linear row index into the flattened pool for each cold position;
    entries not selected by ``write`` get an out-of-range index so a
    ``mode="drop"`` scatter skips them. ``cold_pos``/``write``: (b, ...)
    with matching shapes; routing is per slot along axis 0."""
    ps = cache.page_size
    pg = jnp.clip(cold_pos // ps, 0, cache.pages_per_slot - 1)
    page = jnp.take_along_axis(
        cache.page_table, pg.reshape(pg.shape[0], -1), axis=1
    ).reshape(pg.shape)
    lin = page * ps + cold_pos % ps
    return jnp.where(write, lin, cache.n_pages * ps)


def _paged_append(
    cache: PagedKVCache,
    k_new: jax.Array,
    v_new: jax.Array,
    valid: Optional[jax.Array],
) -> PagedKVCache:
    """Bulk append for the paged cache: hot rows use the same one-hot
    scatter as the contiguous path; cold rows scatter into the flattened
    pool at page-table-routed linear indices. The serving layer guarantees
    each writable page has exactly one writer slot (shared pages are only
    ever *read*; see serving/paging.py), so indices never collide."""
    t_new = k_new.shape[1]
    start = cache.lengths  # (b,)
    t_idx = jnp.arange(t_new, dtype=jnp.int32)[None]  # (1, t)
    pos = start[:, None] + t_idx  # (b, t)
    if valid is None:
        vmask = jnp.ones(pos.shape, bool)
        n_new = jnp.full_like(start, t_new)
    else:
        n_new = valid.astype(jnp.int32)
        vmask = t_idx < n_new[:, None]

    def scatter_hot(tier, new):
        cap = tier.shape[1]
        if cap == 0:
            return tier
        in_hot = (pos < cap) & vmask
        idx = jnp.clip(pos, 0, cap - 1)
        onehot = (jax.nn.one_hot(idx, cap, dtype=tier.dtype)
                  * in_hot.astype(tier.dtype)[..., None])
        upd = jnp.einsum("btc,bt...->bc...", onehot, new.astype(tier.dtype))
        written = jnp.einsum("btc->bc", onehot) > 0
        mask = written.reshape(written.shape + (1,) * (tier.ndim - 2))
        return jnp.where(mask, upd, tier)

    hot_k = scatter_hot(cache.hot_k, k_new)
    hot_v = scatter_hot(cache.hot_v, v_new)

    in_cold = (pos >= cache.hot_cap) & vmask
    lin = _paged_cold_rows(cache, pos - cache.hot_cap, in_cold).reshape(-1)
    tail = cache.pool_k.shape[2:]
    n_rows = cache.n_pages * cache.page_size
    pk = cache.pool_k.reshape((n_rows,) + tail)
    pv = cache.pool_v.reshape((n_rows,) + tail)
    pk = pk.at[lin].set(k_new.astype(pk.dtype).reshape((-1,) + tail),
                        mode="drop")
    pv = pv.at[lin].set(v_new.astype(pv.dtype).reshape((-1,) + tail),
                        mode="drop")
    return cache._replace(
        hot_k=hot_k, hot_v=hot_v,
        pool_k=pk.reshape(cache.pool_k.shape),
        pool_v=pv.reshape(cache.pool_v.shape),
        lengths=start + n_new,
    )


def _paged_append_one(
    cache: PagedKVCache,
    k_new: jax.Array,
    v_new: jax.Array,
    active: Optional[jax.Array],
) -> PagedKVCache:
    """Decode append (one token per slot) for the paged cache."""
    pos = cache.lengths  # (b,)
    act = _active_mask(cache, active)
    in_hot = pos < cache.hot_cap

    def upd_hot(tier, new):
        cap = tier.shape[1]
        if cap == 0:
            return tier
        idx = jnp.clip(pos, 0, cap - 1)
        onehot = idx[:, None] == jnp.arange(cap, dtype=jnp.int32)[None]
        mask = onehot & in_hot[:, None] & act[:, None]
        mask = mask.reshape(mask.shape + (1,) * (tier.ndim - 2))
        return jnp.where(mask, new.astype(tier.dtype)[:, None], tier)

    lin = _paged_cold_rows(cache, pos - cache.hot_cap, ~in_hot & act)
    tail = cache.pool_k.shape[2:]
    n_rows = cache.n_pages * cache.page_size
    pk = cache.pool_k.reshape((n_rows,) + tail)
    pv = cache.pool_v.reshape((n_rows,) + tail)
    pk = pk.at[lin].set(k_new.astype(pk.dtype), mode="drop")
    pv = pv.at[lin].set(v_new.astype(pv.dtype), mode="drop")
    return cache._replace(
        hot_k=upd_hot(cache.hot_k, k_new),
        hot_v=upd_hot(cache.hot_v, v_new),
        pool_k=pk.reshape(cache.pool_k.shape),
        pool_v=pv.reshape(cache.pool_v.shape),
        lengths=pos + act.astype(jnp.int32),
    )


def _append_one(
    cache: TieredKVCache,
    k_new: jax.Array,
    v_new: jax.Array,
    active: Optional[jax.Array],
    ring: bool,
) -> TieredKVCache:
    if isinstance(cache, PagedKVCache):
        assert not ring, "ring layout is not supported for paged caches"
        return _paged_append_one(cache, k_new, v_new, active)
    pos = cache.lengths  # (b,)
    act = _active_mask(cache, active)
    in_hot = pos < cache.hot_cap

    def upd(tier, new, tier_pos, write):
        cap = tier.shape[1]
        if cap == 0:  # zero-size tier (e.g. SWA: hot_cap=0) — nothing to write
            return tier
        idx = tier_pos % cap if ring else jnp.clip(tier_pos, 0, cap - 1)
        onehot = idx[:, None] == jnp.arange(cap, dtype=jnp.int32)[None]  # (b, cap)
        mask = onehot & write[:, None] & act[:, None]
        mask = mask.reshape(mask.shape + (1,) * (tier.ndim - 2))
        return jnp.where(mask, new.astype(tier.dtype)[:, None], tier)

    hot_k = upd(cache.hot_k, k_new, pos, in_hot)
    hot_v = upd(cache.hot_v, v_new, pos, in_hot)
    cold_k = upd(cache.cold_k, k_new, pos - cache.hot_cap, ~in_hot)
    cold_v = upd(cache.cold_v, v_new, pos - cache.hot_cap, ~in_hot)
    return TieredKVCache(hot_k, hot_v, cold_k, cold_v, pos + act.astype(jnp.int32))


def append_decode(
    cache: TieredKVCache,
    k_new: jax.Array,
    v_new: jax.Array,
    active: Optional[jax.Array] = None,
) -> TieredKVCache:
    """Fast path for decode: append exactly one token (batch, ...) per slot.

    ``active`` (batch,) bool gates the write per slot: inactive slots keep
    their buffers and length untouched (continuous-batching retirement).
    """
    return _append_one(cache, k_new, v_new, active, ring=False)


def append_decode_ring(
    cache: TieredKVCache,
    k_new: jax.Array,
    v_new: jax.Array,
    active: Optional[jax.Array] = None,
) -> TieredKVCache:
    """Decode append with a *ring-buffer* cold tier (sliding-window archs).

    Position p ≥ hot_cap lands at cold slot (p - hot_cap) % cold_cap, so the
    cold tier holds exactly the last ``cold_cap`` tokens (SWA window) and
    early tokens are evicted — DR tiering uses hot_cap=0 here (DESIGN.md §4).
    """
    return _append_one(cache, k_new, v_new, active, ring=True)


# ---------------------------------------------------------------------------
# Tiered attention read: per-tier partial attention + streaming-softmax merge
# (never concatenates the tiers — the "hot" tier stays a separate buffer).
# ---------------------------------------------------------------------------


def _upcast(x):
    """fp8 tiers compute in bf16 (avoids materializing a 4x f32 copy of the
    whole cache — observed as multi-GiB temp on the decode dry-run);
    everything else upcasts to f32 for exactness."""
    if x.dtype == jnp.float8_e4m3fn:
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _valid_masks(cache: TieredKVCache):
    """Per-slot validity of each tier position: (b, hot_cap), (b, cold_cap).

    The cold formula clamps at cold_cap, which is correct for both the
    linear layout (lengths never exceed capacity) and the ring layout
    (every slot is valid once the window has wrapped).
    """
    lengths = cache.lengths  # (b,)
    hot_valid = jnp.arange(cache.hot_cap)[None] < lengths[:, None]
    n_cold = jnp.clip(lengths - cache.hot_cap, 0, cache.cold_cap)
    cold_valid = jnp.arange(cache.cold_cap)[None] < n_cold[:, None]
    return hot_valid, cold_valid


def _tier_partial(q, k, v, valid, scale):
    """Partial attention over one tier.

    q: (b, h, d); k/v: (b, s, g, d) with g = kv heads (h = g * rep);
    valid: (b, s) bool. Returns (numerator (b,h,d), denom (b,h), max (b,h)).
    """
    b, s, g, d = k.shape
    h = q.shape[1]
    if s == 0:  # zero-capacity tier: neutral element of the streaming merge
        dv = v.shape[-1]
        return (
            jnp.zeros((b, h, dv), jnp.float32),
            jnp.zeros((b, h), jnp.float32),
            jnp.full((b, h), jnp.finfo(jnp.float32).min),
        )
    rep = h // g
    qg = q.reshape(b, g, rep, d).astype(jnp.float32)
    kf = _upcast(k)
    # invalid rows get p = 0, but 0 * NaN = NaN: a non-finite value in a
    # masked row (stale bytes, an aliased padding page) must contribute
    # nothing, so zero it like the flash kernel's v_safe does
    vf = jnp.where(valid[:, :, None, None], _upcast(v), 0.0)
    logits = jnp.einsum(
        "bgrd,bsgd->bgrs", qg.astype(kf.dtype), kf, preferred_element_type=jnp.float32
    ) * scale
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(valid[:, None, None, :], logits, neg)
    m = jnp.max(logits, axis=-1)  # (b,g,r)
    # guard fully-invalid tiers: exp(neg - neg) would be 1; zero them via mask
    p = jnp.exp(logits - m[..., None]) * valid[:, None, None, :]
    denom = jnp.sum(p, axis=-1)  # (b,g,r)
    num = jnp.einsum("bgrs,bsgd->bgrd", p.astype(vf.dtype), vf,
                     preferred_element_type=jnp.float32)  # (b,g,r,d)
    return num.reshape(b, h, d), denom.reshape(b, h), m.reshape(b, h)


def tiered_decode_attention(
    q: jax.Array,
    cache: TieredKVCache,
    scale: float | None = None,
) -> jax.Array:
    """One-token attention over both tiers. q: (b, h, d) -> (b, h, d).

    Validity is per slot (``cache.lengths``), so mixed-length batches each
    attend to exactly their own prefix. A slot with length 0 (unadmitted)
    returns zeros. Ring-buffer cold tiers (SWA) need no flag: the clamped
    validity formula in ``_valid_masks`` covers the wrapped layout, and
    attention is permutation-invariant over KV positions — call sites
    that want to state their layout use the flash-decode entry points
    (``kernels/flash_decode.py``), for which this function is the XLA
    reference path.
    """
    if isinstance(cache, PagedKVCache):
        cache = as_tiered(cache)
    d = q.shape[-1]
    scale = scale if scale is not None else d**-0.5
    hot_valid, cold_valid = _valid_masks(cache)

    n1, d1, m1 = _tier_partial(q, cache.hot_k, cache.hot_v, hot_valid, scale)
    n2, d2, m2 = _tier_partial(q, cache.cold_k, cache.cold_v, cold_valid, scale)

    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * (d1 > 0)
    a2 = jnp.exp(m2 - m) * (d2 > 0)
    num = n1 * a1[..., None] + n2 * a2[..., None]
    den = d1 * a1 + d2 * a2
    return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)


def tiered_decode_attention_latent(
    q: jax.Array,  # (b, h, D) — D = latent + rope dims
    cache: TieredKVCache,
    value_dim: int,
    scale: float,
) -> jax.Array:
    """MLA absorbed-form attention over a tiered *latent* cache.

    The cache k-slot holds (c_kv ‖ k_rope) per token, shape (b, s, D); the
    v-slot is empty (0-dim) — values are the first ``value_dim`` dims of the
    k-slot (the latent), so the latent is stored exactly once. Returns the
    per-head latent context (b, h, value_dim). Validity is per slot.
    """
    if isinstance(cache, PagedKVCache):
        cache = as_tiered(cache)
    b = q.shape[0]
    hot_valid, cold_valid = _valid_masks(cache)

    def partial(kbuf, valid):
        if kbuf.shape[1] == 0:  # zero-capacity tier: neutral merge element
            h = q.shape[1]
            return (
                jnp.zeros((b, h, value_dim), jnp.float32),
                jnp.zeros((b, h), jnp.float32),
                jnp.full((b, h), jnp.finfo(jnp.float32).min),
            )
        kf = kbuf.astype(jnp.float32)  # (b, s, D)
        logits = jnp.einsum("bhd,bsd->bhs", q.astype(jnp.float32), kf) * scale
        neg = jnp.finfo(jnp.float32).min
        logits = jnp.where(valid[:, None, :], logits, neg)
        m = jnp.max(logits, axis=-1)  # (b, h)
        p = jnp.exp(logits - m[..., None]) * valid[:, None, :]
        denom = jnp.sum(p, axis=-1)
        # p is 0 at invalid rows but 0 * NaN = NaN — zero the latent too
        lat = jnp.where(valid[:, :, None], kf[..., :value_dim], 0.0)
        num = jnp.einsum("bhs,bsv->bhv", p, lat)
        return num, denom, m

    n1, d1, m1 = partial(cache.hot_k, hot_valid)
    n2, d2, m2 = partial(cache.cold_k, cold_valid)
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m) * (d1 > 0)
    a2 = jnp.exp(m2 - m) * (d2 > 0)
    num = n1 * a1[..., None] + n2 * a2[..., None]
    den = d1 * a1 + d2 * a2
    return num / jnp.maximum(den, 1e-30)[..., None]


def fill_fresh(
    cache: TieredKVCache,
    k_new: jax.Array,  # (b, s, ...) — already rotated + tier-dtype-ready
    v_new: jax.Array,
    ring: bool = False,
) -> TieredKVCache:
    """Place an aligned full-prompt k/v (offset 0, every slot s tokens)
    into a *fresh* cache with static slices — no one-hot scatter.

    Content-identical to ``append`` on a zero cache (the flash-prefill
    kernel already emitted k/v in position order and tier dtype, so
    placement degenerates to two slice-assignments), and to the ring
    realign of the legacy SWA fill when ``s > cold_cap``.
    """
    if isinstance(cache, PagedKVCache):
        raise NotImplementedError(
            "fill_fresh targets the contiguous cache (grouped admission); "
            "paged serving always streams prompts via chunked append")
    b, s = k_new.shape[:2]
    if ring and s > cache.cold_cap:
        w = cache.cold_cap
        # slot of token p is p % w; realign so slots match positions
        idx = jnp.arange(s - w, s) % w
        order = jnp.argsort(idx)
        return cache._replace(
            cold_k=k_new[:, s - w:][:, order].astype(cache.cold_k.dtype),
            cold_v=v_new[:, s - w:][:, order].astype(cache.cold_v.dtype),
            lengths=jnp.full_like(cache.lengths, s),
        )
    n_h = min(s, cache.hot_cap)
    n_c = min(s - n_h, cache.cold_cap)
    hot_k, hot_v = cache.hot_k, cache.hot_v
    cold_k, cold_v = cache.cold_k, cache.cold_v
    if n_h:
        hot_k = hot_k.at[:, :n_h].set(k_new[:, :n_h].astype(hot_k.dtype))
        hot_v = hot_v.at[:, :n_h].set(v_new[:, :n_h].astype(hot_v.dtype))
    if n_c:
        cold_k = cold_k.at[:, :n_c].set(
            k_new[:, n_h : n_h + n_c].astype(cold_k.dtype))
        cold_v = cold_v.at[:, :n_c].set(
            v_new[:, n_h : n_h + n_c].astype(cold_v.dtype))
    return TieredKVCache(
        hot_k, hot_v, cold_k, cold_v, jnp.full_like(cache.lengths, s)
    )


def ring_slot_positions(offset: jax.Array, cold_cap: int) -> jax.Array:
    """Absolute position held by each ring-buffer cold slot: (b, cold_cap).

    With hot_cap = 0 (SWA layout) position p writes ring slot p % cold_cap,
    so slot j holds the largest p < offset with p ≡ j (mod cold_cap) — or
    nothing yet, reported as a negative value (mask on ``>= 0``). Decode
    reads never need this (a wrapped window is fully valid and softmax is
    permutation-invariant), but *prefill continuation* does: a chunk's
    later q rows slide the window past the oldest ring entries, and only
    the absolute position says which ones fell out.
    """
    j = jnp.arange(cold_cap, dtype=jnp.int32)[None]  # (1, cap)
    off = offset.astype(jnp.int32)[:, None]  # (b, 1)
    return off - 1 - ((off - 1 - j) % cold_cap)


def tiered_chunk_attention(
    q: jax.Array,  # (b, C, h, dk) — RoPE already applied
    k_new: jax.Array,  # (b, C, g, dk) — RoPE already applied
    v_new: jax.Array,  # (b, C, g, dv)
    cache: Optional[TieredKVCache],
    valid: Optional[jax.Array] = None,  # (b,) valid chunk rows (default C)
    scale: float | None = None,
    window: int = 0,
    ring: bool = False,
) -> jax.Array:
    """Causal chunk attention over [tiered cache prefix ‖ own chunk].

    The XLA reference for the flash-prefill kernel's *continuation* form
    (kernels/flash_prefill.py): each chunk row attends to the slot's
    cached prefix (per-slot ``cache.lengths`` tokens, both tiers) plus
    the causally-earlier rows of its own chunk. ``valid`` marks how many
    chunk rows are real per slot (chunk padding rows produce garbage
    output and attend nothing). ``window`` applies SWA masking by
    absolute position — with ``ring=True`` the cold tier is the wrapped
    ring layout and slot positions come from ``ring_slot_positions``.
    Partials over (hot, cold, chunk) merge with the same streaming
    softmax as the decode read; tiers are never concatenated.
    """
    if isinstance(cache, PagedKVCache):
        assert not ring, "ring layout is not supported for paged caches"
        cache = as_tiered(cache)
    b, C, h, dk = q.shape
    g = k_new.shape[2]
    rep = h // g
    dv = v_new.shape[-1]
    scale = scale if scale is not None else dk**-0.5
    offset = (
        cache.lengths.astype(jnp.int32)
        if cache is not None
        else jnp.zeros((b,), jnp.int32)
    )
    n_new = (
        valid.astype(jnp.int32) if valid is not None
        else jnp.full((b,), C, jnp.int32)
    )
    q_pos = offset[:, None] + jnp.arange(C, dtype=jnp.int32)[None]  # (b, C)
    qg = jnp.moveaxis(q.reshape(b, C, g, rep, dk), 1, 3)  # (b, g, rep, C, dk)
    neg = jnp.finfo(jnp.float32).min

    def partial(kbuf, vbuf, kpos, kvalid):
        # kbuf: (b, S, g, dk); vbuf: (b, S, g, dv); kpos/kvalid: (b, S)
        s = kbuf.shape[1]
        if s == 0:
            return (
                jnp.zeros((b, g, rep, C, dv), jnp.float32),
                jnp.zeros((b, g, rep, C), jnp.float32),
                jnp.full((b, g, rep, C), neg),
            )
        kf = _upcast(kbuf)
        # causally-masked rows hold real (finite) tokens, so per-row
        # kvalid zeroing suffices to keep NaN out of 0 * v products
        vf = jnp.where(kvalid[:, :, None, None], _upcast(vbuf), 0.0)
        logits = jnp.einsum(
            "bgrcd,bsgd->bgrcs", qg.astype(kf.dtype), kf,
            preferred_element_type=jnp.float32,
        ) * scale
        mask = kvalid[:, None, :] & (q_pos[:, :, None] >= kpos[:, None, :])
        if window:
            mask &= (q_pos[:, :, None] - kpos[:, None, :]) < window
        mask = mask[:, None, None]  # (b, 1, 1, C, S)
        logits = jnp.where(mask, logits, neg)
        m = jnp.max(logits, axis=-1)
        p = jnp.exp(logits - m[..., None]) * mask
        denom = jnp.sum(p, axis=-1)
        num = jnp.einsum(
            "bgrcs,bsgd->bgrcd", p.astype(vf.dtype), vf,
            preferred_element_type=jnp.float32,
        )
        return num.astype(jnp.float32), denom, m

    parts = []
    if cache is not None and cache.hot_cap:
        hpos = jnp.broadcast_to(
            jnp.arange(cache.hot_cap, dtype=jnp.int32)[None], (b, cache.hot_cap)
        )
        hvalid = hpos < jnp.minimum(offset, cache.hot_cap)[:, None]
        parts.append(partial(cache.hot_k, cache.hot_v, hpos, hvalid))
    if cache is not None and cache.cold_cap:
        if ring:
            cpos = ring_slot_positions(offset, cache.cold_cap)
            cvalid = cpos >= 0
        else:
            j = jnp.arange(cache.cold_cap, dtype=jnp.int32)[None]
            cpos = jnp.broadcast_to(cache.hot_cap + j, (b, cache.cold_cap))
            n_cold = jnp.clip(offset - cache.hot_cap, 0, cache.cold_cap)
            cvalid = j < n_cold[:, None]
        parts.append(partial(cache.cold_k, cache.cold_v, cpos, cvalid))
    npos = q_pos  # the chunk's own kv rows share the q positions
    nvalid = jnp.arange(C, dtype=jnp.int32)[None] < n_new[:, None]
    parts.append(partial(k_new, v_new, npos, nvalid))

    num, den, m = parts[0]
    for n2, d2, m2 in parts[1:]:
        m_new = jnp.maximum(m, m2)
        a1 = jnp.exp(m - m_new) * (den > 0)
        a2 = jnp.exp(m2 - m_new) * (d2 > 0)
        num = num * a1[..., None] + n2 * a2[..., None]
        den = den * a1 + d2 * a2
        m = m_new
    out = num / jnp.maximum(den, 1e-30)[..., None]  # (b, g, rep, C, dv)
    return jnp.moveaxis(out, 3, 1).reshape(b, C, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged-serving admission ops (device side of serving/paging.py):
# slot (re)initialisation with prefix restore + copy-on-write, and the
# hot-tier snapshot that makes the slot-private hot tier shareable.
# ---------------------------------------------------------------------------


def _pool_flat(cache: PagedKVCache):
    tail = cache.pool_k.shape[2:]
    n_rows = cache.n_pages * cache.page_size
    return (cache.pool_k.reshape((n_rows,) + tail),
            cache.pool_v.reshape((n_rows,) + tail), n_rows)


def paged_admit(
    cache: PagedKVCache,
    reset: jax.Array,  # (b,) bool — slots (re)admitted this wave
    new_lengths: jax.Array,  # (b,) int32 — matched prefix length M
    new_table: jax.Array,  # (b, pages_per_slot) int32
    hot_src: jax.Array,  # (b, n_hot_pages) int32 snapshot pages, -1 = none
    cow_src: jax.Array,  # (b,) int32 boundary-page copy source, -1 = none
    cow_dst: jax.Array,  # (b,) int32 boundary-page copy target, -1 = none
) -> PagedKVCache:
    """(Re)initialise the ``reset`` slots for a new request in one fused
    dispatch: install the slot's page-table row and prefix length, restore
    the hot tier from a pooled snapshot (rows < min(M, hot_cap)), and
    copy-on-write the partially-matched boundary page so the slot can
    append into a private copy. Non-reset slots are untouched. All shapes
    are fixed (full batch, masked), so serving compiles this exactly once.
    """
    reset = reset.astype(bool)
    new_lengths = new_lengths.astype(jnp.int32)
    table = jnp.where(reset[:, None], new_table.astype(jnp.int32),
                      cache.page_table)
    lengths = jnp.where(reset, new_lengths, cache.lengths)
    ps = cache.page_size
    pk, pv, n_rows = _pool_flat(cache)

    # copy-on-write: dst page <- src page (full page; rows past the match
    # boundary are overwritten by the slot's own appends, rows past the
    # slot's final length are masked at read)
    j = jnp.arange(ps, dtype=jnp.int32)[None]  # (1, ps)
    cow_ok = reset & (cow_src >= 0) & (cow_dst >= 0)
    src_rows = jnp.clip(cow_src[:, None], 0, None) * ps + j
    vals_k = pk[jnp.clip(src_rows, 0, n_rows - 1)]
    vals_v = pv[jnp.clip(src_rows, 0, n_rows - 1)]
    dst_rows = jnp.where(cow_ok[:, None],
                         jnp.clip(cow_dst[:, None], 0, None) * ps + j, n_rows)
    flat = dst_rows.reshape(-1)
    tail = pk.shape[1:]
    pk = pk.at[flat].set(vals_k.reshape((-1,) + tail), mode="drop")
    pv = pv.at[flat].set(vals_v.reshape((-1,) + tail), mode="drop")

    # hot restore: rows i < min(M, hot_cap) from the snapshot pages
    hot_k, hot_v = cache.hot_k, cache.hot_v
    if cache.hot_cap:
        i = jnp.arange(cache.hot_cap, dtype=jnp.int32)[None]  # (1, hc)
        pg = jnp.minimum(i // ps, hot_src.shape[1] - 1)
        src_pages = jnp.take_along_axis(
            hot_src.astype(jnp.int32),
            jnp.broadcast_to(pg, (hot_src.shape[0], pg.shape[1])), axis=1)
        rows = jnp.clip(src_pages, 0, None) * ps + i % ps
        ok = (reset[:, None] & (src_pages >= 0)
              & (i < jnp.minimum(new_lengths, cache.hot_cap)[:, None]))
        vk = pk[jnp.clip(rows, 0, n_rows - 1)]
        vv = pv[jnp.clip(rows, 0, n_rows - 1)]
        m = ok.reshape(ok.shape + (1,) * (hot_k.ndim - 2))
        hot_k = jnp.where(m, vk.astype(hot_k.dtype), hot_k)
        hot_v = jnp.where(m, vv.astype(hot_v.dtype), hot_v)

    return cache._replace(
        hot_k=hot_k, hot_v=hot_v,
        pool_k=pk.reshape(cache.pool_k.shape),
        pool_v=pv.reshape(cache.pool_v.shape),
        page_table=table, lengths=lengths,
    )


def save_hot(cache: PagedKVCache, slot: jax.Array,
             page_ids: jax.Array) -> PagedKVCache:
    """Snapshot slot ``slot``'s hot tier into pool pages ``page_ids``
    ((n_hot_pages,) int32, -1 = skip) so the slot-private hot prefix
    becomes shareable through the prefix tree (serving/paging.py). Row i
    of the hot tier lands at row i % ps of page page_ids[i // ps]."""
    ps = cache.page_size
    pk, pv, n_rows = _pool_flat(cache)
    i = jnp.arange(cache.hot_cap, dtype=jnp.int32)
    pages = page_ids.astype(jnp.int32)[jnp.minimum(i // ps,
                                                   page_ids.shape[0] - 1)]
    rows = jnp.where(pages >= 0, jnp.clip(pages, 0, None) * ps + i % ps,
                     n_rows)
    hk = jnp.take(cache.hot_k, slot.astype(jnp.int32), axis=0)
    hv = jnp.take(cache.hot_v, slot.astype(jnp.int32), axis=0)
    pk = pk.at[rows].set(hk.astype(pk.dtype), mode="drop")
    pv = pv.at[rows].set(hv.astype(pv.dtype), mode="drop")
    return cache._replace(pool_k=pk.reshape(cache.pool_k.shape),
                          pool_v=pv.reshape(cache.pool_v.shape))


def release_slots(cache, released: jax.Array):
    """Truncate the ``released`` slots ((b,) bool) to length 0 — slot
    retirement without reinitialisation, used when serving preempts or
    cancels a request mid-flight. Works on tiered and paged caches alike
    (anything with a per-slot ``lengths`` row, stacked or not: the mask
    broadcasts against the trailing batch axis). KV rows and page-table
    entries are left in place: a zero-length slot reads nothing, appends
    restart from row 0 on re-admission, and under paging the freed pool
    pages are owned by the host-side ``PagePool`` refcounts, not by this
    device-side view."""
    released = released.astype(bool)
    lengths = jnp.where(
        jnp.broadcast_to(released, cache.lengths.shape), 0, cache.lengths
    )
    return cache._replace(lengths=lengths)


def truncate(cache, new_lengths: jax.Array):
    """Roll each slot back to ``min(lengths, new_lengths)`` tokens —
    the speculative-decoding reject path (serving/engine.py): the
    verifier appends a full K-token chunk, the acceptance rule keeps a
    prefix, and this drops the rejected suffix. Works on tiered and
    paged caches alike, stacked or not (``new_lengths`` (b,) broadcasts
    against the stacked (L, b) lengths); slots whose length is already
    at or below the target are untouched, so a full-batch call with
    per-slot targets needs no mask.

    KV rows past the new length are left in place — reads are masked by
    ``lengths`` and the next append overwrites them. Under paging the
    page-table entries likewise stay; the HOST decides which pages the
    rollback strands (``ceil(max(len - hot_cap, 0) / page_size)`` pages
    remain live) and decrefs the rest — device state never owns pages.

    NOT valid for ring (SWA) layouts once the window has wrapped: a ring
    append overwrites the oldest window rows in place, so the pre-append
    state is unrecoverable. Ring callers must append only what they keep
    (the serving engine commits ``n_emit`` rows instead of rolling back).
    """
    new_lengths = new_lengths.astype(cache.lengths.dtype)
    return cache._replace(
        lengths=jnp.minimum(cache.lengths, new_lengths)
    )


# ---------------------------------------------------------------------------
# Traffic accounting hooks (ties the functional cache to hwmodel/dr_edram)
# ---------------------------------------------------------------------------


def step_traffic_bytes(
    length: int, hot_cap: int, token_bytes: int
) -> dict:
    """External vs on-die bytes moved by one decode step at cache length L.

    Host-side scalar form (single sequence). The vectorized per-slot form
    used by the jitted serving loop is ``step_traffic_tokens``.
    """
    hot_tokens = min(length, hot_cap)
    cold_tokens = max(length - hot_cap, 0)
    write_ext = 0 if length < hot_cap else token_bytes
    return {
        "ondie_read": hot_tokens * token_bytes,
        "ext_read": cold_tokens * token_bytes,
        "ondie_write": token_bytes - write_ext,
        "ext_write": write_ext,
    }


TRAFFIC_KEYS = ("ondie_read", "ext_read", "ondie_write", "ext_write")


def external_reduction(traffic: dict) -> float:
    """Fraction of accesses kept on-die, from a 4-key traffic ledger.

    Shared by every result type that carries a ledger (engine
    GenerationResult, scheduler FinishedRequest) so the accounting rule
    lives in exactly one place."""
    ext = traffic["ext_read"] + traffic["ext_write"]
    total = ext + traffic["ondie_read"] + traffic["ondie_write"]
    return 1.0 - ext / total if total else 0.0


def step_traffic_tokens(lengths: jax.Array, hot_cap: int) -> dict:
    """Vectorized per-slot ledger for one decode step, in *token* units.

    ``lengths`` (b,) is each slot's cache length *before* the step's append.
    Returns a dict of (b,) int32 token counts; multiply by the per-token KV
    byte size to get bytes (kept as counts on device so int32 never meets
    byte-scaled magnitudes inside the jitted loop). Summing this over steps
    i = 0..S-1 for one slot reproduces ``dr_edram.simulate`` exactly, so the
    accumulated ledger reconciles with ``dr_edram.closed_form_reduction``
    per sequence even in mixed-length batches.
    """
    lengths = lengths.astype(jnp.int32)
    hot = jnp.minimum(lengths, hot_cap)
    cold = jnp.maximum(lengths - hot_cap, 0)
    ext_w = (lengths >= hot_cap).astype(jnp.int32)
    return {
        "ondie_read": hot,
        "ext_read": cold,
        "ondie_write": 1 - ext_w,
        "ext_write": ext_w,
    }


def spec_traffic_tokens(lengths: jax.Array, chunk_valid: jax.Array,
                        committed: jax.Array, hot_cap: int) -> dict:
    """Vectorized per-slot ledger for one speculative draft-verify round
    (token units, like ``step_traffic_tokens``).

    ``lengths`` is each slot's cache length before the round,
    ``chunk_valid`` the number of chunk rows the verifier processed and
    ``committed`` the rows physically appended (= chunk_valid on linear
    layouts, the accepted count on ring layouts). The ledger is charged
    for what the device does, not per emitted token — which is exactly
    the speculation win: the cached prefix streams ONCE per round
    instead of once per token, while the chunk rows attend to each other
    on-die. A spec run therefore does NOT reconcile with the sequential
    closed form ``dr_edram.closed_form_reduction``; it strictly
    undercuts it when acceptance > 0 (asserted in tests). Draft-model
    traffic is outside this ledger — the ledger tracks the target
    model's KV tiers (the draft's KV is a second, much smaller cache).
    """
    lengths = lengths.astype(jnp.int32)
    m = chunk_valid.astype(jnp.int32)
    w = committed.astype(jnp.int32)
    hot = jnp.minimum(lengths, hot_cap)
    cold = jnp.maximum(lengths - hot_cap, 0)
    # chunk row i additionally reads rows 0..i-1 of the chunk, on-die
    intra = m * (m - 1) // 2
    ondie_w = jnp.clip(hot_cap - lengths, 0, w)
    return {
        "ondie_read": hot + intra,
        "ext_read": cold,
        "ondie_write": ondie_w,
        "ext_write": w - ondie_w,
    }


def prompt_traffic_tokens(prompt_len: int, hot_cap: int) -> dict:
    """Closed-form prompt-phase ledger (token units) for one sequence.

    Paper's accounting (§IV Fig. 5a): the edge pipeline processes prompt
    tokens sequentially, so token i writes once and reads tokens 0..i-1 —
    the same ledger as a decode step at length i. This host-side closed
    form equals sum(step_traffic_tokens(i) for i in range(prompt_len)).
    """
    p, b = prompt_len, hot_cap
    if p <= b:
        ondie_read = p * (p - 1) // 2
        ext_read = 0
    else:
        ondie_read = b * (b - 1) // 2 + (p - b) * b
        ext_read = (p - b - 1) * (p - b) // 2
    return {
        "ondie_read": ondie_read,
        "ext_read": ext_read,
        "ondie_write": min(p, b),
        "ext_write": max(p - b, 0),
    }


def prompt_traffic_tokens_resumed(
    prompt_len: int, prefix_len: int, hot_cap: int
) -> dict:
    """Prompt-phase ledger when the first ``prefix_len`` tokens were
    restored from a shared prefix cache (serving/paging.py) instead of
    being prefilled.

    The skipped phase (steps 0..prefix_len-1 of ``prompt_traffic_tokens``)
    never runs; what remains is the tail steps plus the cost of reloading
    the snapshot of the first min(prefix_len, hot_cap) tokens from the
    (external) shared pool into the on-die hot tier. Shared *cold* pages
    cost nothing to adopt — they stay external and are read by the tail
    steps exactly as if the slot had written them itself.
    """
    full = prompt_traffic_tokens(prompt_len, hot_cap)
    skipped = prompt_traffic_tokens(min(prefix_len, prompt_len), hot_cap)
    out = {k: full[k] - skipped[k] for k in TRAFFIC_KEYS}
    reload_hot = min(prefix_len, hot_cap)
    out["ext_read"] += reload_hot
    out["ondie_write"] += reload_hot
    return out


# ---------------------------------------------------------------------------
# Slot-state serialization (replica KV handoff; serving/replica.py)
# ---------------------------------------------------------------------------
#
# Warm migration between engine replicas ships ONE slot's live KV rows in
# the tier STORAGE dtype — with kv_fp8 on, the wire payload is fp8 (one
# byte per element: 4x smaller than an f32 serialization, 2x smaller than
# bf16). The frame carries a crc32 per `page_size` rows of every array
# plus a whole-payload trailer, so a corrupted or torn handoff is
# *detected* (typed `HandoffError`) and the receiver falls back to cold
# recompute-from-prefix instead of serving wrong tokens.


class HandoffError(RuntimeError):
    """A serialized slot-state payload failed verification: truncated
    ("torn") framing, unknown dtype, or a per-page / whole-payload
    checksum mismatch. Receivers treat this as "no handoff" and recompute
    the migrated request from its (prefix-cached) prompt — never import
    unverified KV rows."""

    def __init__(self, msg: str, key: Optional[str] = None,
                 page: Optional[int] = None):
        super().__init__(msg)
        self.key = key
        self.page = page


_HANDOFF_MAGIC = b"KVH1"
_HANDOFF_ARRAYS = ("hot_k", "hot_v", "cold_k", "cold_v")


def _np_storage_dtype(name: str):
    """Resolve a serialized dtype name back to a numpy dtype — including
    the ml_dtypes extension types (bfloat16, float8_e4m3fn, ...) jax
    stores KV tiers in."""
    import ml_dtypes  # ships with jax

    try:
        return np.dtype(name)
    except TypeError:
        pass
    ext = getattr(ml_dtypes, name, None)
    if ext is None:
        raise HandoffError(f"unknown storage dtype {name!r} in handoff")
    return np.dtype(ext)


def slot_state_length(cache) -> "np.ndarray":
    """Per-slot cached length, collapsed over a stacked layer axis."""
    lengths = np.asarray(cache.lengths)
    return lengths.max(axis=0) if lengths.ndim == 2 else lengths


def export_slot_state(cache, slot: int) -> dict:
    """Host copy of one slot's live KV rows, in the tier storage dtype.

    Works on tiered and paged caches, stacked (leading layer axis, the
    engine's per-layer-stack layout) or not; the returned arrays always
    carry a leading layer axis (size 1 when unstacked). ``hot_k/hot_v``
    hold the first ``min(length, hot_cap)`` rows, ``cold_k/cold_v`` the
    remaining ``length - hot_cap`` rows — for a paged cache they are
    gathered through the slot's page-table row, so the export is
    layout-independent: importing on either layout is bit-identical.
    """
    lengths = np.asarray(cache.lengths)
    stacked = lengths.ndim == 2
    length = int(lengths[:, slot].max()) if stacked else int(lengths[slot])
    hot_k = cache.hot_k if stacked else cache.hot_k[None]
    hot_v = cache.hot_v if stacked else cache.hot_v[None]
    hc = hot_k.shape[2]
    n_hot = min(length, hc)
    n_cold = max(length - hc, 0)
    state = {
        "length": length,
        "stacked": stacked,
        "hot_k": np.asarray(hot_k[:, slot, :n_hot]),
        "hot_v": np.asarray(hot_v[:, slot, :n_hot]),
    }
    if hasattr(cache, "page_table"):
        pool_k = cache.pool_k if stacked else cache.pool_k[None]
        pool_v = cache.pool_v if stacked else cache.pool_v[None]
        ps = pool_k.shape[2]
        table = np.asarray(cache.page_table)
        row = table[0, slot] if stacked else table[slot]
        kp = -(-n_cold // ps) if n_cold else 0
        ids = np.asarray(row[:kp], np.int32)
        ck = np.asarray(pool_k[:, ids])  # (layers, kp, ps, ...)
        cv = np.asarray(pool_v[:, ids])
        tail = ck.shape[3:]
        state["cold_k"] = ck.reshape((ck.shape[0], kp * ps) + tail)[:, :n_cold]
        state["cold_v"] = cv.reshape((cv.shape[0], kp * ps) + tail)[:, :n_cold]
    else:
        cold_k = cache.cold_k if stacked else cache.cold_k[None]
        cold_v = cache.cold_v if stacked else cache.cold_v[None]
        state["cold_k"] = np.asarray(cold_k[:, slot, :n_cold])
        state["cold_v"] = np.asarray(cold_v[:, slot, :n_cold])
    return state


def import_slot_state(cache, slot: int, state: dict):
    """Write an exported slot state into ``slot`` of ``cache`` (the
    inverse of :func:`export_slot_state`; bit-identical round trip when
    the dtypes match — enforced, a silent cast would corrupt fp8 bits).

    For a paged cache the cold rows are scattered through the slot's
    CURRENT page-table row, overwriting whole pages — the caller must
    have pointed the row at exclusively-owned (refcount-1) pool pages
    first, exactly like a fresh admission."""
    lengths = np.asarray(cache.lengths)
    stacked = lengths.ndim == 2
    length = int(state["length"])
    hot_k = cache.hot_k if stacked else cache.hot_k[None]
    hc = hot_k.shape[2]
    n_hot = min(length, hc)
    n_cold = max(length - hc, 0)
    for name in _HANDOFF_ARRAYS:
        want = np.dtype(cache.hot_k.dtype.name)
        got = np.dtype(state[name].dtype)
        if want != got:
            raise HandoffError(
                f"handoff dtype {got} does not match cache storage dtype "
                f"{want} for {name!r} — refusing to cast KV bits", key=name)
    hk = jnp.asarray(state["hot_k"])
    hv = jnp.asarray(state["hot_v"])
    if stacked:
        new_hk = cache.hot_k.at[:, slot, :n_hot].set(hk)
        new_hv = cache.hot_v.at[:, slot, :n_hot].set(hv)
        new_lengths = cache.lengths.at[:, slot].set(length)
    else:
        new_hk = cache.hot_k.at[slot, :n_hot].set(hk[0])
        new_hv = cache.hot_v.at[slot, :n_hot].set(hv[0])
        new_lengths = cache.lengths.at[slot].set(length)
    kw = dict(hot_k=new_hk, hot_v=new_hv, lengths=new_lengths)
    if hasattr(cache, "page_table"):
        pool_k = cache.pool_k if stacked else cache.pool_k[None]
        ps = pool_k.shape[2]
        kp = -(-n_cold // ps) if n_cold else 0
        if kp:
            table = np.asarray(cache.page_table)
            row = (table[0, slot] if stacked else table[slot])[:kp]
            ck, cv = state["cold_k"], state["cold_v"]
            tail = ck.shape[2:]
            pad = kp * ps - n_cold
            if pad:
                z = np.zeros((ck.shape[0], pad) + tail, ck.dtype)
                ck = np.concatenate([ck, z], axis=1)
                cv = np.concatenate([cv, z], axis=1)
            ck = jnp.asarray(ck.reshape((ck.shape[0], kp, ps) + tail))
            cv = jnp.asarray(cv.reshape((cv.shape[0], kp, ps) + tail))
            ids = jnp.asarray(row, jnp.int32)
            if stacked:
                kw["pool_k"] = cache.pool_k.at[:, ids].set(ck)
                kw["pool_v"] = cache.pool_v.at[:, ids].set(cv)
            else:
                kw["pool_k"] = cache.pool_k.at[ids].set(ck[0])
                kw["pool_v"] = cache.pool_v.at[ids].set(cv[0])
    else:
        ck = jnp.asarray(state["cold_k"])
        cv = jnp.asarray(state["cold_v"])
        if stacked:
            kw["cold_k"] = cache.cold_k.at[:, slot, :n_cold].set(ck)
            kw["cold_v"] = cache.cold_v.at[:, slot, :n_cold].set(cv)
        else:
            kw["cold_k"] = cache.cold_k.at[slot, :n_cold].set(ck[0])
            kw["cold_v"] = cache.cold_v.at[slot, :n_cold].set(cv[0])
    return cache._replace(**kw)


def write_pool_pages(cache: PagedKVCache, page_ids,
                     k_pages, v_pages) -> PagedKVCache:
    """Write whole pages into the shared pool: ``k_pages/v_pages`` are
    (layers, n, page_size, ...) rows for pool pages ``page_ids`` ((n,)
    int32). The receiver-side primitive of warm migration — imported
    cold pages land in freshly allocated pool pages, then the prefix
    tree adopts them by id (Engine.import_handoff)."""
    ids = jnp.asarray(page_ids, jnp.int32)
    stacked = np.asarray(cache.lengths).ndim == 2
    kp = jnp.asarray(k_pages)
    vp = jnp.asarray(v_pages)
    if stacked:
        return cache._replace(pool_k=cache.pool_k.at[:, ids].set(kp),
                              pool_v=cache.pool_v.at[:, ids].set(vp))
    return cache._replace(pool_k=cache.pool_k.at[ids].set(kp[0]),
                          pool_v=cache.pool_v.at[ids].set(vp[0]))


def gather_pool_pages(cache: PagedKVCache, page_ids):
    """Read whole pages out of the shared pool: the read mirror of
    :func:`write_pool_pages`. Returns ``(k_pages, v_pages)`` as numpy
    arrays of shape (layers, n, page_size, ...) in the storage dtype —
    layers is 1 for an unstacked cache. One device pull per tensor."""
    ids = np.asarray(page_ids, np.int32)
    stacked = np.asarray(cache.lengths).ndim == 2
    if stacked:
        return (np.asarray(cache.pool_k[:, ids]),
                np.asarray(cache.pool_v[:, ids]))
    return (np.asarray(cache.pool_k[ids])[None],
            np.asarray(cache.pool_v[ids])[None])


def pool_page_crcs(caches: dict, pages) -> dict:
    """crc32 of each pool page's bytes across every cache stack: the
    DR-eDRAM retention stamp the serving scrub verifies. ``caches`` is
    the engine's ``{key: PagedKVCache}`` dict; the per-page digest
    chains K then V bytes of every stack in sorted-key order, so any
    single bit flip anywhere in the page's storage changes it. Returns
    ``{page_id: crc}``; two device pulls per stack regardless of page
    count."""
    import zlib

    ids = sorted(int(p) for p in pages)
    if not ids:
        return {}
    crcs = {p: 0 for p in ids}
    for key in sorted(caches):
        cache = caches[key]
        if not hasattr(cache, "page_table"):
            continue
        kp, vp = gather_pool_pages(cache, ids)
        for i, p in enumerate(ids):
            crcs[p] = zlib.crc32(
                np.ascontiguousarray(kp[:, i]).tobytes(), crcs[p])
            crcs[p] = zlib.crc32(
                np.ascontiguousarray(vp[:, i]).tobytes(), crcs[p])
    return {p: c & 0xFFFFFFFF for p, c in crcs.items()}


def pack_slot_state(states: dict, page_size: int) -> bytes:
    """Serialize ``{cache_key: export_slot_state(...)}`` into one framed
    byte payload. Arrays ship in their storage dtype (fp8 stays one byte
    per element on the wire) with a crc32 per ``page_size`` rows and a
    whole-payload crc32 trailer; :func:`unpack_slot_state` verifies both
    and raises :class:`HandoffError` on any mismatch."""
    import struct
    import zlib

    ps = max(int(page_size), 1)
    out = [_HANDOFF_MAGIC, struct.pack("<II", len(states), ps)]
    for key in sorted(states):
        st = states[key]
        kb = key.encode()
        out.append(struct.pack("<H", len(kb)))
        out.append(kb)
        out.append(struct.pack("<IB", int(st["length"]),
                               1 if st["stacked"] else 0))
        for name in _HANDOFF_ARRAYS:
            arr = np.ascontiguousarray(st[name])
            dt = arr.dtype.name.encode()
            rows = arr.shape[1]
            n_pages = -(-rows // ps) if rows else 0
            out.append(struct.pack("<H", len(dt)))
            out.append(dt)
            out.append(struct.pack("<B", arr.ndim))
            out.append(struct.pack(f"<{arr.ndim}I", *arr.shape))
            out.append(struct.pack("<I", n_pages))
            for p in range(n_pages):
                chunk = np.ascontiguousarray(
                    arr[:, p * ps:(p + 1) * ps]).tobytes()
                out.append(struct.pack("<II", len(chunk),
                                       zlib.crc32(chunk) & 0xFFFFFFFF))
                out.append(chunk)
    body = b"".join(out)
    return body + struct.pack("<I", zlib.crc32(body) & 0xFFFFFFFF)


def unpack_slot_state(buf: bytes) -> dict:
    """Parse + verify a :func:`pack_slot_state` payload. Raises
    :class:`HandoffError` on torn framing, unknown dtypes, a per-page
    crc mismatch (``.key``/``.page`` name the damage) or a payload-crc
    mismatch (header corruption) — never returns unverified rows."""
    import struct
    import zlib

    try:
        pos = [0]

        def take(n):
            a, b = pos[0], pos[0] + n
            if b > len(buf):
                raise HandoffError("torn handoff payload: truncated frame")
            pos[0] = b
            return buf[a:b]

        if take(4) != _HANDOFF_MAGIC:
            raise HandoffError("not a slot-state handoff payload (bad magic)")
        n_entries, ps = struct.unpack("<II", take(8))
        states = {}
        for _ in range(n_entries):
            klen, = struct.unpack("<H", take(2))
            key = take(klen).decode()
            length, stacked = struct.unpack("<IB", take(5))
            st = {"length": int(length), "stacked": bool(stacked)}
            for name in _HANDOFF_ARRAYS:
                dlen, = struct.unpack("<H", take(2))
                dtype = _np_storage_dtype(take(dlen).decode())
                ndim, = struct.unpack("<B", take(1))
                shape = struct.unpack(f"<{ndim}I", take(4 * ndim))
                n_pages, = struct.unpack("<I", take(4))
                arr = np.zeros(shape, dtype)
                rows = shape[1] if ndim > 1 else 0
                for p in range(n_pages):
                    nbytes, crc = struct.unpack("<II", take(8))
                    chunk = take(nbytes)
                    if (zlib.crc32(chunk) & 0xFFFFFFFF) != crc:
                        raise HandoffError(
                            f"handoff page checksum mismatch: {key}.{name} "
                            f"page {p}", key=key, page=p)
                    a, b = p * ps, min((p + 1) * ps, rows)
                    arr[:, a:b] = np.frombuffer(chunk, dtype).reshape(
                        (shape[0], b - a) + tuple(shape[2:]))
                st[name] = arr
            states[key] = st
        trailer, = struct.unpack("<I", take(4))
        if (zlib.crc32(buf[:pos[0] - 4]) & 0xFFFFFFFF) != trailer:
            raise HandoffError("handoff payload checksum mismatch "
                               "(corrupted framing)")
        if pos[0] != len(buf):
            raise HandoffError("torn handoff payload: trailing bytes")
        return states
    except HandoffError:
        raise
    except Exception as e:  # struct.error, reshape/frombuffer mismatches
        raise HandoffError(f"torn handoff payload: {e}") from None
