"""Decode-Refresh eDRAM model (paper §IV, Fig. 5).

The paper's observation: during auto-regressive decode, the KV entry of
token ``i`` is read at *every* subsequent decode step, so early tokens are
read most often. Buffering the first ``B`` tokens of a sequence of length
``S`` on-die therefore removes a disproportionate share of external DRAM
traffic, and — because every resident row is touched every step — the reads
double as DRAM refresh (no refresh controller needed while the
token-between-token time stays under the retention time, 64 ms).

Access counting (matches the paper's 43.6% headline exactly):
  * one KV write per generated/prompt token         -> S writes total
  * step t (t = 1..S-1) reads tokens 0..t-1         -> S(S-1)/2 reads total
  * on-die hits: token i<B is read (S-1-i) times and written once
    saved = B(S-1) - B(B-1)/2 + B = B(2S - B + 1)/2
  * reduction = B(2S - B + 1) / (S(S + 1))
    S=128, B=32  ->  3600/8256 = 43.605%  (the paper's 43.6%)

This module provides the closed form, an exact step-by-step counting
simulator (used to cross-validate the closed form and to verify the
refresh-scheduling invariant), and the Fig. 5(b) sweep.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from fractions import Fraction

DEFAULT_TREF_MS = 64.0  # DDR5 retention window (JESD79-5C)


def closed_form_reduction(seq_len: int, buffered: int, include_writes: bool = True) -> float:
    """Fraction of external DRAM accesses removed by buffering ``buffered`` early tokens."""
    s, b = seq_len, min(buffered, seq_len)
    if s <= 0 or b <= 0:
        return 0.0
    if include_writes:
        return float(Fraction(b * (2 * s - b + 1), s * (s + 1)))
    if s == 1:
        return 1.0
    return float(Fraction(b * (2 * s - b - 1), s * (s - 1)))


@dataclass
class AccessTrace:
    """Exact access counts from simulating one full generation of length S."""

    seq_len: int
    buffered: int
    ext_reads: int = 0
    ext_writes: int = 0
    die_reads: int = 0
    die_writes: int = 0
    # per-token read counts, index = token position
    reads_per_token: list = field(default_factory=list)
    # refresh bookkeeping: last decode step at which each on-die row was touched
    max_touch_gap: int = 0

    @property
    def total(self) -> int:
        return self.ext_reads + self.ext_writes + self.die_reads + self.die_writes

    @property
    def external(self) -> int:
        return self.ext_reads + self.ext_writes

    @property
    def reduction(self) -> float:
        return 1.0 - self.external / self.total if self.total else 0.0


def simulate(seq_len: int, buffered: int) -> AccessTrace:
    """Step-by-step decode simulation counting every KV read/write.

    Token 0..S-1; the KV of token t is written when t is processed; decode
    step t (producing token t) reads KV of tokens 0..t-1. Tokens with
    position < ``buffered`` live on-die (DR eDRAM), the rest in external
    DRAM. Also tracks the largest gap (in decode steps) between successive
    touches of any on-die row — the refresh invariant requires this to be 1.
    """
    tr = AccessTrace(seq_len=seq_len, buffered=min(buffered, seq_len))
    tr.reads_per_token = [0] * seq_len
    last_touch = {}
    for t in range(seq_len):
        # write KV of token t
        if t < tr.buffered:
            tr.die_writes += 1
            last_touch[t] = t
        else:
            tr.ext_writes += 1
        # decode step t reads all previous tokens
        for i in range(t):
            tr.reads_per_token[i] += 1
            if i < tr.buffered:
                tr.die_reads += 1
                gap = t - last_touch[i]
                tr.max_touch_gap = max(tr.max_touch_gap, gap)
                last_touch[i] = t
            else:
                tr.ext_reads += 1
    return tr


def refresh_ok(seq_len: int, buffered: int, tbt_ms: float, tref_ms: float = DEFAULT_TREF_MS) -> bool:
    """Is decode-driven refresh sufficient (no explicit refresh controller)?

    Every on-die row is touched at least once per decode step (gap == 1
    step), so refresh holds iff the token-between-token latency is below
    the retention time.
    """
    tr = simulate(min(seq_len, 8), min(buffered, 8))  # gap is structural, small sim suffices
    return tr.max_touch_gap * tbt_ms < tref_ms


def fig5b_sweep(seq_lens=(32, 64, 128, 256), buffers=(4, 8, 16, 32, 64)) -> dict:
    """Reduction-rate table of Fig. 5(b): rows = seq len, cols = buffered tokens."""
    return {
        s: {b: closed_form_reduction(s, b) for b in buffers if b <= s} for s in seq_lens
    }


def edram_bytes(
    buffered_tokens: int,
    n_layers: int,
    n_kv_heads: int,
    head_dim: int,
    n_batches: int = 1,
    bytes_per_elem: int = 2,
) -> int:
    """DR eDRAM capacity for a deployment (paper: 13.5 MiB for Falcon3-1B,
    S=128, 32 buffered tokens, 6 pipelined batches: 32*18*2*6*4*256*2 B)."""
    return (
        buffered_tokens * n_layers * 2 * n_batches * n_kv_heads * head_dim * bytes_per_elem
    )
