"""Render EXPERIMENTS.md tables from the dry-run record JSONs."""

from __future__ import annotations

import json
from pathlib import Path

from repro.analysis import roofline
from repro.configs import SHAPES, get_config, get_overrides

ROOT = Path(__file__).resolve().parents[3] / "results"


def _fmt_b(b):
    return f"{b/2**30:.2f}"


def dryrun_table(d: Path, mesh: str) -> str:
    rows = ["| arch | shape | compile_s | args GiB/dev | temp GiB/dev | HLO flops/dev | collective GiB |",
            "|---|---|---|---|---|---|---|"]
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if r["mesh"] != mesh or "__pack" in p.stem or "__emb8" in p.stem or "__kvfp8" in p.stem:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']} | "
            f"{_fmt_b(r['memory']['argument_bytes'])} | {_fmt_b(r['memory']['temp_bytes'])} | "
            f"{r['flops_total']:.2e} | {_fmt_b(r['collectives']['total_bytes'])} |"
        )
    return "\n".join(rows)


def roofline_table(d: Path, mesh: str = "single") -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s | bottleneck | useful | roofline% |",
            "|---|---|---|---|---|---|---|---|"]
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if r["mesh"] != mesh or "__pack" in p.stem or "__emb8" in p.stem or "__kvfp8" in p.stem:
            continue
        cfg = get_config(r["arch"])
        nm = get_overrides(r["arch"], r["shape"]).get("microbatches", 1)
        t = roofline.roofline_terms(r, cfg, SHAPES[r["shape"]], n_micro=nm)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4g} | {t['memory_s']:.4g} | "
            f"{t['collective_s']:.4g} | {t['bottleneck']} | {t['useful_ratio']:.2f} | "
            f"{100*t.get('roofline_fraction', 0):.2f} |"
        )
    return "\n".join(rows)


def compare_table() -> str:
    rows = ["| arch | shape | baseline coll GiB | optimized coll GiB | Δ | baseline bound s | optimized bound s | speedup |",
            "|---|---|---|---|---|---|---|---|"]
    base_d, opt_d = ROOT / "dryrun", ROOT / "dryrun_opt"
    for p in sorted(base_d.glob("*__single.json")):
        r0 = json.loads(p.read_text())
        po = opt_d / p.name
        if not po.exists():
            continue
        r1 = json.loads(po.read_text())
        cfg = get_config(r0["arch"])
        nm = get_overrides(r0["arch"], r0["shape"]).get("microbatches", 1)
        t0 = roofline.roofline_terms(r0, cfg, SHAPES[r0["shape"]], n_micro=nm)
        t1 = roofline.roofline_terms(r1, cfg, SHAPES[r1["shape"]], n_micro=nm)
        b0 = max(t0["compute_s"], t0["memory_s"], t0["collective_s"])
        b1 = max(t1["compute_s"], t1["memory_s"], t1["collective_s"])
        c0 = r0["collectives"]["total_bytes"]
        c1 = r1["collectives"]["total_bytes"]
        rows.append(
            f"| {r0['arch']} | {r0['shape']} | {_fmt_b(c0)} | {_fmt_b(c1)} | "
            f"{100*(c1-c0)/max(c0,1):+.1f}% | {b0:.4g} | {b1:.4g} | {b0/max(b1,1e-12):.2f}x |"
        )
    return "\n".join(rows)


def main() -> None:
    out = ROOT / "tables.md"
    parts = [
        "## Dry-run, single pod (16x16)", dryrun_table(ROOT / "dryrun", "single"),
        "\n## Dry-run, multi-pod (2x16x16)", dryrun_table(ROOT / "dryrun", "multi"),
        "\n## Roofline (single pod, baseline)", roofline_table(ROOT / "dryrun"),
        "\n## Roofline (single pod, optimized)", roofline_table(ROOT / "dryrun_opt"),
        "\n## Baseline vs optimized (single pod)", compare_table(),
    ]
    out.write_text("\n".join(parts))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
