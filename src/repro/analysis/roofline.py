"""Roofline analysis: three terms per (arch x shape x mesh) from the dry-run.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). Collective bytes
are NOT in cost_analysis — ``collective_bytes_from_hlo`` parses the
compiled HLO text and sums operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Hardware constants (TPU v5e-class, per chip): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

Also computes MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy).
"""

from __future__ import annotations

import json
import re
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link (~per chip, one direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<shape>\([^=]*?\)|\S+?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\(",
    re.MULTILINE,
)

_SHAPE_RE = re.compile(r"(?P<dt>\w+)\[(?P<dims>[\d,]*)\]")

# computation header: "%name (args...) -> ret {"  or  "ENTRY %name (...) {"
_COMP_RE = re.compile(r"^(?P<entry>ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$",
                      re.MULTILINE)

_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?(?P<cond>[\w.\-]+),\s*body=%?(?P<body>[\w.\-]+)"
    r"(?P<rest>[^\n]*)"
)

_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over all tensors in an HLO shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> tuple:
    """-> ({name: body_text}, entry_name)."""
    comps, entry = {}, None
    matches = list(_COMP_RE.finditer(hlo_text))
    for i, m in enumerate(matches):
        start = m.end()
        end = matches[i + 1].start() if i + 1 < len(matches) else len(hlo_text)
        # body runs until the closing "}" at column 0
        close = hlo_text.find("\n}", start, end)
        body = hlo_text[start : close if close != -1 else end]
        comps[m.group("name")] = body
        if m.group("entry"):
            entry = m.group("name")
    return comps, entry


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Trip-count-aware collective traffic from the compiled (per-device) HLO.

    XLA reports a while-loop body once regardless of trip count, so a naive
    scan undercounts scanned programs (layer scans, microbatch scans) by
    10-100x. This walker: (1) splits the module into computations, (2)
    records each computation's local collective bytes (result-shape bytes =
    data landing per participant; async `-done` halves are skipped), (3)
    walks the call graph from ENTRY multiplying by each while's
    ``known_trip_count`` backend config (absent => 1, counted dynamically).
    """
    comps, entry = _split_computations(hlo_text)
    if entry is None:  # fallback: flat scan
        comps, entry = {"__all__": hlo_text}, "__all__"

    local: dict = {}
    whiles: dict = {}
    for name, body in comps.items():
        by_kind: dict = {}
        n_ops = 0
        for m in _COLL_RE.finditer(body):
            if m.group("suffix") == "-done":
                continue
            b = _shape_bytes(m.group("shape"))
            by_kind[m.group("op")] = by_kind.get(m.group("op"), 0) + b
            n_ops += 1
        local[name] = (by_kind, n_ops)
        wl = []
        for m in _WHILE_RE.finditer(body):
            t = _TRIP_RE.search(m.group("rest"))
            wl.append((m.group("body"), int(t.group(1)) if t else 1))
        whiles[name] = wl

    total_by_kind: dict = {}
    total_ops = 0

    def walk(name: str, mult: float, depth: int = 0):
        nonlocal total_ops
        if name not in comps or depth > 32:
            return
        by_kind, n_ops = local[name]
        for k, v in by_kind.items():
            total_by_kind[k] = total_by_kind.get(k, 0) + v * mult
        total_ops += n_ops * mult
        for body_name, trips in whiles[name]:
            walk(body_name, mult * trips, depth + 1)

    walk(entry, 1.0)
    return {
        "total_bytes": int(sum(total_by_kind.values())),
        "by_kind": {k: int(v) for k, v in total_by_kind.items()},
        "op_count": int(total_ops),
        "static_op_sites": sum(n for _, n in local.values()),
    }


# ---------------------------------------------------------------------------
# Analytic FLOPs / HBM ledger.
#
# XLA's cost_analysis() counts while-loop bodies ONCE (layer scans,
# microbatch scans), undercounting scanned programs by 10-100x, so the
# roofline terms are derived analytically from the architecture (every
# matmul in this codebase is accounted below); the HLO-derived numbers are
# recorded alongside as a sanity signal, and collective bytes use the
# trip-count-aware HLO walker above.
# ---------------------------------------------------------------------------


def _attn_flops_per_layer(cfg, b: int, s: int, causal: bool = True) -> float:
    """QK^T + PV matmul FLOPs for one layer, full sequence, forward."""
    if cfg.attn_type == "none":
        return 0.0
    if cfg.attn_type == "mla":
        dk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        dv = cfg.mla.v_head_dim
    else:
        dk = dv = cfg.resolved_head_dim
    h = cfg.n_heads
    s_eff = min(s, cfg.swa_window) if cfg.attn_type == "swa" else s
    f = 2.0 * b * s * s_eff * h * (dk + dv)
    return f / 2 if causal and cfg.attn_type != "swa" else f


def _n_attn_layers(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid_attn_every
    if cfg.attn_type == "none":
        return 0
    return cfg.n_layers


def _ssm_flops_per_layer(cfg, b: int, s: int) -> float:
    if cfg.ssm is None:
        return 0.0
    ssm = cfg.ssm
    h = ssm.n_heads(cfg.d_model)
    p, n, q = ssm.head_dim, ssm.d_state, ssm.chunk
    # state update + output + within-chunk quadratic term
    return 2.0 * b * s * h * p * n * 2 + 2.0 * b * s * q * h * p


def _n_ssm_layers(cfg) -> int:
    if cfg.family == "ssm":
        return cfg.n_layers
    if cfg.family == "hybrid":
        return cfg.n_layers - 0  # every layer has a mamba block
    return 0


def analytic_flops(cfg, shape, kind: str) -> float:
    """Global FLOPs of one step of this cell (fwd=2ND(+attn); train=3x fwd)."""
    b, s = shape.global_batch, shape.seq_len
    n_act = active_params(cfg)
    if kind == "decode":
        f = 2.0 * n_act * b  # one token per sequence
        # attention over the cache: 2 GEMVs per layer over cache length
        if cfg.attn_type != "none":
            dk = (cfg.mla.kv_cache_dim if cfg.attn_type == "mla"
                  else 2 * cfg.resolved_head_dim)
            s_eff = min(s, cfg.swa_window) if cfg.attn_type == "swa" else s
            f += 2.0 * b * s_eff * cfg.n_heads * dk * _n_attn_layers(cfg)
        f += _n_ssm_layers(cfg) * _ssm_flops_per_layer(cfg, b, 1)
        return f
    fwd = 2.0 * n_act * b * s
    fwd += _n_attn_layers(cfg) * _attn_flops_per_layer(cfg, b, s)
    fwd += _n_ssm_layers(cfg) * _ssm_flops_per_layer(cfg, b, s)
    return 3.0 * fwd if kind == "train" else fwd


def _kv_bytes_per_token(cfg) -> float:
    if cfg.attn_type == "none":
        return 0.0
    elem = 1.0 if cfg.bitnet.kv_fp8 else 2.0
    if cfg.attn_type == "mla":
        return cfg.mla.kv_cache_dim * elem
    return 2.0 * cfg.n_kv_heads * cfg.resolved_head_dim * elem


def analytic_hbm_bytes(cfg, shape, kind: str, n_micro: int = 1) -> float:
    """Global HBM traffic of one step (weights + cache + coarse activations)."""
    from repro.core.packing import packed_bytes

    b, s = shape.global_batch, shape.seq_len
    n_params = cfg.param_count()
    d = cfg.d_model
    if kind == "train":
        w_bytes = 2.0 * n_params  # bf16 master weights
        # fwd+bwd re-read weights each microbatch; optimizer RW ~12 B/param
        traffic = 3.0 * w_bytes * n_micro + 12.0 * n_params
        acts = 2.0 * b * s * d * cfg.n_layers * 2 * 3  # remat-era boundaries
        return traffic + acts
    # inference: packed ternary weights (the BiROMA payoff) + fp residue
    w_bytes = packed_bytes(n_params, cfg.bitnet.codec) + 0.1 * n_params
    if kind == "prefill":
        acts = 2.0 * b * s * d * cfg.n_layers * 2
        kv_write = b * s * _kv_bytes_per_token(cfg) * _n_attn_layers(cfg)
        return w_bytes + acts + kv_write
    # decode: weights once + full cache read + small activations
    s_eff = min(s, cfg.swa_window) if cfg.attn_type == "swa" else s
    cache_read = b * s_eff * _kv_bytes_per_token(cfg) * _n_attn_layers(cfg)
    acts = 2.0 * b * d * cfg.n_layers * 8
    return w_bytes + cache_read + acts


def active_params(cfg) -> int:
    """Per-token active parameter count (MoE: routed top-k + shared only)."""
    n = cfg.param_count()
    if cfg.moe is None:
        return n
    mo = cfg.moe
    ff = mo.d_ff_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * ff
    n_moe_layers = cfg.n_layers - mo.n_dense_layers
    inactive = (mo.n_experts - mo.top_k) * per_expert * n_moe_layers
    return n - inactive


def model_flops(cfg, shape, kind: str) -> float:
    """6·N·D for train, 2·N·D for prefill, 2·N per decoded token."""
    n_act = active_params(cfg)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence + attention reads over the cache
    return 2.0 * n_act * shape.global_batch


def roofline_terms(rec: dict, cfg, shape, n_micro: int = 1) -> dict:
    """Three terms (seconds) + dominant bottleneck for one dry-run record.

    Compute/memory terms come from the analytic ledger (global quantities /
    chips); the collective term uses the trip-count-aware HLO parse, whose
    shapes are per-participant — all-reduce moves ~2x its result bytes over
    the links (ring), the others ~1x.
    """
    chips = rec["n_devices"]
    kind = rec["kind"]
    flops = analytic_flops(cfg, shape, kind)
    hbm = analytic_hbm_bytes(cfg, shape, kind, n_micro=n_micro)
    by_kind = rec["collectives"]["by_kind"]
    link_bytes = sum(v * (2.0 if k == "all-reduce" else 1.0) for k, v in by_kind.items())
    terms = {
        "compute_s": flops / (chips * PEAK_FLOPS),
        "memory_s": hbm / (chips * HBM_BW),
        "collective_s": link_bytes / ICI_BW,  # per-participant bytes
    }
    dom = max(terms, key=terms.get)
    out = dict(terms, bottleneck=dom.replace("_s", ""))
    out["analytic_flops"] = flops
    out["analytic_hbm_bytes"] = hbm
    out["hlo_flops_per_dev"] = rec["flops_total"]
    out["hlo_bytes_per_dev"] = rec["bytes_accessed"]
    mf = model_flops(cfg, shape, kind)
    out["model_flops"] = mf
    out["useful_ratio"] = mf / flops if flops > 0 else 0.0
    bound = max(terms.values())
    if bound > 0:
        # fraction of the cluster's peak FLOP/s realized on useful model
        # FLOPs when the step runs at its roofline bound
        out["roofline_fraction"] = (mf / bound) / (chips * PEAK_FLOPS)
        # and utilization of the *binding* resource (1.0 = at that roof)
        out["bound"] = dom.replace("_s", "")
    return out


def load_records(results_dir: Path) -> list:
    return [json.loads(p.read_text()) for p in sorted(results_dir.glob("*.json"))]


def main() -> None:
    import argparse

    from repro.configs import SHAPES, get_config

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--results",
        default=str(Path(__file__).resolve().parents[3] / "results" / "dryrun"),
    )
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()

    from repro.configs import get_overrides

    recs = [r for r in load_records(Path(args.results)) if r["mesh"] == args.mesh]
    print(f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'collect_s':>10s} {'bottleneck':>10s} {'useful':>7s} {'roofl%':>7s}")
    for r in recs:
        cfg = get_config(r["arch"])
        nm = get_overrides(r["arch"], r["shape"]).get("microbatches", 1)
        t = roofline_terms(r, cfg, SHAPES[r["shape"]], n_micro=nm)
        print(
            f"{r['arch']:22s} {r['shape']:12s} {t['compute_s']:10.4g} "
            f"{t['memory_s']:10.4g} {t['collective_s']:10.4g} {t['bottleneck']:>10s} "
            f"{t.get('useful_ratio', 0):7.3f} {100*t.get('roofline_fraction', 0):7.2f}"
        )


if __name__ == "__main__":
    main()
