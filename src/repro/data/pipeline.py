"""Deterministic synthetic data pipeline — shardable, seedable, resumable.

Produces LM token batches (or frame/patch features for the audio/VLM
frontends) from a counter-based PRNG, so:
  * any (step, host, shard) reproduces identically — no data files needed;
  * the pipeline state is just an integer step, checkpointable;
  * per-shard generation matches jax.make_array_from_callback for
    multi-host feeding (each host generates only its addressable shards).

Tokens follow a Zipf-like distribution (LLM-ish unigram stats) with a
deterministic structure so the loss actually decreases during the example
training runs (a learnable n-gram pattern is mixed in).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2
    pattern_period: int = 7  # learnable structure strength


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks**-a
    return (p / p.sum()).astype(np.float32)


def batch_at_step(
    cfg: ModelConfig,
    dcfg: DataConfig,
    step: int,
    global_batch: int,
    seq_len: int,
    dtype=jnp.float32,
) -> dict:
    """Generate the full global batch for ``step`` (host-local use)."""
    key = jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), step)
    if cfg.family == "audio":
        kf, kl = jax.random.split(key)
        frames = jax.random.normal(kf, (global_batch, seq_len, cfg.frontend_dim), dtype)
        labels = jax.random.randint(kl, (global_batch, seq_len), 0, cfg.vocab_size)
        return {"frames": frames, "labels": labels}

    kz, kp, kmix = jax.random.split(key, 3)
    probs = jnp.asarray(_zipf_probs(cfg.vocab_size, dcfg.zipf_a))
    text_len = seq_len - (cfg.n_patches if cfg.family == "vlm" else 0)
    zipf_tokens = jax.random.choice(
        kz, cfg.vocab_size, (global_batch, text_len), p=probs
    ).astype(jnp.int32)
    # learnable structure: periodic arithmetic pattern per sequence
    start = jax.random.randint(kp, (global_batch, 1), 0, cfg.vocab_size)
    pattern = (start + jnp.arange(text_len)[None, :] % dcfg.pattern_period) % cfg.vocab_size
    use_pattern = jax.random.bernoulli(kmix, 0.5, (global_batch, 1))
    tokens = jnp.where(use_pattern, pattern.astype(jnp.int32), zipf_tokens)
    labels = jnp.roll(tokens, -1, axis=1)

    out = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            key, (global_batch, cfg.n_patches, cfg.frontend_dim), dtype
        )
    return out


class DataIterator:
    """Stateful wrapper with checkpointable position."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig, global_batch: int, seq_len: int):
        self.cfg, self.dcfg = cfg, dcfg
        self.global_batch, self.seq_len = global_batch, seq_len
        self.step = 0

    def __next__(self) -> dict:
        b = batch_at_step(self.cfg, self.dcfg, self.step, self.global_batch, self.seq_len)
        self.step += 1
        return b

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.dcfg.seed}

    def load_state_dict(self, s: dict) -> None:
        assert s["seed"] == self.dcfg.seed, "data seed mismatch on resume"
        self.step = int(s["step"])
