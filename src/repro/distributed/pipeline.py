"""GPipe-style pipeline parallelism via shard_map + ppermute.

The paper's system architecture (§V-B) maps Falcon3-1B as 6 macro
partitions × 3 layers with 6 input batches streamed through a 6-stage
pipeline at full macro utilization. This module is that schedule on a TPU
mesh axis: layer stack split into S stages (params sharded over the
``stage`` axis), microbatches streamed with lax.scan, hidden states handed
to the next stage with collective-permute. The bubble fraction is the
classic (S-1)/(T+S-1); with T = S = 6 the paper's configuration reaches
6/11 ≈ 55% per-round utilization in steady state and full utilization for
continuous streams.

Forward-only here matches the paper's inference deployment; jax.grad can
differentiate straight through ppermute for pipelined training.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

try:  # jax >= 0.6: public top-level export
    from jax import shard_map
except ImportError:  # jax 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.transformer import _attn_block_fwd


def reshape_to_stages(stacked_params, n_stages: int):
    """(L, ...) stacked block params -> (S, L/S, ...)."""

    def r(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape((n_stages, l // n_stages) + x.shape[1:])

    return jax.tree.map(r, stacked_params)


def make_pipeline_forward(cfg: ModelConfig, mesh, n_stages: int, n_micro: int,
                          axis: str = "stage", mode: str = "qat"):
    """Returns pipelined(staged_params, x (n_micro, mb, s, d)) -> (n_micro, mb, s, d).

    ``staged_params``: block params reshaped (S, L/S, ...), sharded over
    ``axis`` on dim 0. x holds the embedded microbatch inputs; outputs are
    the last stage's hidden states per microbatch.
    """

    def stage_fn(stage_params, h, positions):
        def body(carry, bp):
            out, _, _ = _attn_block_fwd(bp, carry, cfg, mode, positions)
            return out, None

        h, _ = jax.lax.scan(body, h, stage_params)
        return h

    def pipelined_local(staged_params, x):
        # shapes inside shard_map: staged_params (1, L/S, ...); x (n_micro, mb, s, d)
        sp = jax.tree.map(lambda a: a[0], staged_params)
        idx = jax.lax.axis_index(axis)
        mb, s, d = x.shape[1], x.shape[2], x.shape[3]
        positions = jnp.arange(s, dtype=jnp.int32)
        pad = jnp.zeros((n_stages - 1, mb, s, d), x.dtype)
        stream = jnp.concatenate([x, pad], axis=0)  # (T, mb, s, d)

        def step(h_prev, x_t):
            inp = jnp.where(idx == 0, x_t, h_prev)
            out = stage_fn(sp, inp, positions)
            h_next = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return h_next, out

        h0 = jnp.zeros((mb, s, d), x.dtype)
        _, outs = jax.lax.scan(step, h0, stream)  # (T, mb, s, d) per stage
        # microbatch t leaves the last stage at step t + S - 1
        final = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, n_micro, axis=0)
        return final[None]  # (1, n_micro, mb, s, d) per stage

    try:  # new API spells the replication check check_vma ...
        fn = shard_map(
            pipelined_local,
            mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(axis),
            check_vma=False,
        )
    except TypeError:  # ... jax 0.4.x spells it check_rep
        fn = shard_map(
            pipelined_local,
            mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(axis),
            check_rep=False,
        )

    def pipelined(staged_params, x):
        outs = fn(staged_params, x)  # (S, n_micro, mb, s, d)
        return outs[-1]  # only the last stage's slice is meaningful

    return pipelined


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: (S-1) / (T + S - 1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
