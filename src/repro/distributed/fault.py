"""Fault tolerance: crash recovery, straggler detection, preemption handling.

Designed for the 1000+-node posture (DESIGN.md §5):
  * ``run_with_recovery`` — supervises a training loop; on failure it
    restarts from the latest atomic checkpoint (tested: an injected crash
    at step N resumes and reproduces the uninterrupted run bit-for-bit,
    because the data pipeline state rides in the checkpoint);
  * ``StragglerMonitor`` — sliding-window step-time watchdog; flags steps
    slower than ``factor`` × the window median (on a real cluster the
    callback triggers re-slicing / hot-spare swap; here it records);
  * ``PreemptionGuard`` — SIGTERM-style flag that converts preemption into
    a clean checkpoint-and-exit.

The serving plane reuses the same primitives: ``serving/chaos.py`` builds
its fault-injection harness on ``FaultSchedule`` (seeded, deterministic
per-step event draws) and ``StragglerMonitor`` (the engine-loop iteration
is the "step"), so training and serving chaos tests share one vocabulary.
"""

from __future__ import annotations

import dataclasses
import random
import signal
import statistics
import time
from typing import Callable, Optional, Sequence, Tuple


class InjectedFault(RuntimeError):
    """Deterministic fault for recovery tests."""


@dataclasses.dataclass
class FaultInjector:
    """Raise :class:`InjectedFault` at fixed step(s): ``fail_at_step`` for
    the single-crash recovery tests, ``fail_at_steps`` when a scenario
    needs several deterministic failures in one run (each point fires at
    most once)."""

    fail_at_step: int = -1
    fail_at_steps: Tuple[int, ...] = ()
    fired: bool = False

    def __post_init__(self):
        self._pending = set(self.fail_at_steps)

    def check(self, step: int) -> None:
        if step == self.fail_at_step and not self.fired:
            self.fired = True
            raise InjectedFault(f"injected node failure at step {step}")
        if step in self._pending:
            self._pending.discard(step)
            self.fired = True
            raise InjectedFault(f"injected node failure at step {step}")


class FaultSchedule:
    """Seeded per-step event sampler: ``fires(step)`` draws once per call
    from a private PRNG, so a fixed seed and a fixed call sequence give
    the same injection points every run — the determinism contract the
    chaos tests (three fixed CI seeds) rely on. ``rate`` is the per-step
    event probability."""

    def __init__(self, seed: int, rate: float):
        self.rate = rate
        self._rng = random.Random(seed)
        self.fired_at: list = []

    def fires(self, step: int) -> bool:
        hit = self._rng.random() < self.rate
        if hit:
            self.fired_at.append(step)
        return hit

    def pick(self, items: Sequence):
        """Deterministically choose one of ``items`` (injection target)."""
        return items[self._rng.randrange(len(items))]


class StragglerMonitor:
    def __init__(self, window: int = 20, factor: float = 3.0,
                 on_straggler: Optional[Callable] = None):
        self.window = window
        self.factor = factor
        self.on_straggler = on_straggler
        self.times: list = []
        self.flagged: list = []

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window :]
        if len(hist) >= 5:
            med = statistics.median(hist)
            if seconds > self.factor * med:
                self.flagged.append((step, seconds, med))
                if self.on_straggler:
                    self.on_straggler(step, seconds, med)
                return True
        return False

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a cooperative 'checkpoint now' flag."""

    def __init__(self, install_handlers: bool = False):
        self.requested = False
        if install_handlers:  # not in tests — pytest owns signals
            signal.signal(signal.SIGTERM, self._handler)
            signal.signal(signal.SIGINT, self._handler)

    def _handler(self, signum, frame):  # pragma: no cover
        self.requested = True

    def request(self) -> None:  # manual trigger (tests / external agent)
        self.requested = True


def run_with_recovery(
    loop_fn: Callable[[Optional[int]], dict],
    max_restarts: int = 3,
    on_restart: Optional[Callable] = None,
) -> dict:
    """Supervise ``loop_fn(resume_step)``; restart from checkpoints on crash.

    ``loop_fn`` must accept ``resume_step`` (None = fresh or auto-detect)
    and return its result dict. Exceptions trigger a restart with
    resume_step=None, letting the loop auto-detect the latest checkpoint.
    """
    attempts = 0
    while True:
        try:
            return loop_fn(None)
        except Exception as e:  # noqa: BLE001
            attempts += 1
            if attempts > max_restarts:
                raise
            if on_restart:
                on_restart(attempts, e)
            time.sleep(0.01)
