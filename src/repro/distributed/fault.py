"""Fault tolerance: crash recovery, straggler detection, preemption handling.

Designed for the 1000+-node posture (DESIGN.md §5):
  * ``run_with_recovery`` — supervises a training loop; on failure it
    restarts from the latest atomic checkpoint (tested: an injected crash
    at step N resumes and reproduces the uninterrupted run bit-for-bit,
    because the data pipeline state rides in the checkpoint);
  * ``StragglerMonitor`` — sliding-window step-time watchdog; flags steps
    slower than ``factor`` × the window median (on a real cluster the
    callback triggers re-slicing / hot-spare swap; here it records);
  * ``PreemptionGuard`` — SIGTERM-style flag that converts preemption into
    a clean checkpoint-and-exit.
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Callable, Optional


class InjectedFault(RuntimeError):
    """Deterministic fault for recovery tests."""


@dataclasses.dataclass
class FaultInjector:
    fail_at_step: int = -1
    fired: bool = False

    def check(self, step: int) -> None:
        if step == self.fail_at_step and not self.fired:
            self.fired = True
            raise InjectedFault(f"injected node failure at step {step}")


class StragglerMonitor:
    def __init__(self, window: int = 20, factor: float = 3.0,
                 on_straggler: Optional[Callable] = None):
        self.window = window
        self.factor = factor
        self.on_straggler = on_straggler
        self.times: list = []
        self.flagged: list = []

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window :]
        if len(hist) >= 5:
            med = statistics.median(hist)
            if seconds > self.factor * med:
                self.flagged.append((step, seconds, med))
                if self.on_straggler:
                    self.on_straggler(step, seconds, med)
                return True
        return False

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a cooperative 'checkpoint now' flag."""

    def __init__(self, install_handlers: bool = False):
        self.requested = False
        if install_handlers:  # not in tests — pytest owns signals
            signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, signum, frame):  # pragma: no cover
        self.requested = True

    def request(self) -> None:  # manual trigger (tests / external agent)
        self.requested = True


def run_with_recovery(
    loop_fn: Callable[[Optional[int]], dict],
    max_restarts: int = 3,
    on_restart: Optional[Callable] = None,
) -> dict:
    """Supervise ``loop_fn(resume_step)``; restart from checkpoints on crash.

    ``loop_fn`` must accept ``resume_step`` (None = fresh or auto-detect)
    and return its result dict. Exceptions trigger a restart with
    resume_step=None, letting the loop auto-detect the latest checkpoint.
    """
    attempts = 0
    while True:
        try:
            return loop_fn(None)
        except Exception as e:  # noqa: BLE001
            attempts += 1
            if attempts > max_restarts:
                raise
            if on_restart:
                on_restart(attempts, e)
            time.sleep(0.01)
