"""Mixture-of-Experts layer (Mixtral top-2/8, DeepSeek-V3 shared+routed top-8/256).

Expert-parallel formulation: router scores -> per-expert top-C token
selection (capacity-based, MaxText-style) -> gather to (E, C, d) -> batched
expert GEMMs (sharded over the mesh = EP) -> weighted scatter-add back.
All expert projections are ternary BitLinears (the paper's technique
applies to expert weights identically — they dominate the 671B's footprint).

Two dispatch modes (selected via models/shard_ctx.py hints):
  * global routing — one top-C selection over all tokens (baseline);
  * grouped routing — tokens are split into ``moe_groups`` groups aligned
    with the data shards and routed with per-group capacity, so the
    dispatch gather and combine scatter stay shard-local. This removed the
    two dominant collectives of the mixtral train cell (global-token
    gathers, multi-TB at 256 devices — EXPERIMENTS.md §Perf H3).

Dropped tokens (beyond capacity) pass through the residual only, standard
for capacity-based routing. A load-balance auxiliary loss (Switch-style)
is returned for training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import qops, shard_ctx
from repro.models.layers import init_rms_norm, rms_norm


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    mo = cfg.moe
    d = cfg.d_model
    ff = mo.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 8)
    p = {
        "ln": init_rms_norm(d, dtype),
        "router": {"w": jax.random.normal(ks[0], (d, mo.n_experts), dtype) * d**-0.5},
        "w_gate": qops.init_expert_linear(ks[1], mo.n_experts, d, ff, dtype),
        "w_up": qops.init_expert_linear(ks[2], mo.n_experts, d, ff, dtype),
        "w_down": qops.init_expert_linear(ks[3], mo.n_experts, ff, d, dtype),
    }
    if mo.n_shared:
        p["shared_gate"] = qops.init_linear(ks[4], d, cfg.d_ff * mo.n_shared, dtype)
        p["shared_up"] = qops.init_linear(ks[5], d, cfg.d_ff * mo.n_shared, dtype)
        p["shared_down"] = qops.init_linear(ks[6], cfg.d_ff * mo.n_shared, d, dtype)
    if cfg.bitnet.lora_rank and "down" in cfg.bitnet.lora_targets:
        from repro.core import lora as lora_lib

        # one rank-16 adapter on the shared/aggregate down path (paper's Down target)
        p["lora_down"] = lora_lib.init(ks[7], d, d, cfg.bitnet.lora_rank, dtype)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    mo = cfg.moe
    c = int(n_tokens * mo.top_k / mo.n_experts * mo.capacity_factor) + 1
    return max(min(c, n_tokens), 1)


def _route_tokens(p: dict, h: jax.Array, cfg: ModelConfig, mode: str, cap: int,
                  impl: str | None = None):
    """Dispatch+compute+combine for one token group. h: (T, d).

    Returns (y (T, d) f32, probs (T, E) f32, top1 one-hot (T, E)).
    ``impl`` pins the expert-GEMM execution path — the grouped-dispatch
    caller runs this function under ``jax.vmap``, where the E-loop
    pallas_call cannot appear, so it pins "xla".
    """
    mo = cfg.moe
    n_tok, d = h.shape
    logits = h.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, mo.top_k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)  # renorm

    assign = jnp.zeros((n_tok, mo.n_experts), jnp.float32)
    assign = assign.at[jnp.arange(n_tok)[:, None], gate_idx].set(gate_vals)

    sel_w, sel_idx = jax.lax.top_k(assign.T, cap)  # (E, C)
    xe = jnp.take(h, sel_idx.reshape(-1), axis=0).reshape(mo.n_experts, cap, d)
    if shard_ctx.has_expert_axes():
        xe = shard_ctx.constrain(xe, "EXPERT", None, None)

    if "w_gu" in p:
        # pack-time-fused per-expert gate‖up (models/pack.py::fuse_packed):
        # one E-loop launch serves all experts and both GLU halves.
        g, u = qops.expert_fused_linear(p["w_gu"], xe, cfg, impl=impl)
    else:
        g = qops.expert_linear(p["w_gate"], xe, cfg, mode, impl=impl)
        u = qops.expert_linear(p["w_up"], xe, cfg, mode, impl=impl)
    a = jax.nn.silu(g) * u
    ye = qops.expert_linear(p["w_down"], a, cfg, mode, impl=impl)  # (E, C, d)
    if shard_ctx.has_expert_axes():
        ye = shard_ctx.constrain(ye, "EXPERT", None, None)

    # combine: f32 accumulation for training; bf16 in inference halves the
    # cross-shard combine traffic (top-k expert sums tolerate bf16)
    acc_dtype = jnp.float32 if mode == "qat" else jnp.bfloat16
    ye = ye.astype(acc_dtype) * sel_w[..., None].astype(acc_dtype)
    y = jnp.zeros((n_tok, d), acc_dtype)
    y = y.at[sel_idx.reshape(-1)].add(ye.reshape(-1, d))
    y = y.astype(jnp.float32)

    top1 = jax.nn.one_hot(gate_idx[:, 0], mo.n_experts)
    return y, probs, top1


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig, mode: str):
    """x: (b, t, d) -> (y, aux_loss)."""
    mo = cfg.moe
    b, t, d = x.shape
    h3 = rms_norm(x, p["ln"], cfg.norm_eps)

    groups = shard_ctx.moe_groups()
    if groups > 1 and b % groups == 0:
        # grouped dispatch: routing, gather and combine stay local to each
        # data shard (per-group capacity, production-standard semantics)
        hg = h3.reshape(groups, (b // groups) * t, d)
        hg = shard_ctx.constrain(hg, "BATCH", None, None)
        cap = _capacity(hg.shape[1], cfg)
        yg, probs, top1 = jax.vmap(
            lambda hh: _route_tokens(p, hh, cfg, mode, cap, impl="xla")
        )(hg)
        yg = shard_ctx.constrain(yg, "BATCH", None, None)
        y = yg.reshape(b * t, d)
        probs = probs.reshape(-1, mo.n_experts)
        top1 = top1.reshape(-1, mo.n_experts)
    else:
        h = h3.reshape(b * t, d)
        cap = _capacity(b * t, cfg)
        y, probs, top1 = _route_tokens(p, h, cfg, mode, cap)
        y = shard_ctx.constrain(y, "TOKENS", None)

    # --- shared experts (DeepSeek-V3: always-on) ---
    if mo.n_shared:
        if "shared_gu" in p:
            # fused packed gate‖up (models/pack.py::fuse_packed)
            sg, su = qops.fused_linear(p["shared_gu"], h3, cfg)
        else:
            sg = qops.linear(p["shared_gate"], h3, cfg, mode)
            su = qops.linear(p["shared_up"], h3, cfg, mode)
        shared = qops.linear(p["shared_down"], jax.nn.silu(sg) * su, cfg, mode)
        y = y + shared.astype(jnp.float32).reshape(b * t, d)

    if "lora_down" in p and cfg.bitnet.lora_rank:
        from repro.core import lora as lora_lib

        y = y + lora_lib.apply(
            p["lora_down"], h3.reshape(b * t, d),
            alpha=2.0 * cfg.bitnet.lora_rank, weight_bits=cfg.bitnet.lora_bits,
        ).astype(jnp.float32)

    # --- Switch-style load-balance aux loss ---
    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(top1, axis=0)
    aux = mo.n_experts * jnp.sum(me * fe) * mo.router_aux_weight

    return y.reshape(b, t, d).astype(x.dtype), aux
