"""Shared neural layers: RMSNorm, RoPE, gated MLPs (all BitLinear-backed)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import qops


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(x.dtype)


def init_rms_norm(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, D); positions: (..., T) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., T, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : d // 2], x32[..., d // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs (swiglu / geglu gated, gelu non-gated) — all projections ternary
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None, dtype=jnp.float32) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"ln": init_rms_norm(d, dtype)}
    if cfg.activation == "gelu":
        p["up"] = qops.init_linear(ks[0], d, f, dtype)
    else:
        p["gate"] = qops.init_linear(ks[0], d, f, dtype)
        p["up"] = qops.init_linear(ks[1], d, f, dtype)
    p["down"] = qops.init_linear(ks[2], f, d, dtype)
    if cfg.bitnet.lora_rank and "down" in cfg.bitnet.lora_targets:
        from repro.core import lora as lora_lib

        p["lora_down"] = lora_lib.init(ks[2], f, d, cfg.bitnet.lora_rank, dtype)
    return p


def apply_mlp(p: dict, x: jax.Array, cfg: ModelConfig, mode: str) -> jax.Array:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if cfg.activation == "gelu":
        a = jax.nn.gelu(qops.linear(p["up"], h, cfg, mode))
    else:
        if "wgu" in p:
            # fused packed gate‖up (models/pack.py::fuse_packed): one
            # act-quant + one kernel launch for both halves of the GLU.
            g, u = qops.fused_linear(p["wgu"], h, cfg)
        else:
            g = qops.linear(p["gate"], h, cfg, mode)
            u = qops.linear(p["up"], h, cfg, mode)
        act = jax.nn.gelu(g, approximate=True) if cfg.activation == "geglu" else jax.nn.silu(g)
        a = act * u
    return qops.linear(p["down"], a, cfg, mode, lora_leaf=p.get("lora_down"))
