"""Mamba2 (SSD — state-space duality) blocks [arXiv:2405.21060].

Chunked SSD for full sequences (train / prefill): within-chunk quadratic
attention-form + cross-chunk linear recurrence over chunk-final states,
scanned with lax.scan so memory stays O(chunk²) and the 512k-token cell is
feasible (this is why the SSM/hybrid archs own the long_500k shape).

Decode is the O(1)-state recurrence: S ← exp(dt·A)·S + dt·(B ⊗ x),
y = C·S + D·x — no KV cache grows, which is exactly why the paper's DR
eDRAM tiering is N/A for this family (DESIGN.md §Arch-applicability).

in/out projections are ternary BitLinears (the paper's quantization applies
to every linear); the tiny depthwise conv and SSM scalars stay float.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import qops
from repro.models.layers import init_rms_norm, rms_norm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_ch = di + 2 * s.n_groups * s.d_state
    return s, di, nh, conv_ch


def init_mamba_block(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    s, di, nh, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    in_dim = 2 * di + 2 * s.n_groups * s.d_state + nh  # [z, x, B, C, dt]
    p = {
        "ln": init_rms_norm(d, dtype),
        "in_proj": qops.init_linear(ks[0], d, in_dim, dtype),
        "conv_w": jax.random.normal(ks[1], (conv_ch, s.d_conv), dtype) * (s.d_conv**-0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(dtype)),
        "d_skip": jnp.ones((nh,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "gate_ln": init_rms_norm(di, dtype),
        "out_proj": qops.init_linear(ks[2], di, d, dtype),
    }
    if cfg.bitnet.lora_rank and "down" in cfg.bitnet.lora_targets:
        from repro.core import lora as lora_lib

        # out_proj is the SSM analogue of the Down projection (paper target)
        p["lora_out"] = lora_lib.init(ks[3], di, d, cfg.bitnet.lora_rank, dtype)
    return p


def _split_in_proj(zxbcdt, cfg: ModelConfig):
    s, di, nh, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z = zxbcdt[..., :di]
    xc = zxbcdt[..., di : di + di + 2 * gn]  # conv input: [x, B, C]
    dt = zxbcdt[..., di + di + 2 * gn :]  # (…, nh)
    return z, xc, dt


def _causal_conv_full(xc, w, b):
    """Depthwise causal conv over seq. xc: (bsz, l, c); w: (c, k)."""
    k = w.shape[1]
    xt = jnp.moveaxis(xc, 1, 2)  # (bsz, c, l)
    out = jax.lax.conv_general_dilated(
        xt,
        w[:, None, :],  # (c, 1, k)
        window_strides=(1,),
        padding=[(k - 1, 0)],
        feature_group_count=w.shape[0],
        dimension_numbers=("NCH", "OIH", "NCH"),
    )
    return jnp.moveaxis(out, 1, 2) + b  # (bsz, l, c)


def _ssd_chunked(xh, dt_a, bmat, cmat, cfg: ModelConfig, s_init=None):
    """Chunked SSD scan.

    xh:   (bsz, l, g, r, p)  — dt-scaled inputs per head
    dt_a: (bsz, l, g, r)     — log decays dt*A (negative)
    bmat, cmat: (bsz, l, g, n)
    Returns (y (bsz, l, g, r, p), final_state (bsz, g, r, p, n)).
    """
    s = cfg.ssm
    bsz, l, g, r, p = xh.shape
    n = bmat.shape[-1]
    q = min(s.chunk, l)
    while l % q:
        q //= 2
    nc = l // q

    xh = xh.reshape(bsz, nc, q, g, r, p)
    dt_a = dt_a.reshape(bsz, nc, q, g, r)
    bmat = bmat.reshape(bsz, nc, q, g, n)
    cmat = cmat.reshape(bsz, nc, q, g, n)

    def chunk_step(state, inp):
        xc, ac, bc, cc = inp  # (bsz, q, g, r, p) etc.
        a_cs = jnp.cumsum(ac, axis=1)  # inclusive (bsz, q, g, r)
        # within-chunk (attention-form) term; mask BEFORE exp: the i<j
        # entries have positive exponents that overflow, and exp-then-where
        # would poison the gradient (inf * 0 = NaN).
        tri = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None, None]
        diff = a_cs[:, :, None] - a_cs[:, None]  # (bsz, i, j, g, r)
        ldec = jnp.exp(jnp.where(tri, diff, -jnp.inf))
        scores = jnp.einsum("bign,bjgn->bijg", cc, bc)
        y_diag = jnp.einsum("bijg,bijgr,bjgrp->bigrp", scores, ldec, xc)
        # carry-in state term
        y_off = jnp.einsum("bign,bgrpn,bigr->bigrp", cc, state, jnp.exp(a_cs))
        # chunk-final state
        a_sum = a_cs[:, -1]  # (bsz, g, r)
        decay = jnp.exp(a_sum[:, None] - a_cs)  # (bsz, j, g, r)
        s_chunk = jnp.einsum("bjgn,bjgr,bjgrp->bgrpn", bc, decay, xc)
        state_new = state * jnp.exp(a_sum)[..., None, None] + s_chunk
        return state_new, y_diag + y_off

    s0 = (
        s_init
        if s_init is not None
        else jnp.zeros((bsz, g, r, p, n), jnp.float32)
    )
    xs = (
        jnp.moveaxis(xh, 1, 0),
        jnp.moveaxis(dt_a, 1, 0),
        jnp.moveaxis(bmat, 1, 0),
        jnp.moveaxis(cmat, 1, 0),
    )
    final, ys = jax.lax.scan(chunk_step, s0, xs)  # ys: (nc, bsz, q, g, r, p)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l, g, r, p)
    return y, final


def apply_mamba_full(p: dict, x: jax.Array, cfg: ModelConfig, mode: str,
                     return_state: bool = False):
    """Full-sequence Mamba2 block. x: (bsz, l, d) -> y (+ final SSM/conv state)."""
    s, di, nh, conv_ch = _dims(cfg)
    bsz, l, d = x.shape
    g, n = s.n_groups, s.d_state
    r = nh // g

    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = qops.linear(p["in_proj"], h, cfg, mode)
    z, xc, dt_raw = _split_in_proj(zxbcdt, cfg)
    xc = jax.nn.silu(_causal_conv_full(xc, p["conv_w"], p["conv_b"]))
    xin = xc[..., :di]
    bmat = xc[..., di : di + g * n].reshape(bsz, l, g, n).astype(jnp.float32)
    cmat = xc[..., di + g * n :].reshape(bsz, l, g, n).astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (bsz,l,nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (nh,)
    xheads = xin.reshape(bsz, l, g, r, s.head_dim).astype(jnp.float32)
    dt_h = dt.reshape(bsz, l, g, r)
    xh = xheads * dt_h[..., None]
    dt_a = dt_h * a.reshape(g, r)

    y, final = _ssd_chunked(xh, dt_a, bmat, cmat, cfg)
    y = y + xheads * p["d_skip"].reshape(g, r)[None, None, :, :, None]
    y = y.reshape(bsz, l, di).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["gate_ln"], cfg.norm_eps)
    out = qops.linear(p["out_proj"], y, cfg, mode, lora_leaf=p.get("lora_out"))
    if return_state:
        # conv state = last d_conv-1 *raw* conv inputs (pre-conv, pre-silu)
        _, xc_raw, _ = _split_in_proj(zxbcdt, cfg)
        conv_state = xc_raw[:, l - (s.d_conv - 1) :, :]
        return out, {"ssm": final, "conv": conv_state}
    return out


def init_mamba_state(bsz: int, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    s, di, nh, conv_ch = _dims(cfg)
    g, r = s.n_groups, nh // s.n_groups
    return {
        "ssm": jnp.zeros((bsz, g, r, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((bsz, s.d_conv - 1, conv_ch), dtype),
    }


def apply_mamba_decode(p: dict, x: jax.Array, cfg: ModelConfig, mode: str, state: dict):
    """One-token recurrent step. x: (bsz, d). Returns (y, new_state)."""
    s, di, nh, conv_ch = _dims(cfg)
    bsz, d = x.shape
    g, n = s.n_groups, s.d_state
    r = nh // g

    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = qops.linear(p["in_proj"], h, cfg, mode)
    z, xc_new, dt_raw = _split_in_proj(zxbcdt, cfg)

    # rolling causal conv
    window = jnp.concatenate([state["conv"], xc_new[:, None, :]], axis=1)  # (bsz,k,c)
    conv_out = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(conv_out + p["conv_b"]).astype(x.dtype)
    new_conv_state = window[:, 1:]

    xin = xc[..., :di]
    bvec = xc[..., di : di + g * n].reshape(bsz, g, n).astype(jnp.float32)
    cvec = xc[..., di + g * n :].reshape(bsz, g, n).astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"]).reshape(bsz, g, r)
    a = -jnp.exp(p["a_log"].astype(jnp.float32)).reshape(g, r)
    xheads = xin.reshape(bsz, g, r, s.head_dim).astype(jnp.float32)

    decay = jnp.exp(dt * a)[..., None, None]  # (bsz,g,r,1,1)
    upd = jnp.einsum("bgrp,bgn->bgrpn", xheads * dt[..., None], bvec)
    new_ssm = state["ssm"] * decay + upd
    y = jnp.einsum("bgrpn,bgn->bgrp", new_ssm, cvec)
    y = y + xheads * p["d_skip"].reshape(g, r)[None, :, :, None]
    y = y.reshape(bsz, di).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["gate_ln"], cfg.norm_eps)
    out = qops.linear(p["out_proj"], y, cfg, mode, lora_leaf=p.get("lora_out"))
    return out, {"ssm": new_ssm, "conv": new_conv_state}
