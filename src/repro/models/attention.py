"""Attention variants: GQA/MQA (full + sliding-window), MLA, encoder.

Full-sequence attention (train / prefill) uses a blockwise streaming-softmax
formulation (flash-attention structure in pure JAX): lax.scan over query
chunks with an inner scan over KV chunks carrying (max, denom, acc). Memory
is O(chunk²) instead of O(S²), which is what makes the 32k prefill and the
4k train cells lower at scale.

Decode uses the two-tier DR KV cache (core/kv_cache.py) — hot early-token
buffer + cold tail — or a ring buffer for sliding-window archs (SWA evicts
early tokens, so DR tiering is N/A there; see DESIGN.md §4). The attention
read itself goes through kernels/flash_decode.py: a streaming online-
softmax Pallas kernel (both tiers merged in one launch, per-slot lengths
predicating the S-blocks) on TPU, with the masked full-capacity XLA path
in core/kv_cache.py as the reference fallback.

MLA (DeepSeek-V3) caches the compressed latent (c_kv ‖ k_rope, 576 B/token)
and decodes in *absorbed* form (W_uk folded into the query, W_uv folded out
of the context) so the per-step cost scales with the latent, not the heads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import kv_cache as kvc
from repro.kernels import flash_decode as fd
from repro.kernels import flash_prefill as fprefill
from repro.models import qops
from repro.models.layers import apply_rope, init_rms_norm, rms_norm

NEG_INF = jnp.finfo(jnp.float32).min
DEFAULT_CHUNK = 512


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention over full sequences
# ---------------------------------------------------------------------------


def _chunk(seq: int, target: int = DEFAULT_CHUNK) -> int:
    """Chunk size for the blockwise scan: the target, capped at the
    sequence. Non-dividing lengths are handled by padding + masking in
    ``blockwise_attention`` — the historical behavior of halving until
    the chunk divides collapsed to chunk=1 for prime/odd lengths (e.g.
    257), turning the scan into a length-S loop of 1-token blocks."""
    return min(seq, target)


def blockwise_attention(
    q: jax.Array,  # (b, g, r, sq, dk)
    k: jax.Array,  # (b, g, sk, dk)
    v: jax.Array,  # (b, g, sk, dv)
    *,
    causal: bool = True,
    window: int = 0,  # 0 = unbounded; else SWA: q_pos - kv_pos < window
    q_offset: int = 0,  # absolute position of q[0] (prefill continuation)
    scale: float | None = None,
    q_chunk: int | None = None,
    kv_chunk: int | None = None,
) -> jax.Array:  # (b, g, r, sq, dv)
    b, g, r, sq, dk = q.shape
    sk, dv = k.shape[2], v.shape[3]
    scale = scale if scale is not None else dk**-0.5
    cq = q_chunk or _chunk(sq)
    ck = kv_chunk or _chunk(sk)
    nq, nk = -(-sq // cq), -(-sk // ck)
    # pad to chunk multiples and mask: padded kv columns are masked out of
    # every row below (k_pos < sk), padded q rows are sliced off the output
    if nq * cq != sq:
        q = jnp.pad(q, ((0, 0),) * 3 + ((0, nq * cq - sq), (0, 0)))
    if nk * ck != sk:
        pad_k = ((0, 0), (0, 0), (0, nk * ck - sk), (0, 0))
        k = jnp.pad(k, pad_k)
        v = jnp.pad(v, pad_k)

    qs = jnp.moveaxis(q.reshape(b, g, r, nq, cq, dk), 3, 0)  # (nq, b,g,r,cq,dk)
    ks = jnp.moveaxis(k.reshape(b, g, nk, ck, dk), 2, 0)  # (nk, b,g,ck,dk)
    vs = jnp.moveaxis(v.reshape(b, g, nk, ck, dv), 2, 0)

    q_pos_base = jnp.arange(cq, dtype=jnp.int32)
    k_pos_base = jnp.arange(ck, dtype=jnp.int32)

    @jax.checkpoint
    def q_step(_, qi_qc):
        # rematerialized per q-chunk: the backward pass recomputes one
        # chunk's inner kv scan at a time instead of stashing the full
        # (nq x nk x cq x ck) attention matrix (observed to dominate temp
        # memory on the train_4k dry-run).
        qi, qc = qi_qc
        q_pos = q_offset + qi * cq + q_pos_base  # (cq,)

        def kv_step(carry, ki_kc):
            ki, kc, vc = ki_kc
            m, l, acc = carry
            k_pos = ki * ck + k_pos_base  # (ck,)
            logits = jnp.einsum(
                "bgrqd,bgkd->bgrqk", qc.astype(jnp.float32), kc.astype(jnp.float32)
            ) * scale
            mask = (k_pos < sk)[None, :] & jnp.ones((cq, 1), dtype=bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            logits = jnp.where(mask, logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            p = jnp.where(mask, p, 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p, vc.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, g, r, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, r, cq), jnp.float32)
        a0 = jnp.zeros((b, g, r, cq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))  # (nq, b,g,r,cq,dv)
    out = jnp.moveaxis(outs, 0, 3).reshape(b, g, r, nq * cq, dv)
    return out[:, :, :, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA / MQA / SWA attention block
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "ln": init_rms_norm(d, dtype),
        "wq": qops.init_linear(ks[0], d, h * hd, dtype),
        "wk": qops.init_linear(ks[1], d, g * hd, dtype),
        "wv": qops.init_linear(ks[2], d, g * hd, dtype),
        "wo": qops.init_linear(ks[3], h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd, dtype)
        p["k_norm"] = init_rms_norm(hd, dtype)
    if cfg.bitnet.lora_rank:
        from repro.core import lora as lora_lib

        if "v" in cfg.bitnet.lora_targets:
            p["lora_v"] = lora_lib.init(ks[4], d, g * hd, cfg.bitnet.lora_rank, dtype)
        if "o" in cfg.bitnet.lora_targets:
            p["lora_o"] = lora_lib.init(ks[5], h * hd, d, cfg.bitnet.lora_rank, dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, mode: str):
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    hidden = rms_norm(x, p["ln"], cfg.norm_eps)
    if "wqkv" in p:
        # fused packed fast path (models/pack.py::fuse_packed): one
        # act-quant + one kernel launch produce q‖k‖v; the v-adapter
        # applies to its segment after the split.
        q, k, v = qops.fused_linear(
            p["wqkv"], hidden, cfg,
            out_shapes=((h, hd), (g, hd), (g, hd)),
            lora_leaves={2: p.get("lora_v")},
        )
    else:
        q = qops.linear(p["wq"], hidden, cfg, mode, out_shape=(h, hd))
        k = qops.linear(p["wk"], hidden, cfg, mode, out_shape=(g, hd))
        v = qops.linear(
            p["wv"], hidden, cfg, mode, out_shape=(g, hd), lora_leaf=p.get("lora_v")
        )
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attention_full(
    p: dict,
    x: jax.Array,  # (b, s, d_model)
    cfg: ModelConfig,
    mode: str,
    positions: jax.Array,  # (s,)
    *,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill). Causal unless encoder."""
    b, s, _ = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(p, x, cfg, mode)  # (b,s,h,hd) / (b,s,g,hd)
    q = apply_rope(q, positions[None], cfg.rope_theta)
    k = apply_rope(k, positions[None], cfg.rope_theta)
    rep = h // g
    qg = jnp.moveaxis(q.reshape(b, s, g, rep, hd), 1, 3)  # (b,g,rep,s,hd)
    kg = jnp.moveaxis(k, 1, 2)  # (b,g,s,hd)
    vg = jnp.moveaxis(v, 1, 2)
    o = blockwise_attention(
        qg,
        kg,
        vg,
        causal=not cfg.is_encoder,
        window=cfg.swa_window if cfg.attn_type == "swa" else 0,
    )  # (b,g,rep,s,hd)
    o = jnp.moveaxis(o, 3, 1).reshape(b, s, h * hd)
    y = qops.linear(p["wo"], o, cfg, mode, lora_leaf=p.get("lora_o"))
    if return_kv:
        return y, (k, v)
    return y


def attention_prefill(
    p: dict,
    x: jax.Array,  # (b, s, d_model) — the whole (aligned) prompt
    cfg: ModelConfig,
    mode: str,
    cache: kvc.TieredKVCache,  # fresh per-layer cache rows (lengths 0)
    impl: str | None = None,
):
    """Full-prompt prefill attention + tiered cache fill for one layer.

    Returns (y, filled_cache). On the Pallas path the flash-prefill
    kernel (kernels/flash_prefill.py) rotates q/k in its prologue,
    streams causal attention with upper-triangle kv blocks skipped, and
    emits the chunk's k/v already cast to the tier storage dtype (fp8
    quantized per block in VMEM) — placement is then the static-slice
    ``kv_cache.fill_fresh``, so the legacy whole-sequence one-hot fill
    pass never runs. The XLA path composes the existing ops
    (``apply_rope`` + ``blockwise_attention``) and fills the same way —
    the two paths produce bit-identical caches.
    """
    b, s, _ = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(p, x, cfg, mode)  # (b,s,h,hd) / (b,s,g,hd)
    impl = impl or qops.resolve_impl(cfg)
    swa = cfg.attn_type == "swa"
    window = cfg.swa_window if swa else 0
    if impl == "pallas":
        o, k_c, v_c = fprefill.flash_prefill_attention(
            q, k, v, None,
            window=window, rope_theta=cfg.rope_theta, emit_kv=True,
            kv_dtype=cache.hot_k.dtype, impl="pallas",
        )
        o = o.reshape(b, s, h * hd)
    else:
        positions = jnp.arange(s, dtype=jnp.int32)[None]
        qr = apply_rope(q, positions, cfg.rope_theta)
        kr = apply_rope(k, positions, cfg.rope_theta)
        rep = h // g
        qg = jnp.moveaxis(qr.reshape(b, s, g, rep, hd), 1, 3)
        o = blockwise_attention(
            qg, jnp.moveaxis(kr, 1, 2), jnp.moveaxis(v, 1, 2),
            causal=True, window=window,
        )
        o = jnp.moveaxis(o, 3, 1).reshape(b, s, h * hd)
        k_c, v_c = kr, v
    cache = kvc.fill_fresh(cache, k_c, v_c, ring=swa)
    y = qops.linear(p["wo"], o, cfg, mode, lora_leaf=p.get("lora_o"))
    return y, cache


def attention_prefill_chunk(
    p: dict,
    x: jax.Array,  # (b, C, d_model) — one prompt chunk per slot
    cfg: ModelConfig,
    mode: str,
    cache: kvc.TieredKVCache,  # live per-layer cache (per-slot lengths)
    n_valid: jax.Array,  # (b,) valid chunk rows; 0 = slot not prefilling
    impl: str | None = None,
    append: bool = True,
):
    """Chunked-prefill continuation for one layer: the C chunk tokens of
    each slot attend to the slot's cached prefix (``cache.lengths``
    tokens, both tiers) plus the causally-earlier rows of the chunk,
    then append their k/v at the slot's offset. Returns (y, cache).

    With ``append=False`` the cache is left untouched and the rotated
    chunk k/v are returned instead: ``(y, (k_c, v_c))``. This is the
    speculative-decoding verify form (serving/engine.py): attention
    never reads the chunk's rows *through* the cache (they stream in
    separately on both impls), so deferring the append until the
    accept/reject decision is known changes no numerics — and it is
    what makes verification safe on ring (SWA) layouts, where an
    append-then-rollback would already have clobbered the oldest
    window rows.

    Every shape is fixed by (slots, C) — per-slot offsets and valid
    counts are data — which is what gives the serving engine its
    one-compile chunked admission (docs/serving.md).
    """
    b, c, _ = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(p, x, cfg, mode)
    impl = impl or qops.resolve_impl(cfg)
    swa = cfg.attn_type == "swa"
    window = cfg.swa_window if swa else 0
    if impl == "pallas":
        o, k_c, v_c = fprefill.flash_prefill_attention(
            q, k, v, cache, valid=n_valid,
            window=window, ring=swa, rope_theta=cfg.rope_theta,
            emit_kv=True, impl="pallas",
        )
    else:
        positions = cache.lengths.astype(jnp.int32)[:, None] + jnp.arange(
            c, dtype=jnp.int32
        )[None]
        qr = apply_rope(q, positions, cfg.rope_theta)
        kr = apply_rope(k, positions, cfg.rope_theta)
        o = kvc.tiered_chunk_attention(
            qr, kr, v, cache, n_valid, window=window, ring=swa
        )
        k_c, v_c = kr, v
    if append:
        cache = kvc.append(cache, k_c, v_c, valid=n_valid, ring=swa)
    y = qops.linear(
        p["wo"], o.reshape(b, c, h * hd), cfg, mode, lora_leaf=p.get("lora_o")
    )
    return y, (cache if append else (k_c, v_c))


def attention_decode(
    p: dict,
    x: jax.Array,  # (b, d_model) — one token per slot
    cfg: ModelConfig,
    mode: str,
    cache: kvc.TieredKVCache,
    active: jax.Array | None = None,  # (b,) bool: slots that really decode
):
    """One decode step against the tiered cache. Returns (y, new_cache).

    RoPE positions come from the per-slot ``cache.lengths``, so slots at
    different sequence lengths decode side by side (continuous batching);
    ``active`` gates the KV append per slot. Attention runs the flash-
    decode fast path (``kernels/flash_decode.py``): on the Pallas impl
    the *fused-RoPE* form — q and the new token's k rotate in the kernel
    prologue, the pending (k, v) joins the softmax stream, and the cache
    append consumes the kernel-rotated k, so no separate XLA
    ``apply_rope`` passes run in the decode step. The XLA impl keeps the
    historical rotate → append → masked full-capacity read pipeline
    (``qops.resolve_impl`` — the same dispatch rule as the packed
    matmuls).
    """
    b, _ = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(p, x[:, None, :], cfg, mode)  # (b,1,h,hd)
    impl = qops.resolve_impl(cfg)
    swa = cfg.attn_type == "swa"
    if impl == "pallas":
        entry = fd.flash_decode_attention_ring if swa else fd.flash_decode_attention
        o, k_rot = entry(
            q[:, 0], cache, impl=impl,
            k_new=k[:, 0], v_new=v[:, 0], active=active,
            rope_theta=cfg.rope_theta,
        )
        app = kvc.append_decode_ring if swa else kvc.append_decode
        cache = app(cache, k_rot, v[:, 0], active=active)
    else:
        pos = cache.lengths[:, None]  # (b, 1) per-slot absolute position
        q = apply_rope(q, pos, cfg.rope_theta)[:, 0]  # (b,h,hd)
        k = apply_rope(k, pos, cfg.rope_theta)[:, 0]  # (b,g,hd)
        v = v[:, 0]
        if swa:
            cache = kvc.append_decode_ring(cache, k, v, active=active)
            o = fd.flash_decode_attention_ring(q, cache, impl=impl)
        else:
            cache = kvc.append_decode(cache, k, v, active=active)
            o = fd.flash_decode_attention(q, cache, impl=impl)
    y = qops.linear(
        p["wo"], o.reshape(b, h * hd), cfg, mode, lora_leaf=p.get("lora_o")
    )
    return y, cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): compressed-latent attention
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "ln": init_rms_norm(d, dtype),
        "w_dq": qops.init_linear(ks[0], d, m.q_lora_rank, dtype),
        "q_ln": init_rms_norm(m.q_lora_rank, dtype),
        "w_uq": qops.init_linear(ks[1], m.q_lora_rank, h * qk_head, dtype),
        "w_dkv": qops.init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_ln": init_rms_norm(m.kv_lora_rank, dtype),
        # factor matrices stay dict-leaves (fake-quant ternary) — DESIGN.md §2
        "w_uk": qops.init_linear(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim, dtype),
        "w_uv": qops.init_linear(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "wo": qops.init_linear(ks[5], h * m.v_head_dim, d, dtype),
    }
    if cfg.bitnet.lora_rank:
        from repro.core import lora as lora_lib

        if "v" in cfg.bitnet.lora_targets:
            p["lora_v"] = lora_lib.init(
                ks[6], m.kv_lora_rank, h * m.v_head_dim, cfg.bitnet.lora_rank, dtype
            )
        if "o" in cfg.bitnet.lora_targets:
            p["lora_o"] = lora_lib.init(
                ks[7], h * m.v_head_dim, d, cfg.bitnet.lora_rank, dtype
            )
    return p


def _mla_down(p, hidden, cfg: ModelConfig, mode):
    """Both MLA down-projections of the shared hidden: -> (dq, dkv).

    With the pack-time-fused leaf (models/pack.py: w_dq‖w_dkv ->
    "w_dqkv") this is ONE act-quant + ONE kernel launch; the per-branch
    norms (q_ln on dq, kv_ln on the latent half of dkv) interleave AFTER
    the split, in ``_mla_queries`` / ``_mla_latent``, so fused == separate
    bit-for-bit.
    """
    if "w_dqkv" in p:
        return qops.fused_linear(p["w_dqkv"], hidden, cfg)
    return (
        qops.linear(p["w_dq"], hidden, cfg, mode),
        qops.linear(p["w_dkv"], hidden, cfg, mode),
    )


def _mla_queries(p, dq, cfg: ModelConfig, mode, positions):
    """dq (b,t,q_rank) -> q_nope (b,t,h,dn), q_rope (b,t,h,dr) with RoPE.

    ``positions`` is batch-broadcastable: (1, s) for a shared full
    sequence, (b, 1) for per-slot decode positions.
    """
    m, h = cfg.mla, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = rms_norm(dq, p["q_ln"], cfg.norm_eps)
    q = qops.linear(p["w_uq"], cq, cfg, mode, out_shape=(h, qk_head))
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, dkv, cfg: ModelConfig, positions):
    """dkv (b,t,dl+dr) -> latent c_kv (b,t,dl) [normed], k_rope with RoPE.

    ``positions`` is batch-broadcastable, as in ``_mla_queries``.
    """
    m = cfg.mla
    c_kv = rms_norm(dkv[..., : m.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = apply_rope(
        dkv[..., m.kv_lora_rank :][:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]
    return c_kv, k_rope


def mla_full(p, x, cfg: ModelConfig, mode, positions, *, return_kv: bool = False):
    """Full-sequence MLA (non-absorbed): expand K/V per position once."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    hidden = rms_norm(x, p["ln"], cfg.norm_eps)
    dq, dkv = _mla_down(p, hidden, cfg, mode)
    q_nope, q_rope = _mla_queries(p, dq, cfg, mode, positions[None])
    c_kv, k_rope = _mla_latent(p, dkv, cfg, positions[None])
    k_nope = qops.linear(p["w_uk"], c_kv, cfg, mode, out_shape=(h, m.qk_nope_head_dim))
    v = qops.linear(
        p["w_uv"], c_kv, cfg, mode, out_shape=(h, m.v_head_dim), lora_leaf=p.get("lora_v")
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)  # (b,s,h,dn+dr)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.qk_rope_head_dim))],
        axis=-1,
    )
    qg = jnp.moveaxis(q, 1, 2)[:, :, None]  # (b,h,1,s,d) g=h, rep=1
    kg = jnp.moveaxis(k, 1, 2)
    vg = jnp.moveaxis(v, 1, 2)
    o = blockwise_attention(qg, kg, vg, causal=not cfg.is_encoder)[:, :, 0]
    o = jnp.moveaxis(o, 1, 2).reshape(b, s, h * m.v_head_dim)
    y = qops.linear(p["wo"], o, cfg, mode, lora_leaf=p.get("lora_o"))
    if return_kv:
        # cache the latent: k-slot = (c_kv ‖ k_rope), v-slot is empty (0-dim)
        lat = jnp.concatenate([c_kv, k_rope], axis=-1)
        return y, (lat, jnp.zeros(lat.shape[:-1] + (0,), lat.dtype))
    return y


def mla_prefill(p, x, cfg: ModelConfig, mode, cache: kvc.TieredKVCache,
                impl: str | None = None):
    """Full-prompt MLA prefill + latent cache fill for one layer.

    The Pallas path runs the flash-prefill kernel attention-only
    (``emit_kv=False``, ``rope_dims`` = the rope head dims): the per-head
    (nope ‖ rope) k materializes *unrotated* and both q_rope and k_rope
    rotate in the kernel prologue. The cached row is the latent
    (c_kv ‖ k_rope) — not the per-head k — so the fill rotates the shared
    (b, s, dr) rope vector once outside (negligible next to the (b, s,
    h, ·) tensors the kernel no longer needs pre-rotated) and places it
    with the static-slice ``fill_fresh``. The XLA path delegates to
    ``mla_full``; both fill bit-identical caches.
    """
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    impl = impl or qops.resolve_impl(cfg)
    positions = jnp.arange(s, dtype=jnp.int32)
    if impl != "pallas":
        y, (lat, v_empty) = mla_full(p, x, cfg, mode, positions, return_kv=True)
        return y, kvc.fill_fresh(cache, lat, v_empty)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    hidden = rms_norm(x, p["ln"], cfg.norm_eps)
    dq, dkv = _mla_down(p, hidden, cfg, mode)
    # same per-branch norms as _mla_queries/_mla_latent, minus their RoPE
    cq = rms_norm(dq, p["q_ln"], cfg.norm_eps)
    q = qops.linear(p["w_uq"], cq, cfg, mode, out_shape=(h, qk_head))
    c_kv = rms_norm(dkv[..., : m.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope_raw = dkv[..., m.kv_lora_rank:]  # (b, s, dr) UNROTATED
    k_nope = qops.linear(p["w_uk"], c_kv, cfg, mode, out_shape=(h, m.qk_nope_head_dim))
    v = qops.linear(
        p["w_uv"], c_kv, cfg, mode, out_shape=(h, m.v_head_dim),
        lora_leaf=p.get("lora_v"),
    )
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(
            k_rope_raw[:, :, None, :], (b, s, h, m.qk_rope_head_dim))],
        axis=-1,
    )
    o = fprefill.flash_prefill_attention(
        q, k, v, None, rope_theta=cfg.rope_theta,
        rope_dims=m.qk_rope_head_dim, emit_kv=False, impl="pallas",
    )  # (b, s, h, v_head_dim)
    y = qops.linear(
        p["wo"], o.reshape(b, s, h * m.v_head_dim), cfg, mode,
        lora_leaf=p.get("lora_o"),
    )
    k_rope = apply_rope(
        k_rope_raw[:, :, None, :], positions[None], cfg.rope_theta
    )[:, :, 0]
    lat = jnp.concatenate([c_kv, k_rope], axis=-1)
    return y, kvc.fill_fresh(
        cache, lat, jnp.zeros(lat.shape[:-1] + (0,), lat.dtype)
    )


def mla_decode(p, x, cfg: ModelConfig, mode, cache: kvc.TieredKVCache,
               active: jax.Array | None = None):
    """Absorbed-form MLA decode over the tiered latent cache.

    Per-slot positions from ``cache.lengths``; ``active`` gates the latent
    append per slot (continuous batching).
    """
    m, h = cfg.mla, cfg.n_heads
    b, _ = x.shape
    hidden = rms_norm(x[:, None, :], p["ln"], cfg.norm_eps)
    pos = cache.lengths[:, None]  # (b, 1)
    dq, dkv = _mla_down(p, hidden, cfg, mode)
    q_nope, q_rope = _mla_queries(p, dq, cfg, mode, pos)  # (b,1,h,·)
    c_kv, k_rope = _mla_latent(p, dkv, cfg, pos)
    lat_new = jnp.concatenate([c_kv, k_rope], axis=-1)[:, 0]  # (b, dl+dr)
    cache = kvc.append_decode(cache, lat_new, jnp.zeros((b, 0), lat_new.dtype),
                              active=active)

    # absorb W_uk into the query: q_abs = q_nope @ W_uk^T  (per head)
    w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    from repro.core.ternary import act_quant_ste, weight_quant_ste

    quant = cfg.bitnet.enabled and mode != "none"
    w_uk_q = weight_quant_ste(w_uk) if quant else w_uk
    q_abs = jnp.einsum("bhn,lhn->bhl", q_nope[:, 0], w_uk_q)  # (b,h,dl)
    q_full = jnp.concatenate([q_abs, q_rope[:, 0]], axis=-1)  # (b,h,dl+dr)

    # fake-quantize the cached latent exactly as the non-absorbed path does
    # when it feeds c_kv through the W_uk/W_uv BitLinears (keeps absorbed ==
    # non-absorbed numerics; rope dims are never act-quantized).
    if quant:

        def _q(buf):
            if buf.shape[1] == 0:
                return buf
            ckv = act_quant_ste(buf[..., : m.kv_lora_rank], bits=cfg.bitnet.act_bits)
            return jnp.concatenate([ckv, buf[..., m.kv_lora_rank :]], axis=-1)

        att_cache = cache._replace(hot_k=_q(cache.hot_k), cold_k=_q(cache.cold_k))
    else:
        att_cache = cache

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    ctx = fd.flash_decode_attention_latent(
        q_full, att_cache, value_dim=m.kv_lora_rank, scale=scale,
        impl=qops.resolve_impl(cfg),
    )  # (b,h,dl)

    w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    w_uv_q = weight_quant_ste(w_uv) if cfg.bitnet.enabled and mode != "none" else w_uv
    o = jnp.einsum("bhl,lhv->bhv", ctx, w_uv_q).reshape(b, h * m.v_head_dim)
    y = qops.linear(p["wo"], o.astype(x.dtype), cfg, mode, lora_leaf=p.get("lora_o"))
    return y, cache
