"""Freeze trained (or initialized) params into ROM form: packed ternary.

``pack_params`` walks the parameter tree and converts every quantizable
projection leaf {"w": float (…, K, N)} into a ``PackedLinear`` (uint8 trits
+ per-tensor absmean scale). Leading stack dims (layer scan, experts) are
vmapped through the codec. This is the moment the paper fabricates the ROM:
after it, inference never touches a float weight for these projections.

Not packed (and why):
  * embed / lm_head / frontend — BitNet keeps them high-precision;
  * router — routing accuracy is precision-sensitive and it is tiny;
  * MLA factor matrices (w_uk/w_uv) — consumed in absorbed per-head form,
    kept fake-quant ternary (same numerics, bf16 storage; ~0.3% of weights);
  * norms / conv / SSM scalars / LoRA (LoRA is SRAM, 6-bit, by design).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import packing
from repro.core.bitlinear import PackedLinear
from repro.core.ternary import EPS

PACK_KEYS = {
    "wq", "wk", "wv", "wo",  # attention
    "gate", "up", "down",  # mlp
    "w_gate", "w_up", "w_down",  # experts
    "shared_gate", "shared_up", "shared_down",  # shared experts
    "in_proj", "out_proj",  # mamba
    "w_dq", "w_uq", "w_dkv",  # MLA down/up projections (2-D use)
}
SKIP_KEYS = {"embed", "lm_head", "frontend", "router", "w_uk", "w_uv"}


def _pack_weight(w: jax.Array, codec: str) -> PackedLinear:
    """w: (..., K, N) float -> PackedLinear with leading dims vmapped."""
    lead = w.ndim - 2
    k = w.shape[-2]

    def pack_one(w2):
        scale = jnp.maximum(jnp.mean(jnp.abs(w2.astype(jnp.float32))), EPS)
        trits = jnp.clip(jnp.round(w2.astype(jnp.float32) / scale), -1, 1).astype(jnp.int8)
        pack = packing.pack2 if codec == "pack2" else packing.pack243
        return pack(trits), scale

    fn = pack_one
    for _ in range(lead):
        fn = jax.vmap(fn)
    packed, scale = fn(w)
    return PackedLinear(packed=packed, scale=scale, k=k, codec=codec)


def pack_params(params, cfg: ModelConfig, codec: str | None = None):
    """Convert a QAT parameter tree to the packed-inference tree."""
    from repro.core.bitlinear import quantize_int8

    codec = codec or cfg.bitnet.codec

    def walk(tree, path=()):
        if isinstance(tree, dict):
            if set(tree.keys()) == {"w"} and path and str(path[-1]) in PACK_KEYS:
                if not cfg.bitnet.enabled:
                    return tree
                return _pack_weight(tree["w"], codec)
            if (
                cfg.bitnet.embed_int8
                and set(tree.keys()) == {"w"}
                and path
                and str(path[-1]) in ("embed", "lm_head")
            ):
                # embed (V, d): per-row scale; lm_head (d, V): per-column
                axis = 1 if str(path[-1]) == "embed" else 0
                return quantize_int8(tree["w"], axis=axis)
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return tree

    return walk(params)


def packed_param_bytes(packed_tree) -> dict:
    """HBM ledger: packed trit bytes vs residual float bytes."""
    packed_b, float_b = 0, 0
    for leaf in jax.tree.leaves(
        packed_tree, is_leaf=lambda x: isinstance(x, PackedLinear)
    ):
        if isinstance(leaf, PackedLinear):
            packed_b += leaf.packed.size + 4 * leaf.scale.size
        else:
            packed_b += 0
    for leaf in jax.tree.leaves(packed_tree):
        if leaf.dtype != jnp.uint8:
            float_b += leaf.size * leaf.dtype.itemsize
    return {"packed_bytes": packed_b, "other_bytes": float_b}
