"""Freeze trained (or initialized) params into ROM form: packed ternary.

``pack_params`` walks the parameter tree and converts every quantizable
projection leaf {"w": float (…, K, N)} into a ``PackedLinear`` (uint8 trits
+ per-tensor absmean scale). Leading stack dims (layer scan, experts) are
vmapped through the codec. This is the moment the paper fabricates the ROM:
after it, inference never touches a float weight for these projections.

A second pass (``cfg.bitnet.fuse_proj``, on by default) merges sibling
projections that consume the same input into one ``FusedPackedLinear``
via ``fuse_packed``: wq‖wk‖wv -> "wqkv", gate‖up -> "wgu",
shared_gate‖shared_up -> "shared_gu", MLA w_dq‖w_dkv -> "w_dqkv" (the
per-branch norms q_ln / kv_ln apply to the segments *after* the split, so
the shared-input projection itself fuses cleanly), and per-expert
w_gate‖w_up -> "w_gu" (expert-stacked: the leading E dim passes through
the codec and the fused leaf feeds the E-loop expert kernel — one launch
over all experts AND both GLU halves). One act-quant + one kernel launch
then serves the whole group, and the in-VMEM trit decode of each K tile is
amortized across 3x (resp. 2x) more output columns. Segment scales stay
exact: the fused leaf carries a per-column scale vector.

Callers consume fused leaves by name ("wqkv" in attention._project_qkv,
"wgu" in layers.apply_mlp, "w_dqkv"/"w_gu"/"shared_gu" in attention/moe);
trees packed with ``fuse=False`` keep the original per-projection names —
that is what the launch/dry-run path relies on (see ``pack_params``).

Not packed (and why):
  * embed / lm_head / frontend — BitNet keeps them high-precision;
  * router — routing accuracy is precision-sensitive and it is tiny;
  * MLA factor matrices (w_uk/w_uv) — consumed in absorbed per-head form,
    kept fake-quant ternary (same numerics, bf16 storage; ~0.3% of weights);
  * norms / conv / SSM scalars / LoRA (LoRA is SRAM, 6-bit, by design).

Not fused (and why):
  * w_down / shared_down / wo / out_proj — they consume a *different*
    input (the GLU product / attention context), so there is no shared
    act-quant to amortize and nothing to concatenate along N;
  * w_uq — consumes the q_ln-normed dq segment, not the shared hidden.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import packing
from repro.core.bitlinear import FusedPackedLinear, PackedLinear
from repro.core.ternary import EPS

PACK_KEYS = {
    "wq", "wk", "wv", "wo",  # attention
    "gate", "up", "down",  # mlp
    "w_gate", "w_up", "w_down",  # experts
    "shared_gate", "shared_up", "shared_down",  # shared experts
    "in_proj", "out_proj",  # mamba
    "w_dq", "w_uq", "w_dkv",  # MLA down/up projections (2-D use)
}
SKIP_KEYS = {"embed", "lm_head", "frontend", "router", "w_uk", "w_uv"}


def _pack_weight(w: jax.Array, codec: str) -> PackedLinear:
    """w: (..., K, N) float -> PackedLinear with leading dims vmapped."""
    lead = w.ndim - 2
    k = w.shape[-2]

    def pack_one(w2):
        scale = jnp.maximum(jnp.mean(jnp.abs(w2.astype(jnp.float32))), EPS)
        trits = jnp.clip(jnp.round(w2.astype(jnp.float32) / scale), -1, 1).astype(jnp.int8)
        pack = packing.pack2 if codec == "pack2" else packing.pack243
        return pack(trits), scale

    fn = pack_one
    for _ in range(lead):
        fn = jax.vmap(fn)
    packed, scale = fn(w)
    return PackedLinear(packed=packed, scale=scale, k=k, codec=codec)


# Same-input sibling projections merged by the fusion pass (order matters:
# it fixes the segment order of the fused output splits).
FUSE_GROUPS = (
    (("wq", "wk", "wv"), "wqkv"),
    (("gate", "up"), "wgu"),
    (("shared_gate", "shared_up"), "shared_gu"),
    # MLA down-projections: both consume the attention-ln hidden; the
    # interleaved per-branch norms (q_ln / kv_ln) apply post-split.
    (("w_dq", "w_dkv"), "w_dqkv"),
    # expert-stacked (E, ...) leaves: fuse_packed passes the leading E dim
    # through; the fused leaf runs on the E-loop expert kernel.
    (("w_gate", "w_up"), "w_gu"),
)


def fuse_packed(pws: Sequence[PackedLinear]) -> FusedPackedLinear:
    """Concatenate same-K PackedLinears along N into one fused projection.

    Per-tensor absmean scales become a per-column scale vector (each
    segment's scalar repeated over its width), so the fused epilogue
    rescale is bit-for-bit the same as the per-projection rescales.
    Leading stack dims (layer scan) pass straight through.
    """
    k, codec = pws[0].k, pws[0].codec
    assert all(pw.k == k and pw.codec == codec for pw in pws), [
        (pw.k, pw.codec) for pw in pws
    ]
    splits = tuple(int(pw.packed.shape[-1]) for pw in pws)
    packed = jnp.concatenate([pw.packed for pw in pws], axis=-1)
    cols = []
    for pw, w in zip(pws, splits):
        s = jnp.asarray(pw.scale, jnp.float32)
        cols.append(jnp.broadcast_to(s[..., None], s.shape + (w,)))
    scale = jnp.concatenate(cols, axis=-1)
    fused = FusedPackedLinear(packed=packed, scale=scale, k=k, codec=codec,
                              splits=splits)
    if all(pw.wsum is not None for pw in pws):
        # per-segment wsum vectors are already scale-weighted row-sums,
        # so the fused checksum is their plain sum; the crc re-covers
        # the concatenated words (segment crcs don't compose)
        fused = dataclasses.replace(
            fused,
            wsum=sum(jnp.asarray(pw.wsum, jnp.float32) for pw in pws),
            crc=packing.packed_crc32(packed),
        )
    return fused


def _fuse_tree(tree):
    """Bottom-up pass replacing FUSE_GROUPS siblings with fused leaves."""
    if not isinstance(tree, dict):
        return tree
    out = {k: _fuse_tree(v) for k, v in tree.items()}
    for keys, fused_name in FUSE_GROUPS:
        members = [out.get(kk) for kk in keys]
        if not all(isinstance(m, PackedLinear) for m in members):
            continue
        if len({(m.k, m.codec) for m in members}) != 1:
            continue
        if any(m.packed.ndim != members[0].packed.ndim for m in members):
            continue
        for kk in keys:
            del out[kk]
        out[fused_name] = fuse_packed(members)
    return out


def pack_params(params, cfg: ModelConfig, codec: str | None = None,
                fuse: bool | None = None, integrity: bool = False):
    """Convert a QAT parameter tree to the packed-inference tree.

    Inputs: a (possibly nested) dict tree whose quantizable projection
    leaves are ``{"w": float (..., K, N)}`` under the names in
    ``PACK_KEYS``. Output: the same tree with those leaves replaced by
    ``PackedLinear`` (and, when ``fuse``, sibling groups collapsed into
    ``FusedPackedLinear`` under the fused names in ``FUSE_GROUPS``); all
    other leaves pass through untouched.

    ``fuse`` (default: ``cfg.bitnet.fuse_proj``) controls the fused-
    projection pass (wqkv / wgu / shared_gu / w_dqkv / w_gu); see the
    module docstring. The launch/dry-run path packs with ``fuse=False``
    on purpose: its GSPMD sharding rules are keyed on the ORIGINAL
    per-projection names (launch/sharding.py), and a hand-written fused
    kernel would block GSPMD propagation — sharded lowering runs the XLA
    impl over unfused leaves. Do not flip that default without mirroring
    the fused names into the sharding-rule table.

    ``integrity=True`` additionally stamps every packed leaf with ABFT
    wsum + crc32 metadata (see ``add_integrity``) — what the serving
    SDC scrub verifies against. Off by default: the metadata adds a
    pytree leaf, and structure-sensitive consumers (sharding-rule
    zips) that predate it should opt in explicitly.
    """
    from repro.core.bitlinear import quantize_int8

    codec = codec or cfg.bitnet.codec
    fuse = cfg.bitnet.fuse_proj if fuse is None else fuse

    def walk(tree, path=()):
        if isinstance(tree, dict):
            if set(tree.keys()) == {"w"} and path and str(path[-1]) in PACK_KEYS:
                if not cfg.bitnet.enabled:
                    return tree
                pw = _pack_weight(tree["w"], codec)
                return _stamp_integrity(pw) if integrity else pw
            if (
                cfg.bitnet.embed_int8
                and set(tree.keys()) == {"w"}
                and path
                and str(path[-1]) in ("embed", "lm_head")
            ):
                # embed (V, d): per-row scale; lm_head (d, V): per-column
                axis = 1 if str(path[-1]) == "embed" else 0
                return quantize_int8(tree["w"], axis=axis)
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return tree

    packed = walk(params)
    if fuse and cfg.bitnet.enabled:
        packed = _fuse_tree(packed)
    return packed


# ---------------------------------------------------------------------------
# SDC integrity metadata (serving/sdc.py — docs/serving.md "Fault model")
# ---------------------------------------------------------------------------


def _stamp_integrity(pw):
    """Return ``pw`` with ABFT wsum + crc32 metadata computed from its
    OWN packed words (the "fab" reference the serving scrub re-verifies
    against). Idempotent in effect: re-stamping a clean leaf reproduces
    the same metadata."""
    from repro.kernels.ternary_matmul import abft_wsum

    return dataclasses.replace(
        pw,
        wsum=abft_wsum(pw.packed, pw.k, pw.codec,
                       jnp.asarray(pw.scale, jnp.float32)),
        crc=packing.packed_crc32(pw.packed),
    )


def iter_packed_leaves(packed_tree) -> Iterator[Tuple[str, object]]:
    """Yield ``(dotted_path, leaf)`` for every Packed/FusedPackedLinear
    in the tree, in deterministic (sorted-key) order — the enumeration
    the fault injectors and the weight scrub share, so "leaf i" means
    the same tensor to both."""

    def walk(tree, path):
        if isinstance(tree, (PackedLinear, FusedPackedLinear)):
            yield ".".join(path), tree
        elif isinstance(tree, dict):
            for key in sorted(tree):
                yield from walk(tree[key], path + (str(key),))

    yield from walk(packed_tree, ())


def add_integrity(packed_tree):
    """Stamp ABFT wsum + crc32 metadata onto every packed leaf that
    lacks it (leaves already stamped pass through). Structure-preserving
    for everything else; use on trees packed with ``integrity=False``
    (e.g. before handing them to ``Engine(integrity=...)``)."""
    if isinstance(packed_tree, (PackedLinear, FusedPackedLinear)):
        if packed_tree.crc is None:
            return _stamp_integrity(packed_tree)
        return packed_tree
    if isinstance(packed_tree, dict):
        return {k: add_integrity(v) for k, v in packed_tree.items()}
    return packed_tree


def verify_packed(packed_tree) -> List[str]:
    """Re-crc every stamped packed leaf against its pack-time crc32 and
    return the dotted paths that mismatch (empty list = clean). This is
    the EXACT weight-integrity check — it catches flips the ABFT
    row-sum check cannot see (rows whose activations quantize to zero).
    Leaves without a crc stamp are skipped, not failed."""
    bad = []
    for path, pw in iter_packed_leaves(packed_tree):
        if pw.crc is not None and packing.packed_crc32(pw.packed) != pw.crc:
            bad.append(path)
    return bad


def packed_param_bytes(packed_tree) -> dict:
    """HBM ledger: packed trit bytes (trits + their scales) vs residual
    float bytes. One walk so scale arrays are counted exactly once."""
    packed_b, float_b = 0, 0
    is_packed = lambda x: isinstance(x, (PackedLinear, FusedPackedLinear))  # noqa: E731
    for leaf in jax.tree.leaves(packed_tree, is_leaf=is_packed):
        if is_packed(leaf):
            packed_b += leaf.packed.size + 4 * leaf.scale.size
        elif leaf.dtype != jnp.uint8:
            float_b += leaf.size * leaf.dtype.itemsize
    return {"packed_bytes": packed_b, "other_bytes": float_b}
