"""Top-level model: init / forward / prefill / decode for every family.

Families (DESIGN.md §4):
  dense          — decoder LM (qwen3, deepseek-coder, gemma)
  moe            — decoder LM with MoE FFNs (mixtral, deepseek-v3 incl. MLA)
  ssm            — attention-free Mamba2 stack (mamba2-130m)
  hybrid         — Zamba2: groups of Mamba2 blocks + one *shared* attention
                   block (single param set, per-invocation LoRA)
  audio          — encoder-only (hubert): bidirectional attention, stub
                   frame-embedding frontend, no decode
  vlm            — llava: stub patch-embedding frontend concatenated with
                   text embeddings, then a dense decoder

Uniform layers are stacked and scanned (lax.scan over stacked params) so
the HLO stays O(1) in depth — essential for compiling 61-layer 671B
configs on the 512-device dry-run mesh. Blocks are rematerialized
(jax.checkpoint) in training mode.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import kv_cache as kvc
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import qops
from repro.models import shard_ctx
from repro.models import ssm as ssm_lib
from repro.models.layers import apply_mlp, init_mlp, init_rms_norm, rms_norm

DEFAULT_HOT_CAP = 32  # paper: 32 buffered early tokens (S=128 edge case)


# ---------------------------------------------------------------------------
# Block init/apply per family
# ---------------------------------------------------------------------------


def _init_attn_block(key, cfg: ModelConfig, dtype, d_ff=None) -> dict:
    k1, k2 = jax.random.split(key)
    init_a = attn.init_mla if cfg.attn_type == "mla" else attn.init_attention
    return {"attn": init_a(k1, cfg, dtype), "mlp": init_mlp(k2, cfg, d_ff, dtype)}


def _init_moe_block(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    init_a = attn.init_mla if cfg.attn_type == "mla" else attn.init_attention
    return {"attn": init_a(k1, cfg, dtype), "moe": moe_lib.init_moe(k2, cfg, dtype)}


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 12)
    d = cfg.d_model
    params: dict = {
        "embed": {"w": jax.random.normal(keys[0], (cfg.vocab_size, d), dtype) * 0.02},
        "final_ln": init_rms_norm(d, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = qops.init_linear(keys[1], d, cfg.vocab_size, dtype)

    if cfg.frontend == "audio":
        params["frontend"] = qops.init_linear(keys[2], cfg.frontend_dim, d, dtype)
    elif cfg.frontend == "vision":
        k1, k2 = jax.random.split(keys[2])
        params["frontend"] = {
            "proj1": qops.init_linear(k1, cfg.frontend_dim, d, dtype),
            "proj2": qops.init_linear(k2, d, d, dtype),
        }

    if cfg.family in ("dense", "audio", "vlm"):
        params["blocks"] = _stack_init(
            lambda k: _init_attn_block(k, cfg, dtype), keys[3], cfg.n_layers
        )
    elif cfg.family == "moe":
        nd = cfg.moe.n_dense_layers
        if nd:
            dff = cfg.moe.d_ff_dense or cfg.d_ff
            params["dense_blocks"] = _stack_init(
                lambda k: _init_attn_block(k, cfg, dtype, d_ff=dff), keys[3], nd
            )
        params["moe_blocks"] = _stack_init(
            lambda k: _init_moe_block(k, cfg, dtype), keys[4], cfg.n_layers - nd
        )
    elif cfg.family == "ssm":
        params["blocks"] = _stack_init(
            lambda k: ssm_lib.init_mamba_block(k, cfg, dtype), keys[3], cfg.n_layers
        )
    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        n_tail = cfg.n_layers - n_groups * every
        params["mamba_groups"] = jax.vmap(
            lambda k: _stack_init(
                lambda kk: ssm_lib.init_mamba_block(kk, cfg, dtype), k, every
            )
        )(jax.random.split(keys[3], n_groups))
        if n_tail:
            params["mamba_tail"] = _stack_init(
                lambda k: ssm_lib.init_mamba_block(k, cfg, dtype), keys[5], n_tail
            )
        # ONE shared attention+MLP block (Zamba2) + per-invocation LoRA
        params["shared"] = _init_attn_block(keys[6], cfg, dtype)
        if cfg.bitnet.lora_rank:
            from repro.core import lora as lora_lib

            g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
            params["shared_lora_v"] = jax.vmap(
                lambda k: lora_lib.init(k, d, g * hd, cfg.bitnet.lora_rank, dtype)
            )(jax.random.split(keys[7], n_groups))
    else:  # pragma: no cover
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# Embedding / frontend
# ---------------------------------------------------------------------------


def _embed_tokens(params, cfg: ModelConfig, tokens: jax.Array, dtype) -> jax.Array:
    from repro.core.bitlinear import Int8Linear

    emb = params["embed"]
    if isinstance(emb, Int8Linear):  # int8 rows + per-row scale
        x = (
            jnp.take(emb.q, tokens, axis=0).astype(jnp.float32)
            * jnp.take(emb.scale, tokens, axis=0)
        ).astype(dtype)
    else:
        x = jnp.take(emb["w"], tokens, axis=0).astype(dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, dtype)
    return x


def _frontend_embed(params, cfg: ModelConfig, feats: jax.Array, mode: str) -> jax.Array:
    if cfg.frontend == "audio":
        return qops.linear(params["frontend"], feats, cfg, mode)
    # vision: 2-layer MLP projector (llava)
    h = jax.nn.gelu(qops.linear(params["frontend"]["proj1"], feats, cfg, mode))
    return qops.linear(params["frontend"]["proj2"], h, cfg, mode)


def _lm_logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    from repro.core.bitlinear import Int8Linear

    if cfg.tie_embeddings:
        emb = params["embed"]
        if isinstance(emb, Int8Linear):
            logits = (x @ emb.q.T.astype(x.dtype)).astype(jnp.float32)
            return logits * emb.scale[:, 0][None]  # per-row scale -> per-col
        return (x @ emb["w"].T.astype(x.dtype)).astype(jnp.float32)
    head = params["lm_head"]
    if isinstance(head, Int8Linear):
        logits = (x @ head.q.astype(x.dtype)).astype(jnp.float32)
        return logits * head.scale  # (1, V) per-column scale
    return qops.linear(head, x, cfg, "none", quantize=False).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill body)
# ---------------------------------------------------------------------------


def _attn_block_fwd(bp, x, cfg, mode, positions, return_kv=False):
    f = attn.mla_full if cfg.attn_type == "mla" else attn.attention_full
    if return_kv:
        y, kv = f(bp["attn"], x, cfg, mode, positions, return_kv=True)
    else:
        y, kv = f(bp["attn"], x, cfg, mode, positions), None
    x = x + y
    if "moe" in bp:
        h, aux = moe_lib.apply_moe(bp["moe"], x, cfg, mode)
    else:
        h, aux = apply_mlp(bp["mlp"], x, cfg, mode), 0.0
    return x + h, aux, kv


def _sp(x):
    """Sequence-parallel residual-stream constraint (no-op without hints).

    Between blocks the hidden state lives (batch->data, seq->model, d) —
    Megatron-SP: the row-parallel projections' partial sums reduce-scatter
    onto the sequence axis instead of all-reducing, and norms run on 1/TP
    of the tokens. Only applied to 3-D full-sequence activations.
    """
    if x.ndim == 3 and shard_ctx.active():
        return shard_ctx.constrain(x, "BATCH", "SEQ", None)
    return x


def _scan_stack(fn, x, stacked, remat: bool):
    body = jax.checkpoint(fn) if remat else fn

    def step(carry, bp):
        h, aux = carry
        h2, aux2 = body(h, bp)
        return (_sp(h2), aux + aux2), None

    (x, aux), _ = jax.lax.scan(step, (_sp(x), jnp.zeros((), jnp.float32)), stacked)
    return x, aux


def _scan_stack_collect(fn, x, stacked, remat: bool):
    """Like _scan_stack but also stacks each layer's extra output (e.g. KV)."""
    body = jax.checkpoint(fn) if remat else fn

    def step(carry, bp):
        h, aux = carry
        h2, aux2, extra = body(h, bp)
        return (_sp(h2), aux + aux2), extra

    (x, aux), extras = jax.lax.scan(step, (_sp(x), jnp.zeros((), jnp.float32)), stacked)
    return x, aux, extras


def forward(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    mode: str = "qat",
    remat: bool = True,
    collect_kv: bool = False,
):
    """Full-sequence forward. Returns (logits_f32, aux_loss[, kv_stacks]).

    batch: {"tokens": (b,s)} and/or {"frames"/"patches": features}.
    """
    dtype = params["final_ln"].dtype
    kv_out: dict = {}

    if cfg.family == "audio":
        x = _frontend_embed(params, cfg, batch["frames"].astype(dtype), mode)
    elif cfg.family == "vlm":
        patches = _frontend_embed(params, cfg, batch["patches"].astype(dtype), mode)
        text = _embed_tokens(params, cfg, batch["tokens"], dtype)
        x = jnp.concatenate([patches, text], axis=1)
    else:
        x = _embed_tokens(params, cfg, batch["tokens"], dtype)

    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)

    if cfg.family in ("dense", "audio", "vlm"):
        if collect_kv:
            fn = lambda h, bp: _attn_block_fwd(bp, h, cfg, mode, positions, True)  # noqa: E731
            x, aux, kvs = _scan_stack_collect(fn, x, params["blocks"], remat)
            kv_out["attn"] = kvs  # (L, 2-tuple of (b,s,g,hd))
        else:
            fn = lambda h, bp: _attn_block_fwd(bp, h, cfg, mode, positions)[:2]  # noqa: E731
            x, aux = _scan_stack(fn, x, params["blocks"], remat)
    elif cfg.family == "moe":
        aux = jnp.zeros((), jnp.float32)
        for name in ("dense_blocks", "moe_blocks"):
            if name not in params:
                continue
            if collect_kv:
                fn = lambda h, bp: _attn_block_fwd(bp, h, cfg, mode, positions, True)  # noqa: E731
                x, a2, kvs = _scan_stack_collect(fn, x, params[name], remat)
                kv_out[name] = kvs
            else:
                fn = lambda h, bp: _attn_block_fwd(bp, h, cfg, mode, positions)[:2]  # noqa: E731
                x, a2 = _scan_stack(fn, x, params[name], remat)
            aux = aux + a2
    elif cfg.family == "ssm":
        if collect_kv:
            fn = lambda h, bp: (  # noqa: E731
                *_ssm_fwd_state(bp, h, cfg, mode),
            )
            x, aux, states = _scan_stack_collect(fn, x, params["blocks"], remat)
            kv_out["ssm"] = states
        else:
            fn = lambda h, bp: (ssm_lib.apply_mamba_full(bp, h, cfg, mode), 0.0)  # noqa: E731
            x, aux = _scan_stack(fn, x, params["blocks"], remat)
    elif cfg.family == "hybrid":
        x, aux, kvs = _hybrid_forward(params, cfg, x, mode, positions, remat, collect_kv)
        kv_out.update(kvs)
    else:  # pragma: no cover
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = _lm_logits(params, cfg, x)
    if collect_kv:
        return logits, aux, kv_out
    return logits, aux


def _ssm_fwd_state(bp, h, cfg, mode):
    y, st = ssm_lib.apply_mamba_full(bp, h, cfg, mode, return_state=True)
    return y, 0.0, st


def _hybrid_forward(params, cfg, x, mode, positions, remat, collect_kv):
    """Zamba2: [group of `every` mamba blocks + shared attn] × G + tail."""
    kv_out: dict = {}

    def group_fn(h, xs):
        gp = xs["mamba"]
        extras = {}
        if collect_kv:
            fn = lambda hh, bp: _ssm_fwd_state(bp, hh, cfg, mode)  # noqa: E731
            h, _, states = _scan_stack_collect(fn, h, gp, remat)
            extras["ssm"] = states
        else:
            fn = lambda hh, bp: (ssm_lib.apply_mamba_full(bp, hh, cfg, mode), 0.0)  # noqa: E731
            h, _ = _scan_stack(fn, h, gp, remat)
        sp = dict(params["shared"])
        if "lora_v" in xs:
            sp = {"attn": {**params["shared"]["attn"], "lora_v": xs["lora_v"]},
                  "mlp": params["shared"]["mlp"]}
        h2, _, kv = _attn_block_fwd(sp, h, cfg, mode, positions, collect_kv)
        if collect_kv:
            extras["attn_kv"] = kv
        return h2, extras

    xs = {"mamba": params["mamba_groups"]}
    if "shared_lora_v" in params:
        xs["lora_v"] = params["shared_lora_v"]

    def scan_step(h, xs_i):
        h2, extras = group_fn(h, xs_i)
        return h2, extras

    x, extras = jax.lax.scan(scan_step, x, xs)
    if collect_kv:
        kv_out["hybrid"] = extras

    if "mamba_tail" in params:
        if collect_kv:
            fn = lambda hh, bp: _ssm_fwd_state(bp, hh, cfg, mode)  # noqa: E731
            x, _, st = _scan_stack_collect(fn, x, params["mamba_tail"], remat)
            kv_out["tail_ssm"] = st
        else:
            fn = lambda hh, bp: (ssm_lib.apply_mamba_full(bp, hh, cfg, mode), 0.0)  # noqa: E731
            x, _ = _scan_stack(fn, x, params["mamba_tail"], remat)
    return x, jnp.zeros((), jnp.float32), kv_out


# ---------------------------------------------------------------------------
# Serving: prefill + decode with the tiered DR cache
# ---------------------------------------------------------------------------


def _attn_cache_spec(cfg: ModelConfig):
    if cfg.attn_type == "mla":
        return (cfg.mla.kv_cache_dim,), (0,)
    g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return (g, hd), (g, hd)


def init_decode_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    hot_cap: int = DEFAULT_HOT_CAP,
    dtype=jnp.bfloat16,
    paged: bool = False,
    page_size: int = 256,
    n_pages: Optional[int] = None,
):
    """Empty cache pytree for this arch (stacked per layer-stack).

    With ``paged`` the attention stacks use the page-table-indirected
    cold tier (``kv_cache.PagedKVCache``): one shared ``n_pages`` pool
    per layer, page ids meaning the same physical page index in every
    stack's pool (the serving engine's host-side page accounting is a
    single id space across layers and stacks)."""

    def attn_cache(n_layers):
        kshape, vshape = _attn_cache_spec(cfg)
        kv_dtype = jnp.float8_e4m3fn if cfg.bitnet.kv_fp8 else dtype
        if cfg.attn_type == "swa":
            hc, cc = 0, min(cfg.swa_window, max_len)
        else:
            hc, cc = min(hot_cap, max_len), max_len - min(hot_cap, max_len)
        if paged:
            assert cfg.attn_type != "swa", "paged cold tier has no ring layout"
            one = kvc.init_paged_cache(
                batch, hc, cc, kshape, kv_dtype,
                page_size=page_size, n_pages=n_pages,
            )
            if vshape == (0,):
                one = one._replace(
                    hot_v=jnp.zeros(one.hot_v.shape[:2] + (0,), kv_dtype),
                    pool_v=jnp.zeros(one.pool_v.shape[:2] + (0,), kv_dtype),
                )
        else:
            one = kvc.init_cache(batch, hc, cc, kshape, kv_dtype)
            if vshape == (0,):
                one = one._replace(
                    hot_v=jnp.zeros(one.hot_v.shape[:2] + (0,), kv_dtype),
                    cold_v=jnp.zeros(one.cold_v.shape[:2] + (0,), kv_dtype),
                )
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_layers,) + a.shape), one)

    def ssm_state(n_layers, lead=()):
        one = ssm_lib.init_mamba_state(batch, cfg, dtype)
        return jax.tree.map(
            lambda a: jnp.zeros(lead + (n_layers,) + a.shape, a.dtype), one
        )

    if cfg.family in ("dense", "vlm"):
        return {"attn": attn_cache(cfg.n_layers)}
    if cfg.family == "moe":
        nd = cfg.moe.n_dense_layers
        out = {"attn_moe": attn_cache(cfg.n_layers - nd)}
        if nd:
            out["attn_dense"] = attn_cache(nd)
        return out
    if cfg.family == "ssm":
        return {"ssm": ssm_state(cfg.n_layers)}
    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        ng = cfg.n_layers // every
        nt = cfg.n_layers - ng * every
        out = {
            "mamba": jax.tree.map(
                lambda a: jnp.zeros((ng,) + a.shape, a.dtype),
                ssm_state(every),
            ),
            "attn": attn_cache(ng),
        }
        if nt:
            out["tail"] = ssm_state(nt)
        return out
    raise ValueError(cfg.family)


def _fill_attn_cache(cache_stack, kvs, cfg):
    """Bulk-place prefill KV (L, b, s, ...) into a stacked fresh tiered
    cache — ``kv_cache.fill_fresh`` per layer (static slices; the ring
    realign for SWA windows lives there, in exactly one place)."""
    ks, vs = kvs
    ring = cfg.attn_type == "swa"
    return jax.vmap(
        lambda c, k, v: kvc.fill_fresh(c, k, v, ring=ring)
    )(cache_stack, ks, vs)


def _flash_prefill_capable(cfg: ModelConfig, impl: str) -> bool:
    """The per-layer flash-prefill scan path covers the attention-cache
    families; SSM/hybrid keep the collect-state forward (their cache is
    recurrent state, not KV) and the XLA impl keeps the legacy path so
    the GSPMD dry-run lowering is untouched."""
    return (
        impl == "pallas"
        and cfg.family in ("dense", "vlm", "moe")
        and cfg.attn_type in ("full", "swa", "mla")
    )


def prefill(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    hot_cap: int = DEFAULT_HOT_CAP,
    max_len: Optional[int] = None,
    mode: str = "packed",
    remat: bool = False,
    headroom: Optional[int] = None,
):
    """Process the prompt; return (last-token logits, filled decode cache).

    Cache capacity is ``max_len`` when given, else ``prompt_len +
    headroom`` (defaulting to ``cfg.decode_headroom``) — the headroom is
    the hard cap on how many tokens can subsequently be decoded, so
    callers that rely on the default must size it deliberately.

    On the Pallas impl (``qops.resolve_impl``) attention-cache families
    run the per-layer flash-prefill scan (``attention_prefill`` /
    ``mla_prefill``: fused RoPE + causal-skip streaming + tier-dtype
    cache-fill epilogue, kernels/flash_prefill.py); otherwise the legacy
    collect-KV forward + bulk fill runs, numerically as before.
    """
    tokens = batch.get("tokens")
    if cfg.family == "vlm":
        s = tokens.shape[1] + cfg.n_patches
        b = tokens.shape[0]
    elif cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode/prefill phase")
    else:
        b, s = tokens.shape
    if max_len is None:
        max_len = s + (headroom if headroom is not None else cfg.decode_headroom)

    from repro.models import qops

    if _flash_prefill_capable(cfg, qops.resolve_impl(cfg)):
        return _prefill_flash(params, cfg, batch, b, s, hot_cap, max_len, mode)

    logits, aux, kvs = forward(params, cfg, batch, mode, remat=remat, collect_kv=True)
    cache = init_decode_cache(cfg, b, max_len, hot_cap, dtype=params["final_ln"].dtype)

    if cfg.family in ("dense", "vlm"):
        cache["attn"] = _fill_attn_cache(cache["attn"], kvs["attn"], cfg)
    elif cfg.family == "moe":
        cache["attn_moe"] = _fill_attn_cache(cache["attn_moe"], kvs["moe_blocks"], cfg)
        if "attn_dense" in cache:
            cache["attn_dense"] = _fill_attn_cache(
                cache["attn_dense"], kvs["dense_blocks"], cfg
            )
    elif cfg.family == "ssm":
        cache["ssm"] = kvs["ssm"]
    elif cfg.family == "hybrid":
        cache["mamba"] = kvs["hybrid"]["ssm"]
        cache["attn"] = _fill_attn_cache(cache["attn"], kvs["hybrid"]["attn_kv"], cfg)
        if "tail_ssm" in kvs:
            cache["tail"] = kvs["tail_ssm"]
    return logits[:, -1], cache


def _attn_block_prefill(bp, x, cfg, mode, cache_layer, n_valid=None):
    """One block of the flash-prefill scan: full-seq attention straight
    into the tiered cache rows, then the MLP/MoE. ``n_valid`` switches
    the chunked continuation form (serving engine)."""
    if n_valid is not None:
        y, cache_layer = attn.attention_prefill_chunk(
            bp["attn"], x, cfg, mode, cache_layer, n_valid
        )
    elif cfg.attn_type == "mla":
        y, cache_layer = attn.mla_prefill(bp["attn"], x, cfg, mode, cache_layer)
    else:
        y, cache_layer = attn.attention_prefill(bp["attn"], x, cfg, mode, cache_layer)
    x = x + y
    if "moe" in bp:
        h, _ = moe_lib.apply_moe(bp["moe"], x, cfg, mode)
    else:
        h = apply_mlp(bp["mlp"], x, cfg, mode)
    return x + h, cache_layer


def _prefill_scan(params, cfg, x, cache, mode, n_valid=None):
    """Scan the stacked attention blocks over (params, cache) pairs —
    decode_step's structure at full sequence length."""

    def scan_attn(x1, stack_params, cache_stack):
        def step(h, xs):
            bp, cl = xs
            return _attn_block_prefill(bp, h, cfg, mode, cl, n_valid)

        return jax.lax.scan(step, x1, (stack_params, cache_stack))

    if cfg.family in ("dense", "vlm"):
        x, cache["attn"] = scan_attn(x, params["blocks"], cache["attn"])
    elif cfg.family == "moe":
        if "attn_dense" in cache:
            x, cache["attn_dense"] = scan_attn(
                x, params["dense_blocks"], cache["attn_dense"]
            )
        x, cache["attn_moe"] = scan_attn(x, params["moe_blocks"], cache["attn_moe"])
    else:  # pragma: no cover — guarded by _flash_prefill_capable / engine
        raise ValueError(cfg.family)
    return x, cache


def _prefill_flash(params, cfg, batch, b, s, hot_cap, max_len, mode):
    """Pallas prefill: per-layer flash-attention + cache-fill scan."""
    dtype = params["final_ln"].dtype
    if cfg.family == "vlm":
        patches = _frontend_embed(params, cfg, batch["patches"].astype(dtype), mode)
        text = _embed_tokens(params, cfg, batch["tokens"], dtype)
        x = jnp.concatenate([patches, text], axis=1)
    else:
        x = _embed_tokens(params, cfg, batch["tokens"], dtype)
    cache = init_decode_cache(cfg, b, max_len, hot_cap, dtype=dtype)
    x, cache = _prefill_scan(params, cfg, x, cache, mode)
    x_last = rms_norm(x[:, -1], params["final_ln"], cfg.norm_eps)
    return _lm_logits(params, cfg, x_last), cache


def prefill_chunk_step(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (slots, C) — one prompt chunk per slot
    cache,
    n_valid: jax.Array,  # (slots,) valid rows; 0 = slot not prefilling
    mode: str = "packed",
):
    """One chunked-prefill dispatch over the live slot state.

    Appends each slot's ``n_valid`` chunk tokens at its own
    ``cache.lengths`` offset and returns (last-valid-row logits (slots,
    V), cache). Every shape is fixed by (slots, C), so the serving
    engine compiles this exactly once regardless of the prompt-length
    mix (the compile-count assertion in tests/test_scheduler.py).
    Supported for attention-cache families without a frontend — the
    engine falls back to grouped whole-prompt admission elsewhere.
    """
    dtype = params["final_ln"].dtype
    x = _embed_tokens(params, cfg, tokens, dtype)  # (slots, C, d)
    x, cache = _prefill_scan(params, cfg, x, cache, mode, n_valid=n_valid)
    # logits at each slot's last valid row (garbage for idle slots)
    idx = jnp.clip(n_valid.astype(jnp.int32) - 1, 0, tokens.shape[1] - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    x_last = rms_norm(x_last, params["final_ln"], cfg.norm_eps)
    return _lm_logits(params, cfg, x_last), cache


def _spec_scan(params, cfg, x, cache, mode, n_valid):
    """Verification scan: `_prefill_scan`'s structure, but attention runs
    in the no-append form and each layer's rotated chunk k/v is collected
    instead of written — the cache is READ, never mutated. The collected
    (k, v) stacks feed :func:`spec_commit_chunk` once the accept length
    is known."""

    def scan_attn(x1, stack_params, cache_stack):
        def step(h, xs):
            bp, cl = xs
            y, kv = attn.attention_prefill_chunk(
                bp["attn"], h, cfg, mode, cl, n_valid, append=False
            )
            h = h + y
            if "moe" in bp:
                h2, _ = moe_lib.apply_moe(bp["moe"], h, cfg, mode)
            else:
                h2 = apply_mlp(bp["mlp"], h, cfg, mode)
            return h + h2, kv

        return jax.lax.scan(step, x1, (stack_params, cache_stack))

    kvs = {}
    if cfg.family in ("dense", "vlm"):
        x, kvs["attn"] = scan_attn(x, params["blocks"], cache["attn"])
    elif cfg.family == "moe":
        if "attn_dense" in cache:
            x, kvs["attn_dense"] = scan_attn(
                x, params["dense_blocks"], cache["attn_dense"]
            )
        x, kvs["attn_moe"] = scan_attn(x, params["moe_blocks"], cache["attn_moe"])
    else:  # pragma: no cover — guarded by the engine's capability gate
        raise ValueError(cfg.family)
    return x, kvs


def spec_verify_chunk(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (slots, K) — pending token ‖ draft proposals
    cache,
    n_valid: jax.Array,  # (slots,) valid chunk rows; 0 = slot inactive
    mode: str = "packed",
):
    """Speculative verification: ONE chunk-shaped dispatch that scores a
    K-token draft chunk against the live cache WITHOUT appending.

    Returns ``(logits, kvs)`` where ``logits`` is (slots, K, vocab) —
    the target model's distribution after every chunk position, which
    the engine's acceptance kernel argmaxes against the draft — and
    ``kvs`` maps each attention stack to its (L, slots, K, ...) rotated
    chunk k/v, ready for :func:`spec_commit_chunk`. Deferring the
    append is what makes rollback trivial (nothing to roll back) and
    ring (SWA) caches safe to speculate on. Shapes are fixed by
    (slots, K): one compile per engine, same contract as
    ``prefill_chunk_step``.
    """
    dtype = params["final_ln"].dtype
    x = _embed_tokens(params, cfg, tokens, dtype)  # (slots, K, d)
    x, kvs = _spec_scan(params, cfg, x, cache, mode, n_valid)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    return _lm_logits(params, cfg, x), kvs


def spec_commit_chunk(cfg: ModelConfig, cache, kvs, n_commit: jax.Array):
    """Append the first ``n_commit[b]`` verified chunk rows of each slot
    to the live cache (the accept step of draft-verify speculation).

    ``kvs`` is :func:`spec_verify_chunk`'s per-stack (L, slots, K, ...)
    k/v; the append vmaps over the layer axis, so tiered and paged
    stacks both work. Linear layouts may commit the full chunk and roll
    back via ``kv_cache.truncate``; ring layouts MUST pass the accepted
    count here (a ring append is destructive — see ``truncate``)."""
    ring = cfg.attn_type == "swa"
    out = dict(cache)
    for key, (k, v) in kvs.items():
        out[key] = jax.vmap(
            lambda c, kk, vv: kvc.append(c, kk, vv, valid=n_commit, ring=ring)
        )(cache[key], k, v)
    return out


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def _attn_block_decode(bp, x1, cfg, mode, cache_layer, active=None):
    f = attn.mla_decode if cfg.attn_type == "mla" else attn.attention_decode
    y, cache_layer = f(bp["attn"], x1, cfg, mode, cache_layer, active=active)
    x1 = x1 + y
    if "moe" in bp:
        h, _ = moe_lib.apply_moe(bp["moe"], x1[:, None, :], cfg, mode)
        h = h[:, 0]
    else:
        h = apply_mlp(bp["mlp"], x1[:, None, :], cfg, mode)[:, 0]
    return x1 + h, cache_layer


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array, cache,
                mode: str = "packed", active: Optional[jax.Array] = None):
    """One token for the whole batch. tokens: (b,) int32 -> (logits, cache).

    Each batch row is an independent *slot* at its own sequence length
    (``cache.lengths``). ``active`` (b,) bool gates cache mutation per
    slot: inactive slots (retired or unadmitted, in continuous batching)
    still flow through the compute — their logits are garbage and ignored
    by the caller — but neither append KV nor advance recurrent state.
    """
    dtype = params["final_ln"].dtype
    x = _embed_tokens(params, cfg, tokens[:, None], dtype)[:, 0]  # (b, d)

    def scan_attn(x1, stack_params, cache_stack):
        def step(h, xs):
            bp, cl = xs
            h2, cl2 = _attn_block_decode(bp, h, cfg, mode, cl, active)
            return h2, cl2

        return jax.lax.scan(step, x1, (stack_params, cache_stack))

    def _mask_state(new_state, old_state):
        if active is None:
            return new_state
        return jax.tree.map(
            lambda n, o: jnp.where(
                active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o
            ),
            new_state,
            old_state,
        )

    def scan_ssm(x1, stack_params, state_stack):
        def step(h, xs):
            bp, st = xs
            h2, st2 = ssm_lib.apply_mamba_decode(bp, h, cfg, mode, st)
            return h2, _mask_state(st2, st)

        return jax.lax.scan(step, x1, (stack_params, state_stack))

    if cfg.family in ("dense", "vlm"):
        x, cache["attn"] = scan_attn(x, params["blocks"], cache["attn"])
    elif cfg.family == "moe":
        if "attn_dense" in cache:
            x, cache["attn_dense"] = scan_attn(
                x, params["dense_blocks"], cache["attn_dense"]
            )
        x, cache["attn_moe"] = scan_attn(x, params["moe_blocks"], cache["attn_moe"])
    elif cfg.family == "ssm":
        x, cache["ssm"] = scan_ssm(x, params["blocks"], cache["ssm"])
    elif cfg.family == "hybrid":

        def group_step(h, xs):
            gp, gstate, acache, lora_v = xs
            h, gstate2 = scan_ssm(h, gp, gstate)
            sp = {"attn": params["shared"]["attn"], "mlp": params["shared"]["mlp"]}
            if lora_v is not None:
                sp = {"attn": {**sp["attn"], "lora_v": lora_v}, "mlp": sp["mlp"]}
            h, acache2 = _attn_block_decode(sp, h, cfg, mode, acache, active)
            return h, (gstate2, acache2)

        lora_stack = params.get("shared_lora_v")
        if lora_stack is None:
            def step(h, xs_i):
                gp, gstate, acache = xs_i
                return group_step(h, (gp, gstate, acache, None))
            x, (cache["mamba"], cache["attn"]) = jax.lax.scan(
                step, x, (params["mamba_groups"], cache["mamba"], cache["attn"])
            )
        else:
            def step(h, xs_i):
                gp, gstate, acache, lv = xs_i
                return group_step(h, (gp, gstate, acache, lv))
            x, (cache["mamba"], cache["attn"]) = jax.lax.scan(
                step, x, (params["mamba_groups"], cache["mamba"], cache["attn"], lora_stack)
            )
        if "tail" in cache:
            x, cache["tail"] = scan_ssm(x, params["mamba_tail"], cache["tail"])
    else:  # pragma: no cover
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = _lm_logits(params, cfg, x)
    return logits, cache
