"""Model zoo: attention/MoE/SSM/hybrid/encoder/VLM building blocks and the
family-dispatching top-level transformer (init / forward / prefill / decode)."""

from repro.models import attention, layers, moe, qops, ssm, transformer  # noqa: F401
