"""Quantized linear primitives shared by all architectures.

Every projection in every model funnels through ``linear`` so the paper's
technique (ternary weights, A8/A4 activations, packed storage, LoRA) is
applied uniformly:

  * QAT mode ("qat")    — BitNet STE fake quantization (training forward)
  * packed mode         — leaf already converted to ``PackedLinear`` /
                          ``FusedPackedLinear``: integer ternary matmul on
                          packed trits via the shared fast-path helper
                          (core/bitlinear.packed_matmul — Pallas fused
                          epilogue on TPU, XLA unpack+dot otherwise; see
                          ``resolve_impl``). ``fused_linear`` serves a
                          whole same-input projection group (wq‖wk‖wv,
                          gate‖up) with one act-quant + one launch.
  * float mode ("none") — plain matmul (ablation baseline)

Weights are always stored contraction-first (K, N) — inputs with multiple
contracted dims are flattened to (..., K) — so the packed codecs and the
Pallas kernel apply everywhere. Expert-batched weights (E, K, N) run as a
single E-loop Pallas launch when packed (``expert_linear`` /
``expert_fused_linear`` via ``bitlinear.expert_packed_matmul``; per-expert
absmean scale, as the paper's per-macro scaling suggests) and vmap the
same primitive per expert otherwise.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bitlinear
from repro.core import lora as lora_lib
from repro.core.bitlinear import FusedPackedLinear, PackedLinear
from repro.core.ternary import act_quant_ste, weight_quant_ste
from repro.configs.base import ModelConfig


def resolve_impl(cfg: ModelConfig) -> str:
    """Pick the packed-matmul execution path for this process.

    ``cfg.bitnet.impl`` of "pallas"/"xla" is honored verbatim; "auto"
    selects the Pallas fused-epilogue kernel on a TPU backend and falls
    back to the XLA unpack+dot path on CPU (where Pallas would run in the
    slow interpreter) and under active sharding hints (a hand-written
    kernel blocks GSPMD propagation on the multi-pod dry-run lowering).
    """
    impl = cfg.bitnet.impl
    if impl != "auto":
        return impl
    from repro.models import shard_ctx

    if shard_ctx.active():
        return "xla"
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _apply_lora(y: jax.Array, x: jax.Array, lora_leaf: dict, cfg: ModelConfig):
    """Add the quantized-LoRA delta (paper §III-C) to a projection output.

    The single site for the cfg-driven adapter recipe (alpha = 2r,
    lora_bits weights, A8) on the model projection paths, so the fused
    and unfused ``linear``/``fused_linear`` routes can never diverge.
    (The standalone ``core.bitlinear.apply*`` conveniences predate this
    and use ``lora_lib.apply`` defaults — no model path goes through them.)
    """
    x2l, _ = _flatten_x(x, lora_leaf["a"].shape[0])
    return y + lora_lib.apply(
        lora_leaf,
        x2l,
        alpha=2.0 * cfg.bitnet.lora_rank,
        weight_bits=cfg.bitnet.lora_bits,
        act_bits=8,
    ).astype(y.dtype)


def _flatten_x(x: jax.Array, k: int):
    """Reshape (..., a, b, ...) so contracted dims collapse into last = k."""
    lead_elems = 1
    shape = x.shape
    cut = len(shape)
    prod = 1
    while prod < k:
        cut -= 1
        prod *= shape[cut]
    assert prod == k, (shape, k)
    return x.reshape(shape[:cut] + (k,)), shape[:cut]


def linear(
    leaf,
    x: jax.Array,
    cfg: ModelConfig,
    mode: str = "qat",
    out_shape: tuple | None = None,
    lora_leaf: Optional[dict] = None,
    quantize: bool = True,
    impl: Optional[str] = None,
) -> jax.Array:
    """y = x @ W with the BitNet recipe. ``leaf`` is {"w": (K, N)},
    PackedLinear or FusedPackedLinear.

    ``out_shape``: optional trailing shape to unflatten N into (e.g. (H, hd)).
    ``quantize=False`` exempts a projection from ternarization (embeddings,
    lm_head — BitNet convention). ``impl`` overrides the config-resolved
    packed execution path (the vmapped expert path pins "xla").
    """
    act_bits = cfg.bitnet.act_bits

    if isinstance(leaf, (PackedLinear, FusedPackedLinear)):
        x2, lead = _flatten_x(x, leaf.k)
        y = bitlinear.packed_matmul(
            leaf, x2, act_bits=act_bits, impl=impl or resolve_impl(cfg),
            fuse_actq=cfg.bitnet.fuse_act_quant,
        )
        y = y.astype(x.dtype)
        n = leaf.packed.shape[-1]
    else:
        w = leaf["w"]
        k = w.shape[0] if w.ndim == 2 else w.shape[-2]
        x2, lead = _flatten_x(x, k)
        if not quantize or not cfg.bitnet.enabled or mode == "none":
            y = x2 @ w
        elif mode in ("qat", "packed"):
            # ("packed" with a dict leaf = projection kept unpacked, e.g. MLA
            # factors — same ternary numerics via fake-quant, see DESIGN.md)
            y = act_quant_ste(x2, bits=act_bits) @ weight_quant_ste(w)
        else:
            raise ValueError(f"unknown mode {mode!r}")
        y = y.astype(x.dtype)
        n = w.shape[-1]

    if lora_leaf is not None and cfg.bitnet.lora_rank > 0:
        y = _apply_lora(y, x, lora_leaf, cfg)

    if out_shape is not None:
        y = y.reshape(lead + tuple(out_shape))
    else:
        y = y.reshape(lead + (n,))
    return y


def fused_linear(
    leaf: FusedPackedLinear,
    x: jax.Array,
    cfg: ModelConfig,
    out_shapes: Optional[tuple] = None,
    lora_leaves: Optional[dict] = None,
) -> tuple:
    """Fused projection group: ONE act-quant + ONE packed matmul, split out.

    ``leaf`` is a ``FusedPackedLinear`` (wq‖wk‖wv or gate‖up); returns one
    array per segment. ``out_shapes``: optional per-segment trailing shapes
    (e.g. ((H, hd), (G, hd), (G, hd))). ``lora_leaves``: {segment_index:
    lora leaf} — adapters apply to the segment output after the split, so
    LoRA'd projections (e.g. wv) fuse like any other.
    """
    x2, lead = _flatten_x(x, leaf.k)
    y = bitlinear.packed_matmul(
        leaf, x2, act_bits=cfg.bitnet.act_bits, impl=resolve_impl(cfg),
        fuse_actq=cfg.bitnet.fuse_act_quant,
    ).astype(x.dtype)
    parts = []
    off = 0
    for i, w in enumerate(leaf.splits):
        seg = jax.lax.slice_in_dim(y, off, off + w, axis=-1)
        off += w
        lora_leaf = (lora_leaves or {}).get(i)
        if lora_leaf is not None and cfg.bitnet.lora_rank > 0:
            seg = _apply_lora(seg, x, lora_leaf, cfg)
        shape = out_shapes[i] if out_shapes and out_shapes[i] else (w,)
        parts.append(seg.reshape(lead + tuple(shape)))
    return tuple(parts)


def expert_linear(leaf, x: jax.Array, cfg: ModelConfig, mode: str = "qat",
                  impl: Optional[str] = None) -> jax.Array:
    """Per-expert linear: x (E, C, K) @ W (E, K, N) -> (E, C, N).

    Packed leaves route through ``bitlinear.expert_packed_matmul``: ONE
    E-loop Pallas launch over all experts when the resolved impl is
    "pallas" — act-quant-prologue-fused by default, or the carried-scale
    known-scale kernel under ``fuse_act_quant=False`` — else the vmapped
    per-expert XLA path. ``impl`` overrides the config-resolved path (the
    grouped-dispatch MoE branch runs under ``jax.vmap``, where a
    pallas_call cannot appear).
    """
    if isinstance(leaf, (PackedLinear, FusedPackedLinear)):
        return bitlinear.expert_packed_matmul(
            leaf, x, act_bits=cfg.bitnet.act_bits,
            impl=impl or resolve_impl(cfg),
            fuse_actq=cfg.bitnet.fuse_act_quant,
        ).astype(x.dtype)
    w = leaf["w"]
    if mode == "qat":
        from repro.models import shard_ctx

        # declare the weight gathered-at-use over the FSDP axis: contracting
        # against the K-sharded stored form makes GSPMD emit partial-sum
        # all-reduces of ACTIVATION size (TBs at 256 devices) instead of a
        # weight-sized all-gather (EXPERIMENTS.md §Perf H3 iteration 2)
        if shard_ctx.has_expert_axes() and w.ndim == 3:
            w = shard_ctx.constrain(w, "EXPERT", None, None)
    return jax.vmap(lambda ww, xx: linear({"w": ww}, xx, cfg, mode))(w, x)


def expert_fused_linear(
    leaf: FusedPackedLinear,
    x: jax.Array,
    cfg: ModelConfig,
    impl: Optional[str] = None,
) -> tuple:
    """Fused expert projection group (pack-time w_gate‖w_up -> "w_gu"):
    ONE E-loop launch serves every expert AND both GLU halves, split out.

    x: (E, C, K); returns one (E, C, width) array per segment. Segment
    scales stay exact (per-column scale vector per expert), so fused ==
    separate bit-for-bit on either impl.
    """
    y = expert_linear(leaf, x, cfg, "packed", impl=impl)
    parts = []
    off = 0
    for w in leaf.splits:
        parts.append(jax.lax.slice_in_dim(y, off, off + w, axis=-1))
        off += w
    return tuple(parts)


def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    s = scale if scale is not None else d_in**-0.5
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * s}


def init_expert_linear(key, n_e: int, d_in: int, d_out: int, dtype=jnp.float32):
    return {"w": jax.random.normal(key, (n_e, d_in, d_out), dtype) * d_in**-0.5}
