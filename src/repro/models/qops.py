"""Quantized linear primitives shared by all architectures.

Every projection in every model funnels through ``linear`` so the paper's
technique (ternary weights, A8/A4 activations, packed storage, LoRA) is
applied uniformly:

  * QAT mode ("qat")    — BitNet STE fake quantization (training forward)
  * packed mode         — leaf already converted to ``PackedLinear``:
                          integer ternary matmul on packed trits
  * float mode ("none") — plain matmul (ablation baseline)

Weights are always stored contraction-first (K, N) — inputs with multiple
contracted dims are flattened to (..., K) — so the packed codecs and the
Pallas kernel apply everywhere. Expert-batched weights (E, K, N) vmap the
same primitive per expert (per-expert absmean scale, as the paper's
per-macro scaling suggests).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import lora as lora_lib
from repro.core.bitlinear import PackedLinear
from repro.core.ternary import act_quant, act_quant_ste, weight_quant_ste
from repro.configs.base import ModelConfig


def _flatten_x(x: jax.Array, k: int):
    """Reshape (..., a, b, ...) so contracted dims collapse into last = k."""
    lead_elems = 1
    shape = x.shape
    cut = len(shape)
    prod = 1
    while prod < k:
        cut -= 1
        prod *= shape[cut]
    assert prod == k, (shape, k)
    return x.reshape(shape[:cut] + (k,)), shape[:cut]


def linear(
    leaf,
    x: jax.Array,
    cfg: ModelConfig,
    mode: str = "qat",
    out_shape: tuple | None = None,
    lora_leaf: Optional[dict] = None,
    quantize: bool = True,
) -> jax.Array:
    """y = x @ W with the BitNet recipe. ``leaf`` is {"w": (K, N)} or PackedLinear.

    ``out_shape``: optional trailing shape to unflatten N into (e.g. (H, hd)).
    ``quantize=False`` exempts a projection from ternarization (embeddings,
    lm_head — BitNet convention).
    """
    act_bits = cfg.bitnet.act_bits

    if isinstance(leaf, PackedLinear):
        from repro.kernels import ops

        x2, lead = _flatten_x(x, leaf.k)
        xq = act_quant(x2, bits=act_bits)
        acc = ops.ternary_matmul(xq.xq, leaf.packed, k=leaf.k, codec=leaf.codec, impl="xla")
        y = acc.astype(jnp.float32) * (leaf.scale / xq.scale)
        y = y.astype(x.dtype)
        n = leaf.packed.shape[-1]
    else:
        w = leaf["w"]
        k = w.shape[0] if w.ndim == 2 else w.shape[-2]
        x2, lead = _flatten_x(x, k)
        if not quantize or not cfg.bitnet.enabled or mode == "none":
            y = x2 @ w
        elif mode in ("qat", "packed"):
            # ("packed" with a dict leaf = projection kept unpacked, e.g. MLA
            # factors — same ternary numerics via fake-quant, see DESIGN.md)
            y = act_quant_ste(x2, bits=act_bits) @ weight_quant_ste(w)
        else:
            raise ValueError(f"unknown mode {mode!r}")
        y = y.astype(x.dtype)
        n = w.shape[-1]

    if lora_leaf is not None and cfg.bitnet.lora_rank > 0:
        x2l, _ = _flatten_x(x, lora_leaf["a"].shape[0])
        y = y + lora_lib.apply(
            lora_leaf,
            x2l,
            alpha=2.0 * cfg.bitnet.lora_rank,
            weight_bits=cfg.bitnet.lora_bits,
            act_bits=8,
        ).astype(y.dtype)

    if out_shape is not None:
        y = y.reshape(lead + tuple(out_shape))
    else:
        y = y.reshape(lead + (n,))
    return y


def expert_linear(leaf, x: jax.Array, cfg: ModelConfig, mode: str = "qat") -> jax.Array:
    """Per-expert linear: x (E, C, K) @ W (E, K, N) -> (E, C, N)."""
    if isinstance(leaf, PackedLinear):
        fn = lambda px, xx: linear(  # noqa: E731
            PackedLinear(packed=px[0], scale=px[1], k=leaf.k, codec=leaf.codec),
            xx,
            cfg,
            mode,
        )
        return jax.vmap(fn)((leaf.packed, leaf.scale), x)
    w = leaf["w"]
    if mode == "qat":
        from repro.models import shard_ctx

        # declare the weight gathered-at-use over the FSDP axis: contracting
        # against the K-sharded stored form makes GSPMD emit partial-sum
        # all-reduces of ACTIVATION size (TBs at 256 devices) instead of a
        # weight-sized all-gather (EXPERIMENTS.md §Perf H3 iteration 2)
        if shard_ctx.has_expert_axes() and w.ndim == 3:
            w = shard_ctx.constrain(w, "EXPERT", None, None)
    return jax.vmap(lambda ww, xx: linear({"w": ww}, xx, cfg, mode))(w, x)


def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    s = scale if scale is not None else d_in**-0.5
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * s}


def init_expert_linear(key, n_e: int, d_in: int, d_out: int, dtype=jnp.float32):
    return {"w": jax.random.normal(key, (n_e, d_in, d_out), dtype) * d_in**-0.5}
