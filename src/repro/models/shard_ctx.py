"""Sharding context: lets model code place with_sharding_constraint hints
without threading mesh objects through every layer.

The launcher/dry-run sets the context before tracing; on bare CPU (unit
tests, examples) the context is empty and every constraint is a no-op.
GSPMD propagation handles most tensors — the explicit constraints exist
for the few places where propagation is known to go wrong at 256+ devices:
the MoE dispatch/combine path (observed: involuntary full rematerialization
of 45 GB expert tensors) and the microbatch gradient accumulator.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def _get() -> dict:
    return getattr(_STATE, "ctx", None) or {}


@contextlib.contextmanager
def sharding_hints(
    mesh,
    expert_axes: Optional[Tuple[str, ...]] = None,
    batch_axes: Tuple[str, ...] = ("data",),
    model_axis: str = "model",
    seq_axis: Optional[str] = None,  # sequence parallelism (Megatron-SP)
    moe_groups: int = 1,  # grouped (per-data-shard) MoE dispatch
):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = {
        "mesh": mesh,
        "expert_axes": expert_axes,
        "batch_axes": batch_axes,
        "model_axis": model_axis,
        "seq_axis": seq_axis,
        "moe_groups": moe_groups,
    }
    try:
        yield
    finally:
        _STATE.ctx = prev


def moe_groups() -> int:
    return _get().get("moe_groups", 1) or 1


def has_expert_axes() -> bool:
    return _get().get("expert_axes") is not None


def active() -> bool:
    return _get().get("mesh") is not None


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint(x, P(*spec)) if a mesh context is active.

    Spec entries may be the literal strings "EXPERT"/"BATCH"/"MODEL" which
    resolve against the active context (EXPERT may be None => no-op dim).
    """
    ctx = _get()
    mesh = ctx.get("mesh")
    if mesh is None:
        return x
    resolved = []
    for s in spec:
        if s == "EXPERT":
            ax = ctx.get("expert_axes")
            resolved.append(ax if ax else None)
        elif s == "BATCH":
            ax = ctx.get("batch_axes")
            resolved.append(ax if len(ax) > 1 else ax[0])
        elif s == "MODEL":
            resolved.append(ctx.get("model_axis"))
        elif s == "SEQ":
            resolved.append(ctx.get("seq_axis"))  # None when SP off
        elif s == "TOKENS":
            # flattened (batch*seq) token dim: batch axes (+ seq axis if SP)
            ax = tuple(ctx.get("batch_axes"))
            if ctx.get("seq_axis"):
                ax = ax + (ctx["seq_axis"],)
            resolved.append(ax if len(ax) > 1 else ax[0])
        else:
            resolved.append(s)
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*resolved)))
