"""Pallas TPU kernel: packed-ternary matmul (the TriMLA/BiROMA analogue).

Structure mirrors the paper's local-then-global accumulation (§III-B):

  * the grid's K dimension streams packed trit tiles HBM -> VMEM;
  * each (bm, bn) output block keeps an int32 *local accumulator* in VMEM
    that is updated once per K tile (the TriMLA), never per input bit;
  * the final K step leaves the completed sum — one "global" result per
    block, the one-shot adder-tree pass.

Trits arrive packed (2 bits or base-243, see core/packing.py) and are
decoded *inside* VMEM, so HBM traffic is 0.25 (pack2) or 0.2 (pack243)
bytes per weight — the kernel-level expression of "weights never move".
The ternary MAC itself ({-1,0,+1} weights) rides the MXU int8 datapath:
values -1/0/+1 in int8 make the dot product exactly the add/sub/skip of
the TriMLA truth table (verified bit-exactly against ref.py).

Block shapes default to MXU-aligned (multiples of 128 on M/N, K tiles
sized so the packed rows stay lane-aligned). VMEM footprint per step:
  x tile (bm, bk) int8 + packed tile (bk/g, bn) uint8
  + decoded (bk, bn) int8 + acc (bm, bn) int32
e.g. bm=bn=256, bk=512 (pack2): 128K + 32K + 128K + 256K = 544 KiB << 16 MiB VMEM.

Two entry points:

  * ``ternary_matmul_pallas`` — raw int32 accumulator out (kept for the
    bit-exactness oracle tests and as the building block);
  * ``ternary_matmul_fused_pallas`` — the production fast path: the same
    integer pipeline plus a *fused epilogue*. The int32 local accumulator
    lives in VMEM scratch; on the final K step it is rescaled in VMEM by
    the per-column weight scale and per-row activation scale and written
    out directly as f32/bf16. The (M, N) int32 accumulator never exists
    in HBM and the separate XLA rescale pass disappears — one kernel
    launch goes activations-int8 -> scaled float output. Per-column
    (rather than per-tensor) weight scales are what lets fused QKV /
    gate-up projections (models/pack.py::fuse_packed) ride the same
    kernel: each output segment keeps its own absmean scale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing


def _decode2_block(wp: jax.Array) -> jax.Array:
    """(bk/4, bn) uint8 -> (bk, bn) int8 trits (2-bit codes, LSB=+, MSB=-)."""
    parts = []
    for i in range(packing.PACK2_GROUP):
        c = (wp >> (2 * i)) & 0b11
        parts.append(((c & 1).astype(jnp.int8) - ((c >> 1) & 1).astype(jnp.int8)))
    stacked = jnp.stack(parts, axis=1)  # (bk/4, 4, bn)
    return stacked.reshape(stacked.shape[0] * packing.PACK2_GROUP, stacked.shape[2])


def _decode243_block(wp: jax.Array) -> jax.Array:
    """(bk/5, bn) uint8 -> (bk, bn) int8 trits via repeated divmod-3."""
    v = wp.astype(jnp.int16)
    parts = []
    for _ in range(packing.PACK243_GROUP):
        parts.append((v % 3 - 1).astype(jnp.int8))
        v = v // 3
    stacked = jnp.stack(parts, axis=1)  # (bk/5, 5, bn)
    return stacked.reshape(stacked.shape[0] * packing.PACK243_GROUP, stacked.shape[2])


def _kernel(x_ref, w_ref, o_ref, *, codec: str, k_steps: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    decode = _decode2_block if codec == "pack2" else _decode243_block
    trits = decode(w_ref[...])  # (bk, bn) int8 in {-1,0,+1}
    x = x_ref[...]  # (bm, bk) int8
    # TriMLA: {-1,0,+1} weights => signed add / skip; on MXU this is an
    # int8 x int8 -> int32 dot with trit operands.
    acc = jax.lax.dot_general(
        x,
        trits,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("codec", "block_m", "block_n", "block_k", "interpret"),
)
def ternary_matmul_pallas(
    xq: jax.Array,
    packed: jax.Array,
    *,
    codec: str = "pack2",
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(M, K) int8 x packed (K/g, N) uint8 -> (M, N) int32.

    M, N, K must already be padded to block multiples (ops.py handles
    padding); block_k must be a multiple of the codec group (4 or 5).
    """
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    assert block_k % group == 0, (block_k, group)
    m, k = xq.shape
    kp, n = packed.shape
    assert kp * group == k, (kp, group, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (m, n, k)

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, codec=codec, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // group, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(xq, packed)


def _fused_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, codec: str,
                  k_steps: int):
    """Integer accumulate in VMEM scratch; rescale + emit on the last K step.

    xs_ref: (bm, 1) f32 per-row activation scale (act_quant convention:
            dequant = xq / scale, so the epilogue *divides* by it);
    ws_ref: (1, bn) f32 per-column weight scale (dequant = acc * scale).
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    decode = _decode2_block if codec == "pack2" else _decode243_block
    trits = decode(w_ref[...])  # (bk, bn) int8 in {-1,0,+1}
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        trits,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(kk == k_steps - 1)
    def _epilogue():
        # y = acc * (w_scale / x_scale), computed entirely in VMEM: the
        # (M, N) int32 accumulator never round-trips through HBM.
        y = acc_ref[...].astype(jnp.float32) * (ws_ref[...] / xs_ref[...])
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("codec", "block_m", "block_n", "block_k", "out_dtype",
                     "interpret"),
)
def ternary_matmul_fused_pallas(
    xq: jax.Array,
    packed: jax.Array,
    x_scale: jax.Array,
    col_scale: jax.Array,
    *,
    codec: str = "pack2",
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """(M, K) int8 x packed (K/g, N) uint8 -> (M, N) float, epilogue-fused.

    ``x_scale``: (M, 1) f32 per-row activation scale; ``col_scale``: (1, N)
    f32 per-column weight scale. Shapes must already be padded to block
    multiples (ops.py handles padding; padded x_scale rows must be nonzero).
    """
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    assert block_k % group == 0, (block_k, group)
    m, k = xq.shape
    kp, n = packed.shape
    assert kp * group == k, (kp, group, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (m, n, k)
    assert x_scale.shape == (m, 1), x_scale.shape
    assert col_scale.shape == (1, n), col_scale.shape

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_fused_kernel, codec=codec, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // group, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(xq, packed, x_scale.astype(jnp.float32), col_scale.astype(jnp.float32))
