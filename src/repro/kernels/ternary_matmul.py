"""Pallas TPU kernel: packed-ternary matmul (the TriMLA/BiROMA analogue).

Structure mirrors the paper's local-then-global accumulation (§III-B):

  * the grid's K dimension streams packed trit tiles HBM -> VMEM;
  * each (bm, bn) output block keeps an int32 *local accumulator* in VMEM
    that is updated once per K tile (the TriMLA), never per input bit;
  * the final K step leaves the completed sum — one "global" result per
    block, the one-shot adder-tree pass.

Trits arrive packed (2 bits or base-243, see core/packing.py) and are
decoded *inside* VMEM, so HBM traffic is 0.25 (pack2) or 0.2 (pack243)
bytes per weight — the kernel-level expression of "weights never move".
The ternary MAC itself ({-1,0,+1} weights) rides the MXU int8 datapath:
values -1/0/+1 in int8 make the dot product exactly the add/sub/skip of
the TriMLA truth table (verified bit-exactly against ref.py).

Block shapes default to MXU-aligned (multiples of 128 on M/N, K tiles
sized so the packed rows stay lane-aligned). VMEM footprint per step:
  x tile (bm, bk) int8 + packed tile (bk/g, bn) uint8
  + decoded (bk, bn) int8 + acc (bm, bn) int32
e.g. bm=bn=256, bk=512 (pack2): 128K + 32K + 128K + 256K = 544 KiB << 16 MiB VMEM.

Three entry points:

  * ``ternary_matmul_pallas`` — raw int32 accumulator out (kept for the
    bit-exactness oracle tests and as the building block);
  * ``ternary_matmul_fused_pallas`` — the *known-scale* fast path: the same
    integer pipeline plus a *fused epilogue*. The int32 local accumulator
    lives in VMEM scratch; on the final K step it is rescaled in VMEM by
    the per-column weight scale and per-row activation scale and written
    out directly as f32/bf16. The (M, N) int32 accumulator never exists
    in HBM and the separate XLA rescale pass disappears — one kernel
    launch goes activations-int8 -> scaled float output. Per-column
    (rather than per-tensor) weight scales are what lets fused QKV /
    gate-up projections (models/pack.py::fuse_packed) ride the same
    kernel: each output segment keeps its own absmean scale.
  * ``ternary_matmul_actq_pallas`` — the production fast path: epilogue
    fusion PLUS a *fused act-quant prologue*. The kernel consumes RAW
    bf16/f32 activations; a two-phase grid first sweeps K accumulating the
    per-row absmax into VMEM scratch (phase 0), converts it to the int8
    scale on the last phase-0 step, then re-streams the K tiles and runs
    the quantized int8 x ternary accumulate (phase 1) with the epilogue
    rescale on its final step. The separate XLA act-quant pass — one HBM
    read of the bf16 activations plus a write AND re-read of the (M, K)
    int8 intermediate per projection — disappears entirely; the int8
    activations only ever exist in VMEM, mirroring BitROM's fully-fused
    CiROM datapath where the quantizer sits in front of the ROM read
    pipeline. A leading batch grid dimension makes the same kernel the
    E-loop *expert* kernel: one launch covers all E experts of an MoE
    layer (grid (E, gm, gn, 2, gk)) instead of E vmapped launches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from repro.core.ternary import EPS


def _decode2_block(wp: jax.Array) -> jax.Array:
    """(bk/4, bn) uint8 -> (bk, bn) int8 trits (2-bit codes, LSB=+, MSB=-)."""
    parts = []
    for i in range(packing.PACK2_GROUP):
        c = (wp >> (2 * i)) & 0b11
        parts.append(((c & 1).astype(jnp.int8) - ((c >> 1) & 1).astype(jnp.int8)))
    stacked = jnp.stack(parts, axis=1)  # (bk/4, 4, bn)
    return stacked.reshape(stacked.shape[0] * packing.PACK2_GROUP, stacked.shape[2])


def _decode243_block(wp: jax.Array) -> jax.Array:
    """(bk/5, bn) uint8 -> (bk, bn) int8 trits via repeated divmod-3."""
    v = wp.astype(jnp.int16)
    parts = []
    for _ in range(packing.PACK243_GROUP):
        parts.append((v % 3 - 1).astype(jnp.int8))
        v = v // 3
    stacked = jnp.stack(parts, axis=1)  # (bk/5, 5, bn)
    return stacked.reshape(stacked.shape[0] * packing.PACK243_GROUP, stacked.shape[2])


def _kernel(x_ref, w_ref, o_ref, *, codec: str, k_steps: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    decode = _decode2_block if codec == "pack2" else _decode243_block
    trits = decode(w_ref[...])  # (bk, bn) int8 in {-1,0,+1}
    x = x_ref[...]  # (bm, bk) int8
    # TriMLA: {-1,0,+1} weights => signed add / skip; on MXU this is an
    # int8 x int8 -> int32 dot with trit operands.
    acc = jax.lax.dot_general(
        x,
        trits,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("codec", "block_m", "block_n", "block_k", "interpret"),
)
def ternary_matmul_pallas(
    xq: jax.Array,
    packed: jax.Array,
    *,
    codec: str = "pack2",
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(M, K) int8 x packed (K/g, N) uint8 -> (M, N) int32.

    M, N, K must already be padded to block multiples (ops.py handles
    padding); block_k must be a multiple of the codec group (4 or 5).
    """
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    assert block_k % group == 0, (block_k, group)
    m, k = xq.shape
    kp, n = packed.shape
    assert kp * group == k, (kp, group, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (m, n, k)

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, codec=codec, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // group, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(xq, packed)


def _fused_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, codec: str,
                  k_steps: int):
    """Integer accumulate in VMEM scratch; rescale + emit on the last K step.

    xs_ref: (bm, 1) f32 per-row activation scale (act_quant convention:
            dequant = xq / scale, so the epilogue *divides* by it);
    ws_ref: (1, bn) f32 per-column weight scale (dequant = acc * scale).
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    decode = _decode2_block if codec == "pack2" else _decode243_block
    trits = decode(w_ref[...])  # (bk, bn) int8 in {-1,0,+1}
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        trits,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(kk == k_steps - 1)
    def _epilogue():
        # y = acc * (w_scale / x_scale), computed entirely in VMEM: the
        # (M, N) int32 accumulator never round-trips through HBM.
        y = acc_ref[...].astype(jnp.float32) * (ws_ref[...] / xs_ref[...])
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("codec", "block_m", "block_n", "block_k", "out_dtype",
                     "interpret"),
)
def ternary_matmul_fused_pallas(
    xq: jax.Array,
    packed: jax.Array,
    x_scale: jax.Array,
    col_scale: jax.Array,
    *,
    codec: str = "pack2",
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """(M, K) int8 x packed (K/g, N) uint8 -> (M, N) float, epilogue-fused.

    ``x_scale``: (M, 1) f32 per-row activation scale; ``col_scale``: (1, N)
    f32 per-column weight scale. Shapes must already be padded to block
    multiples (ops.py handles padding; padded x_scale rows must be nonzero).
    """
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    assert block_k % group == 0, (block_k, group)
    m, k = xq.shape
    kp, n = packed.shape
    assert kp * group == k, (kp, group, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (m, n, k)
    assert x_scale.shape == (m, 1), x_scale.shape
    assert col_scale.shape == (1, n), col_scale.shape

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_fused_kernel, codec=codec, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // group, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(xq, packed, x_scale.astype(jnp.float32), col_scale.astype(jnp.float32))


def _fused_batched_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *,
                          codec: str, k_steps: int):
    """Known-scale fused body on the E-loop grid (B, gm, gn, gk): the
    carried-scale twin of the two-phase expert kernel — same integer
    pipeline and epilogue as ``_fused_kernel``, leading batch dimension
    like ``_actq_kernel``, no absmax phase (the caller already owns the
    per-row scale)."""
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    decode = _decode2_block if codec == "pack2" else _decode243_block
    trits = decode(w_ref[0])  # (bk, bn) int8 in {-1,0,+1}
    acc_ref[...] += jax.lax.dot_general(
        x_ref[0],
        trits,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(kk == k_steps - 1)
    def _epilogue():
        y = acc_ref[...].astype(jnp.float32) * (ws_ref[0] / xs_ref[0])
        o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("codec", "block_m", "block_n", "block_k", "out_dtype",
                     "interpret"),
)
def ternary_matmul_fused_batched_pallas(
    xq: jax.Array,
    packed: jax.Array,
    x_scale: jax.Array,
    col_scale: jax.Array,
    *,
    codec: str = "pack2",
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """(B, M, K) int8 x packed (B, K/g, N) uint8 -> (B, M, N) float.

    The *carried-scale* E-loop kernel: one launch covers every batch row
    (B = E experts) with epilogue fusion, taking already-quantized int8
    activations plus their per-row scale — the ``fuse_act_quant=False`` /
    ``QuantizedActivation`` form of ``ternary_matmul_actq_pallas``.
    ``x_scale``: (B, M, 1) f32; ``col_scale``: (B, 1, N) f32. Shapes must
    already be padded to block multiples (ops.py handles padding; padded
    x_scale rows must be nonzero).
    """
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    assert block_k % group == 0, (block_k, group)
    b, m, k = xq.shape
    bb, kp, n = packed.shape
    assert bb == b and kp * group == k, (bb, b, kp, group, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (m, n, k)
    assert x_scale.shape == (b, m, 1), x_scale.shape
    assert col_scale.shape == (b, 1, n), col_scale.shape

    grid = (b, m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_fused_batched_kernel, codec=codec, k_steps=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k), lambda b, i, j, kk: (b, i, kk)),
            pl.BlockSpec((1, block_k // group, block_n),
                         lambda b, i, j, kk: (b, kk, j)),
            pl.BlockSpec((1, block_m, 1), lambda b, i, j, kk: (b, i, 0)),
            pl.BlockSpec((1, 1, block_n), lambda b, i, j, kk: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda b, i, j, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(xq, packed, x_scale.astype(jnp.float32), col_scale.astype(jnp.float32))


def _actq_kernel(x_ref, w_ref, ws_ref, o_ref, scale_ref, acc_ref, *,
                 codec: str, k_steps: int, qmax: float, qmin: float):
    """Two-phase body: absmax K-sweep (phase 0), quantized accumulate +
    epilogue (phase 1).

    Grid is (B, gm, gn, 2, gk); ``scale_ref`` is (bm, 1) f32 VMEM scratch
    that holds the running per-row absmax during phase 0 and the finished
    int8 scale (``qmax / max(absmax, EPS)`` — the exact ``act_quant``
    rule) from the last phase-0 step onward. Scratch persists across grid
    steps, so the absmax sweep runs ONCE per row tile — at j == 0 — and
    every later output-column tile j > 0 reuses the finished scale (its
    phase-0 steps are no-ops with the x BlockSpec parked, see the entry
    point). Quantization happens on the re-streamed raw tile in phase 1,
    so the int8 activations never exist outside VMEM. Zero-padded rows
    quantize to all-zero int8 rows (absmax 0 -> huge scale ->
    round(0 * scale) = 0), so no separate pad-scale repair is needed.
    """
    j = pl.program_id(2)
    p = pl.program_id(3)
    kk = pl.program_id(4)
    sweep = (p == 0) & (j == 0)

    @pl.when(sweep & (kk == 0))
    def _init_absmax():
        scale_ref[...] = jnp.zeros_like(scale_ref)

    @pl.when(sweep)
    def _absmax_sweep():
        x = x_ref[0].astype(jnp.float32)
        scale_ref[...] = jnp.maximum(
            scale_ref[...], jnp.max(jnp.abs(x), axis=1, keepdims=True)
        )

    @pl.when(sweep & (kk == k_steps - 1))
    def _finalize_scale():
        # act_quant convention: scale = qmax / max(absmax, EPS); dequant
        # divides by it, so the epilogue below divides too.
        scale_ref[...] = qmax / jnp.maximum(scale_ref[...], EPS)

    @pl.when((p == 1) & (kk == 0))
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(p == 1)
    def _quantized_accumulate():
        x = x_ref[0].astype(jnp.float32)
        xq = jnp.clip(jnp.round(x * scale_ref[...]), qmin, qmax).astype(jnp.int8)
        decode = _decode2_block if codec == "pack2" else _decode243_block
        trits = decode(w_ref[0])  # (bk, bn) int8 in {-1,0,+1}
        acc_ref[...] += jax.lax.dot_general(
            xq,
            trits,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    @pl.when((p == 1) & (kk == k_steps - 1))
    def _epilogue():
        y = acc_ref[...].astype(jnp.float32) * (ws_ref[0] / scale_ref[...])
        o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("codec", "act_bits", "block_m", "block_n", "block_k",
                     "out_dtype", "interpret"),
)
def ternary_matmul_actq_pallas(
    x: jax.Array,
    packed: jax.Array,
    col_scale: jax.Array,
    *,
    codec: str = "pack2",
    act_bits: int = 8,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """(B, M, K) raw float x packed (B, K/g, N) uint8 -> (B, M, N) float.

    Act-quant-prologue + epilogue fused (see module docstring). ``x`` is the
    RAW bf16/f32 activation (already zero-padded to block multiples —
    ops.py handles padding); ``col_scale`` is (B, 1, N) f32 per-column
    weight scale. B = 1 for ordinary projections; B = E runs the E-loop
    expert grid (one launch over all experts, each with its own packed
    weights and column scales).
    """
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    assert block_k % group == 0, (block_k, group)
    b, m, k = x.shape
    bb, kp, n = packed.shape
    assert bb == b and kp * group == k, (bb, b, kp, group, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (m, n, k)
    assert col_scale.shape == (b, 1, n), col_scale.shape
    if act_bits == 8:
        qmax, qmin = 127.0, -128.0
    elif act_bits == 4:
        qmax, qmin = 7.0, -8.0
    else:  # mirror act_quant so pallas and xla reject identically
        raise ValueError(f"unsupported activation bits: {act_bits}")

    grid = (b, m // block_m, n // block_n, 2, k // block_k)
    return pl.pallas_call(
        functools.partial(_actq_kernel, codec=codec, k_steps=grid[4],
                          qmax=qmax, qmin=qmin),
        grid=grid,
        in_specs=[
            # x streams its K blocks only when the step does real work:
            # phase 1 (quantized accumulate) and the single absmax sweep
            # (phase 0 at j == 0). All other phase-0 steps park on block
            # (b, i, 0) — the pipeline elides copies when consecutive
            # steps map to the same block — so the raw activations cross
            # HBM gn+1 times, not 2*gn.
            pl.BlockSpec(
                (1, block_m, block_k),
                lambda b, i, j, p, kk: (
                    b, i, jnp.where((p == 1) | (j == 0), kk, 0)
                ),
            ),
            # same trick for the packed weights, parked during ALL of
            # phase 0: the trits stream through HBM once (phase 1), not
            # twice — the absmax sweep only ever reads x.
            pl.BlockSpec((1, block_k // group, block_n),
                         lambda b, i, j, p, kk: (b, kk * p, j)),
            pl.BlockSpec((1, 1, block_n), lambda b, i, j, p, kk: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda b, i, j, p, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((block_m, block_n), jnp.int32),
        ],
        interpret=interpret,
    )(x, packed, col_scale.astype(jnp.float32))


# ---------------------------------------------------------------------------
# ABFT weight checksums (serving SDC detection — docs/kernels.md)
# ---------------------------------------------------------------------------


def abft_wsum(packed: jax.Array, k: int, codec: str,
              scale: jax.Array) -> jax.Array:
    """Scale-weighted per-row (contraction-axis) ABFT checksum vector.

    For a packed ternary weight ``W`` of logical shape (K, N) with
    per-column scale ``s`` (a scalar broadcasts), returns the (K,)
    float32 vector ``wsum[k] = sum_n trit[k, n] * s[n]``. Leading stack
    dims (layer scan, experts) are vmapped through.

    This is the classic algorithm-based fault-tolerance column checksum
    specialized to the ternary pipeline: because
    ``y = (x_q @ trits) * s / x_scale``, the predicted output row-sum is
    ``sum_n y[r, n] = (x_q[r, :] @ wsum) / x_scale[r]`` — one GEMV per
    check, a factor-N cheaper than the matmul it guards. A flipped trit
    at row ``k`` shifts the prediction by ``±x_q[r, k] * s`` (±2 for a
    −1↔+1 flip), so any activation with a nonzero quant at that row
    exposes the fault; rows where every activation quantizes to zero are
    the checksum's blind spot, covered by the exact crc scrub
    (``core/packing.packed_crc32``).

    Computed once at pack time (models/pack.py) from the SAME packed
    words the kernels decode, so a post-pack flip is a disagreement
    between checksum and weight — exactly what the check detects.
    """
    unpack = packing.unpack2 if codec == "pack2" else packing.unpack243

    def one(p2, s):
        trits = unpack(p2)[:k].astype(jnp.float32)
        sv = jnp.asarray(s, jnp.float32)
        if sv.ndim == 0:
            return jnp.sum(trits, axis=-1) * sv
        return trits @ sv

    fn = one
    for _ in range(packed.ndim - 2):
        fn = jax.vmap(fn)
    return fn(packed, scale)
