"""Pallas flash-prefill attention over the tiered DR KV cache (paper §IV).

The prefill-side twin of ``kernels/flash_decode.py``. Prefill dominates
admission latency and — per BitROM's DR-eDRAM accounting — generates the
entire KV-cache *write* traffic, yet until this kernel it ran the pure-XLA
``blockwise_attention`` scan followed by a separate whole-sequence
cache-fill pass (``transformer._fill_attn_cache``: a one-hot einsum
scatter over the full (s, capacity) product), with q/k RoPE as separate
XLA passes materializing rotated HBM copies of the full (b, s, h, hd)
tensors. This kernel streams instead:

  * **grid (batch, kv_group, q_blocks, kv_stream)** — for each q block
    the innermost dimension walks the hot tier's S-blocks, then the cold
    tier's, then the *chunk's own* k/v blocks, carrying the online-softmax
    state (running max / denominator / numerator) in VMEM scratch. Cache
    prefix and fresh chunk merge in ONE launch; the tiers are never
    concatenated and the DR structure stays intact.
  * **RoPE in the kernel prologue** — q blocks rotate once per q block
    into VMEM scratch, k blocks rotate as they stream; positions come
    from the per-slot ``q_offset`` (= ``cache.lengths``) scalar-prefetch
    operand. No pre-rotated (b, s, h, hd) HBM copies exist. The rotation
    reproduces ``layers.apply_rope`` bit-for-bit (same freqs expression,
    same f32 arithmetic, same cast-back), which is what makes the emitted
    cache rows bit-identical to the XLA fill path.
  * **causal skip** — a kv block of the chunk that lies entirely in the
    upper triangle of a q block (``k_start > q_block_end``) is skipped in
    the body (``pl.when``) and its BlockSpec index *parks* on the last
    causally-live block (the flash-decode lengths trick applied to the
    causal structure): roughly half the chunk's KV copies are elided.
    Per-slot ``valid`` lengths predicate the tail the same way, so a slot
    whose prompt chunk is only partially real streams only that part.
  * **cache-fill epilogue** — with ``emit_kv=True`` the kernel emits the
    rotated k and the v of the chunk *in the cache tier's storage dtype*
    (fp8(e4m3) tiers quantize per block in VMEM), written once while the
    last q block streams the chunk. Placement into the hot/cold tiers is
    then a static slice (aligned prefill) or the masked per-slot scatter
    ``kv_cache.append(..., valid=, ring=)`` (chunked continuation) — the
    one-hot whole-sequence fill pass of ``_fill_attn_cache`` disappears
    from the serving path.

Two attention layouts share the kernel:

  * GQA/MQA (+ SWA windows): ``rep`` query heads per kv group fold into
    the q rows of a block (a q tile is (block_q · rep, hd));
  * MLA (non-absorbed prefill): g = h, rep = 1, ``rope_dims`` restricts
    the rotation to the trailing rope dims of the (nope ‖ rope) head,
    ``emit_kv=False`` (the latent cache row is not the per-head k; the
    caller stores the latent separately).

``q_offset`` continuation + per-slot ``valid`` are what let the serving
engine stream **chunked prefill**: mixed-length prompts admit as
fixed-shape (slots, chunk) dispatches against the live cache — one
compile total (see serving/engine.py and docs/serving.md).

Dispatch follows ``impl`` ("auto" → Pallas on TPU, XLA elsewhere — the
``qops.resolve_impl`` rule). The XLA fallback composes the existing
pieces: ``layers.apply_rope`` + ``kv_cache.tiered_chunk_attention`` (the
fp32 reference; for fresh aligned prefill, ``attention.blockwise_attention``
remains the production XLA path — see models/attention.py). S/Q block
sizes come from ``kernels/ops.select_blocks(kind="prefill_attn")``.

Numerical conventions match flash-decode: masked logits use
``finfo(f32).min``, the final division guards with 1e-30, and partial
S-block rows are masked *before* the PV matmul (interpret mode pads
partial blocks with uninitialized values).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import kv_cache as kvc
from repro.kernels import ops
from repro.kernels.flash_decode import (
    _interpret,
    _resolve,
    _rope_rows,
    _tier_blocks,
)

NEG_INF = jnp.finfo(jnp.float32).min


def rope_trailing(x, positions, rope_dims: int, theta: float):
    """XLA twin of the in-kernel rotation: rotate the trailing
    ``rope_dims`` dims of x (..., T, H, D) at ``positions`` (..., T) via
    the shared ``layers.apply_rope`` (bit-identical numerics)."""
    from repro.models.layers import apply_rope

    d = x.shape[-1]
    if rope_dims == d:
        return apply_rope(x, positions, theta)
    rot = apply_rope(x[..., d - rope_dims:], positions, theta)
    return jnp.concatenate([x[..., : d - rope_dims], rot], axis=-1)


# ---------------------------------------------------------------------------
# Kernel body
# ---------------------------------------------------------------------------


def _kernel_prefill(lens_ref, valid_ref, q_ref, hk_ref, hv_ref, ck_ref,
                    cv_ref, kn_ref, vn_ref, *refs, scale, n_hot, n_cold,
                    hot_cap, cold_cap, bq, rep, window, ring, rope_dims,
                    theta, emit_kv, k_in_dtype, v_in_dtype):
    """Grid (b, g, q_blocks, kv_stream): hot blocks, cold blocks, then the
    chunk's own kv blocks; scratch carries the online softmax across the
    innermost dimension (re-initialized per q block)."""
    if emit_kv:
        o_ref, ko_ref, vo_ref, m_scr, l_scr, acc_scr, q_scr = refs
    else:
        o_ref, m_scr, l_scr, acc_scr, q_scr = refs
    b_i = pl.program_id(0)
    qi = pl.program_id(2)
    kk = pl.program_id(3)
    nq = pl.num_programs(2)
    nk = pl.num_programs(3)
    offset = lens_ref[b_i]  # tokens already cached = q_offset
    nv = valid_ref[b_i]  # valid rows of this slot's chunk
    rows = bq * rep
    # chunk-token index of each q row (rep query heads fold per token)
    q_tok = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // rep
    q_pos = offset + q_tok  # absolute position

    @pl.when(kk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        q_scr[...] = _rope_rows(
            q_ref[0, 0].astype(jnp.float32), q_pos, rope_dims, theta
        )

    def update(k_tile, v_tile, mask, col_valid):
        """One streamed block: k/v (bs, d*) f32, mask (rows|1, bs) bool,
        col_valid (bs, 1) bool — zeroes uninitialized partial-block v rows
        before the PV matmul (interpret pads with NaN; 0 · NaN = NaN)."""
        q = q_scr[...]
        logits = jax.lax.dot_general(
            q, k_tile, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (rows, bs)
        mask = jnp.broadcast_to(mask, logits.shape)
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new) * mask.astype(jnp.float32)
        alpha = jnp.exp(m_prev - m_new)
        v_safe = jnp.where(col_valid, v_tile, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v_safe, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    # ---- hot tier (absolute positions 0..hot_cap) --------------------
    n_hot_valid = jnp.minimum(offset, hot_cap)
    bs_hot = hk_ref.shape[1]
    start_hot = kk * bs_hot

    @pl.when((kk < n_hot) & (start_hot < n_hot_valid))
    def _hot():
        jcol = start_hot + jax.lax.broadcasted_iota(jnp.int32, (1, bs_hot), 1)
        mask = jcol < n_hot_valid  # causal is automatic: pos < offset <= q_pos
        if window:
            mask = mask & ((q_pos - jcol) < window)
        jrow = start_hot + jax.lax.broadcasted_iota(jnp.int32, (bs_hot, 1), 0)
        update(hk_ref[0].astype(jnp.float32), hv_ref[0].astype(jnp.float32),
               mask, jrow < n_hot_valid)

    # ---- cold tier (linear: hot_cap+j; ring: wrapped SWA layout) -----
    n_cold_valid = jnp.clip(offset - hot_cap, 0, cold_cap)
    bs_cold = ck_ref.shape[1]
    start_cold = (kk - n_hot) * bs_cold

    @pl.when((kk >= n_hot) & (kk < n_hot + n_cold) & (start_cold < n_cold_valid))
    def _cold():
        jcol = start_cold + jax.lax.broadcasted_iota(jnp.int32, (1, bs_cold), 1)
        jrow = start_cold + jax.lax.broadcasted_iota(jnp.int32, (bs_cold, 1), 0)
        if ring:
            # ring slot j holds the largest p < offset with p ≡ j (mod
            # cap). Bound j at cold_cap explicitly: the modulo would wrap
            # a partial last block's out-of-range padding columns back
            # into seemingly-valid positions (uninitialized k/v rows).
            kpos = offset - 1 - ((offset - 1 - jcol) % cold_cap)
            mask = (kpos >= 0) & (jcol < cold_cap)
            col_valid = (
                (offset - 1 - ((offset - 1 - jrow) % cold_cap)) >= 0
            ) & (jrow < cold_cap)
        else:
            kpos = hot_cap + jcol
            mask = jcol < n_cold_valid
            col_valid = jrow < n_cold_valid
        if window:
            mask = mask & ((q_pos - kpos) < window)
        update(ck_ref[0].astype(jnp.float32), cv_ref[0].astype(jnp.float32),
               mask, col_valid)

    # ---- the chunk's own kv blocks (causal skip + valid predication) -
    bs_new = kn_ref.shape[1]
    start_new = (kk - n_hot - n_cold) * bs_new
    q_hi = qi * bq + bq - 1  # last chunk token of this q block

    @pl.when((kk >= n_hot + n_cold) & (start_new < nv) & (start_new <= q_hi))
    def _new():
        ccol = start_new + jax.lax.broadcasted_iota(jnp.int32, (1, bs_new), 1)
        crow = start_new + jax.lax.broadcasted_iota(jnp.int32, (bs_new, 1), 0)
        k_tile = _rope_rows(
            kn_ref[0].astype(jnp.float32), offset + crow, rope_dims, theta
        )
        mask = (ccol < nv) & (q_tok >= ccol)
        if window:
            mask = mask & ((q_tok - ccol) < window)
        update(k_tile, vn_ref[0].astype(jnp.float32), mask, crow < nv)

    @pl.when(kk == nk - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)

    # ---- cache-fill epilogue: the last q block streams every live chunk
    # block anyway (causal), so emit the rotated k / v in tier storage
    # dtype as it passes — rows past ``valid`` zero out (parked blocks
    # hold stale tiles; their `keep` mask is all-false).
    if emit_kv:

        @pl.when((qi == nq - 1) & (kk >= n_hot + n_cold))
        def _emit():
            crow = start_new + jax.lax.broadcasted_iota(
                jnp.int32, (bs_new, 1), 0
            )
            keep = crow < nv
            k_rot = _rope_rows(
                kn_ref[0].astype(jnp.float32), offset + crow, rope_dims, theta
            )
            # cast through the activation dtype first: bit-identical to
            # apply_rope (returns k.dtype) followed by the tier-dtype cast
            ko_ref[0] = jnp.where(keep, k_rot, 0.0).astype(k_in_dtype).astype(
                ko_ref.dtype
            )
            vo_ref[0] = jnp.where(
                keep, vn_ref[0].astype(jnp.float32), 0.0
            ).astype(v_in_dtype).astype(vo_ref.dtype)


# ---------------------------------------------------------------------------
# Launch
# ---------------------------------------------------------------------------


def _flash_prefill(q, k_new, v_new, cache, valid, scale, window, ring,
                   rope_dims, theta, emit_kv, kv_dtype, block_q, block_s,
                   interpret):
    b, c, h, dk = q.shape
    g = k_new.shape[2]
    rep = h // g
    assert rep * g == h, (h, g)
    dv = v_new.shape[-1]
    if block_q is None or block_s is None:
        # table key: grouped q rows when rep > 1; for rep = 1 forms (MLA,
        # plain MHA) the head count drives the row — the decode_attn
        # convention, where the wide-head latent form passes h
        auto = ops.select_blocks(
            rep if rep > 1 else h, max(dk, dv), c, "pack2",
            kind="prefill_attn",
        )
        block_q = block_q or auto[0]
        block_s = block_s or auto[2]
    bq = min(block_q, c)
    nq = pl.cdiv(c, bq)
    cq = nq * bq
    bs_new = min(block_s, c)
    n_new = pl.cdiv(c, bs_new)
    ck_len = n_new * bs_new

    paged = isinstance(cache, kvc.PagedKVCache)
    if cache is None:
        hot_cap = cold_cap = 0
        lens = jnp.zeros((b,), jnp.int32)
        hot_k = hot_v = cold_k = cold_v = None
        tier_dt = k_new.dtype
    else:
        hot_cap, cold_cap = cache.hot_cap, cache.cold_cap
        lens = cache.lengths.astype(jnp.int32)
        hot_k, hot_v = cache.hot_k, cache.hot_v
        cold_k, cold_v = (None, None) if paged else (cache.cold_k,
                                                     cache.cold_v)
        tier_dt = cache.hot_k.dtype
    kv_dtype = kv_dtype or tier_dt

    def flat(t, d, cap):
        if t is None:
            return None
        return t.reshape(b, cap, g * d)

    hk, bs_hot, n_hot = _tier_blocks(
        flat(hot_k, dk, hot_cap), hot_cap, block_s, (b, 1, g * dk), tier_dt)
    hv, _, _ = _tier_blocks(
        flat(hot_v, dv, hot_cap), hot_cap, block_s, (b, 1, g * dv), tier_dt)
    if paged:
        # cold tier = the shared pool, one page per S-block; the per-slot
        # page table rides as a third scalar-prefetch operand and resolves
        # logical -> pool pages inside cold_map (flash_decode's scheme)
        assert not ring, "ring layout is not supported for paged caches"
        bs_cold, n_cold = cache.page_size, cache.pages_per_slot
        ck = cache.pool_k.reshape(cache.n_pages, bs_cold, g * dk)
        cv = cache.pool_v.reshape(cache.n_pages, bs_cold, g * dv)
    else:
        ck, bs_cold, n_cold = _tier_blocks(
            flat(cold_k, dk, cold_cap), cold_cap, block_s, (b, 1, g * dk),
            tier_dt)
        cv, _, _ = _tier_blocks(
            flat(cold_v, dv, cold_cap), cold_cap, block_s, (b, 1, g * dv),
            tier_dt)

    # q: (b, c, h, dk) -> (b, g, cq*rep, dk), token-major rows per block
    qt = jnp.moveaxis(q.reshape(b, c, g, rep, dk), 1, 2)  # (b, g, c, rep, dk)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, cq - c), (0, 0), (0, 0)))
    qt = qt.reshape(b, g, cq * rep, dk)
    kn = jnp.pad(
        k_new.reshape(b, c, g * dk), ((0, 0), (0, ck_len - c), (0, 0)))
    vn = jnp.pad(
        v_new.reshape(b, c, g * dv), ((0, 0), (0, ck_len - c), (0, 0)))

    def hot_map(b_i, g_i, qi, kk, lens, valid, *rest):
        nvalid = jnp.minimum(lens[b_i], hot_cap)
        nvb = jnp.maximum(pl.cdiv(nvalid, bs_hot), 1)
        return b_i, jnp.minimum(kk, nvb - 1), g_i

    if paged:

        def cold_map(b_i, g_i, qi, kk, lens, valid, pt):
            nvalid = jnp.clip(lens[b_i] - hot_cap, 0, cold_cap)
            nvb = jnp.maximum(pl.cdiv(nvalid, bs_cold), 1)
            kc = jnp.maximum(kk - n_hot, 0)
            return pt[b_i, jnp.minimum(kc, nvb - 1)], 0, g_i

    else:

        def cold_map(b_i, g_i, qi, kk, lens, valid, *rest):
            nvalid = jnp.clip(lens[b_i] - hot_cap, 0, cold_cap)
            nvb = jnp.maximum(pl.cdiv(nvalid, bs_cold), 1)
            kc = jnp.maximum(kk - n_hot, 0)
            return b_i, jnp.minimum(kc, nvb - 1), g_i

    def new_map(b_i, g_i, qi, kk, lens, valid, *rest):
        kn_i = jnp.maximum(kk - n_hot - n_cold, 0)
        causal_last = (qi * bq + bq - 1) // bs_new
        valid_last = jnp.maximum(pl.cdiv(valid[b_i], bs_new), 1) - 1
        return b_i, jnp.minimum(kn_i, jnp.minimum(causal_last, valid_last)), g_i

    def emit_map(b_i, g_i, qi, kk, lens, valid, *rest):
        kn_i = jnp.clip(kk - n_hot - n_cold, 0, n_new - 1)
        return b_i, jnp.where(qi == nq - 1, kn_i, 0), g_i

    def q_map(b_i, g_i, qi, kk, lens, valid, *rest):
        return b_i, g_i, qi, 0

    in_specs = [
        pl.BlockSpec((1, 1, bq * rep, dk), q_map),
        pl.BlockSpec((1, bs_hot, dk), hot_map),
        pl.BlockSpec((1, bs_hot, dv), hot_map),
        pl.BlockSpec((1, bs_cold, dk), cold_map),
        pl.BlockSpec((1, bs_cold, dv), cold_map),
        pl.BlockSpec((1, bs_new, dk), new_map),
        pl.BlockSpec((1, bs_new, dv), new_map),
    ]
    out_shapes = [jax.ShapeDtypeStruct((b, g, cq * rep, dv), q.dtype)]
    out_specs = [
        pl.BlockSpec((1, 1, bq * rep, dv), q_map),
    ]
    if emit_kv:
        out_shapes += [
            jax.ShapeDtypeStruct((b, ck_len, g * dk), kv_dtype),
            jax.ShapeDtypeStruct((b, ck_len, g * dv), kv_dtype),
        ]
        out_specs += [
            pl.BlockSpec((1, bs_new, dk), emit_map),
            pl.BlockSpec((1, bs_new, dv), emit_map),
        ]

    prefetch = (lens, valid)
    body = functools.partial(
        _kernel_prefill, scale=scale, n_hot=n_hot, n_cold=n_cold,
        hot_cap=hot_cap, cold_cap=cold_cap, bq=bq, rep=rep,
        window=window, ring=ring, rope_dims=rope_dims, theta=theta,
        emit_kv=emit_kv, k_in_dtype=k_new.dtype, v_in_dtype=v_new.dtype,
    )
    if paged:
        prefetch = (lens, valid, cache.page_table.astype(jnp.int32))
        kern = lambda lens_ref, valid_ref, pt_ref, *rest: body(  # noqa: E731
            lens_ref, valid_ref, *rest)
    else:
        kern = body
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(b, g, nq, n_hot + n_cold + n_new),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((bq * rep, 1), jnp.float32),
            pltpu.VMEM((bq * rep, 1), jnp.float32),
            pltpu.VMEM((bq * rep, dv), jnp.float32),
            pltpu.VMEM((bq * rep, dk), jnp.float32),
        ],
    )
    outs = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(*prefetch, qt, hk, hv, ck, cv, kn, vn)

    o = outs[0].reshape(b, g, cq, rep, dv)[:, :, :c]
    o = jnp.moveaxis(o, 2, 1).reshape(b, c, h, dv)
    if not emit_kv:
        return o
    k_cast = outs[1][:, :c].reshape(b, c, g, dk)
    v_cast = outs[2][:, :c].reshape(b, c, g, dv)
    return o, k_cast, v_cast


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "ring", "rope_theta", "rope_dims",
                     "emit_kv", "kv_dtype", "impl", "block_q", "block_s",
                     "interpret"),
)
def flash_prefill_attention(
    q: jax.Array,  # (b, C, h, dk) — UNROTATED
    k_new: jax.Array,  # (b, C, g, dk) — UNROTATED
    v_new: jax.Array,  # (b, C, g, dv)
    cache: kvc.TieredKVCache | None = None,
    valid: jax.Array | None = None,  # (b,) valid chunk rows (default C)
    *,
    scale: float | None = None,
    window: int = 0,
    ring: bool = False,
    rope_theta: float = 1_000_000.0,
    rope_dims: int | None = None,  # None = whole head (GQA); MLA: rope dims
    emit_kv: bool = True,
    kv_dtype=None,  # tier storage dtype for the emitted k/v (default: cache's)
    impl: str = "auto",
    block_q: int | None = None,
    block_s: int | None = None,
    interpret: bool | None = None,
):
    """Causal/SWA prefill attention over [tiered cache prefix ‖ chunk].

    q/k arrive UNROTATED; RoPE happens inside (kernel prologue, or the
    shared ``apply_rope`` on the XLA path) at absolute positions
    ``cache.lengths[b] + row``. Returns ``(o, k_cast, v_cast)`` with the
    chunk's rotated k and its v cast to the tier storage dtype (rows past
    ``valid`` zeroed) when ``emit_kv``, else just ``o`` (b, C, h, dv).
    ``cache=None`` is the fresh aligned prefill (offset 0, no streamed
    tiers). ``impl``: "pallas" runs the streaming kernel (interpret mode
    on CPU), "xla" the ``kv_cache.tiered_chunk_attention`` reference,
    "auto" picks by backend.
    """
    impl = _resolve(impl)
    b, c, h, dk = q.shape
    scale = float(scale) if scale is not None else dk**-0.5
    rd = rope_dims if rope_dims is not None else dk
    if valid is None:
        valid = jnp.full((b,), c, jnp.int32)
    valid = valid.astype(jnp.int32)
    if impl == "pallas":
        return _flash_prefill(
            q, k_new, v_new, cache, valid, scale, window, ring, rd,
            float(rope_theta), emit_kv, kv_dtype, block_q, block_s,
            _interpret(interpret),
        )
    if impl != "xla":
        raise ValueError(f"unknown impl {impl!r}")
    offset = (
        cache.lengths.astype(jnp.int32)[:, None]
        if cache is not None else jnp.zeros((b, 1), jnp.int32)
    )
    positions = offset + jnp.arange(c, dtype=jnp.int32)[None]  # (b, C)
    q_rot = rope_trailing(q, positions, rd, rope_theta)
    k_rot = rope_trailing(k_new, positions, rd, rope_theta)
    o = kvc.tiered_chunk_attention(
        q_rot, k_rot, v_new, cache, valid, scale, window=window, ring=ring
    )
    if not emit_kv:
        return o
    tier_dt = kv_dtype or (cache.hot_k.dtype if cache is not None else k_new.dtype)
    keep = (jnp.arange(c, dtype=jnp.int32)[None] < valid[:, None])[..., None, None]
    k_cast = jnp.where(keep, k_rot, 0).astype(tier_dt)
    v_cast = jnp.where(keep, v_new, 0).astype(tier_dt)
    return o, k_cast, v_cast
