"""Pallas TPU kernels for the perf-critical ternary compute path.

  ternary_matmul — packed-trit decode + local-then-global accumulation;
                   raw int32 variant + the production epilogue-fused
                   variant (scales applied in VMEM, float out)
  flash_decode   — streaming online-softmax decode attention over the
                   tiered DR KV cache (per-slot length predication,
                   hot+cold merged in one launch)
  ops            — jit'd dispatch (pallas | xla) with padding/batching
                   and the shape-aware block-selection table
                   (select_blocks: skinny-M decode vs MXU-aligned prefill
                   vs decode_attn S-blocks)
  ref            — pure-jnp oracles
"""

from repro.kernels import flash_decode, ops, ref  # noqa: F401
