"""Pallas TPU kernels for the perf-critical ternary compute path.

  ternary_matmul — packed-trit decode + local-then-global accumulation
  ops            — jit'd dispatch (pallas | xla) with padding/batching
  ref            — pure-jnp oracles
"""

from repro.kernels import ops, ref  # noqa: F401
