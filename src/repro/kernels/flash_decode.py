"""Pallas flash-decode attention over the tiered DR KV cache (paper §IV).

The decode-side twin of the packed-ternary matmul fast path: with the
projections fused end to end, decode attention was the last XLA-shaped hot
path in the continuous-batching engine. The XLA reference
(``core/kv_cache.tiered_decode_attention``) materializes full
``(b, g, rep, capacity)`` logits over the *padded* hot+cold capacity every
step, upcasts entire fp8 tiers, and masks instead of skipping — a slot at
length 37 pays for the whole cache. This kernel streams instead:

  * **grid (batch, kv_group, s_blocks)** — the S dimension walks the hot
    tier's blocks first, then the cold tier's, carrying the online-softmax
    state (running max / denominator / numerator) in VMEM scratch, so both
    tiers merge *in one launch* with no two-pass HBM merge and no
    concatenated copy of the tiers (the DR structure stays intact);
  * **per-slot length predication** — ``cache.lengths`` rides in as a
    scalar-prefetch operand: fully-invalid S-blocks are skipped in the
    body (``pl.when``) and their BlockSpec indices *park* on the last
    valid block (the actq-prologue trick — consecutive steps that map to
    the same block elide the HBM→VMEM copy), so a slot streams only the
    KV bytes its own prefix occupies;
  * **per-block fp8 dequant** — fp8(e4m3) tiers are upcast tile-by-tile
    in VMEM; the bf16 copy of the whole tier that the XLA path
    materializes never exists;
  * **GQA folded into the q block** — the ``rep`` query heads of a kv
    group form the (rep, d) q tile of one grid row, so grouped heads
    share each streamed KV tile.

Three entry points, mirroring the attention variants:

  * ``flash_decode_attention``        — GQA/MQA over (k, v) tiers;
  * ``flash_decode_attention_latent`` — MLA absorbed form: the cache
    k-slot holds (c_kv ‖ k_rope); values are the latent *prefix* of the
    k-slot (first ``value_dim`` dims), sliced per block in VMEM;
  * ``flash_decode_attention_ring``   — ring/SWA cold tier. The math is
    identical (the clamped validity formula covers the wrapped layout:
    attention is permutation-invariant over KV positions, and once the
    window wraps every ring slot is valid); the entry point exists so
    call sites state their layout.

All dispatch through ``impl`` ("auto" → Pallas on TPU, XLA elsewhere —
the same rule as ``qops.resolve_impl``); the XLA fallbacks are the
existing ``kv_cache`` paths, bit-*tolerant* (fp32-reference parity to
tight tolerance — the merge order differs, so exact bit equality is not
the contract here, unlike the integer matmul kernels). S-block sizes come
from the kind-keyed table ``kernels/ops.select_blocks(kind="decode_attn")``.

Numerical edge cases share the XLA path's conventions: a slot with
length 0 (unadmitted) returns zeros; masked logits use ``finfo(f32).min``;
the final division guards with 1e-30. Out-of-range rows of a partial
S-block are masked *before* the PV matmul (Pallas pads partial blocks
with uninitialized values — 0·NaN would poison the accumulator).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import kv_cache as kvc
from repro.kernels import ops

NEG_INF = jnp.finfo(jnp.float32).min


def _resolve(impl: str) -> str:
    """"auto" → pallas on TPU, xla elsewhere (qops.resolve_impl's rule,
    minus the sharding hint — decode attention never runs under GSPMD
    hints; model code passes the config-resolved impl explicitly)."""
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _interpret(interpret) -> bool:
    return jax.default_backend() == "cpu" if interpret is None else interpret


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _rope_rows(x, pos, rope_dims: int, theta: float):
    """Rotate the trailing ``rope_dims`` dims of x (rows, d) f32 at ``pos``
    (rows, 1) int32 — the in-kernel RoPE prologue shared by flash-decode
    and flash-prefill. Reproduces ``layers.apply_rope`` bit-for-bit: the
    freqs exponent numerator 2i is formed exactly, the rotation uses the
    same half-split expressions, all in f32."""
    d = x.shape[-1]
    rd = rope_dims
    half = rd // 2
    base = x[:, d - rd:]
    two_i = jax.lax.broadcasted_iota(jnp.float32, (1, half), 1) * 2.0
    freqs = 1.0 / (theta ** (two_i / rd))  # (1, half)
    ang = pos.astype(jnp.float32) * freqs  # (rows, half)
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    x1 = base[:, :half]
    x2 = base[:, half:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if rd == d:
        return rot
    return jnp.concatenate([x[:, : d - rd], rot], axis=-1)


def _online_update(q, k_tile, v_tile, start, n_valid, scale,
                   m_scr, l_scr, acc_scr, extra_mask=None):
    """One S-block step of the streaming softmax.

    q: (bm, dk) f32; k_tile: (bs, dk) f32; v_tile: (bs, dv) f32;
    ``start`` is the block's first absolute position within its tier,
    ``n_valid`` the tier's per-slot valid length. Scratch: m/l (bm, 1),
    acc (bm, dv) — carried across the S grid dimension. ``extra_mask``
    (1, bs) bool further restricts validity (the fused-RoPE decode path
    masks the ring slot its append is about to evict).
    """
    logits = jax.lax.dot_general(
        q, k_tile, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale  # (bm, bs)
    pos = start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = pos < n_valid  # (bm, bs) — identical across rows
    if extra_mask is not None:
        valid &= extra_mask
    logits = jnp.where(valid, logits, NEG_INF)
    m_prev = m_scr[...]  # (bm, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
    p = jnp.exp(logits - m_new) * valid.astype(jnp.float32)
    alpha = jnp.exp(m_prev - m_new)  # (bm, 1); 0 on the first valid block
    # mask v BEFORE the dot: a partial block's out-of-range rows are
    # uninitialized (NaN in interpret mode) and 0 * NaN = NaN
    pos_col = start + jax.lax.broadcasted_iota(
        jnp.int32, (v_tile.shape[0], 1), 0
    )
    v_safe = jnp.where(pos_col < n_valid, v_tile, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v_safe, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new


def _kernel_gqa(lens_ref, q_ref, hk_ref, hv_ref, ck_ref, cv_ref, o_ref,
                m_scr, l_scr, acc_scr, *, scale, n_hot_blocks,
                hot_cap, cold_cap):
    """Grid (b, g, s_blocks): hot blocks [0, n_hot_blocks), cold after."""
    b_i = pl.program_id(0)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lens_ref[b_i]
    n_hot_valid = jnp.minimum(length, hot_cap)
    # clamped at cold_cap: covers both the linear layout (lengths never
    # exceed capacity) and the ring layout (wrapped window = all valid)
    n_cold_valid = jnp.clip(length - hot_cap, 0, cold_cap)
    q = q_ref[0, 0].astype(jnp.float32)  # (rep, dk)

    bs_hot = hk_ref.shape[1]
    start_hot = kk * bs_hot

    @pl.when((kk < n_hot_blocks) & (start_hot < n_hot_valid))
    def _hot():
        _online_update(
            q, hk_ref[0].astype(jnp.float32), hv_ref[0].astype(jnp.float32),
            start_hot, n_hot_valid, scale, m_scr, l_scr, acc_scr,
        )

    bs_cold = ck_ref.shape[1]
    start_cold = (kk - n_hot_blocks) * bs_cold

    @pl.when((kk >= n_hot_blocks) & (start_cold < n_cold_valid))
    def _cold():
        _online_update(
            q, ck_ref[0].astype(jnp.float32), cv_ref[0].astype(jnp.float32),
            start_cold, n_cold_valid, scale, m_scr, l_scr, acc_scr,
        )

    @pl.when(kk == pl.num_programs(2) - 1)
    def _finalize():
        # length-0 slot: l stays 0 -> output 0, matching the XLA path
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def _kernel_gqa_fused(lens_ref, act_ref, q_ref, hk_ref, hv_ref, ck_ref,
                      cv_ref, kn_ref, vn_ref, o_ref, ko_ref, m_scr, l_scr,
                      acc_scr, q_scr, *, scale, n_hot_blocks, hot_cap,
                      cold_cap, ring, theta):
    """The fused-RoPE twin of ``_kernel_gqa``: q and the pending token's
    k arrive UNROTATED and rotate in the prologue at position
    ``lens[b]``; the pending (k, v) joins the softmax as the final
    stream element for active slots (the cache append then happens
    *after* attention, consuming the rotated k this kernel emits). With
    ``ring=True`` the cold slot the append is about to evict is masked —
    the wrapped window [len-w+1, len] stays exact without pre-appending.
    """
    b_i = pl.program_id(0)
    kk = pl.program_id(2)
    length = lens_ref[b_i]
    active = act_ref[b_i] != 0

    @pl.when(kk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        q = q_ref[0, 0].astype(jnp.float32)  # (rep, dk)
        pos = jnp.full((q.shape[0], 1), length, jnp.int32)
        q_scr[...] = _rope_rows(q, pos, q.shape[-1], theta)

    n_hot_valid = jnp.minimum(length, hot_cap)
    n_cold_valid = jnp.clip(length - hot_cap, 0, cold_cap)
    q = q_scr[...]

    bs_hot = hk_ref.shape[1]
    start_hot = kk * bs_hot

    @pl.when((kk < n_hot_blocks) & (start_hot < n_hot_valid))
    def _hot():
        _online_update(
            q, hk_ref[0].astype(jnp.float32), hv_ref[0].astype(jnp.float32),
            start_hot, n_hot_valid, scale, m_scr, l_scr, acc_scr,
        )

    bs_cold = ck_ref.shape[1]
    start_cold = (kk - n_hot_blocks) * bs_cold

    @pl.when((kk >= n_hot_blocks) & (start_cold < n_cold_valid))
    def _cold():
        extra = None
        if ring:
            # the append (post-attention) will overwrite ring slot
            # (length - hot_cap) % cold_cap; once the window has wrapped
            # that slot holds position length - cold_cap — outside the
            # window of the token being decoded — so mask it out.
            j = start_cold + jax.lax.broadcasted_iota(
                jnp.int32, (1, bs_cold), 1
            )
            evictee = (length - hot_cap) % cold_cap
            wrapped = active & (length - hot_cap >= cold_cap)
            extra = ~(wrapped & (j == evictee))
        _online_update(
            q, ck_ref[0].astype(jnp.float32), cv_ref[0].astype(jnp.float32),
            start_cold, n_cold_valid, scale, m_scr, l_scr, acc_scr,
            extra_mask=extra,
        )

    @pl.when(kk == pl.num_programs(2) - 1)
    def _finalize():
        k_rot = _rope_rows(
            kn_ref[0].astype(jnp.float32),
            jnp.full((1, 1), length, jnp.int32),
            kn_ref.shape[-1], theta,
        )  # (1, dk)
        ko_ref[0] = k_rot.astype(ko_ref.dtype)

        @pl.when(active)
        def _pending():
            # the pending token attends to itself, position `length`
            _online_update(
                q, k_rot, vn_ref[0].astype(jnp.float32),
                0, 1, scale, m_scr, l_scr, acc_scr,
            )

        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def _kernel_latent(lens_ref, q_ref, hk_ref, ck_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale, n_hot_blocks,
                   hot_cap, cold_cap, value_dim):
    """MLA absorbed form, grid (b, s_blocks): values = k-slot latent prefix."""
    b_i = pl.program_id(0)
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lens_ref[b_i]
    n_hot_valid = jnp.minimum(length, hot_cap)
    n_cold_valid = jnp.clip(length - hot_cap, 0, cold_cap)
    q = q_ref[0].astype(jnp.float32)  # (h, D)

    bs_hot = hk_ref.shape[1]
    start_hot = kk * bs_hot

    @pl.when((kk < n_hot_blocks) & (start_hot < n_hot_valid))
    def _hot():
        k_tile = hk_ref[0].astype(jnp.float32)
        _online_update(q, k_tile, k_tile[:, :value_dim], start_hot,
                       n_hot_valid, scale, m_scr, l_scr, acc_scr)

    bs_cold = ck_ref.shape[1]
    start_cold = (kk - n_hot_blocks) * bs_cold

    @pl.when((kk >= n_hot_blocks) & (start_cold < n_cold_valid))
    def _cold():
        k_tile = ck_ref[0].astype(jnp.float32)
        _online_update(q, k_tile, k_tile[:, :value_dim], start_cold,
                       n_cold_valid, scale, m_scr, l_scr, acc_scr)

    @pl.when(kk == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Launch helpers
# ---------------------------------------------------------------------------


def _tier_blocks(buf, cap: int, block_s: int, dummy_shape, dummy_dtype):
    """Per-tier S blocking. A zero-capacity tier (SWA hot, max_len <=
    hot_cap cold) becomes a 1-token zeros dummy whose single block is
    never valid (the real cap still drives the validity formula), so the
    kernel arity stays fixed."""
    if cap == 0:
        return jnp.zeros(dummy_shape, dummy_dtype), 1, 1
    bs = min(block_s, cap)
    return buf, bs, pl.cdiv(cap, bs)


def _park_maps(hot_cap: int, cold_cap: int, bs_hot: int, bs_cold: int,
               n_hot: int):
    """Index maps for the tier refs: walk valid blocks, then park on the
    last valid one (consecutive identical indices elide the copy) for the
    rest of the S sweep — the block-level predication."""

    def hot_map(b_i, kk, lens):
        n_valid = jnp.minimum(lens[b_i], hot_cap)
        nvb = jnp.maximum(pl.cdiv(n_valid, bs_hot), 1)
        return b_i, jnp.minimum(kk, nvb - 1)

    def cold_map(b_i, kk, lens):
        n_valid = jnp.clip(lens[b_i] - hot_cap, 0, cold_cap)
        nvb = jnp.maximum(pl.cdiv(n_valid, bs_cold), 1)
        kc = jnp.maximum(kk - n_hot, 0)
        return b_i, jnp.minimum(kc, nvb - 1)

    return hot_map, cold_map


def _cold_operands(cache, g, dk, dv, block_s, b):
    """Cold-tier operands + blocking for a launch: contiguous caches use
    the per-slot (b, cold_cap, g*d) buffers with ``block_s`` S-blocks;
    paged caches stream the shared pool (n_pages, page_size, g*d) with
    one page per S-block — the per-slot page table turns into gather
    indices in the BlockSpec index map (``_paged_cold_map``)."""
    if isinstance(cache, kvc.PagedKVCache):
        ps = cache.page_size
        ck = cache.pool_k.reshape(cache.n_pages, ps, g * dk)
        cv = cache.pool_v.reshape(cache.n_pages, ps, g * dv)
        return ck, cv, ps, cache.pages_per_slot

    def flat(t, d):
        return t.reshape(b, t.shape[1], g * d)

    dt = cache.hot_k.dtype
    cold_cap = cache.cold_cap
    ck, bs_cold, n_cold = _tier_blocks(
        flat(cache.cold_k, dk), cold_cap, block_s, (b, 1, g * dk), dt)
    cv, _, _ = _tier_blocks(
        flat(cache.cold_v, dv), cold_cap, block_s, (b, 1, g * dv), dt)
    return ck, cv, bs_cold, n_cold


def _paged_cold_map(hot_cap: int, cold_cap: int, page_size: int, n_hot: int):
    """Paged twin of ``_park_maps``'s cold map: the S index selects the
    slot's logical page, the page table (scalar-prefetch) resolves it to
    a pool page. Parking works at the page level — an invalid S-block
    repeats the last *valid pool page* index, eliding the copy. Unused
    table entries hold pool index 0 (engine convention), so a length-0
    slot parks on a real page and ``pl.when`` skips the body."""

    def cold_map(b_i, kk, lens, pt):
        n_valid = jnp.clip(lens[b_i] - hot_cap, 0, cold_cap)
        nvb = jnp.maximum(pl.cdiv(n_valid, page_size), 1)
        kc = jnp.maximum(kk - n_hot, 0)
        return pt[b_i, jnp.minimum(kc, nvb - 1)], 0

    return cold_map


def _flash_gqa(q, cache, scale, block_s, interpret):
    b, h, dk = q.shape
    g = cache.hot_k.shape[2]
    rep = h // g
    assert rep * g == h, (h, g)
    dv = cache.hot_v.shape[-1]
    hot_cap, cold_cap = cache.hot_cap, cache.cold_cap
    paged = isinstance(cache, kvc.PagedKVCache)
    if block_s is None:
        block_s = ops.select_blocks(
            rep, max(dk, dv), cache.capacity, "pack2", kind="decode_attn"
        )[2]

    # (b, s, g, d) -> (b, s, g*d): trailing-dim reshape (no copy), so the
    # (1, bs, d) BlockSpec tiles land (sublane=s, lane=d)-aligned with the
    # group picked by the block index along the fused g*d axis.
    def flat(t, d):
        return t.reshape(b, t.shape[1], g * d)

    dt = cache.hot_k.dtype
    hk, bs_hot, n_hot = _tier_blocks(
        flat(cache.hot_k, dk), hot_cap, block_s, (b, 1, g * dk), dt)
    hv, _, _ = _tier_blocks(
        flat(cache.hot_v, dv), hot_cap, block_s, (b, 1, g * dv), dt)
    ck, cv, bs_cold, n_cold = _cold_operands(cache, g, dk, dv, block_s, b)

    hot_map2, cold_map2 = _park_maps(hot_cap, cold_cap, bs_hot, bs_cold, n_hot)
    if paged:
        cold_pt = _paged_cold_map(hot_cap, cold_cap, bs_cold, n_hot)
        hot_g = lambda b_i, g_i, kk, lens, pt: (  # noqa: E731
            *hot_map2(b_i, kk, lens), g_i)
        cold_g = lambda b_i, g_i, kk, lens, pt: (  # noqa: E731
            *cold_pt(b_i, kk, lens, pt), g_i)
        q_map = lambda b_i, g_i, kk, lens, pt: (b_i, g_i, 0, 0)  # noqa: E731
        prefetch = (cache.lengths.astype(jnp.int32),
                    cache.page_table.astype(jnp.int32))
        body = functools.partial(
            _kernel_gqa, scale=scale, n_hot_blocks=n_hot,
            hot_cap=hot_cap, cold_cap=cold_cap,
        )
        kern = lambda lens_ref, pt_ref, *rest: body(lens_ref, *rest)  # noqa: E731
    else:
        hot_g = lambda b_i, g_i, kk, lens: (  # noqa: E731
            *hot_map2(b_i, kk, lens), g_i)
        cold_g = lambda b_i, g_i, kk, lens: (  # noqa: E731
            *cold_map2(b_i, kk, lens), g_i)
        q_map = lambda b_i, g_i, kk, lens: (b_i, g_i, 0, 0)  # noqa: E731
        prefetch = (cache.lengths.astype(jnp.int32),)
        kern = functools.partial(
            _kernel_gqa, scale=scale, n_hot_blocks=n_hot,
            hot_cap=hot_cap, cold_cap=cold_cap,
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(b, g, n_hot + n_cold),
        in_specs=[
            pl.BlockSpec((1, 1, rep, dk), q_map),
            pl.BlockSpec((1, bs_hot, dk), hot_g),
            pl.BlockSpec((1, bs_hot, dv), hot_g),
            pl.BlockSpec((1, bs_cold, dk), cold_g),
            pl.BlockSpec((1, bs_cold, dv), cold_g),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, dv), q_map),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, g, rep, dv), q.dtype),
        interpret=interpret,
    )(*prefetch, q.reshape(b, g, rep, dk), hk, hv, ck, cv)
    return out.reshape(b, h, dv)


def _flash_gqa_fused(q, cache, k_new, v_new, active, scale, theta, ring,
                     block_s, interpret):
    """Launch the fused-RoPE decode kernel: unrotated q/k_new in, rotated
    k_new out alongside the attention output."""
    b, h, dk = q.shape
    g = cache.hot_k.shape[2]
    rep = h // g
    assert rep * g == h, (h, g)
    dv = cache.hot_v.shape[-1]
    hot_cap, cold_cap = cache.hot_cap, cache.cold_cap
    if block_s is None:
        block_s = ops.select_blocks(
            rep, max(dk, dv), cache.capacity, "pack2", kind="decode_attn"
        )[2]

    def flat(t, d):
        return t.reshape(b, t.shape[1], g * d)

    dt = cache.hot_k.dtype
    hk, bs_hot, n_hot = _tier_blocks(
        flat(cache.hot_k, dk), hot_cap, block_s, (b, 1, g * dk), dt)
    hv, _, _ = _tier_blocks(
        flat(cache.hot_v, dv), hot_cap, block_s, (b, 1, g * dv), dt)
    ck, cv, bs_cold, n_cold = _cold_operands(cache, g, dk, dv, block_s, b)

    hot_map2, cold_map2 = _park_maps(hot_cap, cold_cap, bs_hot, bs_cold, n_hot)
    paged = isinstance(cache, kvc.PagedKVCache)
    act = (
        jnp.ones((b,), jnp.int32) if active is None
        else active.astype(jnp.int32)
    )
    body = functools.partial(
        _kernel_gqa_fused, scale=scale, n_hot_blocks=n_hot,
        hot_cap=hot_cap, cold_cap=cold_cap, ring=ring, theta=theta,
    )
    if paged:
        assert not ring, "ring layout is not supported for paged caches"
        cold_pt = _paged_cold_map(hot_cap, cold_cap, bs_cold, n_hot)
        hot_g = lambda b_i, g_i, kk, lens, a, pt: (  # noqa: E731
            *hot_map2(b_i, kk, lens), g_i)
        cold_g = lambda b_i, g_i, kk, lens, a, pt: (  # noqa: E731
            *cold_pt(b_i, kk, lens, pt), g_i)
        q_map = lambda b_i, g_i, kk, lens, a, pt: (  # noqa: E731
            b_i, g_i, 0, 0)
        pin = lambda b_i, g_i, kk, lens, a, pt: (b_i, 0, g_i)  # noqa: E731
        prefetch = (cache.lengths.astype(jnp.int32), act,
                    cache.page_table.astype(jnp.int32))
        kern = lambda lens_ref, act_ref, pt_ref, *rest: body(  # noqa: E731
            lens_ref, act_ref, *rest)
    else:
        hot_g = lambda b_i, g_i, kk, lens, a: (  # noqa: E731
            *hot_map2(b_i, kk, lens), g_i)
        cold_g = lambda b_i, g_i, kk, lens, a: (  # noqa: E731
            *cold_map2(b_i, kk, lens), g_i)
        q_map = lambda b_i, g_i, kk, lens, a: (b_i, g_i, 0, 0)  # noqa: E731
        pin = lambda b_i, g_i, kk, lens, a: (b_i, 0, g_i)  # noqa: E731
        prefetch = (cache.lengths.astype(jnp.int32), act)
        kern = body

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(b, g, n_hot + n_cold),
        in_specs=[
            pl.BlockSpec((1, 1, rep, dk), q_map),
            pl.BlockSpec((1, bs_hot, dk), hot_g),
            pl.BlockSpec((1, bs_hot, dv), hot_g),
            pl.BlockSpec((1, bs_cold, dk), cold_g),
            pl.BlockSpec((1, bs_cold, dv), cold_g),
            pl.BlockSpec((1, 1, dk), pin),
            pl.BlockSpec((1, 1, dv), pin),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rep, dv), q_map),
            pl.BlockSpec((1, 1, dk), pin),
        ],
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, dv), jnp.float32),
            pltpu.VMEM((rep, dk), jnp.float32),
        ],
    )
    out, k_rot = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, g, rep, dv), q.dtype),
            jax.ShapeDtypeStruct((b, 1, g * dk), k_new.dtype),
        ],
        interpret=interpret,
    )(
        *prefetch, q.reshape(b, g, rep, dk),
        hk, hv, ck, cv, k_new.reshape(b, 1, g * dk),
        v_new.reshape(b, 1, g * dv),
    )
    return out.reshape(b, h, dv), k_rot.reshape(b, g, dk)


def _flash_latent(q, cache, value_dim, scale, block_s, interpret):
    b, h, dd = q.shape
    hot_cap, cold_cap = cache.hot_cap, cache.cold_cap
    if block_s is None:
        block_s = ops.select_blocks(
            h, dd, cache.capacity, "pack2", kind="decode_attn"
        )[2]
    dt = cache.hot_k.dtype
    hk, bs_hot, n_hot = _tier_blocks(
        cache.hot_k, hot_cap, block_s, (b, 1, dd), dt)
    ck, bs_cold, n_cold = _tier_blocks(
        cache.cold_k, cold_cap, block_s, (b, 1, dd), dt)
    hot_map, cold_map = _park_maps(hot_cap, cold_cap, bs_hot, bs_cold, n_hot)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n_hot + n_cold),
        in_specs=[
            pl.BlockSpec((1, h, dd), lambda b_i, kk, lens: (b_i, 0, 0)),
            pl.BlockSpec((1, bs_hot, dd),
                         lambda b_i, kk, lens: (*hot_map(b_i, kk, lens), 0)),
            pl.BlockSpec((1, bs_cold, dd),
                         lambda b_i, kk, lens: (*cold_map(b_i, kk, lens), 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, h, value_dim), lambda b_i, kk, lens: (b_i, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, value_dim), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _kernel_latent, scale=scale, n_hot_blocks=n_hot,
            hot_cap=hot_cap, cold_cap=cold_cap, value_dim=value_dim,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, value_dim), jnp.float32),
        interpret=interpret,
    )(cache.lengths.astype(jnp.int32), q, hk, ck)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def _decode_entry(q, cache, scale, impl, block_s, interpret, k_new, v_new,
                  active, rope_theta, ring):
    impl = _resolve(impl)
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    if k_new is None:
        if impl == "xla":
            return kvc.tiered_decode_attention(q, cache, scale)
        if impl != "pallas":
            raise ValueError(f"unknown impl {impl!r}")
        return _flash_gqa(q, cache, float(scale), block_s,
                          _interpret(interpret))
    # fused-RoPE form: q and k_new are UNROTATED, the cache holds the
    # PRE-append state; returns (o, rotated k_new) — the caller appends.
    assert rope_theta is not None, "fused decode needs rope_theta"
    if impl == "pallas":
        return _flash_gqa_fused(
            q, cache, k_new, v_new, active, float(scale),
            float(rope_theta), ring, block_s, _interpret(interpret),
        )
    if impl != "xla":
        raise ValueError(f"unknown impl {impl!r}")
    from repro.models.layers import apply_rope

    pos = cache.lengths.astype(jnp.int32)[:, None]  # (b, 1)
    q_rot = apply_rope(q[:, None], pos, rope_theta)[:, 0]
    k_rot = apply_rope(k_new[:, None], pos, rope_theta)[:, 0]
    app = kvc.append_decode_ring if ring else kvc.append_decode
    attended = app(cache, k_rot, v_new, active=active)
    return kvc.tiered_decode_attention(q_rot, attended, scale), k_rot


@functools.partial(
    jax.jit, static_argnames=("scale", "impl", "block_s", "interpret",
                              "rope_theta")
)
def flash_decode_attention(
    q: jax.Array,  # (b, h, d)
    cache: kvc.TieredKVCache,
    scale: float | None = None,
    *,
    impl: str = "auto",
    block_s: int | None = None,
    interpret: bool | None = None,
    k_new: jax.Array | None = None,  # (b, g, d) — UNROTATED pending token
    v_new: jax.Array | None = None,  # (b, g, dv)
    active: jax.Array | None = None,  # (b,) bool — slots really decoding
    rope_theta: float | None = None,
) -> jax.Array:
    """One-token GQA attention over both tiers. q: (b, h, d) -> (b, h, d).

    ``impl``: "pallas" runs the streaming kernel (interpret mode on CPU),
    "xla" the masked full-capacity reference
    (``kv_cache.tiered_decode_attention``), "auto" picks by backend.
    ``block_s`` overrides the ``select_blocks(kind="decode_attn")``
    S-block. Per-slot ``cache.lengths`` drive validity, so mixed-length
    batches each attend to exactly their own prefix and a length-0
    (unadmitted) slot returns zeros.

    **Fused-RoPE form** (``k_new``/``v_new``/``rope_theta`` given): q and
    the pending token's k arrive UNROTATED and rotate in the kernel
    prologue at position ``cache.lengths[b]``; the pending (k, v) joins
    the stream as the final softmax element for ``active`` slots, and the
    call returns ``(o, k_rot)`` so the caller's cache append consumes the
    kernel-rotated k — the decode step's separate XLA ``apply_rope``
    passes disappear. The cache argument is the PRE-append state.
    """
    return _decode_entry(q, cache, scale, impl, block_s, interpret,
                         k_new, v_new, active, rope_theta, ring=False)


@functools.partial(
    jax.jit, static_argnames=("scale", "impl", "block_s", "interpret",
                              "rope_theta")
)
def flash_decode_attention_ring(
    q: jax.Array,
    cache: kvc.TieredKVCache,
    scale: float | None = None,
    *,
    impl: str = "auto",
    block_s: int | None = None,
    interpret: bool | None = None,
    k_new: jax.Array | None = None,
    v_new: jax.Array | None = None,
    active: jax.Array | None = None,
    rope_theta: float | None = None,
) -> jax.Array:
    """GQA decode attention over a *ring-buffer* cold tier (SWA archs).

    In the plain (pre-rotated, post-append) form this is numerically
    identical to ``flash_decode_attention``: attention is permutation-
    invariant over KV positions, and the validity clamp ``clip(length -
    hot_cap, 0, cold_cap)`` marks the whole window valid once it wraps.
    The fused-RoPE form (``k_new``/``rope_theta``; pre-append cache) is
    where the layout matters: the kernel masks the ring slot the
    upcoming append will evict, keeping the wrapped window exact.
    """
    return _decode_entry(q, cache, scale, impl, block_s, interpret,
                         k_new, v_new, active, rope_theta, ring=True)


@functools.partial(
    jax.jit,
    static_argnames=("value_dim", "scale", "impl", "block_s", "interpret"),
)
def flash_decode_attention_latent(
    q: jax.Array,  # (b, h, D) — D = latent + rope dims
    cache: kvc.TieredKVCache,
    value_dim: int,
    scale: float,
    *,
    impl: str = "auto",
    block_s: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """MLA absorbed-form attention over a tiered *latent* cache.

    The cache k-slot holds (c_kv ‖ k_rope) per token; the v-slot is empty
    — values are the first ``value_dim`` dims of the k-slot, sliced per
    S-block in VMEM (the latent is stored exactly once and streamed
    once). Returns the per-head latent context (b, h, value_dim) f32.
    """
    if isinstance(cache, kvc.PagedKVCache):
        # MLA serving is not paged (engine restriction); gather back to
        # the contiguous layout so direct callers still get the numbers
        cache = kvc.as_tiered(cache)
    impl = _resolve(impl)
    if impl == "xla":
        return kvc.tiered_decode_attention_latent(q, cache, value_dim, scale)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    return _flash_latent(
        q, cache, value_dim, float(scale), block_s, _interpret(interpret)
    )
