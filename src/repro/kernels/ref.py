"""Pure-jnp oracles for the ternary kernels (ground truth for allclose tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.ternary import ternary_mac_reference


def ternary_matmul_ref(xq: jax.Array, packed: jax.Array, k: int, codec: str) -> jax.Array:
    """int8 activations (..., K) x packed trits (K/g, N) -> int32 (..., N).

    Decodes the packed weight to {-1,0,+1} trits and applies the exact
    TriMLA add/sub/skip semantics (no multiplies).
    """
    unpack = packing.unpack2 if codec == "pack2" else packing.unpack243
    wq = unpack(packed, k=k)  # (K, N) int8
    return ternary_mac_reference(xq, wq)


def ternary_matmul_dense_ref(xq: jax.Array, wq: jax.Array) -> jax.Array:
    """Same but from unpacked trits (K, N)."""
    return ternary_mac_reference(xq, wq)


def bitlinear_ref(x: jax.Array, w: jax.Array, act_bits: int = 8) -> jax.Array:
    """Full float-in/float-out reference of the packed BitLinear forward."""
    from repro.core.ternary import act_quant, weight_quant_absmean

    q = weight_quant_absmean(w)
    a = act_quant(x, bits=act_bits)
    acc = ternary_mac_reference(a.xq, q.wq).astype(jnp.float32)
    return acc * (q.scale / a.scale)
