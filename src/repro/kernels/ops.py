"""Jit'd dispatch wrappers around the ternary kernels.

``impl`` selects the execution path:
  * "pallas" — the Pallas TPU kernel (interpret=True automatically on CPU,
    executing the kernel body in Python for correctness validation);
  * "xla"    — unpack-then-dot in plain XLA. Used for the sharded
    multi-pod lowering (dry-run) where a hand-written kernel would block
    GSPMD propagation; keeps the same packed HBM layout so the memory
    roofline term is identical.

Handles arbitrary leading batch dims and non-aligned M/N/K by zero padding
(zero trits are TriMLA skip-ops; zero activations contribute nothing).

Shape-aware block selection
---------------------------
When the caller does not pin block sizes, ``select_blocks`` picks them from
a static table keyed on (M, N, K). The two regimes it distinguishes:

  * decode (M <= 32, continuous-batching GEMV-ish shapes) — block_m = 32
    (the int8 sublane tile) instead of padding the batch up to 256, a 8x
    cut in streamed/accumulated M rows; block_n widens to 512 and block_k
    to 1024 so each launch amortizes the in-VMEM trit decode and the x
    tile reload across more output columns / contraction depth;
  * prefill / train (large M) — classic MXU-aligned 256/256/512 blocks.

    M range   | block_m | block_n | block_k
    ----------|---------|---------|--------
    1..32     |   32    |   512   |  1024      (decode fast path)
    33..64    |   64    |   256   |   512
    65..128   |  128    |   256   |   512
    129..     |  256    |   256   |   512      (prefill/train)

(under pack243, block_k snaps to multiples of 640 = lcm(5 trits/byte,
128 lanes) so both the x tile and the packed tile stay lane-aligned)

block_n / block_k are additionally capped by the (padded) N / K of the
operand and block_k is aligned down to the codec group (4 or 5 trits per
byte).

Fused epilogue
--------------
``ternary_matmul_fused`` is the production entry point used by the model
fast path (core/bitlinear.packed_matmul): it takes the per-row activation
scale and per-column weight scale and returns the *scaled float* output in
one kernel launch (Pallas) or one dot + one elementwise rescale (XLA
fallback, numerically identical ops to the historical unfused path). The
per-column weight scale is what makes fused QKV / gate-up projections
(one launch for wq‖wk‖wv) exact: each segment keeps its own absmean scale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.kernels.ternary_matmul import (
    ternary_matmul_fused_pallas,
    ternary_matmul_pallas,
)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# Static block table: (max_m, block_m, block_n, block_k). See module doc.
_BLOCK_TABLE = (
    (32, 32, 512, 1024),
    (64, 64, 256, 512),
    (128, 128, 256, 512),
    (None, 256, 256, 512),
)


def select_blocks(m: int, n: int, k: int, codec: str) -> tuple:
    """(M, N, K) -> (block_m, block_n, block_k) from the static table.

    Caps block_n / block_k at the padded operand extent and aligns block_k
    to the codec group so a block never spans a partial packed byte. For
    pack243 the group (5) is coprime with the 128-lane tile, so block_k
    additionally snaps to multiples of lcm(5, 128) = 640 whenever K allows
    — otherwise the (bm, bk) x tile and (bk/5, bn) packed tile would be
    lane-misaligned on real TPU (interpret mode doesn't care, Mosaic does).
    """
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    for max_m, bm, bn, bk in _BLOCK_TABLE:
        if max_m is None or m <= max_m:
            break
    bn = min(bn, _round_up(max(n, 1), 128))
    kp = _round_up(max(k, 1), group)
    bk = min(bk, kp)
    if codec == "pack243" and kp >= 640:
        bk = max(640, bk // 640 * 640)
    else:
        bk = max(group, bk // group * group)
    return bm, bn, bk


def _xla_path(xq: jax.Array, packed: jax.Array, k: int, codec: str) -> jax.Array:
    unpack = packing.unpack2 if codec == "pack2" else packing.unpack243
    wq = unpack(packed, k=k)  # (K, N) int8
    return jax.lax.dot_general(
        xq.astype(jnp.int8),
        wq,
        dimension_numbers=(((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _pad_operands(xq, packed, codec, block_m, block_n, block_k):
    """Flatten leading dims and zero-pad to block multiples.

    Returns (x2 (Mp, Kp) int8, wp (Kp/g, Np) uint8, lead shape, m, n).
    Padding is computation-neutral: zero activation rows/columns contribute
    nothing, and padded *weight* bytes are repaired to the all-zero-trit
    code where the byte encoding requires it (pack243's zero code is 121,
    not 0x00 — note the parenthesization below: the repair is only ever
    needed for pack243, for *either* K-row or N-column padding; pack2's
    zero code is 0x00, which jnp.pad already produces).
    """
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    lead = xq.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    x2 = xq.reshape(m, xq.shape[-1])

    n = packed.shape[1]
    kp_logical = packed.shape[0] * group  # K padded to group already
    mp = _round_up(max(m, 1), block_m)
    np_ = _round_up(n, block_n)
    kpp = _round_up(kp_logical, block_k)
    x2 = jnp.pad(
        x2, ((0, mp - m), (0, kpp - xq.shape[-1]))
    )  # pad K with zero activations
    wp = jnp.pad(packed, ((0, kpp // group - packed.shape[0]), (0, np_ - n)))
    if codec == "pack243" and (kpp // group > packed.shape[0] or np_ > n):
        # byte 0 decodes to trits (-1,-1,-1,-1,-1) under pack243; rewrite
        # padded bytes to the all-zero-trit code 121 = sum((0+1) * 3^i).
        zero_code = 121
        mask_r = jnp.arange(kpp // group) >= packed.shape[0]
        mask_c = jnp.arange(np_) >= n
        mask = mask_r[:, None] | mask_c[None, :]
        wp = jnp.where(mask, jnp.uint8(zero_code), wp)
    return x2, wp, lead, m, n


def _resolve_blocks(m, n, k, codec, block_m, block_n, block_k):
    auto = select_blocks(m, n, k, codec)
    bm = block_m if block_m is not None else auto[0]
    bn = block_n if block_n is not None else auto[1]
    bk = block_k if block_k is not None else auto[2]
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    bk = max(group, bk // group * group)  # align block to codec group
    bk = min(bk, _round_up(k, group))  # don't exceed (padded) K
    return bm, bn, bk


@functools.partial(
    jax.jit, static_argnames=("k", "codec", "impl", "block_m", "block_n", "block_k")
)
def ternary_matmul(
    xq: jax.Array,
    packed: jax.Array,
    *,
    k: int,
    codec: str = "pack2",
    impl: str = "xla",
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    """int8 activations (..., K) x packed trits -> int32 (..., N).

    Block sizes default to the shape-aware table (``select_blocks``).
    """
    if impl == "xla":
        return _xla_path(xq, packed, k, codec)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    m = 1
    for d in xq.shape[:-1]:
        m *= d
    bm, bn, bk = _resolve_blocks(
        m, packed.shape[1], packed.shape[0] * group, codec, block_m, block_n, block_k
    )
    x2, wp, lead, m, n = _pad_operands(xq, packed, codec, bm, bn, bk)

    interpret = jax.default_backend() == "cpu"
    out = ternary_matmul_pallas(
        x2, wp, codec=codec, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret,
    )
    return out[:m, :n].reshape(lead + (n,))


@functools.partial(
    jax.jit,
    static_argnames=("k", "codec", "impl", "out_dtype",
                     "block_m", "block_n", "block_k"),
)
def ternary_matmul_fused(
    xq: jax.Array,
    packed: jax.Array,
    x_scale: jax.Array,
    col_scale: jax.Array,
    *,
    k: int,
    codec: str = "pack2",
    impl: str = "pallas",
    out_dtype=jnp.float32,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    """Epilogue-fused ternary matmul: int8 x packed -> scaled float (..., N).

    ``x_scale``: (..., 1) f32 per-row activation scale (act_quant
    convention, dequant = xq / scale); ``col_scale``: (N,) f32 per-column
    weight scale. Returns ``(xq @ trits) * col_scale / x_scale`` without
    materializing the (M, N) int32 accumulator in HBM on the Pallas path.
    """
    n = packed.shape[1]
    if impl == "xla":
        acc = _xla_path(xq, packed, k, codec)
        y = acc.astype(jnp.float32) * (col_scale / x_scale)
        return y.astype(out_dtype)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    m = 1
    for d in xq.shape[:-1]:
        m *= d
    bm, bn, bk = _resolve_blocks(
        m, n, packed.shape[0] * group, codec, block_m, block_n, block_k
    )
    x2, wp, lead, m, n = _pad_operands(xq, packed, codec, bm, bn, bk)
    mp, np_ = x2.shape[0], wp.shape[1]
    # padded rows divide by 1 (not 0); padded columns scale to exactly 0
    xs = jnp.pad(
        x_scale.reshape(m, 1).astype(jnp.float32), ((0, mp - m), (0, 0)),
        constant_values=1.0,
    )
    ws = jnp.pad(
        col_scale.reshape(1, n).astype(jnp.float32), ((0, 0), (0, np_ - n))
    )

    interpret = jax.default_backend() == "cpu"
    out = ternary_matmul_fused_pallas(
        x2, wp, xs, ws, codec=codec, block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n].reshape(lead + (n,))
