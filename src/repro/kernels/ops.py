"""Jit'd dispatch wrappers around the ternary kernels.

``impl`` selects the execution path:
  * "pallas" — the Pallas TPU kernel (interpret=True automatically on CPU,
    executing the kernel body in Python for correctness validation);
  * "xla"    — unpack-then-dot in plain XLA. Used for the sharded
    multi-pod lowering (dry-run) where a hand-written kernel would block
    GSPMD propagation; keeps the same packed HBM layout so the memory
    roofline term is identical.

Handles arbitrary leading batch dims and non-aligned M/N/K by zero padding
(zero trits are TriMLA skip-ops; zero activations contribute nothing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.kernels.ternary_matmul import ternary_matmul_pallas


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _xla_path(xq: jax.Array, packed: jax.Array, k: int, codec: str) -> jax.Array:
    unpack = packing.unpack2 if codec == "pack2" else packing.unpack243
    wq = unpack(packed, k=k)  # (K, N) int8
    return jax.lax.dot_general(
        xq.astype(jnp.int8),
        wq,
        dimension_numbers=(((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(
    jax.jit, static_argnames=("k", "codec", "impl", "block_m", "block_n", "block_k")
)
def ternary_matmul(
    xq: jax.Array,
    packed: jax.Array,
    *,
    k: int,
    codec: str = "pack2",
    impl: str = "xla",
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
) -> jax.Array:
    """int8 activations (..., K) x packed trits -> int32 (..., N)."""
    if impl == "xla":
        return _xla_path(xq, packed, k, codec)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    lead = xq.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    x2 = xq.reshape(m, xq.shape[-1])

    # pad to block multiples (and codec group)
    n = packed.shape[1]
    kp_logical = packed.shape[0] * group  # K padded to group already
    block_k = max(group, block_k // group * group)  # align block to codec group
    block_k = min(block_k, kp_logical)  # don't exceed (padded) K
    mp = _round_up(max(m, 1), block_m)
    np_ = _round_up(n, block_n)
    kpp = _round_up(kp_logical, block_k)
    x2 = jnp.pad(
        x2, ((0, mp - m), (0, kpp - xq.shape[-1]))
    )  # pad K with zero activations
    wp = jnp.pad(packed, ((0, kpp // group - packed.shape[0]), (0, np_ - n)))
    # pack243 zero-pad decodes byte 0 -> trits (-1,...): must use the code of
    # all-zero trits instead. all-zero trits = sum(0+1)*3^i = 121 for pack243,
    # 0x00 for pack2.
    if codec == "pack243" and kpp // group > packed.shape[0] or np_ > n:
        zero_code = 0 if codec == "pack2" else 121
        if zero_code:
            mask_r = jnp.arange(kpp // group) >= packed.shape[0]
            mask_c = jnp.arange(np_) >= n
            mask = mask_r[:, None] | mask_c[None, :]
            wp = jnp.where(mask, jnp.uint8(zero_code), wp)

    interpret = jax.default_backend() == "cpu"
    out = ternary_matmul_pallas(
        x2,
        wp,
        codec=codec,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:m, :n].reshape(lead + (n,))
