"""Jit'd dispatch wrappers around the ternary kernels.

``impl`` selects the execution path:
  * "pallas" — the Pallas TPU kernel (interpret=True automatically on CPU,
    executing the kernel body in Python for correctness validation);
  * "xla"    — unpack-then-dot in plain XLA. Used for the sharded
    multi-pod lowering (dry-run) where a hand-written kernel would block
    GSPMD propagation; keeps the same packed HBM layout so the memory
    roofline term is identical.

Handles arbitrary leading batch dims and non-aligned M/N/K by zero padding
(zero trits are TriMLA skip-ops; zero activations contribute nothing).

Shape-aware block selection
---------------------------
When the caller does not pin block sizes, ``select_blocks`` picks them from
a static table keyed on (M, N, K). The two regimes it distinguishes:

  * decode (M <= 32, continuous-batching GEMV-ish shapes) — block_m = 32
    (the int8 sublane tile) instead of padding the batch up to 256, a 8x
    cut in streamed/accumulated M rows; block_n widens to 512 and block_k
    to 1024 so each launch amortizes the in-VMEM trit decode and the x
    tile reload across more output columns / contraction depth;
  * prefill / train (large M) — classic MXU-aligned 256/256/512 blocks.

    M range   | block_m | block_n | block_k
    ----------|---------|---------|--------
    1..32     |   32    |   512   |  1024      (decode fast path)
    33..64    |   64    |   256   |   512
    65..128   |  128    |   256   |   512
    129..     |  256    |   256   |   512      (prefill/train)

(under pack243, block_k snaps to multiples of 640 = lcm(5 trits/byte,
128 lanes) so both the x tile and the packed tile stay lane-aligned)

block_n / block_k are additionally capped by the (padded) N / K of the
operand and block_k is aligned down to the codec group (4 or 5 trits per
byte).

Fused epilogue / fused act-quant prologue
-----------------------------------------
``ternary_matmul_fused`` is the *known-scale* entry point: it takes already
int8-quantized activations with their per-row scale and the per-column
weight scale and returns the *scaled float* output in one kernel launch
(Pallas) or one dot + one elementwise rescale (XLA fallback, numerically
identical ops to the historical unfused path). The per-column weight scale
is what makes fused QKV / gate-up projections (one launch for wq‖wk‖wv)
exact: each segment keeps its own absmean scale.

``ternary_matmul_actq`` is the production entry point
(core/bitlinear.packed_matmul): it takes the RAW bf16/f32 activations and
fuses the int8 act-quant (per-row absmax + scale) into the kernel prologue
via the two-phase grid, so neither the int8 activations nor the int32
accumulator ever exist in HBM. ``ternary_matmul_expert`` is its E-loop
variant for expert-batched MoE weights (E, K/g, N): ONE launch with a
leading expert grid dimension replaces E vmapped per-expert launches
(which were impossible on the Pallas path anyway — ``pallas_call`` has no
batching rule on this jax version, so the vmapped path was pinned to XLA).
``ternary_matmul_expert_fused`` is the *carried-scale* E-loop form: when
the activations arrive pre-quantized (``fuse_act_quant=False`` / a
``QuantizedActivation`` producer), experts still run as one launch via
the batched known-scale kernel instead of falling back to the vmapped
XLA path.

``select_blocks(kind="decode_attn")`` serves a different grid entirely:
the flash-decode attention kernel (kernels/flash_decode.py) keys its
S-block size off the same static-table machinery.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.kernels.ternary_matmul import (
    ternary_matmul_actq_pallas,
    ternary_matmul_fused_batched_pallas,
    ternary_matmul_fused_pallas,
    ternary_matmul_pallas,
)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# Static block tables, keyed by grid kind: (max_m, block_m, block_n, block_k).
#
#   * "fused"  — the known-scale int8 grids (raw + epilogue-fused kernels);
#     see the module doc for the decode/prefill regime rationale.
#   * "actq"   — the two-phase act-quant-prologue grid. The x tile streams
#     RAW bf16/f32 (2-4 bytes/elem instead of int8) and is read twice
#     (absmax sweep + quantized accumulate), so the decode row halves
#     block_k (1024 -> 512) to keep the double-buffered VMEM footprint at
#     the known-scale level; prefill tiers keep the MXU-aligned 256/256/512.
#   * "expert" — the E-loop grid. Identical per-step footprint, but the
#     leading E dimension multiplies the number of streamed weight tiles,
#     so the decode row narrows block_n (512 -> 256) to shorten each
#     expert's pipeline ramp (capacity C is usually small: C ~ tokens *
#     top_k / E, frequently < 32 rows per expert at decode).
_BLOCK_TABLES = {
    "fused": (
        (32, 32, 512, 1024),
        (64, 64, 256, 512),
        (128, 128, 256, 512),
        (None, 256, 256, 512),
    ),
    "actq": (
        (32, 32, 512, 512),
        (64, 64, 256, 512),
        (128, 128, 256, 512),
        (None, 256, 256, 512),
    ),
    "expert": (
        (32, 32, 256, 512),
        (64, 64, 256, 512),
        (128, 128, 256, 512),
        (None, 256, 256, 512),
    ),
    # decode_attn keys on the flash-decode grid (kernels/flash_decode.py):
    # M = q rows per kv group (GQA rep, or all h heads for the MLA latent
    # form), N = the head/latent lane width, K = cache *capacity*, and the
    # returned block_k is the S-block the kernel streams per grid step.
    # GQA rows (rep <= 16): S = 256 — a (256, 128) bf16 KV tile pair is
    # ~128 KiB double-buffered, and wider S amortizes each tile's copy
    # across more softmax columns. The MLA row halves S: the latent tile
    # is ~4.5x wider (576 lanes) and the (h, value_dim) f32 accumulator
    # already holds ~256 KiB of VMEM.
    "decode_attn": (
        (16, 16, 128, 256),
        (None, 128, 128, 128),
    ),
    # prefill_attn keys on the flash-prefill grid (kernels/flash_prefill.py):
    # M = GQA rep when rep > 1, else the head count (MLA / plain MHA —
    # the same convention as decode_attn's latent form), N = the head
    # lane width, K = the chunk / prompt length. block_m is the Q-BLOCK
    # in *tokens* (the kernel folds rep query heads into each token row,
    # so a q tile is (block_m * rep, head)), block_k the streamed KV
    # S-block. GQA (rep <= 16): 128-token q blocks against 256-token kv
    # blocks keep the f32 (bq*rep, dv) accumulator + double-buffered
    # tiles within VMEM; many-head rep-1 forms (MLA's ~192-lane heads,
    # 128 of them) halve both — per-head grid rows keep each tile small,
    # but the wider lanes double every streamed k/v copy.
    "prefill_attn": (
        (16, 128, 128, 256),
        (None, 64, 128, 128),
    ),
}


def select_blocks(m: int, n: int, k: int, codec: str, kind: str = "fused") -> tuple:
    """(M, N, K) -> (block_m, block_n, block_k) from the static table.

    ``kind`` picks the grid's table: "fused" (known-scale int8 grids),
    "actq" (two-phase act-quant prologue), "expert" (E-loop MoE grid),
    "decode_attn" (flash-decode S blocks; M/N/K are the q rows per kv
    group, head width and cache capacity — block_k is the S-block) or
    "prefill_attn" (flash-prefill; M/N/K are the q rows per token and kv
    group, head width and chunk length — block_m is the q block in
    tokens, block_k the S-block) — see the table comment for how the
    rows differ. The matmul kinds cap block_n / block_k at the padded
    operand extent and align block_k to the codec group so a block never
    spans a partial packed byte. For pack243 the group (5) is coprime
    with the 128-lane tile, so block_k additionally snaps to multiples
    of lcm(5, 128) = 640 whenever K allows — otherwise the (bm, bk) x
    tile and (bk/5, bn) packed tile would be lane-misaligned on real TPU
    (interpret mode doesn't care, Mosaic does). The attention kinds have
    no packed operand, so ``codec`` is ignored and block_k caps at the
    capacity / chunk length directly (the flash kernels handle partial
    S-blocks by masking).
    """
    for max_m, bm, bn, bk in _BLOCK_TABLES[kind]:
        if max_m is None or m <= max_m:
            break
    if kind in ("decode_attn", "prefill_attn"):
        bn = min(bn, _round_up(max(n, 1), 128))
        if kind == "prefill_attn":
            bm = min(bm, max(k, 1))
        return bm, bn, min(bk, max(k, 1))
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    bn = min(bn, _round_up(max(n, 1), 128))
    kp = _round_up(max(k, 1), group)
    bk = min(bk, kp)
    if codec == "pack243" and kp >= 640:
        bk = max(640, bk // 640 * 640)
    else:
        bk = max(group, bk // group * group)
    return bm, bn, bk


def default_page_size(rep: int, d: int, capacity: int) -> int:
    """Page size for the paged KV cache (core/kv_cache.PagedKVCache).

    One page = one flash S-block: the paged cold tier streams through the
    attention kernels with the page table as BlockSpec gather indices, so
    sizing pages off the ``decode_attn`` row keeps the paged launch's
    block geometry identical to the contiguous one — the indirection adds
    an index lookup, never a different tiling. ``rep``/``d`` follow the
    ``select_blocks`` decode-attn convention (q rows per kv group, head
    width); ``capacity`` caps the page at the cold tier's size.
    """
    return select_blocks(rep, d, max(capacity, 1), "pack2",
                         kind="decode_attn")[2]


def _xla_path(xq: jax.Array, packed: jax.Array, k: int, codec: str) -> jax.Array:
    unpack = packing.unpack2 if codec == "pack2" else packing.unpack243
    wq = unpack(packed, k=k)  # (K, N) int8
    return jax.lax.dot_general(
        xq.astype(jnp.int8),
        wq,
        dimension_numbers=(((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _pad_operands(xq, packed, codec, block_m, block_n, block_k):
    """Flatten leading dims and zero-pad to block multiples.

    Returns (x2 (Mp, Kp) int8, wp (Kp/g, Np) uint8, lead shape, m, n).
    Padding is computation-neutral: zero activation rows/columns contribute
    nothing, and padded *weight* bytes are repaired to the all-zero-trit
    code where the byte encoding requires it (pack243's zero code is 121,
    not 0x00 — note the parenthesization below: the repair is only ever
    needed for pack243, for *either* K-row or N-column padding; pack2's
    zero code is 0x00, which jnp.pad already produces).
    """
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    lead = xq.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    x2 = xq.reshape(m, xq.shape[-1])

    n = packed.shape[1]
    kp_logical = packed.shape[0] * group  # K padded to group already
    mp = _round_up(max(m, 1), block_m)
    np_ = _round_up(n, block_n)
    kpp = _round_up(kp_logical, block_k)
    x2 = jnp.pad(
        x2, ((0, mp - m), (0, kpp - xq.shape[-1]))
    )  # pad K with zero activations
    wp = _pad_packed(packed, kpp // group, np_, codec)
    return x2, wp, lead, m, n


def _pad_packed(packed, rows: int, cols: int, codec: str):
    """Zero-pad a packed array to (…, rows, cols) and repair the padding
    to the codec's all-zero-trit code.

    byte 0 decodes to trits (-1,-1,-1,-1,-1) under pack243; rewrite padded
    bytes to the all-zero-trit code 121 = sum((0+1) * 3^i). The repair is
    only ever needed for pack243, for *either* K-row or N-column padding;
    pack2's zero code is 0x00, which jnp.pad already produces. Works for
    2-D (K/g, N) and expert-stacked 3-D (E, K/g, N) packed arrays (leading
    dims pass through; the repair mask broadcasts over them).
    """
    valid_rows, valid_cols = packed.shape[-2], packed.shape[-1]
    pad = ((0, 0),) * (packed.ndim - 2) + (
        (0, rows - valid_rows), (0, cols - valid_cols))
    wp = jnp.pad(packed, pad)
    if codec != "pack243" or (rows == valid_rows and cols == valid_cols):
        return wp
    mask_r = jnp.arange(rows) >= valid_rows
    mask_c = jnp.arange(cols) >= valid_cols
    mask = mask_r[:, None] | mask_c[None, :]
    return jnp.where(mask, jnp.uint8(121), wp)


def _resolve_blocks(m, n, k, codec, block_m, block_n, block_k, kind="fused"):
    auto = select_blocks(m, n, k, codec, kind=kind)
    bm = block_m if block_m is not None else auto[0]
    bn = block_n if block_n is not None else auto[1]
    bk = block_k if block_k is not None else auto[2]
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    bk = max(group, bk // group * group)  # align block to codec group
    bk = min(bk, _round_up(k, group))  # don't exceed (padded) K
    return bm, bn, bk


@functools.partial(
    jax.jit, static_argnames=("k", "codec", "impl", "block_m", "block_n", "block_k")
)
def ternary_matmul(
    xq: jax.Array,
    packed: jax.Array,
    *,
    k: int,
    codec: str = "pack2",
    impl: str = "xla",
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    """int8 activations (..., K) x packed trits -> int32 (..., N).

    Block sizes default to the shape-aware table (``select_blocks``).
    """
    if impl == "xla":
        return _xla_path(xq, packed, k, codec)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    m = 1
    for d in xq.shape[:-1]:
        m *= d
    bm, bn, bk = _resolve_blocks(
        m, packed.shape[1], packed.shape[0] * group, codec, block_m, block_n, block_k
    )
    x2, wp, lead, m, n = _pad_operands(xq, packed, codec, bm, bn, bk)

    interpret = jax.default_backend() == "cpu"
    out = ternary_matmul_pallas(
        x2, wp, codec=codec, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret,
    )
    return out[:m, :n].reshape(lead + (n,))


@functools.partial(
    jax.jit,
    static_argnames=("k", "codec", "impl", "out_dtype",
                     "block_m", "block_n", "block_k"),
)
def ternary_matmul_fused(
    xq: jax.Array,
    packed: jax.Array,
    x_scale: jax.Array,
    col_scale: jax.Array,
    *,
    k: int,
    codec: str = "pack2",
    impl: str = "pallas",
    out_dtype=jnp.float32,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    """Epilogue-fused ternary matmul: int8 x packed -> scaled float (..., N).

    ``x_scale``: (..., 1) f32 per-row activation scale (act_quant
    convention, dequant = xq / scale); ``col_scale``: (N,) f32 per-column
    weight scale. Returns ``(xq @ trits) * col_scale / x_scale`` without
    materializing the (M, N) int32 accumulator in HBM on the Pallas path.
    """
    n = packed.shape[1]
    if impl == "xla":
        acc = _xla_path(xq, packed, k, codec)
        y = acc.astype(jnp.float32) * (col_scale / x_scale)
        return y.astype(out_dtype)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    m = 1
    for d in xq.shape[:-1]:
        m *= d
    bm, bn, bk = _resolve_blocks(
        m, n, packed.shape[0] * group, codec, block_m, block_n, block_k
    )
    x2, wp, lead, m, n = _pad_operands(xq, packed, codec, bm, bn, bk)
    mp, np_ = x2.shape[0], wp.shape[1]
    # padded rows divide by 1 (not 0); padded columns scale to exactly 0
    xs = jnp.pad(
        x_scale.reshape(m, 1).astype(jnp.float32), ((0, mp - m), (0, 0)),
        constant_values=1.0,
    )
    ws = jnp.pad(
        col_scale.reshape(1, n).astype(jnp.float32), ((0, 0), (0, np_ - n))
    )

    interpret = jax.default_backend() == "cpu"
    out = ternary_matmul_fused_pallas(
        x2, wp, xs, ws, codec=codec, block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n].reshape(lead + (n,))


@functools.partial(
    jax.jit,
    static_argnames=("k", "codec", "impl", "atol", "eps_factor"),
)
def ternary_matmul_abft(
    xq: jax.Array,
    packed: jax.Array,
    x_scale: jax.Array,
    col_scale: jax.Array,
    wsum: jax.Array,
    *,
    k: int,
    codec: str = "pack2",
    impl: str = "xla",
    atol: float = 1e-4,
    eps_factor: float = 64.0,
):
    """Epilogue-fused ternary matmul PLUS the ABFT row-sum check, one
    jitted dispatch (docs/kernels.md "ABFT checksums").

    ``wsum`` is the pack-time scale-weighted column checksum
    (``ternary_matmul.abft_wsum``); the predicted output row-sum is the
    GEMV ``(xq @ wsum) / x_scale`` — factor-N cheaper than the matmul it
    guards. Returns ``(y, residual, tol)``: a sound result has
    ``residual <= tol`` everywhere, where ``tol = atol + eps_factor *
    eps_f32 * mag`` bounds the f32 reassociation error of the two sums
    by their positive-term magnitude ``mag``. A flipped trit at row k
    shifts the row-sum by ``±|xq[r, k]| * scale`` — outside ``tol``
    whenever the row's activation quant at k is nonzero (zero-quant rows
    are the blind spot the exact crc scrub covers).
    """
    y = ternary_matmul_fused(
        xq, packed, x_scale, col_scale, k=k, codec=codec, impl=impl)
    xqf = xq.astype(jnp.float32)
    xs = x_scale[..., 0]
    wsum = wsum.astype(jnp.float32)
    pred = (xqf @ wsum) / xs
    residual = jnp.abs(jnp.sum(y, axis=-1) - pred)
    mag = ((jnp.abs(xqf) @ jnp.abs(wsum)) / jnp.abs(xs)
           + jnp.sum(jnp.abs(y), axis=-1))
    tol = atol + eps_factor * jnp.finfo(jnp.float32).eps * mag
    return y, residual, tol


def _actq_xla(x, packed, col_scale, k, codec, act_bits, out_dtype):
    """Quantize-then-matmul reference path: separate act-quant + dot +
    rescale, numerically identical ops to the fused prologue."""
    from repro.core.ternary import act_quant

    q = act_quant(x, bits=act_bits)
    acc = _xla_path(q.xq, packed, k, codec)
    y = acc.astype(jnp.float32) * (col_scale / q.scale)
    return y.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("k", "codec", "act_bits", "impl", "out_dtype",
                     "block_m", "block_n", "block_k"),
)
def ternary_matmul_actq(
    x: jax.Array,
    packed: jax.Array,
    col_scale: jax.Array,
    *,
    k: int,
    codec: str = "pack2",
    act_bits: int = 8,
    impl: str = "pallas",
    out_dtype=jnp.float32,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    """Act-quant-prologue-fused ternary matmul: RAW float (..., K) -> (..., N).

    The production fast path: per-row absmax int8 quantization happens in
    the kernel prologue (two-phase grid, see ternary_matmul.py), so no
    (M, K) int8 intermediate and no (M, N) int32 accumulator ever touch
    HBM. ``col_scale``: (N,) f32 per-column weight scale. The XLA fallback
    runs the separate quantize-then-matmul pipeline with numerically
    identical ops.
    """
    n = packed.shape[1]
    if impl == "xla":
        return _actq_xla(x, packed, col_scale, k, codec, act_bits, out_dtype)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    m = 1
    for d in x.shape[:-1]:
        m *= d
    bm, bn, bk = _resolve_blocks(
        m, n, packed.shape[0] * group, codec, block_m, block_n, block_k,
        kind="actq",
    )
    x2, wp, lead, m, n = _pad_operands(x, packed, codec, bm, bn, bk)
    ws = jnp.pad(
        col_scale.reshape(1, n).astype(jnp.float32),
        ((0, 0), (0, wp.shape[1] - n)),
    )

    interpret = jax.default_backend() == "cpu"
    out = ternary_matmul_actq_pallas(
        x2[None], wp[None], ws[None], codec=codec, act_bits=act_bits,
        block_m=bm, block_n=bn, block_k=bk, out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[0, :m, :n].reshape(lead + (n,))


@functools.partial(
    jax.jit,
    static_argnames=("k", "codec", "act_bits", "impl", "out_dtype",
                     "block_m", "block_n", "block_k"),
)
def ternary_matmul_expert(
    x: jax.Array,
    packed: jax.Array,
    col_scale: jax.Array,
    *,
    k: int,
    codec: str = "pack2",
    act_bits: int = 8,
    impl: str = "pallas",
    out_dtype=jnp.float32,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    """E-loop expert matmul: raw (E, C, K) float x packed (E, K/g, N) ->
    (E, C, N) float, act-quant prologue + epilogue fused.

    ONE kernel launch covers every expert (leading expert grid dimension)
    instead of E vmapped per-expert launches — the ``pallas_call`` batching
    rule the vmapped path lacked. ``col_scale``: (E, N) f32 per-column
    weight scale (an expert's scalar absmean repeated, or per-segment
    scales for pack-time-fused gate‖up). The XLA fallback vmaps the
    separate quantize-then-matmul pipeline per expert.
    """
    e, c, _ = x.shape
    ep, kp, n = packed.shape
    assert ep == e, (ep, e)
    assert col_scale.shape == (e, n), (col_scale.shape, e, n)
    if impl == "xla":
        return jax.vmap(
            lambda xx, pp, ss: _actq_xla(xx, pp, ss, k, codec, act_bits,
                                         out_dtype)
        )(x, packed, col_scale)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    bm, bn, bk = _resolve_blocks(
        c, n, kp * group, codec, block_m, block_n, block_k, kind="expert"
    )
    mp = _round_up(max(c, 1), bm)
    np_ = _round_up(n, bn)
    kpp = _round_up(kp * group, bk)
    x2 = jnp.pad(x, ((0, 0), (0, mp - c), (0, kpp - x.shape[-1])))
    wp = _pad_packed(packed, kpp // group, np_, codec)
    ws = jnp.pad(
        col_scale.astype(jnp.float32), ((0, 0), (0, np_ - n))
    )[:, None, :]

    interpret = jax.default_backend() == "cpu"
    out = ternary_matmul_actq_pallas(
        x2, wp, ws, codec=codec, act_bits=act_bits,
        block_m=bm, block_n=bn, block_k=bk, out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[:, :c, :n]


@functools.partial(
    jax.jit,
    static_argnames=("k", "codec", "impl", "out_dtype",
                     "block_m", "block_n", "block_k"),
)
def ternary_matmul_expert_fused(
    xq: jax.Array,
    packed: jax.Array,
    x_scale: jax.Array,
    col_scale: jax.Array,
    *,
    k: int,
    codec: str = "pack2",
    impl: str = "pallas",
    out_dtype=jnp.float32,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    """Carried-scale E-loop expert matmul: int8 (E, C, K) x packed
    (E, K/g, N) -> (E, C, N) float, epilogue fused.

    The ``fuse_act_quant=False`` / ``QuantizedActivation`` twin of
    ``ternary_matmul_expert``: the caller already quantized the
    activations (``x_scale``: (E, C, 1) f32 per-row scale), so the kernel
    skips the absmax phase and still covers every expert in ONE launch.
    ``col_scale``: (E, N) f32 per-column weight scale. The XLA fallback
    vmaps the unpack-dot + rescale per expert (numerically identical
    ops — bit-exact against the kernel).
    """
    e, c, _ = xq.shape
    ep, kp, n = packed.shape
    assert ep == e, (ep, e)
    assert x_scale.shape == (e, c, 1), (x_scale.shape, e, c)
    assert col_scale.shape == (e, n), (col_scale.shape, e, n)
    if impl == "xla":
        acc = jax.vmap(lambda xx, pp: _xla_path(xx, pp, k, codec))(xq, packed)
        y = acc.astype(jnp.float32) * (
            col_scale[:, None, :] / x_scale.astype(jnp.float32)
        )
        return y.astype(out_dtype)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    bm, bn, bk = _resolve_blocks(
        c, n, kp * group, codec, block_m, block_n, block_k, kind="expert"
    )
    mp = _round_up(max(c, 1), bm)
    np_ = _round_up(n, bn)
    kpp = _round_up(kp * group, bk)
    x2 = jnp.pad(xq, ((0, 0), (0, mp - c), (0, kpp - xq.shape[-1])))
    wp = _pad_packed(packed, kpp // group, np_, codec)
    # padded rows divide by 1 (not 0); padded columns scale to exactly 0
    xs = jnp.pad(
        x_scale.astype(jnp.float32), ((0, 0), (0, mp - c), (0, 0)),
        constant_values=1.0,
    )
    ws = jnp.pad(
        col_scale.astype(jnp.float32), ((0, 0), (0, np_ - n))
    )[:, None, :]

    interpret = jax.default_backend() == "cpu"
    out = ternary_matmul_fused_batched_pallas(
        x2, wp, xs, ws, codec=codec,
        block_m=bm, block_n=bn, block_k=bk, out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[:, :c, :n]
