"""Silent-data-corruption (SDC) fault model + the serving integrity knobs.

BitROM's storage planes fail differently, and this module gives each
plane a seeded, deterministic injector plus the typed errors and config
the engine's detect -> contain -> repair ladder (engine._scrub,
docs/serving.md "Fault model & SDC ladder") is built around:

  * **ROM stuck-at faults** (:class:`RomFaultInjector`) — a fabricated
    CiROM cell that reads wrong does so *persistently*: the same packed
    word returns the same flipped bit on every access. The injector
    draws (leaf, byte, bit) addresses from a seeded stream and
    re-asserts each stuck bit after the engine repairs the leaf from
    its golden copy, which is what makes "repeated faults at the same
    address -> replica unhealthy -> Router retires it" a testable
    ladder rung rather than a story.
  * **DR-eDRAM retention decay** (:class:`RetentionInjector`) — KV
    pages live in dynamic cells whose flip probability grows with time
    since refresh (hwmodel.retention_failure_rate). The injector ages
    every crc-stamped full page and flips a bit with probability
    ``1 - (1 - rate)^age``, modelling a page that outlived its
    retention window.
  * **transient activation flips** (:func:`inject_activation_nan`) — a
    one-shot NaN poked into a slot's hot-tier KV, the undetectable-by-
    checksum case the NaN/Inf logit sentinel exists for.

All injectors are *seeded and replayable*: same seed, same serve call,
same fault schedule — the property CI's fixed-seed chaos lane pins.
They mutate state only through public surfaces (host rebuild of packed
leaves, ``write_pool_pages``, device ``.at[].set``) so every detection
is of a real corruption, not a monkey-patched flag.

Detection lives elsewhere, by design: crc32 + ABFT verification in
models/pack.py + kernels/ops.py, the scrub loop in serving/engine.py.
This module is only the adversary and the shared vocabulary
(:class:`IntegrityConfig`, :class:`NumericsError`,
:class:`WeightFaultError`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import kv_cache
from repro.models import pack as pack_lib


class NumericsError(RuntimeError):
    """Non-finite logits surfaced by the decode-step sentinel. Carries
    the offending slot so the caller can map it back to a request."""

    def __init__(self, msg: str, slot: Optional[int] = None):
        super().__init__(msg)
        self.slot = slot


class WeightFaultError(RuntimeError):
    """Packed weights failed their crc32 check at load time — the ROM
    image is corrupt before serving even starts, so refusing to come up
    beats serving garbage."""


@dataclasses.dataclass
class IntegrityConfig:
    """Knobs for the engine's SDC scrub (``Engine(integrity=...)``).

    ``scrub_every`` is the cadence in loop iterations; a scrub is
    additionally FORCED whenever a slot is ripe for harvest, so no
    request ever retires with unverified weights/KV behind it
    ("harvest gating" — the bit-exactness guarantee leans on this).
    """

    scrub_every: int = 4  # iterations between scrubs (ripe slots force one)
    scrub_weights: bool = True  # crc32 re-check of every packed leaf
    scrub_pages: bool = True  # crc32 re-check of stamped full KV pages
    abft_probe: bool = True  # ABFT checked-matmul probe per packed leaf
    on_numerics: str = "contain"  # "contain" (retire slot) | "raise"
    max_weight_strikes: int = 3  # repeated weight faults -> unhealthy


# ---------------------------------------------------------------------------
# dotted-path access into the packed tree (paths from iter_packed_leaves)
# ---------------------------------------------------------------------------


def get_leaf(tree, path: str):
    """Fetch the packed leaf at a dotted path from ``iter_packed_leaves``."""
    node = tree
    for part in path.split("."):
        node = _child(node, part)
    return node


def set_leaf(tree, path: str, leaf):
    """Return a copy of ``tree`` with the leaf at ``path`` replaced.
    Only the dicts along the path are rebuilt — sibling subtrees are
    shared, so a repair does not churn unrelated device buffers."""
    parts = path.split(".")

    def rebuild(node, i):
        if i == len(parts):
            return leaf
        key = _child_key(node, parts[i])
        out = dict(node)
        out[key] = rebuild(node[key], i + 1)
        return out

    return rebuild(tree, 0)


def _child_key(node: dict, part: str):
    for k in node:
        if str(k) == part:
            return k
    raise KeyError(f"no child {part!r} in packed tree")


def _child(node, part: str):
    return node[_child_key(node, part)]


# ---------------------------------------------------------------------------
# ABFT probe: exercise the checked matmul against every packed leaf
# ---------------------------------------------------------------------------


def _leaf_slices(pw):
    """Yield 2-D (K, N) views of a possibly layer/expert-stacked packed
    leaf, metadata sliced in lock-step (scale, wsum)."""
    if pw.packed.ndim == 2:
        yield pw
        return
    for i in range(pw.packed.shape[0]):
        sub = dataclasses.replace(
            pw, packed=pw.packed[i], scale=pw.scale[i],
            wsum=None if pw.wsum is None else pw.wsum[i])
        yield from _leaf_slices(sub)


def abft_verify_tree(params) -> List[str]:
    """ABFT-probe every stamped packed leaf with the all-ones activation
    and return the dotted paths whose checked matmul trips.

    All-ones is the adversary's worst probe to hide from: every input
    quantizes to qmax, so ANY trit change shifts the checked row-sum by
    a full ``qmax * scale / x_scale`` — far above the float tolerance.
    (The ABFT blind spot — rows whose activations quantize to zero —
    cannot occur under this probe; in live traffic it is covered by the
    exact crc32 check instead, see docs/kernels.md.)"""
    from repro.core import bitlinear

    bad = []
    for path, pw in pack_lib.iter_packed_leaves(params):
        if pw.wsum is None:
            continue
        for sub in _leaf_slices(pw):
            x = jnp.ones((1, sub.k), jnp.float32)
            try:
                bitlinear.packed_matmul_checked(sub, x)
            except bitlinear.AbftError:
                bad.append(path)
                break
    return bad


# ---------------------------------------------------------------------------
# ROM plane: persistent stuck-at faults in packed ternary words
# ---------------------------------------------------------------------------


def flip_packed_bit(params, path: str, index: int, bit: int):
    """Flip one bit of one packed byte of the leaf at ``path`` (flat
    ``index`` into the leaf's packed words) and return the rebuilt
    tree. Host round-trip on purpose: the corrupted array has the same
    aval as the original, so jitted step functions do NOT recompile —
    exactly like a ROM cell silently reading wrong."""
    pw = get_leaf(params, path)
    words = np.asarray(pw.packed).copy()
    flat = words.reshape(-1)
    flat[index % flat.size] ^= np.uint8(1 << (bit % 8))
    bad = dataclasses.replace(pw, packed=jnp.asarray(words))
    return set_leaf(params, path, bad)


class RomFaultInjector:
    """Seeded stuck-at adversary over an engine's packed weights.

    Each firing picks a fresh (leaf, byte, bit) address and flips it in
    ``engine.params``. Addresses are *stuck*: after the engine's scrub
    repairs the leaf from its golden copy, the next ``on_iteration``
    re-asserts the flip (up to ``reassert`` times per address;
    ``None`` = forever, which is what drives a replica to strike out
    and get retired by the Router).
    """

    def __init__(self, seed: int, rate: float, reassert: Optional[int] = 1):
        self._rng = np.random.default_rng(seed)
        self.rate = rate
        self.reassert = reassert
        # live stuck addresses: (path, flat_index, bit, remaining asserts)
        self.stuck: List[Tuple[str, int, int, Optional[int]]] = []
        self.injected = 0  # total bit assertions applied
        self.addresses = 0  # distinct stuck addresses minted

    def on_iteration(self, engine, ctx) -> None:
        del ctx
        if self._rng.random() < self.rate:
            leaves = list(pack_lib.iter_packed_leaves(engine.params))
            if leaves:
                path, pw = leaves[int(self._rng.integers(len(leaves)))]
                n = int(np.asarray(pw.packed).size)
                addr = (path, int(self._rng.integers(n)),
                        int(self._rng.integers(8)), self.reassert)
                self.stuck.append(addr)
                self.addresses += 1
        self._assert_stuck(engine)

    def _assert_stuck(self, engine) -> None:
        """(Re-)apply every live stuck bit whose leaf currently reads
        clean — i.e. the engine repaired it, and the bad cell strikes
        again. Leaves already failing crc are left alone so one address
        is one fault per detection cycle."""
        keep = []
        for path, index, bit, remaining in self.stuck:
            pw = get_leaf(engine.params, path)
            from repro.core import packing

            if pw.crc is not None and packing.packed_crc32(pw.packed) != pw.crc:
                keep.append((path, index, bit, remaining))
                continue  # still corrupt from a previous assert
            if remaining is not None and remaining <= 0:
                continue  # address burned out (bounded test mode)
            engine.params = flip_packed_bit(engine.params, path, index, bit)
            self.injected += 1
            keep.append((path, index, bit,
                         None if remaining is None else remaining - 1))
        self.stuck = keep


# ---------------------------------------------------------------------------
# DR-eDRAM plane: retention decay of KV pages
# ---------------------------------------------------------------------------


class RetentionInjector:
    """Seeded retention-decay adversary over stamped KV pool pages.

    Tracks the age (iterations since stamping) of every page the
    engine's scrub has crc-stamped, keyed by ``(page, born)`` so a
    freed-and-reallocated page id starts a fresh life. Each iteration,
    page P of age ``a`` flips one random bit with probability
    ``1 - (1 - rate)^a`` — the discrete-time form of the retention
    failure law in ``hwmodel.model.retention_failure_prob``.
    """

    def __init__(self, seed: int, rate: float):
        self._rng = np.random.default_rng(seed)
        self.rate = rate
        self._age: Dict[Tuple[int, int], int] = {}
        self.injected = 0  # total bit flips applied
        self.pages_hit: set = set()  # distinct (page, born) lives corrupted

    def on_iteration(self, engine, ctx) -> None:
        del engine
        stamped = getattr(ctx, "page_crc", None)
        if not stamped or ctx.pool is None:
            return
        live = {(p, born) for p, (born, _) in stamped.items()}
        self._age = {k: v + 1 for k, v in self._age.items() if k in live}
        for key in sorted(live - set(self._age)):
            self._age[key] = 0
        victims = []
        for key in sorted(self._age):
            age = self._age[key]
            p_fail = 1.0 - (1.0 - self.rate) ** max(age, 0)
            if self._rng.random() < p_fail:
                victims.append(key)
        for key in victims:
            p, born = key
            # a stamp can be stale within one iteration (its page freed
            # at harvest; the scrub drops it only next pass): decay of a
            # dead page is unobservable, and its bytes may already
            # belong to the page's next tenant — skip, don't count
            if int(ctx.pool.born[p]) != born or ctx.pool.refs[p] <= 0:
                del self._age[key]
                continue
            self._flip_page(ctx, p)
            self.injected += 1
            self.pages_hit.add(key)
            del self._age[key]  # one decay event per page life

    def _flip_page(self, ctx, page: int) -> None:
        """Flip one bit somewhere in page ``page`` of one paged cache
        stack, through the same gather/write surface the drain/restore
        path uses — a real pool mutation, not a bookkeeping lie."""
        caches = ctx.state.cache
        keys = sorted(k for k in caches
                      if hasattr(caches[k], "page_table"))
        if not keys:
            return
        key = keys[int(self._rng.integers(len(keys)))]
        cache = caches[key]
        kp, vp = kv_cache.gather_pool_pages(cache, [page])
        hit_k = bool(self._rng.random() < 0.5)
        target = kp if hit_k else vp
        raw = bytearray(np.ascontiguousarray(target).tobytes())
        raw[int(self._rng.integers(len(raw)))] ^= 1 << int(
            self._rng.integers(8))
        flipped = np.frombuffer(bytes(raw), dtype=target.dtype
                                ).reshape(target.shape)
        kp, vp = (flipped, vp) if hit_k else (kp, flipped)
        new_cache = kv_cache.write_pool_pages(cache, [page], kp, vp)
        new_caches = dict(caches)
        new_caches[key] = new_cache
        ctx.state = ctx.state._replace(cache=new_caches)


# ---------------------------------------------------------------------------
# activation plane: transient non-finite values
# ---------------------------------------------------------------------------


def inject_activation_nan(ctx, slot: int) -> bool:
    """Poison one live slot's hot-tier K with NaN — a transient compute
    upset no checksum can catch (checksums cover *storage*). The decode
    step's isfinite sentinel latches it into ``state.numerics_bad`` and
    the scrub contains the slot. Returns True if a poke landed."""
    caches = ctx.state.cache
    keys = sorted(caches)
    if not keys:
        return False
    cache = caches[keys[0]]
    hot_k = getattr(cache, "hot_k", None)
    if hot_k is None:
        return False
    if jnp.asarray(cache.lengths).ndim == 2:  # layer-stacked cache
        poisoned = hot_k.at[:, slot].set(jnp.nan)
    else:
        poisoned = hot_k.at[slot].set(jnp.nan)
    new_caches = dict(caches)
    new_caches[keys[0]] = cache._replace(hot_k=poisoned)
    ctx.state = ctx.state._replace(cache=new_caches)
    return True


def clear_hot_slot(ctx, slot: int) -> None:
    """Zero one slot's poisonable KV storage — the repair step for the
    transient plane. Containment alone is not enough: a NaN outlives
    the cancelled request. Two leak paths are closed here:

      * the hot tier — attention masking does not promise to ignore
        stale rows, so the next tenant of the SLOT would latch the
        sentinel with no new fault;
      * the slot's sole-owned pool pages — the hot tier spills into the
        cold frontier page as decode advances, and a freed page carries
        its bytes to the next allocation, so the next tenant of the
        PAGE would latch (or worse, read silently-wrong garbage).

    Tree-shared pages (refcount > 1) are left alone: they are full,
    append-frozen prompt pages written at prefill, before any transient
    upset could reach them — and zeroing them would corrupt every other
    reader of the shared prefix."""
    caches = ctx.state.cache
    own_pages = []
    pool = getattr(ctx, "pool", None)
    slot_pages = getattr(ctx, "slot_pages", None)
    if pool is not None and slot_pages is not None:
        own_pages = [p for p in slot_pages[slot] if pool.refs[p] == 1]
    new_caches = dict(caches)
    for key in sorted(caches):
        cache = caches[key]
        repl = {}
        stacked = jnp.asarray(cache.lengths).ndim == 2
        for field in ("hot_k", "hot_v"):
            buf = getattr(cache, field, None)
            if buf is None:
                continue
            repl[field] = (buf.at[:, slot].set(0) if stacked
                           else buf.at[slot].set(0))
        if own_pages:
            idx = jnp.asarray(own_pages, jnp.int32)
            for field in ("pool_k", "pool_v"):
                buf = repl.get(field, getattr(cache, field, None))
                if buf is None:
                    continue
                repl[field] = (buf.at[:, idx].set(0) if stacked
                               else buf.at[idx].set(0))
        if repl:
            new_caches[key] = cache._replace(**repl)
    ctx.state = ctx.state._replace(cache=new_caches)
