"""One data-parallel serving replica: an :class:`Engine` session plus the
control-plane surface the router drives it through.

BitROM's weight-reload-free premise makes replication unusually cheap:
every replica shares the same immutable packed-ternary weights (ROM), so
replica state is ONLY mutable KV pages plus host-side request
bookkeeping — exactly the state PR 6/7 made refcounted, serializable and
recomputable-from-prefix. A :class:`Replica` therefore wraps one engine's
resumable session (``start_session`` / ``run_iteration``) and adds:

  * a **token journal** — after every step, a host copy of each decoding
    slot's emitted-so-far tokens, keyed by rid. When the replica dies
    (device state lost), the router folds the journal into each orphan's
    prompt (``orig_prompt_len``, the PR 7 preemption trick) and re-admits
    on a survivor: greedy decode recomputes from the folded prompt
    bit-exactly. Queued / mid-prefill requests carry NO journal entry on
    purpose — after an engine-internal preemption their emitted tokens
    are already folded into ``req.tokens``, and a stale journal entry
    would fold them twice;
  * **heartbeats** — a liveness timestamp stamped when a step begins, so
    a wedged step is visible as a growing ``heartbeat_age``;
  * **straggler visibility** — the session's per-iteration
    :class:`~repro.distributed.fault.StragglerMonitor` flags, which the
    router's health sweep polls;
  * **fault hooks** — ``kill()`` (next step raises :class:`ReplicaDead`:
    the device is gone, only host bookkeeping survives), ``stall(s)``
    (the next iteration sleeps inside the monitored window — a real
    straggler, not a simulated flag), and a ``restart_faults`` injector
    that makes ``restart()`` itself fail deterministically (exercising
    ``run_with_recovery``);
  * **evacuation** — ``drain()`` (cooperative: fold + optional KV
    handoff payloads, see ``Engine.drain_session``) and ``abandon()``
    (post-mortem: host-only page release, journal is the only token
    source).

:class:`Transport` abstracts the byte channel handoff payloads cross
replicas on. The in-process :class:`LocalTransport` is a byte copy with
a deterministic corruption hook (``corrupt_next``) so chaos tests can
prove the checksum path; a real multi-host backend (RDMA, TCP, object
store) slots in behind the same two-method surface.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.distributed.fault import FaultInjector
from repro.serving.engine import Engine, FinishedRequest, ServeStats
from repro.serving.scheduler import Request


class ReplicaDead(RuntimeError):
    """The replica's device state is gone (crash / kill). Only host-side
    bookkeeping (journal, scheduler mirrors) survives; the router must
    ``abandon()`` the session and cold-migrate its requests."""

    def __init__(self, name: str):
        super().__init__(f"replica {name} is dead")
        self.name = name


class Transport:
    """Abstract byte channel for inter-replica KV handoffs. ``send``
    returns what the receiver observes — implementations may corrupt,
    truncate or drop; the checksummed wire format
    (``kv_cache.pack_slot_state``) is what makes that survivable."""

    def send(self, payload: bytes) -> bytes:  # pragma: no cover - interface
        raise NotImplementedError


class LocalTransport(Transport):
    """In-process transport: a byte copy, plus a deterministic fault
    hook — ``corrupt_next()`` arms a single-byte flip in the middle of
    the next payload (lands inside a page chunk, so the per-page crc
    catches it), ``truncate_next()`` arms a torn transfer."""

    def __init__(self):
        self.sent = 0
        self.corrupted = 0
        self._corrupt_armed = False
        self._truncate_armed = False

    def corrupt_next(self) -> None:
        self._corrupt_armed = True

    def truncate_next(self) -> None:
        self._truncate_armed = True

    def send(self, payload: bytes) -> bytes:
        self.sent += 1
        if self._corrupt_armed:
            self._corrupt_armed = False
            self.corrupted += 1
            buf = bytearray(payload)
            buf[len(buf) // 2] ^= 0xFF
            return bytes(buf)
        if self._truncate_armed:
            self._truncate_armed = False
            self.corrupted += 1
            return payload[: max(len(payload) // 2, 1)]
        return bytes(payload)


class Replica:
    """One engine behind the router. All device work happens inside
    ``step()`` (one engine loop iteration); everything else is host-side
    control plane. The wrapped engine may be rebuilt-free restarted any
    number of times — its jitted step functions persist across sessions,
    so a restart costs no recompilation."""

    def __init__(self, name: str, engine: Engine,
                 clock: Optional[Callable[[], float]] = None):
        self.name = name
        self.engine = engine
        self._clock = clock or time.monotonic
        self.ctx = None  # live session, None once dead
        self.dead = False
        # rid -> emitted tokens (int32 host copy) as of the LAST completed
        # iteration; rebuilt fresh every step (see module docstring)
        self.journal: Dict[int, np.ndarray] = {}
        self.heartbeat = self._clock()
        self._stall_s = 0.0
        self._user_hook: Optional[Callable] = None
        # deterministic restart failures (chaos: prove run_with_recovery
        # actually retries); checked once per restart() call
        self.restart_faults: Optional[FaultInjector] = None
        self._restart_no = 0
        self.restarts = 0
        # sealed ServeStats of every previous session (drained/abandoned/
        # restarted) — fleet accounting sums these plus the live session
        self.past_stats: List[ServeStats] = []

    # -- session lifecycle ----------------------------------------------
    def start(self, stop_token: Optional[int] = None,
              on_iteration: Optional[Callable] = None) -> None:
        """Open an (initially empty) serving session. ``on_iteration``
        composes AFTER the replica's own stall hook, so injected stalls
        land inside the monitored window the hook observes."""
        self._user_hook = on_iteration
        self.ctx = self.engine.start_session(
            [], stop_token=stop_token, on_iteration=self._on_iteration)
        self.dead = False
        self.journal = {}
        self.heartbeat = self._clock()

    def _on_iteration(self, ctx) -> None:
        if self._stall_s > 0.0:
            # a REAL slow iteration: the sleep is inside the span
            # run_iteration hands to the StragglerMonitor
            time.sleep(self._stall_s)
            self._stall_s = 0.0
        if self._user_hook is not None:
            self._user_hook(ctx)

    def submit(self, req: Request) -> bool:
        if self.dead or self.ctx is None:
            raise ReplicaDead(self.name)
        return self.engine.submit_to_session(self.ctx, req)

    def busy(self) -> bool:
        return (self.ctx is not None and not self.dead
                and not self.ctx.sched.idle())

    def load(self) -> Tuple[int, int]:
        """Least-loaded ordering key: (requests in flight, -free pages).
        Fewer live requests wins; free page headroom breaks ties (a
        replica whose pool is fuller is the worse target even at equal
        occupancy)."""
        if self.ctx is None or self.dead:
            return (1 << 30, 0)
        sched = self.ctx.sched
        n = len(sched.queue) + len(sched.active_slots())
        free = self.ctx.pool.available() if self.ctx.pool is not None else 0
        return (n, -free)

    def step(self) -> bool:
        """Advance the session one engine iteration. Raises
        :class:`ReplicaDead` if the replica was killed (the router
        harvests via ``abandon``); any exception out of the engine
        (``PagePoolError``, injected faults) propagates for the router
        to classify. Refreshes the journal and heartbeat on success."""
        if self.dead:
            raise ReplicaDead(self.name)
        if self.ctx is None or self.ctx.sched.idle():
            return False
        self.heartbeat = self._clock()  # checked in: a step began
        progress = self.engine.run_iteration(self.ctx)
        self._refresh_journal()
        return progress

    def _refresh_journal(self) -> None:
        """Rebuild the rid -> emitted-tokens journal from the device's
        sync-point state. ONLY decoding slots get entries: a queued or
        mid-prefill request's emitted tokens (if any) are already folded
        into its ``tokens`` by the engine's own preemption path."""
        ctx = self.ctx
        self.journal = {}
        decoding = [
            s for s in ctx.sched.active_slots()
            if s not in ctx.prefilling and s not in ctx.draft_prefilling
        ]
        if not decoding:
            return
        n_gen = np.asarray(ctx.state.n_gen)
        out = np.asarray(ctx.state.out)
        for s in decoding:
            req = ctx.sched.slot_req[s]
            self.journal[req.rid] = out[s, : int(n_gen[s])].astype(
                np.int32, copy=True)

    def take_finished(self) -> List[FinishedRequest]:
        """Drain terminal records accumulated since the last call."""
        if self.ctx is None:
            return []
        out = list(self.ctx.finished)
        self.ctx.finished.clear()
        return out

    # -- health signals --------------------------------------------------
    def straggler_flags(self) -> int:
        if self.ctx is None or self.ctx.monitor is None:
            return 0
        return len(self.ctx.monitor.flagged)

    def heartbeat_age(self) -> float:
        return self._clock() - self.heartbeat

    # -- fault hooks -----------------------------------------------------
    def kill(self) -> None:
        """Simulate a crash: the device state is lost. The journal keeps
        its last-sync snapshot — that IS what a monitoring plane would
        know about a dead worker."""
        self.dead = True

    def stall(self, seconds: float) -> None:
        """Make the next iteration a real straggler (sleep inside the
        monitored window)."""
        self._stall_s = float(seconds)

    # -- evacuation ------------------------------------------------------
    def drain(self, with_handoffs: bool = False
              ) -> Tuple[List[Request], Dict[int, bytes]]:
        """Cooperatively evacuate a LIVE session (warm migration): every
        request comes back folded (bit-exact resume elsewhere), decoding
        slots optionally ship their KV rows as checksummed handoff
        payloads. The session stays open and idle — the replica can
        keep serving new admissions afterwards."""
        if self.dead or self.ctx is None:
            raise ReplicaDead(self.name)
        drained, handoffs = self.engine.drain_session(
            self.ctx, with_handoffs=with_handoffs)
        self.journal = {}
        return drained, handoffs

    def abandon(self) -> List[Request]:
        """Post-mortem harvest of a DEAD replica's host bookkeeping:
        returns the orphaned requests (tokens NOT folded — the device is
        gone; the router folds from the journal) and releases every page
        the session's slots held, so the pool reconciles even though no
        device op will ever run again."""
        if self.ctx is None:
            return []
        orphans = self.engine.abandon_session(self.ctx)
        self.engine.finish_session(self.ctx)
        self.past_stats.append(self.ctx.stats)
        self.ctx = None
        return orphans

    def seal(self) -> None:
        """Close an idle live session, keeping its stats for accounting."""
        if self.ctx is not None:
            self.engine.finish_session(self.ctx)
            self.past_stats.append(self.ctx.stats)
            self.ctx = None

    def restart(self, stop_token: Optional[int] = None) -> "Replica":
        """Bring a dead replica back with a FRESH session (same engine,
        same jit caches — BitROM weights never reload). A configured
        ``restart_faults`` injector may deterministically fail the
        attempt (``InjectedFault``), which ``run_with_recovery`` turns
        into bounded retries at the router."""
        self._restart_no += 1
        if self.restart_faults is not None:
            self.restart_faults.check(self._restart_no)
        self.start(stop_token=stop_token, on_iteration=self._user_hook)
        self.restarts += 1
        return self

    # -- warm-migration receive side -------------------------------------
    def import_handoff(self, tokens, blob: bytes) -> int:
        """Seed this replica's prefix cache from a handoff payload;
        returns tokens seeded (0 = cold). Raises ``HandoffError`` on a
        corrupted/torn payload — the caller decides the fallback."""
        if self.dead or self.ctx is None:
            raise ReplicaDead(self.name)
        return self.engine.import_handoff(self.ctx, tokens, blob)
