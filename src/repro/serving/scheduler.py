"""Slot scheduler for continuous-batching serving (host-side control plane).

The engine (``serving/engine.py``) holds a fixed number of *slots* — batch
rows of the per-slot tiered KV cache — and decodes all active slots in
lock-free step: each slot is at its own sequence length. This module owns
the host-side bookkeeping around that device state:

  * a FIFO request queue (``submit``),
  * the slot table (which request occupies which slot),
  * admission grouping: the next batch of queued requests that can prefill
    together (same prompt length — no padding tokens ever enter the cache)
    into the currently free slots,
  * retirement: freeing a slot once its request is done.

The scheduler never touches device arrays; it only decides *which* slots
the engine should fill or free at each synchronization point. Mid-decode
admission is the point of the design: new prompts prefill into freed slots
while the remaining slots keep decoding, so the decode hot loop stays
saturated instead of draining the whole batch (the seed engine's lock-step
model, where the slowest sequence gated everyone).

Scheduling policy is FIFO with same-length grouping: the head-of-line
request always admits first; other queued requests with the *same* prompt
length ride along in the same prefill dispatch (one XLA compilation per
(group_size, prompt_len) shape). This keeps admission pad-free — padded
prompt tokens would pollute the causal KV cache — while still batching
prefill work when traffic has repeated shapes.

docs/serving.md documents the full lifecycle this module drives
(admission -> decode chunks -> retirement) and the ``sync_every``
semantics of the engine loop around it.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request.

    ``tokens`` is the prompt (prompt_len,) int32; ``patches`` carries VLM
    image features when the model has a vision frontend.
    """

    rid: int
    tokens: np.ndarray
    max_new_tokens: int
    patches: Optional[np.ndarray] = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class FinishedRequest:
    """A completed request with its per-sequence DR-traffic ledger.

    ``traffic`` is in bytes, split into the four DR-eDRAM categories
    (ondie_read / ext_read / ondie_write / ext_write); it accumulates the
    analytic prompt phase plus the measured per-step decode ledger, so
    ``external_reduction`` reconciles with
    ``dr_edram.closed_form_reduction(seq_len, hot_cap)`` for *this*
    sequence regardless of what other lengths shared the batch.
    """

    rid: int
    prompt_len: int
    tokens: np.ndarray  # (n_generated,) int32
    seq_len: int  # prompt + appended decode tokens
    steps: int  # decode dispatches this request was active for
    traffic: Dict[str, int]

    @property
    def external_reduction(self) -> float:
        from repro.core.kv_cache import external_reduction

        return external_reduction(self.traffic)


class SlotScheduler:
    """Host-side slot table + FIFO admission queue (see module docstring)."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: Deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * n_slots

    # -- queue ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Append ``req`` to the FIFO admission queue (host-side only)."""
        self.queue.append(req)

    # -- slot table -----------------------------------------------------
    def free_slots(self) -> List[int]:
        """Slot indices with no live request (admission targets)."""
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def active_slots(self) -> List[int]:
        """Slot indices currently holding a live request."""
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    # -- admission ------------------------------------------------------
    @staticmethod
    def _group_key(req: Request):
        """Requests may share a prefill dispatch iff their stacked batch is
        homogeneous: same prompt length AND same frontend-feature shape
        (patches present with one shape, or absent)."""
        patches = None if req.patches is None else np.asarray(req.patches).shape
        return (req.prompt_len, patches)

    def next_group(self) -> Tuple[List[int], List[Request]]:
        """Pop the next admissible group: head-of-line request plus any
        queued requests sharing its group key (prompt length + patches
        shape), up to the number of free slots. Returns ([], []) when
        nothing can be admitted."""
        free = self.free_slots()
        if not free or not self.queue:
            return [], []
        key = self._group_key(self.queue[0])
        group: List[Request] = []
        rest: Deque[Request] = deque()
        while self.queue and len(group) < len(free):
            req = self.queue.popleft()
            if self._group_key(req) == key:
                group.append(req)
            else:
                rest.append(req)
        rest.extend(self.queue)
        self.queue = rest
        slots = free[: len(group)]
        for s, req in zip(slots, group):
            self.slot_req[s] = req
        return slots, group

    # -- retirement -----------------------------------------------------
    def retire(self, slot: int) -> Request:
        """Free ``slot`` and return the request that occupied it (the
        engine harvests its outputs before the slot is reused)."""
        req = self.slot_req[slot]
        assert req is not None, f"retiring free slot {slot}"
        self.slot_req[slot] = None
        return req

    def idle(self) -> bool:
        """True when nothing is queued and no slot is occupied — the
        engine's serving-loop exit condition."""
        return not self.queue and all(r is None for r in self.slot_req)
