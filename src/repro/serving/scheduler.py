"""Slot scheduler for continuous-batching serving (host-side control plane).

The engine (``serving/engine.py``) holds a fixed number of *slots* — batch
rows of the per-slot tiered KV cache — and decodes all active slots in
lock-free step: each slot is at its own sequence length. This module owns
the host-side bookkeeping around that device state:

  * a FIFO request queue (``submit``),
  * the slot table (which request occupies which slot),
  * admission pairing — either **chunked** (``next_fills``: every free
    slot takes the next queued request, any prompt length; the engine
    streams the prompt in as fixed-size chunk dispatches) or **grouped**
    (``next_group``: same-prompt-length requests share one whole-prompt
    prefill dispatch),
  * retirement: freeing a slot once its request is done.

The scheduler never touches device arrays; it only decides *which* slots
the engine should fill or free at each synchronization point. Under
paged serving the admission step additionally consults the refcounted
prefix tree (``serving/paging.py``): a new prompt's longest cached
prefix is adopted by reference (plus a copy-on-write boundary page) and
only the novel suffix is chunk-prefilled. Mid-decode
admission is the point of the design: new prompts prefill into freed slots
while the remaining slots keep decoding, so the decode hot loop stays
saturated instead of draining the whole batch (the seed engine's lock-step
model, where the slowest sequence gated everyone).

Both policies are FIFO and pad-free (padded prompt tokens would pollute
the causal KV cache; chunked admission masks the final partial chunk by
per-slot valid counts instead). The difference is compilation shape:
grouped admission costs one XLA prefill compilation per (group_size,
prompt_len) pair and makes unequal lengths wait for a shape partner;
chunked admission has exactly one fixed (slots, chunk) dispatch shape,
so any length mix admits immediately (docs/serving.md, "Admission").

docs/serving.md documents the full lifecycle this module drives
(admission -> decode chunks -> retirement) and the ``sync_every``
semantics of the engine loop around it.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request.

    ``tokens`` is the prompt (prompt_len,) int32; ``patches`` carries VLM
    image features when the model has a vision frontend.
    """

    rid: int
    tokens: np.ndarray
    max_new_tokens: int
    patches: Optional[np.ndarray] = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class FinishedRequest:
    """A completed request with its per-sequence DR-traffic ledger.

    ``traffic`` is in bytes, split into the four DR-eDRAM categories
    (ondie_read / ext_read / ondie_write / ext_write); it accumulates the
    analytic prompt phase plus the measured per-step decode ledger, so
    ``external_reduction`` reconciles with
    ``dr_edram.closed_form_reduction(seq_len, hot_cap)`` for *this*
    sequence regardless of what other lengths shared the batch.
    """

    rid: int
    prompt_len: int
    tokens: np.ndarray  # (n_generated,) int32
    seq_len: int  # prompt + appended decode tokens
    steps: int  # decode dispatches this request was active for
    traffic: Dict[str, int]
    # prompt tokens restored from the shared prefix cache instead of being
    # prefilled (paged serving with prefix sharing; see serving/paging.py).
    # The skipped prefill steps vanish from ``traffic`` — the DR-ledger
    # external-read delta vs an unshared run reconciles with this count.
    prefix_tokens_reused: int = 0

    @property
    def external_reduction(self) -> float:
        from repro.core.kv_cache import external_reduction

        return external_reduction(self.traffic)


class SlotScheduler:
    """Host-side slot table + FIFO admission queue (see module docstring)."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: Deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * n_slots

    # -- queue ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Append ``req`` to the FIFO admission queue (host-side only)."""
        self.queue.append(req)

    # -- slot table -----------------------------------------------------
    def free_slots(self) -> List[int]:
        """Slot indices with no live request (admission targets)."""
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def active_slots(self) -> List[int]:
        """Slot indices currently holding a live request."""
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    # -- admission ------------------------------------------------------
    @staticmethod
    def _group_key(req: Request):
        """Requests may share a prefill dispatch iff their stacked batch is
        homogeneous: same prompt length AND same frontend-feature shape
        (patches present with one shape, or absent)."""
        patches = None if req.patches is None else np.asarray(req.patches).shape
        return (req.prompt_len, patches)

    def next_group(self) -> Tuple[List[int], List[Request]]:
        """Pop the next admissible group: head-of-line request plus any
        queued requests sharing its group key (prompt length + patches
        shape), up to the number of free slots. Returns ([], []) when
        nothing can be admitted."""
        free = self.free_slots()
        if not free or not self.queue:
            return [], []
        key = self._group_key(self.queue[0])
        group: List[Request] = []
        rest: Deque[Request] = deque()
        while self.queue and len(group) < len(free):
            req = self.queue.popleft()
            if self._group_key(req) == key:
                group.append(req)
            else:
                rest.append(req)
        rest.extend(self.queue)
        self.queue = rest
        slots = free[: len(group)]
        for s, req in zip(slots, group):
            self.slot_req[s] = req
        return slots, group

    def next_fills(self) -> List[Tuple[int, Request]]:
        """Chunked-admission pairing: hand each free slot the next queued
        request — strict FIFO, no length grouping. Chunk streaming makes
        the prompt length irrelevant to compilation (the engine's chunk
        dispatch has one fixed (slots, chunk) shape), so unlike
        ``next_group`` nothing ever waits for a shape partner and there
        is no head-of-line blocking on unusual prompt lengths."""
        out: List[Tuple[int, Request]] = []
        for s in self.free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            self.slot_req[s] = req
            out.append((s, req))
        return out

    # -- retirement -----------------------------------------------------
    def retire(self, slot: int) -> Request:
        """Free ``slot`` and return the request that occupied it (the
        engine harvests its outputs before the slot is reused)."""
        req = self.slot_req[slot]
        assert req is not None, f"retiring free slot {slot}"
        self.slot_req[slot] = None
        return req

    def idle(self) -> bool:
        """True when nothing is queued and no slot is occupied — the
        engine's serving-loop exit condition."""
        return not self.queue and all(r is None for r in self.slot_req)
