"""Slot scheduler for continuous-batching serving (host-side control plane).

The engine (``serving/engine.py``) holds a fixed number of *slots* — batch
rows of the per-slot tiered KV cache — and decodes all active slots in
lock-free step: each slot is at its own sequence length. This module owns
the host-side bookkeeping around that device state:

  * a bounded admission queue (``submit``; overflow is *shed*, never
    silently grown — the backpressure contract in docs/serving.md),
  * the slot table (which request occupies which slot),
  * admission pairing — either **chunked** (``next_fills``: every free
    slot takes the strongest-claim queued request, any prompt length;
    the engine streams the prompt in as fixed-size chunk dispatches) or
    **grouped** (``next_group``: same-prompt-length requests share one
    whole-prompt prefill dispatch),
  * retirement: freeing a slot once its request is done,
  * preemption support: ``requeue`` puts a victim's request back at the
    head of the queue and ``preempt_victims`` ranks which active slots a
    pressured admission/growth may reclaim (newest-first / fewest-
    tokens-emitted, never a stronger claim than the beneficiary's).

The scheduler never touches device arrays; it only decides *which* slots
the engine should fill or free at each synchronization point. Under
paged serving the admission step additionally consults the refcounted
prefix tree (``serving/paging.py``): a new prompt's longest cached
prefix is adopted by reference (plus a copy-on-write boundary page) and
only the novel suffix is chunk-prefilled. Mid-decode
admission is the point of the design: new prompts prefill into freed slots
while the remaining slots keep decoding, so the decode hot loop stays
saturated instead of draining the whole batch (the seed engine's lock-step
model, where the slowest sequence gated everyone).

Admission order is by *claim* — ``(priority desc, arrival asc)`` — which
degrades to plain FIFO when every request carries the default priority.
Both policies are pad-free (padded prompt tokens would pollute
the causal KV cache; chunked admission masks the final partial chunk by
per-slot valid counts instead). The difference is compilation shape:
grouped admission costs one XLA prefill compilation per (group_size,
prompt_len) pair and makes unequal lengths wait for a shape partner;
chunked admission has exactly one fixed (slots, chunk) dispatch shape,
so any length mix admits immediately (docs/serving.md, "Admission").

docs/serving.md documents the full lifecycle this module drives
(admission -> decode chunks -> retirement/preemption) and the
``sync_every`` semantics of the engine loop around it; the "Degradation
modes" section covers the overload paths (preemption, deadlines,
cancellation, shedding).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np


class SchedulerError(RuntimeError):
    """Slot-table misuse (retiring or requeueing an unoccupied slot):
    carries the slot index so the report survives ``python -O``."""

    def __init__(self, msg: str, slot: Optional[int] = None):
        if slot is not None:
            msg = f"{msg} (slot={slot})"
        super().__init__(msg)
        self.slot = slot


@dataclasses.dataclass(eq=False)
class Request:
    """One generation request (identity equality: the queue removes
    requests by object, and field equality would compare prompt arrays).

    ``tokens`` is the prompt (prompt_len,) int32; ``patches`` carries VLM
    image features when the model has a vision frontend. ``deadline`` is
    an absolute time on the engine's clock (``Engine(clock=...)``) after
    which the request is expired instead of served further; ``priority``
    orders admission and bounds preemption (a request may only preempt
    strictly weaker claims — lower priority, or equal priority but later
    arrival).

    The remaining fields are engine-managed preemption bookkeeping: a
    preempted request's already-emitted tokens are folded into ``tokens``
    (so re-admission rides the prefix cache and recomputes only past the
    shared prefix), ``orig_prompt_len`` remembers where the real prompt
    ended, and the carried ledgers accumulate the work the earlier
    attempts already paid for.
    """

    rid: int
    tokens: np.ndarray
    max_new_tokens: int
    patches: Optional[np.ndarray] = None
    deadline: Optional[float] = None
    priority: int = 0
    # -- engine-managed (preemption / accounting) -----------------------
    arrival: Optional[int] = None  # submission order, stamped once
    n_preemptions: int = 0
    orig_prompt_len: Optional[int] = None  # set when emitted tokens fold in
    carry_traffic: Optional[Dict[str, int]] = None  # bytes, prior attempts
    carry_reused: int = 0  # prefix tokens reused by prior attempts
    # speculative-decoding ledger of prior attempts (draft proposals
    # scored / accepted before a preemption), folded into the terminal
    # FinishedRequest so acceptance accounting survives eviction
    carry_drafted: int = 0
    carry_accepted: int = 0

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])

    @property
    def claim(self) -> Tuple[int, int]:
        """Admission/preemption strength: lexicographically SMALLER is
        stronger. Arrival breaks priority ties, so the oldest request at
        the top priority can always preempt everyone else — the global-
        progress guarantee preemption liveness rests on."""
        return (-self.priority, self.arrival if self.arrival is not None else 0)


@dataclasses.dataclass
class FinishedRequest:
    """A completed (or terminated) request with its per-sequence DR-traffic
    ledger.

    ``traffic`` is in bytes, split into the four DR-eDRAM categories
    (ondie_read / ext_read / ondie_write / ext_write); it accumulates the
    analytic prompt phase plus the measured per-step decode ledger, so
    ``external_reduction`` reconciles with
    ``dr_edram.closed_form_reduction(seq_len, hot_cap)`` for *this*
    sequence regardless of what other lengths shared the batch. (For a
    preempted-and-resumed request the ledger additionally carries the
    recomputed prefill work of the earlier attempts, so it reports what
    the device actually did, not the unconstrained closed form.)

    ``outcome`` is the terminal state: ``finished`` (full budget or stop
    token), ``cancelled`` (``Engine.cancel`` / ``Router.cancel``),
    ``expired`` (deadline), ``rejected`` (shed by the bounded queue
    before any work ran), or ``failed`` (router-level: the per-request
    retry budget was exhausted across replica failures — single-engine
    serving never emits it). Non-``finished`` outcomes still surface any
    tokens emitted before termination. ``n_preemptions`` counts how many
    times the request was evicted mid-flight and recomputed-from-prefix.
    """

    rid: int
    prompt_len: int
    tokens: np.ndarray  # (n_generated,) int32
    seq_len: int  # prompt + appended decode tokens
    steps: int  # decode dispatches this request was active for
    traffic: Dict[str, int]
    # prompt tokens restored from the shared prefix cache instead of being
    # prefilled (paged serving with prefix sharing; see serving/paging.py).
    # The skipped prefill steps vanish from ``traffic`` — the DR-ledger
    # external-read delta vs an unshared run reconciles with this count.
    prefix_tokens_reused: int = 0
    outcome: str = "finished"
    n_preemptions: int = 0
    # speculative decoding (Engine(spec_k=K)): draft proposals the
    # verifier scored for this request, and how many it accepted. Every
    # round emits 1 + accepted tokens, so the per-request identity
    # ``len(tokens) == accepted + rounds`` reconciles the ledger exactly
    # (asserted in tests/test_speculative.py); both stay 0 on
    # non-speculative engines.
    drafted_tokens: int = 0
    accepted_tokens: int = 0

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals the target confirmed (0.0 when
        nothing was drafted — non-speculative runs, empty generations)."""
        return (self.accepted_tokens / self.drafted_tokens
                if self.drafted_tokens else 0.0)

    @property
    def external_reduction(self) -> float:
        from repro.core.kv_cache import external_reduction

        return external_reduction(self.traffic)


def terminal_record(req: Request, outcome: str) -> FinishedRequest:
    """Terminal record for a request that holds no slot (rejected /
    cancelled / expired while queued, or failed at the router after its
    retry budget ran out). A preempted-then-terminated request still
    surfaces the tokens its earlier attempts emitted (folded into
    ``tokens`` past ``orig_prompt_len``) and the work they cost
    (``carry_traffic``). Pure host bookkeeping — both the engine's
    queue sweep and the router's fleet-level terminations route through
    this one constructor so the two layers can never disagree on what a
    slotless terminal looks like."""
    from repro.core.kv_cache import TRAFFIC_KEYS

    if req.orig_prompt_len is not None:
        tokens = np.asarray(req.tokens, np.int32)[req.orig_prompt_len:]
        prompt_len = req.orig_prompt_len
    else:
        tokens = np.zeros((0,), np.int32)
        prompt_len = req.prompt_len
    traffic = (dict(req.carry_traffic) if req.carry_traffic
               else {k: 0 for k in TRAFFIC_KEYS})
    return FinishedRequest(
        rid=req.rid, prompt_len=prompt_len, tokens=tokens,
        seq_len=prompt_len + len(tokens), steps=len(tokens),
        traffic=traffic, prefix_tokens_reused=req.carry_reused,
        outcome=outcome, n_preemptions=req.n_preemptions,
        drafted_tokens=req.carry_drafted,
        accepted_tokens=req.carry_accepted,
    )


class SlotScheduler:
    """Host-side slot table + bounded claim-ordered admission queue (see
    module docstring)."""

    def __init__(self, n_slots: int, max_queue: Optional[int] = None):
        self.n_slots = n_slots
        self.max_queue = max_queue
        self.queue: Deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self._arrival = 0

    # -- queue ----------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Enqueue ``req``; returns False (shed) when the bounded queue is
        full. The arrival stamp is assigned once and survives preemption
        requeues, so a preempted request keeps its place in claim order."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            return False
        if req.arrival is None:
            req.arrival = self._arrival
            self._arrival += 1
        self.queue.append(req)
        return True

    def drop(self, req: Request) -> None:
        """Remove a queued request (cancellation / deadline expiry)."""
        self.queue.remove(req)

    # -- slot table -----------------------------------------------------
    def free_slots(self) -> List[int]:
        """Slot indices with no live request (admission targets)."""
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def active_slots(self) -> List[int]:
        """Slot indices currently holding a live request."""
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    # -- admission ------------------------------------------------------
    def _pop_best(self) -> Request:
        """Remove and return the strongest-claim queued request (plain
        FIFO when priorities are uniform). O(queue) — queues here are
        short host-side structures, not token streams."""
        best = min(self.queue, key=lambda r: r.claim)
        self.queue.remove(best)
        return best

    @staticmethod
    def _group_key(req: Request):
        """Requests may share a prefill dispatch iff their stacked batch is
        homogeneous: same prompt length AND same frontend-feature shape
        (patches present with one shape, or absent)."""
        patches = None if req.patches is None else np.asarray(req.patches).shape
        return (req.prompt_len, patches)

    def next_group(self) -> Tuple[List[int], List[Request]]:
        """Pop the next admissible group: the strongest-claim request plus
        any queued requests sharing its group key (prompt length + patches
        shape), up to the number of free slots. Returns ([], []) when
        nothing can be admitted."""
        free = self.free_slots()
        if not free or not self.queue:
            return [], []
        head = min(self.queue, key=lambda r: r.claim)
        key = self._group_key(head)
        group: List[Request] = []
        for req in sorted(self.queue, key=lambda r: r.claim):
            if len(group) >= len(free):
                break
            if self._group_key(req) == key:
                group.append(req)
        for req in group:
            self.queue.remove(req)
        slots = free[: len(group)]
        for s, req in zip(slots, group):
            self.slot_req[s] = req
        return slots, group

    def next_fills(self) -> List[Tuple[int, Request]]:
        """Chunked-admission pairing: hand each free slot the strongest-
        claim queued request — no length grouping. Chunk streaming makes
        the prompt length irrelevant to compilation (the engine's chunk
        dispatch has one fixed (slots, chunk) shape), so unlike
        ``next_group`` nothing ever waits for a shape partner and there
        is no head-of-line blocking on unusual prompt lengths."""
        out: List[Tuple[int, Request]] = []
        for s in self.free_slots():
            if not self.queue:
                break
            req = self._pop_best()
            self.slot_req[s] = req
            out.append((s, req))
        return out

    # -- retirement / preemption ----------------------------------------
    def retire(self, slot: int) -> Request:
        """Free ``slot`` and return the request that occupied it (the
        engine harvests its outputs before the slot is reused)."""
        req = self.slot_req[slot]
        if req is None:
            raise SchedulerError("retiring free slot", slot=slot)
        self.slot_req[slot] = None
        return req

    def requeue(self, slot: int) -> Request:
        """Preemption / failed admission: free ``slot`` and put its
        request back in the queue (bypassing the bound — the request was
        already accepted; shedding it now would break the admission
        contract). Claim-ordered selection makes the queue position
        irrelevant; appendleft just keeps ``len(queue)`` honest for
        backpressure accounting."""
        req = self.slot_req[slot]
        if req is None:
            raise SchedulerError("requeueing free slot", slot=slot)
        self.slot_req[slot] = None
        self.queue.appendleft(req)
        return req

    def preempt_victims(
        self,
        beneficiary: Request,
        emitted: Mapping[int, int],
        exclude: Sequence[int] = (),
    ) -> List[int]:
        """Active slots the ``beneficiary`` may reclaim pages from, best
        victim first. Eligible victims hold a strictly weaker claim
        (lower priority, or same priority but later arrival) — so the
        strongest claim in the system can preempt every other slot and
        is itself unpreemptable, which is what makes overload *degrade*
        (oldest request always completes) instead of livelock. Among
        eligible victims the order is fewest-tokens-emitted first,
        newest arrival as tie-break: evict the work that is cheapest to
        recompute."""
        ex = set(exclude)
        cands = [
            s
            for s, r in enumerate(self.slot_req)
            if r is not None and s not in ex and beneficiary.claim < r.claim
        ]
        cands.sort(
            key=lambda s: (
                emitted.get(s, 0),
                -(self.slot_req[s].arrival or 0),
            )
        )
        return cands

    def idle(self) -> bool:
        """True when nothing is queued and no slot is occupied — the
        engine's serving-loop exit condition."""
        return not self.queue and all(r is None for r in self.slot_req)
