"""Weight-reload-free serving: continuous batching over per-slot DR caches.

Public API
----------
:class:`~repro.serving.engine.Engine`
    Owns the packed (ROM-form) weights — loaded to device once, never
    reloaded — and the fully-jitted decode step. ``serve(requests)`` runs
    the continuous-batching loop; ``generate(prompts, ...)`` is the
    aligned-batch convenience wrapper.
:class:`~repro.serving.scheduler.Request` /
:class:`~repro.serving.scheduler.FinishedRequest`
    One generation request and its completed result (tokens + the
    per-sequence DR-traffic ledger that reconciles with
    ``core.dr_edram.closed_form_reduction``).
:class:`~repro.serving.scheduler.SlotScheduler`
    Host-side control plane: FIFO queue, slot table, pad-free admission
    grouping, retirement.

Continuous-batching semantics
-----------------------------
The engine holds ``slots`` batch rows. Each row is an independent
sequence at its own length (``TieredKVCache.lengths``); the jitted decode
step advances every *active* slot by one token per dispatch with
on-device sampling and an on-device ``done`` mask (stop token or budget),
so the Python loop never synchronizes with the device. Every
``sync_every`` steps the host harvests finished slots and prefills queued
prompts into the freed rows — admission happens mid-decode, while the
remaining slots keep generating.
"""

from repro.serving.engine import DecodeState, Engine, GenerationResult
from repro.serving.scheduler import FinishedRequest, Request, SlotScheduler

__all__ = [
    "DecodeState",
    "Engine",
    "FinishedRequest",
    "GenerationResult",
    "Request",
    "SlotScheduler",
]
