"""Weight-reload-free serving: continuous batching over per-slot DR caches.

Public API
----------
:class:`~repro.serving.engine.Engine`
    Owns the packed (ROM-form) weights — loaded to device once, never
    reloaded — and the fully-jitted decode step. ``serve(requests)`` runs
    the continuous-batching loop; ``generate(prompts, ...)`` is the
    aligned-batch convenience wrapper.
:class:`~repro.serving.scheduler.Request` /
:class:`~repro.serving.scheduler.FinishedRequest`
    One generation request and its completed result (tokens + the
    per-sequence DR-traffic ledger that reconciles with
    ``core.dr_edram.closed_form_reduction``).
:class:`~repro.serving.scheduler.SlotScheduler`
    Host-side control plane: bounded claim-ordered queue, slot table,
    pad-free admission grouping, retirement, preemption victim policy.
:class:`~repro.serving.router.Router` /
:class:`~repro.serving.replica.Replica`
    Fault-tolerant data-parallel fleet: N engine replicas (BitROM's
    immutable packed-ternary weights make replica state KV-pages-only)
    behind least-loaded placement, backoff retries, heartbeat/straggler
    health checks, and bit-exact failover — cold recompute-from-prefix
    after a kill, checksummed fp8 KV handoff (warm migration) off a
    draining replica (docs/serving.md, "Multi-replica serving").
:class:`~repro.serving.chaos.ChaosInjector` /
:func:`~repro.serving.chaos.check_serving_invariants`
    Seeded serving-plane fault injection (pool exhaustion, stragglers,
    mid-flight cancellation) and the machine-checked page-refcount
    protocol invariants, wired in via ``serve(on_iteration=...)``.
:class:`~repro.serving.chaos.FleetChaosInjector` /
:func:`~repro.serving.chaos.check_fleet_invariants`
    The replica-level adversary (kills, stalls, handoff corruption on
    independent seeded streams) and the fleet-wide audit: every accepted
    request in exactly one place, no page owned by two replicas, router
    counters reconciled — run after every router tick.

Overload degrades instead of failing: page pressure triggers LRU prefix
eviction then preemption-with-recompute (bit-exact for greedy),
deadlines/cancellation/bounded-queue shedding surface as terminal
``FinishedRequest.outcome`` values, and ``Engine.last_stats``
(:class:`~repro.serving.engine.ServeStats`) counts what happened
(docs/serving.md, "Degradation modes").

Continuous-batching semantics
-----------------------------
The engine holds ``slots`` batch rows. Each row is an independent
sequence at its own length (``TieredKVCache.lengths``); the jitted decode
step advances every *active* slot by one token per dispatch with
on-device sampling and an on-device ``done`` mask (stop token or budget),
so the Python loop never synchronizes with the device. Every
``sync_every`` steps the host harvests finished slots and prefills queued
prompts into the freed rows — admission happens mid-decode, while the
remaining slots keep generating.
"""

from repro.core.kv_cache import HandoffError
from repro.serving.chaos import (ChaosConfig, ChaosInjector,
                                 FleetChaosConfig, FleetChaosInjector,
                                 InvariantViolation,
                                 check_fleet_invariants,
                                 check_serving_invariants)
from repro.serving.engine import (DecodeState, Engine, GenerationResult,
                                  ServeStats)
from repro.serving.paging import PagePool, PagePoolError, PrefixCache
from repro.serving.replica import (LocalTransport, Replica, ReplicaDead,
                                   Transport)
from repro.serving.router import Router, RouterStats
from repro.serving.scheduler import (FinishedRequest, Request,
                                     SchedulerError, SlotScheduler,
                                     terminal_record)

__all__ = [
    "ChaosConfig",
    "ChaosInjector",
    "DecodeState",
    "Engine",
    "FinishedRequest",
    "FleetChaosConfig",
    "FleetChaosInjector",
    "GenerationResult",
    "HandoffError",
    "InvariantViolation",
    "LocalTransport",
    "PagePool",
    "PagePoolError",
    "PrefixCache",
    "Replica",
    "ReplicaDead",
    "Request",
    "Router",
    "RouterStats",
    "SchedulerError",
    "ServeStats",
    "SlotScheduler",
    "Transport",
    "check_fleet_invariants",
    "check_serving_invariants",
    "terminal_record",
]
