"""Draft-verify speculative decoding: acceptance rule + draft config.

The serving engine (``serving/engine.py``, ``Engine(draft_cfg=...,
draft_params=..., spec_k=K)``) decodes K tokens per dispatch round:

  1. **draft** — a small ternary model proposes K-1 greedy continuations
     of the slot's pending token (K cheap ``decode_step`` calls against
     the slot's private draft KV cache),
  2. **verify** — the target model scores the whole K-token chunk in ONE
     fixed-shape ``transformer.spec_verify_chunk`` dispatch against its
     live cache (the chunked-prefill machinery; no new kernel), without
     appending,
  3. **accept** — :func:`longest_accepted_prefix` below keeps the
     longest prefix the target itself would have produced, then the
     engine commits exactly that many KV rows (linear layouts commit
     the full chunk and roll back via ``kv_cache.truncate``; ring
     layouts commit only the accepted rows).

Greedy speculation is *output-invariant*: every emitted token is the
target model's own argmax — the draft only decides how many of them
land per round — so speculative greedy decode is bit-identical to the
sequential loop for every accept/reject mix (asserted end-to-end in
tests/test_speculative.py). Temperature sampling needs rejection
sampling to keep the target distribution; that path is stubbed
(:func:`rejection_sample`) and the engine refuses the combination.

Why a ternary draft is nearly free (ROADMAP / TOM, ROMA): the draft's
packed weights are resident on-die next to the target's, so K draft
steps add no weight traffic — the classic speculation bandwidth cost
(stream the draft from DRAM) does not exist in the BitROM deployment.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def longest_accepted_prefix(
    chunk: jax.Array,  # (slots, K) int32 — pending token ‖ draft proposals
    greedy: jax.Array,  # (slots, K) int32 — target argmax after chunk[:, i]
    chunk_valid: jax.Array,  # (slots,) int32 — valid chunk rows (<= K)
    stop_token: Optional[int] = None,
    force_reject: bool = False,
) -> jax.Array:
    """Vectorized accept rule: tokens to emit this round, (slots,) int32
    in ``[1, chunk_valid]`` (0 where ``chunk_valid`` is 0).

    ``chunk[:, 0]`` is the slot's pending token — already sampled by the
    target, so it always emits (speculation never yields less than one
    token per round). Proposal ``chunk[:, i]`` (i >= 1) is accepted iff
    every earlier proposal was accepted and it equals ``greedy[:, i-1]``
    — the token the sequential loop would have sampled next. The count
    is ``1 + sum(cumprod(match))``: pure vectorized ops, no per-slot
    control flow, XLA-safe inside the jitted round.

    Two clips keep parity with the sequential loop's stop handling:
    the emitted count never passes the first position whose *target*
    continuation is the stop token (the sequential loop retires the slot
    there, leaving the stop token pending and unemitted — even if the
    draft correctly predicted it), and padding rows past ``chunk_valid``
    never match. ``force_reject=True`` statically folds every proposal
    to rejected — the engine's ``spec_force="reject"`` knob, which makes
    the maximal-rollback path deterministic for CI.
    """
    k = chunk.shape[1]
    n_valid = chunk_valid.astype(jnp.int32)
    if k > 1 and not force_reject:
        i = jnp.arange(1, k, dtype=jnp.int32)[None]  # (1, K-1)
        match = (chunk[:, 1:] == greedy[:, :-1]) & (i < n_valid[:, None])
        n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    else:
        n_acc = jnp.zeros(chunk.shape[:1], jnp.int32)
    n_emit = jnp.minimum(1 + n_acc, jnp.maximum(n_valid, 1))
    if stop_token is not None:
        j = jnp.arange(k, dtype=jnp.int32)[None]
        is_stop = (greedy == jnp.int32(stop_token)) & (j < n_valid[:, None])
        first_stop = jnp.where(
            is_stop.any(axis=1),
            jnp.argmax(is_stop, axis=1).astype(jnp.int32),
            jnp.int32(k),
        )
        n_emit = jnp.minimum(n_emit, first_stop + 1)
    return jnp.where(n_valid > 0, n_emit, 0)


def make_draft_config(target: ModelConfig, n_layers: int = 2,
                      d_model: int = 64) -> ModelConfig:
    """Derive a draft config from any target: a small dense full-
    attention model sharing the target's vocabulary (the only hard
    coupling — draft proposals are token ids scored by the target).
    Everything speculative about the draft is architectural freedom;
    greedy outputs do not depend on it, only acceptance rates do.
    Real deployments register a trained draft (``falcon3-draft`` in
    ``configs/falcon3_1b.py``); this helper is for tests/benches that
    need a vocab-matched draft for arbitrary smoke targets."""
    return ModelConfig(
        name=target.name + "-draft",
        family="dense",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=2 * d_model,
        vocab_size=target.vocab_size,
        rope_theta=target.rope_theta,
        tie_embeddings=True,
        bitnet=dataclasses.replace(target.bitnet, lora_rank=0),
        source="derived draft (speculative decoding)",
    )


def rejection_sample(*args, **kwargs):
    """Temperature-sampled speculation (Leviathan-style rejection
    sampling over draft vs target probabilities) is not implemented:
    the engine's greedy acceptance emits target-argmax tokens only.
    Stubbed so the API surface names the missing piece; the engine
    raises before any sampling engine-side state exists."""
    raise NotImplementedError(
        "speculative decoding is greedy-only: temperature speculation "
        "needs draft/target rejection sampling (see docs/serving.md, "
        "'Speculative decoding')"
    )
