"""Serving-plane fault injection + machine-checked invariants.

The serving engine's graceful-degradation claims (docs/serving.md,
"Degradation modes") are protocol claims: refcounts never go negative,
the free list and the referenced pages partition the pool, preempted
work is recomputed bit-exactly. This module turns them into executable
checks and adversarial inputs:

  * :func:`check_serving_invariants` — re-derives the entire page-pool
    refcount protocol from first principles against a live ``_ServeCtx``
    (every count equals its known readers: the prefix tree, the live
    slots' page tables, plus any injector-held pages) and validates the
    host page-table mirror. Run after every engine loop iteration under
    test via ``Engine.serve(on_iteration=...)``.
  * :class:`ChaosInjector` — a seeded, deterministic adversary built on
    the training plane's fault vocabulary (``distributed/fault.py``):
    transient pool exhaustion (grabs pages and holds them for a few
    iterations), decode-straggler stalls (sleeps inside the loop and
    checks the ``StragglerMonitor`` flags them), and mid-flight
    cancellation (prefers slots still prefilling — the hardest path).
    Same seed, same serve call -> same injection sequence, which is what
    lets CI pin three fixed seeds and diff outcomes run-over-run.

The injector only uses public knobs (``PagePool.alloc``/``decref``,
``Engine.cancel``) — it is a hostile *client*, not a monkey-patch — so
anything it breaks is a real protocol hole, not a test artifact.
"""

from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Dict, List, Optional, Tuple

from repro.distributed.fault import FaultSchedule, StragglerMonitor


class InvariantViolation(RuntimeError):
    """A serving-plane protocol invariant failed under test."""


def check_serving_invariants(ctx, extra_refs: Optional[Dict[int, int]] = None,
                             sdc_budget: Optional[Dict[str, int]] = None
                             ) -> None:
    """Validate the page-pool refcount protocol against ``ctx`` (the
    engine's ``_ServeCtx``), raising :class:`InvariantViolation` on the
    first breach. Checks, in order:

      1. no refcount is negative;
      2. the free list has no duplicates and only in-range pages;
      3. free pages have refcount 0 and referenced pages are not free —
         free ∪ referenced ∪ quarantined partitions the pool (a
         quarantined page — SDC scrub found its bytes corrupted — is
         neither free nor, once its readers drained, referenced);
      4. ``pool.used()`` reconciles with the free-list length and the
         quarantine set;
      5. every page's refcount equals its KNOWN readers: prefix-tree
         nodes + live slots' page lists + ``extra_refs`` (pages the
         chaos injector is deliberately holding). This is strict
         equality, so it catches leaks (count > readers — e.g. an
         admission unwind that forgot a decref) and double-frees
         (count < readers) alike. It is valid exactly at iteration
         boundaries: the engine unwinds its transient admission increfs
         before the dispatch returns;
      6. the host page-table mirror's live rows agree with the slot page
         lists and contain only in-range ids;
      7. (speculative engines, ``ctx.spec``) the acceptance ledger is
         sane (``accepted_tokens <= drafted_tokens``) and, when paged,
         every decoding slot's page list holds EXACTLY the pages its
         mirrored length needs (``paging.pages_needed``) — i.e. the
         rollback's trailing decref returned every page the rejected
         suffix transiently occupied, leaving none stranded;
      8. quarantined pages are DEAD: never on the free list, never in
         the prefix tree, never in a live slot's page list (and hence
         never in a host-table live row, by check 6) — the SDC repair
         ladder's "never served again" guarantee;
      9. (with ``sdc_budget``, the chaos injector's own injection
         totals) the SDC counters reconcile with the fault schedule:
         the scrub cannot detect faults nobody injected —
         ``weight_reloads <= weight_asserts``, ``|quarantined| <=
         page_flips``, ``slots_quarantined <= nan_pokes``, and
         ``sdc_detected`` is bounded by the grand total. Detections
         legitimately LAG injections (scrub cadence), so these are
         inequalities per tick; the e2e tests pin exact equality at
         end of run.

    A non-paged ctx (``ctx.pool is None``) passes the page checks
    vacuously (the speculation ledger check still runs).
    """
    if getattr(ctx, "spec", False):
        _check_speculation(ctx)
    pool = ctx.pool
    if pool is None:
        return
    quarantined = getattr(pool, "quarantined", set())
    if (pool.refs < 0).any():
        bad = int((pool.refs < 0).argmax())
        raise InvariantViolation(
            f"negative refcount: page {bad} = {int(pool.refs[bad])}")
    free = list(pool._free)
    free_set = set(free)
    if len(free_set) != len(free):
        raise InvariantViolation("free list contains duplicate pages")
    for p in free:
        if not 0 <= p < pool.n_pages:
            raise InvariantViolation(f"free list holds out-of-range page {p}")
        if pool.refs[p] != 0:
            raise InvariantViolation(
                f"page {p} is on the free list with refcount "
                f"{int(pool.refs[p])}")
    for p in range(pool.n_pages):
        if pool.refs[p] > 0 and p in free_set:
            raise InvariantViolation(
                f"page {p} is referenced ({int(pool.refs[p])}) AND free")
        if pool.refs[p] == 0 and p not in free_set and p not in quarantined:
            raise InvariantViolation(
                f"page {p} has no readers but is not on the free list")
    if pool.used() != pool.n_pages - len(free) - len(quarantined):
        raise InvariantViolation(
            f"used() = {pool.used()} but pool has {len(free)} free and "
            f"{len(quarantined)} quarantined of {pool.n_pages}")
    expected: Counter = Counter()
    if ctx.ptree is not None:
        expected.update(ctx.ptree.tree_pages())
    live = {s for s, r in enumerate(ctx.sched.slot_req) if r is not None}
    for s in live:
        expected.update(ctx.slot_pages[s])
    if extra_refs:
        expected.update(extra_refs)
    for p in range(pool.n_pages):
        if int(pool.refs[p]) != expected.get(p, 0):
            kind = ("leak" if pool.refs[p] > expected.get(p, 0)
                    else "double-free")
            raise InvariantViolation(
                f"refcount {kind}: page {p} has count {int(pool.refs[p])} "
                f"but {expected.get(p, 0)} known readers")
    if ctx.host_table is not None:
        if (ctx.host_table < 0).any() or (
                ctx.host_table >= pool.n_pages).any():
            raise InvariantViolation("host page table holds out-of-range ids")
        for s in live:
            row = list(ctx.host_table[s, : len(ctx.slot_pages[s])])
            if row != ctx.slot_pages[s]:
                raise InvariantViolation(
                    f"slot {s} host-table row {row} != page list "
                    f"{ctx.slot_pages[s]}")
    # 8. quarantined pages are dead to every reader
    for p in sorted(quarantined):
        if p in free_set:
            raise InvariantViolation(f"quarantined page {p} is on the "
                                     "free list")
        if ctx.ptree is not None and p in set(ctx.ptree.tree_pages()):
            raise InvariantViolation(f"quarantined page {p} is still in "
                                     "the prefix tree")
        for s in live:
            if p in ctx.slot_pages[s]:
                raise InvariantViolation(
                    f"quarantined page {p} is still mapped by slot {s}")
    if sdc_budget is not None:
        _check_sdc_counters(ctx, sdc_budget)


def _check_sdc_counters(ctx, budget: Dict[str, int]) -> None:
    """Check 9: per-tick reconciliation of the SDC ladder counters
    against the chaos injectors' own totals (``budget`` keys:
    ``weight_asserts`` / ``page_flips`` / ``nan_pokes``). Detection may
    lag injection (scrub cadence) but can never exceed it — a repair
    counter above its injection budget means the scrub is inventing
    faults (or a test is faking counters, which the falsifiability
    suite does on purpose)."""
    st = ctx.stats
    w = int(budget.get("weight_asserts", 0))
    p = int(budget.get("page_flips", 0))
    n = int(budget.get("nan_pokes", 0))
    if st.weight_reloads > w:
        raise InvariantViolation(
            f"weight_reloads {st.weight_reloads} exceeds injected weight "
            f"asserts {w}")
    n_quar = len(getattr(ctx.pool, "quarantined", set()) or ())
    if n_quar > p:
        raise InvariantViolation(
            f"{n_quar} quarantined pages exceed injected page flips {p}")
    if st.slots_quarantined > n:
        raise InvariantViolation(
            f"slots_quarantined {st.slots_quarantined} exceeds injected "
            f"NaN pokes {n}")
    if st.sdc_detected > w + p + n:
        raise InvariantViolation(
            f"sdc_detected {st.sdc_detected} exceeds total injected "
            f"faults {w + p + n}")


def _check_speculation(ctx) -> None:
    """Speculation-specific invariants (check 7 above): the draft/accept
    ledger is consistent, and paged rollback strands no pages. Valid at
    iteration boundaries only — mid-round the device transiently holds
    the full unverified chunk."""
    st = ctx.stats
    if st.accepted_tokens > st.drafted_tokens:
        raise InvariantViolation(
            f"speculation ledger: accepted {st.accepted_tokens} > "
            f"drafted {st.drafted_tokens}")
    for fin in ctx.finished:
        if fin.accepted_tokens > fin.drafted_tokens:
            raise InvariantViolation(
                f"rid {fin.rid}: accepted {fin.accepted_tokens} > "
                f"drafted {fin.drafted_tokens}")
    if ctx.pool is None:
        return
    from repro.serving.paging import pages_needed

    for s, req in enumerate(ctx.sched.slot_req):
        if req is None or s in ctx.prefilling or s in ctx.draft_prefilling:
            continue
        want = pages_needed(ctx.seq_mirror[s], ctx.hot_cap, ctx.page_size)
        if len(ctx.slot_pages[s]) != want:
            raise InvariantViolation(
                f"speculative rollback stranded pages: slot {s} holds "
                f"{len(ctx.slot_pages[s])} pages but its length "
                f"{ctx.seq_mirror[s]} needs {want}")


@dataclasses.dataclass
class ChaosConfig:
    """Per-event-class injection rates (probability per loop iteration)
    and shapes. All classes draw from independent seeded streams, so
    enabling one does not shift another's injection points."""

    seed: int = 0
    exhaust_rate: float = 0.0  # steal pages from the pool...
    exhaust_pages: int = 4  # ...this many at a time...
    exhaust_hold: int = 3  # ...for this many iterations
    straggle_rate: float = 0.0  # sleep inside the serve loop...
    straggle_seconds: float = 0.02  # ...this long (a 'slow decode chunk')
    cancel_rate: float = 0.0  # cancel a live request mid-flight
    # SDC fault classes (serving/sdc.py; need Engine(integrity=...) for
    # the engine to fight back) — independent streams at seed+3/+4/+5:
    weight_flip_rate: float = 0.0  # mint a stuck ROM bit address
    weight_reassert: Optional[int] = 1  # re-asserts per address (None=∞)
    page_decay_rate: float = 0.0  # per-iteration retention decay rate
    nan_rate: float = 0.0  # poke NaN into a decoding slot's hot KV
    check_invariants: bool = True


class ChaosInjector:
    """Deterministic adversary for ``Engine.serve(on_iteration=...)``.

    Usage::

        chaos = ChaosInjector(engine, ChaosConfig(seed=0, exhaust_rate=.2,
                                                  cancel_rate=.1))
        finished = engine.serve(reqs, on_iteration=chaos.on_iteration)
        chaos.release_all(engine._last_ctx)   # drop any still-held pages
        check_serving_invariants(engine._last_ctx)  # tree-only refs remain

    The injector holds stolen pages as a legitimate pool reader (they
    appear in ``extra_refs`` for the invariant check), so exhaustion
    pressure exercises eviction + preemption without ever faking state.
    """

    def __init__(self, engine, config: ChaosConfig):
        from repro.serving import sdc

        self.engine = engine
        self.cfg = config
        self._exhaust = FaultSchedule(config.seed, config.exhaust_rate)
        self._straggle = FaultSchedule(config.seed + 1, config.straggle_rate)
        self._cancel = FaultSchedule(config.seed + 2, config.cancel_rate)
        # SDC adversaries ride their own streams so enabling them never
        # shifts the classic injection points (same contract as above)
        self.rom = sdc.RomFaultInjector(
            config.seed + 3, config.weight_flip_rate,
            reassert=config.weight_reassert)
        self.retention = sdc.RetentionInjector(
            config.seed + 4, config.page_decay_rate)
        self._nan = FaultSchedule(config.seed + 5, config.nan_rate)
        self.nan_pokes = 0
        self.monitor = StragglerMonitor(window=20, factor=3.0)
        self.held: List[Tuple[int, List[int]]] = []  # (release_at, pages)
        self.cancelled: List[int] = []
        self.exhaustions = 0
        self.violations: List[str] = []
        self._last_t: Optional[float] = None

    # -- event draws ----------------------------------------------------
    def on_iteration(self, ctx) -> None:
        it = ctx.iteration
        # release holds that have served their term (pages free like any
        # other reader leaving)
        due = [h for h in self.held if h[0] <= it]
        self.held = [h for h in self.held if h[0] > it]
        for _, pages in due:
            ctx.pool.decref(pages)
        # transient pool exhaustion: become a reader of free pages
        if ctx.pool is not None and self._exhaust.fires(it):
            pages = ctx.pool.alloc(
                min(self.cfg.exhaust_pages, ctx.pool.available()))
            if pages:
                self.held.append((it + self.cfg.exhaust_hold, pages))
                self.exhaustions += 1
        # straggler: a slow decode chunk is just wall time inside the loop
        now = time.perf_counter()
        if self._straggle.fires(it):
            time.sleep(self.cfg.straggle_seconds)
            now = time.perf_counter()
        if self._last_t is not None:
            self.monitor.record(it, now - self._last_t)
        self._last_t = time.perf_counter()
        # cancellation: prefer a slot still mid-prefill (the hardest
        # teardown path), else any live slot, else a queued request
        if self._cancel.fires(it):
            prefill = [ctx.sched.slot_req[s].rid for s in ctx.prefilling
                       if ctx.sched.slot_req[s] is not None]
            active = [r.rid for r in ctx.sched.slot_req if r is not None]
            queued = [r.rid for r in ctx.sched.queue]
            cands = prefill or active or queued
            if cands:
                rid = self._cancel.pick(cands)
                self.engine.cancel(rid)
                self.cancelled.append(rid)
        # SDC planes: stuck ROM bits (persistent, re-asserted after
        # repair), retention decay of stamped KV pages, transient NaN
        # upsets in a decoding slot's hot tier
        self.rom.on_iteration(self.engine, ctx)
        self.retention.on_iteration(self.engine, ctx)
        if self._nan.fires(it):
            from repro.serving import sdc

            decoding = [s for s in ctx.sched.active_slots()
                        if s not in ctx.prefilling
                        and s not in ctx.draft_prefilling]
            if decoding and sdc.inject_activation_nan(
                    ctx, self._nan.pick(decoding)):
                self.nan_pokes += 1
        if self.cfg.check_invariants:
            check_serving_invariants(
                ctx, extra_refs=self._held_counts(),
                sdc_budget=self.sdc_budget())

    def sdc_budget(self) -> Dict[str, int]:
        """The injected-fault totals the counter-reconciliation check
        (check 9) bounds the engine's detections against."""
        return {
            "weight_asserts": self.rom.injected,
            "page_flips": self.retention.injected,
            "nan_pokes": self.nan_pokes,
        }

    # -- teardown -------------------------------------------------------
    def _held_counts(self) -> Counter:
        c: Counter = Counter()
        for _, pages in self.held:
            c.update(pages)
        return c

    def release_all(self, ctx) -> None:
        """Drop every page still held (call after ``serve`` returns, so
        the final pool state is tree-only and checkable)."""
        for _, pages in self.held:
            ctx.pool.decref(pages)
        self.held = []


# ---------------------------------------------------------------------------
# fleet-level chaos: replica kills / stalls / handoff corruption against the
# data-parallel Router (serving/router.py), plus the fleet-wide invariant
# checker the acceptance criteria pin after every router tick
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetChaosConfig:
    """Replica-level injection rates (probability per router tick). Kill,
    stall and corruption draw from independent seeded streams
    (``seed``, ``seed+1``, ``seed+2``) so enabling one class never
    shifts another's injection points — same determinism contract as
    :class:`ChaosConfig`."""

    seed: int = 0
    kill_rate: float = 0.0  # kill a live replica (device state lost)
    stall_rate: float = 0.0  # make one iteration a real straggler...
    stall_seconds: float = 0.25  # ...this slow
    corrupt_rate: float = 0.0  # flip a byte in the next warm handoff
    max_kills: int = 2  # total kill budget for the run
    min_survivors: int = 1  # never kill below this many live replicas
    # SDC planes, per replica per tick (engines must be built with an
    # IntegrityConfig or the faults go undetected by design). Each
    # replica gets its own stream family at seed + 3*(index+1) in sorted
    # replica-name order, so fleets of different sizes never alias.
    weight_flip_rate: float = 0.0  # mint a stuck ROM bit on one replica
    weight_reassert: Optional[int] = 1  # re-asserts per address (None=forever)
    page_decay_rate: float = 0.0  # per-page-per-tick retention decay
    nan_rate: float = 0.0  # transient NaN upset in a decoding slot
    check_invariants: bool = True


class FleetChaosInjector:
    """Deterministic fleet adversary for ``Router.serve(on_tick=...)``.

    Usage::

        chaos = FleetChaosInjector(FleetChaosConfig(seed=0, kill_rate=.1))
        finished = router.serve(reqs, on_tick=chaos.on_tick)

    Every fault goes through a public surface — ``Replica.kill()`` /
    ``Replica.stall()`` / ``Transport.corrupt_next()`` — so anything
    that breaks is a protocol hole, not a test artifact. When
    ``check_invariants`` is on, :func:`check_fleet_invariants` runs
    after every injection round."""

    def __init__(self, config: FleetChaosConfig):
        self.cfg = config
        self._kill = FaultSchedule(config.seed, config.kill_rate)
        self._stall = FaultSchedule(config.seed + 1, config.stall_rate)
        self._corrupt = FaultSchedule(config.seed + 2, config.corrupt_rate)
        self.kills: List[Tuple[int, str]] = []  # (tick, replica)
        self.stalls: List[Tuple[int, str]] = []
        self.corruptions: List[int] = []
        # per-replica SDC adversaries, created lazily on first sight of a
        # replica name; stream family is a function of the name's rank in
        # the fleet (see FleetChaosConfig) so runs are reproducible
        self._sdc: Dict[str, tuple] = {}
        self.nan_pokes = 0

    def _sdc_for(self, name: str, rank: int):
        if name not in self._sdc:
            from repro.serving import sdc

            base = self.cfg.seed + 3 * (rank + 1)
            self._sdc[name] = (
                sdc.RomFaultInjector(base, self.cfg.weight_flip_rate,
                                     reassert=self.cfg.weight_reassert),
                sdc.RetentionInjector(base + 1, self.cfg.page_decay_rate),
                FaultSchedule(base + 2, self.cfg.nan_rate),
            )
        return self._sdc[name]

    def _inject_sdc(self, router) -> None:
        from repro.serving import sdc

        tick = router.stats.ticks
        for rank, name in enumerate(sorted(router.replicas)):
            rep = router.replicas[name]
            if rep.dead or rep.ctx is None:
                continue
            rom, retention, nan = self._sdc_for(name, rank)
            rom.on_iteration(rep.engine, rep.ctx)
            retention.on_iteration(rep.engine, rep.ctx)
            if nan.fires(tick):
                ctx = rep.ctx
                decoding = [s for s in ctx.sched.active_slots()
                            if s not in ctx.prefilling
                            and s not in ctx.draft_prefilling]
                if decoding and sdc.inject_activation_nan(
                        ctx, nan.pick(decoding)):
                    self.nan_pokes += 1

    def sdc_budget(self) -> Dict[str, int]:
        """Fleet-wide injected-fault totals (summed over replicas)."""
        roms = [v[0] for v in self._sdc.values()]
        rets = [v[1] for v in self._sdc.values()]
        return {
            "weight_asserts": sum(r.injected for r in roms),
            "page_flips": sum(r.injected for r in rets),
            "nan_pokes": self.nan_pokes,
        }

    def on_tick(self, router) -> None:
        tick = router.stats.ticks
        live = [r for r in router.replicas.values() if not r.dead]
        if (self._kill.fires(tick) and len(self.kills) < self.cfg.max_kills
                and len(live) > self.cfg.min_survivors):
            victim = self._kill.pick(live)
            victim.kill()
            self.kills.append((tick, victim.name))
            live = [r for r in live if r.name != victim.name]
        if self._stall.fires(tick) and live:
            target = self._stall.pick(live)
            target.stall(self.cfg.stall_seconds)
            self.stalls.append((tick, target.name))
        if self._corrupt.fires(tick):
            corrupt = getattr(router.transport, "corrupt_next", None)
            if corrupt is not None:
                corrupt()
                self.corruptions.append(tick)
        self._inject_sdc(router)
        if self.cfg.check_invariants:
            check_fleet_invariants(router)


def check_fleet_invariants(router) -> None:
    """Fleet-wide protocol audit, valid at tick boundaries. Checks:

      1. **exactly-one-place**: every accepted rid is in exactly one
         location — terminal records, the router's pending list, or ONE
         live replica's queue/slots. In particular no rid is live on two
         replicas, and no rid has two terminal outcomes;
      2. the router's ``assigned`` map agrees with where requests
         actually are;
      3. every live replica session passes the single-engine
         :func:`check_serving_invariants` (a KILLED replica is exempt —
         its session was abandoned and its pool reconciled at harvest);
      4. no two replicas share a :class:`PagePool` (the in-process
         analog of "no page referenced by two replicas" — WITHIN a pool
         the strict refcount census of check 3 already pins every
         reader);
      5. counter reconciliation: router retries equal the per-request
         dispatch surplus, and every terminal outcome the router holds
         is consistent with its accepted set;
      6. SDC retirement accounting: ``stats.sdc_retirements`` equals the
         router's SDC-retired set, and every replica in that set is
         permanently gone — dead, barred from restart, with its engine
         still flagged ``unhealthy`` (nothing quietly resurrected a
         replica whose ROM plane struck out).
    """
    locations: Dict[int, List[str]] = {}

    def seen(rid: int, where: str) -> None:
        locations.setdefault(rid, []).append(where)

    for fin in router.finished:
        seen(fin.rid, f"terminal:{fin.outcome}")
    for p in router.pending:
        seen(p.req.rid, "router:pending")
    live = [r for r in router.replicas.values()
            if not r.dead and r.ctx is not None]
    # a freshly killed replica's session is a legitimate (transient)
    # location until the router harvests it next tick: its requests live
    # on in host bookkeeping even though the device is gone
    holding = [r for r in router.replicas.values() if r.ctx is not None]
    for rep in holding:
        tag = rep.name if not rep.dead else f"{rep.name}(dead)"
        for req in rep.ctx.sched.queue:
            seen(req.rid, f"{tag}:queued")
        for s, req in enumerate(rep.ctx.sched.slot_req):
            if req is not None:
                seen(req.rid, f"{tag}:slot{s}")
    for rid in router.accepted:
        where = locations.get(rid, [])
        if len(where) != 1:
            raise InvariantViolation(
                f"rid {rid} is in {len(where)} places: {where or 'NOWHERE'}")
    for rid, name in router.assigned.items():
        where = locations[rid][0]
        if not (where.startswith(f"{name}:")
                or where.startswith(f"{name}(dead):")):
            raise InvariantViolation(
                f"rid {rid} assigned to {name} but found at {where}")
    for rep in live:
        check_serving_invariants(rep.ctx)
    pools = [id(rep.ctx.pool) for rep in live if rep.ctx.pool is not None]
    if len(set(pools)) != len(pools):
        raise InvariantViolation("two replicas share one PagePool")
    surplus = sum(max(n - 1, 0) for n in router.attempts.values())
    if router.stats.retries != surplus:
        raise InvariantViolation(
            f"router retries {router.stats.retries} != dispatch surplus "
            f"{surplus}")
    for fin in router.finished:
        if fin.rid not in router.accepted:
            raise InvariantViolation(
                f"terminal record for never-accepted rid {fin.rid}")
    n_failed = sum(1 for f in router.finished if f.outcome == "failed")
    if n_failed != router.stats.failed:
        raise InvariantViolation(
            f"failed terminals {n_failed} != stats.failed "
            f"{router.stats.failed}")
    sdc_retired = getattr(router, "_sdc_retired", set())
    if router.stats.sdc_retirements != len(sdc_retired):
        raise InvariantViolation(
            f"sdc_retirements {router.stats.sdc_retirements} != retired "
            f"set {sorted(sdc_retired)}")
    for name in sdc_retired:
        rep = router.replicas[name]
        if (not rep.dead or name not in router._retired
                or not getattr(rep.engine, "unhealthy", False)):
            raise InvariantViolation(
                f"SDC-retired replica {name} is not permanently dead "
                f"(dead={rep.dead}, retired={name in router._retired}, "
                f"unhealthy={getattr(rep.engine, 'unhealthy', False)})")
