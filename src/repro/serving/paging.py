"""Refcounted page pool + radix prefix tree for paged KV serving.

Host-side control plane of the paged cold tier
(``core/kv_cache.PagedKVCache``): the pool tracks which physical pages
are free and how many readers each live page has; the tree maps token
prefixes to the pages that already hold their KV rows, so admission can
skip prefilling a shared prefix entirely (the SGLang radix-cache idiom,
adapted to the two-tier DR layout).

Layout of a cached prefix (page_size = ps, hot_cap = hc):

  * the tree root's children are keyed by the FULL first ``hc`` tokens
    of a prompt; such a *hot node* owns ``ceil(hc / ps)`` snapshot pages
    holding a copy of a slot's hot tier (the hot tier is per-slot
    pinned memory in the paper's DR-eDRAM sense, so sharing it means
    snapshotting it into the pool and copying it back at admission —
    ``kv_cache.save_hot`` / ``kv_cache.paged_admit``);
  * deeper nodes are keyed by ``ps``-token runs and own exactly one
    cold page each; a slot that matches adopts those pages *in place*
    (its page table points at them — zero copies, this is the sharing);
  * a partially matched boundary page is adopted copy-on-write: the
    engine allocates a fresh page, ``paged_admit`` copies the source
    page into it, and the slot appends its novel tokens after row ``r``.

Refcount protocol (``PagePool``): a page's count is the number of
readers — the tree counts as one, every slot whose page table maps the
page counts as one. ``insert`` increfs the pages it adopts from a slot;
the engine increfs shared pages when a slot adopts them at admission and
decrefs the slot's whole page list at retirement (or preemption — the
engine requeues the request and the pages free like any other reader
leaving). Counts never go negative (``PagePoolError``) and a page
returns to the free list exactly when its last reader drops it.
``serving/chaos.py`` re-derives the whole protocol as a machine-checked
invariant (free list ∪ referenced pages partitions the pool; every
count equals its known readers) after each engine loop iteration under
test. Eviction is leaf-only LRU over tree-only pages
(refcount 1): peeling childless nodes never frees a page a slot still
reads and eventually reaches every unshared node, so admission can
always reclaim the pool down to the live slots' working set.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


def pages_needed(length: int, hot_cap: int, page_size: int) -> int:
    """Cold pages a slot of ``length`` tokens occupies: the hot tier
    absorbs the first ``hot_cap`` rows, the rest rounds up to whole
    pages. The engine's growth funding, the speculative trailing-decref
    and the invariant checker's occupancy audit must all agree on this
    arithmetic — one definition, three call sites."""
    return -(-max(length - hot_cap, 0) // page_size)


class PagePoolError(RuntimeError):
    """Refcount-protocol violation (or an unservable allocation): carries
    the page id and its count so the report survives ``python -O`` and
    points at the page, not just the call site."""

    def __init__(self, msg: str, page: Optional[int] = None,
                 refcount: Optional[int] = None):
        if page is not None:
            msg = f"{msg} (page={page}, refcount={refcount})"
        super().__init__(msg)
        self.page = page
        self.refcount = refcount


class PagePool:
    """Free list + per-page reader counts for the physical page pool.

    Quarantine (SDC repair ladder): a page whose stored bytes were found
    corrupted (serving scrub crc mismatch) is permanently retired —
    ``quarantine`` pulls it off the free list (or marks it so the next
    ``decref`` to zero doesn't return it), and ``alloc`` never hands it
    out again. The pool census becomes free ∪ referenced ∪ quarantined,
    pairwise disjoint (``chaos.check_serving_invariants``)."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.refs = np.zeros(n_pages, np.int32)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self.quarantined: set = set()
        # per-page birth counter, bumped on every alloc: a (page, born)
        # pair names one LIFE of a physical page. The SDC scrub keys its
        # crc stamps on it, so a page freed and re-allocated between
        # scrubs can never false-positive against a stale stamp.
        self.born = np.zeros(n_pages, np.int64)
        self._alloc_seq = 0

    def available(self) -> int:
        return len(self._free)

    def used(self) -> int:
        return self.n_pages - len(self._free) - len(self.quarantined)

    def quarantine(self, page: int) -> None:
        """Retire ``page`` for good. Legal on a free page (removed from
        the free list immediately) or a referenced one (readers drain
        normally; the final decref parks it instead of freeing it)."""
        p = int(page)
        if p in self.quarantined:
            return
        self.quarantined.add(p)
        if self.refs[p] == 0:
            self._free.remove(p)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` free pages (each born with one reader); None if the
        free list is short — the caller evicts (PrefixCache) and retries."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self.refs[p] = 1
            self._alloc_seq += 1
            self.born[p] = self._alloc_seq
        return pages

    def incref(self, pages: Sequence[int]) -> None:
        for p in pages:
            if self.refs[p] <= 0:
                raise PagePoolError(
                    "incref on free page", page=int(p),
                    refcount=int(self.refs[p]))
            self.refs[p] += 1

    def decref(self, pages: Sequence[int]) -> None:
        """Drop one reader per page; a page frees exactly when its count
        hits zero — unless it is quarantined, in which case it parks
        (never reallocated). Counts never go negative (PagePoolError)."""
        for p in pages:
            if self.refs[p] <= 0:
                raise PagePoolError(
                    "decref on free page", page=int(p),
                    refcount=int(self.refs[p]))
            self.refs[p] -= 1
            if self.refs[p] == 0 and int(p) not in self.quarantined:
                self._free.append(int(p))


@dataclasses.dataclass
class PrefixMatch:
    """Result of matching a prompt against the tree (all page ids are
    pool indices; ``length`` counts matched *tokens*, capped at
    prompt_len - 1 so at least one novel token remains to produce the
    first-sample logits)."""

    length: int = 0  # matched tokens M (0 = miss)
    hot_pages: Tuple[int, ...] = ()  # snapshot pages for the hot restore
    shared_pages: Tuple[int, ...] = ()  # fully matched cold pages, in order
    cow_src: int = -1  # partially matched boundary page (-1 = none)
    cow_len: int = 0  # matched rows r within the boundary page


class _Node:
    __slots__ = ("key", "pages", "children", "parent", "last_use")

    def __init__(self, key, pages, parent):
        self.key = key  # token tuple (hot node: hc tokens; else ps)
        self.pages = list(pages)
        self.children: Dict[tuple, "_Node"] = {}
        self.parent = parent
        self.last_use = 0


class PrefixCache:
    """Radix tree over prompt prefixes at page granularity."""

    def __init__(self, pool: PagePool, hot_cap: int, page_size: int):
        self.pool = pool
        self.hot_cap = hot_cap
        self.page_size = page_size
        self.n_hot_pages = -(-hot_cap // page_size) if hot_cap else 0
        self._root = _Node((), (), None)
        self._clock = 0

    # -- bookkeeping ----------------------------------------------------
    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.last_use = self._clock

    def _nodes(self) -> List[_Node]:
        out, stack = [], [self._root]
        while stack:
            n = stack.pop()
            out.extend(n.children.values())
            stack.extend(n.children.values())
        return out

    def tree_pages(self) -> List[int]:
        """All pages currently held by the tree (refcount view helper)."""
        return [p for n in self._nodes() for p in n.pages]

    # -- matching -------------------------------------------------------
    def match(self, tokens: np.ndarray) -> PrefixMatch:
        """Longest cached prefix of ``tokens``. Never matches the whole
        prompt (cap at len - 1): the last token must be prefilled so its
        logits exist to sample the first generated token from."""
        toks = np.asarray(tokens).reshape(-1)
        lim = len(toks) - 1
        hc, ps = self.hot_cap, self.page_size
        if lim < 1 or len(toks) < hc or hc == 0:
            return PrefixMatch()
        node = self._root.children.get(tuple(int(t) for t in toks[:hc]))
        if node is None:
            return PrefixMatch()
        self._touch(node)
        m = PrefixMatch(length=min(hc, lim), hot_pages=tuple(node.pages))
        shared: List[int] = []
        k = 0
        while m.length < lim:
            page_toks = tuple(
                int(t) for t in toks[hc + k * ps : hc + (k + 1) * ps])
            child = (node.children.get(page_toks)
                     if len(page_toks) == ps else None)
            if child is not None and hc + (k + 1) * ps <= lim:
                shared.append(child.pages[0])
                node = child
                self._touch(node)
                m = dataclasses.replace(
                    m, length=hc + (k + 1) * ps,
                    shared_pages=tuple(shared))
                k += 1
                continue
            # boundary: the longest common prefix of any child's page
            best_r, best = 0, None
            for key, c in node.children.items():
                r = 0
                for a, b in zip(key, page_toks):
                    if a != b:
                        break
                    r += 1
                if r > best_r:
                    best_r, best = r, c
            r = min(best_r, lim - m.length)
            if r > 0 and best is not None:
                self._touch(best)
                m = dataclasses.replace(
                    m, length=m.length + r, cow_src=best.pages[0],
                    cow_len=r)
            break
        return m

    # -- insertion ------------------------------------------------------
    def insert(
        self,
        tokens: np.ndarray,
        slot_pages: Sequence[int],
        save_hot: Callable[[Sequence[int]], None],
    ) -> bool:
        """Record a fully prefilled prompt. ``slot_pages[k]`` is the pool
        page holding the slot's cold positions [hc + k*ps, hc + (k+1)*ps);
        only pages the prompt covers COMPLETELY are inserted (the tail
        partial page stays slot-private). Adopted slot pages are increfed
        (the tree becomes a second reader — the "one physical copy");
        runs already present are deduped, keeping the tree's copy. A
        missing hot node is created by snapshotting the slot's hot tier
        into freshly allocated pages via the ``save_hot`` callback (the
        engine's jitted ``kv_cache.save_hot`` dispatch). Best-effort:
        returns False without modifying anything when the pool cannot
        fund the snapshot."""
        toks = np.asarray(tokens).reshape(-1)
        hc, ps = self.hot_cap, self.page_size
        if hc == 0 or len(toks) < hc:
            return False
        hot_key = tuple(int(t) for t in toks[:hc])
        node = self._root.children.get(hot_key)
        if node is None:
            ids = self._alloc(self.n_hot_pages)
            if ids is None:
                return False
            save_hot(ids)
            node = _Node(hot_key, ids, self._root)
            self._root.children[hot_key] = node
        self._touch(node)
        k_full = (len(toks) - hc) // ps
        for k in range(k_full):
            key = tuple(int(t) for t in toks[hc + k * ps : hc + (k + 1) * ps])
            child = node.children.get(key)
            if child is None:
                child = _Node(key, (slot_pages[k],), node)
                self.pool.incref(child.pages)
                node.children[key] = child
            node = child
            self._touch(node)
        return True

    # -- allocation / eviction -----------------------------------------
    def _alloc(self, n: int) -> Optional[List[int]]:
        if not self.evict_for(n):
            return None
        return self.pool.alloc(n)

    def evict_pages(self, pages: Sequence[int]) -> int:
        """Force-evict every node referencing any of ``pages`` AND its
        whole subtree (descendants extend a prefix that ran through the
        damaged page — their cached rows are downstream of the fault and
        must not be served). The tree's reader refs drop; the caller
        quarantines the damaged pages themselves. Returns the number of
        nodes removed. Part of the SDC repair ladder (docs/serving.md)."""
        bad = {int(p) for p in pages}
        victims = []
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            if any(int(p) in bad for p in node.pages):
                victims.append(node)  # highest damaged node wins the cut
            else:
                stack.extend(node.children.values())
        removed = 0
        for v in victims:
            sub = [v]
            while sub:
                node = sub.pop()
                self.pool.decref(node.pages)
                removed += 1
                sub.extend(node.children.values())
            v.parent.children.pop(v.key, None)
        return removed

    def flush(self) -> int:
        """Drop the ENTIRE tree, decrefing every tree-held page: the
        weight-fault response — every cached row was computed by a
        possibly-corrupted matmul, so nothing in the tree can be trusted
        after a weight reload. Returns the number of nodes removed."""
        removed = 0
        for node in self._nodes():
            self.pool.decref(node.pages)
            removed += 1
        self._root.children.clear()
        return removed

    def evict_for(self, n: int) -> bool:
        """Peel LRU childless nodes whose pages have no reader but the
        tree until ``n`` pages are free. Pages a live slot still maps
        (refcount >= 2) are never touched."""
        while self.pool.available() < n:
            victim = None
            for cand in self._nodes():
                if cand.children:
                    continue
                if any(self.pool.refs[p] != 1 for p in cand.pages):
                    continue
                if victim is None or cand.last_use < victim.last_use:
                    victim = cand
            if victim is None:
                return False
            self.pool.decref(victim.pages)
            victim.parent.children.pop(victim.key)
        return True
