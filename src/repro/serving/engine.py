"""Continuous-batching serving engine: packed-ternary weights + per-slot
DR-tiered KV caches, with a fully-jitted decode hot loop.

The paper's deployment (§V-B): weights fused on-die (here: packed ternary,
device-resident across the whole session — ZERO weight reload), a DR
eDRAM hot tier for the first ``hot_cap`` tokens of each sequence, external
memory for the rest. Because the weights never move, the serving problem
reduces to keeping the decode path saturated — which is what the slot
model below does.

Architecture
------------
Device state (``DecodeState``) is a fixed-shape pytree over ``n_slots``
batch rows: the stacked tiered KV cache (per-slot ``lengths``), the last
sampled token, a ``done`` mask, per-slot output buffer and the vectorized
DR-traffic ledger. One decode step is ONE jitted dispatch:

  * embedding -> L-layer scan -> logits for every slot,
  * KV appends and recurrent-state updates gated by the on-device
    ``active = allocated & ~done`` mask,
  * sampling (greedy or temperature) on-device,
  * stop-token detection folds into ``done`` ON DEVICE — no
    ``bool(jnp.all(...))`` host pull, so the Python loop never blocks.

The host only syncs at *chunk boundaries* (every ``sync_every`` steps): it
reads the small ``done``/``allocated`` masks, retires finished slots,
harvests their outputs and per-slot ledgers, and admits queued prompts
into the freed slots (``serving/scheduler.py`` decides who goes where) —
either as whole same-length groups (prefill dispatch + cache scatter) or,
with ``prefill_chunk`` set, as fixed-size chunk dispatches streamed
straight into the live cache at per-slot offsets (flash-prefill
continuation: ONE prefill compilation for any prompt-length mix). Slots at different
sequence lengths decode side by side; per-slot lengths keep each
sequence's attention exact — on TPU via the flash-decode Pallas kernel
(``kernels/flash_decode.py``: hot and cold tier merged in one streaming
launch, S-blocks predicated per slot so a sequence streams only its own
prefix — the compute-side counterpart of the DR-traffic ledger below),
elsewhere via the masked validity paths in ``core/kv_cache.py``.

Traffic accounting
------------------
The ledger is vectorized per slot in *token* units
(``kv_cache.step_traffic_tokens``) and accumulated inside the jitted step;
the analytic prompt-phase ledger (``prompt_traffic_tokens``) is added at
admission. Per sequence, the total reconciles exactly with
``dr_edram.closed_form_reduction(seq_len, hot_cap)`` — including in
mixed-length batches, which is asserted in tests.

Paged serving
-------------
With ``paged=True`` the cold tier is page-table indirected
(``core/kv_cache.PagedKVCache``): cold KV rows live in a shared pool and
each slot's page-table row maps its logical cold pages onto pool pages.
A host-side refcounted radix tree (``serving/paging.py``) matches each
new prompt against previously served prefixes; matched cold pages are
adopted by reference (one physical copy across N slots), the boundary
page is adopted copy-on-write, the hot tier is restored from a pooled
snapshot, and chunked prefill streams only the novel suffix. The whole
per-slot (re)initialisation is ONE fused jitted dispatch
(``kv_cache.paged_admit`` vmapped over the layer stacks). Skipped
prefill work is reported per request as
``FinishedRequest.prefix_tokens_reused`` and the prompt-phase ledger
switches to ``prompt_traffic_tokens_resumed`` so the DR accounting
reconciles with the external reads that actually happened.

Graceful degradation (docs/serving.md, "Degradation modes")
------------------------------------------------------------
The page pool is the paper's fixed on-die KV budget: overload must
degrade against it, never crash against it. Pages are allocated
*lazily* — admission funds only the prompt, decode growth is funded
chunk-by-chunk — and when the pool cannot fund a claim the engine
reclaims in order: LRU tree eviction first, then **preemption** of
strictly weaker slots (``SlotScheduler.preempt_victims``: never a
stronger claim, fewest-emitted/newest first among the eligible). A
preempted request's emitted tokens fold into its prompt and it requeues;
re-admission rides the prefix-cache match + chunked prefill, so only
work past the shared prefix is recomputed and greedy outputs stay
bit-identical to an unconstrained run (asserted in tests). Requests
carry ``deadline``/``priority``, ``Engine.cancel(rid)`` propagates to
slot retirement and page decref mid-flight, and a bounded queue sheds
overflow explicitly; every terminal path surfaces as
``FinishedRequest.outcome``. ``serving/chaos.py`` fault-injects this
plane (pool exhaustion, stragglers, mid-prefill cancellation) and
re-checks the refcount/page-table invariants after every loop iteration
under test, via serve()'s ``on_iteration`` hook.

Speculative decoding (docs/serving.md, "Speculative decoding")
--------------------------------------------------------------
``Engine(draft_cfg=..., draft_params=..., spec_k=K)`` replaces the
one-token decode dispatch with a draft-verify round: K greedy draft
steps against a per-slot draft KV cache propose a K-token chunk, ONE
``transformer.spec_verify_chunk`` dispatch scores it against the target
cache without appending, and the vectorized acceptance rule
(``serving/speculative.longest_accepted_prefix``) keeps the longest
prefix the target itself would have emitted. Linear cache layouts
commit the full chunk and roll the rejected suffix back with
``kv_cache.truncate`` (the paged form then decrefs the stranded trailing
pages at the iteration boundary); ring (SWA) layouts commit only the
accepted rows — a wrapped ring append is destructive, so there is
nothing safe to roll back. Greedy outputs are bit-identical to the
non-speculative loop for every accept/reject mix (every emitted token is
a target argmax; the draft only sets the pace), which
tests/test_speculative.py asserts end-to-end.

docs/serving.md walks the full request lifecycle (slots, admission
groups, ``sync_every`` semantics, the paging lifecycle, the
reconciliation contract); docs/kernels.md covers the packed fast path
the decode loop runs on.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Sequence, Set)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import dr_edram, kv_cache
from repro.core.kv_cache import HandoffError
from repro.distributed.fault import PreemptionGuard, StragglerMonitor
from repro.models import pack as pack_lib
from repro.models import transformer as T
from repro.serving import sdc as sdc_lib
from repro.serving import speculative as spec_lib
from repro.serving.paging import (PagePool, PagePoolError, PrefixCache,
                                  PrefixMatch, pages_needed)
from repro.serving.scheduler import (FinishedRequest, Request, SlotScheduler,
                                     terminal_record)

TRAFFIC_KEYS = kv_cache.TRAFFIC_KEYS

# consecutive no-progress serve-loop iterations tolerated before the
# engine declares the pool unreclaimable. Transient holds (chaos
# injection pinning pages for a few iterations) ride through; a pool
# that genuinely cannot fund the strongest queued claim — unreachable
# under the default sizing + the serve() feasibility check — still
# surfaces as a typed PagePoolError instead of a silent spin.
_STALL_LIMIT = 32

# `generate` pads rows that stopped early with this sentinel. The stop
# token itself is a real emitted token (it appears in `tokens` when
# sampled), so padding with it would make genuine stops
# indistinguishable from padding; -1 is outside every vocabulary.
PAD_TOKEN = -1


class DecodeState(NamedTuple):
    """Fixed-shape device state for the jitted decode loop (one row = slot)."""

    cache: Any  # stacked tiered KV / SSM state pytree, per-slot lengths
    tok: jax.Array  # (slots,) int32 — last sampled token per slot
    key: jax.Array  # PRNG key threaded through on-device sampling
    allocated: jax.Array  # (slots,) bool — slot holds a live request
    done: jax.Array  # (slots,) bool — request finished (stop / budget)
    seq_len: jax.Array  # (slots,) int32 — cache length incl. prompt
    n_gen: jax.Array  # (slots,) int32 — tokens emitted so far
    max_new: jax.Array  # (slots,) int32 — per-slot generation budget
    out: jax.Array  # (slots, out_cap) int32 — emitted tokens
    ledger: Dict[str, jax.Array]  # 4 × (slots,) int32 decode token counts
    # speculative decoding (None / zeros on non-speculative engines):
    draft_cache: Any = None  # draft model's per-slot tiered KV cache
    drafted: Any = None  # (slots,) int32 — draft proposals scored so far
    accepted: Any = None  # (slots,) int32 — proposals the target accepted
    # SDC sentinel: latches (slots,) True when a step's logits go
    # non-finite for an active slot — folded ON DEVICE every dispatch,
    # read only at scrub sync points (serving/sdc.py)
    numerics_bad: Any = None


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array  # (b, max_new) int32, PAD_TOKEN past each row's end
    steps: int  # max over rows (the batch's wall-clock step count)
    traffic: dict  # accumulated on-die vs external bytes
    wall_s: float
    # tokens actually emitted per row — rows that hit the stop token
    # early are shorter than `steps`; `tokens[i, steps_per_row[i]:]` is
    # all PAD_TOKEN.
    steps_per_row: Optional[List[int]] = None

    @property
    def external_reduction(self) -> float:
        return kv_cache.external_reduction(self.traffic)


@dataclasses.dataclass
class ServeStats:
    """Control-plane counters for one ``serve()`` call (``Engine.
    last_stats``): how much degradation the workload forced. ``
    recompute_tokens`` counts prompt tokens a re-admission actually
    prefilled again (attempt prompt minus the prefix-cache match) — the
    price of preemption, to weigh against the prefix-sharing savings in
    ``FinishedRequest.prefix_tokens_reused``."""

    preemptions: int = 0
    rejected: int = 0
    cancelled: int = 0
    expired: int = 0
    recompute_tokens: int = 0
    grown_pages: int = 0
    iterations: int = 0
    # per-iteration wall time (seconds), fed live into the session's
    # StragglerMonitor (distributed/fault.py): p50/max over the whole
    # call plus how many iterations the monitor flagged as stragglers
    # (> factor x window median). The router's health checks consume the
    # same monitor through Replica.straggler_flags().
    iter_p50: float = 0.0
    iter_max: float = 0.0
    straggler_flags: int = 0
    # speculative decoding ledger (0 on non-speculative engines): draft
    # proposals scored by the target vs proposals accepted. Per request
    # the identity `emitted == accepted + rounds` holds (each verify
    # round always emits its pending token on top of the accepted run).
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    # SDC ladder counters (0 unless Engine(integrity=...) is set): faults
    # the scrub detected, full KV pages crc-verified, packed leaves
    # reloaded from their golden copy, and slots contained for
    # non-finite logits (outcome "numerics")
    sdc_detected: int = 0
    pages_scrubbed: int = 0
    weight_reloads: int = 0
    slots_quarantined: int = 0

    def record_spec(self, fin: FinishedRequest) -> None:
        self.drafted_tokens += fin.drafted_tokens
        self.accepted_tokens += fin.accepted_tokens


@dataclasses.dataclass
class _ServeCtx:
    """Mutable state of one ``serve()`` call, threaded through the
    admission / growth / preemption / harvest helpers and handed to the
    ``on_iteration`` hook after every loop iteration (the chaos harness
    and invariant checker in ``serving/chaos.py`` read ``pool`` /
    ``ptree`` / ``host_table`` / ``slot_pages`` / ``sched`` through it;
    mutating anything but the pool's free pages or issuing
    ``Engine.cancel`` from the hook is undefined)."""

    state: DecodeState
    sched: SlotScheduler
    finished: List[FinishedRequest]
    stats: ServeStats
    token_bytes: int
    chunked: bool
    remaining: List[int]  # per-slot budget mirror (host-side, no sync)
    seq_mirror: List[int]  # per-slot upper bound on cache length
    prefix_used: List[int]  # matched-prefix tokens per live slot
    prefilling: Dict[int, list]  # slot -> [req, offset], mid-prefill
    slot_pages: List[List[int]]
    pool: Optional[PagePool] = None
    ptree: Optional[PrefixCache] = None
    host_table: Optional[np.ndarray] = None
    iteration: int = 0
    # speculative decoding: slot -> [req, offset] for the draft model's
    # own chunked prefill (runs alongside the target's; a slot decodes
    # only once BOTH caches hold the full prompt), plus the geometry the
    # invariant checker needs to audit post-rollback page occupancy
    draft_prefilling: Dict[int, list] = dataclasses.field(default_factory=dict)
    spec: bool = False
    hot_cap: int = 0
    page_size: int = 0
    # session plumbing (start_session/run_iteration): the jitted step for
    # this session's (out_cap, stop_token), the sync chunk width, the
    # per-iteration hook, the wall-time straggler monitor, the stall-
    # guard counter, and — after drain_session — the folded requests
    # that were evacuated instead of finished
    step_fn: Any = None
    chunk: int = 8
    on_iteration: Optional[Callable[["_ServeCtx"], None]] = None
    monitor: Optional[StragglerMonitor] = None
    stall: int = 0
    drained: Optional[List[Request]] = None
    # SDC scrub state (Engine(integrity=...)): crc stamps over FULL cold
    # pages keyed page -> (born, crc32) — `born` names the page's
    # current life (PagePool.born), so stale stamps can never follow a
    # reallocated id; per-slot count of tokens verified at the last
    # clean scrub (the rollback target for detected corruption); and
    # the iteration of the last scrub (cadence bookkeeping)
    page_crc: Dict[int, tuple] = dataclasses.field(default_factory=dict)
    verified_len: Optional[List[int]] = None
    last_scrub: int = -1


class Engine:
    """Weight-reload-free continuous-batching inference engine.

    ``serve(requests)`` is the native API: a list of :class:`Request` with
    arbitrary prompt lengths and budgets, served through ``slots``
    concurrent slots with mid-decode admission. ``generate(prompts, ...)``
    is the aligned-batch convenience wrapper (one slot per row) kept for
    the launchers, examples and benchmarks.

    The engine is immutable after construction: sampling mode,
    temperature, hot_cap and max_len are baked into the cached jitted
    step/prefill/admit functions at first trace, so mutating those
    attributes later is silently ignored — build a new Engine instead
    (the packed params can be shared across engines).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        hot_cap: int = 32,
        max_len: int = 256,
        pack: bool = True,
        sample: str = "greedy",
        temperature: float = 1.0,
        seed: int = 0,
        slots: int = 8,
        sync_every: int = 8,
        prefill_chunk: int = 0,
        paged: bool = False,
        page_size: Optional[int] = None,
        n_pages: Optional[int] = None,
        prefix_sharing: bool = True,
        max_queue: Optional[int] = None,
        clock: Optional[Callable[[], float]] = None,
        draft_cfg: Optional[ModelConfig] = None,
        draft_params=None,
        spec_k: int = 0,
        spec_force: Optional[str] = None,
        guard: Optional[PreemptionGuard] = None,
        integrity: Optional[sdc_lib.IntegrityConfig] = None,
    ):
        self.cfg = cfg
        # Freeze to ROM form once (packed trits + fused wqkv/wgu/w_dqkv/w_gu
        # projection groups, models/pack.py); never reloaded afterwards. The
        # decode hot loop then runs the packed fast path (core/bitlinear.
        # packed_matmul: act-quant-prologue + epilogue-fused Pallas kernel on
        # TPU via BitNetConfig.impl="auto" — raw bf16 in, scaled float out,
        # no int8/int32 HBM intermediates; E-loop expert kernel for MoE) and
        # the flash-decode attention kernel (kernels/flash_decode.py) over
        # the tiered KV cache, dispatched by the same impl="auto" rule.
        self.params = pack_lib.pack_params(params, cfg) if pack else params
        self.mode = "packed" if pack else "qat"
        self.hot_cap = hot_cap
        self.max_len = max_len
        self.sample = sample
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.slots = slots
        self.sync_every = sync_every
        # chunked-prefill admission (docs/serving.md): 0 keeps the legacy
        # same-length-group whole-prompt admission; C > 0 streams prompts
        # into freed slots as fixed-size C-token chunk dispatches against
        # the live cache — ONE prefill compilation total for any prompt-
        # length mix. Supported for attention-cache families without a
        # frontend; other archs fall back to grouped admission.
        self.prefill_chunk = prefill_chunk
        # paged cold tier + refcounted prefix sharing (module docstring /
        # serving/paging.py). One page = one flash S-block, so the decode
        # kernel's cold gather indexes whole pages — page_size defaults to
        # the block the kernel would pick anyway.
        self.paged = paged
        self.prefix_sharing = bool(prefix_sharing) and paged
        if paged:
            if not (prefill_chunk > 0 and self._chunked_capable()
                    and cfg.attn_type == "full"):
                raise ValueError(
                    "paged serving needs chunked prefill (prefill_chunk > 0)"
                    " on a full-attention cache family — grouped whole-"
                    "prompt admission bypasses the page table"
                )
            if max_len <= hot_cap:
                raise ValueError(
                    f"paged serving needs a non-empty cold tier (max_len "
                    f"{max_len} <= hot_cap {hot_cap})"
                )
            from repro.kernels import ops as kops

            rep = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
            self._page_size = int(
                page_size
                or kops.default_page_size(rep, cfg.resolved_head_dim, max_len)
            )
            self._pps = -(-(max_len - hot_cap) // self._page_size)
            self._n_hot_pages = (
                -(-hot_cap // self._page_size) if hot_cap else 0
            )
            self._n_pages_cfg = n_pages
        # speculative decoding (module docstring, "Speculative decoding"):
        # a draft model + chunk width K turn the decode dispatch into a
        # draft-verify round. Greedy-only — temperature speculation needs
        # rejection sampling (serving/speculative.rejection_sample, a
        # stub) — and it rides the chunked-prefill machinery, so archs
        # that cannot chunk fall back to plain decode with a warning
        # rather than fail (the conformance suite asserts the warning).
        self.draft_cfg = draft_cfg
        self.spec_k = int(spec_k)
        if spec_force not in (None, "reject"):
            raise ValueError(f"spec_force must be None or 'reject': {spec_force!r}")
        self.spec_force = spec_force
        spec = draft_params is not None and self.spec_k > 0
        if spec:
            if draft_cfg is None:
                raise ValueError("draft_params requires draft_cfg")
            if sample != "greedy":
                spec_lib.rejection_sample()
            if draft_cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {draft_cfg.vocab_size} != target vocab "
                    f"{cfg.vocab_size}: draft proposals are token ids "
                    "scored by the target — the vocabularies must match"
                )
            if not (prefill_chunk > 0 and self._chunked_capable()):
                warnings.warn(
                    f"speculative decoding needs chunked prefill on an "
                    f"attention-cache family without a frontend; "
                    f"{cfg.name} (family={cfg.family}, attn={cfg.attn_type}"
                    f", frontend={cfg.frontend}, prefill_chunk="
                    f"{prefill_chunk}) falls back to non-speculative "
                    "decode", RuntimeWarning, stacklevel=2,
                )
                spec = False
        self.spec = spec
        self.draft_params = (
            pack_lib.pack_params(draft_params, draft_cfg) if (spec and pack)
            else (draft_params if spec else None)
        )
        # backpressure bound on the admission queue (None = unbounded);
        # overflow at submit time is shed as outcome "rejected", never
        # silently queued. serve(max_queue=...) overrides per call.
        self.max_queue = max_queue
        # injectable clock for Request.deadline (tests/chaos use a fake
        # clock so expiry is deterministic); deadlines are absolute times
        # on THIS clock
        self._clock = clock or time.monotonic
        # cooperative preemption (distributed/fault.py): when the guard's
        # flag is raised mid-serve (SIGTERM or an external drain request),
        # the loop finishes the current chunk, folds every active slot's
        # emitted tokens into its request (the PR 7 preemption trick) and
        # returns — the evacuated requests land in `last_drained`, ready
        # to resubmit here or on another replica with bit-exact greedy
        # continuation.
        self.guard = guard
        # SDC integrity plane (serving/sdc.py; docs/serving.md "Fault
        # model & SDC ladder"): stamp every packed leaf with ABFT wsum +
        # crc32, verify the stamps at load (a corrupt ROM image refuses
        # to come up), and keep a HOST-side golden copy of the packed
        # words — the repair ladder's reload source. The serve loop then
        # scrubs on the cadence in `integrity` (engine._scrub).
        self.integrity = integrity
        self._golden: Optional[Dict[str, np.ndarray]] = None
        self.weight_fault_strikes = 0  # distinct scrubs that found faults
        self.unhealthy = False  # strikes >= max_weight_strikes
        if integrity is not None:
            self.params = pack_lib.add_integrity(self.params)
            bad = pack_lib.verify_packed(self.params)
            if bad:
                raise sdc_lib.WeightFaultError(
                    f"packed weights failed crc32 at load: {bad}")
            self._golden = {
                path: np.asarray(pw.packed).copy()
                for path, pw in pack_lib.iter_packed_leaves(self.params)
            }
        self.last_drained: Optional[List[Request]] = None
        self._cancel_requested: Set[int] = set()
        self.last_stats: Optional[ServeStats] = None  # of the last serve()
        self.weight_loads = 0  # host->device weight transfers after init
        self._step_fns: dict = {}  # (out_cap, stop_token) -> jitted step
        self._batch_axes = None  # lazy: cache-leaf batch-axis pytree
        self._admit_fn = None  # jitted admission (compiles per group size)
        self._chunk_step_fn = None  # jitted chunked-prefill dispatch
        self._paged_admit_fn = None  # jitted fused paged (re)admission
        self._save_hot_fn = None  # jitted hot-tier snapshot dispatch
        self._set_table_fn = None  # jitted page-table install (growth)
        self._spec_step_fns: dict = {}  # (out_cap, stop) -> jitted round
        self._draft_chunk_fn = None  # jitted draft-cache prefill chunk
        # jitted prefill (one compile per admitted (group, prompt) shape)
        self._prefill = jax.jit(
            lambda p, batch: T.prefill(
                p, self.cfg, batch,
                hot_cap=self.hot_cap, max_len=self.max_len, mode=self.mode,
            )
        )

    def _chunked_capable(self) -> bool:
        """Chunked prefill needs a pure attention-token path: per-slot
        tiered KV caches (no recurrent SSM state to stream) and no
        frontend features spliced ahead of the text tokens."""
        return (
            self.cfg.family in ("dense", "moe")
            and self.cfg.attn_type in ("full", "swa")
            and self.cfg.frontend == "none"
        )

    # ------------------------------------------------------------------
    # sizing helpers
    # ------------------------------------------------------------------

    def _kv_token_bytes(self) -> int:
        cfg = self.cfg
        if cfg.attn_type == "mla":
            per_layer = cfg.mla.kv_cache_dim * 2
        elif cfg.attn_type == "none":
            per_layer = 0
        else:
            per_layer = 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2
        from repro.analysis.roofline import _n_attn_layers

        return per_layer * _n_attn_layers(cfg)

    # ------------------------------------------------------------------
    # device state init / admission scatter
    # ------------------------------------------------------------------

    def _cache_dtype(self):
        # same rule prefill uses, so admission scatters are cast-free
        return self.params["final_ln"].dtype

    def _pool_pages(self, n_slots: int) -> int:
        """Pool size for a serve() call: a full private page set per slot,
        plus headroom for the transient unevictable pages one admission
        round can pin (per fill: the matched hot snapshot + the COW
        source, protected until the fused admit dispatch lands) and one
        spare page set so insertion can snapshot a hot node."""
        if self._n_pages_cfg is not None:
            return self._n_pages_cfg
        return (
            n_slots * self._pps
            + self._pps
            + n_slots * (self._n_hot_pages + 1)
            + self._n_hot_pages
        )

    def _init_state(self, n_slots: int, out_cap: int) -> DecodeState:
        paged_kw = (
            dict(paged=True, page_size=self._page_size,
                 n_pages=self._pool_pages(n_slots))
            if self.paged else {}
        )
        cache = T.init_decode_cache(
            self.cfg, n_slots, self.max_len, self.hot_cap,
            dtype=self._cache_dtype(), **paged_kw
        )
        # the draft's cache is always a plain contiguous tiered cache —
        # it is private scratch (never prefix-shared, never paged) whose
        # lengths track the target's accepted lengths via truncate
        draft_cache = (
            T.init_decode_cache(
                self.draft_cfg, n_slots, self.max_len, self.hot_cap,
                dtype=self.draft_params["final_ln"].dtype,
            )
            if self.spec else None
        )
        self.key, sub = jax.random.split(self.key)

        def z():
            # distinct buffers: the jitted step/admit donate the state, and
            # XLA rejects donating one buffer through several arguments
            return jnp.zeros((n_slots,), jnp.int32)

        return DecodeState(
            cache=cache,
            tok=z(),
            key=sub,
            allocated=jnp.zeros((n_slots,), bool),
            done=jnp.zeros((n_slots,), bool),
            seq_len=z(),
            n_gen=z(),
            max_new=z(),
            out=jnp.zeros((n_slots, out_cap), jnp.int32),
            ledger={k: z() for k in TRAFFIC_KEYS},
            draft_cache=draft_cache,
            drafted=z(),
            accepted=z(),
            numerics_bad=jnp.zeros((n_slots,), bool),
        )

    def _cache_batch_axes(self):
        """Pytree (matching the cache) of each leaf's batch axis, found by
        diffing the abstract shapes of two init sizes — robust across the
        dense/moe/ssm/hybrid cache layouts without per-family code."""
        if self._batch_axes is not None:
            return self._batch_axes
        sa = jax.eval_shape(
            lambda: T.init_decode_cache(self.cfg, 2, self.max_len, self.hot_cap)
        )
        sb = jax.eval_shape(
            lambda: T.init_decode_cache(self.cfg, 3, self.max_len, self.hot_cap)
        )

        def axis(a, b):
            diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
            assert len(diffs) == 1, (a.shape, b.shape)
            return diffs[0]

        self._batch_axes = jax.tree.map(axis, sa, sb)
        return self._batch_axes

    def _scatter_cache(self, live, fresh, slots_idx: jax.Array):
        """Write each fresh cache row (batch n) into the live cache at
        ``slots_idx`` along every leaf's batch axis."""
        axes = self._cache_batch_axes()

        def scatter(lv, fr, ax):
            lv_m = jnp.moveaxis(lv, ax, 0)
            fr_m = jnp.moveaxis(fr, ax, 0)
            return jnp.moveaxis(lv_m.at[slots_idx].set(fr_m.astype(lv_m.dtype)), 0, ax)

        return jax.tree.map(scatter, live, fresh, axes)

    def _sample_fn(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        if self.sample == "greedy":
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature, axis=-1
        ).astype(jnp.int32)

    # ------------------------------------------------------------------
    # the fully-jitted decode step
    # ------------------------------------------------------------------

    def _get_step(self, out_cap: int, stop_token: Optional[int]):
        """One decode dispatch: emit -> decode/append -> account -> sample
        -> fold stop into ``done``. Entirely on device; no host syncs."""
        key = (out_cap, stop_token)
        if key in self._step_fns:
            return self._step_fns[key]
        cfg, mode, hot_cap = self.cfg, self.mode, self.hot_cap

        def step(params, state: DecodeState) -> DecodeState:
            active = state.allocated & ~state.done
            act32 = active.astype(jnp.int32)
            # emit the pending token (sampled last step / at admission)
            emit = (
                jnp.arange(out_cap, dtype=jnp.int32)[None] == state.n_gen[:, None]
            ) & active[:, None]
            out = jnp.where(emit, state.tok[:, None], state.out)
            n_gen = state.n_gen + act32
            # decode: append the pending token's KV, get next logits
            logits, cache = T.decode_step(
                params, cfg, state.tok, state.cache, mode=mode, active=active
            )
            # vectorized per-slot DR ledger at the pre-append length
            tr = kv_cache.step_traffic_tokens(state.seq_len, hot_cap)
            ledger = {
                k: state.ledger[k] + tr[k] * act32 for k in TRAFFIC_KEYS
            }
            seq_len = state.seq_len + act32
            # on-device sampling
            key_next, sub = jax.random.split(state.key)
            tok = jnp.where(active, self._sample_fn(logits, sub), state.tok)
            # on-device stop handling: retire via mask, never break the loop
            done = state.done | (active & (n_gen >= state.max_new))
            if stop_token is not None:
                done = done | (active & (tok == stop_token))
            # SDC sentinel: latch non-finite logits per active slot, on
            # device — the scrub reads it at the next sync point
            numerics_bad = state.numerics_bad | (
                active & ~jnp.isfinite(logits).all(axis=-1))
            return DecodeState(
                cache=cache, tok=tok, key=key_next, allocated=state.allocated,
                done=done, seq_len=seq_len, n_gen=n_gen,
                max_new=state.max_new, out=out, ledger=ledger,
                draft_cache=state.draft_cache, drafted=state.drafted,
                accepted=state.accepted, numerics_bad=numerics_bad,
            )

        fn = jax.jit(step, donate_argnums=(1,))
        self._step_fns[key] = fn
        return fn

    # ------------------------------------------------------------------
    # admission: prefill queued prompts into freed slots
    # ------------------------------------------------------------------

    def _get_admit(self):
        """Jitted admission: scatter fresh cache rows + sample first tokens
        + reset per-slot bookkeeping, all in ONE dispatch. Compiles once
        per admitted group size (shapes of idx/logits), not per prompt
        length — the fresh cache shape only depends on the group size."""
        if self._admit_fn is not None:
            return self._admit_fn

        def admit(state, fresh, logits, idx, p_len, max_new, key):
            first = self._sample_fn(logits, key)
            cache = self._scatter_cache(state.cache, fresh, idx)
            n = idx.shape[0]
            z = jnp.zeros((n,), jnp.int32)
            return DecodeState(
                cache=cache,
                tok=state.tok.at[idx].set(first),
                key=state.key,
                allocated=state.allocated.at[idx].set(True),
                done=state.done.at[idx].set(max_new <= 0),
                seq_len=state.seq_len.at[idx].set(p_len),
                n_gen=state.n_gen.at[idx].set(0),
                max_new=state.max_new.at[idx].set(max_new),
                out=state.out.at[idx].set(0),
                ledger={k: state.ledger[k].at[idx].set(z) for k in TRAFFIC_KEYS},
                draft_cache=state.draft_cache,
                drafted=state.drafted.at[idx].set(0),
                accepted=state.accepted.at[idx].set(0),
                numerics_bad=state.numerics_bad.at[idx].set(False),
            )

        self._admit_fn = jax.jit(admit, donate_argnums=(0,))
        return self._admit_fn

    # ------------------------------------------------------------------
    # chunked prefill: stream fixed-size prompt chunks into the live state
    # ------------------------------------------------------------------

    def _get_chunk_step(self):
        """Jitted chunked-prefill dispatch. Every shape is fixed by
        (slots, prefill_chunk) — per-slot offsets (``cache.lengths``),
        valid counts and first/last flags are data — so this compiles
        exactly ONCE per engine regardless of the prompt-length mix
        (asserted in tests/test_scheduler.py via ``_cache_size``).

        One dispatch per chunk wave: run ``transformer.prefill_chunk_step``
        over all slots (idle slots ride along with ``n_valid = 0`` and
        touch nothing), reset per-slot bookkeeping where ``is_first``,
        and sample the first token where ``is_last`` — the slot then
        enters the decode loop exactly as a group-admitted one would.
        """
        if self._chunk_step_fn is not None:
            return self._chunk_step_fn
        cfg, mode = self.cfg, self.mode

        def chunk_step(params, state: DecodeState, tokens, n_valid,
                       is_first, is_last, max_new, key) -> DecodeState:
            # a slot's first chunk starts from a clean cache row
            cache = {
                k: c._replace(
                    lengths=jnp.where(is_first[None, :], 0, c.lengths)
                )
                for k, c in state.cache.items()
            }
            logits, cache = T.prefill_chunk_step(
                params, cfg, tokens, cache, n_valid, mode=mode
            )
            first_tok = self._sample_fn(logits, key)
            z32 = jnp.zeros_like(state.n_gen)
            done = jnp.where(is_first, False, state.done)
            ledger = {
                k: jnp.where(is_first, z32, state.ledger[k])
                for k in TRAFFIC_KEYS
            }
            return DecodeState(
                cache=cache,
                tok=jnp.where(is_last, first_tok, state.tok),
                key=state.key,
                allocated=state.allocated | is_last,
                done=jnp.where(is_last, max_new <= 0, done),
                seq_len=jnp.where(is_first, 0, state.seq_len) + n_valid,
                n_gen=jnp.where(is_first, 0, state.n_gen),
                max_new=jnp.where(is_last, max_new, state.max_new),
                out=jnp.where(is_first[:, None], 0, state.out),
                ledger=ledger,
                draft_cache=state.draft_cache,
                drafted=jnp.where(is_first, 0, state.drafted),
                accepted=jnp.where(is_first, 0, state.accepted),
                numerics_bad=jnp.where(is_first, False, state.numerics_bad),
            )

        self._chunk_step_fn = jax.jit(chunk_step, donate_argnums=(1,))
        return self._chunk_step_fn

    # ------------------------------------------------------------------
    # speculative decoding: draft prefill + the jitted draft-verify round
    # ------------------------------------------------------------------

    def _get_draft_chunk(self):
        """Jitted chunked prefill of the DRAFT cache: same wave protocol
        as ``_get_chunk_step`` (idle slots ride along with ``n_valid=0``)
        but only the cache matters — the draft's prompt logits are
        discarded, the target samples every emitted token. Compiles once
        per engine."""
        if self._draft_chunk_fn is not None:
            return self._draft_chunk_fn
        dcfg, mode = self.draft_cfg, self.mode

        def dchunk(dparams, state: DecodeState, tokens, n_valid,
                   is_first) -> DecodeState:
            dcache = {
                k: c._replace(
                    lengths=jnp.where(is_first[None, :], 0, c.lengths)
                )
                for k, c in state.draft_cache.items()
            }
            _, dcache = T.prefill_chunk_step(
                dparams, dcfg, tokens, dcache, n_valid, mode=mode
            )
            return state._replace(draft_cache=dcache)

        self._draft_chunk_fn = jax.jit(dchunk, donate_argnums=(1,))
        return self._draft_chunk_fn

    def _get_spec_step(self, out_cap: int, stop_token: Optional[int]):
        """One speculative draft-verify round, fully on device (the
        spec-mode replacement for ``_get_step``; same compile-key
        discipline). K draft ``decode_step``s propose a chunk, ONE
        ``transformer.spec_verify_chunk`` scores it without appending,
        the acceptance rule picks ``n_emit``, and the commit path writes
        exactly the surviving rows (ring) or writes-then-truncates
        (linear — the paged trailing pages are decrefed host-side at the
        iteration boundary). Every emitted token is the target's argmax,
        so greedy outputs match the sequential loop bit-for-bit."""
        key = (out_cap, stop_token)
        if key in self._spec_step_fns:
            return self._spec_step_fns[key]
        cfg, dcfg, mode = self.cfg, self.draft_cfg, self.mode
        hot_cap, k_spec = self.hot_cap, self.spec_k
        ring = cfg.attn_type == "swa"
        force_reject = self.spec_force == "reject"

        def spec_step(params, dparams, state: DecodeState) -> DecodeState:
            active = state.allocated & ~state.done
            act32 = active.astype(jnp.int32)
            seq0 = state.seq_len
            remaining = jnp.maximum(state.max_new - state.n_gen, 0)
            chunk_valid = jnp.where(
                active, jnp.minimum(k_spec, remaining), 0
            )
            # -- draft: K cheap greedy steps against the draft cache.
            # chunk[:, 0] is the pending token; step i appends row i's
            # KV (gated by chunk_valid, so draft lengths advance by
            # exactly chunk_valid) and its argmax proposes row i+1.
            dcache = dict(state.draft_cache)
            cols = [state.tok]
            tok_i = state.tok
            for i in range(k_spec):
                gate = active & (i < chunk_valid)
                dlogits, dcache = T.decode_step(
                    dparams, dcfg, tok_i, dcache, mode=mode, active=gate
                )
                prop = jnp.argmax(dlogits, axis=-1).astype(jnp.int32)
                tok_i = jnp.where(gate, prop, tok_i)
                if i + 1 < k_spec:
                    cols.append(tok_i)
            chunk = jnp.stack(cols, axis=1)  # (slots, K)
            # -- verify: one fixed-shape chunk dispatch, no append
            logits, kvs = T.spec_verify_chunk(
                params, cfg, chunk, state.cache, chunk_valid, mode=mode
            )
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            n_emit = spec_lib.longest_accepted_prefix(
                chunk, greedy, chunk_valid, stop_token,
                force_reject=force_reject,
            )
            # -- commit: a wrapped ring append is destructive, so ring
            # layouts commit only the accepted rows; linear layouts
            # commit the whole chunk and roll back via truncate (the
            # path the paged page-table machinery audits)
            commit_n = n_emit if ring else chunk_valid
            cache = T.spec_commit_chunk(cfg, state.cache, kvs, commit_n)
            if not ring:
                cache = {
                    kk: kv_cache.truncate(c, seq0 + n_emit)
                    for kk, c in cache.items()
                }
            # draft rollback keeps draft lengths == target lengths at
            # every round boundary (the draft re-proposes the rejected
            # suffix next round, now conditioned on the corrected token)
            dcache = {
                kk: kv_cache.truncate(c, seq0 + n_emit)
                for kk, c in dcache.items()
            }
            # -- emit the accepted run into the output buffer
            pos = (
                jnp.arange(out_cap, dtype=jnp.int32)[None]
                - state.n_gen[:, None]
            )
            emit = (pos >= 0) & (pos < n_emit[:, None])
            vals = jnp.take_along_axis(
                chunk, jnp.clip(pos, 0, k_spec - 1), axis=1
            )
            out = jnp.where(emit, vals, state.out)
            n_gen = state.n_gen + n_emit
            seq_len = seq0 + n_emit
            # pending token for the next round: the target's own
            # continuation after the last emitted token — exactly what
            # the sequential loop would have sampled there
            new_tok = jnp.take_along_axis(
                greedy, jnp.clip(n_emit - 1, 0, k_spec - 1)[:, None], axis=1
            )[:, 0]
            tok = jnp.where(active, new_tok, state.tok)
            done = state.done | (active & (n_gen >= state.max_new))
            if stop_token is not None:
                done = done | (active & (tok == stop_token))
            tr = kv_cache.spec_traffic_tokens(
                seq0, chunk_valid, commit_n, hot_cap
            )
            ledger = {
                kk: state.ledger[kk] + tr[kk] * act32 for kk in TRAFFIC_KEYS
            }
            # SDC sentinel over the verify logits (slots, K, vocab)
            numerics_bad = state.numerics_bad | (
                active & ~jnp.isfinite(logits).all(axis=(-2, -1)))
            return DecodeState(
                cache=cache, tok=tok, key=state.key,
                allocated=state.allocated, done=done, seq_len=seq_len,
                n_gen=n_gen, max_new=state.max_new, out=out, ledger=ledger,
                draft_cache=dcache,
                drafted=state.drafted + jnp.maximum(chunk_valid - 1, 0),
                accepted=state.accepted + jnp.maximum(n_emit - 1, 0),
                numerics_bad=numerics_bad,
            )

        fn = jax.jit(spec_step, donate_argnums=(2,))
        self._spec_step_fns[key] = fn
        return fn

    # ------------------------------------------------------------------
    # paged admission: page-table install + hot restore + COW, one dispatch
    # ------------------------------------------------------------------

    def _get_paged_admit(self):
        """Jitted fused paged (re)admission: vmap ``kv_cache.paged_admit``
        over every attention stack's layer axis and reset the per-slot
        decode bookkeeping where ``reset``. Every shape is fixed by the
        slot count, so this compiles exactly ONCE per engine regardless
        of which slots a round (re)admits or what their prompts matched."""
        if self._paged_admit_fn is not None:
            return self._paged_admit_fn

        def admit(state: DecodeState, reset, new_len, new_table,
                  hot_src, cow_src, cow_dst) -> DecodeState:
            vm = jax.vmap(
                kv_cache.paged_admit,
                in_axes=(0, None, None, None, None, None, None),
            )
            cache = {
                k: vm(c, reset, new_len, new_table, hot_src, cow_src, cow_dst)
                for k, c in state.cache.items()
            }
            z32 = jnp.zeros_like(state.n_gen)
            return DecodeState(
                cache=cache,
                tok=jnp.where(reset, 0, state.tok),
                key=state.key,
                # the slot decodes only after its last prompt chunk
                # (chunk_step folds `is_last` into `allocated`)
                allocated=state.allocated & ~reset,
                done=state.done & ~reset,
                seq_len=jnp.where(reset, new_len, state.seq_len),
                n_gen=jnp.where(reset, 0, state.n_gen),
                max_new=state.max_new,
                out=jnp.where(reset[:, None], 0, state.out),
                ledger={k: jnp.where(reset, z32, state.ledger[k])
                        for k in TRAFFIC_KEYS},
                draft_cache=state.draft_cache,
                drafted=jnp.where(reset, 0, state.drafted),
                accepted=jnp.where(reset, 0, state.accepted),
                numerics_bad=jnp.where(reset, False, state.numerics_bad),
            )

        self._paged_admit_fn = jax.jit(admit, donate_argnums=(0,))
        return self._paged_admit_fn

    def _get_save_hot(self):
        """Jitted hot-tier snapshot (``kv_cache.save_hot`` vmapped over
        the layer stacks): copies one slot's hot tier into pool pages so
        the prefix tree can later restore it into another slot."""
        if self._save_hot_fn is not None:
            return self._save_hot_fn

        def sh(state: DecodeState, slot, page_ids) -> DecodeState:
            vm = jax.vmap(kv_cache.save_hot, in_axes=(0, None, None))
            cache = {k: vm(c, slot, page_ids) for k, c in state.cache.items()}
            return state._replace(cache=cache)

        self._save_hot_fn = jax.jit(sh, donate_argnums=(0,))
        return self._save_hot_fn

    def _get_set_table(self):
        """Jitted page-table install for mid-decode growth: overwrite
        every attention stack's page table with the host mirror (the
        mirror is exact — admission and growth keep it in lock-step with
        the device copy). Fixed shape (slots, pages_per_slot): one
        compile per engine."""
        if self._set_table_fn is not None:
            return self._set_table_fn

        def st(state: DecodeState, table) -> DecodeState:
            cache = {
                k: c._replace(
                    page_table=jnp.broadcast_to(
                        table.astype(c.page_table.dtype), c.page_table.shape
                    )
                )
                for k, c in state.cache.items()
            }
            return state._replace(cache=cache)

        self._set_table_fn = jax.jit(st, donate_argnums=(0,))
        return self._set_table_fn

    # ------------------------------------------------------------------
    # page-pressure control plane: reclaim, preemption, release
    # ------------------------------------------------------------------

    def _release_slot_state(self, state: DecodeState, s: int,
                            truncate: bool = True) -> DecodeState:
        """Release slot ``s``'s device row mid-flight (preemption or
        cancellation): clear the allocated/done masks and truncate the
        cache row to length 0 (``kv_cache.release_slots``) so the slot is
        inert until re-admitted. Grouped-admission archs (SSM state, no
        per-slot lengths) skip the truncation — their admission scatters
        a complete fresh row anyway."""
        n = int(state.allocated.shape[0])
        mask = np.zeros((n,), bool)
        mask[s] = True
        mj = jnp.asarray(mask)
        kw = {}
        if truncate:
            kw["cache"] = {
                k: kv_cache.release_slots(c, mj)
                for k, c in state.cache.items()
            }
            if self.spec and state.draft_cache is not None:
                kw["draft_cache"] = {
                    k: kv_cache.release_slots(c, mj)
                    for k, c in state.draft_cache.items()
                }
        if state.numerics_bad is not None:
            kw["numerics_bad"] = state.numerics_bad & ~mj
        return state._replace(
            allocated=state.allocated & ~mj, done=state.done & ~mj, **kw
        )

    def _preempt_slot(self, ctx: _ServeCtx, s: int,
                      n_fold: Optional[int] = None) -> None:
        """Evict slot ``s`` mid-flight to reclaim its pages: fold the
        tokens it already emitted into the request's prompt, release its
        pages and device row, and requeue the request (its arrival stamp
        — its claim — survives). Recompute-from-prefix is bit-exact for
        greedy decoding: at preemption the pending token t_k is sampled
        but neither emitted nor cached, so re-prefilling
        prompt ‖ t_0..t_{k-1} deterministically re-samples t_k from the
        same last-position logits — and the prefix cache means only the
        suffix past the longest shared prefix is actually recomputed.

        ``n_fold`` caps how many emitted tokens fold into the prompt —
        the SDC repair ladder passes the slot's last scrub-verified
        count, so tokens emitted after a detected corruption are
        DISCARDED and regenerated from the clean prefix instead of
        poisoning the re-admission (the traffic ledger still charges
        the full attempt: the device really did that work)."""
        req = ctx.sched.slot_req[s]
        tb = ctx.token_bytes
        carry = (dict(req.carry_traffic) if req.carry_traffic
                 else {k: 0 for k in TRAFFIC_KEYS})
        if s in ctx.prefilling:
            off = ctx.prefilling.pop(s)[1]
            if off:  # charge the partial prefill the device already did
                prompt = kv_cache.prompt_traffic_tokens_resumed(
                    off, min(ctx.prefix_used[s], off), self.hot_cap)
                for k in TRAFFIC_KEYS:
                    carry[k] += prompt[k] * tb
        else:
            st = ctx.state
            p_attempt = req.prompt_len
            n_gen = int(np.asarray(st.n_gen[s]))
            if n_fold is not None:
                n_gen = min(n_fold, n_gen)
            if n_gen:
                out_row = np.asarray(st.out[s, :n_gen], np.int32)
                if req.orig_prompt_len is None:
                    req.orig_prompt_len = req.prompt_len
                req.tokens = np.concatenate(
                    [np.asarray(req.tokens, np.int32), out_row])
                req.max_new_tokens -= n_gen
            prompt = kv_cache.prompt_traffic_tokens_resumed(
                p_attempt, ctx.prefix_used[s], self.hot_cap)
            for k in TRAFFIC_KEYS:
                carry[k] += (prompt[k] + int(np.asarray(st.ledger[k][s]))) * tb
            if self.spec:
                # speculation accounting survives preemption the same way
                # traffic does: fold this attempt's counters into the
                # request, the re-admission resets the device rows
                req.carry_drafted += int(np.asarray(st.drafted[s]))
                req.carry_accepted += int(np.asarray(st.accepted[s]))
        ctx.draft_prefilling.pop(s, None)
        req.carry_traffic = carry
        req.carry_reused += ctx.prefix_used[s]
        req.n_preemptions += 1
        ctx.stats.preemptions += 1
        if ctx.slot_pages[s]:
            ctx.pool.decref(ctx.slot_pages[s])
            ctx.slot_pages[s] = []
        ctx.prefix_used[s] = 0
        ctx.remaining[s] = 0
        ctx.seq_mirror[s] = 0
        if ctx.verified_len is not None:
            ctx.verified_len[s] = 0
        ctx.sched.requeue(s)
        ctx.state = self._release_slot_state(
            ctx.state, s, truncate=ctx.chunked)

    def _paged_alloc(self, ctx: _ServeCtx, n: int, beneficiary: Request,
                     exclude: Sequence[int] = ()) -> Optional[List[int]]:
        """Allocate ``n`` pages for ``beneficiary``, reclaiming under
        pressure: LRU tree eviction first (cached prefixes are cheaper to
        lose than live work), then preemption of strictly weaker slots,
        one victim at a time (``SlotScheduler.preempt_victims`` policy) —
        a victim's pages may be tree-shared, so each preemption can also
        unlock further eviction. None when the claim cannot be funded:
        the caller requeues (admission) or self-preempts (growth), and
        the request retries at a later sync point."""
        ctx.ptree.evict_for(n)
        pages = ctx.pool.alloc(n)
        while pages is None:
            emitted = {
                s: ctx.sched.slot_req[s].max_new_tokens - ctx.remaining[s]
                for s in ctx.sched.active_slots()
                if s not in ctx.prefilling
            }
            victims = [
                v for v in ctx.sched.preempt_victims(
                    beneficiary, emitted, exclude)
                if ctx.slot_pages[v]  # pageless victims fund nothing
            ]
            if not victims:
                return None
            self._preempt_slot(ctx, victims[0])
            ctx.ptree.evict_for(n)
            pages = ctx.pool.alloc(n)
        return pages

    def _ensure_pages(self, ctx: _ServeCtx, chunk: int) -> None:
        """Fund mid-decode cold-page growth before a decode chunk: extend
        every decoding slot's page row to cover the furthest position the
        chunk can append (the host budget mirror bounds it — no device
        sync). Strongest claims fund first, so when the pool is tight the
        weak get preempted by ``_paged_alloc`` before they themselves ask;
        a slot whose own growth cannot be funded self-preempts (requeues)
        rather than stall the batch."""
        hc, ps = self.hot_cap, self._page_size
        decoding = [
            s for s in ctx.sched.active_slots() if s not in ctx.prefilling
        ]
        dirty = False
        for s in sorted(decoding,
                        key=lambda i: ctx.sched.slot_req[i].claim):
            req = ctx.sched.slot_req[s]
            if req is None:  # preempted by a stronger claim this round
                continue
            target = min(
                ctx.seq_mirror[s] + min(chunk, ctx.remaining[s]),
                self.max_len,
            )
            need = pages_needed(target, hc, ps) - len(ctx.slot_pages[s])
            if need <= 0:
                continue
            pages = self._paged_alloc(ctx, need, req, exclude=(s,))
            if pages is None:
                self._preempt_slot(ctx, s)
                continue
            k0 = len(ctx.slot_pages[s])
            ctx.slot_pages[s].extend(pages)
            ctx.host_table[s, k0 : k0 + len(pages)] = pages
            ctx.stats.grown_pages += len(pages)
            dirty = True
        if dirty:
            ctx.state = self._get_set_table()(
                ctx.state, jnp.asarray(ctx.host_table))

    def _admit_paged(self, ctx: _ServeCtx, fills) -> bool:
        """Host-side page bookkeeping for every slot paired this round,
        then ONE fused device dispatch. Matched pages are transiently
        increfed so the eviction/preemption that funds the fresh
        allocations can never free them before the dispatch reads them.

        Pages are allocated lazily — enough to cover the PROMPT only;
        decode growth is funded chunk-by-chunk by ``_ensure_pages`` — so
        admission pressure reflects real occupancy, not worst-case
        budgets. A fill the pool cannot fund (even after evicting the
        tree and preempting every weaker slot) unwinds its own increfs
        and requeues; it retries at the next sync point once pages free
        up. Returns True when at least one fill was admitted."""
        n_slots = ctx.host_table.shape[0]
        ps, hc, pps = self._page_size, self.hot_cap, self._pps
        reset = np.zeros((n_slots,), bool)
        new_len = np.zeros((n_slots,), np.int32)
        new_table = ctx.host_table.copy()
        hot_src = np.full((n_slots, max(self._n_hot_pages, 1)), -1, np.int32)
        cow_src = np.full((n_slots,), -1, np.int32)
        cow_dst = np.full((n_slots,), -1, np.int32)
        transient: List[int] = []
        # same-round fills are never preemption victims: an already-
        # processed fill has bookkeeping in flight for the fused dispatch
        # (reverting it would corrupt the host mirror), a pending one has
        # no pages to reclaim anyway
        fill_slots = [s for s, _ in fills]
        admitted = False
        for s, req in fills:
            m = (ctx.ptree.match(req.tokens)
                 if self.prefix_sharing else PrefixMatch())
            mine: List[int] = []  # this fill's transient increfs
            if m.length:
                ctx.pool.incref(m.hot_pages)
                mine.extend(m.hot_pages)
                if m.cow_src >= 0:
                    ctx.pool.incref([m.cow_src])
                    mine.append(m.cow_src)
                # the slot's own (retained) reader refs on adopted pages
                ctx.pool.incref(m.shared_pages)
            n_cold = min(pages_needed(req.prompt_len, hc, ps), pps)
            shared = list(m.shared_pages)
            n_fresh = n_cold - len(shared)
            fresh = self._paged_alloc(ctx, n_fresh, req, exclude=fill_slots)
            if fresh is None:
                # unwind THIS fill's bookkeeping before requeueing — the
                # transient and shared increfs must not outlive the
                # failed admission (they would leak the pages for good)
                if mine:
                    ctx.pool.decref(mine)
                if m.length:
                    ctx.pool.decref(list(m.shared_pages))
                ctx.sched.requeue(s)
                ctx.remaining[s] = 0
                ctx.seq_mirror[s] = 0
                continue
            transient.extend(mine)
            row = shared + fresh
            if m.cow_src >= 0 and fresh:
                cow_src[s] = m.cow_src
                cow_dst[s] = fresh[0]  # boundary page = first non-shared
            reset[s] = True
            admitted = True
            new_len[s] = m.length
            if m.hot_pages:
                hot_src[s, : len(m.hot_pages)] = m.hot_pages
            new_table[s] = row + [0] * (pps - len(row))
            ctx.slot_pages[s] = row
            ctx.prefix_used[s] = m.length
            ctx.seq_mirror[s] = req.prompt_len
            if req.orig_prompt_len is not None:
                # a re-admission prefills again what an earlier attempt
                # already computed, minus what the prefix cache kept
                ctx.stats.recompute_tokens += req.prompt_len - m.length
            # chunk streaming resumes at the matched offset: the prefix's
            # KV is already in the cache, only the suffix is prefilled
            ctx.prefilling[s] = [req, m.length]
        if admitted:
            ctx.state = self._get_paged_admit()(
                ctx.state, jnp.asarray(reset), jnp.asarray(new_len),
                jnp.asarray(new_table), jnp.asarray(hot_src),
                jnp.asarray(cow_src), jnp.asarray(cow_dst),
            )
            ctx.host_table[:] = new_table
        if transient:
            ctx.pool.decref(transient)
        return admitted

    # ------------------------------------------------------------------
    # outcomes: finish / cancel / expire / reject
    # ------------------------------------------------------------------

    def cancel(self, rid: int) -> None:
        """Request cancellation of ``rid`` mid-flight. Processed at the
        next sync point of the running ``serve()``: an active slot
        retires immediately (tokens emitted so far surface with outcome
        ``"cancelled"``), its pages decref and its device row is
        released; a queued request is shed without running. Unknown or
        already-finished rids are no-ops."""
        self._cancel_requested.add(rid)

    def _terminal_outcome(self, req: Request, now: float) -> Optional[str]:
        if req.rid in self._cancel_requested:
            self._cancel_requested.discard(req.rid)
            return "cancelled"
        if req.deadline is not None and now >= req.deadline:
            return "expired"
        return None

    def _attempt_prompt_len(self, req: Request) -> int:
        return req.prompt_len + (
            self.cfg.n_patches if req.patches is not None else 0)

    def _build_finished(self, req: Request, out_row: np.ndarray,
                        seq_len: int, decode_ledger: Dict[str, int],
                        prefilled_len: int, prefix_used: int,
                        outcome: str, token_bytes: int,
                        drafted: int = 0, accepted: int = 0) -> FinishedRequest:
        """Assemble a FinishedRequest from one slot's harvest. For a
        request that was preempted along the way, the prompt that the
        final attempt decoded from contains earlier attempts' emitted
        tokens — stitch them back onto the output and report the
        ORIGINAL prompt length, so callers see one uninterrupted
        generation; the traffic ledger sums every attempt's real work
        (``carry_traffic``) on top of this attempt's."""
        traffic = {
            k: int(decode_ledger[k]) * token_bytes for k in TRAFFIC_KEYS
        }
        if prefilled_len:
            prompt = kv_cache.prompt_traffic_tokens_resumed(
                prefilled_len, min(prefix_used, prefilled_len), self.hot_cap)
            for k in TRAFFIC_KEYS:
                traffic[k] += prompt[k] * token_bytes
        if req.carry_traffic:
            for k in TRAFFIC_KEYS:
                traffic[k] += req.carry_traffic[k]
        if req.orig_prompt_len is not None:
            prior = np.asarray(req.tokens, np.int32)[req.orig_prompt_len:]
            tokens = np.concatenate([prior, out_row])
            prompt_len = req.orig_prompt_len
        else:
            tokens = out_row
            prompt_len = req.prompt_len
        return FinishedRequest(
            rid=req.rid,
            prompt_len=prompt_len,
            tokens=tokens,
            seq_len=seq_len,
            steps=len(tokens),
            traffic=traffic,
            prefix_tokens_reused=prefix_used + req.carry_reused,
            outcome=outcome,
            n_preemptions=req.n_preemptions,
            drafted_tokens=drafted + req.carry_drafted,
            accepted_tokens=accepted + req.carry_accepted,
        )

    def _finish_queued(self, req: Request, outcome: str) -> FinishedRequest:
        """Terminal record for a request that never held a slot at the
        end (rejected / cancelled / expired while queued) — shared with
        the router via ``scheduler.terminal_record``."""
        return terminal_record(req, outcome)

    def _cancel_slot(self, ctx: _ServeCtx, s: int, outcome: str) -> None:
        """Terminate an active slot mid-flight (cancel / deadline):
        harvest whatever it emitted, retire it, decref its pages and
        release its device row."""
        req = ctx.sched.retire(s)
        st = ctx.state
        ctx.draft_prefilling.pop(s, None)
        if s in ctx.prefilling:
            off = ctx.prefilling.pop(s)[1]
            fin = self._build_finished(
                req, np.zeros((0,), np.int32), seq_len=off,
                decode_ledger={k: 0 for k in TRAFFIC_KEYS},
                prefilled_len=off, prefix_used=ctx.prefix_used[s],
                outcome=outcome, token_bytes=ctx.token_bytes,
            )
        else:
            n_gen = int(np.asarray(st.n_gen[s]))
            out_row = (np.asarray(st.out[s, :n_gen], np.int32)
                       if n_gen else np.zeros((0,), np.int32))
            spec_kw = (
                dict(drafted=int(np.asarray(st.drafted[s])),
                     accepted=int(np.asarray(st.accepted[s])))
                if self.spec else {}
            )
            fin = self._build_finished(
                req, out_row, seq_len=int(np.asarray(st.seq_len[s])),
                decode_ledger={k: int(np.asarray(st.ledger[k][s]))
                               for k in TRAFFIC_KEYS},
                prefilled_len=self._attempt_prompt_len(req),
                prefix_used=ctx.prefix_used[s],
                outcome=outcome, token_bytes=ctx.token_bytes, **spec_kw,
            )
        ctx.finished.append(fin)
        ctx.stats.record_spec(fin)
        if ctx.slot_pages[s]:
            ctx.pool.decref(ctx.slot_pages[s])
            ctx.slot_pages[s] = []
        ctx.prefix_used[s] = 0
        ctx.remaining[s] = 0
        ctx.seq_mirror[s] = 0
        if ctx.verified_len is not None:
            ctx.verified_len[s] = 0
        ctx.state = self._release_slot_state(
            ctx.state, s, truncate=ctx.chunked)

    def _sweep_cancel_expire(self, ctx: _ServeCtx) -> int:
        """Apply cancellations and deadline expiry at a sync point, to
        queued and active requests alike. Returns the number of requests
        terminated (progress, for the stall guard)."""
        now = self._clock()
        events = 0
        for req in list(ctx.sched.queue):
            outcome = self._terminal_outcome(req, now)
            if outcome:
                ctx.sched.drop(req)
                fin = self._finish_queued(req, outcome)
                ctx.finished.append(fin)
                ctx.stats.record_spec(fin)
                setattr(ctx.stats, outcome,
                        getattr(ctx.stats, outcome) + 1)
                events += 1
        for s, req in enumerate(ctx.sched.slot_req):
            if req is None:
                continue
            outcome = self._terminal_outcome(req, now)
            if outcome:
                self._cancel_slot(ctx, s, outcome)
                setattr(ctx.stats, outcome,
                        getattr(ctx.stats, outcome) + 1)
                events += 1
        return events

    # ------------------------------------------------------------------
    # SDC scrub: the detect -> contain -> repair ladder
    # (serving/sdc.py; docs/serving.md "Fault model & SDC ladder")
    # ------------------------------------------------------------------

    def _scrub(self, ctx: _ServeCtx) -> None:
        """One scrub pass, run inside ``run_iteration`` BEFORE harvest:

          1. weights — re-crc every packed leaf (exact) and optionally
             ABFT-probe it; a mismatch reloads the leaf from its golden
             host copy, flushes the prefix tree, rolls every live slot
             back to its verified frontier and counts a strike
             (``max_weight_strikes`` strikes -> ``unhealthy``, the
             Router's retirement signal);
          2. KV pages — crc-stamp newly FULL cold pages and re-verify
             existing stamps; a mismatch quarantines the page for good,
             evicts the damaged subtree from the prefix tree and rolls
             the owning slots back to their verified frontier;
          3. numerics — read the device ``numerics_bad`` sentinel;
             a latched slot is contained (terminal outcome
             ``"numerics"``) or raised as :class:`sdc.NumericsError`,
             per ``IntegrityConfig.on_numerics``.

        Runs every ``scrub_every`` iterations AND whenever a decoding
        slot is ripe for harvest — harvest gating: no request retires
        with an unverified tail, which is what makes the ladder's
        recompute-from-prefix produce bit-identical greedy outputs.
        Slots that come through clean advance ``ctx.verified_len`` to
        their current emitted count — the rollback target is therefore
        always from a scrub that PRECEDES any later-detected fault."""
        ic = self.integrity
        done = np.asarray(ctx.state.done)
        ripe = any(
            done[s] for s in ctx.sched.active_slots()
            if s not in ctx.prefilling
        )
        if not (ripe or ctx.iteration - ctx.last_scrub >= ic.scrub_every):
            return
        ctx.last_scrub = ctx.iteration
        weight_hit = ic.scrub_weights and self._scrub_weights(ctx)
        if not weight_hit and ic.scrub_pages and self.paged:
            self._scrub_pages(ctx)
        self._check_numerics(ctx)
        # surviving decoding slots advance their verified frontier
        n_gen = np.asarray(ctx.state.n_gen)
        for s in ctx.sched.active_slots():
            if s in ctx.prefilling or s in ctx.draft_prefilling:
                continue
            ctx.verified_len[s] = int(n_gen[s])

    def _scrub_weights(self, ctx: _ServeCtx) -> bool:
        """Detect + repair packed-weight corruption. Returns True when a
        fault was found (the caller then skips the page scrub: every
        page crc stamp was just invalidated anyway)."""
        bad = set(pack_lib.verify_packed(self.params))
        if self.integrity.abft_probe:
            bad |= set(sdc_lib.abft_verify_tree(self.params))
        if not bad:
            return False
        ctx.stats.sdc_detected += len(bad)
        for path in sorted(bad):
            gold = (self._golden or {}).get(path)
            if gold is None:
                continue  # unrepairable leaf: strike below still counts
            leaf = sdc_lib.get_leaf(self.params, path)
            self.params = sdc_lib.set_leaf(
                self.params, path,
                dataclasses.replace(leaf, packed=jnp.asarray(gold)))
            self.weight_loads += 1
            ctx.stats.weight_reloads += 1
        self.weight_fault_strikes += 1
        if self.weight_fault_strikes >= self.integrity.max_weight_strikes:
            # repeated faults = a genuinely bad ROM bank, not a cosmic
            # ray; the Router health sweep drains + retires the replica
            self.unhealthy = True
        # containment: everything computed since the fault window opened
        # is suspect — cached prefixes, page stamps, unverified tails
        if ctx.ptree is not None:
            ctx.ptree.flush()
        ctx.page_crc.clear()
        for s in list(ctx.sched.active_slots()):
            self._preempt_slot(ctx, s, n_fold=ctx.verified_len[s])
        return True

    def _scrub_pages(self, ctx: _ServeCtx) -> None:
        """Detect + contain KV-page corruption: stamp newly full pages,
        re-verify stamped ones, quarantine mismatches and roll their
        readers back. Only FULL cold pages behind each slot's frontier
        (plus all tree-held pages) are covered — full pages are
        append-frozen, so their bytes are content-addressable; the hot
        tier and the partial frontier page mutate legitimately and are
        covered by the numerics sentinel only (docs/serving.md)."""
        pool, ptree = ctx.pool, ctx.ptree
        hc, ps = self.hot_cap, self._page_size
        seq_dev = np.asarray(ctx.state.seq_len)
        want = set(ptree.tree_pages()) if ptree is not None else set()
        for s in ctx.sched.active_slots():
            nf = max(0, int(seq_dev[s]) - hc) // ps
            want.update(ctx.slot_pages[s][:nf])
        # retire stamps whose page left the stamped set or was re-
        # allocated to a new life (born advanced) since stamping
        for p in list(ctx.page_crc):
            if p not in want or ctx.page_crc[p][0] != int(pool.born[p]):
                del ctx.page_crc[p]
        check = sorted(ctx.page_crc)
        fresh = sorted(want - set(check))
        crcs = kv_cache.pool_page_crcs(ctx.state.cache, check + fresh)
        bad = [p for p in check if crcs[p] != ctx.page_crc[p][1]]
        for p in fresh:
            ctx.page_crc[p] = (int(pool.born[p]), crcs[p])
        ctx.stats.pages_scrubbed += len(check)
        if not bad:
            return
        ctx.stats.sdc_detected += len(bad)
        # quarantine FIRST so the eviction/preemption decrefs park the
        # damaged pages instead of returning them to the free list
        for p in bad:
            pool.quarantine(p)
            del ctx.page_crc[p]
        if ptree is not None:
            ptree.evict_pages(bad)
        bad_set = set(bad)
        for s in list(ctx.sched.active_slots()):
            if bad_set & set(ctx.slot_pages[s]):
                self._preempt_slot(ctx, s, n_fold=ctx.verified_len[s])

    def _check_numerics(self, ctx: _ServeCtx) -> None:
        """Read the latched non-finite-logits sentinel and contain (or
        raise on) every flagged slot. Containment surfaces the request
        with terminal outcome ``"numerics"`` — its partial output is
        suspect by construction and must not be silently retried."""
        if ctx.state.numerics_bad is None:
            return
        flagged = np.asarray(ctx.state.numerics_bad)
        for s in list(ctx.sched.active_slots()):
            if not flagged[s]:
                continue
            ctx.stats.sdc_detected += 1
            if self.integrity.on_numerics == "raise":
                req = ctx.sched.slot_req[s]
                raise sdc_lib.NumericsError(
                    f"non-finite logits in slot {s} "
                    f"(rid={getattr(req, 'rid', None)})", slot=s)
            # repair the transient plane before the slot is re-tenanted:
            # the poison bytes outlive the cancelled request otherwise
            sdc_lib.clear_hot_slot(ctx, s)
            self._cancel_slot(ctx, s, "numerics")
            ctx.stats.slots_quarantined += 1

    def _record_prefix(self, state: DecodeState, s: int, req: Request,
                       ptree: PrefixCache,
                       host_table: np.ndarray) -> DecodeState:
        """Insert a freshly prefilled prompt into the prefix tree. The
        ``save_hot`` callback fires only when the tree needs a new hot
        node (one jitted snapshot dispatch); cold pages are adopted from
        the slot's page table by reference."""
        box = [state]

        def save(ids):
            arr = np.full((max(ptree.n_hot_pages, 1),), -1, np.int32)
            arr[: len(ids)] = ids
            box[0] = self._get_save_hot()(
                box[0], jnp.int32(s), jnp.asarray(arr)
            )

        ptree.insert(np.asarray(req.tokens, np.int32), host_table[s], save)
        return box[0]

    def _stream_chunks(self, state: DecodeState, n_slots: int,
                       prefilling: Dict[int, list],
                       max_waves: Optional[int] = None,
                       on_last=None,
                       draft_prefilling: Optional[Dict[int, list]] = None,
                       ) -> DecodeState:
        """Stream pending prompt chunks: one dispatch per wave, one
        C-token chunk per prefilling slot per wave. With ``max_waves``
        set the drain stops early and ``prefilling`` carries the
        remaining offsets into the next serving-loop iteration, so a
        long prompt interleaves with decode chunks instead of stalling
        every active slot until the whole queue's prompts are cached.
        ``on_last(state, slot, req)`` runs after the wave that completes
        a slot's prompt (paged serving records the prefix there).

        Speculative engines stream the DRAFT cache's prefill alongside
        (``draft_prefilling``, one extra dispatch per wave). The draft
        always starts at offset 0 — prefix sharing is a target-cache
        concept — so it can lag a target that resumed mid-prompt; the
        target's FINAL chunk is withheld until the draft catches up,
        because the slot enters the speculative decode rounds the moment
        its target prefill completes (``allocated`` is device state) and
        a round against a partial draft cache would propose garbage."""
        step = self._get_chunk_step()
        c = self.prefill_chunk
        dp = draft_prefilling if draft_prefilling is not None else {}
        waves = 0
        while ((prefilling or dp)
               and (max_waves is None or waves < max_waves)):
            toks = np.zeros((n_slots, c), np.int32)
            n_valid = np.zeros((n_slots,), np.int32)
            is_first = np.zeros((n_slots,), bool)
            is_last = np.zeros((n_slots,), bool)
            max_new = np.zeros((n_slots,), np.int32)
            finished_slots = []
            any_target = False
            for s, (req, off) in prefilling.items():
                part = np.asarray(req.tokens, np.int32)[off : off + c]
                if (s in dp and off + len(part) >= req.prompt_len
                        and dp[s][1] + c < req.prompt_len):
                    continue  # withhold the last chunk; draft still lags
                any_target = True
                toks[s, : len(part)] = part
                n_valid[s] = len(part)
                # paged slots were fully reset by the fused admit dispatch
                # (and may resume mid-prompt at a matched offset), so the
                # chunk step must not re-zero their state
                is_first[s] = off == 0 and not self.paged
                max_new[s] = req.max_new_tokens
                if off + len(part) >= req.prompt_len:
                    is_last[s] = True
                    finished_slots.append(s)
                else:
                    prefilling[s] = [req, off + len(part)]
            if dp:
                dtoks = np.zeros((n_slots, c), np.int32)
                dn_valid = np.zeros((n_slots,), np.int32)
                d_first = np.zeros((n_slots,), bool)
                d_done = []
                for s, (req, doff) in dp.items():
                    part = np.asarray(req.tokens, np.int32)[doff : doff + c]
                    dtoks[s, : len(part)] = part
                    dn_valid[s] = len(part)
                    d_first[s] = doff == 0
                    if doff + len(part) >= req.prompt_len:
                        d_done.append(s)
                    else:
                        dp[s] = [req, doff + len(part)]
                state = self._get_draft_chunk()(
                    self.draft_params, state, jnp.asarray(dtoks),
                    jnp.asarray(dn_valid), jnp.asarray(d_first),
                )
                for s in d_done:
                    dp.pop(s)
            if any_target:
                self.key, sub = jax.random.split(self.key)
                state = step(
                    self.params, state, jnp.asarray(toks),
                    jnp.asarray(n_valid), jnp.asarray(is_first),
                    jnp.asarray(is_last), jnp.asarray(max_new), sub,
                )
            waves += 1
            for s in finished_slots:
                req, _ = prefilling.pop(s)
                if on_last is not None:
                    state = on_last(state, s, req)
        return state

    def _admit(
        self, state: DecodeState, slots_idx: List[int], group: List[Request]
    ) -> DecodeState:
        """Prefill ``group`` (equal prompt lengths) and scatter the fresh
        cache rows + first sampled tokens into ``slots_idx``."""
        toks = jnp.asarray(
            np.stack([np.asarray(r.tokens, np.int32) for r in group]), jnp.int32
        )
        batch = {"tokens": toks}
        if group[0].patches is not None:
            batch["patches"] = jnp.asarray(
                np.stack([np.asarray(r.patches) for r in group])
            )
        logits, fresh = self._prefill(self.params, batch)
        idx = jnp.asarray(slots_idx, jnp.int32)
        p_len = toks.shape[1] + (self.cfg.n_patches if "patches" in batch else 0)
        max_new = jnp.asarray([r.max_new_tokens for r in group], jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return self._get_admit()(
            state, fresh, logits, idx, jnp.int32(p_len), max_new, sub
        )

    # ------------------------------------------------------------------
    # the serving loop — a resumable session: start_session() builds the
    # context, run_iteration() advances it by exactly one loop iteration,
    # finish_session() seals the stats. serve() composes the three; the
    # data-parallel router (serving/router.py) drives them directly so N
    # replica sessions interleave in one process.
    # ------------------------------------------------------------------

    def _validate_request(self, r: Request, n_slots: int) -> None:
        need = r.prompt_len + (
            self.cfg.n_patches if r.patches is not None else 0)
        if need == 0:
            # an empty prompt has no last-token logits to sample the
            # first generated token from — under chunked admission it
            # would silently sample from a zero-valid chunk's garbage
            # logits row
            raise ValueError(
                f"request {r.rid}: empty prompt (at least one prompt "
                "token is required to sample the first output token)"
            )
        if need + r.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {r.rid}: prompt {need} + max_new "
                f"{r.max_new_tokens} exceeds max_len {self.max_len}"
            )
        if self.paged:
            # feasibility, not headroom: with lazy growth plus
            # preemption, any request whose PEAK page set fits the
            # pool will eventually complete (the strongest claim can
            # reclaim every other page); one that cannot fit alone
            # can never be served and must be refused up front
            peak = pages_needed(
                min(need + r.max_new_tokens, self.max_len),
                self.hot_cap, self._page_size)
            if peak > self._pool_pages(n_slots):
                raise ValueError(
                    f"request {r.rid}: needs {peak} cold pages at its "
                    f"peak but the pool holds "
                    f"{self._pool_pages(n_slots)} — unservable even "
                    "with every other slot preempted; raise n_pages"
                )

    def start_session(
        self,
        requests: Sequence[Request],
        slots: Optional[int] = None,
        stop_token: Optional[int] = None,
        sync_every: Optional[int] = None,
        max_queue: Optional[int] = None,
        on_iteration: Optional[Callable[[_ServeCtx], None]] = None,
    ) -> _ServeCtx:
        """Validate ``requests`` and build a live serving session — the
        :class:`_ServeCtx` that ``run_iteration`` advances. ``serve()``
        is ``start_session`` + a ``run_iteration`` loop +
        ``finish_session``; the router holds one open session per
        replica and feeds it via ``submit_to_session``."""
        n_slots = slots or self.slots
        chunk = sync_every or self.sync_every
        chunked = self.prefill_chunk > 0 and self._chunked_capable()
        if max_queue is None:
            max_queue = self.max_queue
        for r in requests:
            self._validate_request(r, n_slots)
        # a fresh session owes nothing to rids of earlier sessions: a
        # stale cancel mark must not shoot down an unrelated request that
        # happens to reuse the rid (replica restarts reuse the engine)
        self._cancel_requested.clear()
        # output buffer sized by max_len (which already bounds any budget),
        # NOT by this batch's max budget — the buffer shape is baked into
        # the jitted step, and a varying out_cap would recompile the whole
        # decode graph per distinct value
        out_cap = self.max_len
        sched = SlotScheduler(n_slots, max_queue=max_queue)
        stats = ServeStats()
        finished: List[FinishedRequest] = []
        for r in requests:
            if not sched.submit(r):
                # backpressure: shed explicitly instead of queueing
                # without bound — the caller sees outcome "rejected"
                stats.rejected += 1
                finished.append(self._finish_queued(r, "rejected"))

        state = self._init_state(n_slots, out_cap)
        step = (self._get_spec_step(out_cap, stop_token) if self.spec
                else self._get_step(out_cap, stop_token))
        ctx = _ServeCtx(
            state=state,
            sched=sched,
            finished=finished,
            stats=stats,
            token_bytes=self._kv_token_bytes(),
            chunked=chunked,
            # host mirror of each slot's remaining budget: generation
            # progress is deterministic (one token per active step), so
            # the host can bound the next chunk without reading device
            # state — only stop tokens finish a slot earlier than this
            # mirror predicts. seq_mirror likewise upper-bounds the cache
            # length for page-growth sizing.
            remaining=[0] * n_slots,
            seq_mirror=[0] * n_slots,
            prefix_used=[0] * n_slots,
            # slots mid-prefill, carried ACROSS loop iterations: each
            # iteration streams at most `chunk` waves, then decodes, so
            # long prompts no longer stall every active slot until fully
            # cached
            prefilling={},
            slot_pages=[[] for _ in range(n_slots)],
            verified_len=[0] * n_slots,
            spec=self.spec,
            hot_cap=self.hot_cap,
            step_fn=step,
            chunk=chunk,
            on_iteration=on_iteration,
            # per-iteration wall time feeds the same StragglerMonitor
            # vocabulary the training plane uses; ServeStats summarizes
            # it at finish_session and the router polls `flagged` live
            monitor=StragglerMonitor(window=16, factor=4.0),
        )
        if self.paged:
            ctx.page_size = self._page_size
            ctx.pool = PagePool(self._pool_pages(n_slots))
            ctx.ptree = PrefixCache(ctx.pool, self.hot_cap, self._page_size)
            ctx.host_table = np.zeros((n_slots, self._pps), np.int32)
            # introspection handles for tests and benches: the refcount
            # ledger and prefix tree of the most recent serve() call
            self._last_pool, self._last_ptree = ctx.pool, ctx.ptree
        self._last_ctx = ctx
        return ctx

    def submit_to_session(self, ctx: _ServeCtx, req: Request) -> bool:
        """Dynamic admission into a live session (the router's entry
        point): same validation as ``start_session``, same backpressure
        contract — False means the bounded queue shed the request and
        the CALLER owns its terminal outcome."""
        self._validate_request(req, len(ctx.sched.slot_req))
        return ctx.sched.submit(req)

    def run_iteration(self, ctx: _ServeCtx) -> bool:
        """One serving-loop iteration: sweep cancellations/expiries,
        admit into free slots, fund page growth, run one decode chunk,
        harvest finished slots, fire the hook, count the stall guard.
        Returns True when the iteration made progress. Call only while
        ``not ctx.sched.idle()``."""
        t0 = time.perf_counter()
        sched, chunk, step = ctx.sched, ctx.chunk, ctx.step_fn
        n_slots = len(sched.slot_req)
        progress = self._sweep_cancel_expire(ctx) > 0
        # -- admission: fill every free slot we can ----------------
        if ctx.chunked:
            fills = sched.next_fills()
            for s, req in fills:
                ctx.remaining[s] = req.max_new_tokens
            if self.paged and fills:
                progress |= self._admit_paged(ctx, fills)
            elif fills:
                for s, req in fills:
                    ctx.prefilling[s] = [req, 0]
                    ctx.seq_mirror[s] = req.prompt_len
                progress = True
            on_last = None
            if self.prefix_sharing:
                on_last = lambda st, s, r: self._record_prefix(  # noqa: E731
                    st, s, r, ctx.ptree, ctx.host_table
                )
            if self.spec:
                # every freshly admitted slot also prefills the draft
                # cache, always from offset 0 (the draft never shares
                # prefixes — it is private per-slot scratch)
                for s, (req, _off) in ctx.prefilling.items():
                    if s not in ctx.draft_prefilling:
                        ctx.draft_prefilling[s] = [req, 0]
            progress |= bool(ctx.prefilling) or bool(ctx.draft_prefilling)
            ctx.state = self._stream_chunks(
                ctx.state, n_slots, ctx.prefilling,
                max_waves=chunk, on_last=on_last,
                draft_prefilling=(ctx.draft_prefilling
                                  if self.spec else None),
            )
        else:
            while True:
                slots_idx, group = sched.next_group()
                if not group:
                    break
                ctx.state = self._admit(ctx.state, slots_idx, group)
                for s, req in zip(slots_idx, group):
                    ctx.remaining[s] = req.max_new_tokens
                    ctx.seq_mirror[s] = self._attempt_prompt_len(req)
                progress = True
        # -- fund mid-decode cold growth (may preempt) -------------
        if self.paged:
            # a speculative round transiently appends up to K rows
            # before rollback, so fund the worst-case advance — the
            # trailing decref below returns what rollback strands
            self._ensure_pages(
                ctx, chunk * self.spec_k if self.spec else chunk)
        # -- decode chunk: no host syncs inside --------------------
        # clip the chunk so no dispatch runs past the earliest
        # budget-exhaustion among decoding slots (those steps would be
        # pure waste: the finished slot idles until the next sync);
        # slots still mid-prefill neither bound the chunk nor burn
        # budget — they ride through the decode dispatches inactive.
        # if every decoding slot has exhausted its budget mirror (e.g.
        # max_new_tokens=0 admissions) skip straight to harvest
        decoding = [
            s for s in sched.active_slots()
            if s not in ctx.prefilling and s not in ctx.draft_prefilling
        ]
        budgets = [ctx.remaining[s] for s in decoding
                   if ctx.remaining[s] > 0]
        n_steps = min([chunk] + budgets) if budgets else 0
        for _ in range(n_steps):
            ctx.state = (step(self.params, self.draft_params, ctx.state)
                         if self.spec else step(self.params, ctx.state))
        if self.spec and n_steps:
            # a speculative round emits a data-dependent 1..K tokens,
            # so the deterministic host mirrors no longer hold —
            # refresh them from the device at the sync point (the
            # harvest below reads `done` anyway), then return the
            # pages the rollback stranded past each slot's real
            # length so pool occupancy tracks acceptance, not the
            # funded worst case
            n_gen_dev = np.asarray(ctx.state.n_gen)
            seq_dev = np.asarray(ctx.state.seq_len)
            for s in decoding:
                req = sched.slot_req[s]
                if req is None:
                    continue
                ctx.remaining[s] = max(
                    int(req.max_new_tokens) - int(n_gen_dev[s]), 0)
                ctx.seq_mirror[s] = int(seq_dev[s])
                if not self.paged or not ctx.slot_pages[s]:
                    continue
                keep = pages_needed(
                    ctx.seq_mirror[s], self.hot_cap, self._page_size)
                extra = ctx.slot_pages[s][keep:]
                if extra:
                    ctx.pool.decref(extra)
                    del ctx.slot_pages[s][keep:]
                    # unused table entries must hold a VALID page
                    # index (PagedKVCache convention); the device
                    # copy may keep stale entries — safe, because
                    # any row a future round writes there is re-
                    # funded and re-installed by _ensure_pages first
                    ctx.host_table[s, keep:] = 0
        else:
            for s in decoding:
                ctx.remaining[s] = max(ctx.remaining[s] - n_steps, 0)
                ctx.seq_mirror[s] = min(
                    ctx.seq_mirror[s] + n_steps, self.max_len)
        progress |= n_steps > 0
        # -- SDC scrub: detect -> contain -> repair, BEFORE harvest —
        # a ripe slot forces a scrub, so no request ever retires with
        # an unverified tail (engine._scrub, "harvest gating")
        if self.integrity is not None:
            self._scrub(ctx)
        # -- sync point: harvest finished slots --------------------
        # (the slot table mirrors `allocated`, so only the small
        # `done` mask crosses the device boundary here)
        done = np.asarray(ctx.state.done)
        ripe = [s for s in decoding if done[s]]
        if ripe:
            progress = True
            n_gen = np.asarray(ctx.state.n_gen)
            seq_len = np.asarray(ctx.state.seq_len)
            out = np.asarray(ctx.state.out)
            ledger = {k: np.asarray(ctx.state.ledger[k])
                      for k in TRAFFIC_KEYS}
            drafted_dev = (np.asarray(ctx.state.drafted)
                           if self.spec else None)
            accepted_dev = (np.asarray(ctx.state.accepted)
                            if self.spec else None)
            for s in ripe:
                req = sched.retire(s)
                spec_kw = (
                    dict(drafted=int(drafted_dev[s]),
                         accepted=int(accepted_dev[s]))
                    if self.spec else {}
                )
                fin = self._build_finished(
                    req, out[s, : n_gen[s]].copy(), int(seq_len[s]),
                    {k: ledger[k][s] for k in TRAFFIC_KEYS},
                    self._attempt_prompt_len(req), ctx.prefix_used[s],
                    "finished", ctx.token_bytes, **spec_kw,
                )
                ctx.finished.append(fin)
                ctx.stats.record_spec(fin)
                self._cancel_requested.discard(req.rid)
                ctx.prefix_used[s] = 0
                ctx.remaining[s] = 0
                ctx.seq_mirror[s] = 0
                if self.paged:
                    # pages free exactly when their last reader leaves
                    ctx.pool.decref(ctx.slot_pages[s])
                    ctx.slot_pages[s] = []
            idx = jnp.asarray(ripe, jnp.int32)
            ctx.state = ctx.state._replace(
                allocated=ctx.state.allocated.at[idx].set(False)
            )
        # the hook sees the 0-based index of the iteration that just
        # completed (chaos schedules / tests key off it)
        if ctx.on_iteration is not None:
            ctx.on_iteration(ctx)
        ctx.stats.iterations += 1
        ctx.iteration += 1
        # chaos sleeps injected through the hook count into the iteration
        # time on purpose — that IS the straggler signal
        ctx.monitor.record(ctx.iteration - 1, time.perf_counter() - t0)
        # -- stall guard -------------------------------------------
        # nothing prefilled, decoded, admitted, harvested or swept
        # for many consecutive iterations: the queue head cannot be
        # funded even with the pool fully reclaimed (with the
        # feasibility check above this is unreachable unless an
        # external actor — e.g. a chaos hold — pins pages for good;
        # a bounded hold just rides through the tolerance window)
        ctx.stall = 0 if progress else ctx.stall + 1
        if ctx.stall >= _STALL_LIMIT and not sched.idle():
            head = (min(sched.queue, key=lambda r: r.claim)
                    if sched.queue else None)
            raise PagePoolError(
                "page pool exhausted and unreclaimable: "
                f"{len(sched.queue)} queued "
                f"(head rid={getattr(head, 'rid', None)}), "
                f"{ctx.pool.available() if ctx.pool else 0} pages "
                f"free of {ctx.pool.n_pages if ctx.pool else 0} — "
                "raise n_pages"
            )
        return progress

    def finish_session(self, ctx: _ServeCtx) -> List[FinishedRequest]:
        """Seal a session: summarize the iteration-time distribution into
        its :class:`ServeStats` and publish them as ``last_stats``.
        Returns the session's terminal records."""
        if ctx.monitor is not None and ctx.monitor.times:
            ctx.stats.iter_p50 = float(np.median(ctx.monitor.times))
            ctx.stats.iter_max = float(max(ctx.monitor.times))
            ctx.stats.straggler_flags = len(ctx.monitor.flagged)
        self.last_stats = ctx.stats
        return ctx.finished

    def serve(
        self,
        requests: Sequence[Request],
        slots: Optional[int] = None,
        stop_token: Optional[int] = None,
        sync_every: Optional[int] = None,
        max_queue: Optional[int] = None,
        on_iteration: Optional[Callable[[_ServeCtx], None]] = None,
    ) -> List[FinishedRequest]:
        """Serve ``requests`` through continuous batching; returns one
        terminal :class:`FinishedRequest` PER submitted request, in
        completion order (sort by ``rid`` if you need submission order).
        ``FinishedRequest.outcome`` distinguishes normal completion from
        cancellation, deadline expiry and backpressure shedding.

        The decode hot loop issues exactly one jitted dispatch per token
        and never reads device memory; host synchronization happens only
        every ``sync_every`` steps, to retire finished slots and admit
        queued prompts into the freed rows. With ``prefill_chunk`` set
        (and a capable arch), admission streams fixed-size prompt chunks
        into the freed slots instead of whole same-length groups — one
        prefill compilation total, mixed lengths admit immediately.

        Under paged serving, page-pool pressure degrades instead of
        failing: admission and mid-decode growth reclaim pages by LRU
        tree eviction, then by preempting strictly weaker slots
        (recompute-from-prefix; see the module docstring). ``max_queue``
        bounds the admission queue (overflow is shed as ``rejected``);
        ``on_iteration(ctx)`` runs after every loop iteration — the
        fault-injection/invariant hook (``serving/chaos.py``).

        With a :class:`PreemptionGuard` attached (``Engine(guard=...)``),
        a raised flag drains gracefully: the loop finishes its current
        iteration, folds every active slot's emitted tokens into its
        request (bit-exact recompute-from-prefix on re-submission) and
        returns early; the evacuated requests are in ``last_drained``
        and do NOT get terminal records from this call."""
        ctx = self.start_session(
            requests, slots=slots, stop_token=stop_token,
            sync_every=sync_every, max_queue=max_queue,
            on_iteration=on_iteration,
        )
        self.last_drained = None
        while not ctx.sched.idle():
            self.run_iteration(ctx)
            if self.guard is not None and self.guard.requested:
                self.last_drained, _ = self.drain_session(ctx)
                self.guard.requested = False  # consumed: drained once
                break
        return self.finish_session(ctx)

    # ------------------------------------------------------------------
    # session evacuation: drain (cooperative) / abandon (after a crash)
    # — the migration primitives serving/replica.py + router.py build on
    # ------------------------------------------------------------------

    def drain_session(
        self, ctx: _ServeCtx, with_handoffs: bool = False,
    ) -> "tuple[List[Request], Dict[int, bytes]]":
        """Evacuate a LIVE session: every active slot is preempted
        through the PR 7 fold-in path (emitted tokens fold into the
        prompt, ``orig_prompt_len`` marks the seam, pages decref), then
        the queue is emptied. Returns the evacuated requests in claim
        order — resubmitting them (here or on another replica) continues
        generation bit-exactly for greedy sampling.

        With ``with_handoffs=True`` on a paged engine, each decoding
        slot's KV rows are additionally serialized
        (``kv_cache.pack_slot_state``, storage dtype + checksums) BEFORE
        the fold, keyed by rid — the warm-migration payload a receiving
        replica can seed its prefix cache from (``import_handoff``) so
        only the post-prefix suffix recomputes. Mid-prefill slots carry
        no handoff (they migrate cold; they lose at most one chunk)."""
        handoffs: Dict[int, bytes] = {}
        for s in list(ctx.sched.active_slots()):
            req = ctx.sched.slot_req[s]
            if (with_handoffs and self.paged and s not in ctx.prefilling
                    and s not in ctx.draft_prefilling):
                handoffs[req.rid] = self.export_slot(ctx, s)
            self._preempt_slot(ctx, s)
        drained = sorted(ctx.sched.queue, key=lambda r: r.claim)
        ctx.sched.queue.clear()
        ctx.drained = drained
        return drained, handoffs

    def abandon_session(self, ctx: _ServeCtx) -> List[Request]:
        """Host-side teardown of a DEAD session (the device state is
        lost — a killed replica): release every slot's page claims and
        the queue, returning the orphaned requests in claim order. No
        device dispatch and no token folding happens — emitted tokens
        must come from the router's journal (Replica.journal), not from
        a dead device. After this the session's pool reconciles to
        tree-only references and ``ctx.sched`` is idle."""
        orphans: List[Request] = []
        for s in list(ctx.sched.active_slots()):
            req = ctx.sched.retire(s)
            ctx.prefilling.pop(s, None)
            ctx.draft_prefilling.pop(s, None)
            if ctx.slot_pages[s]:
                ctx.pool.decref(ctx.slot_pages[s])
                ctx.slot_pages[s] = []
            ctx.prefix_used[s] = 0
            ctx.remaining[s] = 0
            ctx.seq_mirror[s] = 0
            orphans.append(req)
        orphans.sort(key=lambda r: r.claim)
        orphans += sorted(ctx.sched.queue, key=lambda r: r.claim)
        ctx.sched.queue.clear()
        return orphans

    def export_slot(self, ctx: _ServeCtx, s: int) -> bytes:
        """Serialize slot ``s``'s KV rows across every cache stack into
        one checksummed payload (``kv_cache.pack_slot_state``) — the
        warm-migration wire format. Rows ship in the tier storage dtype:
        with ``kv_fp8`` on, one byte per element."""
        states = {
            k: kv_cache.export_slot_state(c, s)
            for k, c in ctx.state.cache.items()
        }
        return kv_cache.pack_slot_state(states, self._page_size)

    def import_handoff(self, ctx: _ServeCtx, tokens, blob: bytes) -> int:
        """Receiver side of warm migration: verify + unpack a serialized
        slot state and seed this session's prefix cache with it, so the
        follow-up ``submit_to_session`` of the folded request prefix-
        matches instead of recomputing. Returns the number of prompt
        tokens seeded (0 = nothing usable — caller proceeds cold, which
        is always correct, just slower).

        The full hot tier plus every FULL cold page of the payload is
        adopted: cold rows are written into freshly allocated pool pages
        and the tree's ``insert`` adopts them by id; the hot rows are
        written through the same ``save_hot`` page layout a local
        snapshot would use. The partial trailing page (if any) is NOT
        seeded — the prefix match is capped at ``len(tokens) - 1``
        anyway, and chunked prefill recomputes the tail bit-exactly.
        Raises :class:`HandoffError` when the payload fails verification
        (corrupted/torn transfer) — the caller falls back to cold."""
        if not (self.paged and self.prefix_sharing and ctx.ptree):
            return 0
        states = kv_cache.unpack_slot_state(blob)
        if set(states) != set(ctx.state.cache):
            raise HandoffError(
                f"handoff cache keys {sorted(states)} do not match this "
                f"engine's {sorted(ctx.state.cache)}")
        toks = np.asarray(tokens, np.int32)
        hc, ps = self.hot_cap, self._page_size
        length = min(st["length"] for st in states.values())
        if length < len(toks):
            raise HandoffError(
                f"handoff covers {length} tokens but the folded request "
                f"carries {len(toks)} — torn capture")
        if len(toks) <= hc:
            return 0  # nothing past the hot tier: cold re-prefill is cheap
        kf = (len(toks) - hc) // ps  # full cold pages only
        ctx.ptree.evict_for(kf)
        pages = ctx.pool.alloc(kf) if kf else []
        if pages is None:
            return 0  # pool too tight to host the handoff: go cold
        new_cache = {}
        for key, st in states.items():
            cache = ctx.state.cache[key]
            if kf:
                ck, cv = st["cold_k"], st["cold_v"]
                tail = ck.shape[2:]
                kp = ck[:, : kf * ps].reshape(
                    (ck.shape[0], kf, ps) + tail)
                vp = cv[:, : kf * ps].reshape(
                    (cv.shape[0], kf, ps) + tail)
                cache = kv_cache.write_pool_pages(cache, pages, kp, vp)
            new_cache[key] = cache
        ctx.state = ctx.state._replace(cache=new_cache)

        def save(ids):
            # hot payload lands in the tree's snapshot pages using the
            # exact save_hot layout: hot row i -> page ids[i // ps],
            # row i % ps — so a later admission restores it the same
            # way it restores a locally saved snapshot
            arr = np.full((max(ctx.ptree.n_hot_pages, 1),), -1, np.int32)
            arr[: len(ids)] = ids
            cache2 = {}
            for key, st in states.items():
                hk, hv = st["hot_k"], st["hot_v"]
                tail = hk.shape[2:]
                nhp = len(ids)
                pad = nhp * ps - hk.shape[1]
                if pad:
                    z = np.zeros((hk.shape[0], pad) + tail, hk.dtype)
                    hk = np.concatenate([hk, z], axis=1)
                    hv = np.concatenate([hv, z], axis=1)
                kp = hk.reshape((hk.shape[0], nhp, ps) + tail)
                vp = hv.reshape((hv.shape[0], nhp, ps) + tail)
                cache2[key] = kv_cache.write_pool_pages(
                    ctx.state.cache[key], np.asarray(ids, np.int32), kp, vp)
            ctx.state = ctx.state._replace(cache=cache2)

        ok = ctx.ptree.insert(toks, np.asarray(pages, np.int32), save)
        # the tree holds its own refs on whatever it adopted; our
        # allocation refs retire either way (failed/duplicate inserts
        # free the pages right here)
        if pages:
            ctx.pool.decref(pages)
        return hc + kf * ps if ok else 0

    # ------------------------------------------------------------------
    # aligned-batch convenience API (launchers / examples / benchmarks)
    # ------------------------------------------------------------------

    def generate(
        self,
        prompts: jax.Array,  # (b, prompt_len) int32
        max_new_tokens: int = 32,
        patches: Optional[jax.Array] = None,
        stop_token: Optional[int] = None,
    ) -> GenerationResult:
        """Aligned-batch generation: one slot per prompt row, all admitted
        in a single prefill. Semantics match the seed lock-step engine —
        same tokens for greedy sampling — but stop handling is per-slot
        (a finished row retires instead of gating the whole batch)."""
        t0 = time.time()
        b = prompts.shape[0]
        prompts_np = np.asarray(prompts, np.int32)
        patches_np = None if patches is None else np.asarray(patches)
        reqs = [
            Request(
                rid=i, tokens=prompts_np[i], max_new_tokens=max_new_tokens,
                patches=None if patches_np is None else patches_np[i],
            )
            for i in range(b)
        ]
        finished = self.serve(reqs, slots=b, stop_token=stop_token)
        finished.sort(key=lambda f: f.rid)
        rows = [
            np.concatenate(
                [
                    f.tokens,
                    np.full(
                        (max_new_tokens - len(f.tokens),), PAD_TOKEN, np.int32
                    ),
                ]
            )
            for f in finished
        ]
        traffic = {k: 0 for k in TRAFFIC_KEYS}
        for f in finished:
            for k in TRAFFIC_KEYS:
                traffic[k] += f.traffic[k]
        return GenerationResult(
            tokens=jnp.asarray(np.stack(rows), jnp.int32),
            steps=max((f.steps for f in finished), default=0),
            traffic=traffic,
            wall_s=time.time() - t0,
            steps_per_row=[f.steps for f in finished],
        )

    def expected_reduction(self, seq_len: int) -> float:
        """Closed-form DR-eDRAM prediction for a full generation to seq_len."""
        return dr_edram.closed_form_reduction(seq_len, self.hot_cap)
