"""Continuous-batching serving engine: packed-ternary weights + per-slot
DR-tiered KV caches, with a fully-jitted decode hot loop.

The paper's deployment (§V-B): weights fused on-die (here: packed ternary,
device-resident across the whole session — ZERO weight reload), a DR
eDRAM hot tier for the first ``hot_cap`` tokens of each sequence, external
memory for the rest. Because the weights never move, the serving problem
reduces to keeping the decode path saturated — which is what the slot
model below does.

Architecture
------------
Device state (``DecodeState``) is a fixed-shape pytree over ``n_slots``
batch rows: the stacked tiered KV cache (per-slot ``lengths``), the last
sampled token, a ``done`` mask, per-slot output buffer and the vectorized
DR-traffic ledger. One decode step is ONE jitted dispatch:

  * embedding -> L-layer scan -> logits for every slot,
  * KV appends and recurrent-state updates gated by the on-device
    ``active = allocated & ~done`` mask,
  * sampling (greedy or temperature) on-device,
  * stop-token detection folds into ``done`` ON DEVICE — no
    ``bool(jnp.all(...))`` host pull, so the Python loop never blocks.

The host only syncs at *chunk boundaries* (every ``sync_every`` steps): it
reads the small ``done``/``allocated`` masks, retires finished slots,
harvests their outputs and per-slot ledgers, and admits queued prompts
into the freed slots (``serving/scheduler.py`` decides who goes where) —
either as whole same-length groups (prefill dispatch + cache scatter) or,
with ``prefill_chunk`` set, as fixed-size chunk dispatches streamed
straight into the live cache at per-slot offsets (flash-prefill
continuation: ONE prefill compilation for any prompt-length mix). Slots at different
sequence lengths decode side by side; per-slot lengths keep each
sequence's attention exact — on TPU via the flash-decode Pallas kernel
(``kernels/flash_decode.py``: hot and cold tier merged in one streaming
launch, S-blocks predicated per slot so a sequence streams only its own
prefix — the compute-side counterpart of the DR-traffic ledger below),
elsewhere via the masked validity paths in ``core/kv_cache.py``.

Traffic accounting
------------------
The ledger is vectorized per slot in *token* units
(``kv_cache.step_traffic_tokens``) and accumulated inside the jitted step;
the analytic prompt-phase ledger (``prompt_traffic_tokens``) is added at
admission. Per sequence, the total reconciles exactly with
``dr_edram.closed_form_reduction(seq_len, hot_cap)`` — including in
mixed-length batches, which is asserted in tests.

docs/serving.md walks the full request lifecycle (slots, admission
groups, ``sync_every`` semantics, the reconciliation contract);
docs/kernels.md covers the packed fast path the decode loop runs on.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import dr_edram, kv_cache
from repro.models import pack as pack_lib
from repro.models import transformer as T
from repro.serving.scheduler import FinishedRequest, Request, SlotScheduler

TRAFFIC_KEYS = kv_cache.TRAFFIC_KEYS


class DecodeState(NamedTuple):
    """Fixed-shape device state for the jitted decode loop (one row = slot)."""

    cache: Any  # stacked tiered KV / SSM state pytree, per-slot lengths
    tok: jax.Array  # (slots,) int32 — last sampled token per slot
    key: jax.Array  # PRNG key threaded through on-device sampling
    allocated: jax.Array  # (slots,) bool — slot holds a live request
    done: jax.Array  # (slots,) bool — request finished (stop / budget)
    seq_len: jax.Array  # (slots,) int32 — cache length incl. prompt
    n_gen: jax.Array  # (slots,) int32 — tokens emitted so far
    max_new: jax.Array  # (slots,) int32 — per-slot generation budget
    out: jax.Array  # (slots, out_cap) int32 — emitted tokens
    ledger: Dict[str, jax.Array]  # 4 × (slots,) int32 decode token counts


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array  # (b, n_generated)
    steps: int
    traffic: dict  # accumulated on-die vs external bytes
    wall_s: float

    @property
    def external_reduction(self) -> float:
        return kv_cache.external_reduction(self.traffic)


class Engine:
    """Weight-reload-free continuous-batching inference engine.

    ``serve(requests)`` is the native API: a list of :class:`Request` with
    arbitrary prompt lengths and budgets, served through ``slots``
    concurrent slots with mid-decode admission. ``generate(prompts, ...)``
    is the aligned-batch convenience wrapper (one slot per row) kept for
    the launchers, examples and benchmarks.

    The engine is immutable after construction: sampling mode,
    temperature, hot_cap and max_len are baked into the cached jitted
    step/prefill/admit functions at first trace, so mutating those
    attributes later is silently ignored — build a new Engine instead
    (the packed params can be shared across engines).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        hot_cap: int = 32,
        max_len: int = 256,
        pack: bool = True,
        sample: str = "greedy",
        temperature: float = 1.0,
        seed: int = 0,
        slots: int = 8,
        sync_every: int = 8,
        prefill_chunk: int = 0,
    ):
        self.cfg = cfg
        # Freeze to ROM form once (packed trits + fused wqkv/wgu/w_dqkv/w_gu
        # projection groups, models/pack.py); never reloaded afterwards. The
        # decode hot loop then runs the packed fast path (core/bitlinear.
        # packed_matmul: act-quant-prologue + epilogue-fused Pallas kernel on
        # TPU via BitNetConfig.impl="auto" — raw bf16 in, scaled float out,
        # no int8/int32 HBM intermediates; E-loop expert kernel for MoE) and
        # the flash-decode attention kernel (kernels/flash_decode.py) over
        # the tiered KV cache, dispatched by the same impl="auto" rule.
        self.params = pack_lib.pack_params(params, cfg) if pack else params
        self.mode = "packed" if pack else "qat"
        self.hot_cap = hot_cap
        self.max_len = max_len
        self.sample = sample
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.slots = slots
        self.sync_every = sync_every
        # chunked-prefill admission (docs/serving.md): 0 keeps the legacy
        # same-length-group whole-prompt admission; C > 0 streams prompts
        # into freed slots as fixed-size C-token chunk dispatches against
        # the live cache — ONE prefill compilation total for any prompt-
        # length mix. Supported for attention-cache families without a
        # frontend; other archs fall back to grouped admission.
        self.prefill_chunk = prefill_chunk
        self.weight_loads = 0  # host->device weight transfers after init
        self._step_fns: dict = {}  # (out_cap, stop_token) -> jitted step
        self._batch_axes = None  # lazy: cache-leaf batch-axis pytree
        self._admit_fn = None  # jitted admission (compiles per group size)
        self._chunk_step_fn = None  # jitted chunked-prefill dispatch
        # jitted prefill (one compile per admitted (group, prompt) shape)
        self._prefill = jax.jit(
            lambda p, batch: T.prefill(
                p, self.cfg, batch,
                hot_cap=self.hot_cap, max_len=self.max_len, mode=self.mode,
            )
        )

    def _chunked_capable(self) -> bool:
        """Chunked prefill needs a pure attention-token path: per-slot
        tiered KV caches (no recurrent SSM state to stream) and no
        frontend features spliced ahead of the text tokens."""
        return (
            self.cfg.family in ("dense", "moe")
            and self.cfg.attn_type in ("full", "swa")
            and self.cfg.frontend == "none"
        )

    # ------------------------------------------------------------------
    # sizing helpers
    # ------------------------------------------------------------------

    def _kv_token_bytes(self) -> int:
        cfg = self.cfg
        if cfg.attn_type == "mla":
            per_layer = cfg.mla.kv_cache_dim * 2
        elif cfg.attn_type == "none":
            per_layer = 0
        else:
            per_layer = 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2
        from repro.analysis.roofline import _n_attn_layers

        return per_layer * _n_attn_layers(cfg)

    # ------------------------------------------------------------------
    # device state init / admission scatter
    # ------------------------------------------------------------------

    def _cache_dtype(self):
        # same rule prefill uses, so admission scatters are cast-free
        return self.params["final_ln"].dtype

    def _init_state(self, n_slots: int, out_cap: int) -> DecodeState:
        cache = T.init_decode_cache(
            self.cfg, n_slots, self.max_len, self.hot_cap, dtype=self._cache_dtype()
        )
        self.key, sub = jax.random.split(self.key)

        def z():
            # distinct buffers: the jitted step/admit donate the state, and
            # XLA rejects donating one buffer through several arguments
            return jnp.zeros((n_slots,), jnp.int32)

        return DecodeState(
            cache=cache,
            tok=z(),
            key=sub,
            allocated=jnp.zeros((n_slots,), bool),
            done=jnp.zeros((n_slots,), bool),
            seq_len=z(),
            n_gen=z(),
            max_new=z(),
            out=jnp.zeros((n_slots, out_cap), jnp.int32),
            ledger={k: z() for k in TRAFFIC_KEYS},
        )

    def _cache_batch_axes(self):
        """Pytree (matching the cache) of each leaf's batch axis, found by
        diffing the abstract shapes of two init sizes — robust across the
        dense/moe/ssm/hybrid cache layouts without per-family code."""
        if self._batch_axes is not None:
            return self._batch_axes
        sa = jax.eval_shape(
            lambda: T.init_decode_cache(self.cfg, 2, self.max_len, self.hot_cap)
        )
        sb = jax.eval_shape(
            lambda: T.init_decode_cache(self.cfg, 3, self.max_len, self.hot_cap)
        )

        def axis(a, b):
            diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
            assert len(diffs) == 1, (a.shape, b.shape)
            return diffs[0]

        self._batch_axes = jax.tree.map(axis, sa, sb)
        return self._batch_axes

    def _scatter_cache(self, live, fresh, slots_idx: jax.Array):
        """Write each fresh cache row (batch n) into the live cache at
        ``slots_idx`` along every leaf's batch axis."""
        axes = self._cache_batch_axes()

        def scatter(lv, fr, ax):
            lv_m = jnp.moveaxis(lv, ax, 0)
            fr_m = jnp.moveaxis(fr, ax, 0)
            return jnp.moveaxis(lv_m.at[slots_idx].set(fr_m.astype(lv_m.dtype)), 0, ax)

        return jax.tree.map(scatter, live, fresh, axes)

    def _sample_fn(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        if self.sample == "greedy":
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature, axis=-1
        ).astype(jnp.int32)

    # ------------------------------------------------------------------
    # the fully-jitted decode step
    # ------------------------------------------------------------------

    def _get_step(self, out_cap: int, stop_token: Optional[int]):
        """One decode dispatch: emit -> decode/append -> account -> sample
        -> fold stop into ``done``. Entirely on device; no host syncs."""
        key = (out_cap, stop_token)
        if key in self._step_fns:
            return self._step_fns[key]
        cfg, mode, hot_cap = self.cfg, self.mode, self.hot_cap

        def step(params, state: DecodeState) -> DecodeState:
            active = state.allocated & ~state.done
            act32 = active.astype(jnp.int32)
            # emit the pending token (sampled last step / at admission)
            emit = (
                jnp.arange(out_cap, dtype=jnp.int32)[None] == state.n_gen[:, None]
            ) & active[:, None]
            out = jnp.where(emit, state.tok[:, None], state.out)
            n_gen = state.n_gen + act32
            # decode: append the pending token's KV, get next logits
            logits, cache = T.decode_step(
                params, cfg, state.tok, state.cache, mode=mode, active=active
            )
            # vectorized per-slot DR ledger at the pre-append length
            tr = kv_cache.step_traffic_tokens(state.seq_len, hot_cap)
            ledger = {
                k: state.ledger[k] + tr[k] * act32 for k in TRAFFIC_KEYS
            }
            seq_len = state.seq_len + act32
            # on-device sampling
            key_next, sub = jax.random.split(state.key)
            tok = jnp.where(active, self._sample_fn(logits, sub), state.tok)
            # on-device stop handling: retire via mask, never break the loop
            done = state.done | (active & (n_gen >= state.max_new))
            if stop_token is not None:
                done = done | (active & (tok == stop_token))
            return DecodeState(
                cache=cache, tok=tok, key=key_next, allocated=state.allocated,
                done=done, seq_len=seq_len, n_gen=n_gen,
                max_new=state.max_new, out=out, ledger=ledger,
            )

        fn = jax.jit(step, donate_argnums=(1,))
        self._step_fns[key] = fn
        return fn

    # ------------------------------------------------------------------
    # admission: prefill queued prompts into freed slots
    # ------------------------------------------------------------------

    def _get_admit(self):
        """Jitted admission: scatter fresh cache rows + sample first tokens
        + reset per-slot bookkeeping, all in ONE dispatch. Compiles once
        per admitted group size (shapes of idx/logits), not per prompt
        length — the fresh cache shape only depends on the group size."""
        if self._admit_fn is not None:
            return self._admit_fn

        def admit(state, fresh, logits, idx, p_len, max_new, key):
            first = self._sample_fn(logits, key)
            cache = self._scatter_cache(state.cache, fresh, idx)
            n = idx.shape[0]
            z = jnp.zeros((n,), jnp.int32)
            return DecodeState(
                cache=cache,
                tok=state.tok.at[idx].set(first),
                key=state.key,
                allocated=state.allocated.at[idx].set(True),
                done=state.done.at[idx].set(max_new <= 0),
                seq_len=state.seq_len.at[idx].set(p_len),
                n_gen=state.n_gen.at[idx].set(0),
                max_new=state.max_new.at[idx].set(max_new),
                out=state.out.at[idx].set(0),
                ledger={k: state.ledger[k].at[idx].set(z) for k in TRAFFIC_KEYS},
            )

        self._admit_fn = jax.jit(admit, donate_argnums=(0,))
        return self._admit_fn

    # ------------------------------------------------------------------
    # chunked prefill: stream fixed-size prompt chunks into the live state
    # ------------------------------------------------------------------

    def _get_chunk_step(self):
        """Jitted chunked-prefill dispatch. Every shape is fixed by
        (slots, prefill_chunk) — per-slot offsets (``cache.lengths``),
        valid counts and first/last flags are data — so this compiles
        exactly ONCE per engine regardless of the prompt-length mix
        (asserted in tests/test_scheduler.py via ``_cache_size``).

        One dispatch per chunk wave: run ``transformer.prefill_chunk_step``
        over all slots (idle slots ride along with ``n_valid = 0`` and
        touch nothing), reset per-slot bookkeeping where ``is_first``,
        and sample the first token where ``is_last`` — the slot then
        enters the decode loop exactly as a group-admitted one would.
        """
        if self._chunk_step_fn is not None:
            return self._chunk_step_fn
        cfg, mode = self.cfg, self.mode

        def chunk_step(params, state: DecodeState, tokens, n_valid,
                       is_first, is_last, max_new, key) -> DecodeState:
            # a slot's first chunk starts from a clean cache row
            cache = {
                k: c._replace(
                    lengths=jnp.where(is_first[None, :], 0, c.lengths)
                )
                for k, c in state.cache.items()
            }
            logits, cache = T.prefill_chunk_step(
                params, cfg, tokens, cache, n_valid, mode=mode
            )
            first_tok = self._sample_fn(logits, key)
            z32 = jnp.zeros_like(state.n_gen)
            done = jnp.where(is_first, False, state.done)
            ledger = {
                k: jnp.where(is_first, z32, state.ledger[k])
                for k in TRAFFIC_KEYS
            }
            return DecodeState(
                cache=cache,
                tok=jnp.where(is_last, first_tok, state.tok),
                key=state.key,
                allocated=state.allocated | is_last,
                done=jnp.where(is_last, max_new <= 0, done),
                seq_len=jnp.where(is_first, 0, state.seq_len) + n_valid,
                n_gen=jnp.where(is_first, 0, state.n_gen),
                max_new=jnp.where(is_last, max_new, state.max_new),
                out=jnp.where(is_first[:, None], 0, state.out),
                ledger=ledger,
            )

        self._chunk_step_fn = jax.jit(chunk_step, donate_argnums=(1,))
        return self._chunk_step_fn

    def _stream_chunks(self, state: DecodeState, n_slots: int,
                       prefilling: Dict[int, list]) -> DecodeState:
        """Drain the pending prompt chunks: one dispatch per wave, one
        C-token chunk per prefilling slot per wave, until every pending
        prompt is fully cached and sampled."""
        step = self._get_chunk_step()
        c = self.prefill_chunk
        while prefilling:
            toks = np.zeros((n_slots, c), np.int32)
            n_valid = np.zeros((n_slots,), np.int32)
            is_first = np.zeros((n_slots,), bool)
            is_last = np.zeros((n_slots,), bool)
            max_new = np.zeros((n_slots,), np.int32)
            finished_slots = []
            for s, (req, off) in prefilling.items():
                part = np.asarray(req.tokens, np.int32)[off : off + c]
                toks[s, : len(part)] = part
                n_valid[s] = len(part)
                is_first[s] = off == 0
                max_new[s] = req.max_new_tokens
                if off + len(part) >= req.prompt_len:
                    is_last[s] = True
                    finished_slots.append(s)
                else:
                    prefilling[s] = [req, off + len(part)]
            self.key, sub = jax.random.split(self.key)
            state = step(
                self.params, state, jnp.asarray(toks), jnp.asarray(n_valid),
                jnp.asarray(is_first), jnp.asarray(is_last),
                jnp.asarray(max_new), sub,
            )
            for s in finished_slots:
                prefilling.pop(s)
        return state

    def _admit(
        self, state: DecodeState, slots_idx: List[int], group: List[Request]
    ) -> DecodeState:
        """Prefill ``group`` (equal prompt lengths) and scatter the fresh
        cache rows + first sampled tokens into ``slots_idx``."""
        toks = jnp.asarray(
            np.stack([np.asarray(r.tokens, np.int32) for r in group]), jnp.int32
        )
        batch = {"tokens": toks}
        if group[0].patches is not None:
            batch["patches"] = jnp.asarray(
                np.stack([np.asarray(r.patches) for r in group])
            )
        logits, fresh = self._prefill(self.params, batch)
        idx = jnp.asarray(slots_idx, jnp.int32)
        p_len = toks.shape[1] + (self.cfg.n_patches if "patches" in batch else 0)
        max_new = jnp.asarray([r.max_new_tokens for r in group], jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return self._get_admit()(
            state, fresh, logits, idx, jnp.int32(p_len), max_new, sub
        )

    # ------------------------------------------------------------------
    # the serving loop
    # ------------------------------------------------------------------

    def serve(
        self,
        requests: Sequence[Request],
        slots: Optional[int] = None,
        stop_token: Optional[int] = None,
        sync_every: Optional[int] = None,
    ) -> List[FinishedRequest]:
        """Serve ``requests`` through continuous batching; returns finished
        requests in completion order (slot order within a sync chunk —
        sort by ``rid`` if you need submission order).

        The decode hot loop issues exactly one jitted dispatch per token
        and never reads device memory; host synchronization happens only
        every ``sync_every`` steps, to retire finished slots and admit
        queued prompts into the freed rows. With ``prefill_chunk`` set
        (and a capable arch), admission streams fixed-size prompt chunks
        into the freed slots instead of whole same-length groups — one
        prefill compilation total, mixed lengths admit immediately.
        """
        n_slots = slots or self.slots
        chunk = sync_every or self.sync_every
        chunked = self.prefill_chunk > 0 and self._chunked_capable()
        for r in requests:
            need = r.prompt_len + (self.cfg.n_patches if r.patches is not None else 0)
            if need + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {need} + max_new "
                    f"{r.max_new_tokens} exceeds max_len {self.max_len}"
                )
        # output buffer sized by max_len (which already bounds any budget),
        # NOT by this batch's max budget — the buffer shape is baked into
        # the jitted step, and a varying out_cap would recompile the whole
        # decode graph per distinct value
        out_cap = self.max_len
        sched = SlotScheduler(n_slots)
        for r in requests:
            sched.submit(r)

        state = self._init_state(n_slots, out_cap)
        step = self._get_step(out_cap, stop_token)
        token_bytes = self._kv_token_bytes()
        finished: List[FinishedRequest] = []
        # host mirror of each slot's remaining budget: generation progress
        # is deterministic (one token per active step), so the host can
        # bound the next chunk without reading device state — only stop
        # tokens finish a slot earlier than this mirror predicts.
        remaining = [0] * n_slots

        while not sched.idle():
            # -- admission: fill every free slot we can ----------------
            if chunked:
                prefilling = {
                    s: [req, 0] for s, req in sched.next_fills()
                }
                for s, (req, _) in prefilling.items():
                    remaining[s] = req.max_new_tokens
                state = self._stream_chunks(state, n_slots, prefilling)
            else:
                while True:
                    slots_idx, group = sched.next_group()
                    if not group:
                        break
                    state = self._admit(state, slots_idx, group)
                    for s, req in zip(slots_idx, group):
                        remaining[s] = req.max_new_tokens
            # -- decode chunk: no host syncs inside --------------------
            # clip the chunk so no dispatch runs past the earliest
            # budget-exhaustion among active slots (those steps would be
            # pure waste: the finished slot idles until the next sync);
            # if every active slot has exhausted its budget mirror (e.g.
            # max_new_tokens=0 admissions) skip straight to harvest
            active = sched.active_slots()
            budgets = [remaining[s] for s in active if remaining[s] > 0]
            n_steps = min([chunk] + budgets) if budgets else 0
            for _ in range(n_steps):
                state = step(self.params, state)
            for s in active:
                remaining[s] = max(remaining[s] - n_steps, 0)
            # -- sync point: harvest finished slots --------------------
            # (the slot table mirrors `allocated`, so only the small
            # `done` mask crosses the device boundary here)
            done = np.asarray(state.done)
            ripe = [i for i in sched.active_slots() if done[i]]
            if ripe:
                n_gen = np.asarray(state.n_gen)
                seq_len = np.asarray(state.seq_len)
                out = np.asarray(state.out)
                ledger = {k: np.asarray(state.ledger[k]) for k in TRAFFIC_KEYS}
                for s in ripe:
                    req = sched.retire(s)
                    traffic = {
                        k: int(ledger[k][s]) * token_bytes for k in TRAFFIC_KEYS
                    }
                    prompt = kv_cache.prompt_traffic_tokens(
                        req.prompt_len
                        + (self.cfg.n_patches if req.patches is not None else 0),
                        self.hot_cap,
                    )
                    for k in TRAFFIC_KEYS:
                        traffic[k] += prompt[k] * token_bytes
                    finished.append(
                        FinishedRequest(
                            rid=req.rid,
                            prompt_len=req.prompt_len,
                            tokens=out[s, : n_gen[s]].copy(),
                            seq_len=int(seq_len[s]),
                            steps=int(n_gen[s]),
                            traffic=traffic,
                        )
                    )
                idx = jnp.asarray(ripe, jnp.int32)
                state = state._replace(
                    allocated=state.allocated.at[idx].set(False)
                )
        return finished

    # ------------------------------------------------------------------
    # aligned-batch convenience API (launchers / examples / benchmarks)
    # ------------------------------------------------------------------

    def generate(
        self,
        prompts: jax.Array,  # (b, prompt_len) int32
        max_new_tokens: int = 32,
        patches: Optional[jax.Array] = None,
        stop_token: Optional[int] = None,
    ) -> GenerationResult:
        """Aligned-batch generation: one slot per prompt row, all admitted
        in a single prefill. Semantics match the seed lock-step engine —
        same tokens for greedy sampling — but stop handling is per-slot
        (a finished row retires instead of gating the whole batch)."""
        t0 = time.time()
        b = prompts.shape[0]
        prompts_np = np.asarray(prompts, np.int32)
        patches_np = None if patches is None else np.asarray(patches)
        reqs = [
            Request(
                rid=i, tokens=prompts_np[i], max_new_tokens=max_new_tokens,
                patches=None if patches_np is None else patches_np[i],
            )
            for i in range(b)
        ]
        finished = self.serve(reqs, slots=b, stop_token=stop_token)
        finished.sort(key=lambda f: f.rid)
        pad = stop_token if stop_token is not None else 0
        rows = [
            np.concatenate(
                [f.tokens, np.full((max_new_tokens - len(f.tokens),), pad, np.int32)]
            )
            for f in finished
        ]
        traffic = {k: 0 for k in TRAFFIC_KEYS}
        for f in finished:
            for k in TRAFFIC_KEYS:
                traffic[k] += f.traffic[k]
        return GenerationResult(
            tokens=jnp.asarray(np.stack(rows), jnp.int32),
            steps=max((f.steps for f in finished), default=0),
            traffic=traffic,
            wall_s=time.time() - t0,
        )

    def expected_reduction(self, seq_len: int) -> float:
        """Closed-form DR-eDRAM prediction for a full generation to seq_len."""
        return dr_edram.closed_form_reduction(seq_len, self.hot_cap)
