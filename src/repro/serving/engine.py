"""Batched serving engine: packed-ternary weights + DR-tiered KV cache.

The paper's deployment (§V-B): weights fused on-die (here: packed ternary,
device-resident across the whole session — ZERO weight reload), a DR
eDRAM hot tier for the first `hot_cap` tokens of each sequence, external
memory for the rest. The engine tracks the access-traffic split per decode
step and reports the external-DRAM reduction, which must match the
closed-form model of core/dr_edram.py (asserted in tests).

Batching model: static batched generation — B aligned sequences decode in
lock-step (the paper pipelines 6 such batches through 6 macro partitions;
see distributed/pipeline.py for that axis). Greedy or temperature
sampling.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import dr_edram, kv_cache
from repro.models import pack as pack_lib
from repro.models import transformer as T


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array  # (b, n_generated)
    steps: int
    traffic: dict  # accumulated on-die vs external bytes
    wall_s: float

    @property
    def external_reduction(self) -> float:
        t = self.traffic
        ext = t["ext_read"] + t["ext_write"]
        total = ext + t["ondie_read"] + t["ondie_write"]
        return 1.0 - ext / total if total else 0.0


class Engine:
    """Weight-reload-free inference engine."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        hot_cap: int = 32,
        max_len: int = 256,
        pack: bool = True,
        sample: str = "greedy",
        temperature: float = 1.0,
        seed: int = 0,
    ):
        self.cfg = cfg
        # Freeze to ROM form once; never reloaded afterwards.
        self.params = pack_lib.pack_params(params, cfg) if pack else params
        self.mode = "packed" if pack else "qat"
        self.hot_cap = hot_cap
        self.max_len = max_len
        self.sample = sample
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, t, c: T.decode_step(p, cfg, t, c, mode=self.mode)
        )
        self.weight_loads = 0  # host->device weight transfers after init

    def _kv_token_bytes(self) -> int:
        cfg = self.cfg
        if cfg.attn_type == "mla":
            per_layer = cfg.mla.kv_cache_dim * 2
        elif cfg.attn_type == "none":
            per_layer = 0
        else:
            per_layer = 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2
        from repro.analysis.roofline import _n_attn_layers

        return per_layer * _n_attn_layers(cfg)

    def _select(self, logits: jax.Array) -> jax.Array:
        if self.sample == "greedy":
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, axis=-1).astype(
            jnp.int32
        )

    def generate(
        self,
        prompts: jax.Array,  # (b, prompt_len) int32
        max_new_tokens: int = 32,
        patches: Optional[jax.Array] = None,
        stop_token: Optional[int] = None,
        on_step: Optional[Callable] = None,
    ) -> GenerationResult:
        t0 = time.time()
        batch = {"tokens": prompts}
        if patches is not None:
            batch["patches"] = patches
        logits, cache = T.prefill(
            self.params,
            self.cfg,
            batch,
            hot_cap=self.hot_cap,
            max_len=self.max_len,
            mode=self.mode,
        )
        token_bytes = self._kv_token_bytes() * prompts.shape[0]
        traffic = {"ondie_read": 0, "ext_read": 0, "ondie_write": 0, "ext_write": 0}
        # Prompt phase, paper's accounting (§IV Fig. 5a): the edge pipeline
        # processes tokens sequentially, so token i writes once and reads
        # tokens 0..i-1 — same ledger as a decode step at length i. This is
        # what makes the measured reduction match the closed form exactly.
        p_len = prompts.shape[1] + (self.cfg.n_patches if patches is not None else 0)
        for i in range(p_len):
            tr = kv_cache.step_traffic_bytes(i, self.hot_cap, token_bytes)
            for k in traffic:
                traffic[k] += tr[k]

        out = []
        tok = self._select(logits)
        length = p_len
        for step in range(max_new_tokens):
            out.append(tok)
            logits, cache = self._decode(self.params, tok, cache)
            tr = kv_cache.step_traffic_bytes(length, self.hot_cap, token_bytes)
            for k in traffic:
                traffic[k] += tr[k]
            length += 1
            tok = self._select(logits)
            if on_step is not None:
                on_step(step, tok)
            if stop_token is not None and bool(jnp.all(tok == stop_token)):
                break
        return GenerationResult(
            tokens=jnp.stack(out, axis=1),
            steps=len(out),
            traffic=traffic,
            wall_s=time.time() - t0,
        )

    def expected_reduction(self, seq_len: int) -> float:
        """Closed-form DR-eDRAM prediction for a full generation to seq_len."""
        return dr_edram.closed_form_reduction(seq_len, self.hot_cap)
