"""Data-parallel router over N engine replicas: load balancing, health
checks, retries, and bit-exact failover.

The router is the fleet's single control plane. It owns admission
(least-loaded placement over live replicas), per-request retry with
exponential backoff + jitter, health checks (heartbeat age plus the
per-iteration :class:`~repro.distributed.fault.StragglerMonitor` each
session already runs), and migration when a replica fails or degrades:

  * **cold migration** (replica died): harvest the orphans from the dead
    replica's host bookkeeping (``Replica.abandon`` — pages decref, no
    device ops), fold each orphan's journaled emitted tokens into its
    prompt (``orig_prompt_len``, the PR 7 preemption trick) and re-admit
    on a survivor. Greedy outputs stay bit-identical to a faultless run
    by construction: re-prefilling prompt‖emitted re-samples the pending
    token from the same logits, and the prefix cache bounds the
    recompute to the un-cached suffix;
  * **warm migration** (replica alive but unhealthy — straggler flags or
    a stale heartbeat): ``Replica.drain(with_handoffs=True)`` folds
    every in-flight request AND ships each decoding slot's KV rows in
    the tier storage dtype (fp8 when enabled) with per-page checksums.
    The payload crosses the :class:`Transport`; the receiver verifies
    and seeds its prefix cache so only post-prefix tokens recompute. A
    corrupted or torn payload raises ``HandoffError`` → the router
    counts it and falls back to cold recompute-from-prefix rather than
    ever serving unverified KV bits.

Replica restarts route through the training plane's
``run_with_recovery`` (bounded retries, same supervisor the training
loop uses), so a deterministically failing restart is retried — and a
replica that exhausts its budget is left dead, its load spread over the
survivors.

Every accepted request ends in EXACTLY ONE terminal
:class:`FinishedRequest` across the fleet — including cancels that land
in the middle of a migration (the rid is tombstoned router-side, so the
re-admit path refuses to resurrect it) and requests whose retry budget
runs out (terminal outcome ``"failed"``). ``serving/chaos.py``'s
``check_fleet_invariants`` re-derives this plus page-ownership and
counter reconciliation after every tick.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.kv_cache import HandoffError
from repro.distributed.fault import InjectedFault, run_with_recovery
from repro.serving.engine import FinishedRequest, PagePoolError
from repro.serving.replica import LocalTransport, Replica, ReplicaDead, Transport
from repro.serving.scheduler import Request, terminal_record


@dataclasses.dataclass
class RouterStats:
    """Fleet-level counters, reconciled against per-replica ``ServeStats``
    by ``check_fleet_invariants``."""

    ticks: int = 0
    admitted: int = 0  # dispatches onto a replica (re-admissions count)
    retries: int = 0  # dispatch attempts beyond each request's first
    cold_migrations: int = 0  # re-admissions after a replica death
    warm_migrations: int = 0  # drain-with-handoff evacuations
    handoffs_imported: int = 0  # payloads that seeded the receiver's cache
    handoff_corruptions: int = 0  # detected (HandoffError) → cold fallback
    replica_failures: int = 0
    restarts: int = 0
    drains: int = 0
    failed: int = 0  # retry budget exhausted → outcome "failed"
    sheds: int = 0  # replica queue bounced an admission (re-dispatched)
    sdc_retirements: int = 0  # replicas retired for repeated weight faults


@dataclasses.dataclass
class _Pending:
    """A request the router owns but no replica currently holds."""

    req: Request
    attempts: int = 0
    retry_at: float = 0.0
    handoff: Optional[bytes] = None  # warm-migration payload in transit
    avoid: Optional[str] = None  # don't re-land on the replica just left


class Router:
    """Load-balancing, health-checking, failure-migrating front door over
    ``replicas``. Single-process cooperative scheduling: each ``tick``
    dispatches pending requests, advances every busy replica by one
    engine iteration, health-sweeps, and restarts the dead. A real
    multi-host deployment replaces the tick loop with per-host threads
    and the :class:`Transport` with a network — the policies here are
    host-count agnostic."""

    def __init__(
        self,
        replicas: Sequence[Replica],
        seed: int = 0,
        retry_limit: int = 4,
        backoff_base: float = 0.02,
        backoff_cap: float = 0.5,
        backoff_jitter: float = 0.5,
        heartbeat_timeout: Optional[float] = None,
        straggler_drain: bool = True,
        max_restarts: int = 2,
        transport: Optional[Transport] = None,
        clock: Optional[Callable[[], float]] = None,
        sleep: Optional[Callable[[float], None]] = None,
    ):
        if not replicas:
            raise ValueError("router needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names: {names}")
        self.replicas: Dict[str, Replica] = {r.name: r for r in replicas}
        self.retry_limit = retry_limit
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.backoff_jitter = backoff_jitter
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_drain = straggler_drain
        self.max_restarts = max_restarts
        self.transport = transport or LocalTransport()
        self._clock = clock or time.monotonic
        self._sleep = sleep or time.sleep
        self._rng = random.Random(seed)
        self.stats = RouterStats()
        self.accepted: Dict[int, Request] = {}
        self.pending: List[_Pending] = []
        self.assigned: Dict[int, str] = {}  # rid -> replica holding it
        self.attempts: Dict[int, int] = {}  # rid -> dispatches so far
        self.finished: List[FinishedRequest] = []
        self._done: set = set()
        self._cancel: set = set()  # tombstones: cancel-before-terminal
        self._retired: set = set()  # replicas whose restart budget is spent
        self._sdc_retired: set = set()  # retired for weight-fault strikes
        self._stop_token: Optional[int] = None
        # straggler flags already acted on, per replica (health sweep
        # reacts to NEW flags only)
        self._flags_seen: Dict[str, int] = {n: 0 for n in self.replicas}

    # -- client surface --------------------------------------------------
    def submit(self, req: Request) -> None:
        """Accept a request into the fleet. Claim order (priority,
        submission order) governs dispatch; the router stamps arrival so
        claims are fleet-global, not per-replica."""
        if req.rid in self.accepted:
            raise ValueError(f"duplicate rid {req.rid}")
        if req.arrival is None:
            req.arrival = len(self.accepted)
        self.accepted[req.rid] = req
        self.pending.append(_Pending(req))

    def cancel(self, rid: int) -> None:
        """Cancel ``rid`` wherever it is — queued at the router, live on
        a replica, or mid-migration between the two. The tombstone
        guarantees exactly one ``cancelled`` terminal even when the
        owning replica dies in the same tick (the migration re-admit
        path checks it before resurrecting the request)."""
        if rid in self._done:
            return
        self._cancel.add(rid)
        name = self.assigned.get(rid)
        if name is not None:
            rep = self.replicas[name]
            if not rep.dead and rep.ctx is not None:
                rep.engine.cancel(rid)

    def serve(
        self,
        requests: Sequence[Request],
        stop_token: Optional[int] = None,
        on_tick: Optional[Callable[["Router"], None]] = None,
        max_ticks: int = 100_000,
    ) -> List[FinishedRequest]:
        """Serve ``requests`` across the fleet to completion; returns one
        terminal record per accepted request. ``on_tick(router)`` runs
        after every tick — the fleet chaos/invariant hook."""
        self._stop_token = stop_token
        for rep in self.replicas.values():
            if rep.ctx is None and not rep.dead:
                rep.start(stop_token=stop_token)
        for r in requests:
            self.submit(r)
        for _ in range(max_ticks):
            if not self.tick(on_tick=on_tick):
                break
        else:
            raise RuntimeError(
                f"router did not converge in {max_ticks} ticks: "
                f"{len(self.pending)} pending, "
                f"{sorted(self.assigned)} assigned")
        return sorted(self.finished, key=lambda f: f.rid)

    # -- the tick --------------------------------------------------------
    def tick(self, on_tick: Optional[Callable[["Router"], None]] = None
             ) -> bool:
        """One control-plane round. Returns True while work remains."""
        # health first: react to the PREVIOUS tick's signals (straggler
        # flags, stale heartbeats) before this tick's steps refresh them
        self._health_sweep()
        self._dispatch()
        stepped = False
        for name in list(self.replicas):
            rep = self.replicas[name]
            if rep.dead:
                if rep.ctx is not None:
                    # killed from outside a step (chaos, operator):
                    # harvest its host bookkeeping before any restart
                    # can replace the session
                    self._on_replica_failure(rep)
                continue
            if not rep.busy():
                continue
            try:
                rep.step()
                stepped = True
            except (ReplicaDead, InjectedFault, PagePoolError):
                self._on_replica_failure(rep)
                continue
            self._collect(rep)
        self._restart_dead()
        self.stats.ticks += 1
        if on_tick is not None:
            on_tick(self)
        live_work = any(rep.busy() for rep in self.replicas.values())
        more = bool(self.pending) or bool(self.assigned) or live_work
        if more and not stepped:
            # everything is backing off — yield instead of spinning
            self._sleep(0.001)
        return more

    # -- placement -------------------------------------------------------
    def _live(self) -> List[Replica]:
        return [r for r in self.replicas.values()
                if not r.dead and r.ctx is not None]

    def _backoff(self, attempts: int) -> float:
        base = min(self.backoff_cap,
                   self.backoff_base * (2 ** max(attempts - 1, 0)))
        return base * (1.0 + self.backoff_jitter * self._rng.random())

    def _dispatch(self) -> None:
        """Place pending requests on the least-loaded live replica, in
        fleet claim order. Honors per-request backoff windows, consumes
        cancel tombstones and deadlines BEFORE placement (a dead rid
        must not be resurrected onto a survivor), and imports any
        in-transit warm handoff on the chosen target."""
        if not self.pending:
            return
        now = self._clock()
        self.pending.sort(key=lambda p: p.req.claim)
        remaining: List[_Pending] = []
        for p in self.pending:
            rid = p.req.rid
            if rid in self._cancel:
                self._terminal(terminal_record(p.req, "cancelled"))
                continue
            if p.req.deadline is not None and now >= p.req.deadline:
                self._terminal(terminal_record(p.req, "expired"))
                continue
            if p.retry_at > now:
                remaining.append(p)
                continue
            cands = [r for r in self._live() if r.name != p.avoid]
            if not cands:
                cands = self._live()
            if not cands:
                remaining.append(p)
                continue
            target = min(cands, key=lambda r: (r.load(), r.name))
            if p.handoff is not None:
                self._import_handoff(target, p)
            if not target.submit(p.req):
                # bounded replica queue shed us: try again after backoff,
                # preferably elsewhere
                self.stats.sheds += 1
                p.avoid = target.name
                p.retry_at = now + self._backoff(p.attempts + 1)
                remaining.append(p)
                continue
            p.attempts += 1
            self.attempts[rid] = self.attempts.get(rid, 0) + 1
            if self.attempts[rid] > 1:
                self.stats.retries += 1
            self.stats.admitted += 1
            self.assigned[rid] = target.name
        self.pending = remaining

    def _import_handoff(self, target: Replica, p: _Pending) -> None:
        """Warm-migration receive: ship the payload over the transport,
        verify + seed the target's prefix cache. Detected corruption is
        counted and silently degrades to cold recompute — wrong KV bits
        never reach a decode."""
        blob, p.handoff = p.handoff, None
        try:
            wire = self.transport.send(blob)
            seeded = target.import_handoff(
                np.asarray(p.req.tokens, np.int32), wire)
        except HandoffError:
            self.stats.handoff_corruptions += 1
            return
        if seeded:
            self.stats.handoffs_imported += 1

    # -- failure handling ------------------------------------------------
    def _fold_journal(self, req: Request, emitted: np.ndarray) -> None:
        """The PR 7 fold, host-only: splice the dead replica's journaled
        tokens into the prompt so re-admission resumes bit-exactly."""
        if emitted.size == 0:
            return
        if req.orig_prompt_len is None:
            req.orig_prompt_len = req.prompt_len
        req.tokens = np.concatenate(
            [np.asarray(req.tokens, np.int32), emitted])
        req.max_new_tokens -= int(emitted.size)
        req.n_preemptions += 1

    def _requeue(self, req: Request, avoid: Optional[str],
                 handoff: Optional[bytes] = None, backoff: bool = True
                 ) -> None:
        """Return a harvested request to router ownership — unless its
        retry budget is spent, in which case it fails terminally (the
        caller has already folded whatever tokens are recoverable, so
        even a failed request surfaces them)."""
        rid = req.rid
        self.assigned.pop(rid, None)
        if self.attempts.get(rid, 0) >= self.retry_limit:
            self.stats.failed += 1
            self._terminal(terminal_record(req, "failed"))
            return
        p = _Pending(req, attempts=self.attempts.get(rid, 0),
                     handoff=handoff, avoid=avoid)
        if backoff:
            p.retry_at = self._clock() + self._backoff(p.attempts)
        self.pending.append(p)

    def _on_replica_failure(self, rep: Replica) -> None:
        """A step raised: the replica is dead. Harvest terminals it
        produced before dying, then cold-migrate every orphan — fold the
        journal snapshot (the last sync point's emitted tokens; the
        device is gone) and hand the request back to dispatch."""
        self.stats.replica_failures += 1
        rep.kill()
        self._collect(rep)  # terminals finished before the crash stand
        journal = dict(rep.journal)
        orphans = rep.abandon()
        for req in orphans:
            emitted = journal.get(req.rid)
            if emitted is not None:
                self._fold_journal(req, emitted)
            self.stats.cold_migrations += 1
            self._requeue(req, avoid=rep.name)

    def _drain_replica(self, rep: Replica, reason: str) -> None:
        """Warm migration off a live-but-unhealthy replica: the engine
        folds every in-flight request and exports each decoding slot's
        KV rows; survivors import what verifies and recompute the rest."""
        del reason  # recorded by callers in stats; kept for readability
        self.stats.drains += 1
        drained, handoffs = rep.drain(with_handoffs=True)
        self._collect(rep)
        for req in drained:
            self.assigned.pop(req.rid, None)
            blob = handoffs.get(req.rid)
            if blob is not None:
                self.stats.warm_migrations += 1
            self._requeue(req, avoid=rep.name, handoff=blob, backoff=False)

    def _health_sweep(self) -> None:
        """React to degradation signals: an engine that struck out on
        repeated weight faults (``Engine.unhealthy`` — the ROM plane is
        untrustworthy, see the SDC ladder in ``engine._scrub_weights``)
        is permanently retired; NEW straggler flags from the session
        monitor or a heartbeat older than the timeout drain the replica
        (warm migration) — it stays live and may receive fresh work
        once healthy iterations resume."""
        for rep in self._live():
            if getattr(rep.engine, "unhealthy", False):
                self._retire_sdc(rep)
                continue
            flags = rep.straggler_flags()
            fresh = flags - self._flags_seen.get(rep.name, 0)
            self._flags_seen[rep.name] = flags
            unhealthy = self.straggler_drain and fresh > 0
            if (not unhealthy and self.heartbeat_timeout is not None
                    and rep.busy()
                    and rep.heartbeat_age() > self.heartbeat_timeout):
                unhealthy = True
            if unhealthy and rep.busy():
                self._drain_replica(rep, "unhealthy")

    def _retire_sdc(self, rep: Replica) -> None:
        """Permanently retire a replica whose engine declared itself
        ``unhealthy`` (weight-fault strike budget spent). Unlike a
        straggler drain, the replica does NOT come back: its weight
        storage keeps re-corrupting, so restarting it would only feed
        the fleet more faults. The session is still live and its last
        scrub verified every surviving slot, so in-flight work warm
        migrates off with handoff payloads before the kill."""
        self.stats.sdc_retirements += 1
        if rep.busy():
            self._drain_replica(rep, "sdc")
        else:
            self._collect(rep)
        rep.seal()  # close the (now idle) session, keep its stats
        rep.kill()
        self._retired.add(rep.name)
        self._sdc_retired.add(rep.name)

    def _restart_dead(self) -> None:
        """Bring dead replicas back through ``run_with_recovery`` (the
        training plane's supervisor): a deterministically failing
        restart is retried up to ``max_restarts`` times; a replica that
        exhausts the budget stays dead and the fleet serves without it."""
        for rep in self.replicas.values():
            if not rep.dead or rep.name in self._retired:
                continue
            try:
                run_with_recovery(
                    lambda _resume, rep=rep: rep.restart(self._stop_token),
                    max_restarts=self.max_restarts,
                )
            except Exception:  # noqa: BLE001 — budget spent: stays dead
                self._retired.add(rep.name)
                continue
            self._flags_seen[rep.name] = 0
            self.stats.restarts += 1

    # -- terminal accounting ---------------------------------------------
    def _collect(self, rep: Replica) -> None:
        for fin in rep.take_finished():
            self.assigned.pop(fin.rid, None)
            self._terminal(fin)

    def _terminal(self, fin: FinishedRequest) -> None:
        self._cancel.discard(fin.rid)
        self._done.add(fin.rid)
        self.finished.append(fin)
