"""Production mesh builders (multi-pod dry-run spec).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (jax locks the device count on first use, and the
dry-run must set XLA_FLAGS before that happens).

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with data parallelism (batch sharded over pod x data)
and carries the cross-pod (DCN-ish) collectives the dry-run must prove out.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; meshes default to Auto
    # axis semantics there, so omitting the kwarg is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return _make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axes(mesh) -> tuple:
    """Mesh axes weights are FSDP-sharded over in training."""
    return ("data",)  # pod stays pure-DP: weights replicated across pods


def axis_size(mesh, *names) -> int:
    n = 1
    for nm in names:
        if nm in mesh.axis_names:
            n *= mesh.shape[nm]
    return n


def replica_devices(index: int, n_replicas: int, devices=None) -> tuple:
    """Devices backing data-parallel serving replica ``index`` (0-based)
    of ``n_replicas``: an even partition of the local device list in
    enumeration order, so replicas never contend for a chip. On hosts
    with fewer devices than replicas (CPU / single-chip dev boxes) the
    replicas share round-robin — the serving router's correctness
    depends only on the Transport boundary, never on physical isolation,
    so the degenerate placement is still a faithful fleet."""
    if not 0 <= index < n_replicas:
        raise ValueError(
            f"replica index {index} out of range [0, {n_replicas})")
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < n_replicas:
        return (devs[index % len(devs)],)
    per = len(devs) // n_replicas
    return tuple(devs[index * per:(index + 1) * per])
