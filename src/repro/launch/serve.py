"""Serving launcher CLI — batched weight-reload-free generation.

Single engine:

  PYTHONPATH=src python -m repro.launch.serve --arch falcon3-1b --smoke \
      --batch 4 --prompt-len 16 --max-new 32 [--hot-cap 32] [--kv-fp8]

Fault-tolerant fleet (data-parallel router over N replicas, optionally
under seeded replica-kill chaos — see docs/serving.md, "Multi-replica
serving"):

  PYTHONPATH=src python -m repro.launch.serve --arch falcon3-1b --smoke \
      --replicas 2 --batch 8 --max-new 16 --kill-rate 0.05 --chaos-seed 0
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T
from repro.serving.engine import Engine


def _serve_fleet(cfg, params, args) -> None:
    from repro.launch.mesh import replica_devices
    from repro.serving import (FleetChaosConfig, FleetChaosInjector,
                               LocalTransport, Replica, Router)
    from repro.serving.scheduler import Request

    max_len = args.prompt_len + args.max_new + 8
    # paged serving needs a non-empty cold tier below the hot window
    hot_cap = min(args.hot_cap, max_len // 2)
    replicas = []
    for i in range(args.replicas):
        devs = replica_devices(i, args.replicas)
        # sync_every=2 keeps router ticks fine-grained: health checks,
        # chaos injection and migration all happen at tick boundaries
        eng = Engine(cfg, params, hot_cap=hot_cap, max_len=max_len,
                     slots=max(2, args.batch // args.replicas),
                     prefill_chunk=8, paged=True, sync_every=2)
        replicas.append(Replica(f"r{i}", eng))
        print(f"replica r{i}: devices {[str(d) for d in devs]}")
    rng = np.random.RandomState(1)
    reqs = [
        Request(rid=i,
                tokens=rng.randint(0, cfg.vocab_size,
                                   size=(args.prompt_len,)).astype(np.int32),
                max_new_tokens=args.max_new)
        for i in range(args.batch)
    ]
    router = Router(replicas, seed=args.chaos_seed,
                    transport=LocalTransport())
    chaos = None
    if args.kill_rate > 0.0 or args.stall_rate > 0.0:
        chaos = FleetChaosInjector(FleetChaosConfig(
            seed=args.chaos_seed, kill_rate=args.kill_rate,
            stall_rate=args.stall_rate, max_kills=args.replicas - 1))
    t0 = time.perf_counter()
    fin = router.serve(reqs, on_tick=chaos.on_tick if chaos else None)
    dt = time.perf_counter() - t0
    toks = sum(len(f.tokens) for f in fin)
    st = router.stats
    bad = sorted((f.rid, f.outcome) for f in fin if f.outcome != "finished")
    print(f"fleet served {len(fin)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s) across {args.replicas} replicas")
    print(f"outcomes: {bad if bad else 'all finished'}")
    print(f"failover: kills={len(chaos.kills) if chaos else 0} "
          f"cold_migrations={st.cold_migrations} "
          f"warm_migrations={st.warm_migrations} "
          f"handoffs_imported={st.handoffs_imported} "
          f"retries={st.retries} restarts={st.restarts} ticks={st.ticks}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--hot-cap", type=int, default=32)
    ap.add_argument("--kv-fp8", action="store_true")
    ap.add_argument("--codec", default="pack2", choices=["pack2", "pack243"])
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a fault-tolerant router over N "
                         "data-parallel engine replicas")
    ap.add_argument("--kill-rate", type=float, default=0.0,
                    help="fleet chaos: per-tick replica-kill probability "
                         "(needs --replicas >= 2)")
    ap.add_argument("--stall-rate", type=float, default=0.0,
                    help="fleet chaos: per-tick replica-stall probability")
    ap.add_argument("--chaos-seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(
        cfg,
        bitnet=dataclasses.replace(cfg.bitnet, kv_fp8=args.kv_fp8, codec=args.codec),
    )
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.replicas > 1:
        _serve_fleet(cfg, params, args)
        return
    eng = Engine(
        cfg, params, hot_cap=args.hot_cap,
        max_len=args.prompt_len + args.max_new + 8,
    )
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    res = eng.generate(prompts, max_new_tokens=args.max_new)
    toks = res.steps * args.batch
    print(f"generated {toks} tokens in {res.wall_s:.2f}s "
          f"({toks/res.wall_s:.1f} tok/s on this host)")
    print(f"external-DRAM reduction {100*res.external_reduction:.1f}% "
          f"(hot_cap={args.hot_cap}); weight reloads: {eng.weight_loads}")


if __name__ == "__main__":
    main()
