"""Serving launcher CLI — batched weight-reload-free generation.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon3-1b --smoke \
      --batch 4 --prompt-len 16 --max-new 32 [--hot-cap 32] [--kv-fp8]
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T
from repro.serving.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--hot-cap", type=int, default=32)
    ap.add_argument("--kv-fp8", action="store_true")
    ap.add_argument("--codec", default="pack2", choices=["pack2", "pack243"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(
        cfg,
        bitnet=dataclasses.replace(cfg.bitnet, kv_fp8=args.kv_fp8, codec=args.codec),
    )
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(
        cfg, params, hot_cap=args.hot_cap,
        max_len=args.prompt_len + args.max_new + 8,
    )
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    res = eng.generate(prompts, max_new_tokens=args.max_new)
    toks = res.steps * args.batch
    print(f"generated {toks} tokens in {res.wall_s:.2f}s "
          f"({toks/res.wall_s:.1f} tok/s on this host)")
    print(f"external-DRAM reduction {100*res.external_reduction:.1f}% "
          f"(hot_cap={args.hot_cap}); weight reloads: {eng.weight_loads}")


if __name__ == "__main__":
    main()
