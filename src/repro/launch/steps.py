"""Entry points lowered by the dry-run / launchers, + input_specs().

One builder per shape kind (DESIGN.md §6):
  train_4k     -> train_step(params, opt_state, batch)
  prefill_32k  -> prefill_step(packed_params, batch) -> (logits, cache)
  decode_32k / long_500k -> serve_step(packed_params, cache, tokens)

``input_specs`` returns ShapeDtypeStruct stand-ins for every input — no
device allocation ever happens in the dry-run (params/caches come from
jax.eval_shape over the real initializers, so the specs can never drift
from the code).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, get_overrides
from repro.models import pack as pack_lib
from repro.models import transformer as T
from repro.training import optimizer as opt_lib
from repro.training import train_lib

PARAM_DTYPE = jnp.bfloat16
HOT_CAP = T.DEFAULT_HOT_CAP


class StepBundle(NamedTuple):
    fn: Any  # callable to jit
    args: tuple  # ShapeDtypeStruct pytrees, in order
    donate: tuple  # donated arg indices
    kind: str


def _batch_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    f = functools.partial(jax.ShapeDtypeStruct, dtype=PARAM_DTYPE)
    if cfg.family == "audio":
        return {"frames": f((batch, seq, cfg.frontend_dim)), "labels": i32((batch, seq))}
    if cfg.family == "vlm":
        st = seq - cfg.n_patches
        return {
            "tokens": i32((batch, st)),
            "patches": f((batch, cfg.n_patches, cfg.frontend_dim)),
            "labels": i32((batch, st)),
        }
    return {"tokens": i32((batch, seq)), "labels": i32((batch, seq))}


def param_specs(cfg: ModelConfig, packed: bool):
    def build(key):
        p = T.init_params(key, cfg, dtype=PARAM_DTYPE)
        # fuse=False: the multi-pod lowering shards per-projection leaves by
        # name (launch/sharding.py) and runs the XLA packed path anyway
        # (qops.resolve_impl returns "xla" under sharding hints); the fused
        # wqkv/wgu/w_dqkv/w_gu fast path is the single-device TPU serving
        # feature (see models/pack.py::pack_params).
        return pack_lib.pack_params(p, cfg, fuse=False) if packed else p

    return jax.eval_shape(build, jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, hot_cap: int = HOT_CAP):
    return jax.eval_shape(
        lambda: T.init_decode_cache(cfg, batch, max_len, hot_cap, dtype=PARAM_DTYPE)
    )


def decode_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Cold capacity stays model-axis divisible: hot 32 + cold seq_len."""
    return HOT_CAP + seq_len


def make_train_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh=None) -> StepBundle:
    ov = get_overrides(cfg.name, shape.name)
    n_micro = ov.get("microbatches", 1)
    opt_cfg = opt_lib.AdamWConfig(quantized_state=ov.get("opt_8bit", False))
    params = param_specs(cfg, packed=False)
    batch = _batch_specs(cfg, shape.global_batch, shape.seq_len)
    grad_sh, micro_sh = None, None
    if mesh is not None:
        from repro.launch import sharding as shd

        grad_sh = shd.param_shardings(params, cfg, mesh, "train")
        micro = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                (x.shape[0] // n_micro,) + x.shape[1:], x.dtype
            ),
            batch,
        )
        micro_sh = shd.micro_batch_shardings(micro, mesh)
    step = train_lib.make_train_step(
        cfg, opt_cfg, n_micro=n_micro, grad_shardings=grad_sh, micro_shardings=micro_sh
    )
    opt_state = jax.eval_shape(lambda p: opt_lib.init(p, opt_cfg), params)
    return StepBundle(fn=step, args=(params, opt_state, batch), donate=(0, 1), kind="train")


def make_prefill_bundle(cfg: ModelConfig, shape: ShapeConfig) -> StepBundle:
    max_len = decode_cache_len(cfg, shape.seq_len)

    if cfg.is_encoder:
        # encoder-only (hubert): "prefill" = one full inference forward
        def prefill_step(params, batch):
            logits, _ = T.forward(params, cfg, batch, mode="packed", remat=False)
            return logits

        params = param_specs(cfg, packed=True)
        batch = _batch_specs(cfg, shape.global_batch, shape.seq_len)
        batch.pop("labels", None)
        return StepBundle(fn=prefill_step, args=(params, batch), donate=(), kind="prefill")

    def prefill_step(params, batch):
        return T.prefill(
            params, cfg, batch, hot_cap=HOT_CAP, max_len=max_len, mode="packed"
        )

    params = param_specs(cfg, packed=True)
    batch = _batch_specs(cfg, shape.global_batch, shape.seq_len)
    batch.pop("labels", None)
    return StepBundle(fn=prefill_step, args=(params, batch), donate=(), kind="prefill")


def make_decode_bundle(cfg: ModelConfig, shape: ShapeConfig) -> StepBundle:
    max_len = decode_cache_len(cfg, shape.seq_len)

    def serve_step(params, cache, tokens):
        return T.decode_step(params, cfg, tokens, cache, mode="packed")

    params = param_specs(cfg, packed=True)
    cache = cache_specs(cfg, shape.global_batch, max_len)
    tokens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    return StepBundle(fn=serve_step, args=(params, cache, tokens), donate=(1,), kind="decode")


def make_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh=None) -> StepBundle:
    if shape.kind == "train":
        return make_train_bundle(cfg, shape, mesh=mesh)
    if shape.kind == "prefill":
        return make_prefill_bundle(cfg, shape)
    if shape.kind == "decode":
        return make_decode_bundle(cfg, shape)
    raise ValueError(shape.kind)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> tuple:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    return make_bundle(cfg, shape).args
