"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch falcon3-1b --smoke \
      --steps 100 --batch 8 --seq 64 [--lora-only] [--opt-8bit] \
      [--ckpt-dir DIR]

Full (non-smoke) configs expect accelerator hardware; the smoke variants
run on CPU. Checkpoint/resume, straggler monitoring and 8-bit optimizer
states are wired through repro.training.loop.
"""

from __future__ import annotations

import argparse

from repro.configs import get_config, get_smoke_config
from repro.training import loop as train_loop
from repro.training.optimizer import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lora-only", action="store_true")
    ap.add_argument("--opt-8bit", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt = AdamWConfig(
        lr=args.lr,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 1),
        quantized_state=args.opt_8bit,
    )
    r = train_loop.train(
        cfg,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        opt_cfg=opt,
        n_micro=args.micro,
        lora_only=args.lora_only,
        ckpt_dir=args.ckpt_dir,
    )
    print(f"done: {r['step']} steps, final loss {r['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
