import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware: the
production mesh is built from 512 placeholder CPU devices (the XLA_FLAGS
line above MUST precede every other import — jax locks the device count on
first init), each cell's step function is jit-lowered with explicit
in/out shardings and compiled, and the compiled artifact's
memory_analysis / cost_analysis plus the HLO collective schedule are
recorded to JSON for the roofline analysis (EXPERIMENTS.md §Dry-run).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.analysis import roofline  # noqa: E402
from repro.configs import SHAPES, applicable_shapes, get_config, list_configs  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_bundle  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"
OPT_RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun_opt"


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
                strategy: str = "baseline", codec: str = "", embed_int8: bool = False,
                kv_fp8: bool = False) -> dict:
    """Lower+compile one cell; return the recorded analysis dict.

    strategy: "baseline" (naive column-parallel TP + FSDP) or "megatron"
    (row/column pairing + sequence parallelism — the beyond-paper
    optimization pass, recorded separately in EXPERIMENTS.md §Perf).
    """
    import dataclasses as _dc

    cfg = get_config(arch)
    if codec or embed_int8 or kv_fp8:
        bn = cfg.bitnet
        bn = _dc.replace(bn, codec=codec or bn.codec, embed_int8=embed_int8,
                         kv_fp8=kv_fp8)
        cfg = _dc.replace(cfg, bitnet=bn)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = make_bundle(cfg, shape, mesh=mesh)

    mode = "train" if bundle.kind == "train" else "infer"
    in_shardings = []
    for i, arg in enumerate(bundle.args):
        if bundle.kind == "train" and i in (0, 1):  # params / opt state
            in_shardings.append(shd.param_shardings(arg, cfg, mesh, mode, strategy))
        elif bundle.kind != "train" and i == 0:  # packed params
            in_shardings.append(shd.param_shardings(arg, cfg, mesh, mode, strategy))
        elif bundle.kind == "decode" and i == 1:  # cache
            in_shardings.append(shd.cache_shardings(arg, cfg, mesh))
        else:  # batch / tokens
            in_shardings.append(shd.batch_shardings(arg, mesh))

    out_shardings = shd.out_shardings_for(bundle, in_shardings, cfg, mesh, shape)

    # MoE expert-parallel hints (see models/shard_ctx.py)
    from repro.launch.mesh import axis_size, batch_axes
    from repro.models import shard_ctx

    expert_axes = None
    moe_groups = 1
    if cfg.moe is not None:
        dn, mn = axis_size(mesh, "data"), axis_size(mesh, "model")
        if strategy.startswith("megatron") and bundle.kind == "train":
            # grouped dispatch: routing local to each data shard; experts
            # sharded over model only (FSDP-K over data carries memory)
            expert_axes = ("model",) if cfg.moe.n_experts % mn == 0 else None
            moe_groups = axis_size(mesh, *batch_axes(mesh))
        elif cfg.moe.n_experts % (dn * mn) == 0:
            expert_axes = ("data", "model")
        elif cfg.moe.n_experts % mn == 0:
            expert_axes = ("model",)

    seq_axis = "model" if strategy == "megatron_sp" and bundle.kind != "decode" else None

    t0 = time.time()
    with mesh, shard_ctx.sharding_hints(
        mesh, expert_axes=expert_axes, batch_axes=batch_axes(mesh),
        seq_axis=seq_axis, moe_groups=moe_groups,
    ):
        jitted = jax.jit(
            bundle.fn,
            in_shardings=tuple(in_shardings),
            out_shardings=out_shardings,
            donate_argnums=bundle.donate,
        )
        lowered = jitted.lower(*bundle.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = roofline.collective_bytes_from_hlo(compiled.as_text())

    n_dev = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "strategy": strategy,
        "codec": cfg.bitnet.codec,
        "embed_int8": embed_int8,
        "kv_fp8": kv_fp8,
        "n_devices": int(n_dev),
        "kind": bundle.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_total": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        },
        "collectives": coll,
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}  "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis (per device): args={rec['memory']['argument_bytes']/2**30:.2f}GiB "
              f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
              f"out={rec['memory']['output_bytes']/2**30:.2f}GiB")
        print(f"  cost_analysis: flops={rec['flops_total']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e}")
        print(f"  collectives: {coll['total_bytes']/2**30:.3f} GiB over "
              f"{coll['op_count']} ops {dict(list(coll['by_kind'].items()))}")
    return rec


def save_record(rec: dict) -> Path:
    d = RESULTS_DIR if rec.get("strategy", "baseline") == "baseline" else OPT_RESULTS_DIR
    d.mkdir(parents=True, exist_ok=True)
    suffix = ""
    if rec.get("codec") and rec["codec"] != "pack2":
        suffix += f"__{rec['codec']}"
    if rec.get("embed_int8"):
        suffix += "__emb8"
    if rec.get("kv_fp8"):
        suffix += "__kvfp8"
    out = d / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    out.write_text(json.dumps(rec, indent=2))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="megatron row/column pairing (results/dryrun_opt)")
    ap.add_argument("--opt-sp", action="store_true",
                    help="megatron pairing + sequence parallelism")
    ap.add_argument("--codec", default="", choices=["", "pack2", "pack243"])
    ap.add_argument("--embed-int8", action="store_true")
    ap.add_argument("--kv-fp8", action="store_true")
    args = ap.parse_args()
    strategy = "megatron_sp" if args.opt_sp else ("megatron" if args.opt else "baseline")

    if args.all:
        cells = [
            (a, s)
            for a in list_configs()
            if a != "falcon3-1b"  # paper-target arch, not an assigned cell
            for s in applicable_shapes(get_config(a))
        ]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    res_dir = RESULTS_DIR if strategy == "baseline" else OPT_RESULTS_DIR
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            name = f"{arch}__{shape}__{'multi' if mp else 'single'}"
            if args.skip_existing and (res_dir / f"{name}.json").exists():
                print(f"[skip] {name}")
                continue
            try:
                rec = dryrun_cell(arch, shape, mp, strategy=strategy, codec=args.codec, embed_int8=args.embed_int8, kv_fp8=args.kv_fp8)
                save_record(rec)
            except Exception as e:  # noqa: BLE001
                failures.append((name, repr(e)))
                print(f"[FAIL] {name}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for n, e in failures:
            print(" ", n, e)
        raise SystemExit(1)
    print("\nall dry-run cells compiled OK")


if __name__ == "__main__":
    main()
