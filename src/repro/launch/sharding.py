"""Logical-axis sharding rules (MaxText-style) for every param/batch/cache.

Rules (DESIGN.md §5):
  batch         -> ("pod","data") on multi-pod, ("data",) on single-pod
  vocab/heads/ffn/expert dims -> "model"   (tensor / expert parallelism)
  weight contraction dims     -> "data"    (FSDP; training mode only)
  kv-cache seq  -> "model" (decode; sequence parallelism for the cache)
  MoE expert dim-> "model" (train EP) or ("data","model") (inference EP,
                   e.g. 256 DeepSeek experts = 16 x 16 chips, 1 expert/chip)

Every rule checks divisibility and falls back to replication — uneven dims
(e.g. mamba2's 50280 vocab) replicate rather than pad.

Param/cache trees contain dataclass leaves (PackedLinear, QTensor); rules
are applied leaf-wise with path+shape pattern matching.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.bitlinear import Int8Linear, PackedLinear
from repro.launch.mesh import axis_size, batch_axes
from repro.training.optimizer import QTensor

# param-tree prefixes with leading stacked dims to skip (scan dims)
_STACK_PREFIXES = {
    "blocks": 1,
    "moe_blocks": 1,
    "dense_blocks": 1,
    "mamba_tail": 1,
    "mamba_groups": 2,
    "shared_lora_v": 1,
}

_EXPERT_KEYS = {"w_gate", "w_up", "w_down"}
_VOCAB_KEYS = {"embed", "lm_head"}


def _path_names(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


def _divisible(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


_ROW_KEYS = {"wo", "down", "w_down", "shared_down", "out_proj"}


def _spec_for_leaf(names, shape, mesh, mode: str, strategy: str = "baseline") -> P:
    """Build a PartitionSpec for one array leaf."""
    model_n = axis_size(mesh, "model")
    data_n = axis_size(mesh, "data")
    nd = len(shape)
    spec: list = [None] * nd

    # leading stack dims to skip
    skip = 0
    for nm in names:
        if nm in _STACK_PREFIXES:
            skip = _STACK_PREFIXES[nm]
            break

    body = list(range(skip, nd))
    if len(body) < 2 or min(shape[d] for d in body) == 0:
        return P(*spec)  # norms / scalars / tiny leaves: replicate

    is_expert = any(n in _EXPERT_KEYS for n in names)
    is_vocab = any(n in _VOCAB_KEYS for n in names)
    train = mode == "train"

    if is_expert and len(body) >= 3:
        e_dim, k_dim, n_dim = body[-3], body[-2], body[-1]
        if _divisible(shape[e_dim], data_n * model_n):
            # pure EP over the full mesh (DeepSeek-style: 256 experts on
            # 256 chips) — no per-layer weight all-gather at all
            spec[e_dim] = ("data", "model")
        elif _divisible(shape[e_dim], model_n):
            spec[e_dim] = "model"  # EP over model axis
            if train and _divisible(shape[k_dim], data_n):
                spec[k_dim] = "data"  # FSDP within expert
        else:
            # few big experts (mixtral): TP over model on N, FSDP on K
            if _divisible(shape[n_dim], model_n):
                spec[n_dim] = "model"
            if train and _divisible(shape[k_dim], data_n):
                spec[k_dim] = "data"
        return P(*spec)

    if is_vocab:
        # embed (V, d) / lm_head (d, V): shard V over model ONLY — FSDP on
        # the feature dim makes GSPMD fully rematerialize the token gather
        # (observed on the 256-dev dry-run) for a ~0.5% param saving.
        v_dim = body[0] if "embed" in names else body[-1]
        if _divisible(shape[v_dim], model_n):
            spec[v_dim] = "model"
        return P(*spec)

    # generic matmul weight (..., K, N)
    k_dim, n_dim = body[-2], body[-1]
    if shape[k_dim] * shape[n_dim] < 1 << 16:
        return P(*spec)  # tiny (LoRA B, scalars): replicate
    row_parallel = strategy.startswith("megatron") and any(n in _ROW_KEYS for n in names)
    if row_parallel and _divisible(shape[k_dim], model_n):
        # Megatron pairing: the *second* projection of each pair (wo, down,
        # out_proj) contracts the TP-sharded dim locally; output partial
        # sums all-reduce (or reduce-scatter onto seq under SP). N stays
        # UNSHARDED: FSDP on the output dim was observed to conflict with
        # batch-over-data activations, forcing per-layer full-activation
        # all-gathers (the baseline's dominant collective).
        spec[k_dim] = "model"
    elif _divisible(shape[n_dim], model_n):
        spec[n_dim] = "model"
        if train and _divisible(shape[k_dim], data_n):
            spec[k_dim] = "data"
    elif _divisible(shape[k_dim], model_n):
        # contraction-sharded (e.g. wo (H*hd, d) with d not divisible)
        spec[k_dim] = "model"
        if train and _divisible(shape[n_dim], data_n):
            spec[n_dim] = "data"
    elif train and _divisible(shape[k_dim], data_n):
        spec[k_dim] = "data"
    return P(*spec)


def param_shardings(param_tree, cfg: ModelConfig, mesh, mode: str, strategy: str = "baseline"):
    """Pytree of NamedSharding mirroring ``param_tree`` (ShapeDtypeStructs ok)."""

    def leaf_rule(path, leaf):
        names = _path_names(path)
        if isinstance(leaf, PackedLinear):
            # packed (…, K/g, N) — same rule as an unpacked weight; scales
            # follow the leading (stack/expert) dims
            pspec = _spec_for_leaf(names + ["w"], leaf.packed.shape, mesh, mode, strategy)
            sspec = P(*[pspec[i] if i < len(leaf.scale.shape) else None
                        for i in range(len(leaf.scale.shape))])
            return PackedLinear(
                packed=NamedSharding(mesh, pspec),
                scale=NamedSharding(mesh, sspec),
                k=leaf.k,
                codec=leaf.codec,
            )
        if isinstance(leaf, Int8Linear):
            pspec = _spec_for_leaf(names + ["w"], leaf.q.shape, mesh, mode, strategy)
            sspec = P(*[
                pspec[i] if leaf.scale.shape[i] == leaf.q.shape[i] else None
                for i in range(len(leaf.scale.shape))
            ])
            return Int8Linear(
                q=NamedSharding(mesh, pspec), scale=NamedSharding(mesh, sspec)
            )
        if isinstance(leaf, QTensor):
            # same-shape codec: q inherits the parameter's sharding, scales
            # drop the (reduced) last dim
            pspec = _spec_for_leaf(names, leaf.q.shape, mesh, mode, strategy)
            sspec = P(*(list(pspec)[: len(leaf.scale.shape) - 1] + [None]))
            return QTensor(
                q=NamedSharding(mesh, pspec),
                scale=NamedSharding(mesh, sspec),
            )
        return NamedSharding(mesh, _spec_for_leaf(names, leaf.shape, mesh, mode, strategy))

    return jax.tree_util.tree_map_with_path(
        leaf_rule,
        param_tree,
        is_leaf=lambda x: isinstance(x, (PackedLinear, QTensor, Int8Linear)),
    )


def batch_shardings(batch_tree, mesh):
    """Batch dim over ("pod","data"); sequence/feature dims replicated."""
    baxes = batch_axes(mesh)

    def rule(leaf):
        bsz = leaf.shape[0]
        n = axis_size(mesh, *baxes)
        spec = [None] * len(leaf.shape)
        if _divisible(bsz, n):
            spec[0] = baxes if len(baxes) > 1 else baxes[0]
        elif _divisible(bsz, axis_size(mesh, "data")):
            spec[0] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(rule, batch_tree)


def micro_batch_shardings(batch_tree, mesh):
    """Shardings for ONE microbatch slice (batch dim 0 over data axes)."""
    baxes = batch_axes(mesh)

    def rule(leaf):
        spec = [None] * len(leaf.shape)
        n = axis_size(mesh, *baxes)
        if _divisible(leaf.shape[0], n):
            spec[0] = baxes if len(baxes) > 1 else baxes[0]
        elif _divisible(leaf.shape[0], axis_size(mesh, "data")):
            spec[0] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(rule, batch_tree)


def cache_shardings(cache_tree, cfg: ModelConfig, mesh):
    """Decode cache: batch over data(+pod), long seq dims over model.

    Layout per leaf: (L, B, cap, ...) for attention tiers; (…, B, …) for
    SSM states. Heuristic: dim matching the global batch -> batch axes; any
    dim >= 1024 divisible by model -> "model" (the cold KV seq); SSM state
    head_dim/channel dims -> "model" when divisible.
    """
    baxes = batch_axes(mesh)
    bn = axis_size(mesh, *baxes)
    model_n = axis_size(mesh, "model")

    def rule(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if not shape:  # length scalars
            return NamedSharding(mesh, P())
        # find batch dim: first dim (after optional leading stacks) that
        # divides by batch axes — attention tiers are (L, B, cap, ...)
        used_batch = False
        for i, d in enumerate(shape[: 3 if len(shape) > 2 else len(shape)]):
            if i >= 1 and not used_batch and _divisible(d, bn) and d >= bn:
                spec[i] = baxes if len(baxes) > 1 else baxes[0]
                used_batch = True
                break
        # long sequence dim -> model
        for i, d in enumerate(shape):
            if spec[i] is None and d >= 1024 and _divisible(d, model_n):
                spec[i] = "model"
                break
        else:
            # SSM states: shard a large trailing channel dim over model
            if "ssm" in names or "conv" in names or "mamba" in names or "tail" in names:
                for i in range(len(shape) - 1, 0, -1):
                    if spec[i] is None and shape[i] >= 64 and _divisible(shape[i], model_n):
                        spec[i] = "model"
                        break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def replicated(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def out_shardings_for(bundle, in_shardings, cfg: ModelConfig, mesh, shape=None):
    """Output shardings per step kind.

    Without explicit out shardings XLA may materialize replicated outputs
    (observed: 639 GiB/device on the 671B train cell) and silently drop
    buffer donation. Outputs mirror the corresponding inputs; small outputs
    (logits, metrics) go batch-sharded / replicated.
    """
    baxes = batch_axes(mesh)
    bspec = baxes if len(baxes) > 1 else baxes[0]
    if shape is not None and shape.global_batch % axis_size(mesh, *baxes):
        bspec = None  # tiny batches (long_500k: b=1) replicate
    scalar = NamedSharding(mesh, P())

    if bundle.kind == "train":
        # (params, opt_state, metrics)
        return (in_shardings[0], in_shardings[1], scalar)
    if bundle.kind == "decode":
        logits_sh = NamedSharding(mesh, P(bspec, None))
        return (logits_sh, in_shardings[1])
    # prefill
    if cfg.is_encoder:
        return NamedSharding(mesh, P(bspec, None, None))
    from repro.launch import steps as steps_lib

    max_len = steps_lib.decode_cache_len(cfg, shape.seq_len)
    cache = steps_lib.cache_specs(cfg, shape.global_batch, max_len)
    return (
        NamedSharding(mesh, P(bspec, None)),
        cache_shardings(cache, cfg, mesh),
    )
