"""Training step: QAT (BitNet STE) forward, CE loss, grad accumulation, AdamW.

``make_train_step`` builds the jit-able step used by both the real training
loop (launch/train.py) and the multi-pod dry-run: microbatched gradient
accumulation via lax.scan (bounds activation memory — the per-arch
``dryrun_overrides`` pick the microbatch count), loss in f32, optional
LoRA-only masking (frozen ternary base = the ROM).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.training import optimizer as opt_lib


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over all positions. logits: (b, s, V) f32; labels: (b, s)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def loss_fn(params, cfg: ModelConfig, batch: dict, mode: str = "qat"):
    logits, aux = T.forward(params, cfg, batch, mode=mode, remat=True)
    labels = batch["labels"]
    logits = logits[:, -labels.shape[1] :, :]  # VLM: patches carry no labels
    ce = cross_entropy(logits, labels)
    return ce + aux, {"ce": ce, "aux": aux}


def lora_trainable_mask(params) -> dict:
    """True only on LoRA leaves — the ROM base stays frozen (paper §III-C)."""

    def walk(path, leaf):
        return any("lora" in str(k) for k in path)

    return jax.tree_util.tree_map_with_path(walk, params)


def _split_micro(batch: dict, n_micro: int) -> dict:
    def sp(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    return jax.tree.map(sp, batch)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: opt_lib.AdamWConfig,
    n_micro: int = 1,
    lora_only: bool = False,
    mode: str = "qat",
    grad_shardings=None,
    micro_shardings=None,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_shardings``: optional pytree of shardings (matching params) used
    to constrain the f32 gradient accumulator of the microbatch scan —
    without it GSPMD may leave the accumulator (param-sized!) partially
    replicated, blowing the per-device temp memory.
    ``micro_shardings``: shardings for ONE microbatch (batch dim over data)
    — the (B,) -> (n_micro, B/n) reshape loses the batch-dim sharding in
    propagation, replicating all activations (observed on the dry-run).
    """

    def _constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, grad_shardings
        )

    def _constrain_micro(mb):
        if micro_shardings is None:
            return mb
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), mb, micro_shardings
        )

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch, mode), has_aux=True
            )(params)
        else:
            micro = _split_micro(batch, n_micro)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                mb = _constrain_micro(mb)
                (l, _), g = jax.value_and_grad(
                    lambda p: loss_fn(p, cfg, mb, mode), has_aux=True
                )(params)
                g_acc = _constrain(
                    jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                )
                return (g_acc, l_acc + l), None

            g0 = _constrain(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (grads, loss), _ = jax.lax.scan(acc_step, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            metrics = {"ce": loss, "aux": jnp.zeros(())}

        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        mask = lora_trainable_mask(params) if lora_only else None
        params_new, opt_new = opt_lib.update(grads, opt_state, params, opt_cfg, mask)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=opt_lib.lr_at(opt_cfg, opt_state.step))
        return params_new, opt_new, metrics

    return train_step
