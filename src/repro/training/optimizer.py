"""AdamW in pure JAX, with optional 8-bit quantized moment states.

The 8-bit states (block-wise absmax int8, bitsandbytes-style) are a
distributed-optimization feature: they cut optimizer HBM by 4× (m, v:
4 B/param fp32 -> 1 B/param + 1 scale per 256 block), which is what lets
the 671B MoE's QAT step fit a pod-scale mesh (DESIGN.md §5). The
quantization is stateless per step: dequant -> update -> requant.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

QBLOCK = 256


# ---------------------------------------------------------------------------
# Row-wise int8 tensor codec (for moment states).
#
# The payload keeps the PARAMETER'S OWN SHAPE (int8) with one f32 absmax
# scale per last-dim row. Earlier flat-(nblocks, 256) layout forced GSPMD
# to all-gather multi-TB moment tensors at the quantize/dequantize reshapes
# (observed on the 671B train dry-run); the same-shape codec inherits the
# parameter sharding with zero resharding.
# ---------------------------------------------------------------------------

MIN_QUANT_SIZE = 4096  # smaller leaves stay f32 (scales would dominate)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QTensor:
    """int8 payload (same shape as the source) + per-row f32 absmax scale."""

    q: jax.Array  # int8, shape == source shape
    scale: jax.Array  # f32, shape[:-1] + (1,)

    @property
    def shape(self) -> tuple:
        return tuple(self.q.shape)

    @property
    def size(self) -> int:
        return self.q.size


def qtensor_quantize(x: jax.Array) -> QTensor:
    x32 = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale)


def qtensor_dequantize(t: QTensor) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    m: Any  # pytree of f32 arrays or QTensors
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    quantized_state: bool = False  # 8-bit m/v
    # linear warmup then cosine decay to lr_min
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def _should_quantize(p, cfg: AdamWConfig) -> bool:
    return cfg.quantized_state and p.ndim >= 1 and p.size >= MIN_QUANT_SIZE


def init(params, cfg: AdamWConfig) -> AdamWState:
    def zeros_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return qtensor_quantize(z) if _should_quantize(p, cfg) else z

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros_like, params),
        v=jax.tree.map(zeros_like, params),
    )


def update(grads, state: AdamWState, params, cfg: AdamWConfig, trainable_mask=None):
    """One AdamW step. Returns (new_params, new_state).

    ``trainable_mask``: optional pytree of bools — False leaves are frozen
    (the ROM: LoRA-only adaptation sets True only on lora leaves).
    """
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    is_q = lambda x: isinstance(x, QTensor)  # noqa: E731

    def _core(g, m, v, p, decay: bool):
        g32 = g.astype(jnp.float32)
        m32 = qtensor_dequantize(m) if is_q(m) else m
        v32 = qtensor_dequantize(v) if is_q(v) else v
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * (g32 * g32)
        upd = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if decay:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p32
        p_new = (p32 - lr * upd).astype(p.dtype)
        if is_q(m):
            return p_new, qtensor_quantize(m32), qtensor_quantize(v32)
        return p_new, m32, v32

    def leaf_update(g, m, v, p, train=True):
        if not train:
            return p, m, v
        decay = p.ndim >= 2
        if p.ndim >= 3 and p.shape[0] > 1:
            # layer/expert-stacked leaf: update one slice at a time — the
            # f32 dequant/update transients are 1/stack of the full leaf
            # (the 671B's expert moments are ~3 GiB/device each otherwise)
            return jax.lax.map(lambda a: _core(*a, decay), (g, m, v, p))
        return _core(g, m, v, p, decay)

    if trainable_mask is None:
        trainable_mask = jax.tree.map(lambda _: True, params)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_t = treedef.flatten_up_to(trainable_mask)
    out = [
        leaf_update(g, m, v, p, t)
        for g, m, v, p, t in zip(flat_g, flat_m, flat_v, flat_p, flat_t)
    ]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def state_bytes(state: AdamWState) -> int:
    """HBM footprint of the optimizer state (for the memory ledger)."""
    total = 0
    for leaf in jax.tree.leaves(state, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.q.size + leaf.scale.size * 4
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
