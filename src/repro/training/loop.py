"""The training loop: data -> step -> metrics -> checkpoint -> resume."""

from __future__ import annotations

import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import manager as ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, DataIterator
from repro.distributed.fault import FaultInjector, PreemptionGuard, StragglerMonitor
from repro.models import transformer as T
from repro.training import optimizer as opt_lib
from repro.training import train_lib


def train(
    cfg: ModelConfig,
    steps: int,
    global_batch: int,
    seq_len: int,
    opt_cfg: Optional[opt_lib.AdamWConfig] = None,
    n_micro: int = 1,
    lora_only: bool = False,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    keep: int = 3,
    seed: int = 0,
    dtype=jnp.float32,
    fault: Optional[FaultInjector] = None,
    preemption: Optional[PreemptionGuard] = None,
    log_every: int = 10,
    verbose: bool = True,
) -> dict:
    """Run (or resume) a training job; returns {'losses': [...], 'step': n, ...}."""
    opt_cfg = opt_cfg or opt_lib.AdamWConfig(total_steps=steps)
    params = T.init_params(jax.random.PRNGKey(seed), cfg, dtype=dtype)
    opt_state = opt_lib.init(params, opt_cfg)
    data = DataIterator(cfg, DataConfig(seed=seed), global_batch, seq_len)
    start = 0
    losses: list = []

    if ckpt_dir is not None:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            trees, extra = ckpt.restore(
                ckpt_dir, last, {"params": params, "opt": opt_state}
            )
            params, opt_state = trees["params"], trees["opt"]
            data.load_state_dict(extra["data"])
            start = last
            losses = list(extra.get("losses", []))
            if verbose:
                print(f"[train] resumed from step {last}")

    step_fn = jax.jit(
        train_lib.make_train_step(cfg, opt_cfg, n_micro=n_micro, lora_only=lora_only),
        donate_argnums=(0, 1),
    )
    monitor = StragglerMonitor()

    def save(step):
        if ckpt_dir is None:
            return
        ckpt.save(
            ckpt_dir,
            step,
            {"params": params, "opt": opt_state},
            extra={"data": data.state_dict(), "losses": losses[-200:]},
        )
        ckpt.keep_last_k(ckpt_dir, keep)

    for step in range(start, steps):
        if fault is not None:
            fault.check(step)
        if preemption is not None and preemption.requested:
            save(step)
            if verbose:
                print(f"[train] preempted at step {step}; checkpointed cleanly")
            return {"losses": losses, "step": step, "preempted": True}
        batch = next(data)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.record(step, time.time() - t0)
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.3f}")
        if ckpt_dir is not None and (step + 1) % ckpt_every == 0:
            save(step + 1)

    save(steps)
    return {
        "losses": losses,
        "step": steps,
        "params": params,
        "opt_state": opt_state,
        "stragglers": monitor.flagged,
    }
