"""Analytical hardware model — reproduces BitROM's evaluation axes.

Calibration constants come from the paper itself (Table III, §V-B) and its
cited references; every derived claim is asserted in tests/benchmarks:

  * 20.8 / 5.2 TOPS/W (A4 / A8 activations, 65 nm, 0.6/1.2 V)
  * bit density 4,967 kb/mm² (BiROMA: 1.58 x 2 bits per 1-T cell)
  * 10x density over digital DCiROM (487 kb/mm², ASPDAC'25 [1])
  * TriMLA + periphery + adder tree = 4.8% of macro area
  * DR eDRAM 13.5 MB for Falcon3-1B (S=128, 32 hot tokens, 6 batches)
  * 43.6% external-DRAM reduction (via core/dr_edram.py)
  * Fig. 1(a): LLaMA-7B > 1,000 cm² at DCiROM-class density; BitNet-1B
    "tens of cm²" — reproduced holding density at the 65 nm measured value
    (ROM arrays are wire/periphery-limited; the paper's node-scaled figure
    is not derivable from its own densities, noted as a deviation).

System-level energy compares BitROM (zero weight reload) against a
weight-reloading accelerator baseline (the paper's "Update-Free" row):
DRAM access energy uses LPDDR-class 20 pJ/bit.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import dr_edram

# ---- paper calibration constants (65 nm unless noted) ----
TOPS_PER_W_A4 = 20.8  # 1.58b weights, 4b activations
TOPS_PER_W_A8 = 5.2  # 8b activations (2-cycle bit-serial + tree toggling)
BIT_DENSITY_KB_MM2 = 4967.0  # BiROMA
DCIROM_DENSITY_KB_MM2 = 487.0  # ASPDAC'25 [1] digital CiROM baseline (macro)
# Task-level density implied by [1]'s full ResNet-56 mapping: 0.85M params
# x 4b in 12 mm^2 (incl. all periphery/trees) — the basis of Fig. 1(a)
DCIROM_TASK_DENSITY_KB_MM2 = 0.85e6 * 4 / 1e3 / 12.0
DCIROM_TOPS_PER_W = (38.0, 9.0)
PERIPHERY_FRACTION = 0.048  # TriMLA + peripheral logic + adder tree
BITS_PER_WEIGHT = 1.58

# DR eDRAM density calibrated from the paper's 14 nm deployment:
# 13.5 MiB <-> 10.24 cm^2
EDRAM_MB_PER_CM2_14NM = 13.5 / 10.24

# energy constants (documented assumptions)
DRAM_PJ_PER_BIT = 20.0  # LPDDR-class external DRAM
EDRAM_PJ_PER_BIT = 0.6  # on-die eDRAM access
SRAM_PJ_PER_BIT = 0.2  # LoRA SRAM


@dataclasses.dataclass(frozen=True)
class MacroSpec:
    """One BiROMA + TriMLA macro (paper §III-B)."""

    rows: int = 2048
    cols: int = 1024
    trits_per_cell: int = 2  # bidirectional: two ternary weights / transistor
    cols_per_trimla: int = 8

    @property
    def trits(self) -> int:
        return self.rows * self.cols * self.trits_per_cell

    @property
    def capacity_bits(self) -> float:
        return self.trits * BITS_PER_WEIGHT

    @property
    def n_trimla(self) -> int:
        return self.cols // self.cols_per_trimla


def energy_per_op_pj(act_bits: int = 4) -> float:
    tops_w = TOPS_PER_W_A4 if act_bits == 4 else TOPS_PER_W_A8
    return 1e12 / (tops_w * 1e12)  # pJ per OP


def macro_area_mm2(n_weights: int) -> float:
    """Silicon area for n ternary weights incl. periphery (65 nm)."""
    bits_kb = n_weights * BITS_PER_WEIGHT / 1e3
    array = bits_kb / BIT_DENSITY_KB_MM2
    return array / (1.0 - PERIPHERY_FRACTION)


def edram_area_cm2(nbytes: int) -> float:
    return nbytes / 2**20 / EDRAM_MB_PER_CM2_14NM


def density_ratio_vs_dcirom() -> float:
    return BIT_DENSITY_KB_MM2 / DCIROM_DENSITY_KB_MM2


def model_area_estimate_cm2(n_params: int, bits_per_weight: float,
                            density_kb_mm2: float = DCIROM_DENSITY_KB_MM2) -> float:
    """Fig. 1(a)-style full-model CiROM area at a given cell density."""
    kb = n_params * bits_per_weight / 1e3
    return kb / density_kb_mm2 / 100.0  # mm^2 -> cm^2


# ---------------------------------------------------------------------------
# System-level per-token energy (the "Update-Free" comparison)
# ---------------------------------------------------------------------------


def token_energy_uj(
    n_active_params: int,
    seq_len: int,
    kv_bytes_per_token: int,
    hot_tokens: int = 32,
    act_bits: int = 4,
    weight_reload: bool = False,
    weight_bits: float = BITS_PER_WEIGHT,
) -> dict:
    """Energy breakdown (uJ) for ONE decode step at context length seq_len."""
    macs = 2.0 * n_active_params  # ops per token
    e_mac = macs * energy_per_op_pj(act_bits)

    e_weights = 0.0
    if weight_reload:  # baseline: stream all weights from DRAM each token
        e_weights = n_active_params * weight_bits * DRAM_PJ_PER_BIT

    hot = min(hot_tokens, seq_len)
    cold = seq_len - hot
    e_kv_ext = cold * kv_bytes_per_token * 8 * DRAM_PJ_PER_BIT
    e_kv_die = hot * kv_bytes_per_token * 8 * EDRAM_PJ_PER_BIT

    total = e_mac + e_weights + e_kv_ext + e_kv_die
    return {
        "mac_uj": e_mac / 1e6,
        "weight_reload_uj": e_weights / 1e6,
        "kv_external_uj": e_kv_ext / 1e6,
        "kv_ondie_uj": e_kv_die / 1e6,
        "total_uj": total / 1e6,
    }


def system_efficiency_gain(n_active_params: int, seq_len: int,
                           kv_bytes_per_token: int, act_bits: int = 4) -> float:
    """BitROM vs weight-reloading accelerator: total-energy ratio (>1)."""
    reload = token_energy_uj(
        n_active_params, seq_len, kv_bytes_per_token,
        hot_tokens=0, act_bits=act_bits, weight_reload=True,
    )["total_uj"]
    bitrom = token_energy_uj(
        n_active_params, seq_len, kv_bytes_per_token,
        hot_tokens=32, act_bits=act_bits, weight_reload=False,
    )["total_uj"]
    return reload / bitrom


# ---------------------------------------------------------------------------
# DR-eDRAM retention: refresh interval vs failure rate
# ---------------------------------------------------------------------------
# The decay-aware eDRAM holds KV state in leaky 1T cells: a cell read
# after its retention time has decayed. Refreshing more often burns
# energy; refreshing less often raises the per-bit failure probability —
# the residual failures are exactly what the serving layer's KV scrub
# (serving/sdc.py, RetentionInjector) detects and repairs. Retention
# times follow an exponential tail model: a cell refreshed every t ms
# fails with p = 1 - exp(-t / tau).

EDRAM_RETENTION_TAU_MS = 100.0  # characteristic retention time, 1T eDRAM
EDRAM_REFRESH_PJ_PER_BIT = EDRAM_PJ_PER_BIT  # refresh = read + restore


def retention_failure_prob(refresh_interval_ms: float,
                           tau_ms: float = EDRAM_RETENTION_TAU_MS) -> float:
    """Per-bit probability of decay within one refresh interval:
    ``p = 1 - exp(-t/tau)``. Monotone increasing in the interval, -> 0
    as the interval -> 0 and -> 1 as it grows past the retention tail."""
    if refresh_interval_ms < 0:
        raise ValueError("refresh interval must be non-negative")
    return 1.0 - math.exp(-refresh_interval_ms / tau_ms)


def refresh_tradeoff(nbytes: int, refresh_interval_ms: float,
                     tau_ms: float = EDRAM_RETENTION_TAU_MS) -> dict:
    """The refresh-power / failure-rate frontier for an eDRAM of
    ``nbytes``: refresh power falls as 1/interval while the expected
    bit failures per interval rise as ``1 - exp(-t/tau)``. The serving
    stack picks a scrub cadence against exactly this residual rate."""
    nbits = nbytes * 8
    p = retention_failure_prob(refresh_interval_ms, tau_ms)
    interval_s = refresh_interval_ms * 1e-3
    # pJ per refresh pass, spread over the interval -> average microwatts
    refresh_uw = (nbits * EDRAM_REFRESH_PJ_PER_BIT / interval_s * 1e-6
                  if interval_s > 0 else float("inf"))
    return {
        "refresh_interval_ms": refresh_interval_ms,
        "p_fail_per_bit": p,
        "expected_bit_failures": nbits * p,
        "refresh_power_uw": refresh_uw,
    }


# ---------------------------------------------------------------------------
# Falcon3-1B deployment (paper §V-B)
# ---------------------------------------------------------------------------


def falcon3_deployment(cfg, seq_len: int = 128, hot_tokens: int = 32,
                       n_batches: int = 6, n_partitions: int = 6) -> dict:
    """The paper's reference deployment, all numbers derived."""
    kv_token = 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2  # bytes / layer
    edram = dr_edram.edram_bytes(
        hot_tokens, cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim, n_batches
    )
    n = cfg.param_count()
    return {
        "n_params": n,
        "macro_partitions": n_partitions,
        "layers_per_partition": cfg.n_layers // n_partitions,
        "pipeline_batches": n_batches,
        "edram_bytes": edram,
        "edram_mib": edram / 2**20,
        "macro_area_mm2_65nm": macro_area_mm2(n),
        "edram_area_cm2_14nm": edram_area_cm2(edram),
        "kv_reduction": dr_edram.closed_form_reduction(seq_len, hot_tokens),
        "kv_bytes_per_token_per_layer": kv_token,
    }
