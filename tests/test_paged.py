"""Paged tiered KV cache + refcounted prefix sharing (ISSUE 6).

Three layers, matching the feature's own:

  * **paging parity** (``kernel_parity`` marked — first step of the CI
    kernels lane): the page-table-indirected cold tier must be
    numerically invisible. Flash decode (plain + fused-RoPE) and flash
    prefill over a ``PagedKVCache`` — identity AND shuffled page tables
    — match the contiguous ``TieredKVCache`` paths; the XLA reference
    functions dispatch paged caches through the same ``as_tiered``
    gather; ``paged_admit``/``save_hot`` round-trip hot snapshots and
    copy-on-write boundary pages bit-exactly.
  * **host control plane**: ``PagePool`` refcounts never go negative and
    a page returns to the free list exactly when its last reader drops
    it; ``PrefixCache`` match/insert/evict honour the leaf-only-LRU and
    never evict a page a live slot still maps.
  * **serving end-to-end** (CPU, XLA gather paths): shared-prefix
    workloads produce bit-exact greedy tokens vs unshared/contiguous
    baselines, store the shared prefix physically once (refcount ledger
    asserted through a recording pool), report
    ``prefix_tokens_reused`` that reconciles with the DR-ledger
    external-read delta, and keep the chunked-admission compile count at
    ONE with paging enabled. The serving-path bugfix sweep rides along:
    decode interleaves with long-prompt chunk streaming, ``generate``
    pads with a sentinel instead of the stop token, and empty prompts
    are rejected at validation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import kv_cache as kvc
from repro.kernels import flash_decode as fd
from repro.kernels import flash_prefill as fp
from repro.kernels import ops
from repro.models import transformer as T
from repro.serving import engine as engine_mod
from repro.serving.engine import PAD_TOKEN, Engine
from repro.serving.paging import PagePool, PagePoolError, PrefixCache
from repro.serving.scheduler import Request

TOL = dict(rtol=2e-5, atol=2e-5)
THETA = 1e4


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _build_pair(b, hot, cold, g, d, lens, ps=8, dtype=jnp.float32, seed=0,
                n_pages=None):
    """Contiguous + paged caches filled with IDENTICAL per-slot content
    via active-masked decode appends (mixed lengths)."""
    cont = kvc.init_cache(b, hot, cold, (g, d), dtype)
    paged = kvc.init_paged_cache(
        b, hot, cold, (g, d), dtype, page_size=ps, n_pages=n_pages
    )
    key = jax.random.PRNGKey(seed)
    for t in range(max(lens)):
        key, k1, k2 = jax.random.split(key, 3)
        kn = jax.random.normal(k1, (b, g, d), jnp.float32).astype(dtype)
        vn = jax.random.normal(k2, (b, g, d), jnp.float32).astype(dtype)
        act = jnp.asarray([t < n for n in lens])
        cont = kvc.append_decode(cont, kn, vn, active=act)
        paged = kvc.append_decode(paged, kn, vn, active=act)
    return cont, paged


def _shuffle_pages(cache: kvc.PagedKVCache, seed=0) -> kvc.PagedKVCache:
    """Re-address the pool through a random page permutation — same
    logical content, maximally non-identity page table."""
    perm = np.asarray(
        jax.random.permutation(jax.random.PRNGKey(seed), cache.n_pages)
    )
    inv = np.argsort(perm)  # new_pool[perm[p]] = old_pool[p]
    return cache._replace(
        pool_k=jnp.asarray(np.asarray(cache.pool_k)[inv]),
        pool_v=jnp.asarray(np.asarray(cache.pool_v)[inv]),
        page_table=jnp.asarray(perm, jnp.int32)[cache.page_table],
    )


def _prompt(seed, n, vocab):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab), np.int32
    )


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class RecordingPool(PagePool):
    """PagePool that tracks the peak reader count per page and the total
    number of page allocations — the test-side refcount ledger."""

    def __init__(self, n_pages):
        super().__init__(n_pages)
        self.peak = np.zeros(n_pages, np.int32)
        self.total_allocs = 0

    def alloc(self, n):
        pages = super().alloc(n)
        if pages is not None:
            self.total_allocs += len(pages)
            for p in pages:
                self.peak[p] = max(self.peak[p], 1)
        return pages

    def incref(self, pages):
        super().incref(pages)
        for p in pages:
            self.peak[p] = max(self.peak[p], self.refs[p])


# ---------------------------------------------------------------------------
# paging parity: kernels + reference paths (CI kernels lane, first step)
# ---------------------------------------------------------------------------

pytestmark_parity = pytest.mark.kernel_parity


@pytest.mark.kernel_parity
def test_paged_append_and_as_tiered_match_contiguous():
    b, hot, cold, g, d = 3, 4, 24, 2, 8
    lens = [2, 9, 23]
    cont, paged = _build_pair(b, hot, cold, g, d, lens)
    np.testing.assert_array_equal(
        np.asarray(cont.lengths), np.asarray(paged.lengths)
    )
    tv = kvc.as_tiered(paged)
    np.testing.assert_array_equal(np.asarray(cont.hot_k), np.asarray(tv.hot_k))
    for s, n in enumerate(lens):
        nc = max(n - hot, 0)
        np.testing.assert_array_equal(
            np.asarray(cont.cold_k[s, :nc]), np.asarray(tv.cold_k[s, :nc])
        )
        np.testing.assert_array_equal(
            np.asarray(cont.cold_v[s, :nc]), np.asarray(tv.cold_v[s, :nc])
        )


@pytest.mark.kernel_parity
def test_paged_bulk_append_valid_matches_contiguous():
    b, hot, cold, g, d, C = 2, 4, 16, 2, 8, 6
    cont, paged = _build_pair(b, hot, cold, g, d, [3, 11])
    key = jax.random.PRNGKey(7)
    kn = jax.random.normal(key, (b, C, g, d), jnp.float32)
    vn = jax.random.normal(jax.random.fold_in(key, 1), (b, C, g, d))
    valid = jnp.asarray([4, 6], jnp.int32)
    cont2 = kvc.append(cont, kn, vn, valid=valid)
    paged2 = kvc.append(paged, kn, vn, valid=valid)
    tv = kvc.as_tiered(paged2)
    np.testing.assert_array_equal(
        np.asarray(cont2.lengths), np.asarray(tv.lengths)
    )
    for s in range(b):
        n = int(cont2.lengths[s])
        nc = max(n - hot, 0)
        np.testing.assert_array_equal(
            np.asarray(cont2.hot_k[s]), np.asarray(tv.hot_k[s])
        )
        np.testing.assert_array_equal(
            np.asarray(cont2.cold_k[s, :nc]), np.asarray(tv.cold_k[s, :nc])
        )


@pytest.mark.kernel_parity
@pytest.mark.parametrize("shuffled", [False, True])
def test_flash_decode_paged_parity(shuffled):
    b, hot, cold, g, d, rep = 3, 4, 24, 2, 16, 2
    lens = [2, 9, 23]
    cont, paged = _build_pair(b, hot, cold, g, d, lens, n_pages=12)
    if shuffled:
        paged = _shuffle_pages(paged, seed=3)
    q = jax.random.normal(jax.random.PRNGKey(5), (b, g * rep, d), jnp.float32)
    o_ref = fd.flash_decode_attention(
        q, cont, impl="pallas", interpret=True, block_s=8
    )
    o_pg = fd.flash_decode_attention(
        q, paged, impl="pallas", interpret=True, block_s=8
    )
    np.testing.assert_allclose(np.asarray(o_pg), np.asarray(o_ref), **TOL)
    # XLA reference dispatches the paged cache through the same gather
    o_xla = fd.flash_decode_attention(q, paged, impl="xla")
    np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_ref), **TOL)


@pytest.mark.kernel_parity
def test_flash_decode_fused_paged_parity():
    """Fused-RoPE decode (pre-append cache, 3 scalar-prefetch operands on
    the paged path) against the contiguous XLA composition."""
    b, hot, cold, g, d, rep = 3, 4, 24, 2, 16, 2
    lens = [1, 7, 20]
    cont, paged = _build_pair(b, hot, cold, g, d, lens, n_pages=12)
    paged = _shuffle_pages(paged, seed=11)
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (b, g * rep, d), jnp.float32)
    kn = jax.random.normal(jax.random.fold_in(key, 1), (b, g, d))
    vn = jax.random.normal(jax.random.fold_in(key, 2), (b, g, d))
    active = jnp.asarray([True, False, True])
    o_ref, krot_ref = fd.flash_decode_attention(
        q, cont, impl="xla", k_new=kn, v_new=vn, active=active,
        rope_theta=THETA,
    )
    o_pg, krot_pg = fd.flash_decode_attention(
        q, paged, impl="pallas", interpret=True, block_s=8,
        k_new=kn, v_new=vn, active=active, rope_theta=THETA,
    )
    np.testing.assert_allclose(np.asarray(o_pg), np.asarray(o_ref), **TOL)
    np.testing.assert_allclose(
        np.asarray(krot_pg), np.asarray(krot_ref), rtol=1e-6, atol=1e-6
    )


@pytest.mark.kernel_parity
@pytest.mark.parametrize("shuffled", [False, True])
def test_flash_prefill_paged_parity(shuffled):
    """Chunked-prefill continuation over a paged cache: o / k_cast /
    v_cast match the contiguous kernel; appending the emitted KV back
    through the paged bulk append reproduces the contiguous cache."""
    b, hot, cold, g, d, rep, C = 3, 4, 24, 2, 16, 2, 6
    lens = [0, 5, 14]
    cont, paged = _build_pair(b, hot, cold, g, d, lens, n_pages=11)
    if shuffled:
        paged = _shuffle_pages(paged, seed=4)
    key = jax.random.PRNGKey(13)
    q = jax.random.normal(key, (b, C, g * rep, d), jnp.float32)
    kn = jax.random.normal(jax.random.fold_in(key, 1), (b, C, g, d))
    vn = jax.random.normal(jax.random.fold_in(key, 2), (b, C, g, d))
    valid = jnp.asarray([6, 3, 5], jnp.int32)
    ref = fp.flash_prefill_attention(
        q, kn, vn, cont, valid, rope_theta=THETA, impl="pallas",
        interpret=True, block_q=4, block_s=8,
    )
    got = fp.flash_prefill_attention(
        q, kn, vn, paged, valid, rope_theta=THETA, impl="pallas",
        interpret=True, block_q=4, block_s=8,
    )
    for r, g_ in zip(ref, got):
        np.testing.assert_allclose(np.asarray(g_), np.asarray(r), **TOL)
    cont2 = kvc.append(cont, ref[1], ref[2], valid=valid)
    paged2 = kvc.append(paged, got[1], got[2], valid=valid)
    tv = kvc.as_tiered(paged2)
    for s in range(b):
        n = int(cont2.lengths[s])
        assert n == int(tv.lengths[s])
        nc = max(n - hot, 0)
        np.testing.assert_allclose(
            np.asarray(cont2.cold_k[s, :nc]), np.asarray(tv.cold_k[s, :nc]),
            rtol=1e-6, atol=1e-6,
        )


@pytest.mark.kernel_parity
def test_xla_chunk_attention_paged_dispatch():
    b, hot, cold, g, d, rep, C = 2, 4, 16, 2, 8, 2, 5
    cont, paged = _build_pair(b, hot, cold, g, d, [6, 13])
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (b, C, g * rep, d), jnp.float32)
    kn = jax.random.normal(jax.random.fold_in(key, 1), (b, C, g, d))
    vn = jax.random.normal(jax.random.fold_in(key, 2), (b, C, g, d))
    valid = jnp.asarray([5, 2], jnp.int32)
    ref = kvc.tiered_chunk_attention(q, kn, vn, cont, valid)
    got = kvc.tiered_chunk_attention(q, kn, vn, paged, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6,
                               atol=1e-6)


@pytest.mark.kernel_parity
def test_save_hot_paged_admit_roundtrip_with_cow():
    """Snapshot slot 1's hot tier, then (re)admit slot 0 with the
    snapshot + slot 1's first cold page shared + a COW boundary copy:
    slot 0's logical rows [0, M) must equal slot 1's bit-exactly, and
    appending into slot 0's COW page must not disturb slot 1's copy."""
    b, hot, cold, g, d, ps = 2, 4, 16, 2, 8, 8
    _, paged = _build_pair(b, hot, cold, g, d, [0, 14], ps=ps, n_pages=8)
    # slot 1 owns pool pages (per the identity table) 2, 3; snapshot its
    # hot tier into spare page 6
    paged = kvc.save_hot(paged, jnp.int32(1), jnp.asarray([6], jnp.int32))
    M = 13  # hot 4 + full page 8 + 1 boundary row
    reset = jnp.asarray([True, False])
    new_table = jnp.asarray([[2, 5], [2, 3]], jnp.int32)  # share page 2
    state = kvc.paged_admit(
        paged, reset,
        jnp.asarray([M, 0], jnp.int32), new_table,
        jnp.asarray([[6], [-1]], jnp.int32),  # hot restore from page 6
        jnp.asarray([3, -1], jnp.int32),  # COW: copy slot 1's page 3 ...
        jnp.asarray([5, -1], jnp.int32),  # ... into fresh page 5
    )
    assert int(state.lengths[0]) == M
    tv = kvc.as_tiered(state)
    np.testing.assert_array_equal(
        np.asarray(tv.hot_k[0]), np.asarray(tv.hot_k[1])
    )
    np.testing.assert_array_equal(
        np.asarray(tv.cold_k[0, : M - hot]),
        np.asarray(tv.cold_k[1, : M - hot]),
    )
    # slot 0 appends past the boundary into its COW copy; slot 1's page
    # must be untouched (copy-on-write, not aliasing)
    before = np.asarray(state.pool_k[3]).copy()
    kn = jnp.ones((b, g, d), jnp.float32)
    state = kvc.append_decode(
        state, kn, kn, active=jnp.asarray([True, False])
    )
    np.testing.assert_array_equal(np.asarray(state.pool_k[3]), before)
    row = (M - hot) % ps  # boundary row just written in slot 0's page 5
    np.testing.assert_array_equal(
        np.asarray(state.pool_k[5, row]), np.ones((g, d), np.float32)
    )


@pytest.mark.kernel_parity
def test_default_page_size_is_decode_s_block():
    for rep, d, cap in [(4, 128, 544), (2, 64, 96), (8, 128, 4096)]:
        expect = ops.select_blocks(rep, d, cap, "pack2", kind="decode_attn")[2]
        assert ops.default_page_size(rep, d, cap) == expect


# ---------------------------------------------------------------------------
# host control plane: PagePool / PrefixCache invariants
# ---------------------------------------------------------------------------


def test_pagepool_refcount_lifecycle():
    pool = PagePool(4)
    a = pool.alloc(2)
    assert len(a) == 2 and pool.available() == 2 and pool.used() == 2
    pool.incref(a)  # second reader
    pool.decref(a)  # first reader leaves: pages still live
    assert pool.available() == 2
    assert all(pool.refs[p] == 1 for p in a)
    pool.decref(a)  # last reader leaves: freed exactly now
    assert pool.available() == 4
    assert all(pool.refs[p] == 0 for p in a)
    # over-alloc refuses rather than corrupting
    assert pool.alloc(5) is None
    # refcounts never go negative: double-free raises the typed error
    # (with page context), and keeps doing so under `python -O`
    b = pool.alloc(1)
    pool.decref(b)
    with pytest.raises(PagePoolError, match="decref on free page"):
        pool.decref(b)
    with pytest.raises(PagePoolError, match="incref on free page"):
        pool.incref(b)  # incref on a free page is a bug too
    try:
        pool.decref(b)
    except PagePoolError as e:
        assert e.page == b[0] and e.refcount == 0


def test_prefix_cache_match_insert_roundtrip():
    hc, ps = 4, 4
    pool = PagePool(16)
    tree = PrefixCache(pool, hot_cap=hc, page_size=ps)
    toks = np.arange(100, 115, dtype=np.int32)  # 15 tokens: hot 4 + 2 runs + 3
    slot_pages = pool.alloc(3)  # the serving slot's cold pages
    saved = []
    assert tree.match(toks).length == 0  # empty tree: miss
    assert tree.insert(toks, slot_pages, saved.extend)
    assert len(saved) == 1  # one hot-snapshot page (hc <= ps)
    # full re-match caps at len - 1 (the last token must be prefilled)
    m = tree.match(toks)
    assert m.length == hc + 2 * ps  # 12: hot + both full runs; tail stays
    assert m.shared_pages == (slot_pages[0], slot_pages[1])
    assert m.cow_src == -1
    # an extended prompt matches everything the tree holds
    ext = np.concatenate([toks[:12], np.asarray([7, 8, 9, 10], np.int32)])
    m2 = tree.match(ext)
    assert m2.length == 12 and m2.shared_pages == m.shared_pages
    # divergence inside the second run: COW on the partial boundary
    div = toks.copy()
    div[10] = 999
    m3 = tree.match(div)
    assert m3.length == hc + ps + 2  # hot + run 1 + 2 boundary rows
    assert m3.shared_pages == (slot_pages[0],)
    assert m3.cow_src == slot_pages[1] and m3.cow_len == 2
    # different hot prefix: miss (hot nodes are keyed by the full hc run)
    other = toks.copy()
    other[1] = 999
    assert tree.match(other).length == 0
    # adopted pages gained the tree as a second reader
    assert all(pool.refs[p] == 2 for p in slot_pages[:2])
    assert pool.refs[slot_pages[2]] == 1  # partial tail stays slot-private


def test_prefix_cache_insert_dedup_keeps_one_copy():
    hc, ps = 2, 2
    pool = PagePool(12)
    tree = PrefixCache(pool, hot_cap=hc, page_size=ps)
    toks = np.asarray([1, 2, 3, 4, 5, 6], np.int32)
    first = pool.alloc(2)
    assert tree.insert(toks, first, lambda ids: None)
    # a second slot that served the same prompt re-inserts: the tree
    # keeps its existing nodes and adopts nothing new
    second = pool.alloc(2)
    assert tree.insert(toks, second, lambda ids: None)
    assert all(pool.refs[p] == 2 for p in first)
    assert all(pool.refs[p] == 1 for p in second)  # slot-private only


def test_prefix_cache_eviction_is_leaf_only_lru_and_respects_readers():
    hc, ps = 2, 2
    pool = PagePool(8)
    tree = PrefixCache(pool, hot_cap=hc, page_size=ps)
    a = np.asarray([1, 2, 3, 4, 5, 6], np.int32)  # hot + 2 runs
    pa = pool.alloc(2)
    assert tree.insert(a, pa, lambda ids: None)
    # pool now: 2 slot pages (ref 2 via tree) + 1 hot page = free 5
    b = np.asarray([9, 9, 7, 7], np.int32)  # different hot prefix + 1 run
    pb = pool.alloc(1)
    assert tree.insert(b, pb, lambda ids: None)
    assert pool.available() == 3
    # a live slot still reads pa/pb (ref 2): eviction may only reclaim
    # the two hot-snapshot pages (ref 1, childless once leaves peel)
    assert not tree.evict_for(8)  # impossible: live readers pin 3 pages
    # drop slot a's refs: its chain (2 pages) becomes evictable leaf-first
    pool.decref(pa)
    tree.match(b)  # touch b: a's chain is now strictly older (LRU)
    assert tree.evict_for(6)
    assert pool.available() >= 6
    # b's pages survived — a slot still reads pb
    assert pool.refs[pb[0]] == 2


# ---------------------------------------------------------------------------
# serving end-to-end: shared prefixes, COW, ledger reconciliation, bugfixes
# ---------------------------------------------------------------------------


def _mk_reqs(reqs):
    return [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs]


def test_paged_serving_shared_prefix_end_to_end(setup, monkeypatch):
    """The acceptance scenario: N requests sharing one prompt prefix.
    Greedy tokens bit-exact vs the unshared-paged AND contiguous-chunked
    baselines; the prefix is stored physically once (refcount ledger);
    ``prefix_tokens_reused`` reconciles with the DR external-read delta;
    chunked admission still compiles exactly once with paging enabled."""
    cfg, params = setup
    monkeypatch.setattr(engine_mod, "PagePool", RecordingPool)
    hot, ml, ps = 4, 64, 8
    shared = _prompt(1, 21, cfg.vocab_size)
    reqs = [
        Request(i, np.concatenate([shared, _prompt(10 + i, 5, cfg.vocab_size)]), 6)
        for i in range(3)
    ]
    reqs.append(Request(3, _prompt(99, 7, cfg.vocab_size), 5))  # unrelated
    eng = Engine(cfg, params, hot_cap=hot, max_len=ml, prefill_chunk=4,
                 paged=True, page_size=ps, slots=1)
    fin = {f.rid: f for f in eng.serve(_mk_reqs(reqs), slots=1, sync_every=3)}
    assert set(fin) == {0, 1, 2, 3}
    # satellite: ONE chunk-dispatch compile and ONE admit compile with
    # paging enabled, regardless of the length/match mix
    assert eng._chunk_step_fn._cache_size() == 1
    assert eng._paged_admit_fn._cache_size() == 1
    # rid 0 populated the tree; 1 and 2 reused hot 4 + 2 full pages = 20
    # tokens of the 21-token shared prefix; rid 3 shares nothing
    assert fin[0].prefix_tokens_reused == 0
    assert fin[1].prefix_tokens_reused == 20
    assert fin[2].prefix_tokens_reused == 20
    assert fin[3].prefix_tokens_reused == 0
    pool, tree = eng._last_pool, eng._last_ptree
    # ONE physical copy: the shared cold pages were simultaneously read
    # by the tree and a live slot (peak refcount 2), never duplicated —
    # rid 1/2 allocated only their novel-suffix + budget pages
    tree_pages = set(tree.tree_pages())
    assert any(pool.peak[p] >= 2 for p in tree_pages)
    # every slot retired: tree is the only reader left, and every
    # non-tree page is back on the free list (freed exactly when its
    # last reader left — the never-negative half is asserted in decref)
    for p in range(pool.n_pages):
        if p in tree_pages:
            assert pool.refs[p] == 1
        else:
            assert pool.refs[p] == 0
    assert pool.available() == pool.n_pages - len(tree_pages)
    # tokens bit-exact vs paged-without-sharing and contiguous-chunked
    eng_n = Engine(cfg, params, hot_cap=hot, max_len=ml, prefill_chunk=4,
                   paged=True, page_size=ps, slots=1, prefix_sharing=False)
    fin_n = {f.rid: f for f in eng_n.serve(_mk_reqs(reqs), slots=1,
                                           sync_every=3)}
    eng_c = Engine(cfg, params, hot_cap=hot, max_len=ml, prefill_chunk=4,
                   slots=1)
    fin_c = {f.rid: f for f in eng_c.serve(_mk_reqs(reqs), slots=1,
                                           sync_every=3)}
    tb = eng._kv_token_bytes()
    for r in reqs:
        np.testing.assert_array_equal(fin[r.rid].tokens, fin_n[r.rid].tokens)
        np.testing.assert_array_equal(fin[r.rid].tokens, fin_c[r.rid].tokens)
        assert fin_n[r.rid].prefix_tokens_reused == 0
        # the external reads the shared run skipped reconcile exactly
        # with the reuse count through the closed-form resumed ledger
        M = fin[r.rid].prefix_tokens_reused
        full = kvc.prompt_traffic_tokens(r.prompt_len, hot)
        res = kvc.prompt_traffic_tokens_resumed(r.prompt_len, M, hot)
        for k in kvc.TRAFFIC_KEYS:
            assert (fin_n[r.rid].traffic[k] - fin[r.rid].traffic[k]
                    == (full[k] - res[k]) * tb), (r.rid, k)


def test_paged_serving_cow_divergent_prompts(setup):
    """Two prompts diverging inside a cold page: the second adopts the
    boundary page copy-on-write and still decodes bit-exactly."""
    cfg, params = setup
    hot, ml, ps = 4, 64, 8
    base = _prompt(2, 26, cfg.vocab_size)  # hot 4 + 2 full pages + tail
    div = base.copy()
    div[15] = (int(div[15]) + 1) % cfg.vocab_size  # diverge inside run 2
    reqs = [Request(0, base, 6), Request(1, div, 6)]
    eng = Engine(cfg, params, hot_cap=hot, max_len=ml, prefill_chunk=4,
                 paged=True, page_size=ps, slots=1)
    fin = {f.rid: f for f in eng.serve(_mk_reqs(reqs), slots=1)}
    # matched: hot 4 + full run [4:12) + 3 boundary rows of run [12:20)
    assert fin[0].prefix_tokens_reused == 0
    assert fin[1].prefix_tokens_reused == hot + ps + 3
    for r in reqs:
        solo = eng.serve([Request(9, r.tokens, r.max_new_tokens)], slots=1)[0]
        np.testing.assert_array_equal(fin[r.rid].tokens, solo.tokens)


def test_paged_matches_grouped_admission_tokens(setup):
    """Paged chunked serving == the legacy grouped-admission engine."""
    cfg, params = setup
    reqs = [
        Request(0, _prompt(40, 5, cfg.vocab_size), 9),
        Request(1, _prompt(41, 12, cfg.vocab_size), 3),
        Request(2, _prompt(42, 1, cfg.vocab_size), 5),
    ]
    eng_p = Engine(cfg, params, hot_cap=4, max_len=64, prefill_chunk=4,
                   paged=True, page_size=8)
    fin_p = {f.rid: f for f in eng_p.serve(_mk_reqs(reqs), slots=2,
                                           sync_every=3)}
    eng_g = Engine(cfg, params, hot_cap=4, max_len=64)
    fin_g = {f.rid: f for f in eng_g.serve(_mk_reqs(reqs), slots=2,
                                           sync_every=3)}
    for r in reqs:
        np.testing.assert_array_equal(fin_p[r.rid].tokens, fin_g[r.rid].tokens)
        assert len(fin_p[r.rid].tokens) == r.max_new_tokens


def test_paged_engine_validates_construction(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="chunked prefill"):
        Engine(cfg, params, hot_cap=4, max_len=64, paged=True)
    with pytest.raises(ValueError, match="cold tier"):
        Engine(cfg, params, hot_cap=64, max_len=64, prefill_chunk=4,
               paged=True)


# ---------------------------------------------------------------------------
# serving-path bugfix sweep (satellites)
# ---------------------------------------------------------------------------


def test_decode_interleaves_with_long_prompt_streaming(setup):
    """Regression (chunked admission stall): while a long prompt streams
    in, already-active slots must keep emitting — chunk waves and decode
    dispatches interleave instead of the old drain-everything loop."""
    cfg, params = setup
    eng = Engine(cfg, params, hot_cap=4, max_len=64, prefill_chunk=2)
    events = []
    real_chunk = eng._get_chunk_step()
    real_step = eng._get_step(eng.max_len, None)
    eng._chunk_step_fn = lambda *a, **k: (
        events.append("chunk"), real_chunk(*a, **k))[1]
    eng._step_fns[(eng.max_len, None)] = lambda *a, **k: (
        events.append("decode"), real_step(*a, **k))[1]
    reqs = [
        Request(0, _prompt(50, 3, cfg.vocab_size), 12),  # short, decodes early
        Request(1, _prompt(51, 24, cfg.vocab_size), 2),  # 12 chunk waves
    ]
    fin = {f.rid: f for f in eng.serve(_mk_reqs(reqs), slots=2, sync_every=2)}
    assert len(fin[0].tokens) == 12 and len(fin[1].tokens) == 2
    # decode dispatches happen BEFORE the long prompt finishes streaming
    assert "decode" in events
    first_decode = events.index("decode")
    last_chunk = len(events) - 1 - events[::-1].index("chunk")
    assert first_decode < last_chunk, events
    # and the interleaved run is still bit-exact vs solo serves
    for r in reqs:
        solo = eng.serve([Request(9, r.tokens, r.max_new_tokens)], slots=1)[0]
        np.testing.assert_array_equal(fin[r.rid].tokens, solo.tokens)


def test_generate_pads_with_sentinel_not_stop_token(setup):
    """Regression: rows that stop early are padded with PAD_TOKEN, never
    the stop token itself — a stop token the model actually emitted
    remains distinguishable from padding, and per-row step counts are
    exposed."""
    cfg, params = setup
    eng = Engine(cfg, params, hot_cap=4, max_len=64)
    prompts = jnp.stack([
        jnp.asarray(_prompt(60, 6, cfg.vocab_size)),
        jnp.asarray(_prompt(61, 6, cfg.vocab_size)),
    ])
    probe = eng.generate(prompts, max_new_tokens=12)
    # stop at row 0's third greedy token: row 0 retires after 2 emits
    stop = int(probe.tokens[0, 2])
    res = eng.generate(prompts, max_new_tokens=12, stop_token=stop)
    toks = np.asarray(res.tokens)
    assert res.steps_per_row is not None
    n0 = res.steps_per_row[0]
    assert n0 <= 2
    # emitted region survives the round trip; padding is the sentinel
    np.testing.assert_array_equal(toks[0, :n0], np.asarray(probe.tokens)[0, :n0])
    assert (toks[0, n0:] == PAD_TOKEN).all()
    assert stop not in toks[0, n0:]
    # an un-stopped row is full length and unpadded
    if res.steps_per_row[1] == 12:
        assert (toks[1] != PAD_TOKEN).all()
    assert res.steps == max(res.steps_per_row)


def test_empty_prompt_rejected_at_validation(setup):
    cfg, params = setup
    empty = Request(0, np.zeros((0,), np.int32), 4)
    for kw in (dict(), dict(prefill_chunk=4),
               dict(prefill_chunk=4, paged=True, page_size=8)):
        eng = Engine(cfg, params, hot_cap=4, max_len=64, **kw)
        with pytest.raises(ValueError, match="empty prompt"):
            eng.serve([Request(0, empty.tokens, 4)], slots=1)
