"""Continuous-batching serving: slot scheduler, mixed-length exactness,
retirement/re-admission, and per-slot DR-traffic reconciliation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import dr_edram
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.scheduler import Request, SchedulerError, SlotScheduler


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(seed, n, vocab):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab), np.int32
    )


# ---------------------------------------------------------------------------
# host-side scheduler unit tests
# ---------------------------------------------------------------------------


def test_scheduler_fifo_same_length_grouping():
    sched = SlotScheduler(n_slots=3)
    for rid, p_len in [(0, 4), (1, 4), (2, 7), (3, 4), (4, 7)]:
        sched.submit(Request(rid, np.zeros(p_len, np.int32), 8))
    slots, group = sched.next_group()
    # head-of-line p_len=4 group admits first, rides along rid 1 and 3
    assert [r.rid for r in group] == [0, 1, 3]
    assert slots == [0, 1, 2]
    # nothing free -> nothing admitted, queue preserved in order
    assert sched.next_group() == ([], [])
    assert [r.rid for r in sched.queue] == [2, 4]
    sched.retire(1)
    slots, group = sched.next_group()
    assert [r.rid for r in group] == [2] and slots == [1]


def test_scheduler_groups_split_on_patches():
    """Same prompt length but different frontend features (VLM patches
    present/absent) must not share a prefill dispatch."""
    sched = SlotScheduler(n_slots=4)
    img = np.zeros((8, 32), np.float32)
    sched.submit(Request(0, np.zeros(4, np.int32), 8))
    sched.submit(Request(1, np.zeros(4, np.int32), 8, patches=img))
    sched.submit(Request(2, np.zeros(4, np.int32), 8))
    slots, group = sched.next_group()
    assert [r.rid for r in group] == [0, 2]
    slots2, group2 = sched.next_group()
    assert [r.rid for r in group2] == [1] and group2[0].patches is not None


def test_scheduler_retire_and_idle():
    sched = SlotScheduler(2)
    sched.submit(Request(0, np.zeros(3, np.int32), 4))
    slots, group = sched.next_group()
    assert not sched.idle()
    req = sched.retire(slots[0])
    assert req.rid == 0 and sched.idle()


def test_scheduler_fifo_preserved_across_requeue():
    """``next_group`` pops head-of-line key matches and requeues the
    rest; repeated admission rounds must never reorder the skipped
    requests relative to each other OR to later submissions."""
    sched = SlotScheduler(n_slots=1)
    lens = [4, 7, 4, 9, 7, 4, 9]
    for rid, p_len in enumerate(lens):
        sched.submit(Request(rid, np.zeros(p_len, np.int32), 2))
    admitted = []
    while not sched.idle():
        slots, group = sched.next_group()
        assert len(group) == 1  # one free slot -> singleton groups
        admitted.append(group[0].rid)
        # skipped requests stayed in submission order after the requeue
        qr = [r.rid for r in sched.queue]
        assert qr == sorted(qr)
        sched.retire(slots[0])
    # with singleton groups the requeue shuffle must collapse to pure FIFO
    assert admitted == list(range(len(lens)))
    # and a late submission lands behind requeued survivors, not ahead
    sched.submit(Request(10, np.zeros(7, np.int32), 2))
    sched.submit(Request(11, np.zeros(4, np.int32), 2))
    _, g = sched.next_group()
    assert g[0].rid == 10
    sched.retire(0)
    _, g = sched.next_group()
    assert g[0].rid == 11


def test_scheduler_slot_reuse_mixed_patches_shapes():
    """Retire/readmit churn with heterogeneous frontend-feature shapes:
    freed slots are reused lowest-first, no group ever mixes patch
    shapes, and every request is admitted exactly once."""
    sched = SlotScheduler(n_slots=2)
    shapes = [None, (4, 8), None, (2, 8), (4, 8), None]
    for rid, shp in enumerate(shapes):
        patches = None if shp is None else np.zeros(shp, np.float32)
        sched.submit(Request(rid, np.zeros(5, np.int32), 3, patches=patches))
    seen = []
    held = []  # slots kept occupied across admission rounds
    while not sched.idle():
        slots, group = sched.next_group()
        if group:
            assert slots == sorted(slots)  # freed slots reused lowest-first
            keys = {
                None if r.patches is None else np.asarray(r.patches).shape
                for r in group
            }
            assert len(keys) <= 1, "a group mixed patch shapes"
            seen.extend(r.rid for r in group)
            for s, r in zip(slots, group):
                assert sched.slot_req[s] is r
            # retire only the first admitted slot; the rest stay occupied
            # a while, so admission must work off partially-free tables
            sched.retire(slots[0])
            held.extend(slots[1:])
        else:
            assert held, "scheduler stuck: nothing admitted, nothing held"
            sched.retire(held.pop(0))
    assert sorted(seen) == list(range(len(shapes)))
    assert seen.index(0) < seen.index(2) < seen.index(5)  # FIFO per key
    assert seen.index(1) < seen.index(4)


def test_scheduler_slot_misuse_raises_typed_error():
    """Retiring/requeueing an unoccupied slot is a protocol bug: a typed
    SchedulerError carrying the slot index, not a bare assert (it must
    survive ``python -O``)."""
    sched = SlotScheduler(2)
    with pytest.raises(SchedulerError, match="retiring free slot"):
        sched.retire(1)
    with pytest.raises(SchedulerError, match="requeueing free slot"):
        sched.requeue(0)
    try:
        sched.retire(1)
    except SchedulerError as e:
        assert e.slot == 1


def test_scheduler_bounded_queue_sheds():
    sched = SlotScheduler(1, max_queue=2)
    assert sched.submit(Request(0, np.zeros(3, np.int32), 2))
    assert sched.submit(Request(1, np.zeros(3, np.int32), 2))
    assert not sched.submit(Request(2, np.zeros(3, np.int32), 2))  # shed
    assert [r.rid for r in sched.queue] == [0, 1]
    # a requeue (preemption path) bypasses the bound — the request was
    # already admitted once; shedding it now would break the contract
    sched.next_fills()
    assert sched.submit(Request(3, np.zeros(3, np.int32), 2))
    sched.requeue(0)
    assert len(sched.queue) == 3  # over the bound, by design


def test_scheduler_claim_ordering_priorities():
    """Admission is by claim (priority desc, arrival asc): a later
    high-priority submission jumps the queue; ties stay FIFO."""
    sched = SlotScheduler(2)
    sched.submit(Request(0, np.zeros(3, np.int32), 2))
    sched.submit(Request(1, np.zeros(3, np.int32), 2))
    sched.submit(Request(2, np.zeros(3, np.int32), 2, priority=5))
    fills = sched.next_fills()
    assert [r.rid for _, r in fills] == [2, 0]
    # grouped admission honours the same order: the strongest head picks
    # its shape group
    sched2 = SlotScheduler(2)
    sched2.submit(Request(0, np.zeros(3, np.int32), 2))
    sched2.submit(Request(1, np.zeros(7, np.int32), 2, priority=1))
    sched2.submit(Request(2, np.zeros(7, np.int32), 2, priority=1))
    _, group = sched2.next_group()
    assert [r.rid for r in group] == [1, 2]


def test_scheduler_preempt_victims_policy():
    """Victims must hold a strictly weaker claim than the beneficiary;
    among them, fewest-tokens-emitted first, newest arrival tie-break.
    The strongest claim in the system is never a victim — the liveness
    anchor of preemption."""
    sched = SlotScheduler(3)
    for rid, prio in [(0, 0), (1, 0), (2, 3)]:
        sched.submit(Request(rid, np.zeros(4, np.int32), 8, priority=prio))
    fills = dict((r.rid, s) for s, r in sched.next_fills())
    late = Request(9, np.zeros(4, np.int32), 8, priority=1)
    sched.submit(late)
    emitted = {fills[0]: 5, fills[1]: 2, fills[2]: 0}
    # rid 2 (priority 3) outranks the beneficiary (priority 1): only the
    # two priority-0 slots are eligible, fewest-emitted (rid 1) first
    victims = sched.preempt_victims(late, emitted)
    assert victims == [fills[1], fills[0]]
    # equal emission counts: newest arrival evicts first
    victims = sched.preempt_victims(late, {})
    assert victims == [fills[1], fills[0]]
    # a FIFO peer (equal priority, earlier arrival) cannot be preempted
    # by a later arrival ...
    peer = Request(10, np.zeros(4, np.int32), 8)
    sched.submit(peer)
    assert sched.preempt_victims(peer, {}) == []
    # ... and exclusions (the beneficiary's own slot at growth) hold
    assert sched.preempt_victims(late, {}, exclude=victims) == []


def test_scheduler_requeue_keeps_arrival_claim():
    """A preempted request re-enters the queue with its ORIGINAL arrival
    stamp, so it outranks everything submitted after it — preemption
    defers work, it never demotes it."""
    sched = SlotScheduler(1)
    sched.submit(Request(0, np.zeros(3, np.int32), 2))
    [(s, first)] = sched.next_fills()
    sched.submit(Request(1, np.zeros(3, np.int32), 2))
    back = sched.requeue(s)
    assert back is first and back.arrival == 0
    assert [r.rid for _, r in sched.next_fills()] == [0]


# ---------------------------------------------------------------------------
# mixed-length exactness at the model level: decode logits per slot must be
# bit-exact vs a single-sequence (batch=1) reference at the same state
# ---------------------------------------------------------------------------


def test_mixed_length_decode_logits_bit_exact(setup):
    cfg, params = setup
    eng = Engine(cfg, params, hot_cap=4, max_len=48)
    lens = [3, 11, 7]
    prompts = [_prompt(10 + i, L, cfg.vocab_size) for i, L in enumerate(lens)]
    # mixed batch: admit the three prompts one by one (admission groups
    # share a prompt length, so unequal lengths arrive in separate groups)
    state = eng._init_state(3, out_cap=4)
    for i, p in enumerate(prompts):
        state = eng._admit(state, [i], [Request(i, p, 4)])
    logits_mix, _ = T.decode_step(
        eng.params, cfg, state.tok, state.cache, mode=eng.mode,
        active=jnp.ones((3,), bool),
    )
    # solo references: same prompt alone in a 1-slot state
    for i, p in enumerate(prompts):
        solo = eng._init_state(1, out_cap=4)
        solo = eng._admit(solo, [0], [Request(0, p, 4)])
        assert int(solo.tok[0]) == int(state.tok[i])  # greedy first token
        logits_solo, _ = T.decode_step(
            eng.params, cfg, solo.tok, solo.cache, mode=eng.mode,
            active=jnp.ones((1,), bool),
        )
        np.testing.assert_array_equal(
            np.asarray(logits_mix[i]), np.asarray(logits_solo[0])
        )


def test_continuous_tokens_match_solo_serving(setup):
    """End-to-end: tokens from a crowded mixed-length serve == solo runs."""
    cfg, params = setup
    eng = Engine(cfg, params, hot_cap=4, max_len=64)
    reqs = [
        Request(0, _prompt(20, 5, cfg.vocab_size), 9),
        Request(1, _prompt(21, 12, cfg.vocab_size), 3),
        Request(2, _prompt(22, 5, cfg.vocab_size), 6),
        Request(3, _prompt(23, 8, cfg.vocab_size), 11),
        Request(4, _prompt(24, 12, cfg.vocab_size), 5),
    ]
    fin = {f.rid: f for f in eng.serve(reqs, slots=2, sync_every=3)}
    assert set(fin) == {0, 1, 2, 3, 4}
    for r in reqs:
        solo = eng.serve([Request(99, r.tokens, r.max_new_tokens)], slots=1)[0]
        np.testing.assert_array_equal(fin[r.rid].tokens, solo.tokens)
        assert len(fin[r.rid].tokens) == r.max_new_tokens


def test_slot_retirement_readmission_roundtrip(setup):
    """A slot that served a long request is reused by a later one with a
    different length; the recycled slot must behave like a fresh one."""
    cfg, params = setup
    eng = Engine(cfg, params, hot_cap=4, max_len=64)
    a = Request(0, _prompt(30, 10, cfg.vocab_size), 4)
    b = Request(1, _prompt(31, 6, cfg.vocab_size), 8)  # admitted after a retires
    fin = {f.rid: f for f in eng.serve([a, b], slots=1, sync_every=2)}
    solo_b = eng.serve([Request(9, b.tokens, b.max_new_tokens)], slots=1)[0]
    np.testing.assert_array_equal(fin[1].tokens, solo_b.tokens)
    assert fin[1].seq_len == 6 + 8


def test_stop_token_retires_slot_on_device(setup):
    """Stop handling is a device-side done mask: a stopped slot emits no
    further tokens while other slots keep decoding to their budget."""
    cfg, params = setup
    eng = Engine(cfg, params, hot_cap=4, max_len=64)
    reqs = [
        Request(0, _prompt(40, 6, cfg.vocab_size), 16),
        Request(1, _prompt(41, 6, cfg.vocab_size), 16),
    ]
    # pick a stop token we know appears early for rid 0: use its 3rd token
    probe = eng.serve([Request(9, reqs[0].tokens, 16)], slots=1)[0]
    stop = int(probe.tokens[2])
    fin = {f.rid: f for f in eng.serve(reqs, slots=2, stop_token=stop)}
    assert len(fin[0].tokens) <= 3  # stopped early (stop token not emitted)
    # the other slot is unaffected unless it also samples the stop token
    solo1 = eng.serve([Request(9, reqs[1].tokens, 16, )], slots=1,
                      stop_token=stop)[0]
    np.testing.assert_array_equal(fin[1].tokens, solo1.tokens)


# ---------------------------------------------------------------------------
# per-slot DR-traffic ledger reconciles with the closed form, per sequence,
# in mixed-length batches (the lock-step seed only asserted aligned batches)
# ---------------------------------------------------------------------------


def test_per_slot_traffic_reconciles_mixed_lengths(setup):
    cfg, params = setup
    hot = 6
    eng = Engine(cfg, params, hot_cap=hot, max_len=96)
    reqs = [
        Request(0, _prompt(50, 4, cfg.vocab_size), 20),
        Request(1, _prompt(51, 16, cfg.vocab_size), 8),
        Request(2, _prompt(52, 9, cfg.vocab_size), 30),
        Request(3, _prompt(53, 2, cfg.vocab_size), 3),
    ]
    fin = eng.serve(reqs, slots=3, sync_every=5)
    assert len(fin) == len(reqs)
    for f in fin:
        assert f.seq_len == f.prompt_len + f.steps
        expect = dr_edram.closed_form_reduction(f.seq_len, hot)
        assert f.external_reduction == pytest.approx(expect, abs=1e-12), f.rid
        # and the raw ledger matches the exact counting simulator
        sim = dr_edram.simulate(f.seq_len, hot)
        tb = eng._kv_token_bytes()
        assert f.traffic["ext_read"] == sim.ext_reads * tb
        assert f.traffic["ext_write"] == sim.ext_writes * tb
        assert f.traffic["ondie_read"] == sim.die_reads * tb
        assert f.traffic["ondie_write"] == sim.die_writes * tb


def test_scheduler_next_fills_ungrouped_fifo():
    """Chunked admission pairs free slots with queued requests in strict
    FIFO order — mixed prompt lengths admit together, nothing waits for
    a same-length partner."""
    sched = SlotScheduler(n_slots=3)
    for rid, p_len in [(0, 4), (1, 9), (2, 4), (3, 7)]:
        sched.submit(Request(rid, np.zeros(p_len, np.int32), 8))
    fills = sched.next_fills()
    assert [(s, r.rid) for s, r in fills] == [(0, 0), (1, 1), (2, 2)]
    assert [r.rid for r in sched.queue] == [3]
    assert sched.next_fills() == []  # no free slots
    sched.retire(1)
    fills = sched.next_fills()
    assert [(s, r.rid) for s, r in fills] == [(1, 3)]


# ---------------------------------------------------------------------------
# chunked-prefill admission: mixed lengths, ONE prefill compilation,
# token parity with grouped admission and with solo serves, ledger intact
# ---------------------------------------------------------------------------


def test_chunked_prefill_end_to_end(setup):
    """Mixed-length prompts through a prefill_chunk engine: tokens match
    solo chunked serves bit-exactly AND the grouped-admission engine;
    exactly ONE chunk-step compilation serves every length; per-slot DR
    ledgers still reconcile with the closed form."""
    cfg, params = setup
    hot = 4
    eng = Engine(cfg, params, hot_cap=hot, max_len=64, prefill_chunk=4)
    reqs = [
        Request(0, _prompt(70, 5, cfg.vocab_size), 9),
        Request(1, _prompt(71, 12, cfg.vocab_size), 3),
        Request(2, _prompt(72, 4, cfg.vocab_size), 6),   # == chunk size
        Request(3, _prompt(73, 13, cfg.vocab_size), 8),  # prime length
        Request(4, _prompt(74, 1, cfg.vocab_size), 5),   # sub-chunk
    ]
    fin = {f.rid: f for f in eng.serve(
        [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs],
        slots=2, sync_every=3,
    )}
    assert set(fin) == {0, 1, 2, 3, 4}
    # one compile for the chunk dispatch, regardless of the length mix
    assert eng._chunk_step_fn._cache_size() == 1
    # solo chunked serves reproduce the crowded run bit-exactly
    for r in reqs:
        solo = eng.serve([Request(99, r.tokens, r.max_new_tokens)], slots=1)[0]
        np.testing.assert_array_equal(fin[r.rid].tokens, solo.tokens)
        assert len(fin[r.rid].tokens) == r.max_new_tokens
    # the solo serves ran at slots=1 — a different dispatch width, hence
    # one more compile; prompt lengths never add any (5 lengths, 2 shapes)
    assert eng._chunk_step_fn._cache_size() == 2
    # grouped-admission engine produces the same greedy tokens
    eng_g = Engine(cfg, params, hot_cap=hot, max_len=64)
    fin_g = {f.rid: f for f in eng_g.serve(
        [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs],
        slots=2, sync_every=3,
    )}
    for r in reqs:
        np.testing.assert_array_equal(fin[r.rid].tokens, fin_g[r.rid].tokens)
    # DR-ledger reconciliation is untouched by chunked admission
    for f in fin.values():
        assert f.seq_len == f.prompt_len + f.steps
        expect = dr_edram.closed_form_reduction(f.seq_len, hot)
        assert f.external_reduction == pytest.approx(expect, abs=1e-12), f.rid
        sim = dr_edram.simulate(f.seq_len, hot)
        tb = eng._kv_token_bytes()
        assert f.traffic["ext_read"] == sim.ext_reads * tb
        assert f.traffic["ondie_read"] == sim.die_reads * tb


def test_chunked_prefill_slot_reuse(setup):
    """A slot freed mid-serve is re-admitted with a *different* prompt
    length via chunk streaming; the recycled slot behaves like fresh."""
    cfg, params = setup
    eng = Engine(cfg, params, hot_cap=4, max_len=64, prefill_chunk=4)
    a = Request(0, _prompt(80, 10, cfg.vocab_size), 4)
    b = Request(1, _prompt(81, 7, cfg.vocab_size), 8)
    fin = {f.rid: f for f in eng.serve([a, b], slots=1, sync_every=2)}
    solo_b = eng.serve([Request(9, b.tokens, b.max_new_tokens)], slots=1)[0]
    np.testing.assert_array_equal(fin[1].tokens, solo_b.tokens)
    assert fin[1].seq_len == 7 + 8
    assert eng._chunk_step_fn._cache_size() == 1


def test_chunked_prefill_swa_ring(setup):
    """Chunked admission over the ring-buffer cold tier (SWA arch),
    prompts longer than the window included."""
    cfg = get_smoke_config("mixtral-8x22b")
    if cfg.attn_type != "swa":
        pytest.skip("mixtral smoke is no longer SWA")
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    eng = Engine(cfg, params, hot_cap=4, max_len=32, prefill_chunk=4)
    reqs = [
        Request(0, _prompt(90, 12, cfg.vocab_size), 6),  # > swa_window=8
        Request(1, _prompt(91, 3, cfg.vocab_size), 10),
    ]
    fin = {f.rid: f for f in eng.serve(reqs, slots=2)}
    for r in reqs:
        solo = eng.serve([Request(9, r.tokens, r.max_new_tokens)], slots=1)[0]
        np.testing.assert_array_equal(fin[r.rid].tokens, solo.tokens)
    # one compile per slot-count shape (2 and 1), none per prompt length
    assert eng._chunk_step_fn._cache_size() == 2


def test_chunked_prefill_falls_back_when_incapable(setup):
    """Archs outside the chunked contract (recurrent state / frontend)
    silently serve through grouped admission."""
    cfg = get_smoke_config("mamba2-130m")
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    eng = Engine(cfg, params, hot_cap=4, max_len=48, prefill_chunk=4)
    assert not eng._chunked_capable()
    fin = eng.serve([Request(0, _prompt(95, 6, cfg.vocab_size), 4)], slots=1)
    assert len(fin) == 1 and len(fin[0].tokens) == 4
    assert eng._chunk_step_fn is None  # never traced


def test_swa_family_serves_mixed_lengths(setup):
    """Ring-buffer cold tier (SWA smoke config) through the same engine."""
    cfg = get_smoke_config("mixtral-8x22b")
    if cfg.attn_type != "swa":  # guard: config family drifted
        pytest.skip("mixtral smoke is no longer SWA")
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    eng = Engine(cfg, params, hot_cap=4, max_len=32)
    reqs = [
        Request(0, _prompt(60, 12, cfg.vocab_size), 6),  # > swa_window=8: wraps
        Request(1, _prompt(61, 3, cfg.vocab_size), 10),
    ]
    fin = {f.rid: f for f in eng.serve(reqs, slots=2)}
    for r in reqs:
        solo = eng.serve([Request(9, r.tokens, r.max_new_tokens)], slots=1)[0]
        np.testing.assert_array_equal(fin[r.rid].tokens, solo.tokens)
