"""Round-trip + density tests for the BiROMA packing codecs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import packing


@pytest.mark.parametrize("codec", ["pack2", "pack243"])
@pytest.mark.parametrize("k,n", [(4, 3), (5, 3), (64, 16), (129, 7), (1, 1)])
def test_roundtrip(codec, k, n):
    wq = jax.random.randint(jax.random.PRNGKey(k * 31 + n), (k, n), -1, 2, dtype=jnp.int8)
    pack = packing.pack2 if codec == "pack2" else packing.pack243
    unpack = packing.unpack2 if codec == "pack2" else packing.unpack243
    packed = pack(wq)
    assert packed.dtype == jnp.uint8
    out = unpack(packed, k=k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(wq))


@settings(max_examples=30, deadline=None)
@given(
    k=st.integers(1, 97),
    n=st.integers(1, 13),
    seed=st.integers(0, 2**30),
    codec=st.sampled_from(["pack2", "pack243"]),
)
def test_property_roundtrip(k, n, seed, codec):
    wq = jax.random.randint(jax.random.PRNGKey(seed), (k, n), -1, 2, dtype=jnp.int8)
    pack = packing.pack2 if codec == "pack2" else packing.pack243
    unpack = packing.unpack2 if codec == "pack2" else packing.unpack243
    np.testing.assert_array_equal(np.asarray(unpack(pack(wq), k=k)), np.asarray(wq))


def test_pack2_density():
    # 4 trits/byte = 2.0 bits per weight
    assert packing.packed_bytes(1024, "pack2") == 256


def test_pack243_density_beats_pack2():
    # 5 trits/byte = 1.6 bits per weight, within 1.3% of log2(3)=1.585
    assert packing.packed_bytes(1000, "pack243") == 200
    assert 8.0 / 5.0 / packing.TRIT_ENTROPY_BITS < 1.013


def test_padding_is_zero_trits():
    """K-padding must decode to zero trits (TriMLA skip => no compute effect)."""
    wq = jnp.ones((3, 2), dtype=jnp.int8)
    for codec, unpack, group in [
        ("pack2", packing.unpack2, 4),
        ("pack243", packing.unpack243, 5),
    ]:
        pack = packing.pack2 if codec == "pack2" else packing.pack243
        full = unpack(pack(wq))  # no trim
        assert full.shape[0] == group
        np.testing.assert_array_equal(np.asarray(full[3:]), 0)


def test_decode_table_243():
    tbl = packing.decode_table_243()
    assert tbl.shape == (243, 5)
    # spot checks: code 121 = all zeros; code 0 = all -1; code 242 = all +1
    np.testing.assert_array_equal(tbl[121], 0)
    np.testing.assert_array_equal(tbl[0], -1)
    np.testing.assert_array_equal(tbl[242], 1)


def test_bidirectional_two_weights_per_cell_analogue():
    """BiROMA stores 2 trits/transistor; pack2 stores 4 trits/byte — the
    density ledger in hwmodel uses these constants, assert they agree."""
    assert packing.BITS_PER_TRIT["pack2"] == 2.0
    assert packing.BITS_PER_TRIT["pack243"] == 1.6
