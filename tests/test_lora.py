"""Quantized LoRA adapter tests (paper §III-C, Tables I/II, Fig. 6a)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lora


def test_init_zero_delta():
    """B=0 at init => adapter is a no-op initially (standard LoRA)."""
    p = lora.init(jax.random.PRNGKey(0), 64, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    np.testing.assert_array_equal(np.asarray(lora.apply(p, x)), 0.0)


def test_delta_flows_after_update():
    p = lora.init(jax.random.PRNGKey(0), 64, 32)
    p["b"] = jax.random.normal(jax.random.PRNGKey(2), (16, 32)) * 0.1
    y = lora.apply(p, jax.random.normal(jax.random.PRNGKey(3), (4, 64)))
    assert float(jnp.abs(y).max()) > 0


def test_gradients_only_through_lora():
    """Base (ROM) weights are frozen — grads flow to A/B only."""
    p = lora.init(jax.random.PRNGKey(0), 32, 16)
    p["b"] = jnp.ones_like(p["b"]) * 0.01
    w_base = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32))

    def loss(lp):
        y = x @ jax.lax.stop_gradient(w_base) + lora.apply(lp, x)
        return jnp.sum(y**2)

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["a"]).max()) > 0
    assert float(jnp.abs(g["b"]).max()) > 0


def test_6bit_quantization_bounded_error():
    """Fig. 6(a): 6-bit LoRA weights are ~lossless. Quantized apply must be
    within one 6-bit step of the unquantized apply."""
    p = lora.init(jax.random.PRNGKey(4), 128, 64)
    p["b"] = jax.random.normal(jax.random.PRNGKey(5), (16, 64)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 128))
    y_q = lora.apply(p, x, weight_bits=6)
    y_hi = lora.apply(p, x, weight_bits=16)  # effectively unquantized
    rel = float(jnp.linalg.norm(y_q - y_hi) / (jnp.linalg.norm(y_hi) + 1e-9))
    assert rel < 0.15


def test_ops_fraction_matches_paper():
    """Paper: extra ops ~0.7% of the host projections (falcon3-7b dims)."""
    # Falcon3-7B: d_model 3072, ffn 23040
    fracs = [
        lora.lora_ops_fraction(3072, 3072),     # V (square-ish)
        lora.lora_ops_fraction(3072, 3072),     # O
        lora.lora_ops_fraction(23040, 3072),    # Down
    ]
    avg = sum(fracs) / len(fracs)
    assert 0.004 < avg < 0.012  # ~0.7%, paper rounds


def test_param_count():
    assert lora.lora_params_count(100, 50, rank=16) == 16 * 150
