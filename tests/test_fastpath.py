"""Fused ternary fast path: epilogue-fused kernel, fused projections, blocks.

Covers the production path end to end (ISSUE 2 + ISSUE 3):
  * epilogue-fused Pallas kernel vs the XLA dot+rescale (interpret on CPU),
    including the int-exact accumulator (unit scales) and odd shapes;
  * two-phase act-quant PROLOGUE kernel vs the quantize-then-matmul
    reference — bit-exact, both codecs, M=1/odd shapes, A8 and A4;
  * E-loop expert kernel (one launch over all experts) vs the vmapped
    per-expert forward — bit-exact, incl. the fused gate‖up MoE path and
    the carried-scale (fuse_act_quant=False) form, which no longer falls
    back to the vmapped XLA path;
  * MLA down-projection fusion (w_dq‖w_dkv -> "w_dqkv", post-split norms);
  * shape-aware block selection (decode-shaped auto blocks stay exact);
  * pack2/pack243 zero-code padding repair regression (operator precedence);
  * fuse_packed / FusedPackedLinear: fused QKV and gate-up vs separate
    projections, bit-exact at the projection level, both impls;
  * config-threaded impl selection (BitNetConfig.impl).

Everything here runs in Pallas interpret mode on CPU — this module is the
CI kernel-parity lane (pytest -m kernel_parity).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import bitlinear, packing
from repro.kernels import ops, ref

pytestmark = pytest.mark.kernel_parity

CODECS = ("pack2", "pack243")
ODD_SHAPES = [
    (1, 256, 128),   # GEMV decode
    (5, 33, 7),      # everything ragged
    (8, 64, 16),     # tiny
    (16, 512, 256),  # one aligned block
    (32, 520, 96),   # K not a block/group multiple
]


def _case(seed, m, k, n):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    xq = jax.random.randint(kx, (m, k), -128, 128, dtype=jnp.int8)
    wq = jax.random.randint(kw, (k, n), -1, 2, dtype=jnp.int8)
    return xq, wq


def _pack(wq, codec):
    return (packing.pack2 if codec == "pack2" else packing.pack243)(wq)


# ---------------------------------------------------------------------------
# Epilogue-fused kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("m,k,n", ODD_SHAPES)
def test_fused_epilogue_matches_oracle(codec, m, k, n):
    xq, wq = _case(m * 131 + k * 7 + n, m, k, n)
    packed = _pack(wq, codec)
    xs = jax.random.uniform(jax.random.PRNGKey(1), (m, 1)) + 0.5
    cs = jax.random.uniform(jax.random.PRNGKey(2), (n,)) + 0.5
    want = (
        (np.asarray(xq, np.float64) @ np.asarray(wq, np.float64))
        * np.asarray(cs, np.float64)[None, :]
        / np.asarray(xs, np.float64)
    )
    for impl in ("pallas", "xla"):
        got = ops.ternary_matmul_fused(
            xq, packed, xs, cs, k=k, codec=codec, impl=impl
        )
        np.testing.assert_allclose(np.asarray(got, np.float64), want,
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("codec", CODECS)
def test_fused_epilogue_int_accumulator_exact(codec):
    """With unit scales the fused output IS the int32 accumulator — the
    integer pipeline of the fused kernel is bit-identical to the raw one."""
    m, k, n = 7, 130, 40
    xq, wq = _case(99, m, k, n)
    packed = _pack(wq, codec)
    got = ops.ternary_matmul_fused(
        xq, packed, jnp.ones((m, 1)), jnp.ones((n,)), k=k, codec=codec,
        impl="pallas",
    )
    want = ref.ternary_matmul_ref(xq, packed, k=k, codec=codec)
    np.testing.assert_array_equal(
        np.asarray(got, np.int64), np.asarray(want, np.int64)
    )


def test_fused_epilogue_batched_leading_dims():
    xq = jax.random.randint(jax.random.PRNGKey(1), (2, 3, 64), -128, 128,
                            dtype=jnp.int8)
    wq = jax.random.randint(jax.random.PRNGKey(2), (64, 32), -1, 2,
                            dtype=jnp.int8)
    packed = packing.pack2(wq)
    xs = jax.random.uniform(jax.random.PRNGKey(3), (2, 3, 1)) + 0.5
    cs = jax.random.uniform(jax.random.PRNGKey(4), (32,)) + 0.5
    got = ops.ternary_matmul_fused(xq, packed, xs, cs, k=64, codec="pack2",
                                   impl="pallas")
    acc = jnp.einsum("btk,kn->btn", xq.astype(jnp.int32), wq.astype(jnp.int32))
    want = acc.astype(jnp.float32) * cs / xs
    assert got.shape == (2, 3, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Two-phase act-quant prologue kernel
# ---------------------------------------------------------------------------


def _raw_case(seed, m, k, n, codec):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k))
    wq = jax.random.randint(kw, (k, n), -1, 2, dtype=jnp.int8)
    return x, _pack(wq, codec)


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("m,k,n", ODD_SHAPES)
def test_actq_prologue_matches_quantize_then_matmul(codec, m, k, n):
    """The tentpole guarantee: in-kernel act-quant (absmax K-sweep + int8
    quantize in VMEM) is BIT-EXACT against the two-pass reference —
    act_quant as a separate op feeding the known-scale fused kernel, and
    the XLA quantize+dot+rescale path."""
    from repro.core.ternary import act_quant

    x, packed = _raw_case(m * 37 + k * 5 + n, m, k, n, codec)
    cs = jax.random.uniform(jax.random.PRNGKey(3), (n,)) + 0.5
    got = ops.ternary_matmul_actq(x, packed, cs, k=k, codec=codec,
                                  impl="pallas")
    q = act_quant(x)
    want_fused = ops.ternary_matmul_fused(q.xq, packed, q.scale, cs, k=k,
                                          codec=codec, impl="pallas")
    want_xla = ops.ternary_matmul_actq(x, packed, cs, k=k, codec=codec,
                                       impl="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_fused))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_xla))


@pytest.mark.parametrize("codec", CODECS)
def test_actq_prologue_a4(codec):
    """A4 activations (BitNet a4.8 / TriMLA-native) quantize identically
    in the prologue: qmax 7 / qmin -8 threads through."""
    m, k, n = 5, 130, 40
    x, packed = _raw_case(21, m, k, n, codec)
    cs = jnp.ones((n,))
    got = ops.ternary_matmul_actq(x, packed, cs, k=k, codec=codec,
                                  act_bits=4, impl="pallas")
    want = ops.ternary_matmul_actq(x, packed, cs, k=k, codec=codec,
                                   act_bits=4, impl="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_actq_prologue_batched_leading_dims_and_bf16():
    """Leading batch dims flatten through, and bf16 inputs quantize to the
    same int8 values as act_quant's f32 upcast does."""
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 64)).astype(jnp.bfloat16)
    wq = jax.random.randint(jax.random.PRNGKey(2), (64, 32), -1, 2,
                            dtype=jnp.int8)
    packed = packing.pack2(wq)
    cs = jax.random.uniform(jax.random.PRNGKey(4), (32,)) + 0.5
    got = ops.ternary_matmul_actq(x, packed, cs, k=64, codec="pack2",
                                  impl="pallas")
    want = ops.ternary_matmul_actq(x, packed, cs, k=64, codec="pack2",
                                   impl="xla")
    assert got.shape == (2, 3, 32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("codec", CODECS)
def test_actq_prologue_scale_persists_across_column_tiles(codec):
    """The absmax sweep runs only at the first output-column tile (j == 0)
    and the finished scale in VMEM scratch serves every later j — pin it
    with a grid that has several j AND several i tiles."""
    m, k, n = 40, 96, 120  # blocks below force gm=2, gn=4, gk=2
    x, packed = _raw_case(77, m, k, n, codec)
    cs = jax.random.uniform(jax.random.PRNGKey(9), (n,)) + 0.5
    got = ops.ternary_matmul_actq(
        x, packed, cs, k=k, codec=codec, impl="pallas",
        block_m=32, block_n=32, block_k=40,
    )
    want = ops.ternary_matmul_actq(x, packed, cs, k=k, codec=codec,
                                   impl="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_actq_prologue_rejects_unsupported_bits():
    """pallas and xla reject unsupported act_bits identically."""
    x, packed = _raw_case(1, 4, 64, 32, "pack2")
    cs = jnp.ones((32,))
    for impl in ("pallas", "xla"):
        with pytest.raises(ValueError, match="unsupported activation bits"):
            ops.ternary_matmul_actq(x, packed, cs, k=64, act_bits=6,
                                    impl=impl)


def test_actq_prologue_zero_row():
    """An all-zero activation row must produce an all-zero output (EPS
    guard in the in-kernel scale), not NaN/Inf."""
    m, k, n = 4, 64, 32
    x, packed = _raw_case(30, m, k, n, "pack2")
    x = x.at[1].set(0.0)
    cs = jnp.ones((n,))
    got = ops.ternary_matmul_actq(x, packed, cs, k=k, codec="pack2",
                                  impl="pallas")
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_array_equal(np.asarray(got[1]), 0.0)


def test_packed_matmul_carried_scale_fallback():
    """packed_matmul accepts an already-quantized activation (the
    carried-scale fallback): same result as handing it the raw floats."""
    from repro.core.ternary import act_quant

    pw = bitlinear.quantize_pack(
        {"w": jax.random.normal(jax.random.PRNGKey(5), (96, 48))})
    x = jax.random.normal(jax.random.PRNGKey(6), (3, 96))
    y_raw = bitlinear.packed_matmul(pw, x, impl="pallas")
    y_carried = bitlinear.packed_matmul(pw, act_quant(x), impl="pallas")
    y_unfused = bitlinear.packed_matmul(pw, x, impl="pallas", fuse_actq=False)
    np.testing.assert_array_equal(np.asarray(y_raw), np.asarray(y_carried))
    np.testing.assert_array_equal(np.asarray(y_raw), np.asarray(y_unfused))


def test_linear_fuse_act_quant_config_threading():
    """BitNetConfig.fuse_act_quant=False pins the separate-act-quant path;
    results stay identical either way (same numerics, different fusion)."""
    import dataclasses as dc

    from repro.models import qops

    cfg = get_smoke_config("falcon3-1b")
    cfg_p = dc.replace(cfg, bitnet=dc.replace(cfg.bitnet, impl="pallas"))
    cfg_np = dc.replace(
        cfg, bitnet=dc.replace(cfg.bitnet, impl="pallas", fuse_act_quant=False)
    )
    leaf = bitlinear.quantize_pack(
        _random_linear(jax.random.PRNGKey(3), 64, 48))
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 64))
    y_f = qops.linear(leaf, x, cfg_p, "packed")
    y_s = qops.linear(leaf, x, cfg_np, "packed")
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_s))


# ---------------------------------------------------------------------------
# E-loop expert kernel (one launch over all experts)
# ---------------------------------------------------------------------------


def _expert_case(seed, e, c, k, n, codec):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (e, c, k))
    wq = jax.random.randint(kw, (e, k, n), -1, 2, dtype=jnp.int8)
    pack = packing.pack2 if codec == "pack2" else packing.pack243
    return x, jax.vmap(pack)(wq)


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("c", [1, 5, 16])
def test_expert_eloop_matches_vmapped(codec, c):
    """One E-loop launch (leading expert grid dim) == the vmapped
    per-expert quantize-then-matmul, bit-for-bit."""
    e, k, n = 4, 96, 72
    x, packed = _expert_case(c * 11 + 1, e, c, k, n, codec)
    cs = jax.random.uniform(jax.random.PRNGKey(3), (e, n)) + 0.5
    got = ops.ternary_matmul_expert(x, packed, cs, k=k, codec=codec,
                                    impl="pallas")
    want = ops.ternary_matmul_expert(x, packed, cs, k=k, codec=codec,
                                     impl="xla")
    assert got.shape == (e, c, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("codec", CODECS)
def test_expert_packed_matmul_paths_agree(codec):
    """bitlinear.expert_packed_matmul: E-loop pallas == vmapped xla for
    both leaf kinds (scalar-scale PackedLinear, per-column fused)."""
    from repro.models.pack import fuse_packed

    e, c, k, ff = 3, 4, 64, 32
    keys = jax.random.split(jax.random.PRNGKey(17), 3)
    w_g = jax.random.normal(keys[0], (e, k, ff)) * k**-0.5
    w_u = jax.random.normal(keys[1], (e, k, ff)) * k**-0.5
    from repro.models.pack import _pack_weight

    pg = _pack_weight(w_g, codec)
    pu = _pack_weight(w_u, codec)
    fused = fuse_packed([pg, pu])
    assert fused.packed.ndim == 3 and fused.scale.shape == (e, 2 * ff)
    x = jax.random.normal(keys[2], (e, c, k))
    for leaf in (pg, fused):
        y_p = bitlinear.expert_packed_matmul(leaf, x, impl="pallas")
        y_x = bitlinear.expert_packed_matmul(leaf, x, impl="xla")
        np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_x))


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("c", [1, 5, 16])
def test_expert_carried_scale_eloop_matches_vmapped(codec, c):
    """The carried-scale E-loop kernel (fuse_act_quant=False form:
    pre-quantized int8 x + per-row scale, no absmax phase) == the vmapped
    per-expert known-scale pipeline, bit-for-bit."""
    from repro.core.ternary import act_quant

    e, k, n = 4, 96, 72
    x, packed = _expert_case(c * 13 + 5, e, c, k, n, codec)
    cs = jax.random.uniform(jax.random.PRNGKey(4), (e, n)) + 0.5
    q = act_quant(x)
    got = ops.ternary_matmul_expert_fused(
        q.xq, packed, q.scale, cs, k=k, codec=codec, impl="pallas")
    want = ops.ternary_matmul_expert_fused(
        q.xq, packed, q.scale, cs, k=k, codec=codec, impl="xla")
    assert got.shape == (e, c, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and both equal the prologue-fused kernel (same int ops end to end)
    fused = ops.ternary_matmul_expert(x, packed, cs, k=k, codec=codec,
                                      impl="pallas")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(fused))


@pytest.mark.parametrize("codec", CODECS)
def test_expert_packed_matmul_carried_scale_no_xla_fallback(codec):
    """ROADMAP gap closed: with fuse_actq=False (or a QuantizedActivation
    producer) the Pallas path runs the carried-scale E-loop kernel and
    stays bit-identical to the vmapped XLA path for both leaf kinds."""
    from repro.core.ternary import act_quant
    from repro.models.pack import _pack_weight, fuse_packed

    e, c, k, ff = 3, 4, 64, 32
    keys = jax.random.split(jax.random.PRNGKey(19), 3)
    w_g = jax.random.normal(keys[0], (e, k, ff)) * k**-0.5
    w_u = jax.random.normal(keys[1], (e, k, ff)) * k**-0.5
    pg = _pack_weight(w_g, codec)
    fused = fuse_packed([pg, _pack_weight(w_u, codec)])
    x = jax.random.normal(keys[2], (e, c, k))
    for leaf in (pg, fused):
        want = bitlinear.expert_packed_matmul(leaf, x, impl="xla",
                                              fuse_actq=False)
        got = bitlinear.expert_packed_matmul(leaf, x, impl="pallas",
                                             fuse_actq=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        got_q = bitlinear.expert_packed_matmul(leaf, act_quant(x),
                                               impl="pallas")
        np.testing.assert_array_equal(np.asarray(got_q), np.asarray(want))


def test_moe_fused_gate_up_eloop_exact():
    """apply_moe with the pack-time-fused w_gu leaf == the unfused tree,
    on the XLA path AND the E-loop Pallas path (bit-exact end to end)."""
    import dataclasses as dc

    from repro.models import moe as moe_lib
    from repro.models import pack as pack_lib

    cfg = get_smoke_config("mixtral-8x22b")
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    pf = pack_lib.pack_params(p, cfg)
    pu = pack_lib.pack_params(p, cfg, fuse=False)
    assert "w_gu" in pf and "w_gate" in pu
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 6, cfg.d_model))
    y_u, _ = moe_lib.apply_moe(pu, x, cfg, "packed")
    y_f, _ = moe_lib.apply_moe(pf, x, cfg, "packed")
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_u))
    cfg_p = dc.replace(cfg, bitnet=dc.replace(cfg.bitnet, impl="pallas"))
    y_p, _ = moe_lib.apply_moe(pf, x, cfg_p, "packed")
    np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_u))


# ---------------------------------------------------------------------------
# MLA down-projection fusion (w_dq‖w_dkv -> "w_dqkv")
# ---------------------------------------------------------------------------


def test_mla_fused_down_projection_exact():
    """mla_full with the fused w_dqkv leaf == separate w_dq/w_dkv (the
    per-branch q_ln/kv_ln norms apply post-split), bit-exact."""
    from repro.models import attention as attn
    from repro.models import pack as pack_lib

    cfg = get_smoke_config("deepseek-v3-671b")
    p = attn.init_mla(jax.random.PRNGKey(0), cfg)
    pf = pack_lib.pack_params(p, cfg)
    pu = pack_lib.pack_params(p, cfg, fuse=False)
    assert "w_dqkv" in pf and "w_dq" in pu
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 5, cfg.d_model))
    pos = jnp.arange(5)
    y_f = attn.mla_full(pf, x, cfg, "packed", pos)
    y_u = attn.mla_full(pu, x, cfg, "packed", pos)
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_u))


# ---------------------------------------------------------------------------
# Shape-aware block selection
# ---------------------------------------------------------------------------


def test_select_blocks_decode_vs_prefill():
    # decode-shaped M stays on the skinny row of the table, not pad-to-256
    for m in (1, 8, 32):
        bm, bn, bk = ops.select_blocks(m, 2048, 2048, "pack2")
        assert bm == 32 and bn == 512 and bk == 1024
    assert ops.select_blocks(64, 2048, 2048, "pack2")[0] == 64
    assert ops.select_blocks(4096, 4096, 4096, "pack2") == (256, 256, 512)
    # caps: block_n / block_k never exceed the padded operand
    bm, bn, bk = ops.select_blocks(1, 96, 200, "pack243")
    assert bn == 128 and bk % packing.PACK243_GROUP == 0 and bk <= 205
    # pack243 lane alignment: block_k snaps to lcm(5, 128) = 640 so the
    # (bm, bk) x tile and (bk/5, bn) packed tile compile on real TPU
    for m in (1, 32, 4096):
        bk243 = ops.select_blocks(m, 2048, 2048, "pack243")[2]
        assert bk243 == 640, bk243


def test_select_blocks_kinds():
    """The two-phase and E-loop grids get their own table rows: the actq
    decode row halves block_k (raw-float x tile, read twice); the expert
    decode row narrows block_n."""
    assert ops.select_blocks(1, 2048, 2048, "pack2", kind="actq") == (32, 512, 512)
    assert ops.select_blocks(1, 2048, 2048, "pack2", kind="expert") == (32, 256, 512)
    # prefill tier is shared across kinds
    for kind in ("fused", "actq", "expert"):
        assert ops.select_blocks(4096, 4096, 4096, "pack2", kind=kind) == (256, 256, 512)
    # pack243 lane alignment applies to every table
    assert ops.select_blocks(1, 2048, 2048, "pack243", kind="actq")[2] == 640


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("m", [1, 8, 32])
def test_auto_blocks_decode_shapes_exact(codec, m):
    """Auto-selected decode blocks (no explicit block args) stay bit-exact."""
    k, n = 192, 72
    xq, wq = _case(m * 17 + 3, m, k, n)
    got = ops.ternary_matmul(xq, _pack(wq, codec), k=k, codec=codec,
                             impl="pallas")
    np.testing.assert_array_equal(
        np.asarray(got, np.int64), np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)
    )


# ---------------------------------------------------------------------------
# Padding zero-code repair (regression: `and`/`or` precedence, ops.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", CODECS)
def test_column_padding_zero_code_repair(codec):
    """Non-aligned N forces column padding; pack2 hits the (previously
    mis-parenthesized) repair branch, pack243 needs the 121 rewrite."""
    m, k, n = 4, 40, 7  # n far below any block_n -> heavy column padding
    xq, wq = _case(5, m, k, n)
    got = ops.ternary_matmul(
        xq, _pack(wq, codec), k=k, codec=codec, impl="pallas",
        block_m=8, block_n=32, block_k=20,
    )
    np.testing.assert_array_equal(
        np.asarray(got, np.int64), np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)
    )


@pytest.mark.parametrize("codec", CODECS)
def test_pad_operands_padding_decodes_to_zero_trits(codec):
    """Direct invariant: every padded weight byte must decode to zero trits
    (TriMLA skip-ops) for BOTH codecs and BOTH padding directions. This is
    the regression for the `a and b or c` precedence hazard: under the old
    parse the repair branch ran for pack2 column padding (saved only by the
    inner zero_code check) — assert the invariant itself, not the luck."""
    m, k, n = 4, 33, 7
    xq, wq = _case(8, m, k, n)
    packed = _pack(wq, codec)
    group = packing.PACK2_GROUP if codec == "pack2" else packing.PACK243_GROUP
    x2, wp, lead, m2, n2 = ops._pad_operands(xq, packed, codec, 8, 32, 20)
    unpack = packing.unpack2 if codec == "pack2" else packing.unpack243
    trits = np.asarray(unpack(wp))  # (Kp, Np) int8, no trim
    assert wp.shape[0] > packed.shape[0] and wp.shape[1] > n  # both pads hit
    np.testing.assert_array_equal(trits[packed.shape[0] * group :, :], 0)
    np.testing.assert_array_equal(trits[:, n:], 0)
    np.testing.assert_array_equal(np.asarray(x2[:, k:]), 0)


def test_pack243_row_padding_only_repair():
    """K-only padding (N block-aligned): pack243 pad rows must decode to
    zero trits, not byte-0 = (-1,-1,-1,-1,-1)."""
    m, k, n = 4, 33, 32  # packed K = 35 bytes*5, block_k=20 -> pad to 40
    xq, wq = _case(6, m, k, n)
    got = ops.ternary_matmul(
        xq, _pack(wq, "pack243"), k=k, codec="pack243", impl="pallas",
        block_m=8, block_n=32, block_k=20,
    )
    np.testing.assert_array_equal(
        np.asarray(got, np.int64), np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)
    )


# ---------------------------------------------------------------------------
# Fused projections (fuse_packed / FusedPackedLinear)
# ---------------------------------------------------------------------------


def _random_linear(key, k, n):
    return {"w": jax.random.normal(key, (k, n)) * k**-0.5}


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("m", [1, 5, 16])
def test_fused_group_matches_separate(codec, impl, m):
    """wq‖wk‖wv fused into one launch == three separate projections,
    bit-for-bit (same int accumulators, same per-segment scales)."""
    from repro.models.pack import fuse_packed

    k = 96
    widths = (64, 32, 32)  # h*hd, g*hd, g*hd
    keys = jax.random.split(jax.random.PRNGKey(11), len(widths) + 1)
    leaves = [_random_linear(kk, k, w) for kk, w in zip(keys, widths)]
    pws = [bitlinear.quantize_pack(lf, codec=codec) for lf in leaves]
    fused = fuse_packed(pws)
    assert fused.splits == widths
    assert fused.packed.shape[-1] == sum(widths)

    x = jax.random.normal(keys[-1], (m, k))
    y = bitlinear.packed_matmul(fused, x, impl=impl)
    off = 0
    for pw, w in zip(pws, widths):
        want = bitlinear.packed_matmul(pw, x, impl=impl)
        np.testing.assert_array_equal(
            np.asarray(y[:, off : off + w]), np.asarray(want)
        )
        off += w


@pytest.mark.parametrize("codec", CODECS)
def test_fused_pallas_matches_separate_xla(codec):
    """The production combination: fused + Pallas epilogue vs the historical
    separate + XLA path, float tolerance 1e-5 (acceptance criterion)."""
    from repro.models.pack import fuse_packed

    k, widths = 130, (48, 24, 24)
    keys = jax.random.split(jax.random.PRNGKey(13), len(widths) + 1)
    pws = [
        bitlinear.quantize_pack(_random_linear(kk, k, w), codec=codec)
        for kk, w in zip(keys, widths)
    ]
    fused = fuse_packed(pws)
    x = jax.random.normal(keys[-1], (5, k))
    y = bitlinear.packed_matmul(fused, x, impl="pallas")
    want = jnp.concatenate(
        [bitlinear.packed_matmul(pw, x, impl="xla") for pw in pws], axis=-1
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_bitlinear_apply_dispatches_fused():
    """bitlinear.apply (the mode-dispatching forward) routes fused leaves
    to the packed path, not apply_qat."""
    from repro.models.pack import fuse_packed

    pws = [
        bitlinear.quantize_pack(_random_linear(jax.random.PRNGKey(i), 64, 16))
        for i in range(2)
    ]
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 64))
    y = bitlinear.apply(fuse_packed(pws), x)
    want = jnp.concatenate([bitlinear.apply(pw, x) for pw in pws], axis=-1)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


def test_attention_fused_qkv_exact():
    """_project_qkv via the fused leaf == separate projections (with the
    v-segment LoRA applied after the split), prefill and decode shapes."""
    from repro.models import attention as attn
    from repro.models import pack as pack_lib

    cfg = get_smoke_config("zamba2-7b")  # qk_norm off, lora_v on
    p = attn.init_attention(jax.random.PRNGKey(0), cfg)
    pf = pack_lib.pack_params(p, cfg)
    pu = pack_lib.pack_params(p, cfg, fuse=False)
    assert "wqkv" in pf and "wq" in pu
    for shape in ((2, 5, cfg.d_model), (3, 1, cfg.d_model)):
        x = jax.random.normal(jax.random.PRNGKey(2), shape)
        for a, b in zip(
            attn._project_qkv(pf, x, cfg, "packed"),
            attn._project_qkv(pu, x, cfg, "packed"),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mlp_fused_gate_up_exact():
    from repro.models import pack as pack_lib
    from repro.models.layers import apply_mlp, init_mlp

    cfg = get_smoke_config("falcon3-1b")
    p = init_mlp(jax.random.PRNGKey(0), cfg)
    pf = pack_lib.pack_params(p, cfg)
    pu = pack_lib.pack_params(p, cfg, fuse=False)
    assert "wgu" in pf and "gate" in pu
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 5, cfg.d_model))
    np.testing.assert_array_equal(
        np.asarray(apply_mlp(pf, x, cfg, "packed")),
        np.asarray(apply_mlp(pu, x, cfg, "packed")),
    )


def test_model_prefill_decode_fused_vs_unfused():
    """End-to-end smoke: fused vs unfused trees agree. Tolerance is loose
    on purpose — a 1-ulp float wobble from XLA refusing can flip an int8
    act-quant bucket downstream (~3e-2 on one logit row); the strict
    guarantees live in the projection-level tests above."""
    from repro.models import pack as pack_lib
    from repro.models import transformer as T

    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    fused = pack_lib.pack_params(params, cfg)
    unfused = pack_lib.pack_params(params, cfg, fuse=False)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    lg_f, cache_f = T.prefill(fused, cfg, {"tokens": toks}, max_len=24)
    lg_u, cache_u = T.prefill(unfused, cfg, {"tokens": toks}, max_len=24)
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_u),
                               rtol=1e-2, atol=5e-2)
    nxt = jnp.argmax(lg_f, -1).astype(jnp.int32)
    d_f, _ = T.decode_step(fused, cfg, nxt, cache_f)
    d_u, _ = T.decode_step(unfused, cfg, nxt, cache_u)
    np.testing.assert_allclose(np.asarray(d_f), np.asarray(d_u),
                               rtol=1e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# Config-threaded impl selection
# ---------------------------------------------------------------------------


def test_resolve_impl():
    from repro.models import qops

    cfg = get_smoke_config("falcon3-1b")
    # auto on CPU -> xla (Pallas would run in the slow interpreter)
    assert jax.default_backend() == "cpu"
    assert qops.resolve_impl(cfg) == "xla"
    forced = dataclasses.replace(
        cfg, bitnet=dataclasses.replace(cfg.bitnet, impl="pallas")
    )
    assert qops.resolve_impl(forced) == "pallas"


def test_linear_pallas_impl_via_config():
    """qops.linear honors BitNetConfig.impl (the serving engine's path)."""
    import dataclasses as dc

    from repro.models import qops

    cfg = get_smoke_config("falcon3-1b")
    cfg_p = dc.replace(cfg, bitnet=dc.replace(cfg.bitnet, impl="pallas"))
    leaf = bitlinear.quantize_pack(_random_linear(jax.random.PRNGKey(3), 64, 48))
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 64))
    y_p = qops.linear(leaf, x, cfg_p, "packed")
    y_x = qops.linear(leaf, x, cfg, "packed")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_x),
                               rtol=1e-5, atol=1e-5)
