"""Hardware-model reproduction gates (paper Table III, §V-B, Fig. 1a)."""

import pytest

from repro.configs import get_config
from repro.hwmodel import model as hw


def test_bit_density_10x_over_dcirom():
    assert hw.density_ratio_vs_dcirom() == pytest.approx(10.2, abs=0.05)


def test_tops_per_watt_headline():
    # energy/op must invert exactly to the reported TOPS/W
    assert 1e12 / hw.energy_per_op_pj(4) / 1e12 == pytest.approx(20.8)
    assert 1e12 / hw.energy_per_op_pj(8) / 1e12 == pytest.approx(5.2)
    # A8 runs 2-cycle bit-serial (plus tree toggling): 4x energy per op
    assert hw.energy_per_op_pj(8) / hw.energy_per_op_pj(4) == pytest.approx(4.0)


def test_biroma_macro_spec():
    m = hw.MacroSpec()
    assert m.trits == 2048 * 1024 * 2  # two ternary weights per transistor
    assert m.n_trimla == 128
    # macro stores ~4.2M weights at 1.58 b
    assert m.capacity_bits == pytest.approx(m.trits * 1.58)


def test_falcon3_deployment_matches_paper():
    dep = hw.falcon3_deployment(get_config("falcon3-1b"))
    assert dep["edram_mib"] == pytest.approx(13.5, abs=0.01)  # 13.5 MB DR eDRAM
    assert dep["macro_partitions"] == 6 and dep["layers_per_partition"] == 3
    assert dep["kv_reduction"] == pytest.approx(0.436, abs=0.001)  # 43.6%
    assert dep["edram_area_cm2_14nm"] == pytest.approx(10.24, abs=0.01)


def test_fig1a_llama7b_exceeds_1000cm2():
    """Fig 1(a): LLaMA-7B CiROM mapping exceeds 1,000 cm² at the task-level
    density implied by [1]'s full ResNet-56 deployment (8-bit weights)."""
    area = hw.model_area_estimate_cm2(7e9, 8.0, hw.DCIROM_TASK_DENSITY_KB_MM2)
    assert area > 1000.0


def test_fig1a_bitnet1b_tens_of_cm2():
    """BitNet-1B at DCiROM density lands at 'tens of cm²' (the design gap)…"""
    area = hw.model_area_estimate_cm2(1e9, 1.58)
    assert 10.0 < area < 100.0
    # …and BitROM's 10x density closes it to single-digit cm²
    area_bitrom = hw.model_area_estimate_cm2(1e9, 1.58, hw.BIT_DENSITY_KB_MM2)
    assert area_bitrom < 10.0


def test_update_free_gain_positive():
    """Zero weight reload must dominate a DRAM-streaming baseline."""
    cfg = get_config("falcon3-1b")
    kv = 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * cfg.n_layers
    gain = hw.system_efficiency_gain(cfg.param_count(), seq_len=128, kv_bytes_per_token=kv)
    assert gain > 3.0  # weight streaming dominates edge energy


def test_periphery_fraction():
    """Adder tree + TriMLA + periphery = 4.8% of macro area."""
    n = 10_000_000
    total = hw.macro_area_mm2(n)
    array = n * 1.58 / 1e3 / hw.BIT_DENSITY_KB_MM2
    assert (total - array) / total == pytest.approx(0.048, abs=1e-3)


def test_retention_failure_prob_monotone_and_limits():
    """DR-eDRAM retention: longer refresh intervals strictly raise the
    per-bit failure probability, pinned to 0 at interval 0 and
    saturating at 1 far beyond tau."""
    assert hw.retention_failure_prob(0.0) == 0.0
    probs = [hw.retention_failure_prob(t) for t in (1.0, 10.0, 100.0, 1000.0)]
    assert all(b > a for a, b in zip(probs, probs[1:]))
    assert all(0.0 < p < 1.0 for p in probs)
    assert hw.retention_failure_prob(1e9) == pytest.approx(1.0)
    assert hw.retention_failure_prob(hw.EDRAM_RETENTION_TAU_MS) == \
        pytest.approx(1.0 - 2.718281828459045 ** -1.0)
    with pytest.raises(ValueError):
        hw.retention_failure_prob(-1.0)


def test_refresh_tradeoff_power_vs_failures():
    """The tradeoff the scrubber navigates: refresh power falls as 1/t
    while expected bit failures rise — the two axes move in opposite
    directions over the same interval sweep."""
    nbytes = 13_500_000
    rows = [hw.refresh_tradeoff(nbytes, t) for t in (5.0, 10.0, 50.0, 100.0)]
    powers = [r["refresh_power_uw"] for r in rows]
    fails = [r["expected_bit_failures"] for r in rows]
    assert all(b < a for a, b in zip(powers, powers[1:]))
    assert all(b > a for a, b in zip(fails, fails[1:]))
    # halving the interval doubles refresh power exactly (energy per
    # refresh pass is fixed; only the pass rate changes)
    assert rows[0]["refresh_power_uw"] == pytest.approx(
        2.0 * rows[1]["refresh_power_uw"])
    assert rows[0]["expected_bit_failures"] == pytest.approx(
        nbytes * 8 * hw.retention_failure_prob(5.0))
    # interval 0: failure-free but unbounded refresh power
    zero = hw.refresh_tradeoff(nbytes, 0.0)
    assert zero["p_fail_per_bit"] == 0.0
    assert zero["refresh_power_uw"] == float("inf")
