"""Pipeline parallelism (GPipe via shard_map): exactness vs plain forward.

Needs >1 local device, so the heavy check runs in a subprocess with
XLA_FLAGS set before jax imports (the main pytest process keeps 1 device).
"""

import subprocess
import sys
from pathlib import Path

import pytest

from repro.distributed.pipeline import bubble_fraction

ROOT = Path(__file__).resolve().parents[1]


def test_bubble_fraction():
    assert bubble_fraction(6, 6) == 5 / 11  # the paper's 6x6 configuration
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 28) < 0.1


@pytest.mark.slow
def test_pipeline_matches_plain_forward_subprocess():
    """Runs the falcon3 6-stage pipeline example, which asserts exactness."""
    r = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "pipeline_falcon3.py")],
        capture_output=True,
        text=True,
        timeout=900,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "pipelined forward == plain forward" in r.stdout
