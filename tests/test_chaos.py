"""Serving fault-injection harness (ISSUE 7 tentpole, part 3).

Layers:

  * the **invariant checker** itself must be falsifiable — hand-built
    protocol violations (leak, double-free, negative count, free-list
    corruption, stale host table) each raise InvariantViolation;
  * **seeded chaos runs** (the three fixed CI seeds): pool exhaustion,
    straggler stalls and mid-flight cancellation injected into a real
    paged serve under page pressure — every request reaches a terminal
    outcome, requests that finish are BIT-IDENTICAL to a fault-free
    run, the invariant checker is green after every iteration, and the
    whole injection sequence is deterministic per seed;
  * the **fault vocabulary extensions** in distributed/fault.py
    (multi-point FaultInjector, FaultSchedule determinism) that both
    the training and serving chaos paths share.
"""

from collections import Counter
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed.fault import (FaultInjector, FaultSchedule,
                                     InjectedFault, StragglerMonitor)
from repro.models import transformer as T
from repro.serving.chaos import (ChaosConfig, ChaosInjector,
                                 InvariantViolation,
                                 check_serving_invariants)
from repro.serving.engine import Engine
from repro.serving.paging import PagePool, PrefixCache
from repro.serving.scheduler import Request

HOT, ML, PS = 4, 64, 8


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(seed, n, vocab):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab), np.int32
    )


def _mk(reqs):
    return [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs]


def _engine(cfg, params, **kw):
    return Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4,
                  paged=True, page_size=PS, **kw)


# ---------------------------------------------------------------------------
# the checker must be falsifiable: constructed violations are caught
# ---------------------------------------------------------------------------


def _fake_ctx(pool, tree=None, slot_pages=(), host_table=None):
    return SimpleNamespace(
        pool=pool,
        ptree=tree,
        sched=SimpleNamespace(slot_req=[object()] * len(slot_pages)),
        slot_pages=[list(p) for p in slot_pages],
        host_table=host_table,
    )


def test_checker_catches_leak():
    pool = PagePool(4)
    pool.alloc(1)  # a reader nobody registered
    with pytest.raises(InvariantViolation, match="leak"):
        check_serving_invariants(_fake_ctx(pool))


def test_checker_catches_double_free():
    pool = PagePool(4)
    [p] = pool.alloc(1)
    pool.decref([p])  # freed while the (fake) slot still maps it
    with pytest.raises(InvariantViolation, match="double-free|free list"):
        check_serving_invariants(_fake_ctx(pool, slot_pages=[[p]]))


def test_checker_catches_negative_refcount():
    pool = PagePool(4)
    pool.refs[2] = -1  # corrupt directly: decref itself refuses to
    with pytest.raises(InvariantViolation, match="negative"):
        check_serving_invariants(_fake_ctx(pool))


def test_checker_catches_free_list_corruption():
    pool = PagePool(4)
    [p] = pool.alloc(1)
    pool._free.append(p)  # referenced AND free
    with pytest.raises(InvariantViolation,
                       match="AND free|free list with refcount"):
        check_serving_invariants(_fake_ctx(pool, slot_pages=[[p]]))
    pool2 = PagePool(4)
    pool2._free.append(pool2._free[0])  # duplicate entry
    with pytest.raises(InvariantViolation, match="duplicate"):
        check_serving_invariants(_fake_ctx(pool2))


def test_checker_catches_stale_host_table():
    pool = PagePool(8)
    pages = pool.alloc(2)
    table = np.zeros((1, 4), np.int32)
    table[0, :2] = pages[::-1]  # mirror disagrees with the page list
    with pytest.raises(InvariantViolation, match="host-table"):
        check_serving_invariants(
            _fake_ctx(pool, slot_pages=[pages], host_table=table))


def test_checker_accepts_extra_refs_for_held_pages():
    pool = PagePool(4)
    pages = pool.alloc(2)  # e.g. a chaos hold
    ctx = _fake_ctx(pool)
    with pytest.raises(InvariantViolation):
        check_serving_invariants(ctx)  # unknown reader without the hint
    check_serving_invariants(ctx, extra_refs=Counter(pages))  # ok with it


def test_checker_green_on_tree_and_slots():
    pool = PagePool(8)
    tree = PrefixCache(pool, hot_cap=2, page_size=2)
    toks = np.asarray([1, 2, 3, 4, 5], np.int32)
    pages = pool.alloc(1)
    assert tree.insert(toks, pages, lambda ids: None)
    check_serving_invariants(_fake_ctx(pool, tree, slot_pages=[pages]))
    pool.decref(pages)  # slot retires; the tree keeps its copy
    check_serving_invariants(_fake_ctx(pool, tree))


# ---------------------------------------------------------------------------
# seeded chaos against a real paged serve under page pressure
# ---------------------------------------------------------------------------

CI_SEEDS = [0, 1, 2]  # the fixed fast-lane seeds (.github/workflows/ci.yml)


def _chaos_serve(cfg, params, seed):
    reqs = [Request(i, _prompt(200 + i, 8 + i, cfg.vocab_size), 12)
            for i in range(5)]
    # pool sized so the workload alone JUST fits — the injector's holds
    # are what create the pressure (and they must always find a free
    # page to steal at fire time, so the exhaustion count is meaningful)
    eng = _engine(cfg, params, slots=2, n_pages=12)
    chaos = ChaosInjector(eng, ChaosConfig(
        seed=seed, exhaust_rate=0.4, exhaust_pages=2, exhaust_hold=2,
        cancel_rate=0.08,
    ))
    fin = eng.serve(_mk(reqs), slots=2, sync_every=2,
                    on_iteration=chaos.on_iteration)
    chaos.release_all(eng._last_ctx)
    check_serving_invariants(eng._last_ctx)
    return reqs, eng, chaos, fin


@pytest.mark.parametrize("seed", CI_SEEDS)
def test_chaos_serve_survives_and_stays_exact(setup, seed):
    """Under seeded exhaustion + cancellation chaos: every request
    reaches exactly one terminal outcome, finished requests are
    bit-identical to a fault-free run, invariants hold after every
    iteration (checked inside the hook) and after teardown."""
    cfg, params = setup
    reqs, eng, chaos, fin = _chaos_serve(cfg, params, seed)
    by_rid = {f.rid: f for f in fin}
    assert sorted(by_rid) == [r.rid for r in reqs]
    assert {f.outcome for f in fin} <= {"finished", "cancelled"}
    # chaos actually injected something across the CI seeds
    assert chaos.exhaustions > 0
    # fault-free reference (ample pool): finished tokens must match
    ref_eng = _engine(cfg, params, slots=2)
    ref = {f.rid: f for f in ref_eng.serve(_mk(reqs), slots=2, sync_every=2)}
    for f in fin:
        if f.outcome == "finished":
            np.testing.assert_array_equal(f.tokens, ref[f.rid].tokens)
        else:
            assert f.rid in set(chaos.cancelled)
            np.testing.assert_array_equal(
                f.tokens, ref[f.rid].tokens[: len(f.tokens)])
    assert eng.last_stats.cancelled == sum(
        f.outcome == "cancelled" for f in fin)
    # final pool state is tree-only (all slots + holds released)
    pool, tree = eng._last_pool, eng._last_ptree
    tp = set(tree.tree_pages())
    for p in range(pool.n_pages):
        assert pool.refs[p] == (1 if p in tp else 0)


def test_chaos_is_deterministic_per_seed(setup):
    """Same seed, same workload -> identical injection points, identical
    cancellations, identical outcome map (the CI-diffability contract)."""
    cfg, params = setup
    _, _, chaos_a, fin_a = _chaos_serve(cfg, params, seed=1)
    _, _, chaos_b, fin_b = _chaos_serve(cfg, params, seed=1)
    assert chaos_a._exhaust.fired_at == chaos_b._exhaust.fired_at
    assert chaos_a.cancelled == chaos_b.cancelled
    out_a = sorted((f.rid, f.outcome, len(f.tokens)) for f in fin_a)
    out_b = sorted((f.rid, f.outcome, len(f.tokens)) for f in fin_b)
    assert out_a == out_b


def test_chaos_straggler_injection_flags(setup):
    """A slow-decode-chunk injection (sleep inside the loop) is flagged
    by the shared StragglerMonitor wired into the injector."""
    cfg, params = setup
    reqs = [Request(0, _prompt(300, 8, cfg.vocab_size), 40)]
    eng = _engine(cfg, params, slots=1)
    chaos = ChaosInjector(eng, ChaosConfig(
        seed=3, straggle_rate=0.15, straggle_seconds=0.25,
    ))
    # warm the jit caches first so compile time doesn't drown the median
    eng.serve(_mk(reqs), slots=1, sync_every=2)
    fin = eng.serve(_mk(reqs), slots=1, sync_every=2,
                    on_iteration=chaos.on_iteration)
    assert fin[0].outcome == "finished"
    assert chaos._straggle.fired_at  # injections happened...
    assert chaos.monitor.flagged  # ...and the watchdog caught them


# ---------------------------------------------------------------------------
# shared fault vocabulary (distributed/fault.py extensions)
# ---------------------------------------------------------------------------


def test_fault_injector_multi_step_fires_each_once():
    inj = FaultInjector(fail_at_steps=(3, 7))
    fired = []
    for step in range(10):
        try:
            inj.check(step)
        except InjectedFault:
            fired.append(step)
    assert fired == [3, 7]
    # a second pass over the same steps stays quiet (each point is once)
    for step in range(10):
        inj.check(step)


def test_fault_schedule_is_seed_deterministic():
    a = FaultSchedule(seed=42, rate=0.3)
    b = FaultSchedule(seed=42, rate=0.3)
    ha = [a.fires(i) for i in range(200)]
    hb = [b.fires(i) for i in range(200)]
    assert ha == hb and a.fired_at == b.fired_at
    assert 0 < sum(ha) < 200  # actually samples both outcomes
    assert a.pick([10, 20, 30]) == b.pick([10, 20, 30])
    c = FaultSchedule(seed=43, rate=0.3)
    assert [c.fires(i) for i in range(200)] != ha  # seed matters


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(window=10, factor=3.0)
    for i in range(8):
        assert not mon.record(i, 0.01)
    assert mon.record(8, 0.1)  # 10x the median
    assert mon.flagged and mon.flagged[0][0] == 8
