"""Fault-tolerant multi-replica serving (ISSUE 9 tentpole).

Layers:

  * **bit-exact failover** — under seeded replica kills, stalls and
    handoff corruption (the three fixed CI seeds), every request reaches
    exactly one terminal outcome and greedy outputs are bit-identical to
    a faultless single-engine run, with the fleet invariant checker
    green after every router tick;
  * **lifecycle-stage kills** — a replica dies while its requests are
    queued, mid-prefill, mid-decode, and mid-migration (double kill);
  * **migration mechanics** — warm drain ships checksummed fp8 KV
    payloads that seed the survivor's prefix cache (prefix reuse > 0);
    corruption is detected (``HandoffError``) and degrades to cold
    recompute, never to wrong tokens;
  * **slot-state serialization** — export → import round-trips
    bit-identically for tiered and paged layouts; the fp8 wire payload
    is 4x smaller than the f32 wire form (and 2x smaller than native
    bf16); checksum mismatch raises the typed error;
  * **control plane** — least-loaded placement, deterministic
    backoff/retry reconciliation, heartbeat health checks on an
    injected clock, restart through ``run_with_recovery`` with injected
    restart failures, the kill+cancel same-tick race, and
    ``PreemptionGuard`` graceful drain (including the signal-handler
    path, triggered manually);
  * the fleet invariant checker itself is **falsifiable** — hand-built
    violations raise.
"""

import signal
from types import SimpleNamespace

import jax
import numpy as np
import pytest

import repro.core.kv_cache as kvc
from repro.configs import get_smoke_config
from repro.core.kv_cache import HandoffError
from repro.distributed.fault import (FaultInjector, InjectedFault,
                                     PreemptionGuard)
from repro.models import transformer as T
from repro.serving import (Engine, FleetChaosConfig, FleetChaosInjector,
                           InvariantViolation, LocalTransport, Replica,
                           ReplicaDead, Request, Router,
                           check_fleet_invariants)

CI_SEEDS = [0, 1, 2]
HOT, ML, PS = 4, 64, 8


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("sync_every", 2)
    return Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4,
                  paged=True, page_size=PS, **kw)


def _prompt(seed, n, vocab):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab), np.int32
    )


def _reqs(cfg, n=5, base_rid=0, budget=10):
    return [
        Request(rid=base_rid + i, tokens=_prompt(i, 6 + i, cfg.vocab_size),
                max_new_tokens=budget)
        for i in range(n)
    ]


def _fleet(cfg, params, n=2, **router_kw):
    reps = [Replica(f"r{i}", _engine(cfg, params)) for i in range(n)]
    return Router(reps, **router_kw), reps


@pytest.fixture(scope="module")
def reference(setup):
    """Faultless single-engine terminal tokens, keyed by rid offset."""
    cfg, params = setup
    fins = _engine(cfg, params).serve(_reqs(cfg))
    return {f.rid: f.tokens for f in fins}


def _assert_bit_exact(fins, reference, base_rid=0):
    assert len(fins) == len(reference)
    for f in fins:
        assert f.outcome == "finished", (f.rid, f.outcome)
        np.testing.assert_array_equal(f.tokens, reference[f.rid - base_rid])


# ---------------------------------------------------------------------------
# bit-exact failover under seeded chaos (the CI smoke: 3 fixed seeds)
# ---------------------------------------------------------------------------


def _tick_clock(step=0.005):
    """Deterministic clock: advances a fixed amount per READ, so backoff
    windows are measured in control-flow events, not wall time — two
    identical runs see identical clocks regardless of jit compilation."""
    state = {"t": 0.0}

    def clock():
        state["t"] += step
        return state["t"]

    return clock


@pytest.mark.parametrize("seed", CI_SEEDS)
def test_kill_and_migrate_bit_exact(setup, reference, seed):
    cfg, params = setup
    rid0 = 100 * (seed + 1)

    def run():
        router, _ = _fleet(cfg, params, seed=seed, clock=_tick_clock(),
                           sleep=lambda s: None, straggler_drain=False)
        chaos = FleetChaosInjector(
            FleetChaosConfig(seed=seed, kill_rate=0.3, max_kills=2))
        fins = router.serve(_reqs(cfg, base_rid=rid0),
                            on_tick=chaos.on_tick)
        return router, chaos, fins

    router, chaos, fins = run()
    _assert_bit_exact(fins, reference, base_rid=rid0)
    assert chaos.kills, "seeded schedule must actually kill"
    assert router.stats.cold_migrations > 0
    assert router.stats.restarts == len(chaos.kills)
    # determinism: same seed → same injection points, same counters,
    # same tokens (the injected clock removes wall-time influence)
    router2, chaos2, fins2 = run()
    assert chaos2.kills == chaos.kills
    assert router2.stats.cold_migrations == router.stats.cold_migrations
    assert router2.stats.ticks == router.stats.ticks
    for a, b in zip(fins, fins2):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_warm_migration_reuses_prefix(setup, reference):
    """A stall flags the replica as a straggler; the router drains it
    with KV handoffs — at least one survivor admission rides the
    imported prefix instead of recomputing from scratch."""
    cfg, params = setup
    router, _ = _fleet(cfg, params, seed=0)
    chaos = FleetChaosInjector(
        FleetChaosConfig(seed=0, stall_rate=0.25, stall_seconds=0.3))
    fins = router.serve(_reqs(cfg, base_rid=300), on_tick=chaos.on_tick)
    _assert_bit_exact(fins, reference, base_rid=300)
    assert chaos.stalls
    assert router.stats.drains >= 1
    assert router.stats.warm_migrations >= 1
    assert router.stats.handoffs_imported >= 1
    assert sum(f.prefix_tokens_reused for f in fins) > 0


def test_corrupt_handoff_detected_falls_back_cold(setup, reference):
    """Every handoff is corrupted in flight: the checksum catches each
    one (typed HandoffError, counted), nothing seeds the receiver, and
    the outputs are STILL bit-exact via cold recompute-from-prefix."""
    cfg, params = setup
    router, _ = _fleet(cfg, params, seed=0)
    chaos = FleetChaosInjector(
        FleetChaosConfig(seed=0, stall_rate=0.25, stall_seconds=0.3,
                         corrupt_rate=1.0))
    fins = router.serve(_reqs(cfg, base_rid=400), on_tick=chaos.on_tick)
    _assert_bit_exact(fins, reference, base_rid=400)
    assert router.stats.warm_migrations >= 1
    assert router.stats.handoff_corruptions == router.stats.warm_migrations
    assert router.stats.handoffs_imported == 0


# ---------------------------------------------------------------------------
# kills at every lifecycle stage
# ---------------------------------------------------------------------------


def _kill_at_tick(router, name, tick):
    def hook(r):
        if r.stats.ticks == tick and not r.replicas[name].dead:
            r.replicas[name].kill()
        check_fleet_invariants(r)
    return hook


@pytest.mark.parametrize("tick", [1, 2, 4])
def test_kill_at_stage(setup, reference, tick):
    """tick 1 kills while victims are queued/mid-prefill (chunked
    admission is still streaming its first chunks), later ticks catch
    mid-decode. All stages recover bit-exactly."""
    cfg, params = setup
    rid0 = 500 + 20 * tick
    router, _ = _fleet(cfg, params, seed=0)
    fins = router.serve(_reqs(cfg, base_rid=rid0),
                        on_tick=_kill_at_tick(router, "r0", tick))
    _assert_bit_exact(fins, reference, base_rid=rid0)
    assert router.stats.replica_failures == 1


def test_kill_mid_migration_double_kill(setup, reference):
    """The target of a migration dies before it finishes the migrated
    work (second kill two ticks after the first): requests migrate
    twice and still finish bit-exactly."""
    cfg, params = setup
    router, _ = _fleet(cfg, params, seed=0, max_restarts=2)

    state = {"killed": 0, "first": None}

    def hook(r):
        t = r.stats.ticks
        if state["killed"] == 0 and t == 1:
            r.replicas["r0"].kill()
            state.update(killed=1, first=t)
        elif state["killed"] == 1 and t == state["first"] + 2:
            r.replicas["r1"].kill()
            state["killed"] = 2
        check_fleet_invariants(r)

    fins = router.serve(_reqs(cfg, base_rid=600), on_tick=hook)
    _assert_bit_exact(fins, reference, base_rid=600)
    assert state["killed"] == 2
    assert router.stats.replica_failures == 2


# ---------------------------------------------------------------------------
# the kill + cancel same-tick race (satellite: migration-boundary cancel)
# ---------------------------------------------------------------------------


def test_kill_and_cancel_same_tick(setup):
    """Cancel lands in the same tick the owning replica dies: the rid
    must get EXACTLY ONE terminal (outcome cancelled) — not resurrect on
    the survivor, not double-terminate — and both replicas' pools must
    reconcile (the fleet checker audits refcounts every tick)."""
    cfg, params = setup
    router, reps = _fleet(cfg, params, seed=0)
    reqs = _reqs(cfg, base_rid=700)
    victim_rid = reqs[0].rid

    fired = {"done": False}

    def hook(r):
        if not fired["done"] and r.stats.ticks == 1:
            owner = r.assigned.get(victim_rid)
            r.cancel(victim_rid)
            if owner is not None:
                r.replicas[owner].kill()
            fired["done"] = True
        check_fleet_invariants(r)

    fins = router.serve(reqs, on_tick=hook)
    assert fired["done"]
    terms = [f for f in fins if f.rid == victim_rid]
    assert len(terms) == 1
    assert terms[0].outcome == "cancelled"
    others = [f for f in fins if f.rid != victim_rid]
    assert all(f.outcome == "finished" for f in others)
    assert len(fins) == len(reqs)
    # pools reconcile to tree-only refs on every live replica
    for rep in reps:
        if rep.ctx is not None and rep.ctx.pool is not None:
            tree = rep.ctx.ptree.tree_pages()
            for p in range(rep.ctx.pool.n_pages):
                held = tree.count(p) if hasattr(tree, "count") else \
                    list(tree).count(p)
                assert int(rep.ctx.pool.refs[p]) == held


def test_cancel_mid_migration_window(setup):
    """Cancel lands while the request sits in the router's pending list
    BETWEEN harvest-from-dead-replica and re-admit-on-survivor: the
    tombstone stops the re-admission."""
    cfg, params = setup
    router, _ = _fleet(cfg, params, seed=0)
    reqs = _reqs(cfg, base_rid=720)
    victim_rid = reqs[1].rid
    state = {"phase": 0}

    def hook(r):
        if state["phase"] == 0 and r.stats.ticks == 1:
            owner = r.assigned.get(victim_rid)
            if owner is not None:
                r.replicas[owner].kill()
                state["phase"] = 1
        elif state["phase"] == 1:
            # the kill was harvested this tick: the rid is back in the
            # router's pending list — cancel it THERE
            assert any(p.req.rid == victim_rid for p in r.pending)
            r.cancel(victim_rid)
            state["phase"] = 2
        check_fleet_invariants(r)

    fins = router.serve(reqs, on_tick=hook)
    assert state["phase"] == 2
    terms = [f for f in fins if f.rid == victim_rid]
    assert len(terms) == 1 and terms[0].outcome == "cancelled"
    assert len(fins) == len(reqs)


def test_fresh_session_forgets_stale_cancels(setup):
    """A cancel mark left behind by a dead session must not shoot down
    an unrelated request in the engine's NEXT session (the rid-reuse
    hazard the start_session clear closes)."""
    cfg, params = setup
    eng = _engine(cfg, params)
    eng.cancel(740)  # stale mark, no such request yet
    fins = eng.serve([Request(rid=740, tokens=_prompt(0, 6, cfg.vocab_size),
                              max_new_tokens=4)])
    assert len(fins) == 1 and fins[0].outcome == "finished"


# ---------------------------------------------------------------------------
# slot-state serialization (satellite: round-trip + size + typed errors)
# ---------------------------------------------------------------------------


def _run_one_slot(cfg, params, **kw):
    """Serve one request partway and return (engine, ctx, slot)."""
    eng = _engine(cfg, params, **kw)
    ctx = eng.start_session(
        [Request(rid=1, tokens=_prompt(3, 14, cfg.vocab_size),
                 max_new_tokens=24)])
    for _ in range(8):
        eng.run_iteration(ctx)
    active = [s for s in ctx.sched.active_slots()
              if s not in ctx.prefilling]
    assert active, "request should be mid-decode"
    return eng, ctx, active[0]


def test_export_import_roundtrip_bit_identical(setup):
    """export → pack → unpack → import on a fresh engine reproduces the
    slot's KV rows bit-for-bit (paged layout, both tiers)."""
    cfg, params = setup
    eng, ctx, s = _run_one_slot(cfg, params)
    states = {k: kvc.export_slot_state(c, s)
              for k, c in ctx.state.cache.items()}
    blob = kvc.pack_slot_state(states, PS)
    back = kvc.unpack_slot_state(blob)
    assert set(back) == set(states)
    for key, st in states.items():
        for name in ("hot_k", "hot_v", "cold_k", "cold_v"):
            np.testing.assert_array_equal(st[name], back[key][name])
        assert back[key]["length"] == st["length"]

    # import into a second engine's fresh session: the written rows
    # must read back identically through its cache stacks
    eng2 = _engine(cfg, params)
    ctx2 = eng2.start_session(
        [Request(rid=2, tokens=_prompt(3, 14, cfg.vocab_size),
                 max_new_tokens=24)])
    for _ in range(8):
        eng2.run_iteration(ctx2)
    s2 = [t for t in ctx2.sched.active_slots() if t not in ctx2.prefilling][0]
    for key in ctx2.state.cache:
        new_cache = kvc.import_slot_state(
            ctx2.state.cache[key], s2, back[key])
        got = kvc.export_slot_state(new_cache, s2)
        for name in ("hot_k", "hot_v", "cold_k", "cold_v"):
            np.testing.assert_array_equal(got[name], states[key][name])


def test_roundtrip_tiered_unpaged_layout(setup):
    """The same serialization works on the contiguous tiered layout
    (no page table): non-paged engines can still export/import."""
    cfg, params = setup
    eng = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4,
                 slots=2, sync_every=2, paged=False)
    ctx = eng.start_session(
        [Request(rid=1, tokens=_prompt(4, 14, cfg.vocab_size),
                 max_new_tokens=24)])
    for _ in range(8):
        eng.run_iteration(ctx)
    s = [t for t in ctx.sched.active_slots() if t not in ctx.prefilling][0]
    states = {k: kvc.export_slot_state(c, s)
              for k, c in ctx.state.cache.items()}
    blob = kvc.pack_slot_state(states, PS)
    back = kvc.unpack_slot_state(blob)
    for key, st in states.items():
        for name in ("hot_k", "hot_v", "cold_k", "cold_v"):
            np.testing.assert_array_equal(st[name], back[key][name])


def test_fp8_payload_4x_smaller_than_f32_wire(setup):
    """The handoff ships rows in the tier STORAGE dtype: with kv_fp8 on
    that is ONE byte per element — 4x smaller than the f32 wire form a
    dtype-naive serializer would send (numpy upcasts fp8 payloads to
    f32 unless told otherwise, and the default engine cache here IS
    f32), and 2x smaller than a native-bf16 wire form."""
    import dataclasses as dc

    import ml_dtypes

    cfg, params = setup
    cfg8 = dc.replace(cfg, name=f"{cfg.name}-fp8wire",
                      bitnet=dc.replace(cfg.bitnet, kv_fp8=True))
    eng, ctx, s = _run_one_slot(cfg8, params)
    states8 = {k: kvc.export_slot_state(c, s)
               for k, c in ctx.state.cache.items()}
    any8 = next(iter(states8.values()))
    assert any8["hot_k"].dtype.itemsize == 1  # fp8 ships as 1 B/elem
    n8 = len(kvc.pack_slot_state(states8, PS))

    def recast(dtype):
        return {
            k: {n: (np.asarray(v).astype(dtype)
                    if isinstance(v, np.ndarray) else v)
                for n, v in st.items()}
            for k, st in states8.items()
        }

    n16 = len(kvc.pack_slot_state(recast(ml_dtypes.bfloat16), PS))
    n32 = len(kvc.pack_slot_state(recast(np.float32), PS))
    # the array BODIES scale exactly with itemsize; framing (magic, key
    # names, dtype strings, shapes, checksums) is a small shared tax
    body8 = sum(int(np.asarray(v).nbytes)
                for st in states8.values()
                for n, v in st.items() if isinstance(v, np.ndarray))
    assert n8 - body8 < 0.15 * n8  # framing is a sliver of the payload
    # the wire stores dtype NAMES, so frames differ by a few bytes per
    # array across dtypes — allow that slack, nothing more
    assert abs((n32 - n8) - 3 * body8) < 128  # f32 wire adds 3 bodies (4x)
    assert abs((n16 - n8) - 1 * body8) < 128  # bf16 wire adds 1 body (2x)
    assert n32 / n8 > 3.5 and n16 / n8 > 1.8
    assert n8 < n16 < n32

    # the default engine really does store f32 tiers (the naive wire
    # form is the honest baseline, not a strawman)
    eng0, ctx0, s0 = _run_one_slot(cfg, params)
    st0 = next(iter(ctx0.state.cache.values()))
    assert kvc.export_slot_state(st0, s0)["hot_k"].dtype.itemsize == 4


def test_checksum_mismatch_raises_typed_error(setup):
    cfg, params = setup
    eng, ctx, s = _run_one_slot(cfg, params)
    states = {k: kvc.export_slot_state(c, s)
              for k, c in ctx.state.cache.items()}
    blob = bytearray(kvc.pack_slot_state(states, PS))
    blob[len(blob) // 2] ^= 0xFF
    with pytest.raises(HandoffError) as ei:
        kvc.unpack_slot_state(bytes(blob))
    assert ei.value.key is not None  # names the corrupted entry
    with pytest.raises(HandoffError, match="torn"):
        kvc.unpack_slot_state(bytes(blob[: len(blob) // 3]))
    with pytest.raises(HandoffError):
        kvc.unpack_slot_state(b"NOPE" + bytes(blob)[4:])


def test_import_refuses_dtype_cast(setup):
    """import_slot_state must never silently cast KV bits."""
    cfg, params = setup
    eng, ctx, s = _run_one_slot(cfg, params)
    key = next(iter(ctx.state.cache))
    st = kvc.export_slot_state(ctx.state.cache[key], s)
    st = dict(st, hot_k=st["hot_k"].astype(np.float16))
    with pytest.raises(HandoffError, match="dtype"):
        kvc.import_slot_state(ctx.state.cache[key], s, st)


# ---------------------------------------------------------------------------
# control plane: placement, backoff, health, restart
# ---------------------------------------------------------------------------


def test_least_loaded_placement_spreads(setup):
    """With both replicas idle and equal, requests spread instead of
    piling on one replica."""
    cfg, params = setup
    router, reps = _fleet(cfg, params, seed=0)
    for rep in reps:
        rep.start()
    for r in _reqs(cfg, n=4, base_rid=800, budget=4):
        router.submit(r)
    router._dispatch()
    homes = set(router.assigned.values())
    assert homes == {"r0", "r1"}
    while router.tick():
        pass
    assert len(router.finished) == 4


def test_backoff_is_deterministic_and_reconciles(setup):
    """Same router seed → same backoff delays; retry counters reconcile
    with per-request dispatch surplus (the fleet checker's rule)."""
    cfg, params = setup

    def run():
        router, _ = _fleet(cfg, params, seed=7)
        delays = [router._backoff(a) for a in (1, 1, 2, 3, 4)]
        return delays

    a, b = run(), run()
    assert a == b
    assert all(x <= router_cap() * (1.5) for x in a)
    # monotone envelope: attempt k's un-jittered base doubles up to cap
    router, _ = _fleet(cfg, params, seed=7, backoff_jitter=0.0)
    bases = [router._backoff(k) for k in (1, 2, 3, 4, 5, 6)]
    assert bases == sorted(bases)
    assert bases[-1] == router.backoff_cap


def router_cap():
    return 0.5


def test_retry_budget_exhaustion_fails_terminally(setup):
    """A replica that dies every time it touches the work makes the
    request fail AFTER retry_limit dispatches — outcome 'failed',
    exactly one terminal, counters reconcile."""
    cfg, params = setup
    reps = [Replica("r0", _engine(cfg, params))]
    router = Router(reps, seed=0, retry_limit=2, max_restarts=3,
                    sleep=lambda s: None)

    def hook(r):
        # kill the lone replica whenever it holds live work
        rep = r.replicas["r0"]
        if not rep.dead and rep.busy():
            rep.kill()
        check_fleet_invariants(r)

    fins = router.serve(_reqs(cfg, n=1, base_rid=820), on_tick=hook)
    assert len(fins) == 1
    assert fins[0].outcome == "failed"
    assert router.attempts[820] == 2
    assert router.stats.failed == 1


def test_heartbeat_timeout_drains(setup):
    """A replica whose heartbeat goes stale (injected clock) is drained
    even with straggler detection off."""
    cfg, params = setup
    now = {"t": 0.0}
    clock = lambda: now["t"]  # noqa: E731
    reps = [Replica(f"r{i}", _engine(cfg, params), clock=clock)
            for i in range(2)]
    router = Router(reps, seed=0, straggler_drain=False,
                    heartbeat_timeout=5.0, clock=clock,
                    sleep=lambda s: None)
    fired = {"done": False}

    def hook(r):
        now["t"] += 0.1
        if not fired["done"] and r.stats.ticks == 2:
            # r0's heartbeat goes stale relative to the fake clock
            r.replicas["r0"].heartbeat = now["t"] - 10.0
            fired["done"] = True
        check_fleet_invariants(r)

    fins = router.serve(_reqs(cfg, base_rid=840), on_tick=hook)
    assert len(fins) == 5
    assert router.stats.drains >= 1


def test_restart_retries_through_run_with_recovery(setup):
    """A deterministically failing restart (FaultInjector on the
    replica) is retried by run_with_recovery and the replica rejoins."""
    cfg, params = setup
    router, reps = _fleet(cfg, params, seed=0, max_restarts=2)
    reps[0].restart_faults = FaultInjector(fail_at_steps=(1,))

    def hook(r):
        if r.stats.ticks == 1 and not r.replicas["r0"].dead:
            r.replicas["r0"].kill()
        check_fleet_invariants(r)

    fins = router.serve(_reqs(cfg, base_rid=860), on_tick=hook)
    assert len(fins) == 5
    assert all(f.outcome == "finished" for f in fins)
    assert reps[0].restart_faults.fired  # the injected failure happened
    assert not reps[0].dead  # ...and recovery retried past it
    assert router.stats.restarts == 1


def test_restart_budget_exhausted_replica_stays_dead(setup):
    """Every restart attempt fails: the replica is retired and the
    fleet finishes on the survivor."""
    cfg, params = setup
    router, reps = _fleet(cfg, params, seed=0, max_restarts=1)
    reps[0].restart_faults = FaultInjector(fail_at_steps=(1, 2, 3, 4, 5))

    def hook(r):
        if r.stats.ticks == 1 and not r.replicas["r0"].dead:
            r.replicas["r0"].kill()
        check_fleet_invariants(r)

    fins = router.serve(_reqs(cfg, base_rid=880), on_tick=hook)
    assert len(fins) == 5
    assert all(f.outcome == "finished" for f in fins)
    assert reps[0].dead
    assert "r0" in router._retired


# ---------------------------------------------------------------------------
# PreemptionGuard graceful drain (satellite)
# ---------------------------------------------------------------------------


def test_preemption_guard_graceful_drain(setup, reference):
    """guard.request() mid-serve: the engine finishes its iteration,
    folds the active slots, and returns early with the evacuated
    requests in last_drained; resubmitting them (fresh engine) yields
    the same tokens bit-exactly."""
    cfg, params = setup
    guard = PreemptionGuard()
    eng = _engine(cfg, params, guard=guard)
    reqs = _reqs(cfg, base_rid=900)

    def hook(ctx):
        if ctx.iteration == 2:
            guard.request()

    fins = eng.serve(reqs, on_iteration=hook)
    assert eng.last_drained, "drain must evacuate in-flight work"
    assert not guard.requested  # consumed by the drain
    drained_rids = {r.rid for r in eng.last_drained}
    assert drained_rids.isdisjoint({f.rid for f in fins})
    # resume elsewhere: a second engine completes the drained requests
    fins2 = _engine(cfg, params).serve(eng.last_drained)
    combined = {f.rid: f for f in list(fins) + list(fins2)}
    assert len(combined) == len(reqs)
    for f in combined.values():
        assert f.outcome == "finished"
        np.testing.assert_array_equal(f.tokens, reference[f.rid - 900])


def test_preemption_guard_signal_handler_path(setup):
    """The signal-handler body (pragma: no cover) flips the flag — call
    it directly, the way a real SIGTERM delivery would."""
    guard = PreemptionGuard(install_handlers=False)
    assert not guard.requested
    guard._handler(signal.SIGTERM, None)
    assert guard.requested


def test_router_uses_drain_for_warm_migration(setup):
    """Replica.drain (the guard's evacuation path) is what the router's
    health sweep calls: after a manual drain the work migrates and
    finishes on the fleet."""
    cfg, params = setup
    router, reps = _fleet(cfg, params, seed=0)
    state = {"drained": False}

    def hook(r):
        if not state["drained"] and r.stats.ticks == 2:
            rep = r.replicas["r0"]
            if rep.busy():
                r._drain_replica(rep, "manual")
                state["drained"] = True
        check_fleet_invariants(r)

    fins = router.serve(_reqs(cfg, base_rid=920), on_tick=hook)
    assert len(fins) == 5
    assert all(f.outcome == "finished" for f in fins)
    assert state["drained"] and router.stats.drains >= 1


# ---------------------------------------------------------------------------
# straggler stats wiring (satellite)
# ---------------------------------------------------------------------------


def test_serve_stats_iteration_times(setup):
    """Every serve() records per-iteration wall time: p50/max populated,
    and an injected slow iteration shows up in straggler_flags."""
    cfg, params = setup
    eng = _engine(cfg, params)
    import time as _time

    def hook(ctx):
        if ctx.iteration == 6:
            _time.sleep(0.3)

    eng.serve(_reqs(cfg, base_rid=940), on_iteration=hook)
    st = eng.last_stats
    assert st.iter_p50 > 0.0
    assert st.iter_max >= 0.3
    assert st.straggler_flags >= 1
    assert st.iter_max >= st.iter_p50


def test_replica_exposes_straggler_flags(setup):
    cfg, params = setup
    rep = Replica("r0", _engine(cfg, params))
    rep.start()
    assert rep.straggler_flags() == 0
    for r in _reqs(cfg, n=2, base_rid=960, budget=12):
        rep.submit(r)
    steps = 0
    while rep.busy():
        if steps == 5:  # after the monitor has its >=5 baseline samples
            rep.stall(0.3)
        rep.step()
        steps += 1
    assert steps >= 6  # the stalled iteration had its >=5-sample baseline
    assert rep.straggler_flags() >= 1


# ---------------------------------------------------------------------------
# the fleet checker is falsifiable
# ---------------------------------------------------------------------------


def _fake_router(**kw):
    base = dict(finished=[], pending=[], replicas={}, assigned={},
                attempts={}, accepted={},
                stats=SimpleNamespace(retries=0, failed=0))
    base.update(kw)
    return SimpleNamespace(**base)


def test_fleet_checker_catches_lost_request():
    r = _fake_router(accepted={1: object()})
    with pytest.raises(InvariantViolation, match="NOWHERE"):
        check_fleet_invariants(r)


def test_fleet_checker_catches_double_terminal():
    fin = SimpleNamespace(rid=1, outcome="finished")
    r = _fake_router(accepted={1: object()}, finished=[fin, fin])
    with pytest.raises(InvariantViolation, match="2 places"):
        check_fleet_invariants(r)


def test_fleet_checker_catches_rid_on_two_replicas():
    req = SimpleNamespace(rid=1)
    rep = lambda name: SimpleNamespace(  # noqa: E731
        name=name, dead=False,
        ctx=SimpleNamespace(sched=SimpleNamespace(queue=[req],
                                                  slot_req=[None]),
                            pool=None))
    r = _fake_router(accepted={1: req},
                     replicas={"a": rep("a"), "b": rep("b")})
    with pytest.raises(InvariantViolation, match="2 places"):
        check_fleet_invariants(r)


def test_fleet_checker_catches_shared_pool(setup):
    cfg, params = setup
    from repro.serving import PagePool
    pool = PagePool(4)
    mk = lambda name: SimpleNamespace(  # noqa: E731
        name=name, dead=False,
        ctx=SimpleNamespace(sched=SimpleNamespace(queue=[], slot_req=[]),
                            pool=pool, ptree=None, slot_pages=[],
                            host_table=None, spec=False))
    r = _fake_router(replicas={"a": mk("a"), "b": mk("b")})
    with pytest.raises(InvariantViolation, match="share one PagePool"):
        check_fleet_invariants(r)


def test_fleet_checker_catches_retry_mismatch():
    r = _fake_router(attempts={1: 3},
                     accepted={},
                     stats=SimpleNamespace(retries=0, failed=0))
    with pytest.raises(InvariantViolation, match="retries"):
        check_fleet_invariants(r)


# ---------------------------------------------------------------------------
# replica guard rails
# ---------------------------------------------------------------------------


def test_dead_replica_refuses_work(setup):
    cfg, params = setup
    rep = Replica("r0", _engine(cfg, params))
    rep.start()
    rep.kill()
    with pytest.raises(ReplicaDead):
        rep.submit(Request(rid=1, tokens=np.asarray([1, 2, 3], np.int32),
                           max_new_tokens=2))
    with pytest.raises(ReplicaDead):
        rep.step()
    with pytest.raises(ReplicaDead):
        rep.drain()


def test_local_transport_corruption_is_one_shot():
    t = LocalTransport()
    payload = bytes(range(64))
    t.corrupt_next()
    assert t.send(payload) != payload
    assert t.send(payload) == payload
    t.truncate_next()
    assert len(t.send(payload)) < len(payload)
    assert t.sent == 3 and t.corrupted == 2


def test_replica_devices_partitions_evenly():
    from repro.launch.mesh import replica_devices

    devs = list(range(8))  # partitioning is pure — any sequence works
    assert replica_devices(0, 2, devs) == (0, 1, 2, 3)
    assert replica_devices(1, 2, devs) == (4, 5, 6, 7)
    got = [replica_devices(i, 3, devs) for i in range(3)]
    assert all(len(g) == 2 for g in got)
    assert len({d for g in got for d in g}) == 6  # pairwise disjoint
    # fewer devices than replicas (CPU dev box): round-robin, never empty
    assert replica_devices(2, 4, [0, 1]) == (0,)
    assert replica_devices(3, 4, [0, 1]) == (1,)
    with pytest.raises(ValueError):
        replica_devices(2, 2, devs)
