"""Training substrate: optimizer (incl. 8-bit states), data pipeline,
grad accumulation, LoRA-only masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, DataIterator, batch_at_step
from repro.training import optimizer as opt_lib
from repro.training import train_lib

CFG = get_smoke_config("qwen3-8b")


def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    cfg = opt_lib.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    state = opt_lib.init(params, cfg)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state = opt_lib.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_quantized_state_tracks_fp32():
    """8-bit m/v AdamW stays close to the fp32 trajectory."""
    key = jax.random.PRNGKey(0)
    w0 = jax.random.normal(key, (64, 64))
    cfg32 = opt_lib.AdamWConfig(lr=0.01, warmup_steps=0, weight_decay=0.0)
    cfg8 = opt_lib.AdamWConfig(lr=0.01, warmup_steps=0, weight_decay=0.0,
                               quantized_state=True)
    p32, p8 = {"w": w0}, {"w": w0}
    s32, s8 = opt_lib.init(p32, cfg32), opt_lib.init(p8, cfg8)
    assert isinstance(s8.m["w"], opt_lib.QTensor)
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64, 64))}
        p32, s32 = opt_lib.update(g, s32, p32, cfg32)
        p8, s8 = opt_lib.update(g, s8, p8, cfg8)
    rel = float(jnp.linalg.norm(p8["w"] - p32["w"]) / jnp.linalg.norm(p32["w"]))
    assert rel < 0.05


def test_quantized_state_memory_4x_smaller():
    params = {"w": jnp.zeros((512, 512))}
    s32 = opt_lib.init(params, opt_lib.AdamWConfig())
    s8 = opt_lib.init(params, opt_lib.AdamWConfig(quantized_state=True))
    assert opt_lib.state_bytes(s8) < opt_lib.state_bytes(s32) / 3.5


def test_lr_schedule():
    cfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(opt_lib.lr_at(cfg, jnp.asarray(0))) < 2e-4
    assert float(opt_lib.lr_at(cfg, jnp.asarray(10))) == pytest.approx(1e-3, rel=0.01)
    assert float(opt_lib.lr_at(cfg, jnp.asarray(100))) == pytest.approx(1e-4, rel=0.05)


def test_data_pipeline_deterministic_and_resumable():
    it1 = DataIterator(CFG, DataConfig(seed=7), 4, 32)
    batches = [next(it1) for _ in range(3)]
    it2 = DataIterator(CFG, DataConfig(seed=7), 4, 32)
    it2.load_state_dict({"step": 2, "seed": 7})
    b2 = next(it2)
    np.testing.assert_array_equal(np.asarray(b2["tokens"]), np.asarray(batches[2]["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(batches[0]["labels"][:, :-1]), np.asarray(batches[0]["tokens"][:, 1:])
    )


def test_grad_accumulation_matches_full_batch():
    """n_micro=2 must produce (nearly) the same update as n_micro=1."""
    cfg = get_smoke_config("falcon3-1b")
    import repro.models.transformer as T

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=0)
    batch = batch_at_step(cfg, DataConfig(), 0, 8, 32)

    s1 = opt_lib.init(params, opt_cfg)
    step1 = train_lib.make_train_step(cfg, opt_cfg, n_micro=1)
    p1, _, m1 = step1(params, s1, batch)

    s2 = opt_lib.init(params, opt_cfg)
    step2 = train_lib.make_train_step(cfg, opt_cfg, n_micro=2)
    p2, _, m2 = step2(params, s2, batch)

    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    # Adam's first step is sign-normalized (upd ~ g/|g|), so elements whose
    # grad is ~0 may flip sign between accumulation orders and differ by up
    # to 2*lr. Require: bounded by 2*lr everywhere, and the flip fraction
    # (beyond float noise) is tiny.
    lr = opt_cfg.lr
    total, off = 0, 0
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        d = np.abs(np.asarray(a, np.float64) - np.asarray(b, np.float64))
        assert d.max() <= 2.05 * lr
        total += d.size
        off += int((d > 1e-5).sum())
    assert off / total < 0.01, f"{off}/{total} elements diverged"


def test_lora_only_freezes_base():
    import dataclasses

    import repro.models.transformer as T

    cfg = get_smoke_config("falcon3-1b")  # lora_rank=4 in smoke
    assert cfg.bitnet.lora_rank > 0
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = opt_lib.AdamWConfig(lr=1e-2, warmup_steps=0)
    state = opt_lib.init(params, opt_cfg)
    batch = batch_at_step(cfg, DataConfig(), 0, 4, 16)
    step = train_lib.make_train_step(cfg, opt_cfg, lora_only=True)
    p2, _, _ = step(params, state, batch)

    flat1 = jax.tree_util.tree_flatten_with_path(params)[0]
    flat2 = jax.tree.leaves(p2)
    changed_lora, changed_base = 0, 0
    for (path, a), b in zip(flat1, flat2):
        moved = not np.array_equal(np.asarray(a), np.asarray(b))
        if any("lora" in str(k) for k in path):
            changed_lora += moved
        else:
            changed_base += moved
    assert changed_lora > 0 and changed_base == 0  # the ROM stays fused
