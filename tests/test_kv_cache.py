"""Two-tier DR KV cache: routing, tiered attention vs single-buffer oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_cache


def _mk(batch=2, hot=4, cold=12, heads=2, dim=8, dtype=jnp.float32):
    return kv_cache.init_cache(batch, hot, cold, (heads, dim), dtype)


def test_append_routes_early_tokens_hot():
    cache = _mk()
    b, h, d = 2, 2, 8
    for t in range(6):
        k = jnp.full((b, h, d), float(t + 1))
        cache = kv_cache.append_decode(cache, k, k * 10)
    assert int(cache.length) == 6
    # tokens 0..3 in hot, 4..5 in cold
    np.testing.assert_allclose(np.asarray(cache.hot_k[0, :, 0, 0]), [1, 2, 3, 4])
    np.testing.assert_allclose(np.asarray(cache.cold_k[0, :2, 0, 0]), [5, 6])
    np.testing.assert_allclose(np.asarray(cache.cold_v[0, :2, 0, 0]), [50, 60])


def test_bulk_append_matches_decode_appends():
    cache_a = _mk()
    cache_b = _mk()
    ks = jax.random.normal(jax.random.PRNGKey(0), (2, 7, 2, 8))
    vs = jax.random.normal(jax.random.PRNGKey(1), (2, 7, 2, 8))
    cache_a = kv_cache.append(cache_a, ks, vs)
    for t in range(7):
        cache_b = kv_cache.append_decode(cache_b, ks[:, t], vs[:, t])
    for fa, fb in zip(cache_a, cache_b):
        np.testing.assert_allclose(np.asarray(fa), np.asarray(fb), rtol=1e-6)


def _oracle_attention(q, ks, vs):
    """Plain single-buffer attention oracle. q: (b,h,d); ks/vs: (b,t,g,d)."""
    b, t, g, d = ks.shape
    h = q.shape[1]
    rep = h // g
    qg = q.reshape(b, g, rep, d)
    logits = jnp.einsum("bgrd,bsgd->bgrs", qg, ks) * (d**-0.5)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, vs)
    return out.reshape(b, h, d)


@pytest.mark.parametrize("n_tokens", [1, 3, 4, 5, 11, 16])
def test_tiered_attention_matches_oracle(n_tokens):
    """Streaming-softmax merge over (hot, cold) == softmax over the concat."""
    cache = _mk()
    ks = jax.random.normal(jax.random.PRNGKey(2), (2, n_tokens, 2, 8))
    vs = jax.random.normal(jax.random.PRNGKey(3), (2, n_tokens, 2, 8))
    cache = kv_cache.append(cache, ks, vs)
    q = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 8))  # 4 q heads, 2 kv (GQA rep=2)
    got = kv_cache.tiered_decode_attention(q, cache)
    want = _oracle_attention(q, ks, vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_tiered_attention_hot_only():
    cache = _mk()
    ks = jax.random.normal(jax.random.PRNGKey(5), (2, 2, 2, 8))
    vs = jax.random.normal(jax.random.PRNGKey(6), (2, 2, 2, 8))
    cache = kv_cache.append(cache, ks, vs)
    q = jax.random.normal(jax.random.PRNGKey(7), (2, 2, 8))
    got = kv_cache.tiered_decode_attention(q, cache)
    want = _oracle_attention(q, ks, vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_append_is_jittable_and_scan_safe():
    cache = _mk()

    def step(c, kv):
        k, v = kv
        return kv_cache.append_decode(c, k, v), None

    ks = jax.random.normal(jax.random.PRNGKey(8), (10, 2, 2, 8))
    vs = jax.random.normal(jax.random.PRNGKey(9), (10, 2, 2, 8))
    final, _ = jax.lax.scan(step, cache, (ks, vs))
    assert int(final.length) == 10


def test_step_traffic_accounting():
    tb = 100  # bytes per token per step
    tr = kv_cache.step_traffic_bytes(length=40, hot_cap=32, token_bytes=tb)
    assert tr["ondie_read"] == 32 * tb
    assert tr["ext_read"] == 8 * tb
    assert tr["ext_write"] == tb  # position 40 >= hot_cap -> external write
    tr2 = kv_cache.step_traffic_bytes(length=10, hot_cap=32, token_bytes=tb)
    assert tr2["ext_read"] == 0 and tr2["ext_write"] == 0
