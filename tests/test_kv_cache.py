"""Two-tier DR KV cache: routing, per-slot lengths, tiered attention vs
single-buffer oracle, ring wrap-around, vectorized traffic ledger."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_cache


def _mk(batch=2, hot=4, cold=12, heads=2, dim=8, dtype=jnp.float32):
    return kv_cache.init_cache(batch, hot, cold, (heads, dim), dtype)


def test_append_routes_early_tokens_hot():
    cache = _mk()
    b, h, d = 2, 2, 8
    for t in range(6):
        k = jnp.full((b, h, d), float(t + 1))
        cache = kv_cache.append_decode(cache, k, k * 10)
    np.testing.assert_array_equal(np.asarray(cache.lengths), [6, 6])
    # tokens 0..3 in hot, 4..5 in cold
    np.testing.assert_allclose(np.asarray(cache.hot_k[0, :, 0, 0]), [1, 2, 3, 4])
    np.testing.assert_allclose(np.asarray(cache.cold_k[0, :2, 0, 0]), [5, 6])
    np.testing.assert_allclose(np.asarray(cache.cold_v[0, :2, 0, 0]), [50, 60])


def test_bulk_append_matches_decode_appends():
    cache_a = _mk()
    cache_b = _mk()
    ks = jax.random.normal(jax.random.PRNGKey(0), (2, 7, 2, 8))
    vs = jax.random.normal(jax.random.PRNGKey(1), (2, 7, 2, 8))
    cache_a = kv_cache.append(cache_a, ks, vs)
    for t in range(7):
        cache_b = kv_cache.append_decode(cache_b, ks[:, t], vs[:, t])
    for fa, fb in zip(cache_a, cache_b):
        np.testing.assert_allclose(np.asarray(fa), np.asarray(fb), rtol=1e-6)


def _oracle_attention(q, ks, vs):
    """Plain single-buffer attention oracle. q: (b,h,d); ks/vs: (b,t,g,d)."""
    b, t, g, d = ks.shape
    h = q.shape[1]
    rep = h // g
    qg = q.reshape(b, g, rep, d)
    logits = jnp.einsum("bgrd,bsgd->bgrs", qg, ks) * (d**-0.5)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, vs)
    return out.reshape(b, h, d)


@pytest.mark.parametrize("n_tokens", [1, 3, 4, 5, 11, 16])
def test_tiered_attention_matches_oracle(n_tokens):
    """Streaming-softmax merge over (hot, cold) == softmax over the concat."""
    cache = _mk()
    ks = jax.random.normal(jax.random.PRNGKey(2), (2, n_tokens, 2, 8))
    vs = jax.random.normal(jax.random.PRNGKey(3), (2, n_tokens, 2, 8))
    cache = kv_cache.append(cache, ks, vs)
    q = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 8))  # 4 q heads, 2 kv (GQA rep=2)
    got = kv_cache.tiered_decode_attention(q, cache)
    want = _oracle_attention(q, ks, vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_tiered_attention_hot_only():
    cache = _mk()
    ks = jax.random.normal(jax.random.PRNGKey(5), (2, 2, 2, 8))
    vs = jax.random.normal(jax.random.PRNGKey(6), (2, 2, 2, 8))
    cache = kv_cache.append(cache, ks, vs)
    q = jax.random.normal(jax.random.PRNGKey(7), (2, 2, 8))
    got = kv_cache.tiered_decode_attention(q, cache)
    want = _oracle_attention(q, ks, vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_append_is_jittable_and_scan_safe():
    cache = _mk()

    def step(c, kv):
        k, v = kv
        return kv_cache.append_decode(c, k, v), None

    ks = jax.random.normal(jax.random.PRNGKey(8), (10, 2, 2, 8))
    vs = jax.random.normal(jax.random.PRNGKey(9), (10, 2, 2, 8))
    final, _ = jax.lax.scan(step, cache, (ks, vs))
    np.testing.assert_array_equal(np.asarray(final.lengths), [10, 10])


# ---------------------------------------------------------------------------
# per-slot (continuous batching) behaviour
# ---------------------------------------------------------------------------


def test_mixed_length_slots_attention_matches_oracle():
    """Slots at different lengths each attend to exactly their own prefix."""
    b, hot, cold = 3, 4, 12
    cache = kv_cache.init_cache(b, hot, cold, (2, 8), jnp.float32)
    lens = [2, 9, 14]
    ks = jax.random.normal(jax.random.PRNGKey(10), (b, 16, 2, 8))
    vs = jax.random.normal(jax.random.PRNGKey(11), (b, 16, 2, 8))
    # build per-slot lengths via active-masked decode appends
    for t in range(16):
        active = jnp.asarray([t < L for L in lens])
        cache = kv_cache.append_decode(cache, ks[:, t], vs[:, t], active=active)
    np.testing.assert_array_equal(np.asarray(cache.lengths), lens)
    q = jax.random.normal(jax.random.PRNGKey(12), (b, 4, 8))
    got = kv_cache.tiered_decode_attention(q, cache)
    for i, L in enumerate(lens):
        want = _oracle_attention(q[i : i + 1], ks[i : i + 1, :L], vs[i : i + 1, :L])
        np.testing.assert_allclose(
            np.asarray(got[i : i + 1]), np.asarray(want), rtol=2e-5, atol=2e-5
        )


def test_inactive_slots_do_not_write():
    cache = _mk()
    k1 = jnp.ones((2, 2, 8))
    cache = kv_cache.append_decode(cache, k1, k1, active=jnp.asarray([True, False]))
    np.testing.assert_array_equal(np.asarray(cache.lengths), [1, 0])
    assert float(jnp.abs(cache.hot_k[1]).sum()) == 0.0
    np.testing.assert_allclose(np.asarray(cache.hot_k[0, 0]), np.asarray(k1[0]))


def test_per_slot_bulk_append_from_unequal_starts():
    """append() continues from each slot's own length."""
    cache = _mk(batch=2, hot=2, cold=10)
    # advance slot 0 by 3 tokens, slot 1 stays empty
    for t in range(3):
        k = jnp.full((2, 2, 8), float(t + 1))
        cache = kv_cache.append_decode(cache, k, k, active=jnp.asarray([True, False]))
    ks = jnp.stack([jnp.full((2, 2, 8), 7.0), jnp.full((2, 2, 8), 9.0)])  # (b,2,g,d)
    cache = kv_cache.append(cache, ks, ks)
    np.testing.assert_array_equal(np.asarray(cache.lengths), [5, 2])
    # slot 0: positions 3,4 -> cold slots 1,2 (hot_cap=2)
    np.testing.assert_allclose(np.asarray(cache.cold_k[0, 1:3, 0, 0]), [7, 7])
    # slot 1: positions 0,1 -> hot slots 0,1
    np.testing.assert_allclose(np.asarray(cache.hot_k[1, :2, 0, 0]), [9, 9])


def test_ring_cold_tier_wraparound_per_slot():
    """append_decode_ring keeps exactly the last cold_cap tokens per slot,
    at slot (p - hot_cap) % cold_cap, including after wrap-around — and
    slots can wrap independently."""
    b, hot, cold = 2, 0, 4
    cache = kv_cache.init_cache(b, hot, cold, (1, 4), jnp.float32)
    lens = [7, 3]  # slot 0 wraps (7 > 4), slot 1 does not
    for t in range(7):
        k = jnp.stack([jnp.full((1, 4), float(10 + t)), jnp.full((1, 4), float(20 + t))])
        active = jnp.asarray([t < lens[0], t < lens[1]])
        cache = kv_cache.append_decode_ring(cache, k, k, active=active)
    np.testing.assert_array_equal(np.asarray(cache.lengths), lens)
    # slot 0 holds tokens 3..6 at ring positions p % 4
    want0 = [0.0] * 4
    for p in range(3, 7):
        want0[p % 4] = 10.0 + p
    np.testing.assert_allclose(np.asarray(cache.cold_k[0, :, 0, 0]), want0)
    # slot 1 holds tokens 0..2 in order, last ring slot untouched
    np.testing.assert_allclose(np.asarray(cache.cold_k[1, :, 0, 0]), [20, 21, 22, 0])
    # validity clamps at cold_cap: all 4 positions valid for the wrapped
    # slot, 3 for the unwrapped one
    q = jax.random.normal(jax.random.PRNGKey(13), (b, 1, 4))
    got = kv_cache.tiered_decode_attention(q, cache)
    ks0 = cache.cold_k[0:1]  # ring content (order irrelevant to attention)
    want = _oracle_attention(q[0:1], ks0, ks0)
    np.testing.assert_allclose(np.asarray(got[0:1]), np.asarray(want), rtol=2e-5, atol=2e-5)
    ks1 = cache.cold_k[1:2, :3]
    want1 = _oracle_attention(q[1:2], ks1, ks1)
    np.testing.assert_allclose(np.asarray(got[1:2]), np.asarray(want1), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# traffic ledger
# ---------------------------------------------------------------------------


def test_step_traffic_accounting():
    tb = 100  # bytes per token per step
    tr = kv_cache.step_traffic_bytes(length=40, hot_cap=32, token_bytes=tb)
    assert tr["ondie_read"] == 32 * tb
    assert tr["ext_read"] == 8 * tb
    assert tr["ext_write"] == tb  # position 40 >= hot_cap -> external write
    tr2 = kv_cache.step_traffic_bytes(length=10, hot_cap=32, token_bytes=tb)
    assert tr2["ext_read"] == 0 and tr2["ext_write"] == 0


def test_step_traffic_tokens_matches_scalar_form():
    """Vectorized per-slot ledger == scalar ledger at every length."""
    hot = 8
    lengths = jnp.asarray([0, 1, 7, 8, 9, 40], jnp.int32)
    vec = kv_cache.step_traffic_tokens(lengths, hot)
    for i, L in enumerate(np.asarray(lengths)):
        scal = kv_cache.step_traffic_bytes(int(L), hot, token_bytes=1)
        for k in kv_cache.TRAFFIC_KEYS:
            assert int(vec[k][i]) == scal[k], (k, int(L))


@pytest.mark.parametrize("p_len", [0, 1, 3, 8, 9, 17])
def test_prompt_traffic_closed_form_matches_step_sum(p_len):
    hot = 8
    want = {k: 0 for k in kv_cache.TRAFFIC_KEYS}
    for i in range(p_len):
        tr = kv_cache.step_traffic_bytes(i, hot, token_bytes=1)
        for k in want:
            want[k] += tr[k]
    got = kv_cache.prompt_traffic_tokens(p_len, hot)
    assert got == want
