"""DR eDRAM reproduction tests — the paper's 43.6% claim and Fig. 5(b)."""

import pytest

from repro.core import dr_edram


def test_paper_headline_43_6_percent():
    """S=128, B=32 must give exactly the paper's 43.6% reduction."""
    r = dr_edram.closed_form_reduction(128, 32)
    assert r == pytest.approx(0.43605, abs=1e-5)
    assert round(r * 100, 1) == 43.6


def test_simulator_matches_closed_form():
    for s, b in [(32, 4), (64, 16), (128, 32), (256, 64), (128, 128), (16, 16)]:
        tr = dr_edram.simulate(s, b)
        expect = dr_edram.closed_form_reduction(s, b)
        assert tr.reduction == pytest.approx(expect, abs=1e-9), (s, b)


def test_simulator_total_accesses():
    tr = dr_edram.simulate(128, 32)
    # S writes + S(S-1)/2 reads
    assert tr.total == 128 + 128 * 127 // 2 == 8256
    assert tr.external == 8256 - 3600


def test_early_tokens_read_most():
    """Paper §IV property (i)/(ii): token i is read S-1-i times."""
    s = 64
    tr = dr_edram.simulate(s, 8)
    for i, reads in enumerate(tr.reads_per_token):
        assert reads == s - 1 - i


def test_refresh_invariant_every_step():
    """Every resident row is touched every decode step (gap == 1) =>
    decode-driven refresh works iff TBT < tREF."""
    tr = dr_edram.simulate(64, 16)
    assert tr.max_touch_gap == 1
    assert dr_edram.refresh_ok(128, 32, tbt_ms=50.0)  # TBT 50ms < 64ms
    assert not dr_edram.refresh_ok(128, 32, tbt_ms=70.0)


def test_fig5b_quarter_buffer_halves_traffic():
    """Paper: 'relocating only 1/4 of the early tokens ... reduces the DRAM
    access rate by nearly half'."""
    for s in (32, 64, 128, 256):
        r = dr_edram.closed_form_reduction(s, s // 4)
        assert 0.40 <= r <= 0.50, (s, r)


def test_fig5b_monotonicity():
    tbl = dr_edram.fig5b_sweep()
    for s, row in tbl.items():
        vals = [row[b] for b in sorted(row)]
        assert all(b2 > b1 for b1, b2 in zip(vals, vals[1:]))  # more buffer, more saving
    # longer sequence, same buffer => smaller relative saving
    assert tbl[256][32] < tbl[128][32] < tbl[64][32]


def test_edram_capacity_falcon3_1b():
    """Paper §V-B: 13.5 MB DR eDRAM for Falcon3-1B, S=128, 32 tokens, 6 batches."""
    nbytes = dr_edram.edram_bytes(
        buffered_tokens=32, n_layers=18, n_kv_heads=4, head_dim=256, n_batches=6
    )
    assert nbytes == 32 * 18 * 2 * 6 * 4 * 256 * 2
    assert nbytes / 2**20 == pytest.approx(13.5, abs=0.01)


def test_full_buffer_removes_all_traffic():
    assert dr_edram.closed_form_reduction(64, 64) == pytest.approx(1.0)
