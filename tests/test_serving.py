"""Serving engine: packed generation, DR traffic accounting, zero reload."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import dr_edram
from repro.models import pack as pack_lib
from repro.models import transformer as T
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_generation_shapes_and_determinism(setup):
    cfg, params = setup
    eng = Engine(cfg, params, hot_cap=4, max_len=64)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab_size)
    r1 = eng.generate(prompts, max_new_tokens=6)
    r2 = eng.generate(prompts, max_new_tokens=6)
    assert r1.tokens.shape == (3, 6)
    np.testing.assert_array_equal(np.asarray(r1.tokens), np.asarray(r2.tokens))


def test_traffic_matches_closed_form(setup):
    """Measured on-die/external split == dr_edram closed form (writes+reads)."""
    cfg, params = setup
    hot = 8
    eng = Engine(cfg, params, hot_cap=hot, max_len=80)
    p_len, new = 16, 48
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, p_len), 0, cfg.vocab_size)
    res = eng.generate(prompts, max_new_tokens=new)
    seq = p_len + res.steps
    expect = dr_edram.closed_form_reduction(seq, hot)
    assert res.external_reduction == pytest.approx(expect, abs=0.02)


def test_packed_vs_qat_generation_equivalence(setup):
    """ROM (packed) weights must generate the same tokens as fake-quant."""
    cfg, params = setup
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab_size)
    toks_packed = Engine(cfg, params, hot_cap=4, max_len=48, pack=True).generate(
        prompts, max_new_tokens=5
    ).tokens
    toks_qat = Engine(cfg, params, hot_cap=4, max_len=48, pack=False).generate(
        prompts, max_new_tokens=5
    ).tokens
    np.testing.assert_array_equal(np.asarray(toks_packed), np.asarray(toks_qat))


def test_int8_embed_generation_close(setup):
    """Beyond-paper int8 embedding/lm_head: same argmax path at smoke scale."""
    import dataclasses

    cfg, params = setup
    cfg8 = dataclasses.replace(
        cfg, bitnet=dataclasses.replace(cfg.bitnet, embed_int8=True)
    )
    packed8 = pack_lib.pack_params(params, cfg8)
    from repro.core.bitlinear import Int8Linear

    assert isinstance(packed8["embed"], Int8Linear)
    logits8, _ = T.forward(packed8, cfg8, {"tokens": jnp.zeros((1, 8), jnp.int32)},
                           mode="packed", remat=False)
    packed = pack_lib.pack_params(params, cfg)
    logits, _ = T.forward(packed, cfg, {"tokens": jnp.zeros((1, 8), jnp.int32)},
                          mode="packed", remat=False)
    # int8 table quantization is near-lossless on logits
    rel = float(jnp.linalg.norm(logits8 - logits) / (jnp.linalg.norm(logits) + 1e-9))
    assert rel < 0.05


def test_zero_weight_reload(setup):
    cfg, params = setup
    eng = Engine(cfg, params, hot_cap=4, max_len=48)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab_size)
    eng.generate(prompts, max_new_tokens=4)
    eng.generate(prompts, max_new_tokens=4)
    assert eng.weight_loads == 0  # fabricated once, never reloaded
