"""BitNet a4.8 mode (paper headline config: 1.58-bit weights / 4-bit acts).

TriMLA takes 4-bit activations natively (8-bit runs 2-cycle bit-serial);
on TPU both execute as one int8 MXU pass (DESIGN.md §2.1) but the VALUES
must follow the 4-bit quantization grid. These tests exercise act_bits=4
end to end: forward, gradient, packed serving, and the hardware model's
4x energy ratio.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T


def _a4(cfg):
    return dataclasses.replace(cfg, bitnet=dataclasses.replace(cfg.bitnet, act_bits=4))


@pytest.mark.parametrize("arch", ["falcon3-1b", "mixtral-8x22b", "mamba2-130m"])
def test_a4_forward_and_grad(arch):
    cfg = _a4(get_smoke_config(arch))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size),
    }

    def loss(p):
        logits, aux = T.forward(p, cfg, batch, mode="qat", remat=False)
        lp = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.take_along_axis(lp, batch["labels"][..., None], -1)) + aux

    l, g = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l))
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


def test_a4_activations_on_16_level_grid():
    """Inside an A4 BitLinear the activation values occupy <= 16 levels/row."""
    from repro.core.ternary import act_quant

    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    q = act_quant(x, bits=4)
    for row in np.asarray(q.xq):
        assert len(np.unique(row)) <= 16
        assert row.min() >= -8 and row.max() <= 7


def test_a4_packed_serving_runs():
    from repro.serving.engine import Engine

    cfg = _a4(get_smoke_config("falcon3-1b"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, hot_cap=4, max_len=48)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab_size)
    res = eng.generate(prompts, max_new_tokens=4)
    assert res.tokens.shape == (2, 4)


def test_a4_vs_a8_energy_headline():
    """Paper Table III: 20.8 TOPS/W @A4 vs 5.2 @A8 — A4 is the headline."""
    from repro.hwmodel.model import energy_per_op_pj

    assert energy_per_op_pj(8) / energy_per_op_pj(4) == pytest.approx(4.0)


def test_a4_quality_degrades_gracefully():
    """A4 fake-quant forward stays correlated with the A8 forward."""
    cfg8 = get_smoke_config("falcon3-1b")
    cfg4 = _a4(cfg8)
    params = T.init_params(jax.random.PRNGKey(5), cfg8)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0, cfg8.vocab_size)}
    l8, _ = T.forward(params, cfg8, batch, mode="qat", remat=False)
    l4, _ = T.forward(params, cfg4, batch, mode="qat", remat=False)
    a, b = np.asarray(l8).ravel(), np.asarray(l4).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.95, corr
