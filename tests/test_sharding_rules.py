"""Sharding-rule unit tests (1-device mesh — structure, not placement)."""

import jax
import pytest
from jax.sharding import NamedSharding

from repro.configs import SHAPES, get_smoke_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import input_specs, make_bundle


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


@pytest.mark.parametrize("arch", ["qwen3-8b", "mixtral-8x22b", "mamba2-130m", "zamba2-7b"])
def test_param_shardings_cover_tree(arch, mesh):
    cfg = get_smoke_config(arch)
    from repro.launch.steps import param_specs

    for packed in (False, True):
        tree = param_specs(cfg, packed=packed)
        sh = shd.param_shardings(tree, cfg, mesh, "train" if not packed else "infer")
        flat_t = jax.tree.leaves(tree)
        flat_s = jax.tree.leaves(
            sh, is_leaf=lambda x: isinstance(x, NamedSharding)
        )
        assert len(flat_t) == len(flat_s)
        assert all(isinstance(s, NamedSharding) for s in flat_s)


def test_input_specs_no_allocation():
    """input_specs must return ShapeDtypeStructs (never device arrays)."""
    cfg = get_smoke_config("qwen3-8b")
    for shape_name in ("train_4k", "decode_32k"):
        args = input_specs(cfg, SHAPES[shape_name])
        for leaf in jax.tree.leaves(
            args, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
        ):
            assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)


def test_bundle_kinds():
    cfg = get_smoke_config("qwen3-8b")
    assert make_bundle(cfg, SHAPES["train_4k"]).kind == "train"
    assert make_bundle(cfg, SHAPES["prefill_32k"]).kind == "prefill"
    assert make_bundle(cfg, SHAPES["decode_32k"]).kind == "decode"


def test_applicable_shapes_rules():
    from repro.configs import applicable_shapes, get_config

    assert applicable_shapes(get_config("hubert-xlarge")) == ("train_4k", "prefill_32k")
    assert "long_500k" in applicable_shapes(get_config("mamba2-130m"))
    assert "long_500k" in applicable_shapes(get_config("zamba2-7b"))
    assert "long_500k" not in applicable_shapes(get_config("qwen3-8b"))
    # 31 combos = the 62-cell dry-run over two meshes (the quickstart
    # config and the serving-side speculative draft are not dry-run
    # targets)
    from repro.configs import list_configs

    combos = sum(
        len(applicable_shapes(get_config(a)))
        for a in list_configs()
        if a != "falcon3-1b" and not a.endswith("-draft")
    )
    assert combos == 31


def test_dryrun_records_complete():
    """If the dry-run artifacts exist, every expected cell must be present."""
    import json
    from pathlib import Path

    from repro.configs import applicable_shapes, get_config, list_configs

    d = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run results not generated in this checkout")
    for arch in list_configs():
        if arch == "falcon3-1b" or arch.endswith("-draft"):
            continue
        for shape in applicable_shapes(get_config(arch)):
            for mesh_name in ("single", "multi"):
                p = d / f"{arch}__{shape}__{mesh_name}.json"
                assert p.exists(), p.name
                r = json.loads(p.read_text())
                assert r["memory"]["argument_bytes"] > 0
                assert r["flops_total"] > 0
